"""Distributed ZenLDA iteration on a TPU mesh (paper Fig. 2 workflow).

One iteration, under ``shard_map`` on a ``(pod?, data, model)`` mesh:

  step 1  N_k is replicated (the "driver broadcast" is free in SPMD)
  step 2  model state is already resident: N_w|k sharded over `model`
          (replicated over data axes), N_k|d sharded over data axes
          (replicated over `model`) — the master->mirror ship becomes the
          sharding layout itself
  step 3  every device samples its token cell with iteration-start counts
          ("unsynchronized model", §4.1)
  step 4  mirror->master aggregation = psum of *delta* counts (§5.2 delta
          aggregation): ΔN_k|d over `model`, ΔN_w|k over data axes —
          optionally width-compressed (int16/int8), the TPU realization of
          "only the topic of changed tokens is transferred"
  step 5  ΔN_k aggregated from the word side only (as the paper does —
          docs outnumber words 100+x)

Sampling algorithms are resolved through the ``repro.algorithms`` registry
(DESIGN.md §4): any backend with ``supports_shard_map`` plugs into step 3 —
the dense paths (``zen_dense``, ``zen_cdf``, ``zen_pallas``) and the
padded-sparse ones (``zen_sparse``, ``zen_hybrid``, ``sparselda``,
``lightlda``), whose Alg. 2 row machinery runs cell-locally on the shard
blocks. The single-box trainer resolves the *same* entries.

The step makes no dense-backend assumptions: each backend declares its
static per-cell workspace through ``resolve_cell_knobs`` (padded row
widths, tiles), and data-driven widths are filled from the *sharded*
counts by ``resolve_dist_row_pads`` before the step is built — capacities
are per-shard row maxima (clamped to K), never a gather of the global
matrices.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import algorithms
from repro.algorithms import SamplerKnobs
from repro.core.graph import GridPartition
from repro.core.types import LDAHyperParams
from repro.utils import compat


@dataclasses.dataclass(frozen=True)
class DistConfig:
    algorithm: str = "zen_cdf"  # any registered backend w/ supports_shard_map
    sampling_method: str = "gumbel"  # zen_dense: gumbel | cdf
    # padded-sparse row widths (doc / word side). 0 = auto: fill from the
    # sharded counts via ``resolve_dist_row_pads``, else the backend's
    # static default via ``resolve_cell_knobs`` (shard_map workspaces need
    # concrete widths at trace time).
    max_kd: int = 0
    max_kw: int = 0
    num_mh: int = 8  # lightlda cycle-MH steps per token
    delta_dtype: str = "int32"  # int32 | int16 | int8 (psum payload width)
    rebuild_every: int = 0  # exact count rebuild period (0 = never)
    exclusion_start: int = 0  # 0 = disabled; else iteration to enable at
    # 0 = whole cell at once (zen_dense / zen_pallas memory knob); nonzero
    # values must divide the padded per-cell token count
    token_chunk: int = 0
    # doc-topic state width: counts are bounded by doc length, so int16
    # halves every N_kd pass (top-k extraction, delta apply, llh reads) —
    # §Perf iteration l4. Requires max doc length < 32768.
    kd_dtype: str = "int32"  # int32 | int16
    bt: int = 256  # zen_pallas token tile
    bk: int = 512  # zen_pallas topic tile
    bs: int = 128  # sparse-row lane tile (kernel suite v2, kernel (c))
    kernels: str = "auto"  # Pallas kernel dispatch: auto | on | off

    def knobs(self) -> SamplerKnobs:
        """The shared backend knob dataclass (the single ``knobs_from``
        derivation — same one ``RunConfig``/``TrainConfig`` use)."""
        return algorithms.knobs_from(self)


class DistLDAState(NamedTuple):
    """Global-view sharded state (a pytree; see ``state_shardings``)."""

    topic: jax.Array  # (cells, e_cell) int32
    prev_topic: jax.Array  # (cells, e_cell) int32
    n_wk: jax.Array  # (W_pad, K) int32
    n_kd: jax.Array  # (D_pad, K) int32
    n_k: jax.Array  # (K,) int32
    stale_iters: jax.Array  # (cells, e_cell) int32
    same_count: jax.Array  # (cells, e_cell) int32
    iteration: jax.Array  # () int32
    rng: jax.Array  # key


class DistLDAData(NamedTuple):
    """Static (per-run) sharded token data."""

    word: jax.Array  # (cells, e_cell) int32 — global relabeled ids
    doc: jax.Array  # (cells, e_cell) int32
    mask: jax.Array  # (cells, e_cell) bool


def _axes(mesh: Mesh) -> Tuple[Tuple[str, ...], str]:
    names = mesh.axis_names
    model = "model"
    data_axes = tuple(n for n in names if n != model)
    return data_axes, model


def state_shardings(mesh: Mesh) -> Tuple[DistLDAState, DistLDAData]:
    """NamedShardings for state/data pytrees (also the dry-run in_shardings)."""
    data_axes, model = _axes(mesh)
    cellspec = P(data_axes + (model,), None)
    tok = NamedSharding(mesh, cellspec)
    return (
        DistLDAState(
            topic=tok, prev_topic=tok,
            n_wk=NamedSharding(mesh, P(model, None)),
            n_kd=NamedSharding(mesh, P(data_axes, None)),
            n_k=NamedSharding(mesh, P()),
            stale_iters=tok, same_count=tok,
            iteration=NamedSharding(mesh, P()),
            rng=NamedSharding(mesh, P()),
        ),
        DistLDAData(word=tok, doc=tok, mask=tok),
    )


def _specs(mesh: Mesh) -> Tuple[DistLDAState, DistLDAData]:
    data_axes, model = _axes(mesh)
    cellspec = P(data_axes + (model,), None)
    return (
        DistLDAState(
            topic=cellspec, prev_topic=cellspec,
            n_wk=P(model, None), n_kd=P(data_axes, None), n_k=P(),
            stale_iters=cellspec, same_count=cellspec,
            iteration=P(), rng=P(),
        ),
        DistLDAData(word=cellspec, doc=cellspec, mask=cellspec),
    )


# ---------------------------------------------------------------------------
# The distributed step
# ---------------------------------------------------------------------------

def resolve_dist_row_pads(state: DistLDAState, cfg: DistConfig) -> DistConfig:
    """Fill auto (0) padded-row widths from the *sharded* counts.

    Capacity is the per-shard row maximum: the nnz reduction runs
    shard-locally under the arrays' sharding (no shard gathers another's
    block) and only two scalars reach the host. SPMD compiles one program
    for all shards, so the static width is the max over the per-shard
    maxima — lane-rounded and clamped to K (``shard_row_capacity``), which
    keeps a hot word's global density from exploding every cell's pad.

    The width is frozen into the compiled step, but rows keep moving: a
    row that later grows past the capacity is *truncated by the sparse
    tables* (its overflow topics become unproposable that iteration — a
    sampling-quality bias, never a count-corruption, since the driver
    merges deltas against the dense state). One lane multiple of headroom
    is added against that drift; random init starts rows near their
    occupancy ceiling, so growth past init+headroom is rare. The full
    answer is periodic re-resolution: ``TrainSession``'s "repad" schedule
    action re-runs this on the ``rebuild_every`` cadence against the
    current counts and rebuilds the jitted step when the widths changed.

    Host-side, once per (re)build — not callable inside jit/shard_map.
    """
    backend = algorithms.get(cfg.algorithm)
    if not backend.needs_row_pads or (cfg.max_kw and cfg.max_kd):
        return cfg
    from repro.core.zen_sparse import shard_row_capacity

    k = state.n_wk.shape[-1]
    return dataclasses.replace(
        cfg,
        max_kw=cfg.max_kw or min(shard_row_capacity(state.n_wk) + 8, k),
        max_kd=cfg.max_kd or min(shard_row_capacity(state.n_kd) + 8, k),
    )


def _compress_psum(delta: jax.Array, axes, dtype: str) -> jax.Array:
    """Width-compressed collective (§5.2 delta aggregation, TPU realization).

    int16/int8 halve/quarter the all-reduce payload. Saturating cast; any
    clipped residue is corrected by the periodic exact rebuild
    (``rebuild_every``) — same staleness-tolerance argument as the paper's.
    """
    if dtype == "int32":
        return jax.lax.psum(delta, axes)
    info = jnp.iinfo(jnp.int16 if dtype == "int16" else jnp.int8)
    small = jnp.clip(delta, info.min, info.max).astype(dtype)
    return jax.lax.psum(small, axes).astype(jnp.int32)


def make_dist_step(
    mesh: Mesh,
    hyper: LDAHyperParams,
    cfg: DistConfig,
    words_per_shard: int,
    docs_per_shard: int,
):
    """Build the jitted distributed iteration fn: (state, data) -> state."""
    data_axes, model = _axes(mesh)
    all_axes = data_axes + (model,)
    num_words_pad = words_per_shard * mesh.shape[model]
    state_spec, data_spec = _specs(mesh)
    k = hyper.num_topics
    backend = algorithms.get(cfg.algorithm)
    if not backend.supports_shard_map:
        raise ValueError(
            f"backend {cfg.algorithm!r} does not support shard_map cells; "
            f"mesh-capable backends: "
            f"{', '.join(n for n in algorithms.registered() if algorithms.get(n).supports_shard_map)}"
        )
    # the backend declares its static per-cell workspace (padded row
    # widths, tiles): every auto knob must be concrete before tracing
    knobs = backend.resolve_cell_knobs(cfg.knobs(), hyper)

    def local_step(state: DistLDAState, data: DistLDAData) -> DistLDAState:
        # local views --------------------------------------------------
        word = data.word.reshape(-1)
        doc = data.doc.reshape(-1)
        mask = data.mask.reshape(-1)
        z_old = state.topic.reshape(-1)
        stale_i = state.stale_iters.reshape(-1)
        same_t = state.same_count.reshape(-1)
        n_wk_l = state.n_wk  # (Ws, K) local block
        n_kd_l = state.n_kd  # (Ds, K)
        n_k = state.n_k

        col = jax.lax.axis_index(model)
        row = jax.lax.axis_index(data_axes[0])
        for ax in data_axes[1:]:
            row = row * mesh.shape[ax] + jax.lax.axis_index(ax)
        word_l = word - col * words_per_shard
        doc_l = doc - row * docs_per_shard

        dev = row * mesh.shape[model] + col
        key = jax.random.fold_in(state.rng, state.iteration)
        key = jax.random.fold_in(key, dev)
        k_sample, k_excl = jax.random.split(key)

        # converged-token exclusion (§5.1) ------------------------------
        if cfg.exclusion_start > 0:
            prob = jnp.clip(
                jnp.exp2(stale_i.astype(jnp.float32) - same_t.astype(jnp.float32)),
                0.0, 1.0,
            )
            u = jax.random.uniform(k_excl, z_old.shape)
            active = (u < prob) | (state.iteration < cfg.exclusion_start)
        else:
            active = jnp.ones_like(mask)
        active = active & mask

        # step 3: sample on stale counts — one registry-resolved call
        # (zen_dense / zen_cdf / zen_pallas / any future cell backend)
        z_prop = backend.cell_sweep(
            k_sample, word_l, doc_l, z_old, mask, n_wk_l, n_kd_l, n_k,
            hyper, num_words_pad, knobs,
        )
        z_new = jnp.where(active, z_prop, z_old)

        # step 4: delta aggregation (§5.2) -------------------------------
        # the delta buffers are built directly in the compressed dtype:
        # per-iteration per-(vertex, topic) changes are bounded by the
        # vertex's local token count, so int16 is exact for docs and safe
        # for all but ultra-hot words (periodic rebuild corrects any
        # saturation — §Perf iteration l3)
        ddt = jnp.int32 if cfg.delta_dtype == "int32" else jnp.dtype(cfg.delta_dtype)
        changed = (z_new != z_old) & mask
        inc = changed.astype(ddt)
        d_wk = (
            jnp.zeros(n_wk_l.shape, ddt)
            .at[word_l, z_new].add(inc)
            .at[word_l, z_old].add(-inc)
        )
        d_kd = (
            jnp.zeros(n_kd_l.shape, ddt)
            .at[doc_l, z_new].add(inc)
            .at[doc_l, z_old].add(-inc)
        )
        d_wk = jax.lax.psum(d_wk, data_axes).astype(jnp.int32)
        d_kd = jax.lax.psum(d_kd, (model,)).astype(jnp.int32)
        # step 5: N_k from the word side only (paper Fig. 2 step 5)
        d_k = jax.lax.psum(jnp.sum(d_wk, axis=0), model)

        # exclusion stats update
        proc_changed = changed
        new_i = jnp.where(active, 0, stale_i + 1)
        new_t = jnp.where(
            active, jnp.where(proc_changed, 0, same_t + 1), same_t
        )

        shp = state.topic.shape
        new_n_kd = (n_kd_l.astype(jnp.int32) + d_kd).astype(n_kd_l.dtype)
        return DistLDAState(
            topic=z_new.reshape(shp),
            prev_topic=z_old.reshape(shp),
            n_wk=n_wk_l + d_wk,
            n_kd=new_n_kd,
            n_k=n_k + d_k,
            stale_iters=new_i.reshape(shp),
            same_count=new_t.reshape(shp),
            iteration=state.iteration + 1,
            rng=state.rng,
        )

    step = compat.shard_map(
        local_step, mesh, (state_spec, data_spec), state_spec,
    )
    return jax.jit(step, donate_argnums=(0,))


def make_rebuild_counts(
    mesh: Mesh,
    hyper: LDAHyperParams,
    words_per_shard: int,
    docs_per_shard: int,
):
    """Exact count rebuild from assignments (elastic restore / drift fix)."""
    data_axes, model = _axes(mesh)
    state_spec, data_spec = _specs(mesh)
    k = hyper.num_topics

    def local(state: DistLDAState, data: DistLDAData) -> DistLDAState:
        word = data.word.reshape(-1)
        doc = data.doc.reshape(-1)
        mask = data.mask.reshape(-1)
        z = state.topic.reshape(-1)
        col = jax.lax.axis_index(model)
        row = jax.lax.axis_index(data_axes[0])
        for ax in data_axes[1:]:
            row = row * mesh.shape[ax] + jax.lax.axis_index(ax)
        word_l = word - col * words_per_shard
        doc_l = doc - row * docs_per_shard
        ones = mask.astype(jnp.int32)
        n_wk = jnp.zeros_like(state.n_wk).at[word_l, z].add(ones)
        n_kd = jnp.zeros(state.n_kd.shape, jnp.int32).at[doc_l, z].add(ones)
        n_wk = jax.lax.psum(n_wk, data_axes)
        n_kd = jax.lax.psum(n_kd, (model,)).astype(state.n_kd.dtype)
        n_k = jax.lax.psum(jnp.sum(n_wk, axis=0), model)
        return state._replace(n_wk=n_wk, n_kd=n_kd, n_k=n_k)

    fn = compat.shard_map(
        local, mesh, (state_spec, data_spec), state_spec,
    )
    return jax.jit(fn, donate_argnums=(0,))


def make_dist_llh(
    mesh: Mesh, hyper: LDAHyperParams, words_per_shard: int, docs_per_shard: int
):
    """Distributed predictive log-likelihood (paper footnote 6)."""
    data_axes, model = _axes(mesh)
    all_axes = data_axes + (model,)
    num_words_pad = words_per_shard * mesh.shape[model]
    state_spec, data_spec = _specs(mesh)

    def local(state: DistLDAState, data: DistLDAData) -> jax.Array:
        word = data.word.reshape(-1)
        doc = data.doc.reshape(-1)
        mask = data.mask.reshape(-1)
        col = jax.lax.axis_index(model)
        row = jax.lax.axis_index(data_axes[0])
        for ax in data_axes[1:]:
            row = row * mesh.shape[ax] + jax.lax.axis_index(ax)
        word_l = word - col * words_per_shard
        doc_l = doc - row * docs_per_shard
        alpha_k = hyper.alpha_k(state.n_k)
        alpha_sum = jnp.sum(alpha_k)
        n_d = jnp.sum(state.n_kd, axis=-1).astype(jnp.float32)  # (Ds,)
        w_beta = num_words_pad * hyper.beta
        theta = (state.n_kd[doc_l].astype(jnp.float32) + alpha_k[None, :]) / (
            n_d[doc_l][:, None] + alpha_sum
        )
        phi = (state.n_wk[word_l].astype(jnp.float32) + hyper.beta) / (
            state.n_k.astype(jnp.float32)[None, :] + w_beta
        )
        token_llh = jnp.log(jnp.maximum(jnp.sum(theta * phi, -1), 1e-30))
        local_sum = jnp.sum(jnp.where(mask, token_llh, 0.0))
        return jax.lax.psum(local_sum, all_axes)

    fn = compat.shard_map(
        local, mesh, (state_spec, data_spec), P(),
    )
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# State construction
# ---------------------------------------------------------------------------

def init_dist_state(
    rng: jax.Array,
    mesh: Mesh,
    grid: GridPartition,
    hyper: LDAHyperParams,
    init_topics: Optional[np.ndarray] = None,
    kd_dtype=jnp.int32,
) -> Tuple[DistLDAState, DistLDAData]:
    """Build + device_put the sharded state from a host GridPartition."""
    state_sh, data_sh = state_shardings(mesh)
    cells, e_cell = grid.word.shape
    k = hyper.num_topics
    if init_topics is None:
        init_topics = np.asarray(
            jax.random.randint(rng, (cells, e_cell), 0, k, dtype=jnp.int32)
        )
    data = DistLDAData(
        word=jax.device_put(jnp.asarray(grid.word), data_sh.word),
        doc=jax.device_put(jnp.asarray(grid.doc), data_sh.doc),
        mask=jax.device_put(jnp.asarray(grid.mask), data_sh.mask),
    )
    topic = jax.device_put(jnp.asarray(init_topics, jnp.int32), state_sh.topic)
    # distinct buffer: step functions donate the state, and donating one
    # buffer twice (topic aliasing prev_topic) is rejected by the runtime
    prev_topic = jax.device_put(jnp.asarray(init_topics, jnp.int32), state_sh.topic)
    zeros_tok = jax.device_put(
        jnp.zeros((cells, e_cell), jnp.int32), state_sh.stale_iters
    )
    zeros_tok2 = jax.device_put(
        jnp.zeros((cells, e_cell), jnp.int32), state_sh.same_count
    )
    state = DistLDAState(
        topic=topic,
        prev_topic=prev_topic,
        n_wk=jax.device_put(
            jnp.zeros((grid.num_words_padded, k), jnp.int32), state_sh.n_wk
        ),
        n_kd=jax.device_put(
            jnp.zeros((grid.num_docs_padded, k), kd_dtype), state_sh.n_kd
        ),
        n_k=jax.device_put(jnp.zeros((k,), jnp.int32), state_sh.n_k),
        stale_iters=zeros_tok,
        same_count=zeros_tok2,
        iteration=jnp.int32(0),
        rng=rng,
    )
    rebuild = make_rebuild_counts(
        mesh, hyper, grid.words_per_shard, grid.docs_per_shard
    )
    state = rebuild(state, data)
    return state, data
