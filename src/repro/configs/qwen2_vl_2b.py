"""qwen2-vl-2b [vlm]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936, M-RoPE (3-component), dynamic-resolution vision frontend
STUB (input_specs supplies patch embeddings + 3D position ids).
[arXiv:2409.12191; hf]

Pure full attention -> long_500k skipped.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    mrope=True,
    qkv_bias=True,
    rope_theta=1000000.0,
    tie_embeddings=True,
    skip_shapes=("long_500k",),
)
