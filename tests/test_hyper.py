"""Topic-duplicate merging (paper §4.3)."""
import jax.numpy as jnp
import numpy as np

from repro.core.hyper import duplicate_topic_map, merge_topics, topic_l1_distances


def test_l1_distances():
    n_wk = jnp.asarray([[10, 10, 0], [0, 0, 10], [10, 10, 0]], jnp.int32)
    d = np.asarray(topic_l1_distances(n_wk))
    assert d[0, 1] < 1e-6  # identical distributions
    assert d[0, 2] > 1.0  # disjoint -> L1 distance 2


def test_duplicate_map_and_merge():
    # topics 0 and 1 identical; 2 distinct
    n_wk = np.array([[5, 5, 0], [5, 5, 0], [0, 0, 10], [2, 2, 0]], np.int32)
    tmap = duplicate_topic_map(n_wk, threshold=0.1)
    assert tmap[1] == tmap[0] == 0
    assert tmap[2] == 2

    topic = jnp.asarray([0, 1, 2, 1], jnp.int32)
    n_kd = jnp.asarray([[1, 1, 1], [1, 1, 0]], jnp.int32)
    n_k = jnp.asarray(np.asarray(n_wk).sum(0), jnp.int32)
    new_topic, m_wk, m_kd, m_k = merge_topics(
        topic, jnp.asarray(n_wk), n_kd, n_k, jnp.asarray(tmap)
    )
    # conservation
    assert int(jnp.sum(m_wk)) == int(np.asarray(n_wk).sum())
    assert int(jnp.sum(m_k)) == int(np.asarray(n_wk).sum())
    # merged column got both topics' mass; old column emptied
    assert int(m_k[0]) == int(n_k[0] + n_k[1])
    assert int(m_k[1]) == 0
    np.testing.assert_array_equal(np.asarray(new_topic), [0, 0, 2, 0])


def test_lower_threshold_merges_more():
    rng = np.random.default_rng(0)
    n_wk = rng.integers(0, 5, (30, 8)).astype(np.int32)
    m_strict = duplicate_topic_map(n_wk, threshold=0.01)
    m_loose = duplicate_topic_map(n_wk, threshold=2.1)
    assert len(np.unique(m_loose)) <= len(np.unique(m_strict))


def test_degenerate_all_below_threshold_keeps_min_topics():
    """Regression: when EVERY pair is below threshold (e.g. a freshly
    initialized near-uniform model), the map used to collapse the whole
    model into topic 0.  The min-topic floor must keep >= 2 clusters."""
    n_wk = np.full((20, 6), 3, np.int32)  # all topics identical
    tmap = duplicate_topic_map(n_wk, threshold=10.0)
    assert len(np.unique(tmap)) == 2  # floor holds, not 1
    # floor respects K when min_topics > K
    tiny = duplicate_topic_map(np.full((4, 2), 1, np.int32),
                               threshold=10.0, min_topics=5)
    assert len(np.unique(tiny)) == 2


def test_degenerate_floor_merges_closest_pairs_first():
    """With a floor of 2, the surviving split must separate the truly
    distinct topic from the near-duplicates, not an arbitrary pair."""
    # topics 0..2 identical, topic 3 far but still under a huge threshold
    n_wk = np.array([[9, 9, 9, 0], [0, 0, 0, 9]], np.int32)
    tmap = duplicate_topic_map(n_wk, threshold=100.0)
    assert tmap[0] == tmap[1] == tmap[2] == 0  # duplicates merged
    assert tmap[3] == 3  # the distinct topic survives as its own cluster


def test_min_topics_one_restores_unguarded_collapse():
    n_wk = np.full((20, 6), 3, np.int32)
    tmap = duplicate_topic_map(n_wk, threshold=10.0, min_topics=1)
    np.testing.assert_array_equal(tmap, np.zeros(6, np.int32))


def test_floor_inert_on_normal_inputs():
    """Non-degenerate matrices merge exactly as before the floor."""
    n_wk = np.array([[5, 5, 0], [5, 5, 0], [0, 0, 10], [2, 2, 0]], np.int32)
    np.testing.assert_array_equal(
        duplicate_topic_map(n_wk, threshold=0.1),
        duplicate_topic_map(n_wk, threshold=0.1, min_topics=1),
    )
