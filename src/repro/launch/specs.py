"""``input_specs``: ShapeDtypeStruct stand-ins + shardings for every cell.

No device allocation anywhere — weak-type-correct abstract values only.
Each (arch x shape) cell resolves to:

  step_kind 'train'    -> train_step(state, batch)
  step_kind 'prefill'  -> prefill_step(params, batch)   (forward, logits)
  step_kind 'decode'   -> serve_step(params, token, caches)
  step_kind 'lda'      -> lda_step(state, data)         (one CGS iteration)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.configs.base import ArchConfig, LDAArchConfig, ShapeConfig
from repro.models.model import init_cache
from repro.sharding import (
    batch_sharding,
    cache_sharding,
    data_axes_of,
    param_shardings,
)
from repro.train.optimizer import OptConfig
from repro.train.train_step import init_train_state


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Abstract batch for a full-sequence (train/prefill) cell."""
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    batch: Dict[str, Any] = {}
    if cfg.family == "encdec":
        # stub audio frontend: precomputed frame embeddings
        batch["enc_embeds"] = _sds((b, s, cfg.d_model), dt)
        batch["tokens"] = _sds((b, s), jnp.int32)
    elif cfg.family == "vlm":
        # stub vision frontend: patch embeddings + 3D M-RoPE position ids
        batch["embeds"] = _sds((b, s, cfg.d_model), dt)
        batch["positions"] = _sds((b, s, 3), jnp.int32)
    else:
        batch["tokens"] = _sds((b, s), jnp.int32)
    if shape.kind == "train":
        batch["labels"] = _sds((b, s), jnp.int32)
    return batch


def params_abstract(cfg: ArchConfig) -> Any:
    from repro.models.model import init_params

    return jax.eval_shape(lambda k: init_params(k, cfg), jax.random.key(0))


def state_abstract(cfg: ArchConfig) -> Any:
    return jax.eval_shape(
        lambda k: init_train_state(k, cfg, OptConfig()), jax.random.key(0)
    )


def lm_cell_specs(
    cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh
) -> Tuple[str, Dict[str, Any], Dict[str, Any]]:
    """(step_kind, kwargs of ShapeDtypeStructs, kwargs of shardings)."""
    if shape.kind == "train":
        state = state_abstract(cfg)
        batch = batch_specs(cfg, shape)
        # params + opt state share the param rules; step scalar replicated
        p_sh = param_shardings(state.params, cfg, mesh)
        opt_sh = _opt_shardings(state.opt_state, state.params, cfg, mesh)
        from repro.train.train_step import TrainState

        st_sh = TrainState(
            params=p_sh, opt_state=opt_sh, step=NamedSharding(mesh, P())
        )
        return (
            "train",
            {"state": state, "batch": batch},
            {"state": st_sh, "batch": batch_sharding(batch, mesh)},
        )
    if shape.kind == "prefill":
        params = params_abstract(cfg)
        batch = batch_specs(cfg, shape)
        return (
            "prefill",
            {"params": params, "batch": batch},
            {
                "params": param_shardings(params, cfg, mesh),
                "batch": batch_sharding(batch, mesh),
            },
        )
    # decode
    params = params_abstract(cfg)
    b = shape.global_batch
    s_enc = shape.seq_len if cfg.family == "encdec" else 0
    caches = init_cache(cfg, b, shape.seq_len, s_enc=s_enc, abstract=True)
    token = _sds((b,), jnp.int32)
    dp = int(np.prod([mesh.shape[a] for a in data_axes_of(mesh)]))
    tok_sh = NamedSharding(
        mesh, P(data_axes_of(mesh)) if b % dp == 0 else P()
    )
    return (
        "decode",
        {"params": params, "token": token, "caches": caches},
        {
            "params": param_shardings(params, cfg, mesh),
            "token": tok_sh,
            "caches": cache_sharding(caches, mesh),
        },
    )


def _opt_shardings(opt_state, params, cfg, mesh):
    """Optimizer-state shardings: moments follow their param's rule; factored
    stats inherit the param rule with the reduced dim dropped; scalars
    replicate."""
    from repro.sharding.partition import param_specs
    from repro.train.optimizer import AdamWState, AdafactorState, FactoredStat

    p_specs = param_specs(params, cfg, mesh)
    if isinstance(opt_state, AdamWState):
        msh = jax.tree.map(
            lambda spec: NamedSharding(mesh, spec), p_specs,
            is_leaf=lambda x: isinstance(x, P),
        )
        return AdamWState(
            step=NamedSharding(mesh, P()), m=msh,
            v=jax.tree.map(
                lambda spec: NamedSharding(mesh, spec), p_specs,
                is_leaf=lambda x: isinstance(x, P),
            ),
        )
    assert isinstance(opt_state, AdafactorState)

    def stat_sh(spec, stat):
        if isinstance(stat, FactoredStat):
            row_spec = P(*spec[:-1]) if len(spec) else P()
            col_spec = P(*(tuple(spec[:-2]) + (spec[-1],))) if len(spec) >= 2 else P()
            return FactoredStat(
                row=NamedSharding(mesh, row_spec),
                col=NamedSharding(mesh, col_spec),
            )
        return NamedSharding(mesh, spec)

    stats = jax.tree.map(
        stat_sh, p_specs, opt_state.stats,
        is_leaf=lambda x: isinstance(x, P),
    )
    return AdafactorState(step=NamedSharding(mesh, P()), stats=stats)


# ---------------------------------------------------------------------------
# LDA cells
# ---------------------------------------------------------------------------

def lda_cell_specs(
    cfg: LDAArchConfig, mesh: Mesh
) -> Tuple[str, Dict[str, Any], Dict[str, Any], Dict[str, int]]:
    """Abstract DistLDAState/DistLDAData for one streaming iteration."""
    from repro.core.distributed import DistLDAData, DistLDAState, state_shardings

    data_axes = data_axes_of(mesh)
    dp = int(np.prod([mesh.shape[a] for a in data_axes]))
    mp = mesh.shape["model"]
    cells = dp * mp
    k = cfg.num_topics
    e_cell = int(np.ceil(cfg.tokens_per_step / cells / 8) * 8)
    wps = int(np.ceil(cfg.num_words / mp / 8) * 8)
    dps = int(np.ceil(cfg.docs_per_step / dp / 8) * 8)
    tok = _sds((cells, e_cell), jnp.int32)
    state = DistLDAState(
        topic=tok, prev_topic=tok,
        n_wk=_sds((wps * mp, k), jnp.int32),
        n_kd=_sds((dps * dp, k), jnp.dtype(getattr(cfg, "kd_dtype", "int32"))),
        n_k=_sds((k,), jnp.int32),
        stale_iters=tok, same_count=tok,
        iteration=_sds((), jnp.int32),
        rng=jax.eval_shape(lambda: jax.random.key(0)),
    )
    data = DistLDAData(
        word=tok, doc=tok, mask=_sds((cells, e_cell), jnp.bool_)
    )
    st_sh, dt_sh = state_shardings(mesh)
    dims = {"words_per_shard": wps, "docs_per_shard": dps, "e_cell": e_cell}
    return "lda", {"state": state, "data": data}, {
        "state": st_sh, "data": dt_sh,
    }, dims
