"""repro.observe — metric primitives, the shared latency math, and the
JSONL sink (DESIGN.md §8).

Pins the numbers, not just the shapes:
* ``summarize_latencies`` known answers (nearest-rank percentiles) plus
  the empty / single-element edge cases — this is the ONE summary every
  latency figure in the repo (serving CLI, bench_infer, telemetry
  windows) is computed with;
* ``nnz_row_stats`` against a hand-counted matrix;
* histogram bucket placement (scalar and bulk array paths agree);
* sink round-trip: every record parses, carries ``t``, and numpy
  payloads serialize;
* the serving telemetry window closes on the arrival budget and its
  summary fields come from the same shared math.
"""
import json
import math
import threading

import numpy as np
import pytest

from repro.observe import (
    Counter,
    Gauge,
    Histogram,
    JsonlSink,
    MetricsRegistry,
    ServeTelemetry,
    latency_percentile,
    nnz_row_stats,
    summarize_latencies,
)
from repro.observe.metrics import read_jsonl


# ---------------------------------------------------------------------------
# shared latency math (satellite: one percentile implementation)
# ---------------------------------------------------------------------------

def test_summarize_latencies_known_answers():
    stats = summarize_latencies(range(1, 101))  # 1..100, already sorted
    assert stats == {"count": 100, "p50": 51.0, "p99": 99.0,
                     "max": 100.0, "mean": 50.5}
    # order-independent: callers pass unsorted measurements
    shuffled = list(range(1, 101))
    np.random.default_rng(0).shuffle(shuffled)
    assert summarize_latencies(shuffled) == stats


def test_summarize_latencies_edge_cases():
    empty = summarize_latencies([])
    assert empty["count"] == 0
    assert all(math.isnan(empty[k]) for k in ("p50", "p99", "max", "mean"))
    one = summarize_latencies([7.5])
    assert one == {"count": 1, "p50": 7.5, "p99": 7.5,
                   "max": 7.5, "mean": 7.5}


def test_latency_percentile_nearest_rank():
    vals = [10.0, 20.0, 30.0, 40.0]
    assert latency_percentile(vals, 0.0) == 10.0
    assert latency_percentile(vals, 0.5) == 30.0  # round(0.5*3)=2
    assert latency_percentile(vals, 1.0) == 40.0
    assert math.isnan(latency_percentile([], 0.5))


def test_serving_reexport_is_the_shared_implementation():
    # the engine module re-exports the factored helper, so legacy
    # importers (`from repro.serving import latency_percentile`) get the
    # exact same definition
    from repro.serving import latency_percentile as via_serving

    assert via_serving is latency_percentile


def test_nnz_row_stats_hand_counted():
    counts = np.array([
        [3, 0, 1, 0],   # nnz 2
        [0, 0, 0, 0],   # nnz 0
        [1, 1, 1, 1],   # nnz 4
    ])
    stats = nnz_row_stats(counts)
    assert stats["mean"] == pytest.approx(2.0)
    assert stats["p50"] == pytest.approx(2.0)
    assert stats["max"] == 4
    assert stats["num_topics"] == 4
    assert nnz_row_stats(np.zeros((0, 5)))["num_topics"] == 5


# ---------------------------------------------------------------------------
# metric primitives
# ---------------------------------------------------------------------------

def test_counter_gauge_snapshots():
    c = Counter("spills")
    c.inc()
    c.inc(3)
    assert c.snapshot() == {"kind": "counter", "name": "spills", "value": 4}
    g = Gauge("queue_depth")
    g.set(17)
    assert g.snapshot()["value"] == 17


def test_histogram_bucket_placement_scalar_and_array_agree():
    a = Histogram("h", bounds=(1.0, 10.0, 100.0))
    b = Histogram("h", bounds=(1.0, 10.0, 100.0))
    vals = [0.5, 1.0, 5.0, 10.0, 99.0, 1000.0]
    for v in vals:
        a.observe(v)
    b.observe_array(np.array(vals))
    assert a.snapshot() == b.snapshot()
    # bounds are inclusive upper edges; 1000 overflows into the last bin
    assert a.counts == [2, 2, 1, 1]
    assert a.count == 6 and a.min == 0.5 and a.max == 1000.0
    with pytest.raises(ValueError, match="ascending"):
        Histogram("bad", bounds=(5.0, 1.0))


def test_registry_type_conflicts_and_thread_safety():
    reg = MetricsRegistry()
    reg.counter("n").inc()
    with pytest.raises(TypeError):
        reg.gauge("n")
    # concurrent increments through the registry stay consistent
    def bump():
        for _ in range(500):
            reg.counter("n").inc()
    threads = [threading.Thread(target=bump) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert reg.counter("n").value == 1 + 4 * 500


# ---------------------------------------------------------------------------
# JSONL sink
# ---------------------------------------------------------------------------

def test_jsonl_sink_roundtrip_and_numpy_payloads(tmp_path):
    path = str(tmp_path / "m.jsonl")
    with JsonlSink(path) as sink:
        reg = MetricsRegistry(sink)
        reg.counter("events").inc(2)
        reg.emit({"kind": "train_iter", "nnz": np.int64(7),
                  "rate": np.float32(1.5), "pads": np.array([8, 16]),
                  "ppl": float("nan")})
        with reg.timer("jit_rebuild"):
            pass
        reg.emit_snapshot()
    records = read_jsonl(path)
    kinds = [r["kind"] for r in records]
    assert kinds == ["train_iter", "span", "snapshot"]
    assert all("t" in r for r in records)
    # numpy scalars/arrays serialize as plain JSON; NaN floats become null
    assert records[0]["nnz"] == 7 and records[0]["pads"] == [8, 16]
    assert records[0]["ppl"] is None
    assert records[1]["name"] == "jit_rebuild"
    snap = {m["name"]: m for m in records[2]["metrics"]}
    assert snap["events"]["value"] == 2
    assert snap["jit_rebuild"]["count"] == 1
    # every line is independently parseable (the grep-a-run contract)
    with open(path) as fh:
        for line in fh:
            json.loads(line)


def test_jsonl_sink_appends(tmp_path):
    path = str(tmp_path / "m.jsonl")
    with JsonlSink(path) as sink:
        sink.write({"kind": "span", "seconds": 1})
    with JsonlSink(path) as sink:
        sink.write({"kind": "span", "seconds": 2})
    assert [r["seconds"] for r in read_jsonl(path)] == [1, 2]


# ---------------------------------------------------------------------------
# serving telemetry windows
# ---------------------------------------------------------------------------

def test_serve_telemetry_window_closes_on_arrival_budget(tmp_path):
    path = str(tmp_path / "serve.jsonl")
    reg = MetricsRegistry(JsonlSink(path))
    tel = ServeTelemetry(reg, window_ticks=10_000, window_arrivals=4)
    t0 = 100.0
    for i in range(4):
        tel.record_submit(t0 + 0.010 * i, doc_len=32)  # 10ms spacing
    summary = None
    for _ in range(5):
        summary = tel.record_tick(
            queue_depth=1, occupancy=2, finished=[], spills_total=0,
            tick_period=0.001, max_slot_wait=0, bucket_widths=(32, 64),
            model_version=1,
        ) or summary
    assert summary is not None and summary["kind"] == "serve_window"
    assert summary["arrivals"] == 4
    # interarrival summary uses the shared math: 3 gaps of 10ms
    assert summary["interarrival_ms"]["count"] == 3
    assert summary["interarrival_ms"]["p50"] == pytest.approx(10.0, rel=1e-6)
    assert summary["knobs"]["tick_period"] == pytest.approx(0.001)
    assert summary["knobs"]["buckets"] == [32, 64]
    assert tel.last_window == summary
    # the window record also landed in the sink
    assert any(r["kind"] == "serve_window" for r in read_jsonl(path))
