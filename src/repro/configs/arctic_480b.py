"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864
vocab=32000, MoE 128 experts top-2 + dense residual FFN in parallel.
[hf:Snowflake/snowflake-arctic-base; hf]

128 experts shard 8-per-chip over the 16-way model axis (expert
parallelism); Adafactor optimizer. Pure full attention -> long_500k
skipped.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    moe=MoEConfig(num_experts=128, top_k=2, dense_residual=True),
    tie_embeddings=True,
    optimizer="adafactor",
    skip_shapes=("long_500k",),
)
