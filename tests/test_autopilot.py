"""repro.autotune — the measure→decide→act layer (DESIGN.md §8).

* policy units: backend re-pick from measured row density (the paper's
  §3.2 cost argument applied to live stats), dwell hysteresis, row-pad
  targets; serving period/wait/bucket derivations with hysteresis;
* training integration: a mis-padded single-box run emits an applied
  ``RowRepad`` and a word-heavy regime emits an applied
  ``BackendSwitch``, both logged to the metrics JSONL;
* the inertness pin: with ``autopilot=False`` and no ``metrics_out``
  the session builds no telemetry, registers no extra actions, and its
  final state is bit-identical to a metrics-on run of the same seed;
* serving integration: under a paced load with a mis-set tick period
  the engine's autopilot shrinks ``tick_period`` between admission
  ticks and no ticket is lost;
* ``LDAServeConfig`` JSON round-trip incl. the new observability fields
  (unknown-field rejection preserved).
"""
import math

import jax
import numpy as np
import pytest

from repro.autotune import (
    BackendSwitch,
    RowRepad,
    ServeAutopilot,
    ServeRetune,
    TrainAutopilot,
)
from repro.autotune.policy import backend_cost


@pytest.fixture(scope="module", autouse=True)
def _release_jit_memory():
    # this module compiles many session/engine variants; drop its
    # executables from the process-wide jit cache afterwards so the
    # accumulated code memory doesn't destabilize the tail of a full
    # suite run (XLA:CPU segfaults past a per-process compile load)
    yield
    jax.clear_caches()


def _train_window(mean_kw, mean_kd, K, max_kw=None, max_kd=None):
    """One train_iter record shaped like TrainTelemetry emits."""
    return [{
        "kind": "train_iter",
        "word_rows": {"mean": mean_kw, "p50": mean_kw, "p99": mean_kw,
                      "max": max_kw if max_kw is not None else mean_kw,
                      "num_topics": K},
        "doc_rows": {"mean": mean_kd, "p50": mean_kd, "p99": mean_kd,
                     "max": max_kd if max_kd is not None else mean_kd,
                     "num_topics": K},
    }]


# ---------------------------------------------------------------------------
# training policy units
# ---------------------------------------------------------------------------

def test_backend_cost_model_matches_paper_classes():
    # K dense, K_d doc-side, K_w word-side, min() hybrid (§3.2)
    assert backend_cost("zen", 30.0, 5.0, 64) == 64.0
    assert backend_cost("zen_sparse", 30.0, 5.0, 64) == 5.0
    assert backend_cost("sparselda", 30.0, 5.0, 64) == 30.0
    assert backend_cost("zen_hybrid", 30.0, 5.0, 64) == 5.0


def test_switch_fires_only_past_ratio_with_dwell():
    pilot = TrainAutopilot(("sparselda", "zen_sparse"), switch_ratio=0.8,
                           dwell=2)
    # hot vocab: word rows dense, doc rows short -> doc-side wins big
    window = _train_window(mean_kw=34.0, mean_kd=6.6, K=64)
    decisions = pilot.decide(window, current_backend="sparselda",
                             current_pads=(0, 0), num_topics=64,
                             pads_tunable=False)
    assert [type(d) for d in decisions] == [BackendSwitch]
    assert decisions[0].backend == "zen_sparse"
    # dwell: the next two ticks are cooldown even with the same evidence
    for _ in range(2):
        assert pilot.decide(window, current_backend="zen_sparse",
                            current_pads=(0, 0), num_topics=64,
                            pads_tunable=False) == []
    # after cooldown, the now-correct backend produces no decision
    assert pilot.decide(window, current_backend="zen_sparse",
                        current_pads=(0, 0), num_topics=64,
                        pads_tunable=False) == []


def test_switch_respects_ratio_margin():
    pilot = TrainAutopilot(("sparselda", "zen_sparse"), switch_ratio=0.8)
    # doc-side only ~10% cheaper: inside the margin, no flapping
    window = _train_window(mean_kw=10.0, mean_kd=9.0, K=64)
    assert pilot.decide(window, current_backend="sparselda",
                        current_pads=(0, 0), num_topics=64,
                        pads_tunable=False) == []


def test_row_repad_targets_quantile_slack_lane_rounded():
    pilot = TrainAutopilot(("zen_sparse",), pad_quantile="max", pad_slack=8)
    window = _train_window(mean_kw=20.0, mean_kd=5.0, K=128,
                           max_kw=50, max_kd=11)
    (d,) = pilot.decide(window, current_backend="zen_sparse",
                        current_pads=(128, 128), num_topics=128)
    assert isinstance(d, RowRepad)
    # max + 8 slack, rounded up to 8 lanes: 58->64, 19->24
    assert (d.max_kw, d.max_kd) == (64, 24)
    # targets clamp at K, and a matching current config is a no-op
    window_hot = _train_window(mean_kw=120.0, mean_kd=5.0, K=128,
                               max_kw=128, max_kd=11)
    (d2,) = pilot.decide(window_hot, current_backend="zen_sparse",
                         current_pads=(64, 24), num_topics=128)
    assert d2.max_kw == 128
    assert pilot.decide(window, current_backend="zen_sparse",
                        current_pads=(64, 24), num_topics=128) == []
    # pads_tunable=False suppresses capacity decisions entirely
    assert pilot.decide(window, current_backend="zen_sparse",
                        current_pads=(128, 128), num_topics=128,
                        pads_tunable=False) == []


def test_empty_or_padless_window_decides_nothing():
    pilot = TrainAutopilot(("zen_sparse",))
    assert pilot.decide([], current_backend="zen_sparse",
                        current_pads=(0, 0), num_topics=64) == []
    assert pilot.decide([{"kind": "decision"}],
                        current_backend="zen_sparse",
                        current_pads=(0, 0), num_topics=64) == []


# ---------------------------------------------------------------------------
# serving policy units
# ---------------------------------------------------------------------------

def _serve_summary(inter_p50_ms, count=16, wait_p90=0.0,
                   doc_len=(24.0, 50.0, 60)):
    p50, p99, mx = doc_len
    return {
        "kind": "serve_window",
        "interarrival_ms": {"count": count, "p50": inter_p50_ms},
        "wait_ticks_p90": wait_p90,
        "doc_len": {"count": count, "p50": p50, "p99": p99, "max": mx},
    }


def test_serve_period_derivation_clamp_and_hysteresis():
    pilot = ServeAutopilot(period_fraction=0.5, min_period=5e-4,
                           max_period=0.1, hysteresis=0.25)
    # 10ms arrivals, 50ms tick: retune to 5ms
    d = pilot.decide(_serve_summary(10.0), tick_period=0.05,
                     max_slot_wait=0, buckets=(32, 64))
    assert isinstance(d, ServeRetune)
    assert d.tick_period == pytest.approx(0.005)
    assert d.buckets is None and d.max_slot_wait is None
    # within 25% of current: no decision at all
    assert pilot.decide(_serve_summary(10.0), tick_period=0.0045,
                        max_slot_wait=0, buckets=(32, 64)) is None
    # clamps: sub-ms arrivals floor at min_period, slow ones cap
    d = pilot.decide(_serve_summary(0.1), tick_period=0.05,
                     max_slot_wait=0, buckets=(32, 64))
    assert d.tick_period == pytest.approx(5e-4)
    d = pilot.decide(_serve_summary(5000.0), tick_period=0.001,
                     max_slot_wait=0, buckets=(32, 64))
    assert d.tick_period == pytest.approx(0.1)
    # too few arrivals to estimate a process: no decision
    assert pilot.decide(_serve_summary(10.0, count=3), tick_period=0.05,
                        max_slot_wait=0, buckets=(32, 64)) is None


def test_serve_wait_derivation_from_queueing_tail():
    pilot = ServeAutopilot()
    d = pilot.decide(_serve_summary(10.0, wait_p90=4.0), tick_period=0.005,
                     max_slot_wait=0, buckets=(32, 64))
    assert d.max_slot_wait == 4
    # already set correctly, sub-threshold waits: nothing to do
    assert pilot.decide(_serve_summary(10.0, wait_p90=4.0),
                        tick_period=0.005, max_slot_wait=4,
                        buckets=(32, 64)) is None
    assert pilot.decide(_serve_summary(10.0, wait_p90=1.0),
                        tick_period=0.005, max_slot_wait=0,
                        buckets=(32, 64)) is None


def test_serve_bucket_recut_on_truncation_or_waste():
    pilot = ServeAutopilot()
    # truncating: longest doc exceeds the widest bucket
    d = pilot.decide(_serve_summary(10.0, doc_len=(24.0, 90.0, 120)),
                     tick_period=0.005, max_slot_wait=0, buckets=(32, 64))
    assert d.buckets == (24, 96, 120)
    # wasteful: smallest bucket >= 4x p50
    d = pilot.decide(_serve_summary(10.0, doc_len=(8.0, 30.0, 31)),
                     tick_period=0.005, max_slot_wait=0,
                     buckets=(64, 256))
    assert d.buckets == (8, 32)
    # a well-cut grid is left alone (bucket drains are expensive)
    assert pilot.decide(_serve_summary(10.0, doc_len=(24.0, 50.0, 60)),
                        tick_period=0.005, max_slot_wait=0,
                        buckets=(32, 64)) is None
    # retune_buckets=False suppresses the knob
    assert ServeAutopilot(retune_buckets=False).decide(
        _serve_summary(10.0, doc_len=(24.0, 90.0, 120)),
        tick_period=0.005, max_slot_wait=0, buckets=(32, 64)) is None


# ---------------------------------------------------------------------------
# training integration (single-box)
# ---------------------------------------------------------------------------

def _hot_vocab():
    from repro.data import synthetic_corpus

    # tiny hot vocab under Zipf a=0.8: word rows touch ~K/2 topics while
    # doc rows stay short -> doc-side decomposition wins by >2x
    return synthetic_corpus(0, num_docs=120, num_words=24,
                            avg_doc_len=8, zipf_a=0.8)


def test_autopilot_switches_backend_and_logs(tmp_path):
    from repro.core.types import LDAHyperParams
    from repro.observe.metrics import read_jsonl
    from repro.train import RunConfig, TrainSession

    path = str(tmp_path / "train.jsonl")
    cfg = RunConfig(algorithm="sparselda", num_iterations=6, eval_every=0,
                    autopilot=True, autopilot_every=2, metrics_out=path)
    session = TrainSession(_hot_vocab(), LDAHyperParams(num_topics=64), cfg)
    assert session.schedule.names() == ("autopilot", "telemetry")
    fired = []
    session.run(rng=jax.random.PRNGKey(0),
                callback=lambda st, m: fired.extend(m.get("autopilot", ())))
    # the mis-picked word-side backend was swapped for doc-side
    assert session.backend.name == "zen_sparse"
    applied = [r for r in fired
               if r["decision"] == "BackendSwitch" and r["applied"]]
    assert applied and applied[0]["backend"] == "zen_sparse"
    # ... and the decision record landed in the JSONL, alongside
    # per-iteration telemetry
    records = read_jsonl(path)
    kinds = {r["kind"] for r in records}
    assert "train_iter" in kinds
    logged = [r for r in records if r["kind"] == "decision"]
    assert any(r["decision"] == "BackendSwitch" and r["applied"]
               for r in logged)
    iters = [r for r in records if r["kind"] == "train_iter"]
    assert iters[-1]["backend"] == "zen_sparse"
    # first record has no prior stamp (null rate); the rest are finite
    assert all(r["tokens_per_s"] is None or math.isfinite(r["tokens_per_s"])
               for r in iters)
    assert any(r["tokens_per_s"] for r in iters[1:])


def test_autopilot_shrinks_mis_sized_pads(tmp_path):
    from repro.core.types import LDAHyperParams
    from repro.train import RunConfig, TrainSession

    K = 64
    cfg = RunConfig(algorithm="zen_sparse", num_iterations=4, eval_every=0,
                    max_kw=K, max_kd=K, autopilot=True, autopilot_every=2)
    session = TrainSession(_hot_vocab(), LDAHyperParams(num_topics=K), cfg)
    fired = []
    session.run(rng=jax.random.PRNGKey(0),
                callback=lambda st, m: fired.extend(m.get("autopilot", ())))
    repads = [r for r in fired if r["decision"] == "RowRepad"]
    assert repads and repads[0]["applied"]
    # doc rows can't exceed doc length (~8 here): the K-wide pad shrank
    assert session.plan.row_pads[1] < K
    assert session.plan.row_pads == (repads[-1]["max_kw"],
                                     repads[-1]["max_kd"])


# ---------------------------------------------------------------------------
# the inertness pin: off by default means OFF
# ---------------------------------------------------------------------------

def test_autopilot_off_is_bit_identical_and_structure_free(
        tmp_path, tiny_corpus, tiny_hyper):
    from repro.train import RunConfig, TrainSession

    base = dict(algorithm="zen_sparse", num_iterations=5, rebuild_every=2)
    plain = TrainSession(tiny_corpus, tiny_hyper, RunConfig(**base))
    # no telemetry objects, no extra schedule actions
    assert plain.telemetry is None
    assert plain.schedule.names() == ("rebuild", "repad")

    metered = TrainSession(
        tiny_corpus, tiny_hyper,
        RunConfig(**base, metrics_out=str(tmp_path / "m.jsonl")),
    )
    assert metered.schedule.names() == ("rebuild", "repad", "telemetry")

    st_plain = plain.run(rng=jax.random.PRNGKey(3))
    st_metered = metered.run(rng=jax.random.PRNGKey(3))
    # observation must not perturb the chain: bit-identical final state
    np.testing.assert_array_equal(np.asarray(st_plain.topic),
                                  np.asarray(st_metered.topic))
    np.testing.assert_array_equal(np.asarray(st_plain.n_wk),
                                  np.asarray(st_metered.n_wk))
    np.testing.assert_array_equal(np.asarray(st_plain.n_kd),
                                  np.asarray(st_metered.n_kd))


# ---------------------------------------------------------------------------
# serving integration
# ---------------------------------------------------------------------------

def _frozen_model(W=80, K=8):
    import jax.numpy as jnp

    from repro.core.types import LDAHyperParams
    from repro.serving import FrozenLDAModel

    rng = np.random.default_rng(0)
    n_wk = rng.poisson(2.0, size=(W, K)).astype(np.int32)
    return FrozenLDAModel(
        n_wk=jnp.asarray(n_wk),
        n_k=jnp.asarray(n_wk.sum(0).astype(np.int32)),
        hyper=LDAHyperParams(num_topics=K),
    )


def test_engine_autopilot_retunes_tick_period_under_paced_load(tmp_path):
    import time

    from repro.observe.metrics import read_jsonl
    from repro.serving import LDAEngine, LDAServeConfig

    path = str(tmp_path / "serve.jsonl")
    cfg = LDAServeConfig(
        buckets=(16, 32), max_batch=4, mode="latency", rtlda_sweeps=1,
        tick_period=0.05,  # mis-set: 25x the arrival spacing
        autopilot=True, autopilot_window=12, metrics_out=path,
    )
    engine = LDAEngine(_frozen_model(), cfg, seed=0)
    engine.warm()
    engine.start()
    try:
        rng = np.random.default_rng(1)
        tickets = []
        for _ in range(40):
            doc = rng.integers(0, 80, size=12).astype(np.int32)
            tickets.append(engine.submit_async(doc))
            time.sleep(0.002)
        thetas = [engine.result(t, timeout=30.0) for t in tickets]
    finally:
        engine.stop()
    # every ticket served (retunes apply between ticks, nothing dropped)
    assert len(thetas) == 40
    assert all(th.shape == (8,) for th in thetas)
    # the measured arrival process pulled the period down
    assert engine.tick_period < cfg.tick_period
    records = read_jsonl(path)
    assert any(r["kind"] == "serve_window" for r in records)
    retunes = [r for r in records if r["kind"] == "decision"]
    assert any(r["decision"] == "ServeRetune" and r["applied"]
               for r in retunes)


def test_engine_without_autopilot_keeps_configured_knobs():
    from repro.serving import LDAEngine, LDAServeConfig

    cfg = LDAServeConfig(buckets=(16, 32), max_batch=4, tick_period=0.01)
    engine = LDAEngine(_frozen_model(), cfg, seed=0)
    assert engine._telemetry is None and engine._autopilot is None
    doc = np.arange(10, dtype=np.int32)
    engine.result(engine.submit_async(doc))
    assert engine.tick_period == 0.01
    assert engine.bucket_widths == (16, 32)


# ---------------------------------------------------------------------------
# LDAServeConfig JSON round-trip (new fields included)
# ---------------------------------------------------------------------------

def test_serve_config_json_roundtrip():
    from repro.serving import LDAServeConfig

    cfg = LDAServeConfig(
        buckets=(16, 64), max_batch=12, num_sweeps=7, burn_in=2, thin=2,
        algorithm="zen_cdf", mode="latency", rtlda_sweeps=3,
        tick_period=0.004, max_slot_wait=3, mesh_shape=(1, 2),
        metrics_out="/tmp/serve.jsonl", autopilot=True, autopilot_window=32,
    )
    back = LDAServeConfig.from_json(cfg.to_json())
    assert back == cfg
    assert back.buckets == (16, 64) and back.mesh_shape == (1, 2)
    # defaults survive; unknown fields still rejected
    assert (LDAServeConfig.from_json(LDAServeConfig().to_json())
            == LDAServeConfig())
    with pytest.raises(ValueError, match="unknown LDAServeConfig fields"):
        LDAServeConfig.from_json('{"max_batch": 4, "definitely_not": 1}')
