"""Mixture-of-Experts layer (grok-1: 8e top-2; arctic: 128e top-2 + dense
residual) — GShard/Switch-style capacity dispatch, expert-parallel friendly.

Dispatch/combine are einsums against one-hot capacity tensors so the whole
layer is MXU matmuls + an all-to-all when experts are sharded over `model`
(XLA SPMD inserts it from the shardings). Expert placement reuses the DBH+
insight (DESIGN.md §4): the greedy LPT balancer in ``core.graph`` is what a
production loader would use to place unevenly-hot experts; under SPMD the
static layout is uniform and the router aux loss keeps load flat.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import _act


def init_moe(key, cfg: ArchConfig, dtype) -> dict:
    m = cfg.moe
    d, f, e = cfg.d_model, cfg.d_ff, m.num_experts
    ks = jax.random.split(key, 5)
    s_in = d ** -0.5
    s_out = f ** -0.5
    p = {
        "router": (jax.random.normal(ks[0], (d, e)) * s_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, f)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d)) * s_out).astype(dtype),
    }
    return p


def moe_block(
    x: jax.Array,  # (B, S, D)
    params: dict,
    cfg: ArchConfig,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,D), aux_loss ())."""
    m = cfg.moe
    b, s, d = x.shape
    e = m.num_experts
    t = b * s
    # group-local dispatch: capacity bookkeeping + one-hot einsums operate
    # per group of `ts` tokens (groups align with the batch sharding, so
    # the group dim shards over the data axes and capacity stays per-shard)
    ts = m.group_size if t % m.group_size == 0 else t
    g = t // ts
    xg = x.reshape(g, ts, d)
    cap = int(max(1, round(ts * m.top_k * m.capacity_factor / e)))

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, m.top_k)  # (g, ts, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # position of each (token, choice) within its expert's group capacity
    choice_onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)  # (g,ts,k,e)
    flat = choice_onehot.reshape(g, ts * m.top_k, e)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat).reshape(
        g, ts, m.top_k, e
    )
    pos = jnp.sum(pos_in_expert * choice_onehot, axis=-1).astype(jnp.int32)
    keep = pos < cap
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    pos_onehot = jax.nn.one_hot(pos, cap, dtype=jnp.float32)  # (g,ts,k,cap)
    # dispatch / combine (g, ts, e, cap)
    dispatch = jnp.einsum(
        "gtke,gtkc->gtec", choice_onehot * keep[..., None], pos_onehot
    )
    combine = jnp.einsum(
        "gtke,gtkc,gtk->gtec", choice_onehot, pos_onehot, gate_vals
    )

    xe = jnp.einsum("gtec,gtd->gecd", dispatch,
                    xg.astype(jnp.float32)).astype(x.dtype)
    gate = _act(jnp.einsum("gecd,edf->gecf", xe, params["w_gate"]), cfg.act)
    up = jnp.einsum("gecd,edf->gecf", xe, params["w_up"])
    ye = jnp.einsum("gecf,efd->gecd", gate * up, params["w_down"])
    y = jnp.einsum("gtec,gecd->gtd", combine,
                   ye.astype(jnp.float32)).astype(x.dtype)

    # aux losses: load balance (Switch) + router z-loss
    density = jnp.mean(choice_onehot[:, :, 0, :], axis=(0, 1))
    density_proxy = jnp.mean(probs, axis=(0, 1))
    aux = jnp.sum(density * density_proxy) * (e ** 2) * m.aux_loss
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * m.router_z_loss
    return y.reshape(b, s, d), aux + z
