"""Serving benchmarks: throughput sweep + latency-vs-throughput frontier.

Two serving questions, same frozen synthetic model (DESIGN.md §5):

* **Throughput** — docs/sec vs batch size x bucket layout, per backend,
  for the chain-based CGS mode (the original PR 2 sweep).
* **Frontier** — per-request latency (p50/p99 of submit-to-done, small
  batches served through the async front) for ``mode="throughput"`` per
  backend vs ``mode="latency"`` (the RT-LDA fast path, one fused
  deterministic decode per tick). The fast path's job is to beat the
  chain mode's p99 on small batches; these rows show by how much.

    PYTHONPATH=src python benchmarks/run.py --only infer
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import row

BACKENDS = ("zen", "zen_cdf", "zen_pallas")
NUM_DOCS = 96
NUM_WORDS = 2000
NUM_TOPICS = 64
FRONTIER_DOCS = 24  # small-batch latency probe


def _frozen_model():
    import jax.numpy as jnp

    from repro.core.types import LDAHyperParams
    from repro.serving import FrozenLDAModel

    rng = np.random.default_rng(0)
    n_wk = rng.poisson(2.0, size=(NUM_WORDS, NUM_TOPICS)).astype(np.int32)
    return FrozenLDAModel(
        n_wk=jnp.asarray(n_wk),
        n_k=jnp.asarray(n_wk.sum(0).astype(np.int32)),
        hyper=LDAHyperParams(num_topics=NUM_TOPICS),
    )


def _load(rng, n=NUM_DOCS):
    """Mixed-length Zipf query docs (the serving traffic shape)."""
    lengths = np.clip(rng.poisson(48, size=n), 4, 240)
    ranks = np.arange(1, NUM_WORDS + 1, dtype=np.float64) ** -1.2
    pmf = ranks / ranks.sum()
    return [
        rng.choice(NUM_WORDS, size=ln, p=pmf).astype(np.int32)
        for ln in lengths
    ]


def _throughput_sweep(model, docs):
    from repro.serving import LDAEngine, LDAServeConfig

    layouts = [
        ("1bucket", (256,)),
        ("2buckets", (64, 256)),
        ("4buckets", (32, 64, 128, 256)),
    ]
    for backend in BACKENDS:
        for batch in (8, 32):
            for lname, buckets in layouts:
                cfg = LDAServeConfig(
                    buckets=buckets, max_batch=batch, num_sweeps=10,
                    algorithm=backend,
                )
                engine = LDAEngine(model, cfg, seed=0)
                # warm THIS engine's per-bucket jit caches (they are
                # per-instance closures): one doc per bucket width
                engine.infer_batch(
                    [np.zeros(bl, np.int32) for bl in buckets]
                )
                t0 = time.perf_counter()
                engine.infer_batch(docs)
                dt = time.perf_counter() - t0
                row(
                    f"infer_{backend}_b{batch}_{lname}",
                    dt * 1e6 / NUM_DOCS,
                    f"{NUM_DOCS / dt:.1f} docs/s",
                )


def _closed_loop_latencies(engine, docs):
    """Serve one doc at a time through the async front; per-doc ms."""
    lats = []
    for d in docs:
        t0 = time.perf_counter()
        ticket = engine.submit_async(d)
        engine.result(ticket)
        lats.append((time.perf_counter() - t0) * 1e3)
    return sorted(lats)


def _frontier(model, docs):
    """Small-batch latency: chain mode per backend vs the RT-LDA path."""
    from repro.observe import summarize_latencies
    from repro.serving import LDAEngine, LDAServeConfig

    buckets = (64, 256)
    probes = [("latency", LDAServeConfig(
        buckets=buckets, max_batch=8, mode="latency", rtlda_sweeps=2,
    ))]
    probes += [
        (f"throughput_{backend}", LDAServeConfig(
            buckets=buckets, max_batch=8, num_sweeps=10, algorithm=backend,
        ))
        for backend in BACKENDS
    ]
    for name, cfg in probes:
        engine = LDAEngine(model, cfg, seed=0)
        engine.infer_batch([np.zeros(bl, np.int32) for bl in buckets])
        stats = summarize_latencies(_closed_loop_latencies(engine, docs))
        row(
            f"frontier_{name}",
            stats["p50"] * 1e3,  # us_per_call column = p50 in us
            f"p99 {stats['p99']:.2f} ms",
        )


def _sharded_and_replicas(model, docs):
    """Scaling rows (DESIGN.md §5.4): sharded decode over the model axis
    (skipped with a note on single-device hosts) and router replica
    scaling — same load, 1 vs 2 replicas, docs/sec."""
    import jax

    from repro.serving import LDAEngine, LDARouter, LDAServeConfig

    base = dict(buckets=(64, 256), max_batch=16, num_sweeps=10,
                algorithm="zen_cdf")

    n_dev = len(jax.devices())
    if n_dev >= 2:
        cfg = LDAServeConfig(mesh_shape=(1, 2), **base)
        engine = LDAEngine(model, cfg, seed=0)
        engine.warm()
        t0 = time.perf_counter()
        engine.infer_batch(docs)
        dt = time.perf_counter() - t0
        row("infer_sharded_zen_cdf_m2", dt * 1e6 / len(docs),
            f"{len(docs) / dt:.1f} docs/s (2 word shards)")
    else:
        row("infer_sharded_zen_cdf_m2", float("nan"),
            "skipped: 1 device (set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=2)")

    for replicas in (1, 2):
        router = LDARouter(model, LDAServeConfig(**base),
                           replicas=replicas, seed=0)
        router.warm()
        router.start(0.0005)
        tickets = [router.submit_async(d) for d in docs]
        t0 = time.perf_counter()
        for t in tickets:
            router.result(t)
        dt = time.perf_counter() - t0
        router.stop()
        row(f"infer_router_r{replicas}", dt * 1e6 / len(docs),
            f"{len(docs) / dt:.1f} docs/s ({replicas} replicas)")


def main() -> None:
    model = _frozen_model()
    rng = np.random.default_rng(1)
    _throughput_sweep(model, _load(rng))
    _frontier(model, _load(rng, FRONTIER_DOCS))
    _sharded_and_replicas(model, _load(rng))


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
