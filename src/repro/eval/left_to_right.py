"""Wallach-style left-to-right held-out evaluation (particle estimate).

Estimates ``log p(w_1..w_L | N_wk, N_k, hyper)`` for one held-out
document under the frozen-model predictive process: topics follow the
Polya-urn doc prior ``p(z_n = k | z_{<n}) ∝ count_{<n}(k) + alpha_k``
and words follow the frozen ``phi_wk = (N_wk + beta)/(N_k + W beta)``.
The exact marginal sums over K^L assignments; the left-to-right
algorithm (Wallach et al. 2009, "Evaluation Methods for Topic Models",
Alg. 1) replaces that sum with R particles swept position by position:

    for n = 1..L:
        resample z_{<n} for every particle (the full variant)
        p_n^{(r)} = sum_k p(z=k | z^{(r)}_{<n}) phi[w_n, k]
        draw z^{(r)}_n ∝ p(z=k | z^{(r)}_{<n}) phi[w_n, k]
    log p(w) ≈ sum_n log mean_r p_n^{(r)}

``exhaustive_llh`` computes the K^L enumeration exactly — the oracle
the tests cross-check the particle estimate against on short documents.

Host-side numpy throughout (evaluation read, seeded generator in, so a
trajectory is bit-reproducible).
"""
from __future__ import annotations

from typing import Optional

import numpy as np


def _host_alpha_k(n_k: np.ndarray, hyper) -> np.ndarray:
    """Numpy mirror of ``LDAHyperParams.alpha_k`` (frozen n_k)."""
    k = hyper.num_topics
    if not hyper.asymmetric_alpha:
        return np.full(k, hyper.alpha, np.float64)
    n_k = np.asarray(n_k, np.float64)
    return (k * hyper.alpha) * (n_k + hyper.alpha_prime / k) / (
        n_k.sum() + hyper.alpha_prime
    )


def _frozen_phi(n_wk: np.ndarray, n_k: np.ndarray, words: np.ndarray,
                hyper) -> np.ndarray:
    """(L, K) frozen word-topic probabilities for the doc's tokens."""
    n_wk = np.asarray(n_wk, np.float64)
    n_k = np.asarray(n_k, np.float64)
    w_total = n_wk.shape[0]
    return (n_wk[np.asarray(words)] + hyper.beta) / (
        n_k + w_total * hyper.beta
    )[None, :]


def left_to_right_llh(
    n_wk: np.ndarray,
    n_k: np.ndarray,
    words: np.ndarray,
    hyper,
    num_particles: int = 20,
    rng: Optional[np.random.Generator] = None,
    resample: bool = True,
) -> float:
    """Particle left-to-right estimate of ``log p(words | model)``.

    Args:
        n_wk: (W, K) frozen word-topic counts.
        n_k: (K,) frozen topic totals.
        words: (L,) token word ids of the held-out document.
        hyper: ``LDAHyperParams`` (alpha_k derives from the frozen n_k).
        num_particles: R; the estimator variance shrinks as 1/R.
        rng: seeded ``np.random.Generator`` — pass one for reproducible
            trajectories (default: fresh default_rng()).
        resample: run the full variant (resweep ``z_{<n}`` before every
            position). False = the cheaper O(L) variant; biased slightly
            high on long docs but far faster.

    Returns:
        The scalar log-likelihood estimate (natural log).
    """
    rng = rng if rng is not None else np.random.default_rng()
    words = np.asarray(words)
    l = int(words.shape[0])
    if l == 0:
        return 0.0
    k = hyper.num_topics
    r = int(num_particles)
    alpha_k = _host_alpha_k(n_k, hyper)
    alpha_sum = float(alpha_k.sum())
    phi = _frozen_phi(n_wk, n_k, words, hyper)  # (L, K)

    z = np.zeros((r, l), np.int64)
    counts = np.zeros((r, k), np.float64)
    total = 0.0
    for n in range(l):
        if resample:
            for m in range(n):
                # remove position m, resample it from the conditional
                np.subtract.at(counts, (np.arange(r), z[:, m]), 1.0)
                probs = (counts + alpha_k) * phi[m][None, :]
                z[:, m] = _categorical_rows(rng, probs)
                np.add.at(counts, (np.arange(r), z[:, m]), 1.0)
        weights = (counts + alpha_k) * phi[n][None, :]  # (R, K)
        p_n = weights.sum(axis=1) / (n + alpha_sum)
        total += float(np.log(max(p_n.mean(), 1e-300)))
        z[:, n] = _categorical_rows(rng, weights)
        np.add.at(counts, (np.arange(r), z[:, n]), 1.0)
    return total


def _categorical_rows(rng: np.random.Generator,
                      weights: np.ndarray) -> np.ndarray:
    """One categorical draw per row of an unnormalized (R, K) matrix."""
    cdf = np.cumsum(weights, axis=1)
    u = rng.random(weights.shape[0]) * cdf[:, -1]
    return np.minimum(
        (cdf < u[:, None]).sum(axis=1), weights.shape[1] - 1
    ).astype(np.int64)


def exhaustive_llh(n_wk: np.ndarray, n_k: np.ndarray, words: np.ndarray,
                   hyper) -> float:
    """Exact ``log p(words | model)`` by K^L enumeration (test oracle).

    Feasible only for short documents; the left-to-right tests pin the
    particle estimate against this on 3-token documents.
    """
    words = np.asarray(words)
    l = int(words.shape[0])
    if l == 0:
        return 0.0
    k = hyper.num_topics
    assert k ** l <= 2_000_000, "enumeration oracle: document too long"
    alpha_k = _host_alpha_k(n_k, hyper)
    alpha_sum = float(alpha_k.sum())
    phi = _frozen_phi(n_wk, n_k, words, hyper)  # (L, K)

    total = 0.0
    from itertools import product

    for assign in product(range(k), repeat=l):
        counts = np.zeros(k, np.float64)
        p = 1.0
        for n, zn in enumerate(assign):
            p *= (counts[zn] + alpha_k[zn]) / (n + alpha_sum) * phi[n, zn]
            counts[zn] += 1.0
        total += p
    return float(np.log(total))
