"""Topic coherence: UMass and sliding-window NPMI over corpus statistics.

Both metrics score a topic by how often its top-N words co-occur in the
corpus, which correlates with human topic-quality judgments far better
than held-out likelihood alone (Mimno et al. 2011; Röder et al. 2015 —
gensim's ``CoherenceModel`` is the exemplar implementation).

* UMass: document co-occurrence. For a topic's top words ordered by
  descending count ``v_1..v_N``::

      C_umass = sum_{m=2..N} sum_{l<m} log[(D(v_m, v_l) + 1) / D(v_l)]

  where ``D(w)`` is the number of documents containing ``w`` and
  ``D(w, w')`` the number containing both. Pairs whose denominator word
  never occurs are skipped (a zero-count word can reach the top-N of an
  empty topic).

* NPMI: sliding-window probability estimation. ``p(w)`` is the fraction
  of windows (length ``window``, stride 1, one whole-doc window for
  shorter docs) containing ``w``::

      npmi(w, w') = log[p(w, w') / (p(w) p(w'))] / (-log p(w, w'))

  averaged over unordered top-word pairs; a never-co-occurring pair
  contributes the limit value -1.

Everything here is host-side numpy on the frozen counts — coherence is
an evaluation read, never part of the sampling hot path.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np


def top_topic_words(n_wk: np.ndarray, top_n: int) -> np.ndarray:
    """Top-``top_n`` word ids per topic from frozen counts, (K, top_n).

    Ordered by descending ``N_w|k``; ties break toward the lower word id
    (stable sort), so the selection is deterministic across runs.
    """
    n_wk = np.asarray(n_wk)
    top_n = min(int(top_n), n_wk.shape[0])
    # stable argsort on (-count, word_id): lowest id wins ties
    order = np.argsort(-n_wk.astype(np.int64), axis=0, kind="stable")
    return order[:top_n].T.astype(np.int32)  # (K, top_n)


class CoherenceStats:
    """Corpus co-occurrence statistics shared by both coherence metrics.

    Built once per corpus (the expensive part — grouping the edge list
    into per-document token sequences and window sets) and queried per
    eval tick with whatever top-word matrix the current model produces.
    """

    def __init__(self, word: np.ndarray, doc: np.ndarray, num_docs: int,
                 window: int = 10):
        word = np.asarray(word)
        doc = np.asarray(doc)
        order = np.argsort(doc, kind="stable")  # edge order kept within doc
        w_sorted, d_sorted = word[order], doc[order]
        bounds = np.searchsorted(d_sorted, np.arange(num_docs + 1))
        self.docs: List[np.ndarray] = [
            w_sorted[bounds[i]:bounds[i + 1]] for i in range(num_docs)
        ]
        self.num_docs = num_docs
        self.window = max(1, int(window))
        # word -> set of doc ids (UMass document co-occurrence)
        self._word_docs: Dict[int, frozenset] = {}
        for i, toks in enumerate(self.docs):
            for w in np.unique(toks):
                self._word_docs.setdefault(int(w), set()).add(i)  # type: ignore[arg-type]
        self._word_docs = {w: frozenset(s) for w, s in self._word_docs.items()}
        # sliding windows as sets (NPMI probability estimation)
        self._windows: List[frozenset] = []
        s = self.window
        for toks in self.docs:
            if len(toks) == 0:
                continue
            if len(toks) <= s:
                self._windows.append(frozenset(int(t) for t in toks))
            else:
                for i in range(len(toks) - s + 1):
                    self._windows.append(
                        frozenset(int(t) for t in toks[i:i + s])
                    )
        self.num_windows = len(self._windows)
        self._win_membership: Dict[int, frozenset] = {}

    @classmethod
    def from_corpus(cls, corpus, window: int = 10) -> "CoherenceStats":
        """Build from a ``repro.core.types.Corpus`` (host transfer)."""
        return cls(np.asarray(corpus.word), np.asarray(corpus.doc),
                   corpus.num_docs, window=window)

    # -- document co-occurrence (UMass) ---------------------------------
    def doc_freq(self, w: int) -> int:
        return len(self._word_docs.get(int(w), ()))

    def co_doc_freq(self, w1: int, w2: int) -> int:
        a = self._word_docs.get(int(w1))
        b = self._word_docs.get(int(w2))
        if not a or not b:
            return 0
        return len(a & b)

    # -- window co-occurrence (NPMI) ------------------------------------
    def _windows_with(self, w: int) -> frozenset:
        got = self._win_membership.get(int(w))
        if got is None:
            got = frozenset(
                i for i, win in enumerate(self._windows) if int(w) in win
            )
            self._win_membership[int(w)] = got
        return got

    def window_prob(self, w: int) -> float:
        if self.num_windows == 0:
            return 0.0
        return len(self._windows_with(w)) / self.num_windows

    def co_window_prob(self, w1: int, w2: int) -> float:
        if self.num_windows == 0:
            return 0.0
        a, b = self._windows_with(w1), self._windows_with(w2)
        return len(a & b) / self.num_windows


def umass_coherence(
    stats: CoherenceStats, top_words: np.ndarray
) -> Tuple[float, np.ndarray]:
    """UMass coherence per topic + mean over topics.

    ``top_words`` is the (K, N) matrix from :func:`top_topic_words`,
    rows ordered by descending count. Returns ``(mean, per_topic)``.
    """
    top_words = np.asarray(top_words)
    per_topic = np.zeros(top_words.shape[0], np.float64)
    for t, row in enumerate(top_words):
        score = 0.0
        for m in range(1, len(row)):
            for l in range(m):
                d_l = stats.doc_freq(row[l])
                if d_l == 0:
                    continue  # denominator word absent from the corpus
                score += np.log(
                    (stats.co_doc_freq(row[m], row[l]) + 1.0) / d_l
                )
        per_topic[t] = score
    return float(per_topic.mean()), per_topic


def npmi_coherence(
    stats: CoherenceStats, top_words: np.ndarray
) -> Tuple[float, np.ndarray]:
    """Sliding-window NPMI coherence per topic + mean over topics.

    Pairs are unordered (NPMI is symmetric); each topic's score is the
    mean pairwise NPMI in [-1, 1], with never-co-occurring pairs pinned
    to the -1 limit. Higher is better.
    """
    top_words = np.asarray(top_words)
    per_topic = np.zeros(top_words.shape[0], np.float64)
    for t, row in enumerate(top_words):
        vals = []
        for m in range(1, len(row)):
            for l in range(m):
                p_i = stats.window_prob(row[l])
                p_j = stats.window_prob(row[m])
                if p_i == 0.0 or p_j == 0.0:
                    continue  # word absent: pair carries no evidence
                p_ij = stats.co_window_prob(row[l], row[m])
                if p_ij == 0.0:
                    vals.append(-1.0)
                    continue
                if p_ij >= 1.0:
                    vals.append(1.0)  # degenerate: every window has both
                    continue
                vals.append(
                    float(np.log(p_ij / (p_i * p_j)) / (-np.log(p_ij)))
                )
        per_topic[t] = np.mean(vals) if vals else 0.0
    return float(per_topic.mean()), per_topic
