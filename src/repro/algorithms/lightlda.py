"""``lightlda`` — LightLDA (Yuan et al.) cycle Metropolis-Hastings on the
shared substrate (paper §7.2). ``prepare`` builds the CSR doc->token index
that realizes the O(1) doc proposal.

Mesh-capable: ``cell_sweep`` rebuilds the doc->token index *inside* the
cell (an O(T log T) sort per iteration over the cell's tokens, masked
padding excluded), so the doc proposal draws from the doc's tokens within
this word shard, and its MH density is evaluated on the same cell-local
histogram (see ``lightlda_cell``) — a locality-restricted proposal with a
matching density, targeting the true conditional from the synced blocks.
The single-box sweep keeps the once-per-run prepared index.
"""
from __future__ import annotations

from repro.algorithms.base import CellBackend, SamplerKnobs, kernel_dispatch
from repro.algorithms.registry import register
from repro.core.baselines import (
    build_cell_doc_index,
    build_doc_index,
    lightlda_cell,
    lightlda_sweep,
)


@register("lightlda")
class LightLDA(CellBackend):
    """Alternating word/doc proposals, ``num_mh`` MH steps per token."""

    needs_doc_index = True
    needs_row_pads = True

    def prepare(self, corpus, hyper, knobs: SamplerKnobs):
        return build_doc_index(corpus)

    def sweep(self, state, corpus, hyper, knobs: SamplerKnobs, aux=None):
        # single-box keeps the prepared corpus-level index (static across
        # iterations; the cell path re-sorts per sweep because shard_map
        # hands it only the cell's token arrays)
        assert aux is not None, "lightlda needs prepare()'s doc index"
        return lightlda_sweep(
            state, corpus, hyper, aux, knobs.max_kw, num_mh=knobs.num_mh,
            use_kernel=kernel_dispatch(knobs.kernels),
            bt=knobs.bt, bs=knobs.bs,
        )

    def cell_sweep(
        self, key, word, doc, z_old, mask, n_wk, n_kd, n_k, hyper,
        num_words_pad, knobs: SamplerKnobs,
    ):
        knobs = self.resolve_cell_knobs(knobs, hyper)
        doc_index = build_cell_doc_index(doc, mask, n_kd.shape[0])
        return lightlda_cell(
            key, word, doc, z_old, mask, n_wk, n_kd, n_k, hyper,
            num_words_pad, doc_index, knobs.max_kw, num_mh=knobs.num_mh,
            use_kernel=kernel_dispatch(knobs.kernels),
            bt=knobs.bt, bs=knobs.bs,
        )
