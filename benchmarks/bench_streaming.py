"""Streaming vs batch training: throughput and resident doc-side state.

The streaming subsystem's pitch (DESIGN.md §7) is two numbers per
window: docs/sec through the windowed plan vs the batch plan on the same
corpus, and the resident doc-side count state — ``window_docs * K * 4``
bytes for the stream vs ``D * K * 4`` for batch, the O(window) vs
O(corpus) memory claim from *Towards Big Topic Modeling*. Emits CSV rows
through the run.py contract plus ``BENCH_streaming.json`` for CI.

    PYTHONPATH=src:. python benchmarks/run.py --only streaming

Scale knobs (env, for CI-sized runs): BENCH_STREAM_D (docs),
BENCH_STREAM_W (vocab), BENCH_STREAM_K (topics), BENCH_STREAM_WIN
(window_docs), BENCH_STREAM_ITERS (epochs / batch iterations).
"""
from __future__ import annotations

import json
import os
import time

from benchmarks.common import row

NUM_DOCS = int(os.environ.get("BENCH_STREAM_D", 512))
NUM_WORDS = int(os.environ.get("BENCH_STREAM_W", 1000))
NUM_TOPICS = int(os.environ.get("BENCH_STREAM_K", 32))
WINDOW_DOCS = int(os.environ.get("BENCH_STREAM_WIN", 64))
ITERS = int(os.environ.get("BENCH_STREAM_ITERS", 3))


def main() -> None:
    import jax

    from repro.core.types import LDAHyperParams
    from repro.data import synthetic_corpus
    from repro.data.stream import ReplaySource
    from repro.train.online import StreamingSession
    from repro.train.session import RunConfig, TrainSession

    corpus = synthetic_corpus(0, num_docs=NUM_DOCS, num_words=NUM_WORDS,
                              avg_doc_len=60, zipf_a=1.2)
    hyper = LDAHyperParams(num_topics=NUM_TOPICS)
    records = []

    # -- batch reference: one full-corpus sweep per iteration ------------
    batch = TrainSession(corpus, hyper,
                         RunConfig(algorithm="zen", num_iterations=ITERS))
    state = batch.init(jax.random.key(0))
    state = batch.step(state)  # compile
    t0 = time.perf_counter()
    for _ in range(ITERS):
        state = batch.step(state)
    jax.block_until_ready(state.n_wk)
    dt = time.perf_counter() - t0
    batch_docs_sec = NUM_DOCS * ITERS / dt
    batch_kd_bytes = NUM_DOCS * NUM_TOPICS * 4
    row("stream/batch_ref", dt / ITERS * 1e6,
        f"docs_per_sec={batch_docs_sec:.0f} "
        f"resident_kd_bytes={batch_kd_bytes}")
    records.append({
        "name": "batch_ref", "docs_per_sec": batch_docs_sec,
        "resident_kd_bytes": batch_kd_bytes,
        "docs": NUM_DOCS, "topics": NUM_TOPICS, "iters": ITERS,
    })

    # -- streaming: same corpus through the windowed rotation ------------
    src = ReplaySource(corpus, window_docs=WINDOW_DOCS, epochs=ITERS + 1)
    cfg = RunConfig(algorithm="zen", num_iterations=0,
                    window_docs=WINDOW_DOCS, window_sweeps=1)
    sess = StreamingSession(src, hyper, cfg)
    metrics = []
    sess.run(jax.random.key(0), callback=lambda s, m: metrics.append(m))
    # drop epoch 0: it pays compilation and cold model composition
    warm = metrics[src.windows_per_epoch:]
    docs = sum(m["docs"] for m in warm)
    secs = sum(m["docs"] / m["docs_per_sec"] for m in warm)
    stream_docs_sec = docs / secs
    stream_kd_bytes = max(m["resident_kd_bytes"] for m in warm)
    row("stream/windowed", secs / len(warm) * 1e6,
        f"docs_per_sec={stream_docs_sec:.0f} "
        f"resident_kd_bytes={stream_kd_bytes} "
        f"window_docs={WINDOW_DOCS}")
    records.append({
        "name": "windowed", "docs_per_sec": stream_docs_sec,
        "resident_kd_bytes": stream_kd_bytes,
        "window_docs": WINDOW_DOCS, "windows": len(metrics),
        "final_window_perplexity": warm[-1]["perplexity"],
    })

    shrink = batch_kd_bytes / max(1, stream_kd_bytes)
    row("stream/kd_state_shrink", 0.0,
        f"batch_over_window={shrink:.1f}x")
    records.append({"name": "kd_state_shrink", "batch_over_window": shrink})

    from benchmarks.common import bench_out_path

    with open(bench_out_path("BENCH_streaming.json"), "w") as f:
        json.dump(records, f, indent=2)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
