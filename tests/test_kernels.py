"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + statistics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from helpers import given, settings, st  # hypothesis, or the fallback shim

from repro.kernels.ops import topic_histogram, zen_infer_sample, zen_sample
from repro.kernels.ref import (
    topic_histogram_ref,
    zen_infer_sample_ref,
    zen_probs_ref,
    zen_sample_ref,
)
from repro.kernels.zen_sampler import hash_uniform


@pytest.mark.parametrize(
    "t,k,bt,bk",
    [
        (64, 128, 64, 128),
        (128, 256, 64, 128),
        (9, 33, 8, 128),  # unaligned -> padding path
        (300, 700, 64, 128),
        (256, 1024, 128, 256),
        (1, 5, 8, 128),
    ],
)
def test_zen_sampler_bit_exact(t, k, bt, bk, rng):
    nwk = jnp.asarray(rng.integers(0, 50, (t, k)), jnp.int32)
    nkd = jnp.asarray(rng.integers(0, 20, (t, k)), jnp.int32)
    z = jnp.asarray(rng.integers(0, k, (t,)), jnp.int32)
    nk = jnp.asarray(np.asarray(nwk).sum(0) + 1, jnp.float32)
    ak = jnp.asarray(rng.random(k) + 0.01, jnp.float32)
    out = zen_sample(nwk, nkd, z, ak, nk, jnp.int32(7), beta=0.01,
                     w_beta=5.0, bt=bt, bk=bk)
    ref = zen_sample_ref(nwk, nkd, z, ak, nk, jnp.int32(7), beta=0.01,
                         w_beta=5.0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize(
    "t,k,bt,bk",
    [
        (64, 128, 64, 128),
        (9, 33, 8, 128),  # unaligned -> padding path
        (300, 700, 64, 128),
        (1, 5, 8, 128),
    ],
)
def test_zen_infer_sampler_bit_exact(t, k, bt, bk, rng):
    """Frozen-model serving variant == its pure-jnp oracle, bit for bit
    (doc-side-only exclusion, per-token seeds)."""
    nwk = jnp.asarray(rng.integers(0, 50, (t, k)), jnp.int32)
    nkd = jnp.asarray(rng.integers(0, 20, (t, k)), jnp.int32)
    z = jnp.asarray(rng.integers(0, k, (t,)), jnp.int32)
    seeds = jnp.asarray(rng.integers(0, 2 ** 31 - 1, (t,)), jnp.int32)
    nk = jnp.asarray(np.asarray(nwk).sum(0) + 1, jnp.float32)
    ak = jnp.asarray(rng.random(k) + 0.01, jnp.float32)
    out = zen_infer_sample(nwk, nkd, z, seeds, ak, nk, beta=0.01,
                           w_beta=5.0, bt=bt, bk=bk)
    ref = zen_infer_sample_ref(nwk, nkd, z, seeds, ak, nk, beta=0.01,
                               w_beta=5.0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 80), st.integers(2, 200), st.integers(0, 2 ** 20))
def test_zen_sampler_property_sweep(t, k, seed):
    rng = np.random.default_rng(seed)
    nwk = jnp.asarray(rng.integers(0, 9, (t, k)), jnp.int32)
    nkd = jnp.asarray(rng.integers(0, 5, (t, k)), jnp.int32)
    z = jnp.asarray(rng.integers(0, k, (t,)), jnp.int32)
    nk = jnp.asarray(np.asarray(nwk).sum(0) + 1, jnp.float32)
    ak = jnp.asarray(rng.random(k) + 0.01, jnp.float32)
    out = zen_sample(nwk, nkd, z, ak, nk, jnp.int32(seed % 97), beta=0.05,
                     w_beta=2.0, bt=8, bk=128)
    ref = zen_sample_ref(nwk, nkd, z, ak, nk, jnp.int32(seed % 97),
                         beta=0.05, w_beta=2.0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_zen_sampler_distribution_chi_square(rng):
    """The Gumbel-max draw follows the exact ¬dw conditional."""
    k = 16
    reps = 4000
    nwk = jnp.asarray(np.tile(rng.integers(0, 20, (1, k)), (reps, 1)), jnp.int32)
    nkd = jnp.asarray(np.tile(rng.integers(0, 8, (1, k)), (reps, 1)), jnp.int32)
    z = jnp.full((reps,), 3, jnp.int32)
    nk = jnp.asarray(np.asarray(nwk)[0] * 50 + 10, jnp.float32)
    ak = jnp.asarray(rng.random(k) + 0.05, jnp.float32)
    # different seed per batch -> independent draws of the same conditional
    draws = []
    for seed in range(6):
        out = zen_sample(nwk, nkd, z, ak, nk, jnp.int32(seed), beta=0.01,
                         w_beta=3.0, bt=8, bk=128)
        draws.append(np.asarray(out))
    emp = np.bincount(np.concatenate(draws), minlength=k) / (reps * 6)
    p = np.asarray(
        zen_probs_ref(nwk[:1], nkd[:1], z[:1], ak, nk, beta=0.01, w_beta=3.0)
    )[0]
    chi2 = ((emp - p) ** 2 / np.maximum(p, 1e-9)).sum() * reps * 6
    assert chi2 < 3 * k, (chi2, emp, p)  # loose 3x dof bound


def test_hash_uniform_statistics():
    """The in-kernel counter hash is uniform enough: mean/var/KS checks."""
    rows = jnp.arange(1 << 12, dtype=jnp.int32)[:, None]
    cols = jnp.arange(64, dtype=jnp.int32)[None, :]
    u = np.asarray(hash_uniform(jnp.int32(123), rows, cols)).ravel()
    assert 0.0 < u.min() and u.max() < 1.0
    np.testing.assert_allclose(u.mean(), 0.5, atol=2e-3)
    np.testing.assert_allclose(u.var(), 1.0 / 12, atol=2e-3)
    # no obvious correlation between adjacent counters
    c = np.corrcoef(u[:-1], u[1:])[0, 1]
    assert abs(c) < 0.02


@pytest.mark.parametrize(
    "t,k,r",
    [(256, 512, 40), (100, 48, 7), (1024, 256, 200), (8, 16, 1), (33, 9, 5)],
)
def test_topic_histogram_exact(t, k, r, rng):
    rows = np.sort(rng.integers(0, r, t)).astype(np.int32)
    zo = rng.integers(0, k, t).astype(np.int32)
    zn = rng.integers(0, k, t).astype(np.int32)
    inc = rng.integers(0, 2, t).astype(np.int32)
    out = topic_histogram(
        jnp.asarray(rows), jnp.asarray(zo), jnp.asarray(zn),
        jnp.asarray(inc), r, k, bt=64, bk=128,
    )
    ref = topic_histogram_ref(
        jnp.asarray(rows), jnp.asarray(zo), jnp.asarray(zn),
        jnp.asarray(inc), r, k,
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 120), st.integers(2, 60), st.integers(1, 30),
       st.integers(0, 2 ** 20))
def test_topic_histogram_property_sweep(t, k, r, seed):
    rng = np.random.default_rng(seed)
    rows = np.sort(rng.integers(0, r, t)).astype(np.int32)
    zo = rng.integers(0, k, t).astype(np.int32)
    zn = rng.integers(0, k, t).astype(np.int32)
    inc = rng.integers(0, 2, t).astype(np.int32)
    out = topic_histogram(
        jnp.asarray(rows), jnp.asarray(zo), jnp.asarray(zn),
        jnp.asarray(inc), r, k, bt=16, bk=128,
    )
    ref = topic_histogram_ref(
        jnp.asarray(rows), jnp.asarray(zo), jnp.asarray(zn),
        jnp.asarray(inc), r, k,
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    # row sums are zero: a move is (-1, +1) within the same row
    np.testing.assert_array_equal(np.asarray(jnp.sum(out, 1)),
                                  np.zeros(r, np.int32))
