"""Corpus generation and IO.

* ``synthetic_corpus``     — power-law (Zipf) word frequencies and varying
  document lengths: the "natural graph" skew the paper's partitioning work
  targets (hot words vs long-tail words).
* ``synthetic_lda_corpus`` — documents generated *from* an LDA model with
  known topics, so convergence tests have ground truth structure to recover.
* ``load_libsvm/save_libsvm`` — the paper's corpus format ("saved as libsvm
  format"): one line per doc, ``label word_id:count ...``.
"""
from __future__ import annotations

import io
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.types import Corpus


def synthetic_corpus(
    seed: int,
    num_docs: int,
    num_words: int,
    avg_doc_len: int,
    zipf_a: float = 1.2,
) -> Corpus:
    """Zipf-distributed words, geometric-ish doc lengths. Token-level."""
    rng = np.random.default_rng(seed)
    lengths = np.maximum(1, rng.poisson(avg_doc_len, size=num_docs))
    total = int(lengths.sum())
    # Zipf over a finite vocabulary via inverse-CDF on k^-a
    ranks = np.arange(1, num_words + 1, dtype=np.float64)
    pmf = ranks ** (-zipf_a)
    pmf /= pmf.sum()
    words = rng.choice(num_words, size=total, p=pmf).astype(np.int32)
    docs = np.repeat(np.arange(num_docs, dtype=np.int32), lengths)
    return Corpus(
        word=jnp.asarray(words), doc=jnp.asarray(docs),
        num_words=num_words, num_docs=num_docs,
    )


def synthetic_lda_corpus(
    seed: int,
    num_docs: int,
    num_words: int,
    num_topics: int,
    avg_doc_len: int,
    alpha: float = 0.1,
    beta: float = 0.05,
) -> Tuple[Corpus, np.ndarray]:
    """Generate documents from the LDA generative process (paper Eq. 1).

    Returns (corpus, true_phi (K, W)) for recovery checks.
    """
    rng = np.random.default_rng(seed)
    phi = rng.dirichlet(np.full(num_words, beta), size=num_topics)  # (K, W)
    theta = rng.dirichlet(np.full(num_topics, alpha), size=num_docs)  # (D, K)
    lengths = np.maximum(1, rng.poisson(avg_doc_len, size=num_docs))
    words_list = []
    docs_list = []
    for d in range(num_docs):
        zs = rng.choice(num_topics, size=lengths[d], p=theta[d])
        for z in np.unique(zs):
            n = int((zs == z).sum())
            ws = rng.choice(num_words, size=n, p=phi[z])
            words_list.append(ws)
            docs_list.append(np.full(n, d, dtype=np.int32))
    words = np.concatenate(words_list).astype(np.int32)
    docs = np.concatenate(docs_list).astype(np.int32)
    return (
        Corpus(
            word=jnp.asarray(words), doc=jnp.asarray(docs),
            num_words=num_words, num_docs=num_docs,
        ),
        phi,
    )


def save_libsvm(corpus: Corpus, path: str) -> None:
    """Write doc-major libsvm lines: ``0 word:count ...``."""
    words = np.asarray(corpus.word)
    docs = np.asarray(corpus.doc)
    order = np.argsort(docs, kind="stable")
    words, docs = words[order], docs[order]
    with open(path, "w") as f:
        boundaries = np.searchsorted(docs, np.arange(corpus.num_docs + 1))
        for d in range(corpus.num_docs):
            ws = words[boundaries[d] : boundaries[d + 1]]
            uniq, cnt = np.unique(ws, return_counts=True)
            f.write(
                "0 " + " ".join(f"{w}:{c}" for w, c in zip(uniq, cnt)) + "\n"
            )


def load_libsvm(
    path_or_buf,
    num_words: Optional[int] = None,
    max_docs: Optional[int] = None,
) -> Corpus:
    """Read libsvm lines into a token-level corpus (counts expanded).

    ``path_or_buf`` may be a path or an already-open file handle. With
    ``max_docs`` set, reading stops after that many documents and — when a
    handle was passed — leaves the handle positioned at the next unread
    line, so a caller can chunk one file into document windows without
    re-reading it per window (``repro.data.stream.LibsvmStreamSource``).
    Doc ids in the returned corpus are always 0-based and local to the
    read, i.e. each window is a self-contained ``Corpus``; an exhausted
    handle yields an empty corpus (``num_docs == 0``). The whole-file path
    (``max_docs=None``) is unchanged.
    """
    if isinstance(path_or_buf, (str, bytes)):
        f = open(path_or_buf)
    else:
        f = path_or_buf
    words_list, docs_list = [], []
    d = 0
    max_w = -1
    for line in f:
        parts = line.strip().split()
        if not parts:
            continue
        for tok in parts[1:]:
            w, c = tok.split(":")
            w, c = int(w), int(float(c))
            max_w = max(max_w, w)
            words_list.extend([w] * c)
            docs_list.extend([d] * c)
        d += 1
        if max_docs is not None and d >= max_docs:
            break
    if isinstance(path_or_buf, (str, bytes)):
        f.close()
    return Corpus(
        word=jnp.asarray(np.asarray(words_list, dtype=np.int32)),
        doc=jnp.asarray(np.asarray(docs_list, dtype=np.int32)),
        num_words=num_words or (max_w + 1),
        num_docs=d,
    )


def skip_libsvm_docs(f, n: int) -> int:
    """Advance an open libsvm handle past ``n`` documents (blank lines
    don't count, matching ``load_libsvm``). Returns how many documents
    were actually skipped (fewer at EOF) — the window cursor fast-forward
    used when a stream resumes from a checkpoint."""
    skipped = 0
    while skipped < n:
        line = f.readline()
        if not line:
            break
        if line.strip():
            skipped += 1
    return skipped
