"""Faithful ZenLDA sampler on padded-sparse topic rows (paper Alg. 2).

This is the paper's algorithm with its CPU sparse structures adapted to
fixed shapes (DESIGN.md §2): doc-topic and word-topic rows are stored as
``(idx, cnt)`` pairs padded to a static max-nnz, so K_d / K_w cost shows up
as the padded row width — work per token is O(max_kd) (resp. O(max_kw) for
the hybrid's alternate branch), not O(K).

Per iteration (Alg. 2 structure):
  lines 3-6   gDense = alpha_k*beta/(N_k+W*beta)        -> gTable (alias)
  lines 7-11  wSparse[w] = N_w|k*alpha_k/(N_k+W*beta)   -> wTable (alias, per
              word, over the padded slots)               [stale, remedied]
  lines 12-16 dSparse = N_k|d*(N_w|k+beta)/(N_k+W*beta) -> CDF + binary
              search over the doc's padded slots         [fresh per (d,w)]
  line 18     two-level sample: pick the term by mass, then within the term
  remedy      if the draw equals the previous topic, resample once with the
              paper's per-term probability (§3.1).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.alias import AliasTable, build_alias, sample_alias
from repro.core.decompositions import ZenTerms, precompute_zen_terms
from repro.core.types import CGSState, Corpus, LDAHyperParams


class SparseRows(NamedTuple):
    """Padded-sparse rows of a count matrix: row r = {(idx[r,j], cnt[r,j])}.

    ``idx`` is sorted ascending per row; empty slots hold idx == K (sentinel)
    and cnt == 0, so searchsorted lookups miss them naturally.
    """

    idx: jax.Array  # (R, max_nnz) int32
    cnt: jax.Array  # (R, max_nnz) int32
    num_topics: int

    @property
    def nnz(self) -> jax.Array:  # (R,)
        return jnp.sum(self.cnt > 0, axis=-1)


def sparsify_rows(dense: jax.Array, max_nnz: int) -> SparseRows:
    """Dense (R, K) -> padded-sparse. Rows with more than ``max_nnz``
    nonzeros would be truncated — callers assert via ``max_row_nnz``."""
    k = dense.shape[-1]
    # sort key: zeros last, then by topic id -> sorted nonzero prefix
    key = jnp.where(dense > 0, jnp.arange(k, dtype=jnp.int32)[None, :], k)
    order = jnp.argsort(key, axis=-1)[:, :max_nnz]
    idx = jnp.take_along_axis(key, order, axis=-1).astype(jnp.int32)
    cnt = jnp.take_along_axis(dense, order, axis=-1).astype(jnp.int32)
    cnt = jnp.where(idx < k, cnt, 0)
    return SparseRows(idx=idx, cnt=cnt, num_topics=k)


def max_row_nnz(dense: jax.Array) -> jax.Array:
    return jnp.max(jnp.sum(dense > 0, axis=-1))


def shard_row_capacity(dense_block: jax.Array, multiple: int = 8) -> int:
    """Padded-row capacity for one shard's count block (host-side).

    The capacity is computed from the rows the shard will actually
    sparsify — a lane-friendly round-up of the block's max row nnz, capped
    at K (a row can never hold more than K live topics, so any larger pad
    is pure waste). On a sharded global-view array the reduction runs
    shard-locally and only the scalar max crosses devices, so no shard ever
    gathers another shard's block.
    """
    k = dense_block.shape[-1]
    m = int(jax.device_get(max_row_nnz(dense_block)))
    m = max(multiple, ((m + multiple - 1) // multiple) * multiple)
    return min(m, k)


def densify_rows(rows: SparseRows) -> jax.Array:
    r = rows.idx.shape[0]
    out = jnp.zeros((r, rows.num_topics + 1), jnp.int32)
    out = out.at[jnp.arange(r)[:, None], rows.idx].add(rows.cnt)
    return out[:, : rows.num_topics]


def lookup_rows(rows: SparseRows, row_ids: jax.Array, topics: jax.Array) -> jax.Array:
    """cnt[row_ids, topics] via per-row binary search. Shapes broadcast:
    row_ids (T,), topics (T, J) -> (T, J)."""
    idx = rows.idx[row_ids]  # (T, max_nnz)
    cnt = rows.cnt[row_ids]
    pos = jax.vmap(jnp.searchsorted)(idx, topics)  # (T, J)
    pos = jnp.minimum(pos, idx.shape[-1] - 1)
    hit = jnp.take_along_axis(idx, pos, axis=-1) == topics
    val = jnp.take_along_axis(cnt, pos, axis=-1)
    return jnp.where(hit, val, 0)


class ZenTables(NamedTuple):
    """Per-iteration sampling state (the 'ship model state' payload)."""

    terms: ZenTerms
    g_table: AliasTable  # over K
    w_prob: jax.Array  # (W, max_kw) alias prob over padded slots
    w_alias: jax.Array  # (W, max_kw) alias target (slot index)
    w_mass: jax.Array  # (W,) total wSparse mass per word
    wk_rows: SparseRows
    kd_rows: SparseRows


def build_tables(
    n_wk: jax.Array,
    n_kd: jax.Array,
    n_k: jax.Array,
    hyper: LDAHyperParams,
    num_words: int,
    max_kw: int,
    max_kd: int,
) -> ZenTables:
    terms = precompute_zen_terms(n_k, hyper, num_words)
    g_table = build_alias(terms.g_dense)
    wk_rows = sparsify_rows(n_wk, max_kw)
    kd_rows = sparsify_rows(n_kd, max_kd)
    # wSparse over padded slots: cnt * t4[idx]; empty slots -> 0 mass.
    t4 = jnp.concatenate([terms.t4, jnp.zeros((1,), jnp.float32)])
    w_vals = wk_rows.cnt.astype(jnp.float32) * t4[wk_rows.idx]
    w_table = jax.vmap(build_alias)(w_vals)
    return ZenTables(
        terms=terms,
        g_table=g_table,
        w_prob=w_table.prob,
        w_alias=w_table.alias,
        w_mass=jnp.sum(w_vals, axis=-1),
        wk_rows=wk_rows,
        kd_rows=kd_rows,
    )


def _d_sparse(
    tables: ZenTables, word: jax.Array, doc: jax.Array, beta: float
) -> Tuple[jax.Array, jax.Array]:
    """dSparse values over the doc's padded slots. Returns (vals (T, max_kd),
    topics (T, max_kd))."""
    kd_idx = tables.kd_rows.idx[doc]  # (T, max_kd)
    kd_cnt = tables.kd_rows.cnt[doc]
    n_wk_at = lookup_rows(tables.wk_rows, word, kd_idx)  # (T, max_kd)
    t1 = jnp.concatenate([tables.terms.t1, jnp.zeros((1,), jnp.float32)])
    vals = (
        kd_cnt.astype(jnp.float32)
        * (n_wk_at.astype(jnp.float32) + beta)
        * t1[kd_idx]
    )
    vals = jnp.where(kd_cnt > 0, vals, 0.0)
    return vals, kd_idx


def zen_sample_tokens(
    key: jax.Array,
    tables: ZenTables,
    word: jax.Array,  # (T,)
    doc: jax.Array,  # (T,)
    prev_topic: jax.Array,  # (T,) z from last iteration (for the remedy)
    hyper: LDAHyperParams,
    use_kernel: bool = False,
    bt: int = 256,
    bs: int = 128,
) -> jax.Array:
    """Sample new topics for T tokens — the faithful two-level ZenLDA draw.

    ``use_kernel`` routes the term-3 dSparse inversion through the
    padded-sparse Pallas kernel (``kernels.sparse_row``). The kernel's op
    sequence (cumsum, lower-bound count, clamp, topic select) is exactly
    this function's XLA term-3 sequence, so dispatch is bit-identical."""

    def draw(key):
        k_u, k_g1, k_g2, k_w1, k_w2, k_d = jax.random.split(key, 6)
        d_vals, d_topics = _d_sparse(tables, word, doc, hyper.beta)
        m3 = jnp.sum(d_vals, axis=-1)
        m1 = tables.terms.g_mass
        m2 = tables.w_mass[word]
        total = m1 + m2 + m3
        u = jax.random.uniform(k_u, word.shape) * total

        # term 1: global alias table
        z_g = sample_alias(
            tables.g_table,
            jax.random.uniform(k_g1, word.shape),
            jax.random.uniform(k_g2, word.shape),
        )
        # term 2: per-word alias over padded slots -> topic id
        w_tab = AliasTable(prob=tables.w_prob[word], alias=tables.w_alias[word])
        slots = jnp.arange(tables.w_prob.shape[-1])
        u1 = jax.random.uniform(k_w1, word.shape)
        u2 = jax.random.uniform(k_w2, word.shape)
        nbins = tables.w_prob.shape[-1]
        bins = jnp.minimum((u1 * nbins).astype(jnp.int32), nbins - 1)
        keep = u2 < jnp.take_along_axis(w_tab.prob, bins[:, None], axis=-1)[:, 0]
        slot = jnp.where(
            keep, bins, jnp.take_along_axis(w_tab.alias, bins[:, None], axis=-1)[:, 0]
        )
        z_w = jnp.take_along_axis(
            tables.wk_rows.idx[word], slot[:, None], axis=-1
        )[:, 0]
        # term 3: CDF binary search over the doc's padded slots
        target = jnp.maximum(u - (m1 + m2), 0.0)
        if use_kernel:
            from repro.kernels.ops import sparse_row_sample

            z_d = sparse_row_sample(d_vals, d_topics, target, bt=bt, bs=bs)
        else:
            cdf = jnp.cumsum(d_vals, axis=-1)
            pos = jnp.sum(cdf < target[:, None], axis=-1)
            pos = jnp.minimum(pos, d_vals.shape[-1] - 1)
            z_d = jnp.take_along_axis(d_topics, pos[:, None], axis=-1)[:, 0]

        branch = jnp.where(u < m1, 0, jnp.where(u < m1 + m2, 1, 2))
        z = jnp.where(branch == 0, z_g, jnp.where(branch == 1, z_w, z_d))
        # guard: sentinel K can only appear from fully-padded rows
        z = jnp.minimum(z, hyper.num_topics - 1).astype(jnp.int32)
        return z, branch

    key_a, key_b, key_r = jax.random.split(key, 3)
    z1, branch1 = draw(key_a)
    z2, _ = draw(key_b)

    # Resampling remedy (§3.1): the stale tables did not exclude the token's
    # own previous assignment. If the draw equals prev_topic, redraw once
    # with the per-term probability.
    n_wk_prev = lookup_rows(tables.wk_rows, word, prev_topic[:, None])[:, 0]
    n_kd_prev = lookup_rows(tables.kd_rows, doc, prev_topic[:, None])[:, 0]
    nw = jnp.maximum(n_wk_prev.astype(jnp.float32), 1.0)
    nd = jnp.maximum(n_kd_prev.astype(jnp.float32), 1.0)
    p_w = 1.0 / nw  # wSparse remedy
    p_d = jnp.clip(1.0 / nd + (nd + nw - 1.0) / (nd * nw), 0.0, 1.0)  # dSparse
    remedy_p = jnp.where(branch1 == 1, p_w, jnp.where(branch1 == 2, p_d, 0.0))
    u_r = jax.random.uniform(key_r, z1.shape)
    take_second = (z1 == prev_topic) & (u_r < remedy_p)
    return jnp.where(take_second, z2, z1).astype(jnp.int32)


def zen_sparse_cell(
    key: jax.Array,
    word: jax.Array,  # (T,) shard-local word ids
    doc: jax.Array,  # (T,) shard-local doc ids
    z_old: jax.Array,  # (T,)
    n_wk: jax.Array,  # (Ws, K) local word-topic block
    n_kd: jax.Array,  # (Ds, K) local doc-topic block
    n_k: jax.Array,  # (K,) replicated
    hyper: LDAHyperParams,
    num_words: int,  # global (padded) vocabulary — the W in W*beta
    max_kw: int,
    max_kd: int,
    use_kernel: bool = False,
    bt: int = 256,
    bs: int = 128,
) -> jax.Array:
    """One faithful ZenLDA pass over a cell's tokens (stale counts) -> (T,).

    Everything is shard-relative: ids index the local count blocks, the
    padded-sparse tables are built from the local blocks only (widths are
    the *per-shard* capacities, see ``shard_row_capacity``), and only the
    replicated ``n_k``/``num_words`` carry global scale. The single-box
    sweep is this with the whole corpus as one cell.
    """
    tables = build_tables(n_wk, n_kd, n_k, hyper, num_words, max_kw, max_kd)
    return zen_sample_tokens(
        key, tables, word, doc, z_old, hyper,
        use_kernel=use_kernel, bt=bt, bs=bs,
    )


def zen_sparse_sweep(
    state: CGSState,
    corpus: Corpus,
    hyper: LDAHyperParams,
    max_kw: int,
    max_kd: int,
    use_kernel: bool = False,
    bt: int = 256,
    bs: int = 128,
) -> jax.Array:
    """One faithful ZenLDA sweep over all tokens (stale counts). -> (E,)."""
    key = jax.random.fold_in(state.rng, state.iteration)
    return zen_sparse_cell(
        key, corpus.word, corpus.doc, state.topic,
        state.n_wk, state.n_kd, state.n_k, hyper, corpus.num_words,
        max_kw, max_kd, use_kernel=use_kernel, bt=bt, bs=bs,
    )
