"""Sharded model-parallel serving + the multi-engine router (DESIGN.md §5.4).

The serve-path analogue of ``test_mesh_parity.py``: on a 2-device CPU mesh
(subprocess — device count locks at first jax init) the sharded
``infer_sweep`` dispatch must produce **bit-equal** thetas to the
single-host engine for every native-infer backend, because per-slot keys
are consumed at the full (B, L) layout and every draw is per-token (the
``infer_sweep`` contract in ``algorithms/base.py``). The ticket-lifecycle
invariants from the latency-serving and streaming suites (admitted-slot
version pinning, zero dropped tickets under reload) are re-proven under
sharded dispatch, and the router's admission contract (unique tickets,
load spread, broadcast reload) is pinned single-process.
"""
import numpy as np
import pytest

from helpers import run_with_devices

import jax
import jax.numpy as jnp

from repro.core.types import LDAHyperParams
from repro.serving import (
    FrozenLDAModel,
    LDAEngine,
    LDARouter,
    LDAServeConfig,
    ShardedFrozenLDAModel,
)

SERVE_BACKENDS = ("zen", "zen_cdf", "zen_pallas")
DOC_LENGTHS = (3, 9, 17, 1, 12, 30)


def _model(seed=1, w=40, k=8):
    n_wk = np.random.default_rng(seed).poisson(3.0, (w, k)).astype(np.int32)
    return FrozenLDAModel(
        n_wk=jnp.asarray(n_wk),
        n_k=jnp.asarray(n_wk.sum(0).astype(np.int32)),
        hyper=LDAHyperParams(num_topics=k, alpha=0.5, beta=0.1),
    )


def _docs(rng, w=40):
    return [rng.integers(0, w, size=ln).astype(np.int32)
            for ln in DOC_LENGTHS]


# ---------------------------------------------------------------------------
# 2-device mesh parity (subprocess)
# ---------------------------------------------------------------------------

_PARITY = """
import warnings; warnings.filterwarnings('ignore')
import numpy as np, jax, jax.numpy as jnp
from repro.core.types import LDAHyperParams
from repro.serving import (FrozenLDAModel, LDAEngine, LDARouter,
                           LDAServeConfig, ShardedFrozenLDAModel)

assert len(jax.devices()) == 2
rng = np.random.default_rng(0)
W, K = 40, 8
n_wk = np.random.default_rng(1).poisson(3.0, (W, K)).astype(np.int32)
model = FrozenLDAModel(n_wk=jnp.asarray(n_wk),
                       n_k=jnp.asarray(n_wk.sum(0).astype(np.int32)),
                       hyper=LDAHyperParams(num_topics=K, alpha=0.5,
                                            beta=0.1))
docs = [rng.integers(0, W, size=l).astype(np.int32)
        for l in (3, 9, 17, 1, 12, 30)]
keys = [jax.random.key(100 + i) for i in range(len(docs))]
algo = {algo!r}

base = dict(buckets=(8, 32), max_batch=4, num_sweeps=5, algorithm=algo)
single = LDAEngine(model, LDAServeConfig(**base), seed=0)
t_single = np.stack([single.infer_batch([d], key=k)[0]
                     for d, k in zip(docs, keys)])

cfg = LDAServeConfig(mesh_shape=(1, 2), **base)
sharded = LDAEngine(model, cfg, seed=0)
sm = sharded.model
assert isinstance(sm, ShardedFrozenLDAModel)
assert sm.num_words == W and sm.num_shards == 2
assert sm.n_wk.shape[0] == 2 * sm.words_per_shard
# phi() inverts the relabeling: bit-equal to the single-host phi
np.testing.assert_array_equal(np.asarray(sm.phi()),
                              np.asarray(model.phi()))
t_sharded = np.stack([sharded.infer_batch([d], key=k)[0]
                      for d, k in zip(docs, keys)])
np.testing.assert_array_equal(t_sharded, t_single)

# the router composes with sharding: 2 replicas, each a sharded engine;
# explicit per-request keys make routing irrelevant to the draws
router = LDARouter(model, cfg, replicas=2, seed=0)
t_router = np.stack([router.infer_batch([d], key=k)[0]
                     for d, k in zip(docs, keys)])
np.testing.assert_array_equal(t_router, t_single)
print('PARITY_OK', algo)
"""


@pytest.mark.parametrize("algo", SERVE_BACKENDS)
def test_sharded_serve_parity_2dev(algo):
    out = run_with_devices(_PARITY.format(algo=algo), n_devices=2)
    assert f"PARITY_OK {algo}" in out


_RELOAD = """
import warnings; warnings.filterwarnings('ignore')
import numpy as np, jax, jax.numpy as jnp
from repro.core.types import LDAHyperParams
from repro.serving import (FrozenLDAModel, LDAEngine, LDAServeConfig)

hyper = LDAHyperParams(num_topics=8, alpha=0.5, beta=0.1)
def mk(seed, scale):
    # very different row masses => very different LPT permutations, so a
    # relabel frozen at submit time would decode garbage after reload
    rng = np.random.default_rng(seed)
    n_wk = rng.poisson(scale, (40, 8)).astype(np.int32)
    n_wk[rng.permutation(40)[:5]] += 200
    return FrozenLDAModel(n_wk=jnp.asarray(n_wk),
                          n_k=jnp.asarray(n_wk.sum(0).astype(np.int32)),
                          hyper=hyper)

m0, m1 = mk(1, 3.0), mk(2, 1.0)
rng = np.random.default_rng(0)
doc_a = rng.integers(0, 40, size=7).astype(np.int32)
doc_b = rng.integers(0, 40, size=6).astype(np.int32)
key_b = jax.random.key(77)

cfg = LDAServeConfig(buckets=(8,), max_batch=1, num_sweeps=40,
                     algorithm='zen_cdf', mesh_shape=(1, 2))
eng = LDAEngine(m0, cfg, seed=0)
ta = eng.submit_async(doc_a)
eng.step()
assert eng.poll(ta) == 'admitted'
eng.reload(m1)
tb = eng.submit_async(doc_b, key=key_b)
eng.step()
assert eng.poll(tb) == 'queued'  # old-version occupant pins the bucket
ra, rb = eng.request(ta), eng.request(tb)  # refs survive the reap
theta_a = eng.result(ta)
theta_b = eng.result(tb)
assert theta_a.shape == (8,)
# A finished on the model it was admitted under; B on the reloaded one
assert ra.model_version == 0 and rb.model_version == 1

# zero dropped tickets, and B decoded under the NEW model's permutation:
# bit-equal to a fresh sharded engine serving m1 with the same key
fresh = LDAEngine(m1, cfg, seed=0)
np.testing.assert_array_equal(theta_b, fresh.infer_batch([doc_b],
                                                         key=key_b)[0])
assert eng.model_version == 1
print('RELOAD_OK')
"""


def test_sharded_reload_relabels_at_placement_2dev():
    out = run_with_devices(_RELOAD, n_devices=2)
    assert "RELOAD_OK" in out


# ---------------------------------------------------------------------------
# single-process: config validation, 1-shard path, router contract
# ---------------------------------------------------------------------------

def test_mesh_shape_validation():
    model = _model()
    with pytest.raises(ValueError, match="latency"):
        LDAEngine(model, LDAServeConfig(mode="latency", mesh_shape=(1, 1)))
    with pytest.raises(ValueError, match=r"\(1, m\)"):
        LDAEngine(model, LDAServeConfig(mesh_shape=(2, 1)))
    with pytest.raises(ValueError, match=r"\(1, m\)"):
        LDAEngine(model, LDAServeConfig(mesh_shape=(1, 2, 1)))


def test_one_shard_mesh_matches_single_host():
    """mesh_shape=(1, 1) runs the whole sharded machinery (relabel,
    shard_map dispatch, psum combine) on one device — bit-equal to the
    plain engine, so the sharded path is testable without a mesh."""
    model = _model()
    rng = np.random.default_rng(0)
    docs = _docs(rng)
    keys = [jax.random.key(100 + i) for i in range(len(docs))]
    base = dict(buckets=(8, 32), max_batch=4, num_sweeps=5,
                algorithm="zen_cdf")
    single = LDAEngine(model, LDAServeConfig(**base), seed=0)
    sharded = LDAEngine(model, LDAServeConfig(mesh_shape=(1, 1), **base),
                        seed=0)
    assert isinstance(sharded.model, ShardedFrozenLDAModel)
    for d, k in zip(docs, keys):
        np.testing.assert_array_equal(
            sharded.infer_batch([d], key=k)[0],
            single.infer_batch([d], key=k)[0],
        )


def test_sharded_model_relabel_and_phi():
    model = _model()
    mesh = LDAEngine(
        model, LDAServeConfig(mesh_shape=(1, 1), algorithm="zen")
    )._mesh
    sm = ShardedFrozenLDAModel.shard(model, mesh)
    # the permutation is a bijection [0, W) -> [0, W_pad)
    assert len(set(sm.word_perm.tolist())) == model.num_words
    ids = np.arange(model.num_words, dtype=np.int32)
    np.testing.assert_array_equal(
        np.asarray(sm.n_wk)[sm.relabel(ids)], np.asarray(model.n_wk)
    )
    np.testing.assert_array_equal(np.asarray(sm.phi()),
                                  np.asarray(model.phi()))


def test_router_unique_tickets_and_load_spread():
    model = _model()
    router = LDARouter(
        model,
        LDAServeConfig(buckets=(8, 32), max_batch=2, num_sweeps=3,
                       algorithm="zen"),
        replicas=2, seed=0,
    )
    rng = np.random.default_rng(0)
    docs = [rng.integers(0, 40, size=6).astype(np.int32) for _ in range(8)]
    tickets = [router.submit_async(d) for d in docs]
    assert len(set(tickets)) == len(tickets)
    # least-loaded admission alternates queue depth across replicas
    assert all(e.load > 0 for e in router.engines)
    thetas = np.stack([router.result(t) for t in tickets])
    assert thetas.shape == (len(docs), model.num_topics)
    np.testing.assert_allclose(thetas.sum(1), 1.0, rtol=1e-5)
    assert router.docs_done == len(docs)
    # every ticket was reaped: poll now raises
    for t in tickets:
        with pytest.raises(KeyError):
            router.poll(t)


def test_router_parity_with_explicit_keys():
    """Explicit per-request keys make thetas routing-independent: the
    router fleet reproduces a single engine bit-for-bit."""
    model = _model()
    cfg = LDAServeConfig(buckets=(8, 32), max_batch=2, num_sweeps=5,
                         algorithm="zen")
    router = LDARouter(model, cfg, replicas=3, seed=9)
    single = LDAEngine(model, cfg, seed=0)
    rng = np.random.default_rng(3)
    docs = _docs(rng)
    keys = [jax.random.key(500 + i) for i in range(len(docs))]
    t_router = np.stack([router.infer_batch([d], key=k)[0]
                         for d, k in zip(docs, keys)])
    t_single = np.stack([single.infer_batch([d], key=k)[0]
                         for d, k in zip(docs, keys)])
    np.testing.assert_array_equal(t_router, t_single)


def test_router_reload_broadcast_zero_drops():
    """Reload mid-traffic broadcasts one version tag to every replica;
    every outstanding ticket still completes (on its admitted version)."""
    model = _model(seed=1)
    model2 = _model(seed=2)
    router = LDARouter(
        model,
        LDAServeConfig(buckets=(8,), max_batch=1, num_sweeps=30,
                       algorithm="zen"),
        replicas=2, seed=0,
    )
    rng = np.random.default_rng(0)
    docs = [rng.integers(0, 40, size=5).astype(np.int32) for _ in range(4)]
    tickets = [router.submit_async(d) for d in docs]
    for e in router.engines:
        e.step()  # admit one per replica, pre-reload
    v = router.reload(model2)
    assert v == 1
    assert [e.model_version for e in router.engines] == [1, 1]
    thetas = [router.result(t) for t in tickets]
    assert all(th.shape == (model.num_topics,) for th in thetas)
    assert router.docs_done == len(docs)


def test_router_cancel_delegates_and_frees_slot():
    model = _model()
    router = LDARouter(
        model,
        LDAServeConfig(buckets=(8,), max_batch=1, num_sweeps=50,
                       algorithm="zen"),
        replicas=1, seed=0,
    )
    rng = np.random.default_rng(0)
    ta = router.submit_async(rng.integers(0, 40, 5).astype(np.int32))
    router.engines[0].step()
    assert router.poll(ta) == "admitted"
    assert router.cancel(ta) is True
    assert router.cancel(ta) is False  # reaped: idempotent False
    # slot freed: a new request admits immediately
    tb = router.submit_async(rng.integers(0, 40, 5).astype(np.int32))
    router.engines[0].step()
    assert router.poll(tb) == "admitted"
