"""``zen_hybrid`` — ZenLDAHybrid (paper §3.1): per-token pick the
decomposition whose fresh term ranges over the sparser row.

Realized as two-group dispatch over the *registry's own* ``zen_sparse``
(fresh term over K_d) and ``sparselda`` (fresh term over K_w) backends, so
measured work tracks min(K_d, K_w) and the hybrid automatically follows any
improvement to either constituent backend.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.algorithms.base import SamplerBackend, SamplerKnobs
from repro.algorithms.registry import get, register


@register("zen_hybrid")
class ZenHybrid(SamplerBackend):
    """Route each token to the sparser of the two decompositions."""

    needs_row_pads = True

    def sweep(self, state, corpus, hyper, knobs: SamplerKnobs, aux=None):
        kd_nnz = jnp.sum(state.n_kd > 0, axis=-1)[corpus.doc]
        kw_nnz = jnp.sum(state.n_wk > 0, axis=-1)[corpus.word]
        use_zen = kd_nnz <= kw_nnz
        z_zen = get("zen_sparse").sweep(state, corpus, hyper, knobs)
        z_alt = get("sparselda").sweep(state, corpus, hyper, knobs)
        return jnp.where(use_zen, z_zen, z_alt)
