"""Sharding rules: every assigned arch's param specs divide on the
production meshes (subprocess builds a 4-device stand-in + pure spec math
against production mesh shapes)."""
import numpy as np
import pytest

from helpers import run_with_devices


def test_param_specs_divide_on_production_shapes():
    """Validate divisibility of every rule against 16x16 and 2x16x16 by
    constructing the specs on a small mesh with the same axis names and
    checking dims against the production sizes analytically."""
    run_with_devices("""
import warnings; warnings.filterwarnings('ignore')
import jax, numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs import get_config, list_archs
from repro.launch.specs import params_abstract
from repro.utils.compat import abstract_mesh
from repro.sharding.partition import param_specs

# the REAL production meshes, as abstract shapes (no 512 devices needed)
MESHES = [
    abstract_mesh((16, 16), ('data', 'model')),
    abstract_mesh((2, 16, 16), ('pod', 'data', 'model')),
]

def axis_size(mesh, entry):
    if entry is None: return 1
    if isinstance(entry, str): return mesh.shape[entry]
    return int(np.prod([mesh.shape[a] for a in entry]))

checked = 0
for mesh in MESHES:
    for arch in list_archs(lm_only=True):
        cfg = get_config(arch)
        shapes = params_abstract(cfg)
        specs = param_specs(shapes, cfg, mesh)
        flat_s = jax.tree_util.tree_leaves_with_path(shapes)
        flat_p = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_s) == len(flat_p)
        for (path, leaf), spec in zip(flat_s, flat_p):
            for dim, entry in zip(leaf.shape, tuple(spec)):
                size = axis_size(mesh, entry)
                assert dim % size == 0, (arch, path, leaf.shape, tuple(spec))
            checked += 1
print('checked', checked, 'leaves')
""", n_devices=4)


def test_sharded_matmul_runs():
    run_with_devices("""
import warnings; warnings.filterwarnings('ignore')
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 2), ('data', 'model'))
x = jax.device_put(jnp.ones((8, 16)), NamedSharding(mesh, P('data', None)))
w = jax.device_put(jnp.ones((16, 8)), NamedSharding(mesh, P(None, 'model')))
y = jax.jit(lambda a, b: a @ b)(x, w)
np.testing.assert_allclose(np.asarray(y), 16.0)
print('OK')
""", n_devices=4)


def test_cache_sharding_rules():
    run_with_devices("""
import warnings; warnings.filterwarnings('ignore')
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models.model import init_cache
from repro.sharding import cache_sharding
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 2), ('data', 'model'))
cfg = get_config('qwen3-8b')
# decode_32k-like: batch divides -> batch over data, seq over model
caches = init_cache(cfg, 4, 64, abstract=True)
sh = cache_sharding(caches, mesh)
spec = sh.k.spec
assert spec[1] is not None, spec    # batch sharded
assert spec[2] == 'model', spec     # seq sharded for flash-decode
# long-context batch=1 -> sequence takes every axis
caches1 = init_cache(cfg, 1, 64, abstract=True)
sh1 = cache_sharding(caches1, mesh)
assert sh1.k.spec[2] is not None
print('OK')
""", n_devices=4)


def test_small_scale_sharded_train_step():
    """An actually-executed sharded LM train step on a 2x2 mesh."""
    run_with_devices("""
import warnings; warnings.filterwarnings('ignore')
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.launch.specs import batch_specs, state_abstract
from repro.sharding import batch_sharding, param_shardings
from repro.launch.specs import _opt_shardings
from repro.train.train_step import TrainState, init_train_state, make_train_step
from repro.train.optimizer import OptConfig
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 2), ('data', 'model'))
import dataclasses
cfg = get_config('qwen3-8b-smoke')
cfg = dataclasses.replace(cfg, d_model=128, num_heads=4, num_kv_heads=2,
                          d_ff=256, vocab_size=512)
st = init_train_state(jax.random.key(0), cfg, OptConfig())
p_sh = param_shardings(st.params, cfg, mesh)
opt_sh = _opt_shardings(st.opt_state, st.params, cfg, mesh)
from jax.sharding import NamedSharding, PartitionSpec as P
st_sh = TrainState(params=p_sh, opt_state=opt_sh,
                   step=NamedSharding(mesh, P()))
st = jax.device_put(st, st_sh)
batch = {'tokens': jnp.ones((4, 16), jnp.int32),
         'labels': jnp.ones((4, 16), jnp.int32)}
b_sh = batch_sharding(batch, mesh)
batch = jax.device_put(batch, b_sh)
step = jax.jit(make_train_step(cfg), in_shardings=(st_sh, b_sh),
               out_shardings=(st_sh, None), donate_argnums=(0,))
losses = []
for _ in range(3):
    st, m = step(st, batch)
    losses.append(float(m['loss']))
assert np.isfinite(losses).all() and losses[-1] < losses[0], losses
print('SHARDED TRAIN OK', losses)
""", n_devices=4, timeout=900)
