"""``zen_sparse`` — the faithful padded-sparse ZenLDA sampler (paper Alg. 2)
behind the backend interface. The heavy lifting stays in
``core.zen_sparse``; this wrapper only adapts the contract."""
from __future__ import annotations

from repro.algorithms.base import SamplerBackend, SamplerKnobs
from repro.algorithms.registry import register
from repro.core.zen_sparse import zen_sparse_sweep


@register("zen_sparse")
class ZenSparse(SamplerBackend):
    """Alias tables + padded-sparse rows; work/token tracks O(K_d)."""

    needs_row_pads = True

    def sweep(self, state, corpus, hyper, knobs: SamplerKnobs, aux=None):
        return zen_sparse_sweep(
            state, corpus, hyper, knobs.max_kw, knobs.max_kd
        )
