"""LDA training driver (launch-level CLI) — one ``TrainSession`` for both
paths.

Every run is a declarative ``RunConfig`` driving a ``TrainSession``
(DESIGN.md §6): the algorithm resolves once through the ``repro.algorithms``
registry, ``mesh_shape`` selects the execution plan (single-box vs the
shard_map mesh), and periodic events — llh/perplexity eval, model +
elastic training checkpoints, exclusion enablement, exact count rebuild,
padded-row re-resolution, duplicate-topic merging — fire from the
session's schedule. Every backend with ``supports_shard_map`` runs the
mesh plan; only backends without a cell sweep (std) fall back to
single-box. On a real TPU slice the mesh plan runs under
``jax.distributed``; on CPU hosts pass --host-devices to simulate N
devices.

    PYTHONPATH=src python -m repro.launch.train \
        --rows 2 --cols 2 --host-devices 4 --iters 50 \
        [--corpus path.libsvm] [--ckpt DIR] [--algorithm <registered-name>]
        [--delta-dtype int16] [--exclusion-start 30] [--rebuild-every 10]
    PYTHONPATH=src python -m repro.launch.train --config run.json
    PYTHONPATH=src python -m repro.launch.train --dump-config run.json ...
    PYTHONPATH=src python -m repro.launch.train --list-algorithms

``--config`` loads a ``RunConfig`` JSON (the ``to_json`` round-trip);
``--dump-config`` writes the resolved config and exits, so any CLI
invocation can be frozen into a reproducible run file.

``--checkpoint-dir`` writes *model* checkpoints (N_wk/N_k + hyper) on both
paths — the artifact ``launch/serve_lda.py`` serves from. ``--ckpt``
remains the elastic *training* checkpoint (assignments only; resumes
automatically).

``--stream`` switches to windowed online training (DESIGN.md §7): a
``CorpusSource`` (``--stream-source replay|libsvm:<path>|drift``) feeds a
``StreamingSession`` window by window, model checkpoints land on a
per-window cadence, and ``launch/serve_lda.py --follow`` hot-reloads them
into a running engine — the two commands form the live pipeline:

    PYTHONPATH=src python -m repro.launch.train --stream \
        --window-docs 64 --decay 0.02 --checkpoint-dir /tmp/lda_live
    PYTHONPATH=src python -m repro.launch.serve_lda \
        --checkpoint-dir /tmp/lda_live --follow
"""
import argparse
import os


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default=None,
                    help="load a RunConfig JSON (overrides per-field flags)")
    ap.add_argument("--dump-config", default=None, metavar="PATH",
                    help="write the resolved RunConfig JSON and exit")
    ap.add_argument("--rows", type=int, default=2, help="data-parallel rows")
    ap.add_argument("--cols", type=int, default=2, help="model-parallel cols")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="simulate N host devices (CPU bring-up)")
    ap.add_argument("--corpus", default=None, help="libsvm corpus path")
    ap.add_argument("--topics", type=int, default=64)
    ap.add_argument("--iters", type=int, default=50)
    ap.add_argument("--algorithm", default="zen_cdf",
                    help="any name from --list-algorithms")
    ap.add_argument("--list-algorithms", action="store_true",
                    help="print the registered sampler backends and exit")
    ap.add_argument("--single-box", action="store_true",
                    help="force the single-box plan")
    ap.add_argument("--max-kd", type=int, default=None,
                    help="sparse doc-row width (default: auto — resolved "
                         "from the counts, and re-resolved on the "
                         "--rebuild-every cadence on the mesh plan)")
    ap.add_argument("--max-kw", type=int, default=None,
                    help="sparse word-row width (padded-sparse backends; "
                         "default: auto, like --max-kd)")
    ap.add_argument("--delta-dtype", default="int32",
                    choices=["int32", "int16", "int8"])
    ap.add_argument("--exclusion-start", type=int, default=0)
    ap.add_argument("--rebuild-every", type=int, default=0,
                    help="exact count rebuild + padded-row re-resolution "
                         "cadence (0 = never)")
    ap.add_argument("--merge-every", type=int, default=0,
                    help="duplicate-topic merge cadence (0 = never)")
    ap.add_argument("--merge-threshold", type=float, default=0.05)
    ap.add_argument("--ckpt", default=None,
                    help="elastic training checkpoints (assignments)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="model checkpoints (N_wk/N_k + hyper) for serving")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="model-checkpoint cadence (0 = final only)")
    ap.add_argument("--llh-every", type=int, default=10,
                    help="eval cadence (llh/perplexity)")
    ap.add_argument("--target-perplexity", type=float, default=None,
                    help="stop once eval perplexity reaches this")
    # -- model quality + Alg. 5 hyper opt (repro.eval, DESIGN.md §9) ------
    ap.add_argument("--quality-every", type=int, default=0,
                    help="model-quality eval cadence: UMass/NPMI "
                         "coherence (+ left-to-right with --l2r-docs)")
    ap.add_argument("--quality-top-n", type=int, default=10,
                    help="top words per topic entering coherence")
    ap.add_argument("--npmi-window", type=int, default=10,
                    help="NPMI sliding-window size (0 = UMass only)")
    ap.add_argument("--l2r-docs", type=int, default=0,
                    help="held-out docs for left-to-right eval (0 = skip)")
    ap.add_argument("--l2r-particles", type=int, default=20,
                    help="particles per left-to-right document")
    ap.add_argument("--hyper-every", type=int, default=0,
                    help="Alg. 5 hyper-opt cadence: Minka fixed-point "
                         "alpha + beta annealing (0 = off)")
    ap.add_argument("--beta-anneal", type=float, default=1.0,
                    help="beta *= this per hyper firing (1.0 = no anneal)")
    ap.add_argument("--synthetic-docs", type=int, default=1000,
                    help="synthetic corpus size (when --corpus is not given)")
    ap.add_argument("--synthetic-words", type=int, default=2000)
    ap.add_argument("--synthetic-len", type=int, default=80)
    # -- observability + autopilot (DESIGN.md §8) -------------------------
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write per-iteration telemetry JSONL here")
    ap.add_argument("--autopilot", action="store_true",
                    help="re-pick backend + row capacities from measured "
                         "sparsity on the --rebuild-every cadence")
    ap.add_argument("--autopilot-every", type=int, default=0,
                    help="autopilot decision cadence "
                         "(0 = --rebuild-every, else every 10)")
    # -- streaming mode (DESIGN.md §7) -----------------------------------
    ap.add_argument("--stream", action="store_true",
                    help="windowed online training (StreamingSession); "
                         "--iters becomes the absolute window budget "
                         "(0 = run to source exhaustion)")
    ap.add_argument("--stream-source", default=None,
                    help="replay | libsvm:<path> | drift[:<seed>] "
                         "(default: replay of --corpus, else drift)")
    ap.add_argument("--window-docs", type=int, default=64,
                    help="documents per stream window")
    ap.add_argument("--window-sweeps", type=int, default=2,
                    help="CGS sweeps per window visit")
    ap.add_argument("--decay", type=float, default=0.0,
                    help="forgetting factor: counts *= (1-decay) per "
                         "window transition (0 = never forget)")
    ap.add_argument("--epochs", type=int, default=1,
                    help="replay source: passes over the corpus")
    ap.add_argument("--num-windows", type=int, default=8,
                    help="drift source: stream length in windows")
    return ap


def run_stream(args, cfg) -> None:
    """The ``--stream`` path: build a ``CorpusSource`` from the config's
    spec string and drive a ``StreamingSession`` over it. Pairs with
    ``launch/serve_lda.py --follow`` watching the same
    ``--checkpoint-dir`` for the live train→serve pipeline."""
    import jax

    from repro.core.types import LDAHyperParams
    from repro.data import load_libsvm, synthetic_corpus
    from repro.data.stream import make_source
    from repro.train.online import StreamingSession

    spec = cfg.stream_source or ("replay" if args.corpus else "drift")
    corpus = None
    if spec == "replay":
        corpus = (load_libsvm(args.corpus) if args.corpus
                  else synthetic_corpus(0, num_docs=args.synthetic_docs,
                                        num_words=args.synthetic_words,
                                        avg_doc_len=args.synthetic_len,
                                        zipf_a=1.2))
    source = make_source(
        spec, cfg.window_docs,
        corpus=corpus,
        # chunked sources cannot infer the global vocabulary — take it
        # from --synthetic-words (the stable-vocabulary contract)
        num_words=args.synthetic_words,
        epochs=args.epochs,
        num_windows=args.num_windows,
    )
    hyper = LDAHyperParams(num_topics=args.topics)
    session = StreamingSession(source, hyper, cfg)
    print(f"stream  source={spec}  window_docs={cfg.window_docs}  "
          f"sweeps/window={cfg.window_sweeps}  decay={cfg.decay}  "
          f"algorithm={cfg.algorithm}")

    def cb(sess, m):
        print(f"window {m['window']:4d} ({m['uid']})  docs {m['docs']:5d}  "
              f"ppl {m['perplexity']:.1f}  {m['docs_per_sec']:.0f} docs/s  "
              f"resident kd {m['resident_kd_bytes'] / 1024:.1f} KiB")

    session.run(jax.random.key(0), callback=cb)
    print(f"stream finished at window {session.windows_done}")
    if cfg.checkpoint_dir:
        print(f"model checkpoints: {cfg.checkpoint_dir} "
              f"(follow with: python -m repro.launch.serve_lda "
              f"--checkpoint-dir {cfg.checkpoint_dir} --follow)")


def main() -> None:
    args = build_parser().parse_args()

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices} "
            + os.environ.get("XLA_FLAGS", "")
        )

    import jax

    from repro import algorithms
    from repro.train.session import RunConfig, TrainSession

    if args.list_algorithms:
        for name, backend, aliases in algorithms.describe():
            mesh = "mesh+single-box" if backend.supports_shard_map \
                else "single-box"
            alias_s = f" (aliases: {', '.join(aliases)})" if aliases else ""
            print(f"{name:12s} {mesh}{alias_s}")
        return

    if args.config:
        with open(args.config) as f:
            cfg = RunConfig.from_json(f.read())
    else:
        backend = algorithms.get(args.algorithm)  # one registry resolution
        mesh_shape = None
        if backend.supports_shard_map and not args.single_box:
            mesh_shape = (args.rows, args.cols)
        elif not backend.supports_shard_map and not args.single_box:
            print(f"note: backend {args.algorithm!r} has no shard_map cell "
                  f"sweep; running the single-box plan")
        if args.stream and mesh_shape is not None:
            print("note: --stream runs the single-box windowed plan; "
                  "ignoring the mesh shape")
            mesh_shape = None
        if mesh_shape is None and args.delta_dtype != "int32":
            print("note: single-box plan ignores --delta-dtype")
        cfg = RunConfig(
            algorithm=args.algorithm,
            max_kd=args.max_kd or 0,  # 0 = auto-size from the counts
            max_kw=args.max_kw or 0,
            mesh_shape=mesh_shape,
            delta_dtype=args.delta_dtype,
            num_iterations=args.iters,
            eval_every=args.llh_every,
            target_perplexity=args.target_perplexity,
            exclusion_start=args.exclusion_start,
            rebuild_every=args.rebuild_every,
            merge_every=args.merge_every,
            merge_threshold=args.merge_threshold,
            checkpoint_dir=args.checkpoint_dir,
            checkpoint_every=args.checkpoint_every,
            train_checkpoint_dir=args.ckpt,
            train_checkpoint_every=(
                (1 if args.stream else 25) if args.ckpt else 0
            ),
            window_docs=args.window_docs if args.stream else 0,
            window_sweeps=args.window_sweeps,
            decay=args.decay if args.stream else 0.0,
            stream_source=(
                (args.stream_source
                 or ("replay" if args.corpus else "drift"))
                if args.stream else None
            ),
            metrics_out=args.metrics_out,
            autopilot=args.autopilot,
            autopilot_every=args.autopilot_every,
            quality_every=args.quality_every,
            quality_top_n=args.quality_top_n,
            quality_npmi_window=args.npmi_window,
            quality_l2r_docs=args.l2r_docs,
            quality_l2r_particles=args.l2r_particles,
            hyper_every=args.hyper_every,
            hyper_beta_anneal=args.beta_anneal,
        )

    if args.dump_config:
        with open(args.dump_config, "w") as f:
            f.write(cfg.to_json() + "\n")
        print(f"wrote {args.dump_config}")
        return

    if args.stream or cfg.stream_source:
        run_stream(args, cfg)
        return

    from repro.core.types import LDAHyperParams
    from repro.data import load_libsvm, synthetic_corpus

    if args.corpus:
        corpus = load_libsvm(args.corpus)
    else:
        corpus = synthetic_corpus(0, num_docs=args.synthetic_docs,
                                  num_words=args.synthetic_words,
                                  avg_doc_len=args.synthetic_len, zipf_a=1.2)
    hyper = LDAHyperParams(num_topics=args.topics)

    session = TrainSession(corpus, hyper, cfg)
    if cfg.mesh_shape is None:
        print(f"single-box  algorithm={cfg.algorithm}  "
              f"tokens={corpus.num_tokens}")
    else:
        grid = session.plan.grid
        rows, cols = cfg.mesh_shape
        print(f"mesh {rows}x{cols}  tokens={int(grid.mask.sum())}  "
              f"pad={grid.padding_overhead:.2%}")

    state = session.init(jax.random.key(0))
    if session.backend.needs_row_pads and cfg.mesh_shape is not None:
        kw, kd = session.row_pads
        print(f"padded-row widths: max_kw={kw} max_kd={kd}")

    def cb(st, metrics):
        if not metrics:
            return
        line = f"iter {int(st.iteration):4d}"
        if "llh" in metrics:
            line += (f"  llh {metrics['llh']:.1f}"
                     f"  ppl {metrics['perplexity']:.1f}"
                     f"  change {metrics['change_rate']:.3f}")
        if "coherence_umass" in metrics:
            line += f"  umass {metrics['coherence_umass']:.3f}"
        if "coherence_npmi" in metrics:
            line += f"  npmi {metrics['coherence_npmi']:.3f}"
        if "l2r_per_token" in metrics:
            line += f"  l2r/tok {metrics['l2r_per_token']:.3f}"
        if "hyper" in metrics:
            line += (f"  hyper a={metrics['hyper']['alpha']:.4f}"
                     f" b={metrics['hyper']['beta']:.4f}")
        if "row_pads" in metrics:
            kw, kd = metrics["row_pads"]
            line += f"  repad kw={kw} kd={kd}"
        for rec in metrics.get("autopilot", ()):
            line += (f"\n  autopilot {rec['decision']}"
                     f"{' applied' if rec['applied'] else ' (no-op)'}: "
                     f"{rec['reason']}")
        print(line)

    final = session.run(state=state, callback=cb)
    print(f"finished at iteration {int(final.iteration)}; "
          f"final llh {session.llh(final):.1f}")
    if cfg.autopilot:
        print(f"autopilot: final backend={session.plan.backend.name} "
              f"row_pads={session.row_pads}")
    if cfg.metrics_out:
        print(f"telemetry: {cfg.metrics_out}")
    if cfg.checkpoint_dir:
        print(f"model checkpoint: {cfg.checkpoint_dir} "
              f"(serve with: python -m repro.launch.serve_lda "
              f"--checkpoint-dir {cfg.checkpoint_dir})")


if __name__ == "__main__":
    main()
