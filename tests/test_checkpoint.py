"""Checkpointing: atomicity, checksums, torn-write recovery, elasticity."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.checkpoint import (
    CheckpointManager,
    restore_checkpoint,
    save_checkpoint,
)


def _tree():
    return {
        "params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)},
        "step": jnp.asarray(7),
    }


def test_roundtrip_and_metadata():
    with tempfile.TemporaryDirectory() as td:
        path = save_checkpoint(td, 7, _tree(), {"arch": "x"})
        restored, meta = restore_checkpoint(path, _tree())
        np.testing.assert_array_equal(
            np.asarray(restored["params"]["w"]),
            np.asarray(_tree()["params"]["w"]),
        )
        assert meta == {"arch": "x"}


def test_corruption_detected_and_skipped():
    with tempfile.TemporaryDirectory() as td:
        mgr = CheckpointManager(td)
        mgr.save(1, _tree())
        mgr.save(2, _tree())
        # corrupt the newest checkpoint's data
        newest = os.path.join(td, "step_00000002")
        leaf = os.path.join(newest, "leaf_00000.npy")
        with open(leaf, "r+b") as f:
            f.seek(-1, os.SEEK_END)
            f.write(b"\xff")
        got = mgr.restore_latest(_tree())
        assert got is not None
        _, _, step = got
        assert step == 1  # fell back past the corrupted one


def test_uncommitted_checkpoint_ignored():
    with tempfile.TemporaryDirectory() as td:
        mgr = CheckpointManager(td)
        mgr.save(1, _tree())
        # simulate a torn write: step dir without COMMITTED
        torn = os.path.join(td, "step_00000005")
        os.makedirs(torn)
        with open(os.path.join(torn, "manifest.json"), "w") as f:
            f.write("{}")
        got = mgr.restore_latest(_tree())
        assert got is not None and got[2] == 1


def test_gc_keeps_newest():
    with tempfile.TemporaryDirectory() as td:
        mgr = CheckpointManager(td, keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, _tree())
        steps = [s for s, _ in mgr._steps()]
        assert steps == [3, 4]


def test_step_ordering_numeric_across_digit_boundaries():
    """Steps resolve numerically, never lexicographically: 9 -> 10 and
    99 -> 100 survive un-padded dir names (where "step_100" < "step_99"
    as strings), shuffled creation order, and a stray non-numeric
    ``step_final`` dir that must be skipped, not crash the scan."""
    from repro.train.checkpoint import committed_steps

    with tempfile.TemporaryDirectory() as td:
        mgr = CheckpointManager(td, keep=100)
        for s in (100, 9, 99, 10):  # shuffled creation order
            mgr.save(s, _tree())
        # un-padded writers exist: strip the zero padding off the two
        # digit-boundary upper steps so lexicographic order inverts
        for s in (99, 100):
            os.rename(os.path.join(td, f"step_{s:08d}"),
                      os.path.join(td, f"step_{s}"))
        stray = os.path.join(td, "step_final")
        os.makedirs(stray)
        with open(os.path.join(stray, "COMMITTED"), "w") as f:
            f.write("ok")

        assert [s for s, _ in committed_steps(td)] == [9, 10, 99, 100]
        got = mgr.restore_latest(_tree())
        assert got is not None and got[2] == 100


def test_lda_elastic_restore_rebuilds_counts(key, tiny_corpus, tiny_hyper):
    """The LDA checkpoint is (assignments, rng); counts rebuild identically
    for ANY partitioning — the elastic-rescale path (DESIGN.md §3.2)."""
    from repro.core import counts as counts_lib
    from repro.core.init import random_init

    state = random_init(key, tiny_corpus, tiny_hyper)
    with tempfile.TemporaryDirectory() as td:
        save_checkpoint(td, 3, {"topic": state.topic},
                        {"iteration": 3})
        restored, meta = restore_checkpoint(
            os.path.join(td, "step_00000003"), {"topic": state.topic}
        )
    # "new cluster": counts rebuilt from assignments only
    n_wk, n_kd, n_k = counts_lib.build_counts(
        tiny_corpus.word, tiny_corpus.doc, restored["topic"],
        tiny_corpus.num_words, tiny_corpus.num_docs, tiny_hyper.num_topics,
    )
    np.testing.assert_array_equal(np.asarray(n_wk), np.asarray(state.n_wk))
    np.testing.assert_array_equal(np.asarray(n_kd), np.asarray(state.n_kd))
    np.testing.assert_array_equal(np.asarray(n_k), np.asarray(state.n_k))
