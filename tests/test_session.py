"""TrainSession / RunConfig / Schedule — the unified driver (DESIGN.md §6).

Covers the api_redesign contract:
* bit-equality of the single-box session against an inline re-derivation
  of the historical LDATrainer step (same seed, same backend, identical
  final N_wk / N_kd / z) and against the deprecated shim;
* schedule firing-order / cadence property tests;
* RunConfig JSON round-trip (and unknown-field rejection);
* target-perplexity termination from the eval tick's own llh — one
  likelihood evaluation per tick (counting spy), honored on every tick;
* duplicate-topic merging as a scheduled action (count conservation);
* mesh re-pad: a grown row is no longer truncated after the
  rebuild-cadence capacity re-resolution (subprocess, 2 CPU devices).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import given, run_with_devices, settings, st

from repro.core import counts as counts_lib
from repro.core import LDATrainer, TrainConfig
from repro.core.exclusion import ExclusionConfig, active_mask, update_exclusion_stats
from repro.core.types import CGSState
from repro.train.schedule import ActionContext, Schedule, ScheduledAction
from repro.train.session import RunConfig, TrainSession


# ---------------------------------------------------------------------------
# bit-equality with the legacy single-box path
# ---------------------------------------------------------------------------

def _legacy_step(trainer_cfg, corpus, hyper, backend, knobs, aux, state):
    """The historical LDATrainer.step, re-derived inline: this is the
    independent oracle the session's single-box plan must match bit-for-
    bit (same key schedule, same delta merge, same exclusion masking)."""
    from repro import algorithms

    key = jax.random.fold_in(state.rng, 2**20 + state.iteration)
    mask = active_mask(state, trainer_cfg.exclusion, key)
    k = knobs
    if backend.needs_row_pads:
        k = algorithms.resolve_row_pads(state, k)
    z_all = backend.sweep(state, corpus, hyper, k, aux)
    z_new = jnp.where(mask, z_all, state.topic)
    d_wk, d_kd, d_k = counts_lib.delta_counts(
        corpus.word, corpus.doc, state.topic, z_new,
        corpus.num_words, corpus.num_docs, hyper.num_topics,
    )
    i_new, t_new = update_exclusion_stats(state, z_new, mask)
    return CGSState(
        topic=z_new, prev_topic=state.topic,
        n_wk=state.n_wk + d_wk, n_kd=state.n_kd + d_kd,
        n_k=state.n_k + d_k, rng=state.rng,
        iteration=state.iteration + 1,
        stale_iters=i_new, same_count=t_new,
    )


@pytest.mark.parametrize("alg,excl_start", [
    ("zen", 0), ("zen_sparse", 0), ("zen_sparse", 3),
])
def test_single_box_session_bit_equal_legacy(
    key, tiny_corpus, tiny_hyper, alg, excl_start
):
    """Same seed, same backend: the session's run and an inline legacy
    step loop produce identical final N_wk / N_kd / z — including with
    the exclusion event enabled mid-run."""
    from repro import algorithms

    iters = 6
    tcfg = TrainConfig(
        algorithm=alg,
        exclusion=ExclusionConfig(enabled=excl_start > 0,
                                  start_iteration=excl_start),
    )
    session = TrainSession(
        tiny_corpus, tiny_hyper,
        RunConfig(algorithm=alg, num_iterations=iters,
                  exclusion_start=excl_start),
    )
    st_sess = session.init(key)

    backend = algorithms.get(alg)
    knobs = tcfg.knobs()
    aux = backend.prepare(tiny_corpus, tiny_hyper, knobs)
    st_ref = session.init(key)  # identical init (same rng, same cfg)

    st_sess = session.run(state=st_sess)
    for _ in range(iters):
        st_ref = _legacy_step(tcfg, tiny_corpus, tiny_hyper, backend,
                              knobs, aux, st_ref)

    np.testing.assert_array_equal(np.asarray(st_sess.topic),
                                  np.asarray(st_ref.topic))
    np.testing.assert_array_equal(np.asarray(st_sess.n_wk),
                                  np.asarray(st_ref.n_wk))
    np.testing.assert_array_equal(np.asarray(st_sess.n_kd),
                                  np.asarray(st_ref.n_kd))
    np.testing.assert_array_equal(np.asarray(st_sess.stale_iters),
                                  np.asarray(st_ref.stale_iters))

    # the deprecated shim rides the same plan: bit-identical too
    tr = LDATrainer(tiny_corpus, tiny_hyper, tcfg)
    st_shim = tr.train(key, iters)
    np.testing.assert_array_equal(np.asarray(st_shim.topic),
                                  np.asarray(st_ref.topic))
    np.testing.assert_array_equal(np.asarray(st_shim.n_wk),
                                  np.asarray(st_ref.n_wk))


# ---------------------------------------------------------------------------
# schedule cadence + firing order
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(0, 7), st.integers(0, 7), st.integers(1, 9),
       st.integers(1, 30))
def test_schedule_cadence_property(every_a, every_b, at_c, num_iters):
    """Firing is a pure function of (every, start, at): simulate
    num_iters iterations and check the event log against the closed
    form, with order == registration order within each iteration."""
    sched = Schedule()
    sched.add(ScheduledAction("a", lambda ctx, s: s, every=every_a))
    sched.add(ScheduledAction("b", lambda ctx, s: s + 1 if every_b else s,
                              every=every_b, start=3))
    sched.add(ScheduledAction("c", lambda ctx, s: s, at=at_c))
    ctx = ActionContext()
    state = 0
    for it in range(1, num_iters + 1):
        state = sched.fire(ctx, state, it)
    expected = []
    for it in range(1, num_iters + 1):
        if every_a and it % every_a == 0:
            expected.append((it, "a"))
        if every_b and it >= 3 and it % every_b == 0:
            expected.append((it, "b"))
        if it == at_c:
            expected.append((it, "c"))
    assert ctx.fired == expected
    # state threading: every "b" firing incremented the state
    assert state == sum(1 for _, n in expected if n == "b")


def test_schedule_rejects_duplicates_and_bad_actions():
    sched = Schedule()
    sched.add(ScheduledAction("x", lambda ctx, s: s, every=2))
    with pytest.raises(ValueError, match="duplicate"):
        sched.add(ScheduledAction("x", lambda ctx, s: s, every=3))
    with pytest.raises(ValueError, match="exclusive"):
        ScheduledAction("y", lambda ctx, s: s, every=2, at=5)
    with pytest.raises(KeyError):
        sched.replace(ScheduledAction("missing", lambda ctx, s: s, every=1))
    with pytest.raises(KeyError):
        sched.remove("missing")


def test_schedule_replace_preserves_position_remove_drops():
    sched = Schedule()
    sched.add(ScheduledAction("a", lambda ctx, s: s, every=1))
    sched.add(ScheduledAction("b", lambda ctx, s: s, every=2))
    sched.add(ScheduledAction("c", lambda ctx, s: s, every=1))
    sched.replace(ScheduledAction("b", lambda ctx, s: s, every=1))
    assert sched.names() == ("a", "b", "c")  # position (= firing order) kept
    assert sched.due(1) == ("a", "b", "c")   # the new cadence is live
    sched.remove("b")
    assert sched.names() == ("a", "c")


def test_runtime_registered_action_and_midrun_cadence_change(
        key, tiny_corpus, tiny_hyper):
    """Satellite contract: actions registered AFTER session init fire on
    their cadence, and a mid-run ``Schedule.replace`` retimes one
    without disturbing the rest of the run (the autopilot's actuation
    path depends on exactly this)."""
    session = TrainSession(
        tiny_corpus, tiny_hyper,
        RunConfig(algorithm="zen", num_iterations=6),
    )
    state = session.init(key)
    hits = []
    session.schedule.add(ScheduledAction(
        "probe", lambda ctx, s: (hits.append(int(s.iteration)), s)[1],
        every=2,
    ))
    assert session.schedule.names() == ("probe",)

    retimed = []

    def on_iter(st, metrics):
        # after iteration 3, tighten the probe cadence to every iteration
        if int(st.iteration) == 3 and not retimed:
            retimed.append(True)
            session.schedule.replace(ScheduledAction(
                "probe",
                lambda ctx, s: (hits.append(int(s.iteration)), s)[1],
                every=1,
            ))

    session.run(state=state, callback=on_iter)
    # every=2 through iteration 3 (fires at 2), then every=1 from 4 on.
    # actions see post-step state, so s.iteration is the firing tick.
    assert hits == [2, 4, 5, 6]


def test_session_schedule_registration_order(tmp_path, tiny_corpus,
                                             tiny_hyper):
    """Structural events precede observational ones, so an eval on the
    same iteration sees post-rebuild/post-merge counts."""
    cfg = RunConfig(algorithm="zen", num_iterations=4, eval_every=2,
                    rebuild_every=2, merge_every=2, exclusion_start=3,
                    checkpoint_dir=str(tmp_path / "m"), checkpoint_every=2,
                    train_checkpoint_dir=str(tmp_path / "t"),
                    train_checkpoint_every=2)
    session = TrainSession(tiny_corpus, tiny_hyper, cfg)
    names = session.schedule.names()
    assert names == ("exclusion_on", "rebuild", "merge", "eval",
                     "model_checkpoint", "train_checkpoint")
    # the plan-default sampling method resolved at construction
    assert session.cfg.sampling_method == "cdf"
    # zen is dense: no repad action; a padded-sparse backend gets one
    sparse = TrainSession(
        tiny_corpus, tiny_hyper,
        RunConfig(algorithm="zen_sparse", num_iterations=4, rebuild_every=2),
    )
    assert sparse.schedule.names() == ("rebuild", "repad")
    assert session.schedule.due(2) == ("rebuild", "merge", "eval",
                                       "model_checkpoint",
                                       "train_checkpoint")
    assert session.schedule.due(3) == ("exclusion_on",)


# ---------------------------------------------------------------------------
# RunConfig JSON round-trip
# ---------------------------------------------------------------------------

def test_runconfig_json_roundtrip():
    cfg = RunConfig(
        algorithm="lightlda", sampling_method="gumbel", max_kw=48,
        max_kd=24, num_mh=4, token_chunk=256, mesh_shape=(2, 3),
        delta_dtype="int16", kd_dtype="int16", num_iterations=77,
        eval_every=5, target_perplexity=123.5, exclusion_start=30,
        rebuild_every=10, merge_every=20, merge_threshold=0.1,
        checkpoint_dir="/tmp/m", checkpoint_every=25,
        train_checkpoint_dir="/tmp/t", train_checkpoint_every=50,
        window_docs=128, window_sweeps=3, decay=0.05,
        stream_source="libsvm:/tmp/c.libsvm",
        metrics_out="/tmp/train.jsonl", metrics_every=2,
        autopilot=True, autopilot_every=4,
    )
    assert RunConfig.from_json(cfg.to_json()) == cfg
    # mesh_shape survives as a tuple, default None survives as None
    assert RunConfig.from_json(RunConfig().to_json()) == RunConfig()
    with pytest.raises(ValueError, match="unknown RunConfig fields"):
        RunConfig.from_json('{"algorithm": "zen", "definitely_not": 1}')


# ---------------------------------------------------------------------------
# target perplexity from the eval tick (no second likelihood pass)
# ---------------------------------------------------------------------------

def test_target_perplexity_single_eval_per_tick(
    monkeypatch, key, tiny_corpus, tiny_hyper
):
    import repro.train.session as session_mod

    calls = {"n": 0}
    real = session_mod.predictive_llh

    def spy(*args, **kwargs):
        calls["n"] += 1
        return real(*args, **kwargs)

    monkeypatch.setattr(session_mod, "predictive_llh", spy)

    # no target: exactly one likelihood evaluation per eval tick
    session = TrainSession(
        tiny_corpus, tiny_hyper,
        RunConfig(algorithm="zen", num_iterations=6, eval_every=2),
    )
    session.run(key)
    assert calls["n"] == 3

    # an immediately-satisfied target stops at the FIRST eval tick and
    # still pays only that tick's single evaluation
    calls["n"] = 0
    session = TrainSession(
        tiny_corpus, tiny_hyper,
        RunConfig(algorithm="zen", num_iterations=50, eval_every=1,
                  target_perplexity=1e9),
    )
    final = session.run(key)
    assert int(final.iteration) == 1
    assert calls["n"] == 1

    # unreachable target: every tick checks (runs to num_iterations)
    calls["n"] = 0
    session = TrainSession(
        tiny_corpus, tiny_hyper,
        RunConfig(algorithm="zen", num_iterations=4, eval_every=1,
                  target_perplexity=1e-9),
    )
    final = session.run(key)
    assert int(final.iteration) == 4
    assert calls["n"] == 4

    # the deprecated shim inherits the fix
    calls["n"] = 0
    tr = LDATrainer(tiny_corpus, tiny_hyper, TrainConfig(algorithm="zen"))
    final = tr.train(key, 50, llh_every=1, target_perplexity=1e9)
    assert int(final.iteration) == 1
    assert calls["n"] == 1


# ---------------------------------------------------------------------------
# duplicate-topic merge as a scheduled action
# ---------------------------------------------------------------------------

def test_merge_action_merges_duplicates_and_conserves_counts(tiny_corpus,
                                                             tiny_hyper):
    """Seed the sampler state with pairwise-duplicate topics (0<->1 and
    2<->3 carry identical word distributions); the merge action collapses
    each pair without breaking count conservation."""
    k = 4
    hyper = dataclasses.replace(tiny_hyper, num_topics=k)
    cfg = RunConfig(algorithm="zen", num_iterations=1, merge_every=1,
                    merge_threshold=0.2)
    session = TrainSession(tiny_corpus, hyper, cfg)

    # duplicated init: the "true" topic of token t is (word_t % 2); the
    # duplicate label splits each true topic over two ids by alternating
    # within every word's own token list, so columns 2j and 2j+1 carry
    # near-identical word distributions (each word's count splits in half)
    w = np.asarray(tiny_corpus.word)
    true = w % 2
    occ = np.zeros_like(w)
    seen: dict = {}
    for idx in np.argsort(w, kind="stable"):
        occ[idx] = seen.get(w[idx], 0)
        seen[w[idx]] = occ[idx] + 1
    dup = true * 2 + (occ % 2)
    state = session.init(jax.random.key(0), init_topics=dup.astype(np.int32))

    from repro.core.hyper import duplicate_topic_map

    tm = duplicate_topic_map(np.asarray(state.n_wk), cfg.merge_threshold)
    assert tm[1] == 0 and tm[3] == 2, tm  # the pairs ARE duplicates

    merged = session.merge_duplicates(state)
    merged.check_invariants(tiny_corpus)
    n_k = np.asarray(merged.n_k)
    assert n_k[1] == 0 and n_k[3] == 0  # merged-away columns emptied
    assert n_k.sum() == tiny_corpus.num_tokens
    z = np.asarray(merged.topic)
    assert set(np.unique(z)) <= {0, 2}

    # end-to-end: one scheduled iteration fires the merge action
    ctx_names = []
    final = session.run(
        state=session.init(jax.random.key(0),
                           init_topics=dup.astype(np.int32)),
        callback=lambda s, m: ctx_names.append(int(s.iteration)),
    )
    final.check_invariants(tiny_corpus)


# ---------------------------------------------------------------------------
# elastic training checkpoints through the session surface
# ---------------------------------------------------------------------------

def test_session_train_checkpoint_resume(tmp_path, key, tiny_corpus,
                                         tiny_hyper):
    """A second session with the same train_checkpoint_dir resumes from
    the saved assignments (counts rebuild exactly) and finishes the
    remaining iterations."""
    cfg = RunConfig(algorithm="zen", num_iterations=4,
                    train_checkpoint_dir=str(tmp_path),
                    train_checkpoint_every=2)
    s1 = TrainSession(tiny_corpus, tiny_hyper, cfg)
    mid = s1.run(key)
    assert int(mid.iteration) == 4

    cfg2 = dataclasses.replace(cfg, num_iterations=6)
    s2 = TrainSession(tiny_corpus, tiny_hyper, cfg2)
    final = s2.run(key)
    assert int(final.iteration) == 6
    final.check_invariants(tiny_corpus)
    # the restored counts matched the saved assignments exactly
    n_wk, n_kd, n_k = counts_lib.build_counts(
        tiny_corpus.word, tiny_corpus.doc, final.topic,
        tiny_corpus.num_words, tiny_corpus.num_docs, tiny_hyper.num_topics,
    )
    np.testing.assert_array_equal(np.asarray(final.n_wk), np.asarray(n_wk))


# ---------------------------------------------------------------------------
# mesh re-pad: grown rows stop being truncated (2 CPU devices)
# ---------------------------------------------------------------------------

def test_mesh_repad_unfreezes_grown_rows():
    run_with_devices("""
import warnings; warnings.filterwarnings('ignore')
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.data import synthetic_lda_corpus
from repro.core.types import LDAHyperParams
from repro.core.zen_sparse import shard_row_capacity
from repro.train.session import RunConfig, TrainSession

corpus, _ = synthetic_lda_corpus(0, num_docs=80, num_words=60,
                                 num_topics=8, avg_doc_len=40)
# symmetric, exploration-heavy priors: the asymmetric prior would keep
# reinforcing the degenerate init instead of letting rows grow
hyper = LDAHyperParams(num_topics=64, alpha=2.0, beta=0.5,
                       asymmetric_alpha=False)

def run(rebuild_every):
    cfg = RunConfig(algorithm='zen_sparse', mesh_shape=(1, 2),
                    num_iterations=8, rebuild_every=rebuild_every)
    session = TrainSession(corpus, hyper, cfg)
    assert session.cfg.sampling_method == 'gumbel'  # mesh plan default
    # degenerate init: every token on topic 0 -> row capacities freeze at
    # the lane minimum even though K=64 leaves lots of room to grow
    init = np.zeros(session.plan.grid.word.shape, np.int32)
    state = session.init(jax.random.key(0), init_topics=init)
    pads0 = session.row_pads
    state = session.run(state=state)
    return session, state, pads0

# frozen capacities: the init widths never move, and by the end the real
# row occupancy has outgrown them -> the sparse tables were truncating
frozen, st_f, pads0_f = run(rebuild_every=0)
assert frozen.row_pads == pads0_f
need_kw = shard_row_capacity(st_f.n_wk)
need_kd = shard_row_capacity(st_f.n_kd)
assert need_kw > pads0_f[0] or need_kd > pads0_f[1], (
    pads0_f, need_kw, need_kd)

# with the rebuild-cadence repad the capacities were re-resolved upward:
# the step's padded widths now cover every live row (no truncation)
repad, st_r, pads0_r = run(rebuild_every=2)
assert pads0_r == pads0_f
kw, kd = repad.row_pads
assert (kw, kd) != pads0_r, (kw, kd)
# the final repad resolved against the final (rebuilt) counts, so the
# step's padded widths cover every live row — no truncation remains
assert kw >= shard_row_capacity(st_r.n_wk), (kw,)
assert kd >= shard_row_capacity(st_r.n_kd), (kd,)
# and nothing was corrupted along the way
E = repad.plan.num_tokens
assert int(jnp.sum(st_r.n_k)) == E
np.testing.assert_array_equal(np.asarray(jnp.sum(st_r.n_wk, 0)),
                              np.asarray(st_r.n_k))
print('REPAD OK', pads0_r, '->', (kw, kd))
""", n_devices=2, timeout=900)
