"""Autopilot recovery benchmark (DESIGN.md §8): does measure→decide→act
close the gap a mis-configured run leaves on the table?

Two probes, each comparing three runs of the same workload:

* **Training** — ``zen_sparse`` on a hot-vocabulary corpus (small
  vocab, Zipf ``a < 1``), where doc rows stay short. Hand-tuned uses
  auto row pads (re-resolved per sweep); the mis-configured run pins
  explicit ``max_kw = max_kd = K`` — every doc row padded to the full
  topic count, ~4x the work the counts justify; the third run starts
  mis-configured with ``autopilot=True`` and must shrink the capacity
  via a ``RowRepad`` decision from the measured row-nnz stats. Metric:
  steady-state docs/sec (median per-iteration wall time over the last
  half of the run). The cost model keeps the backend at ``zen_sparse``
  here (doc-side is right for this shape), so the probe isolates the
  capacity decision; the backend-switch decision itself is pinned by
  ``tests/test_autopilot.py``.
* **Serving** — an open-loop paced load against ``mode="latency"`` with
  the admission ticker mis-set to 25x the arrival spacing, vs the
  hand-tuned period, vs mis-set plus ``autopilot=True`` deriving
  ``tick_period`` from observed inter-arrivals. Metric: p99 of
  submit-to-done over the last half of the requests (after the
  autopilot's first window has fired).

Both probes report ``recovered``: the fraction of the mis→tuned gap the
autopilot run closed (≥ 0.5 is the acceptance bar). Results also land in
``BENCH_autopilot.json`` under the shared output dir.

Scale knobs (env, for CI-sized runs): BENCH_AUTO_ITERS (train
iterations), BENCH_AUTO_DOCS (serve requests), BENCH_AUTO_PACE
(serve inter-arrival seconds).

    PYTHONPATH=src:. python benchmarks/run.py --only autopilot
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import bench_out_path, row

ITERS = int(os.environ.get("BENCH_AUTO_ITERS", 12))
SERVE_DOCS = int(os.environ.get("BENCH_AUTO_DOCS", 120))
PACE = float(os.environ.get("BENCH_AUTO_PACE", 0.002))
NUM_TOPICS = 128


def _hot_vocab_corpus():
    """Small vocab under Zipf a=0.8: every word is hot, so word rows
    touch many topics while doc rows stay short (mean K_d « K) — the
    regime where doc-side decomposition is right and a full-K doc-row
    pad is maximally wasteful."""
    from repro.data import synthetic_corpus

    return synthetic_corpus(0, num_docs=800, num_words=64,
                            avg_doc_len=32, zipf_a=0.8)


def _train_run(corpus, **cfg_kw):
    """One training run; returns (steady docs/sec, final backend name)."""
    import jax

    from repro.core.types import LDAHyperParams
    from repro.train import RunConfig, TrainSession

    cfg = RunConfig(num_iterations=ITERS, eval_every=0, **cfg_kw)
    session = TrainSession(
        corpus, LDAHyperParams(num_topics=NUM_TOPICS), cfg
    )
    stamps = [time.perf_counter()]
    session.run(rng=jax.random.PRNGKey(0),
                callback=lambda st, m: stamps.append(time.perf_counter()))
    dts = np.diff(stamps)[len(stamps) // 2:]  # steady-state half
    docs_per_sec = corpus.num_docs / float(np.median(dts))
    return docs_per_sec, session.plan.row_pads


def _train_probe(records):
    K = NUM_TOPICS
    corpus = _hot_vocab_corpus()
    tuned, _ = _train_run(corpus, algorithm="zen_sparse")
    mis, _ = _train_run(corpus, algorithm="zen_sparse",
                        max_kw=K, max_kd=K)
    auto, pads = _train_run(corpus, algorithm="zen_sparse",
                            max_kw=K, max_kd=K,
                            autopilot=True, autopilot_every=2)
    gap = tuned - mis
    recovered = (auto - mis) / gap if gap > 0 else float("nan")
    row("autopilot_train_tuned", 1e6 / tuned,
        f"{tuned:.1f} docs/s auto pads")
    row("autopilot_train_mis", 1e6 / mis,
        f"{mis:.1f} docs/s pads=({K},{K})")
    row("autopilot_train_auto", 1e6 / auto,
        f"{auto:.1f} docs/s settled pads={pads} "
        f"recovered={recovered:.2f}")
    records.append({
        "name": "train", "tuned_docs_per_sec": tuned,
        "mis_docs_per_sec": mis, "auto_docs_per_sec": auto,
        "settled_pads": list(pads), "recovered": recovered,
    })


def _frozen_model():
    import jax.numpy as jnp

    from repro.core.types import LDAHyperParams
    from repro.serving import FrozenLDAModel

    rng = np.random.default_rng(0)
    n_wk = rng.poisson(2.0, size=(400, NUM_TOPICS)).astype(np.int32)
    return FrozenLDAModel(
        n_wk=jnp.asarray(n_wk),
        n_k=jnp.asarray(n_wk.sum(0).astype(np.int32)),
        hyper=LDAHyperParams(num_topics=NUM_TOPICS),
    )


def _serve_run(model, docs, tick_period, autopilot):
    """Open-loop paced load through the background ticker; returns the
    p99 submit-to-done ms over the last half of the requests."""
    from repro.observe import summarize_latencies
    from repro.serving import LDAEngine, LDAServeConfig

    cfg = LDAServeConfig(
        buckets=(32, 64), max_batch=8, mode="latency", rtlda_sweeps=2,
        tick_period=tick_period, autopilot=autopilot,
        autopilot_window=16,
    )
    engine = LDAEngine(model, cfg, seed=0)
    engine.warm()
    engine.start()
    try:
        tickets = []
        for d in docs:
            tickets.append(engine.submit_async(d))
            time.sleep(PACE)
        reqs = [engine.request(t) for t in tickets]
        for t in tickets:
            engine.result(t)
    finally:
        engine.stop()
    tail = reqs[len(reqs) // 2:]
    stats = summarize_latencies(
        (r.t_done - r.t_submit) * 1e3 for r in tail
    )
    return stats["p99"], engine.tick_period


def _serve_probe(records):
    model = _frozen_model()
    rng = np.random.default_rng(1)
    docs = [rng.integers(0, 400, size=int(ln)).astype(np.int32)
            for ln in np.clip(rng.poisson(24, size=SERVE_DOCS), 4, 60)]
    mis_period = PACE * 25  # ticker 25x slower than arrivals
    tuned_p99, _ = _serve_run(model, docs, PACE, autopilot=False)
    mis_p99, _ = _serve_run(model, docs, mis_period, autopilot=False)
    auto_p99, settled = _serve_run(model, docs, mis_period, autopilot=True)
    gap = mis_p99 - tuned_p99
    recovered = (mis_p99 - auto_p99) / gap if gap > 0 else float("nan")
    row("autopilot_serve_tuned", tuned_p99 * 1e3,
        f"p99 {tuned_p99:.2f} ms tick={PACE * 1e3:.1f}ms")
    row("autopilot_serve_mis", mis_p99 * 1e3,
        f"p99 {mis_p99:.2f} ms tick={mis_period * 1e3:.1f}ms")
    row("autopilot_serve_auto", auto_p99 * 1e3,
        f"p99 {auto_p99:.2f} ms settled tick={settled * 1e3:.2f}ms "
        f"recovered={recovered:.2f}")
    records.append({
        "name": "serve", "tuned_p99_ms": tuned_p99, "mis_p99_ms": mis_p99,
        "auto_p99_ms": auto_p99, "settled_tick_period": settled,
        "recovered": recovered,
    })


def main() -> None:
    records = []
    _train_probe(records)
    _serve_probe(records)
    with open(bench_out_path("BENCH_autopilot.json"), "w") as f:
        json.dump(records, f, indent=2)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
