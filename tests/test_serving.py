"""Serving engine: the batched greedy decode implements greedy decode.

The old reference re-decoded the sequence separately (eagerly, batch 1)
and compared tokens. That comparison was *never* deterministic on this
container: XLA CPU float reductions vary run-to-run (measured logit
deltas > 1.0 on the smoke model), so the reference chain and the engine
chain could diverge at any near-tie — the long-standing flake. What the
test actually needs to pin down is the engine's **bookkeeping**: prompt
tokens are fed to the decode step in order, each emitted token is the
argmax of the logits the engine itself computed for that slot, and
emitted tokens are fed back in. We assert exactly that, by spying on the
engine's decode calls, plus a cache-correctness check: replaying the
engine's exact fed-token sequence through the engine's own jitted
executable with a fresh cache must reproduce the logits (measured
bit-exact across 24 trials under 3-way CPU oversubscription — same
executable + same inputs is the stable configuration; two independently
chosen chains is not).
"""
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import init_cache, init_params
from repro.serving import ServeConfig, ServingEngine


def _setup(key):
    cfg = dataclasses.replace(get_config("qwen3-8b-smoke"), dtype="float32",
                              num_layers=2)
    params = init_params(key, cfg)
    return cfg, params


def test_engine_implements_greedy_decode(key):
    cfg, params = _setup(key)
    engine = ServingEngine(params, cfg, ServeConfig(max_batch=2, max_len=32))
    decode = engine._decode
    calls = []  # (tokens fed, logits produced) per decode call

    def spy(p, t, c):
        logits, caches = decode(p, t, c)
        calls.append((np.asarray(t).copy(), np.asarray(logits, np.float32)))
        return logits, caches

    engine._decode = spy
    prompt = [5, 9, 11]
    engine.submit(prompt, max_new=4)
    done = engine.run_until_done()
    assert len(done) == 1 and len(done[0].out) == 4

    # prefill + decode feed exactly the prompt then the emitted tokens
    fed = [int(t[0]) for t, _ in calls]
    assert fed == prompt + done[0].out[:-1]
    # every emitted token is the argmax of the engine's own slot-0 logits
    # at that step (the 2 prefill calls' logits are unused)
    for i, tok in enumerate(done[0].out):
        _, logits = calls[len(prompt) - 1 + i]
        assert tok == int(np.argmax(logits[0])), (i, tok)
    # cache correctness: replaying the same fed tokens through the same
    # executable from a fresh cache reproduces the engine's logits — a
    # slot-swap or off-by-one position bug in the packed cache would
    # diverge here
    cache = init_cache(cfg, 2, 32)
    for fed, eng_logits in calls:
        logits, cache = decode(params, jnp.asarray(fed), cache)
        np.testing.assert_allclose(
            np.asarray(logits, np.float32), eng_logits, atol=1e-5
        )


def test_engine_batches_multiple_requests(key):
    cfg, params = _setup(key)
    engine = ServingEngine(params, cfg, ServeConfig(max_batch=4, max_len=32))
    uids = [engine.submit([3, 1 + i], max_new=3) for i in range(4)]
    done = engine.run_until_done()
    assert sorted(r.uid for r in done) == sorted(uids)
    assert all(len(r.out) == 3 for r in done)
    # different prompts should (generically) produce different outputs
    assert len({tuple(r.out) for r in done}) > 1
