"""Alias tables (Walker/Vose) — the paper's O(1) samplers for gDense/wSparse.

Two constructions are provided:

* ``build_alias``        — jittable, fixed-iteration stack-based Vose in JAX.
  Used inside the distributed sampler (tables are rebuilt once per iteration,
  paper Alg. 2 lines 5-8 / 9-13).
* ``build_alias_counts`` — host-side (numpy) *integer-exact* construction for
  integer count vectors, implementing the paper's §5.3 refinement: scale every
  probability by K so the average and the split probabilities stay integral,
  avoiding the divide and float drift.  Only the H ("high") worklist is kept;
  low items are placed into bins sequentially, exactly as described.

TPU adaptation note (DESIGN.md §2): alias *sampling* is two random gathers,
which the TPU dislikes; the production dense path therefore uses the fused
Gumbel-max Pallas kernel instead. Alias tables remain the faithful path and
win for very large K where an O(K) dense pass is wasteful.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class AliasTable(NamedTuple):
    prob: jax.Array  # (K,) float32 — threshold for keeping bin index
    alias: jax.Array  # (K,) int32 — alternative outcome of each bin


def build_alias(p: jax.Array) -> AliasTable:
    """Jittable Vose alias construction. ``p`` is an unnormalized pmf (K,)."""
    k = p.shape[0]
    p = p.astype(jnp.float32)
    total = jnp.sum(p)
    # Degenerate all-zero pmf -> uniform.
    q = jnp.where(total > 0, p * (k / jnp.maximum(total, 1e-30)), 1.0)

    idx = jnp.arange(k, dtype=jnp.int32)
    small0 = q < 1.0
    # Stable partition of indices into the two stacks.
    order_small = jnp.argsort(jnp.where(small0, idx, k)).astype(jnp.int32)
    order_large = jnp.argsort(jnp.where(~small0, idx, k)).astype(jnp.int32)
    n_small = jnp.sum(small0).astype(jnp.int32)
    n_large = (k - n_small).astype(jnp.int32)

    # Stacks are preallocated to 2K: every large can be demoted to small once.
    pad = jnp.zeros((k,), jnp.int32)
    small_stack = jnp.concatenate([order_small, pad])
    large_stack = jnp.concatenate([order_large, pad])

    prob = jnp.ones((k,), jnp.float32)
    alias = idx

    def body(_, carry):
        q, prob, alias, ss, st, ls, lt = carry
        can = (st > 0) & (lt > 0)
        s = ss[jnp.maximum(st - 1, 0)]
        l = ls[jnp.maximum(lt - 1, 0)]
        new_prob = jnp.where(can, q[s], prob[s])
        new_alias = jnp.where(can, l, alias[s])
        prob = prob.at[s].set(new_prob)
        alias = alias.at[s].set(new_alias)
        ql = q[l] - (1.0 - q[s])
        q = q.at[l].set(jnp.where(can, ql, q[l]))
        l_small = ql < 1.0
        # pop s; if the updated l became small it replaces s on the small
        # stack, otherwise it simply stays on top of the large stack.
        ss = ss.at[jnp.maximum(st - 1, 0)].set(
            jnp.where(can & l_small, l, ss[jnp.maximum(st - 1, 0)])
        )
        st = jnp.where(can, jnp.where(l_small, st, st - 1), st)
        lt = jnp.where(can, jnp.where(l_small, lt - 1, lt), lt)
        return q, prob, alias, ss, st, ls, lt

    carry = (q, prob, alias, small_stack, n_small, large_stack, n_large)
    carry = jax.lax.fori_loop(0, 2 * k, body, carry)
    _, prob, alias, _, _, _, _ = carry
    return AliasTable(prob=prob, alias=alias)


def sample_alias(table: AliasTable, u_bin: jax.Array, u_split: jax.Array) -> jax.Array:
    """O(1) alias sampling: pick a bin with u_bin, resolve split with u_split.

    ``u_bin``/``u_split`` are uniforms in [0,1) of any (matching) shape.
    The paper's random-number-reuse trick (§5.3 "Others") — using one uniform
    for both the bin index and the split — is available via
    ``sample_alias_reuse``.
    """
    k = table.prob.shape[0]
    bins = jnp.minimum((u_bin * k).astype(jnp.int32), k - 1)
    keep = u_split < table.prob[bins]
    return jnp.where(keep, bins, table.alias[bins])


def sample_alias_reuse(table: AliasTable, u: jax.Array) -> jax.Array:
    """Alias sampling reusing one uniform: fractional part resolves the split."""
    k = table.prob.shape[0]
    scaled = u * k
    bins = jnp.minimum(scaled.astype(jnp.int32), k - 1)
    frac = scaled - bins.astype(scaled.dtype)
    keep = frac < table.prob[bins]
    return jnp.where(keep, bins, table.alias[bins])


def alias_pmf(table: AliasTable) -> jax.Array:
    """Exact pmf realized by the table (for property tests)."""
    k = table.prob.shape[0]
    direct = table.prob / k
    spill = jnp.zeros((k,)).at[table.alias].add((1.0 - table.prob) / k)
    return direct + spill


def build_alias_counts(counts: np.ndarray) -> Tuple[np.ndarray, np.ndarray, int]:
    """Host-side integer-exact alias build for integer count vectors (§5.3).

    Implements the paper's refinement: scale every count by K so that the
    average bin mass equals ``total`` and all split thresholds stay integral
    (no divides, no float drift); maintain only the H (above-average)
    worklist and place low bins sequentially.

    Bin i keeps itself when ``u_int < prob_num[i]`` with ``u_int`` uniform
    over [0, total). Returns (prob_num int64 (K,), alias int32 (K,), total).
    """
    counts = np.asarray(counts, dtype=np.int64)
    k = counts.shape[0]
    total = int(counts.sum())
    if total == 0:
        return np.full(k, 1, np.int64), np.arange(k, dtype=np.int32), 1
    q = counts * k  # scaled masses; average bin mass == total (integer)
    prob_num = np.full(k, total, dtype=np.int64)
    alias = np.arange(k, dtype=np.int32)
    high = [i for i in range(k) if q[i] > total]  # the only worklist kept
    low = [i for i in range(k) if q[i] < total]  # consumed sequentially
    while low and high:
        s = low.pop()
        l = high[-1]
        prob_num[s] = q[s]
        alias[s] = l
        q[l] -= total - q[s]
        if q[l] <= total:
            high.pop()
            if q[l] < total:
                low.append(l)
    # Integer arithmetic is exact: anything left has mass exactly ``total``.
    return prob_num, alias, total


def sample_alias_counts(
    prob_num: np.ndarray, alias: np.ndarray, total: int, rng: np.random.Generator, n: int
) -> np.ndarray:
    """Host-side sampling from an integer alias table."""
    k = prob_num.shape[0]
    bins = rng.integers(0, k, size=n)
    u = rng.integers(0, total, size=n)
    return np.where(u < prob_num[bins], bins, alias[bins]).astype(np.int32)


class FPlusTree(NamedTuple):
    """F+ tree (complete binary tree over topic masses) — Table 1's sampler
    for terms that change per sample (ZenLDAHybrid's N_kd*beta term).

    ``tree``: (2 * cap,) float32 where cap = next_pow2(K); leaves at
    [cap, cap+K). Build O(K), update O(log K), sample O(log K).
    """

    tree: jax.Array
    k: int


def ftree_build(p: jax.Array) -> FPlusTree:
    k = p.shape[0]
    cap = 1 << max(1, (k - 1).bit_length())
    leaves = jnp.zeros((cap,), jnp.float32).at[:k].set(p.astype(jnp.float32))
    tree = jnp.zeros((2 * cap,), jnp.float32).at[cap:].set(leaves)

    def up(level_size, tree):
        i = jnp.arange(level_size) + level_size
        return tree.at[i].set(tree[2 * i] + tree[2 * i + 1])

    size = cap // 2
    while size >= 1:
        tree = up(size, tree)
        size //= 2
    return FPlusTree(tree=tree, k=k)


def ftree_total(t: FPlusTree) -> jax.Array:
    return t.tree[1]


def ftree_sample(t: FPlusTree, u: jax.Array) -> jax.Array:
    """Descend the tree with target mass u * total. Vectorized over u."""
    cap = t.tree.shape[0] // 2
    target = u * t.tree[1]

    def body(carry, _):
        node, target = carry
        left = t.tree[2 * node]
        go_right = target >= left
        node = 2 * node + go_right.astype(node.dtype)
        target = jnp.where(go_right, target - left, target)
        return (node, target), None

    node0 = jnp.ones_like(u, dtype=jnp.int32)
    depth = int(np.log2(cap))  # root (node 1) -> leaf level
    (node, _), _ = jax.lax.scan(body, (node0, target), None, length=depth)
    return jnp.minimum(node - cap, t.k - 1).astype(jnp.int32)


def ftree_update(t: FPlusTree, index: jax.Array, new_value: jax.Array) -> FPlusTree:
    """Set leaf ``index`` to ``new_value`` and fix ancestors (O(log K)).

    ``index`` may be traced; the ancestor walk has fixed depth log2(cap)+1.
    """
    cap = t.tree.shape[0] // 2
    leaf = index + cap
    delta = new_value - t.tree[leaf]
    tree = t.tree
    node = leaf
    depth = int(np.log2(cap)) + 1
    for _ in range(depth):
        tree = tree.at[node].add(delta)
        node = node // 2
    return FPlusTree(tree=tree, k=t.k)
