"""Optimizers built from scratch (no optax): AdamW and Adafactor.

AdamW keeps fp32 m/v (sharded like the params via the same rules — FSDP
makes them fit). Adafactor factors the second moment into row/col statistics
(O(n+m) instead of O(nm)) — the choice for grok-1/arctic where full Adam
state would exceed the 16 GB/chip HBM budget (DESIGN.md §3; napkin math in
EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    learning_rate: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    # adafactor
    decay_rate: float = 0.8
    min_dim_size_to_factor: int = 128


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params: Any) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def _global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(tree))
    )


def _clip(grads: Any, max_norm: float) -> Any:
    gn = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def adamw_update(
    params: Any, grads: Any, state: AdamWState, cfg: OptConfig
) -> Tuple[Any, AdamWState, dict]:
    grads, gn = _clip(grads, cfg.grad_clip)
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.beta1 ** t
    bc2 = 1.0 - cfg.beta2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = cfg.beta1 * m + (1 - cfg.beta1) * g
        v2 = cfg.beta2 * v + (1 - cfg.beta2) * g * g
        mh = m2 / bc1
        vh = v2 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - cfg.learning_rate * delta).astype(
            p.dtype
        ), m2, v2

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v), {
        "grad_norm": gn
    }


# ---------------------------------------------------------------------------
# Adafactor (factored second moments)
# ---------------------------------------------------------------------------

class FactoredStat(NamedTuple):
    row: jax.Array  # (..., n) mean over last dim
    col: jax.Array  # (..., m) mean over second-to-last dim


class AdafactorState(NamedTuple):
    step: jax.Array
    stats: Any  # FactoredStat for factored leaves, full v for small ones


def _factorable(shape) -> bool:
    return len(shape) >= 2 and shape[-1] >= 128 and shape[-2] >= 128


def adafactor_init(params: Any) -> AdafactorState:
    def one(p):
        if _factorable(p.shape):
            return FactoredStat(
                row=jnp.zeros(p.shape[:-1], jnp.float32),
                col=jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            )
        return jnp.zeros(p.shape, jnp.float32)

    return AdafactorState(
        step=jnp.zeros((), jnp.int32),
        stats=jax.tree.map(one, params),
    )


def adafactor_update(
    params: Any, grads: Any, state: AdafactorState, cfg: OptConfig
) -> Tuple[Any, AdafactorState, dict]:
    grads, gn = _clip(grads, cfg.grad_clip)
    step = state.step + 1
    t = step.astype(jnp.float32)
    beta2t = 1.0 - t ** (-cfg.decay_rate)

    def upd(p, g, s):
        g = g.astype(jnp.float32)
        g2 = g * g + 1e-30
        if isinstance(s, FactoredStat):
            row = beta2t * s.row + (1 - beta2t) * jnp.mean(g2, axis=-1)
            col = beta2t * s.col + (1 - beta2t) * jnp.mean(g2, axis=-2)
            row_mean = jnp.mean(row, axis=-1, keepdims=True)
            vhat = (
                row[..., :, None] / jnp.maximum(row_mean[..., None], 1e-30)
            ) * col[..., None, :]
            update = g * jax.lax.rsqrt(jnp.maximum(vhat, 1e-30))
            new_s = FactoredStat(row=row, col=col)
        else:
            v = beta2t * s + (1 - beta2t) * g2
            update = g * jax.lax.rsqrt(jnp.maximum(v, 1e-30))
            new_s = v
        # update clipping (Adafactor's RMS-1 rule)
        rms = jnp.sqrt(jnp.mean(jnp.square(update)) + 1e-30)
        update = update / jnp.maximum(1.0, rms)
        new_p = (
            p.astype(jnp.float32)
            - cfg.learning_rate * update
            - cfg.learning_rate * cfg.weight_decay * p.astype(jnp.float32)
        )
        return new_p.astype(p.dtype), new_s

    leaves = jax.tree_util.tree_structure(params)
    out = jax.tree.map(
        upd, params, grads, state.stats,
        is_leaf=lambda x: isinstance(x, FactoredStat),
    )
    new_params = jax.tree_util.tree_map(
        lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple)
    )
    new_stats = jax.tree_util.tree_map(
        lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple)
    )
    return new_params, AdafactorState(step=step, stats=new_stats), {
        "grad_norm": gn
    }


def make_optimizer(kind: str, cfg: OptConfig):
    """(init_fn, update_fn) pair."""
    if kind == "adamw":
        return adamw_init, lambda p, g, s: adamw_update(p, g, s, cfg)
    if kind == "adafactor":
        return adafactor_init, lambda p, g, s: adafactor_update(p, g, s, cfg)
    raise ValueError(kind)
