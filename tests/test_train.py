"""Training substrate: optimizers, microbatching, loop fault tolerance."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.train.optimizer import (
    AdafactorState,
    OptConfig,
    adafactor_init,
    adamw_init,
    make_optimizer,
)
from repro.train.train_step import init_train_state, make_train_step
from repro.utils import tree_bytes


def _quad_problem():
    """min ||Wx - y||^2 toy problem for optimizer sanity."""
    rng = np.random.default_rng(0)
    w_true = rng.normal(size=(16, 8)).astype(np.float32)
    x = rng.normal(size=(64, 16)).astype(np.float32)
    y = x @ w_true
    params = {"w": jnp.zeros((16, 8), jnp.float32)}

    def loss(p):
        return jnp.mean((x @ p["w"] - y) ** 2)

    return params, loss


@pytest.mark.parametrize("kind", ["adamw", "adafactor"])
def test_optimizer_minimizes(kind):
    params, loss = _quad_problem()
    cfg = OptConfig(learning_rate=0.05, weight_decay=0.0)
    init, update = make_optimizer(kind, cfg)
    state = init(params)
    l0 = float(loss(params))
    for _ in range(200):
        grads = jax.grad(loss)(params)
        params, state, _ = update(params, grads, state)
    assert float(loss(params)) < 0.05 * l0


def test_grad_clip():
    params = {"w": jnp.zeros((4,), jnp.float32)}
    cfg = OptConfig(learning_rate=1.0, grad_clip=1.0, weight_decay=0.0)
    _, update = make_optimizer("adamw", cfg)
    state = adamw_init(params)
    huge = {"w": jnp.full((4,), 1e9, jnp.float32)}
    _, _, metrics = update(params, huge, state)
    assert float(metrics["grad_norm"]) > 1e8  # reported pre-clip


def test_adafactor_state_smaller_than_adam():
    """The reason grok/arctic use it: factored stats are O(n+m)."""
    cfg = get_config("qwen3-8b-smoke")
    st = init_train_state(jax.random.key(0), cfg)
    adam_bytes = tree_bytes(adamw_init(st.params))
    fact_bytes = tree_bytes(adafactor_init(st.params))
    assert fact_bytes < adam_bytes / 3


def test_microbatch_equivalence():
    cfg = get_config("qwen2-vl-2b-smoke")
    st = init_train_state(jax.random.key(1), cfg)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, 100, (8, 16)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, 100, (8, 16)), jnp.int32),
    }
    s1, m1 = jax.jit(make_train_step(cfg))(st, batch)
    s2, m2 = jax.jit(make_train_step(cfg, num_microbatches=4))(st, batch)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=2e-3
        )


def test_loop_retries_and_resumes():
    from repro.train.loop import LoopConfig, TrainLoop

    calls = {"n": 0, "fails": 0}

    def flaky_step(state):
        calls["n"] += 1
        if calls["n"] == 3 and calls["fails"] == 0:
            calls["fails"] += 1
            raise RuntimeError("transient device error")
        return state + 1, {"loss": float(state)}

    with tempfile.TemporaryDirectory() as td:
        loop = TrainLoop(
            flaky_step,
            LoopConfig(num_steps=10, checkpoint_every=4, checkpoint_dir=td,
                       log_every=0, max_retries=2),
            checkpoint_tree_fn=lambda s: {"state": jnp.asarray(s)},
            restore_fn=lambda s, tree: int(tree["state"]),
        )
        final = loop.run(0)
        assert final == 10
        assert calls["fails"] == 1  # retried through the failure
        # a fresh loop resumes from the checkpoint, not from zero
        loop2 = TrainLoop(
            lambda s: (s + 1, {}),
            LoopConfig(num_steps=12, checkpoint_every=100, checkpoint_dir=td,
                       log_every=0),
            checkpoint_tree_fn=lambda s: {"state": jnp.asarray(s)},
            restore_fn=lambda s, tree: int(tree["state"]),
        )
        final2 = loop2.run(0)
        assert final2 == 12  # resumed at 8 (last ckpt) and ran 4 more
