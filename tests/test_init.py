"""Initialization strategies (paper §5.1 sparse model initialization)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.init import (
    beta_boost,
    random_init,
    sparse_doc_init,
    sparse_word_init,
)


def test_all_inits_keep_invariants(key, tiny_corpus, tiny_hyper):
    for fn in (random_init,
               lambda k, c, h: sparse_word_init(k, c, h, 0.3),
               lambda k, c, h: sparse_doc_init(k, c, h, 0.3)):
        state = fn(key, tiny_corpus, tiny_hyper)
        state.check_invariants(tiny_corpus)


def test_sparse_word_init_bounds_row_nnz(key, tiny_corpus, tiny_hyper):
    """Each word's topic set is drawn from a subset of size ceil(deg*K)."""
    deg = 0.34
    state = sparse_word_init(key, tiny_corpus, tiny_hyper, degree=deg)
    s = max(1, int(round(deg * tiny_hyper.num_topics)))
    nnz = np.asarray(jnp.sum(state.n_wk > 0, axis=-1))
    assert nnz.max() <= s
    # and it is actually sparser than random init on hot words
    rand = random_init(key, tiny_corpus, tiny_hyper)
    assert nnz.sum() <= np.asarray(jnp.sum(rand.n_wk > 0, -1)).sum()


def test_sparse_doc_init_bounds_doc_nnz(key, tiny_corpus, tiny_hyper):
    state = sparse_doc_init(key, tiny_corpus, tiny_hyper, degree=0.34)
    s = max(1, int(round(0.34 * tiny_hyper.num_topics)))
    nnz = np.asarray(jnp.sum(state.n_kd > 0, axis=-1))
    assert nnz.max() <= s


def test_beta_boost_targets_unassigned(key, tiny_corpus, tiny_hyper):
    state = sparse_word_init(key, tiny_corpus, tiny_hyper, degree=0.3)
    bb = beta_boost(state, tiny_hyper, boost=2.0)
    unassigned = np.asarray(state.n_wk == 0)
    b = np.asarray(bb)
    assert (b[unassigned] == tiny_hyper.beta * 2.0).all()
    assert (b[~unassigned] == tiny_hyper.beta).all()


def test_sparse_init_converges(key, tiny_corpus, tiny_hyper):
    """Fig. 7: sparse init must still converge (side effect recovered)."""
    from repro.core import LDATrainer, TrainConfig

    tr = LDATrainer(
        tiny_corpus, tiny_hyper,
        TrainConfig(algorithm="zen", init="sparse_word",
                    sparse_init_degree=0.3),
    )
    st = tr.init_state(key)
    l0 = tr.llh(st)
    for _ in range(10):
        st = tr.step(st)
    assert tr.llh(st) > l0
