"""Paper Figs. 5 + 6: scalability in workers and in topic count.

Fig. 5 (workers): on this 1-core container real speedup is unmeasurable, so
we report the two quantities that *determine* scale-out on the real mesh:
padding overhead (load balance) and collective bytes per iteration, as the
partition count grows. Both come from the same partitioner + runtime the
512-device dry-run uses.

Fig. 6 (topics): time per iteration as K grows 8x — ZenLDA's decomposed
sampler (zen_cdf work = O(max_kd) per token + O(K) per word per iteration)
grows far slower than the standard O(K)-per-token sampler.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import row
from repro.core import LDATrainer, TrainConfig, LDAHyperParams
from repro.core.graph import grid_partition
from repro.data import synthetic_lda_corpus


def fig5_partition_scaling():
    corpus, _ = synthetic_lda_corpus(
        1, num_docs=600, num_words=900, num_topics=16, avg_doc_len=50
    )
    k = 16
    for parts in (4, 16, 64):
        rows = int(np.sqrt(parts))
        cols = parts // rows
        grid = grid_partition(corpus, rows, cols)
        # per-iteration collective payload (int32 deltas, both directions)
        wk_bytes = grid.num_words_padded * k * 4
        kd_bytes = grid.num_docs_padded * k * 4
        row(
            f"fig5_partitions_{parts}", 0.0,
            f"pad_overhead={grid.padding_overhead:.3f};"
            f"coll_bytes_per_iter={wk_bytes + kd_bytes}",
        )


def fig6_topic_scaling(iters: int = 5):
    corpus, _ = synthetic_lda_corpus(
        2, num_docs=300, num_words=600, num_topics=16, avg_doc_len=50
    )
    times = {}
    for k in (64, 128, 256, 512):
        hyper = LDAHyperParams(num_topics=k, alpha=0.05, beta=0.01)
        tr = LDATrainer(corpus, hyper,
                        TrainConfig(algorithm="zen_sparse", max_kw=64,
                                    max_kd=64))
        st = tr.init_state(jax.random.key(0))
        st = tr.step(st)
        t0 = time.perf_counter()
        for _ in range(iters):
            st = tr.step(st)
        times[k] = (time.perf_counter() - t0) / iters
        row(f"fig6_zen_sparse_K{k}", times[k] * 1e6, "")
    row("fig6_zen_growth_64_to_512", 0.0,
        f"ratio={times[512] / times[64]:.2f} (paper: ~3x for 100x topics)")
    # contrast: the O(K) standard sampler
    tstd = {}
    for k in (64, 512):
        hyper = LDAHyperParams(num_topics=k, alpha=0.05, beta=0.01)
        tr = LDATrainer(corpus, hyper, TrainConfig(algorithm="std"))
        st = tr.init_state(jax.random.key(0))
        st = tr.step(st)
        t0 = time.perf_counter()
        for _ in range(iters):
            st = tr.step(st)
        tstd[k] = (time.perf_counter() - t0) / iters
    row("fig6_std_growth_64_to_512", 0.0, f"ratio={tstd[512] / tstd[64]:.2f}")


def main():
    fig5_partition_scaling()
    fig6_topic_scaling()


if __name__ == "__main__":
    main()
