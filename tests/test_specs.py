"""Dry-run cell spec construction: every (arch x shape) builds abstract
inputs + shardings without error (regression guard for the launch layer)."""
from helpers import run_with_devices


def test_all_cells_build_specs():
    run_with_devices("""
import warnings; warnings.filterwarnings('ignore')
import jax
from repro.configs import SHAPES, get_config, list_archs, shapes_for
from repro.configs.base import LDAArchConfig
from repro.launch.specs import lda_cell_specs, lm_cell_specs
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 2), ('data', 'model'))
built = 0
for arch in list_archs():
    cfg = get_config(arch)
    if isinstance(cfg, LDAArchConfig):
        kind, inputs, shardings, dims = lda_cell_specs(cfg, mesh)
        assert kind == 'lda' and dims['e_cell'] > 0
        # abstract state matches the sharding tree structure
        assert jax.tree_util.tree_structure(inputs['state']) \
            == jax.tree_util.tree_structure(shardings['state'])
        built += 1
        continue
    for shape_name in shapes_for(cfg):
        kind, inputs, shardings = lm_cell_specs(cfg, SHAPES[shape_name], mesh)
        assert set(inputs) == set(shardings)
        for k in inputs:
            si = jax.tree_util.tree_structure(inputs[k])
            ss = jax.tree_util.tree_structure(shardings[k])
            assert si == ss, (arch, shape_name, k)
        # no leaf is missing a sharding
        n_in = len(jax.tree_util.tree_leaves(inputs))
        n_sh = len(jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: hasattr(x, 'spec')))
        assert n_in == n_sh, (arch, shape_name)
        built += 1
print('built', built, 'cells')
assert built == 35
""", n_devices=4, timeout=900)
