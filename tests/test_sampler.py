"""Dense CGS sweeps: correctness of the sampling distribution + invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import counts as counts_lib
from repro.core.init import random_init
from repro.core.sampler import (
    cgs_sweep_serial,
    cgs_sweep_stale,
    conditional_probs,
    gibbs_iteration,
    sample_categorical,
)
from repro.core.types import LDAHyperParams


def test_sample_categorical_matches_distribution(key):
    probs = jnp.asarray(
        np.tile([[0.1, 0.0, 0.4, 0.5]], (50_000, 1)), jnp.float32
    )
    for method in ("cdf", "gumbel"):
        s = sample_categorical(key, probs, method=method)
        emp = np.bincount(np.asarray(s), minlength=4) / probs.shape[0]
        np.testing.assert_allclose(emp, [0.1, 0.0, 0.4, 0.5], atol=8e-3)


def test_cdf_and_gumbel_agree_statistically(key, tiny_corpus, tiny_hyper):
    state = random_init(key, tiny_corpus, tiny_hyper)
    z_cdf = cgs_sweep_stale(state, tiny_corpus, tiny_hyper, method="cdf")
    z_gum = cgs_sweep_stale(state, tiny_corpus, tiny_hyper, method="gumbel")
    # same conditional => similar per-topic totals
    h_cdf = np.bincount(np.asarray(z_cdf), minlength=tiny_hyper.num_topics)
    h_gum = np.bincount(np.asarray(z_gum), minlength=tiny_hyper.num_topics)
    assert np.abs(h_cdf - h_gum).sum() < 0.15 * tiny_corpus.num_tokens


def test_conditional_probs_exclude_self(key, tiny_corpus, tiny_hyper):
    """¬dw semantics: excluding the token's own topic = manual decrement."""
    state = random_init(key, tiny_corpus, tiny_hyper)
    p = conditional_probs(state, tiny_corpus, tiny_hyper, exclude_self=True,
                          decomposition="std")
    i = 7
    w = int(state.n_wk[tiny_corpus.word[i], state.topic[i]])
    n_wk = state.n_wk.at[tiny_corpus.word[i], state.topic[i]].add(-1)
    n_kd = state.n_kd.at[tiny_corpus.doc[i], state.topic[i]].add(-1)
    n_k = state.n_k.at[state.topic[i]].add(-1)
    alpha_k = tiny_hyper.alpha_k(state.n_k)
    wb = tiny_corpus.num_words * tiny_hyper.beta
    manual = (
        (n_wk[tiny_corpus.word[i]].astype(jnp.float32) + tiny_hyper.beta)
        / (n_k.astype(jnp.float32) + wb)
        * (n_kd[tiny_corpus.doc[i]].astype(jnp.float32) + alpha_k)
    )
    np.testing.assert_allclose(np.asarray(p[i]), np.asarray(manual), rtol=2e-5)


def test_zen_equals_std_dense(key, tiny_corpus, tiny_hyper):
    """The ZenLDA decomposition is algebraically Eq. 3: same samples."""
    state = random_init(key, tiny_corpus, tiny_hyper)
    z1 = cgs_sweep_stale(state, tiny_corpus, tiny_hyper, decomposition="zen")
    z2 = cgs_sweep_stale(state, tiny_corpus, tiny_hyper, decomposition="std")
    assert float(jnp.mean((z1 == z2).astype(jnp.float32))) > 0.99


def test_gibbs_iteration_invariants(key, tiny_corpus, tiny_hyper):
    state = random_init(key, tiny_corpus, tiny_hyper)
    for _ in range(3):
        state = gibbs_iteration(state, tiny_corpus, tiny_hyper)
    state.check_invariants(tiny_corpus)


def test_serial_sweep_invariants_and_convergence(key, tiny_corpus, tiny_hyper):
    from repro.core.likelihood import predictive_llh

    state = random_init(key, tiny_corpus, tiny_hyper)
    llh0 = float(predictive_llh(state, tiny_corpus, tiny_hyper))
    for _ in range(2):
        state = cgs_sweep_serial(state, tiny_corpus, tiny_hyper)
    state.check_invariants(tiny_corpus)
    llh1 = float(predictive_llh(state, tiny_corpus, tiny_hyper))
    assert llh1 > llh0  # the true Gibbs chain improves fast on easy data


def test_token_chunking_matches_unchunked(key, tiny_corpus, tiny_hyper):
    state = random_init(key, tiny_corpus, tiny_hyper)
    e = tiny_corpus.num_tokens
    pad = (-e) % 5
    # choose a divisor-friendly chunk by truncating to a multiple of 4
    e4 = e - (e % 4)
    from repro.core.types import Corpus

    c4 = Corpus(word=tiny_corpus.word[:e4], doc=tiny_corpus.doc[:e4],
                num_words=tiny_corpus.num_words, num_docs=tiny_corpus.num_docs)
    import dataclasses

    s4 = dataclasses.replace(
        state, topic=state.topic[:e4], prev_topic=state.prev_topic[:e4],
        stale_iters=None, same_count=None,
    )
    z_full = cgs_sweep_stale(s4, c4, tiny_hyper)
    z_chunk = cgs_sweep_stale(s4, c4, tiny_hyper, token_chunk=e4 // 4)
    # chunking changes RNG stream layout; distributions must match
    h1 = np.bincount(np.asarray(z_full), minlength=tiny_hyper.num_topics)
    h2 = np.bincount(np.asarray(z_chunk), minlength=tiny_hyper.num_topics)
    assert np.abs(h1 - h2).sum() < 0.2 * e4
