"""Model-quality metrics: log-likelihood (total/word/doc split) and perplexity.

Two likelihoods are implemented:

* ``predictive_llh`` — the formula the paper states it uses (footnote 6):
      llh = sum_tokens log sum_k [(N_k|d + α_k)/(N_d + Kα̂)] ·
                               [(N_w|k + β)/(N_k + Wβ)]
  used for the Fig. 3/4 comparisons and for perplexity.

* ``joint_llh`` — the standard collapsed joint p(w, z) split into its word
  part and doc part (paper Fig. 7 plots "word log-likelihood" and "doc
  log-likelihood" separately).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.scipy.special import gammaln

from repro.core.types import CGSState, Corpus, LDAHyperParams
from repro.core import counts as counts_lib


class LLH(NamedTuple):
    total: jax.Array
    word: jax.Array
    doc: jax.Array


def predictive_llh(
    state: CGSState, corpus: Corpus, hyper: LDAHyperParams,
    token_chunk: int | None = None,
) -> jax.Array:
    """Paper footnote-6 log-likelihood (a token-level predictive score)."""
    alpha_k = hyper.alpha_k(state.n_k)
    alpha_sum = jnp.sum(alpha_k)
    n_d = counts_lib.doc_lengths(corpus.doc, corpus.num_docs).astype(jnp.float32)
    w_beta = corpus.num_words * hyper.beta
    phi_denom = state.n_k.astype(jnp.float32) + w_beta  # (K,)

    def chunk(args):
        w, d = args
        theta = (state.n_kd[d].astype(jnp.float32) + alpha_k[None, :]) / (
            n_d[d][:, None] + alpha_sum
        )
        phi = (state.n_wk[w].astype(jnp.float32) + hyper.beta) / phi_denom[None, :]
        return jnp.log(jnp.maximum(jnp.sum(theta * phi, axis=-1), 1e-30))

    e = corpus.word.shape[0]
    if token_chunk is None or token_chunk >= e:
        return jnp.sum(chunk((corpus.word, corpus.doc)))
    assert e % token_chunk == 0
    n_chunks = e // token_chunk
    vals = jax.lax.map(
        chunk,
        (corpus.word.reshape(n_chunks, -1), corpus.doc.reshape(n_chunks, -1)),
    )
    return jnp.sum(vals)


def perplexity(
    state: CGSState, corpus: Corpus, hyper: LDAHyperParams,
    token_chunk: int | None = None,
) -> jax.Array:
    llh = predictive_llh(state, corpus, hyper, token_chunk=token_chunk)
    return jnp.exp(-llh / corpus.num_tokens)


def joint_llh(state: CGSState, corpus: Corpus, hyper: LDAHyperParams) -> LLH:
    """Collapsed joint log p(w, z | α, β) = word part + doc part."""
    k = hyper.num_topics
    w = corpus.num_words
    d = corpus.num_docs
    beta = hyper.beta
    alpha_k = hyper.alpha_k(state.n_k)
    alpha_sum = jnp.sum(alpha_k)
    n_d = counts_lib.doc_lengths(corpus.doc, corpus.num_docs).astype(jnp.float32)

    # word part: prod_k [Γ(Wβ)/Γ(N_k+Wβ)] * prod_w Γ(N_wk+β)/Γ(β)
    word_part = (
        k * gammaln(w * beta)
        - jnp.sum(gammaln(state.n_k.astype(jnp.float32) + w * beta))
        + jnp.sum(gammaln(state.n_wk.astype(jnp.float32) + beta))
        - k * w * gammaln(beta)
    )
    # doc part: prod_d [Γ(Σα)/Γ(N_d+Σα)] * prod_k Γ(N_kd+α_k)/Γ(α_k)
    doc_part = (
        d * gammaln(alpha_sum)
        - jnp.sum(gammaln(n_d + alpha_sum))
        + jnp.sum(gammaln(state.n_kd.astype(jnp.float32) + alpha_k[None, :]))
        - d * jnp.sum(gammaln(alpha_k))
    )
    return LLH(total=word_part + doc_part, word=word_part, doc=doc_part)
