"""Serving throughput: docs/sec vs batch size x bucket layout, per backend.

The serving analogue of the training-sweep benchmarks: a frozen synthetic
model, a mixed-length query load, and the bucketed ``LDAEngine`` from
``repro.serving``. Derived column = docs/sec.

    PYTHONPATH=src python benchmarks/run.py --only infer
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import row

BACKENDS = ("zen", "zen_cdf", "zen_pallas")
NUM_DOCS = 96
NUM_WORDS = 2000
NUM_TOPICS = 64


def _frozen_model():
    import jax.numpy as jnp

    from repro.core.types import LDAHyperParams
    from repro.serving import FrozenLDAModel

    rng = np.random.default_rng(0)
    n_wk = rng.poisson(2.0, size=(NUM_WORDS, NUM_TOPICS)).astype(np.int32)
    return FrozenLDAModel(
        n_wk=jnp.asarray(n_wk),
        n_k=jnp.asarray(n_wk.sum(0).astype(np.int32)),
        hyper=LDAHyperParams(num_topics=NUM_TOPICS),
    )


def _load(rng):
    """Mixed-length Zipf query docs (the serving traffic shape)."""
    lengths = np.clip(rng.poisson(48, size=NUM_DOCS), 4, 240)
    ranks = np.arange(1, NUM_WORDS + 1, dtype=np.float64) ** -1.2
    pmf = ranks / ranks.sum()
    return [
        rng.choice(NUM_WORDS, size=n, p=pmf).astype(np.int32)
        for n in lengths
    ]


def main() -> None:
    from repro.serving import LDAEngine, LDAServeConfig

    model = _frozen_model()
    docs = _load(np.random.default_rng(1))
    layouts = [
        ("1bucket", (256,)),
        ("2buckets", (64, 256)),
        ("4buckets", (32, 64, 128, 256)),
    ]
    for backend in BACKENDS:
        for batch in (8, 32):
            for lname, buckets in layouts:
                cfg = LDAServeConfig(
                    buckets=buckets, max_batch=batch, num_sweeps=10,
                    algorithm=backend,
                )
                engine = LDAEngine(model, cfg, seed=0)
                # warm THIS engine's per-bucket jit caches (they are
                # per-instance closures): one doc per bucket width
                engine.infer_batch(
                    [np.zeros(bl, np.int32) for bl in buckets]
                )
                t0 = time.perf_counter()
                engine.infer_batch(docs)
                dt = time.perf_counter() - t0
                row(
                    f"infer_{backend}_b{batch}_{lname}",
                    dt * 1e6 / NUM_DOCS,
                    f"{NUM_DOCS / dt:.1f} docs/s",
                )


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
