import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# The two lines above MUST run before any other import (jax locks the device
# count at first init). Everything below is normal.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:
  * build abstract inputs + shardings (launch/specs.py)
  * jax.jit(step, in_shardings=..., out_shardings=...).lower(...).compile()
  * record memory_analysis / cost_analysis / collective bytes (roofline.py)
  * append the result to a JSON store so interrupted sweeps resume

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
  PYTHONPATH=src python -m repro.launch.dryrun --list
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax
import numpy as np


RESULTS_PATH = os.environ.get("DRYRUN_RESULTS", "results/dryrun.json")


def _load_results(path: str) -> Dict[str, Any]:
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def _save_results(path: str, results: Dict[str, Any]) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def build_step(cfg, kind: str, dims=None):
    """The function each cell lowers (closed over the config)."""
    from repro.configs.base import ArchConfig, LDAArchConfig
    from repro.models.model import decode_step, forward
    from repro.train.train_step import make_train_step

    if kind == "train":
        inner = make_train_step(cfg)

        def train_step(state, batch):
            return inner(state, batch)

        return train_step
    if kind == "prefill":
        def prefill_step(params, batch):
            logits, _ = forward(
                params, cfg,
                tokens=batch.get("tokens"),
                embeds=batch.get("embeds"),
                positions=batch.get("positions"),
                enc_embeds=batch.get("enc_embeds"),
            )
            return logits

        return prefill_step
    if kind == "decode":
        def serve_step(params, token, caches):
            return decode_step(params, cfg, token, caches)

        return serve_step
    if kind == "lda":
        from repro import algorithms
        from repro.core.distributed import DistConfig, make_dist_step
        from repro.core.types import LDAHyperParams

        # fail fast (before lowering) on unknown / non-mesh backends — the
        # same registry entry the trainer and the mesh step resolve
        backend = algorithms.get(cfg.algorithm)
        if not backend.supports_shard_map:
            raise ValueError(
                f"LDA arch {cfg.name!r}: backend {cfg.algorithm!r} has no "
                f"shard_map cell sweep"
            )
        hyper = LDAHyperParams(num_topics=cfg.num_topics)
        dcfg = DistConfig(
            algorithm=cfg.algorithm, max_kd=cfg.max_kd,
            delta_dtype=cfg.delta_dtype,
        )

        def make(mesh):
            return make_dist_step(
                mesh, hyper, dcfg, dims["words_per_shard"],
                dims["docs_per_shard"],
            )

        return make
    raise ValueError(kind)


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> Dict[str, Any]:
    """Lower+compile one cell; returns the result record."""
    from repro.configs import SHAPES, get_config
    from repro.configs.base import LDAArchConfig
    from repro.launch import roofline
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import lda_cell_specs, lm_cell_specs

    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    t0 = time.time()
    if isinstance(cfg, LDAArchConfig):
        kind, inputs, shardings, dims = lda_cell_specs(cfg, mesh)
        step = build_step(cfg, kind, dims)(mesh)
        lowered = step.lower(inputs["state"], inputs["data"])
    else:
        shape = SHAPES[shape_name]
        kind, inputs, shardings = lm_cell_specs(cfg, shape, mesh)
        step = build_step(cfg, kind)
        in_sh = tuple(shardings[k] for k in inputs)
        out_sh = None
        if kind == "train":
            # state out keeps the state-in layout (donation-compatible)
            out_sh = (shardings["state"], None)
        jitted = jax.jit(
            step,
            in_shardings=in_sh,
            out_shardings=out_sh,
            # donation matches production (train state / decode caches are
            # updated in place) and makes memory_analysis reflect reality
            donate_argnums=(0,) if kind == "train" else
                           ((2,) if kind == "decode" else ()),
        )
        lowered = jitted.lower(*inputs.values())
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = roofline.collective_bytes(compiled)
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "ok": True,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops_per_device": cost.get("flops", 0.0) if cost else 0.0,
        "bytes_per_device": cost.get("bytes accessed", 0.0) if cost else 0.0,
        "collective_bytes_per_device": coll,
        "memory_analysis": roofline.memory_summary(mem),
    }
    return record


def main() -> None:
    from repro.configs import SHAPES, get_config, list_archs, shapes_for

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true",
                    help="recompute cells already in the results store")
    ap.add_argument("--fit", action="store_true",
                    help="also depth-fit true per-step costs (single-pod "
                         "mesh; see rooffit.py) for the roofline table")
    ap.add_argument("--out", default=RESULTS_PATH)
    args = ap.parse_args()

    cells = []
    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    for arch in archs:
        cfg = get_config(arch)
        names = shapes_for(cfg)
        if args.shape:
            names = [s for s in names if s == args.shape]
        for s in names:
            cells.append((arch, s))

    if args.list:
        for c in cells:
            print(f"{c[0]} x {c[1]}")
        print(f"total {len(cells)} cells")
        return

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    results = _load_results(args.out)
    for arch, shape in cells:
        for multi in meshes:
            key = f"{arch}|{shape}|{'multi' if multi else 'single'}"
            if key in results and results[key].get("ok") and not args.force:
                print(f"[skip] {key}")
                continue
            print(f"[cell] {key} ...", flush=True)
            try:
                rec = run_cell(arch, shape, multi)
                print(
                    f"  ok: compile {rec['compile_s']}s, "
                    f"flops/dev {rec['flops_per_device']:.3e}, "
                    f"coll B/dev {rec['collective_bytes_per_device']:.3e}",
                    flush=True,
                )
            except Exception as e:  # record failures: they are bugs to fix
                rec = {
                    "arch": arch, "shape": shape,
                    "mesh": "2x16x16" if multi else "16x16",
                    "ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:],
                }
                print(f"  FAIL: {rec['error']}", flush=True)
            results[key] = rec
            _save_results(args.out, results)
        if args.fit:
            from repro.configs.base import LDAArchConfig
            from repro.launch.mesh import make_production_mesh
            from repro.launch.rooffit import fit_cell

            fkey = f"{arch}|{shape}|fit"
            cfg = get_config(arch)
            if isinstance(cfg, LDAArchConfig):
                continue  # no scans: the raw record is already exact
            if fkey in results and results[fkey].get("ok") and not args.force:
                print(f"[skip] {fkey}")
                continue
            print(f"[fit ] {fkey} ...", flush=True)
            try:
                rec = fit_cell(arch, shape, make_production_mesh())
                rec["ok"] = True
                print(
                    f"  fitted flops/dev {rec['flops_per_device']:.3e}, "
                    f"coll B/dev {rec['collective_bytes_per_device']:.3e}",
                    flush=True,
                )
            except Exception as e:
                rec = {"ok": False, "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
                print(f"  FAIL: {rec['error']}", flush=True)
            results[fkey] = rec
            _save_results(args.out, results)


if __name__ == "__main__":
    main()
