"""Corpus generation + libsvm IO (the paper's corpus format)."""
import io
import os
import tempfile

import numpy as np

from repro.data import (
    load_libsvm,
    save_libsvm,
    skip_libsvm_docs,
    synthetic_corpus,
    synthetic_lda_corpus,
)


def test_synthetic_power_law():
    c = synthetic_corpus(0, num_docs=200, num_words=500, avg_doc_len=50,
                         zipf_a=1.3)
    freq = np.bincount(np.asarray(c.word), minlength=500)
    # hot head: top-10 words carry a disproportionate share
    assert freq[np.argsort(-freq)[:10]].sum() > 0.2 * c.num_tokens
    assert c.num_tokens > 0 and int(c.doc.max()) < 200


def test_libsvm_roundtrip():
    c = synthetic_corpus(1, num_docs=30, num_words=40, avg_doc_len=10)
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "c.libsvm")
        save_libsvm(c, path)
        c2 = load_libsvm(path, num_words=40)
    assert c2.num_docs == c.num_docs
    assert c2.num_tokens == c.num_tokens
    # same word histogram per doc (token order within doc may differ)
    for d in range(c.num_docs):
        a = np.sort(np.asarray(c.word)[np.asarray(c.doc) == d])
        b = np.sort(np.asarray(c2.word)[np.asarray(c2.doc) == d])
        np.testing.assert_array_equal(a, b)


def test_libsvm_windowed_read_matches_whole_file():
    """Chunking one handle with max_docs reassembles the whole-file read
    exactly (satellite contract for LibsvmStreamSource)."""
    c = synthetic_corpus(2, num_docs=23, num_words=30, avg_doc_len=8)
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "c.libsvm")
        save_libsvm(c, path)
        whole = load_libsvm(path, num_words=30)
        words, docs, base = [], [], 0
        with open(path) as f:
            while True:
                w = load_libsvm(f, num_words=30, max_docs=7)
                if w.num_docs == 0:
                    break
                assert w.num_docs <= 7
                assert int(w.doc.min()) == 0  # window-local doc ids
                words.append(np.asarray(w.word))
                docs.append(np.asarray(w.doc) + base)
                base += w.num_docs
    assert base == whole.num_docs == 23
    np.testing.assert_array_equal(np.concatenate(words),
                                  np.asarray(whole.word))
    np.testing.assert_array_equal(np.concatenate(docs),
                                  np.asarray(whole.doc))


def test_libsvm_max_docs_on_path_and_skip():
    c = synthetic_corpus(3, num_docs=10, num_words=20, avg_doc_len=6)
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "c.libsvm")
        save_libsvm(c, path)
        head = load_libsvm(path, num_words=20, max_docs=4)
        assert head.num_docs == 4
        whole = load_libsvm(path, num_words=20)
        np.testing.assert_array_equal(
            np.asarray(head.word),
            np.asarray(whole.word)[np.asarray(whole.doc) < 4],
        )
        # skip_libsvm_docs fast-forwards to the same boundary
        with open(path) as f:
            assert skip_libsvm_docs(f, 4) == 4
            tail = load_libsvm(f, num_words=20)
        assert tail.num_docs == 6
        np.testing.assert_array_equal(
            np.asarray(tail.word),
            np.asarray(whole.word)[np.asarray(whole.doc) >= 4],
        )
        # skipping past EOF reports the shortfall
        with open(path) as f:
            assert skip_libsvm_docs(f, 99) == 10


def test_generative_corpus_shapes():
    c, phi = synthetic_lda_corpus(0, num_docs=20, num_words=50, num_topics=5,
                                  avg_doc_len=20)
    assert phi.shape == (5, 50)
    np.testing.assert_allclose(phi.sum(1), 1.0, rtol=1e-6)
    assert c.num_tokens > 0
