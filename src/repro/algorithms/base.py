"""SamplerBackend protocol + the one shared knob dataclass (DESIGN.md §4).

Every CGS sampling algorithm in the repo — single-box, distributed, and the
fused Pallas kernel — implements the same contract over the shared
counts/corpus substrate:

* ``prepare(corpus, hyper, knobs) -> aux`` — optional per-run precompute
  (e.g. LightLDA's CSR doc->token index). Called once by the driver; the
  result is passed back into every ``sweep``.
* ``sweep(state, corpus, hyper, knobs, aux) -> new_topics (E,)`` — one full
  pass over all tokens against iteration-start (stale) counts. The driver
  owns masking (token exclusion), the delta merge, and the state update, so
  a backend is *only* the per-token draw.
* ``cell_sweep(key, word, doc, z_old, mask, n_wk, n_kd, n_k, hyper,
  num_words_pad, knobs) -> new_topics (T,)`` — the per-device form used
  inside ``shard_map`` by the distributed runtime: all ids are local to the
  device's (word-shard x doc-shard) cell and the count blocks are the local
  shards. Only backends with ``supports_shard_map`` implement it.
* ``prepare_infer(n_wk, n_k, hyper, knobs) -> frozen aux`` /
  ``infer_sweep(keys, words, mask, z_old, n_kd, n_wk, n_k, hyper, knobs,
  aux) -> new_topics (B, L)`` — the *serving* form (frozen-model
  inference, paper §4.3): the trained ``N_w|k``/``N_k`` are held fixed and
  only the per-slot doc-topic counts move. The base class provides a
  default derivation that every backend inherits (the dense frozen-phi
  sweep, sweep-equivalent math with the word side frozen), so all
  registered backends serve for free; ``zen_cdf`` (one-time frozen
  per-word CDFs) and ``zen_pallas`` (a dedicated frozen-model kernel
  variant with per-slot seeds) override it natively and set
  ``native_infer``.

Capability flags let drivers adapt instead of hard-coding per-name logic:

* ``supports_shard_map`` — has a ``cell_sweep`` the mesh path can call
  (``make_dist_step`` rejects backends without it).
* ``needs_row_pads``     — the trainer resolves ``max_kw``/``max_kd`` (>0)
  before ``sweep`` (padded-sparse row widths; 0 = "auto from the counts").
* ``needs_doc_index``    — declares the aux contract: ``prepare`` returns a
  doc->token index that ``sweep`` requires (drivers call ``prepare``
  unconditionally; the flag tells them the aux is a corpus-sized structure
  worth budgeting for, not a behavior switch).

Backends also *declare their cell workspace shapes*: the distributed step
calls ``resolve_cell_knobs(knobs, hyper)`` once at trace time, and the
backend fills every knob that sizes a static per-cell workspace (padded
row widths, tile sizes). Inside ``shard_map`` nothing can be data-derived,
so 0/auto knobs must become concrete static widths here; drivers then
treat the returned knobs as the backend's actual workspace commitment
(benchmarks and launch scripts report them). Data-driven widths come from
the *shards* instead: ``repro.core.distributed.resolve_dist_row_pads``
fills 0 knobs from the sharded counts before the step is built.

``CellBackend`` derives the single-box ``sweep`` from ``cell_sweep`` by
treating the whole corpus as one cell — this is what makes the distributed
algorithms (``zen_cdf``, ``zen_dense``, ``zen_pallas``) selectable from the
single-box trainer with zero extra code.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp

# Kernel dispatch policy values for SamplerKnobs.kernels (see
# ``kernel_dispatch``): "auto" = Pallas kernels on TPU, legacy XLA
# elsewhere; "on"/"off" force either path (interpret-mode kernels on CPU
# are bit-exact but walk the grid step by step — fine for tests, far too
# slow for CPU *training*, hence a policy knob instead of a backend flag).
VALID_KERNEL_MODES = ("auto", "on", "off")

# TPU f32 tiling floors: Pallas blocks need >= 8 sublanes and lane-dim
# multiples of 128; violations surface as opaque Mosaic lowering errors
# deep inside jit, so SamplerKnobs rejects them at construction instead.
_MIN_BT = 8
_LANE = 128


@dataclasses.dataclass(frozen=True)
class SamplerKnobs:
    """Algorithm knobs shared by every backend and both drivers.

    This unifies what used to be divergent fields on ``TrainConfig``
    (``token_chunk: Optional[int]``) and ``DistConfig``
    (``token_chunk: int = 0``): 0 always means "disabled / auto".

    Tile knobs are validated at construction (``__post_init__`` fires for
    ``knobs_from``, direct construction, and ``dataclasses.replace`` alike)
    so a bad ``bt``/``bk``/``bs`` fails with a clear ``ValueError`` at
    config time, not as a Pallas lowering error mid-trace.
    """

    sampling_method: str = "cdf"  # dense paths: cdf | gumbel
    max_kw: int = 0  # padded-sparse word-row width (0 = auto)
    max_kd: int = 0  # padded-sparse doc-row width (0 = auto)
    num_mh: int = 8  # LightLDA cycle-MH steps
    token_chunk: int = 0  # bound peak memory by chunking tokens (0 = off)
    bt: int = 256  # Pallas token-tile (zen_pallas + kernel suite v2)
    bk: int = 512  # Pallas topic-tile (zen_pallas + kernel suite v2)
    bs: int = 128  # sparse-row lane-alignment tile (kernel (c))
    kernels: str = "auto"  # kernel dispatch policy: auto | on | off

    def __post_init__(self):
        if self.bt < _MIN_BT:
            raise ValueError(
                f"SamplerKnobs.bt={self.bt}: Pallas token tiles need at "
                f"least {_MIN_BT} rows (TPU f32 sublane minimum)"
            )
        for name, v in (("bk", self.bk), ("bs", self.bs)):
            if v < _LANE or v % _LANE:
                raise ValueError(
                    f"SamplerKnobs.{name}={v}: topic/lane tiles must be "
                    f"positive multiples of the {_LANE}-wide TPU lane dim"
                )
        if self.kernels not in VALID_KERNEL_MODES:
            raise ValueError(
                f"SamplerKnobs.kernels={self.kernels!r}: expected one of "
                f"{VALID_KERNEL_MODES}"
            )

    def chunk_or_none(self) -> Optional[int]:
        return self.token_chunk or None


def kernel_dispatch(mode: str) -> bool:
    """Resolve a ``SamplerKnobs.kernels`` policy to "use Pallas kernels?".

    ``auto`` dispatches kernels when the default backend is a TPU and the
    legacy XLA paths elsewhere (interpret-mode grids are too slow for CPU
    training); ``on``/``off`` force either path. The ``REPRO_KERNELS``
    environment variable overrides the knob when set (read at call time,
    not import time) — this is how the parity tests force kernel dispatch
    through the unchanged mesh harness.
    """
    mode = os.environ.get("REPRO_KERNELS", mode)
    if mode not in VALID_KERNEL_MODES:
        raise ValueError(
            f"kernel mode {mode!r}: expected one of {VALID_KERNEL_MODES}"
        )
    if mode == "auto":
        return jax.default_backend() == "tpu"
    return mode == "on"


_KNOB_FIELDS = tuple(f.name for f in dataclasses.fields(SamplerKnobs))


def knobs_from(cfg) -> SamplerKnobs:
    """THE SamplerKnobs derivation — every driver config builds its knobs
    here (``RunConfig``, and the deprecated ``TrainConfig``/``DistConfig``
    shims), so a new knob is one field on ``SamplerKnobs`` plus one field
    on ``RunConfig``, never a per-config copy."""
    return SamplerKnobs(**{f: getattr(cfg, f) for f in _KNOB_FIELDS})


class SamplerBackend:
    """Base class: capability flags + the sweep contract."""

    name: str = "?"
    supports_shard_map: bool = False
    needs_doc_index: bool = False
    needs_row_pads: bool = False

    def prepare(self, corpus, hyper, knobs: SamplerKnobs) -> Any:
        """Per-run precompute; returns the aux object threaded into sweep."""
        return None

    def sweep(
        self, state, corpus, hyper, knobs: SamplerKnobs, aux: Any = None
    ) -> jax.Array:
        raise NotImplementedError(
            f"backend {self.name!r} has no single-box sweep"
        )

    def cell_sweep(
        self, key, word, doc, z_old, mask, n_wk, n_kd, n_k, hyper,
        num_words_pad: int, knobs: SamplerKnobs,
    ) -> jax.Array:
        raise NotImplementedError(
            f"backend {self.name!r} does not support shard_map cells"
        )

    def resolve_cell_knobs(
        self, knobs: SamplerKnobs, hyper
    ) -> SamplerKnobs:
        """Declare the static per-cell workspace the backend will use.

        Called once by ``make_dist_step`` before tracing: every knob that
        sizes a ``cell_sweep`` workspace (padded row widths, tiles) must
        come back concrete — 0/auto values replaced by the backend's
        static defaults, capacities clamped to K. The default declares no
        workspace (dense backends size everything from the shard blocks
        themselves)."""
        return knobs

    # -- frozen-model serving (repro.serving.lda_engine) -------------------
    native_infer: bool = False
    # names of ``prepare_infer`` aux leaves indexed by word rows along dim
    # 0 (NamedTuple field names). The sharded serving path
    # (``repro.serving.sharded``) uses this declaration to lay the frozen
    # tables out over the mesh's model axis — word-indexed tables shard
    # with the count rows, everything else replicates. Backends whose aux
    # is None or purely topic-indexed leave it empty.
    infer_aux_word_fields: tuple = ()

    def prepare_infer(
        self, n_wk, n_k, hyper, knobs: SamplerKnobs,
        num_words_total: Optional[int] = None,
    ) -> Any:
        """Freeze the trained model into a sampling-ready aux object.

        Called once when a serving engine is built; the result is passed
        back into every ``infer_sweep``. The default needs no tables.

        ``num_words_total`` is the true (unsharded) vocabulary size W for
        any table whose math involves ``W * beta`` — the mesh-capable
        path mirroring ``cell_sweep``'s ``num_words_pad``: under sharded
        serving ``n_wk`` is one shard's padded row block, so its leading
        dim is *not* W. None (single-host) means ``n_wk.shape[0]``."""
        return None

    def infer_sweep(
        self, keys, words, mask, z_old, n_kd, n_wk, n_k, hyper,
        knobs: SamplerKnobs, aux: Any = None,
        num_words_total: Optional[int] = None,
    ) -> jax.Array:
        """One frozen-model CGS sweep over a padded slot batch.

        ``keys`` (B,) per-slot PRNG keys; ``words``/``mask``/``z_old``
        (B, L) padded token rows; ``n_kd`` (B, K) per-slot doc-topic
        counts; ``n_wk``/``n_k`` the frozen trained model. Returns new
        topics (B, L) (padded positions produce garbage the engine masks).

        ``num_words_total`` mirrors ``cell_sweep``'s ``num_words_pad``:
        inside a sharded dispatch ``n_wk`` is the device's word-row block
        and ``words`` are shard-local row ids with ``mask`` true only on
        tokens the shard owns, so the ``W * beta`` denominator must come
        from this argument, never from ``n_wk.shape[0]``. Single-host
        callers omit it. Because per-slot keys are consumed at the full
        (B, L) layout and draws are per-token, a shard that computes the
        whole batch but keeps only its owned tokens draws bit-identically
        to the single-host sweep — the property the sharded serve parity
        test pins (``tests/test_sharded_serving.py``).

        Contract of the *default derivation* (the engine's tests rely on
        it): slot b consumes randomness only from ``keys[b]``, so results
        are independent of batch composition; draws are prefix-stable in
        L (threefry counters are per-token), so growing the bucket pad
        never changes a real token's sample; and it is draw-for-draw
        compatible with the single-doc oracle
        ``repro.core.inference.cgs_infer`` (same conditional, same cdf
        inversion, same key schedule), which the serving tests verify
        bit-exactly. Overrides must keep slot chains *statistically*
        independent AND layout-stable: ``zen_cdf`` inherits both from
        per-slot threefry keys; ``zen_pallas`` gets layout-stability
        from per-token counter-based seeds hashed out of the slot key +
        in-doc position (so it is bit-stable across batch layouts, but
        under its own hash noise — statistically, not bitwise,
        comparable to the oracle; see its docstring).
        """
        return _dense_infer_sweep(
            keys, words, mask, z_old, n_kd, n_wk, n_k, hyper,
            knobs.sampling_method, num_words_total=num_words_total,
        )

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        flags = [
            f for f in ("supports_shard_map", "needs_doc_index",
                        "needs_row_pads", "native_infer")
            if getattr(self, f)
        ]
        return f"<{type(self).__name__} {self.name!r} {' '.join(flags)}>"


def _dense_infer_sweep(
    keys, words, mask, z_old, n_kd, n_wk, n_k, hyper, method: str,
    num_words_total: Optional[int] = None,
) -> jax.Array:
    """Default frozen-model sweep: dense phi rows, doc-side-only exclusion.

    Draw-for-draw identical to one ``cgs_infer`` sweep per slot (cdf
    method): same conditional, same cumsum inversion, and per-slot keys so
    slots are independent. Keep the op sequence in lockstep with
    ``repro.core.inference.cgs_infer`` — tests enforce bit-equality.
    """
    k = hyper.num_topics
    w_total = (n_wk.shape[0] if num_words_total is None
               else num_words_total)
    alpha_k = hyper.alpha_k(n_k)
    denom = n_k.astype(jnp.float32) + w_total * hyper.beta

    def slot(key, w_row, m_row, z_row, nkd_row):
        phi = (n_wk[w_row].astype(jnp.float32) + hyper.beta) / denom[None, :]
        onehot = jax.nn.one_hot(z_row, k, dtype=jnp.int32) * m_row[:, None]
        nkd_excl = (nkd_row[None, :] - onehot).astype(jnp.float32)
        probs = phi * (nkd_excl + alpha_k)
        if method == "gumbel":
            g = jax.random.gumbel(key, probs.shape, dtype=jnp.float32)
            return jnp.argmax(
                jnp.log(jnp.maximum(probs, 1e-30)) + g, -1
            ).astype(jnp.int32)
        cdf = jnp.cumsum(probs, axis=-1)
        u = jax.random.uniform(key, (probs.shape[0], 1))
        return jnp.minimum(
            jnp.sum(cdf < u * cdf[:, -1:], axis=-1), k - 1
        ).astype(jnp.int32)

    return jax.vmap(slot)(keys, words, mask.astype(jnp.int32), z_old, n_kd)


class CellBackend(SamplerBackend):
    """Single-box sweep derived from the per-device cell sweep: the whole
    corpus is one cell, every id is already local, every token is live."""

    supports_shard_map = True

    def resolve_cell_knobs(self, knobs: SamplerKnobs, hyper) -> SamplerKnobs:
        """Padded-row backends (``needs_row_pads``) share one workspace
        declaration: auto widths become the static defaults, clamped to K
        (``fill_cell_row_pads``). Idempotent, so cell sweeps may re-apply
        it defensively for direct callers that skipped resolution."""
        if self.needs_row_pads:
            return fill_cell_row_pads(knobs, hyper.num_topics)
        return knobs

    def sweep(self, state, corpus, hyper, knobs, aux=None):
        key = jax.random.fold_in(state.rng, state.iteration)
        mask = jnp.ones(corpus.word.shape, bool)
        return self.cell_sweep(
            key, corpus.word, corpus.doc, state.topic, mask,
            state.n_wk, state.n_kd, state.n_k, hyper, corpus.num_words,
            knobs,
        )


def chunked_token_map(chunk_fn, key, arrays, token_chunk: int) -> jax.Array:
    """Apply ``chunk_fn((arr0, arr1, ..., subkey)) -> (chunk,)`` over token
    chunks (bounds peak memory; 0/oversized chunk = one whole-sweep call).

    Every ``(E,)`` array in ``arrays`` is reshaped to ``(n, token_chunk)``;
    E must divide evenly."""
    e = arrays[0].shape[0]
    if not token_chunk or token_chunk >= e:
        return chunk_fn(tuple(arrays) + (key,))
    assert e % token_chunk == 0, (e, token_chunk)
    n = e // token_chunk
    keys = jax.random.split(key, n)
    out = jax.lax.map(
        chunk_fn, tuple(a.reshape(n, -1) for a in arrays) + (keys,)
    )
    return out.reshape(e)


def auto_pad(n: jax.Array, multiple: int = 8) -> int:
    """Round a (traced-free) max-nnz up to a lane-friendly multiple."""
    m = int(jax.device_get(n))
    return max(multiple, ((m + multiple - 1) // multiple) * multiple)


def resolve_row_pads(state, knobs: SamplerKnobs) -> SamplerKnobs:
    """Fill max_kw/max_kd = 0 from the current counts (host-side; not for
    use inside jit/shard_map — the distributed path resolves widths via
    ``resolve_dist_row_pads`` / ``resolve_cell_knobs`` instead)."""
    if knobs.max_kw and knobs.max_kd:
        return knobs
    from repro.core.zen_sparse import max_row_nnz

    max_kw = knobs.max_kw or auto_pad(max_row_nnz(state.n_wk))
    max_kd = knobs.max_kd or auto_pad(max_row_nnz(state.n_kd))
    return dataclasses.replace(knobs, max_kw=max_kw, max_kd=max_kd)


# static fallback row widths for padded-sparse cell sweeps when nothing
# data-driven was resolved: shard_map workspaces need concrete shapes, and
# these match the paper's observed row-sparsity regime (K_d smaller than
# K_w; both clamped to K so small-topic runs never over-pad)
DEFAULT_CELL_MAX_KW = 128
DEFAULT_CELL_MAX_KD = 64


def fill_cell_row_pads(
    knobs: SamplerKnobs,
    num_topics: int,
    default_kw: int = DEFAULT_CELL_MAX_KW,
    default_kd: int = DEFAULT_CELL_MAX_KD,
) -> SamplerKnobs:
    """Make the padded-row widths concrete for a cell workspace: 0/auto
    becomes the static default clamped to K (a row never holds more than K
    live topics — wider pads are pure waste, the 'padding explodes'
    failure mode). Explicit nonzero widths are honored untouched so
    resolved single-box pads keep their exact (lane-rounded) shapes."""
    return dataclasses.replace(
        knobs,
        max_kw=knobs.max_kw or min(default_kw, num_topics),
        max_kd=knobs.max_kd or min(default_kd, num_topics),
    )
