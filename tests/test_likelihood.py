"""Log-likelihood / perplexity metrics."""
import jax.numpy as jnp
import numpy as np

from repro.core.init import random_init
from repro.core.likelihood import joint_llh, perplexity, predictive_llh


def test_predictive_llh_finite_and_negative(key, tiny_corpus, tiny_hyper):
    state = random_init(key, tiny_corpus, tiny_hyper)
    llh = float(predictive_llh(state, tiny_corpus, tiny_hyper))
    assert np.isfinite(llh) and llh < 0


def test_chunked_llh_matches(key, tiny_corpus, tiny_hyper):
    state = random_init(key, tiny_corpus, tiny_hyper)
    full = float(predictive_llh(state, tiny_corpus, tiny_hyper))
    e = tiny_corpus.num_tokens
    e4 = e - (e % 4)
    import dataclasses

    from repro.core.types import Corpus

    c4 = Corpus(word=tiny_corpus.word[:e4], doc=tiny_corpus.doc[:e4],
                num_words=tiny_corpus.num_words,
                num_docs=tiny_corpus.num_docs)
    s4 = dataclasses.replace(state, topic=state.topic[:e4],
                             prev_topic=state.prev_topic[:e4],
                             stale_iters=None, same_count=None)
    a = float(predictive_llh(s4, c4, tiny_hyper))
    b = float(predictive_llh(s4, c4, tiny_hyper, token_chunk=e4 // 4))
    np.testing.assert_allclose(a, b, rtol=1e-5)


def test_joint_llh_split(key, tiny_corpus, tiny_hyper):
    """Fig. 7 metric: total == word part + doc part, all finite."""
    state = random_init(key, tiny_corpus, tiny_hyper)
    llh = joint_llh(state, tiny_corpus, tiny_hyper)
    np.testing.assert_allclose(
        float(llh.total), float(llh.word) + float(llh.doc), rtol=1e-6
    )
    assert np.isfinite(float(llh.word)) and np.isfinite(float(llh.doc))


def test_perplexity_definition(key, tiny_corpus, tiny_hyper):
    state = random_init(key, tiny_corpus, tiny_hyper)
    llh = float(predictive_llh(state, tiny_corpus, tiny_hyper))
    ppl = float(perplexity(state, tiny_corpus, tiny_hyper))
    np.testing.assert_allclose(
        ppl, np.exp(-llh / tiny_corpus.num_tokens), rtol=1e-5
    )
    # random assignment perplexity must be below vocab size, above 1
    assert 1.0 < ppl <= tiny_corpus.num_words * 2


def test_llh_improves_with_training(key, tiny_corpus, tiny_hyper):
    from repro.core import LDATrainer, TrainConfig

    tr = LDATrainer(tiny_corpus, tiny_hyper, TrainConfig(algorithm="zen"))
    st = tr.init_state(key)
    l0 = tr.llh(st)
    j0 = tr.llh_split(st)
    for _ in range(10):
        st = tr.step(st)
    assert tr.llh(st) > l0
    j1 = tr.llh_split(st)
    assert float(j1.total) > float(j0.total)
