"""Pallas TPU kernels for the paper's compute hot spots.

* ``zen_sampler``     — fused three-term CGS probability + Gumbel-max topic
  sampling, streaming K tiles through VMEM (the paper's sampling inner loop).
* ``topic_histogram`` — scatter-free signed count-delta histogram via
  rank-one-hot MXU contraction (the paper's count-update step).

Each kernel ships ``ref.py`` pure-jnp oracles (bit-exact for the sampler,
exact integer equality for the histogram) and jitted wrappers in ``ops.py``.
Validation runs in ``interpret=True`` on CPU; Mosaic lowering on real TPUs.
"""
from repro.kernels.ops import topic_histogram, zen_sample  # noqa: F401
