"""Per-arch smoke tests (assignment requirement): reduced config of the same
family, one forward/train step + one decode step on CPU, asserting output
shapes and no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models.model import (
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    prefill_with_cache,
)

ARCHS = list_archs(lm_only=True)


def _batch(cfg, b=2, s=16):
    batch = {
        "tokens": jnp.ones((b, s), jnp.int32),
        "labels": jnp.ones((b, s), jnp.int32),
    }
    if cfg.family == "encdec":
        batch["enc_embeds"] = jnp.ones((b, s, cfg.d_model), cfg.dtype)
    if cfg.mrope:
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None, :, None], (b, s, 3)
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, key):
    cfg = get_config(arch + "-smoke")
    params = init_params(key, cfg)
    b, s = 2, 16
    batch = _batch(cfg, b, s)
    logits, aux = forward(
        params, cfg, tokens=batch["tokens"],
        positions=batch.get("positions"),
        enc_embeds=batch.get("enc_embeds"),
    )
    assert logits.shape == (b, s, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_finite_and_decreases(arch, key):
    from repro.train.optimizer import OptConfig
    from repro.train.train_step import init_train_state, make_train_step

    cfg = get_config(arch + "-smoke")
    st = init_train_state(key, cfg, OptConfig(learning_rate=3e-3))
    step = jax.jit(make_train_step(cfg, OptConfig(learning_rate=3e-3)))
    batch = _batch(cfg)
    losses = []
    for _ in range(4):
        st, m = step(st, batch)
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0]  # overfits a constant batch


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_shapes(arch, key):
    cfg = get_config(arch + "-smoke")
    params = init_params(key, cfg)
    b = 2
    cache = init_cache(cfg, b, 32,
                       s_enc=16 if cfg.family == "encdec" else 0)
    logits, cache2 = decode_step(params, cfg, jnp.zeros((b,), jnp.int32),
                                 cache)
    assert logits.shape == (b, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # repeated decode keeps advancing
    logits, _ = decode_step(params, cfg, jnp.ones((b,), jnp.int32), cache2)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_prefill_decode_consistency(key):
    """Dense fast path: prefill-then-decode logits == full forward logits."""
    cfg = dataclasses.replace(get_config("qwen3-8b-smoke"), dtype="float32")
    params = init_params(key, cfg)
    b, s = 2, 12
    tokens = jax.random.randint(key, (b, s + 1), 0, cfg.vocab_size,
                                dtype=jnp.int32)
    logits_pre, cache = prefill_with_cache(params, cfg, tokens[:, :s], 32)
    dec_logits, _ = decode_step(params, cfg, tokens[:, s], cache)
    full_logits, _ = forward(params, cfg, tokens=tokens)
    np.testing.assert_allclose(
        np.asarray(dec_logits), np.asarray(full_logits[:, s]),
        rtol=2e-3, atol=2e-3,
    )
    # prefill's own last-position logits match the forward at s-1
    np.testing.assert_allclose(
        np.asarray(logits_pre), np.asarray(full_logits[:, s - 1]),
        rtol=2e-3, atol=2e-3,
    )


def test_sliding_window_masks_distant_tokens(key):
    """A gemma3-style local layer cannot see past its window."""
    cfg = dataclasses.replace(
        get_config("gemma3-4b-smoke"), dtype="float32",
        local_global_pattern=0, sliding_window=4, num_layers=2,
    )
    params = init_params(key, cfg)
    s = 12
    t1 = jax.random.randint(key, (1, s), 0, cfg.vocab_size, dtype=jnp.int32)
    t2 = t1.at[:, 0].set((t1[0, 0] + 1) % cfg.vocab_size)  # perturb pos 0
    l1, _ = forward(params, cfg, tokens=t1)
    l2, _ = forward(params, cfg, tokens=t2)
    # last position is > window away from pos 0: logits identical
    np.testing.assert_allclose(
        np.asarray(l1[:, -1]), np.asarray(l2[:, -1]), atol=1e-5
    )
    # a position inside the window of pos 0 must differ
    assert np.abs(np.asarray(l1[:, 2]) - np.asarray(l2[:, 2])).max() > 1e-6


def test_mla_cache_is_latent_sized(key):
    """MiniCPM3's raison d'etre: decode cache stores latents, not full KV."""
    cfg = get_config("minicpm3-4b-smoke")
    cache = init_cache(cfg, 2, 32)
    m = cfg.mla
    assert cache.v is None
    assert cache.k.shape[-1] == m.kv_lora_rank + m.qk_rope_head_dim
    full_kv_dim = 2 * cfg.num_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
    assert cache.k.shape[-1] * cache.k.shape[-2] < full_kv_dim


def test_moe_routes_to_multiple_experts(key):
    cfg = get_config("arctic-480b-smoke")
    params = init_params(key, cfg)
    from repro.models.moe import moe_block

    lp = jax.tree.map(lambda a: a[0], params["layers"])  # layer 0
    x = jax.random.normal(key, (2, 16, cfg.d_model), dtype=jnp.bfloat16)
    y, aux = moe_block(x, lp["moe"], cfg)
    assert y.shape == x.shape
    assert float(aux) > 0  # load-balance + z losses are active


def test_mamba_decode_matches_forward(key):
    """SSM recurrent decode == full-sequence scan on the same prefix."""
    cfg = dataclasses.replace(get_config("falcon-mamba-7b-smoke"),
                              dtype="float32", num_layers=2)
    params = init_params(key, cfg)
    s = 8
    tokens = jax.random.randint(key, (1, s), 0, cfg.vocab_size, jnp.int32)
    full, _ = forward(params, cfg, tokens=tokens)
    cache = init_cache(cfg, 1, s)
    outs = []
    for i in range(s):
        logits, cache = decode_step(params, cfg, tokens[:, i], cache)
        outs.append(np.asarray(logits))
    np.testing.assert_allclose(
        np.stack(outs, 1), np.asarray(full), rtol=3e-3, atol=3e-3
    )
