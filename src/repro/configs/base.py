"""Architecture config schema for the LM zoo + LDA configs.

Every assigned architecture is an ``ArchConfig``; reduced smoke variants are
derived with ``ArchConfig.reduced()``. LDA runs use ``LDAArchConfig``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style)."""

    kv_lora_rank: int = 256
    q_lora_rank: int = 768
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 2.0
    router_z_loss: float = 1e-3
    aux_loss: float = 1e-2
    # group-local dispatch (GShard-style): capacity and the one-hot
    # dispatch/combine einsums are per token-group, so dispatch flops are
    # O(T * ts * ...) instead of O(T^2 * cf / E) — at 1M tokens the global
    # formulation costs more than the experts themselves (§Perf a1)
    group_size: int = 1024


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    version: int = 1  # 1 = Mamba (falcon-mamba), 2 = Mamba2/SSD (zamba2)
    state_dim: int = 16
    conv_dim: int = 4
    expand: int = 2  # d_inner = expand * d_model
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)
    num_heads: int = 0  # mamba2: d_inner // head_dim
    head_dim: int = 64  # mamba2
    chunk: int = 128  # mamba2 SSD chunk length


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    # attention flavor
    qk_norm: bool = False  # qwen3
    qkv_bias: bool = False  # qwen1.5
    rope_theta: float = 10000.0
    rope_theta_global: float = 0.0  # gemma3: different theta on global layers
    sliding_window: int = 0  # 0 = full attention
    local_global_pattern: int = 0  # gemma3: N local then 1 global (N=5)
    mla: Optional[MLAConfig] = None  # minicpm3
    mrope: bool = False  # qwen2-vl (3-component M-RoPE)
    # MoE / SSM / hybrid / enc-dec
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid_attn_every: int = 0  # zamba2: shared attn block period
    encoder_decoder: bool = False  # whisper
    num_encoder_layers: int = 0
    # misc
    norm_style: str = "rmsnorm"  # rmsnorm | layernorm (whisper)
    act: str = "silu"  # silu | gelu
    glu: bool = True  # gated MLP (false for whisper)
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # training
    remat_policy: str = "nothing_saveable"  # nothing_saveable|dots|none
    optimizer: str = "adamw"  # adamw | adafactor (giant MoEs)
    # which shapes this arch supports (DESIGN.md §4 skip rules)
    skip_shapes: Tuple[str, ...] = ()
    # roofline instrumentation: python-loop the layer stacks instead of
    # lax.scan so HLO cost_analysis counts every layer (scan bodies are
    # counted once); used only by shallow fit-compiles, never production
    unroll_layers: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def padded_vocab_size(self) -> int:
        """Embedding-table rows padded to 512 (Megatron-style vocab
        padding) so the vocab dim shards on any production axis; logits
        columns >= vocab_size are masked in the loss / sliced at decode."""
        return ((self.vocab_size + 511) // 512) * 512

    @property
    def is_sub_quadratic(self) -> bool:
        return (
            self.ssm is not None
            or self.hybrid_attn_every > 0
            or self.local_global_pattern > 0
        )

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: same family/flavor, tiny dims."""
        changes = dict(
            num_layers=min(self.num_layers, 4) if not self.hybrid_attn_every
            else 4,
            d_model=128,
            num_heads=max(2, min(4, self.num_heads)),
            num_kv_heads=1 if self.num_kv_heads < self.num_heads else 2,
            head_dim=32,
            d_ff=256,
            vocab_size=512,
        )
        if self.num_kv_heads == self.num_heads:
            changes["num_kv_heads"] = changes["num_heads"]
        if self.mla is not None:
            changes["mla"] = MLAConfig(
                kv_lora_rank=32, q_lora_rank=48,
                qk_nope_head_dim=16, qk_rope_head_dim=16, v_head_dim=16,
            )
            changes["head_dim"] = 32
        if self.moe is not None:
            changes["moe"] = dataclasses.replace(
                self.moe, num_experts=min(self.moe.num_experts, 4), top_k=2
            )
        if self.ssm is not None:
            changes["ssm"] = dataclasses.replace(
                self.ssm,
                state_dim=min(self.ssm.state_dim, 16),
                head_dim=32,
                chunk=16,
            )
        if self.hybrid_attn_every:
            changes["hybrid_attn_every"] = 2
        if self.num_encoder_layers:
            changes["num_encoder_layers"] = 2
        if self.local_global_pattern:
            changes["local_global_pattern"] = min(self.local_global_pattern, 2)
        if self.sliding_window:
            changes["sliding_window"] = 16
        return dataclasses.replace(self, name=self.name + "-smoke", **changes)


@dataclasses.dataclass(frozen=True)
class LDAArchConfig:
    """An LDA training run as a dry-runnable "architecture"."""

    name: str
    num_words: int
    num_topics: int
    docs_per_step: int  # documents resident per iteration (streamed corpus)
    avg_doc_len: int
    algorithm: str = "zen_cdf"
    max_kd: int = 64
    delta_dtype: str = "int32"
    kd_dtype: str = "int32"  # int16 halves every N_kd pass (§Perf l4)

    @property
    def tokens_per_step(self) -> int:
        return self.docs_per_step * self.avg_doc_len


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell: what to lower and with which sizes."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int
