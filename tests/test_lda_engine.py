"""Batched LDA serving engine vs the single-doc ``cgs_infer`` oracle.

The engine's statistical contract (see ``repro/serving/lda_engine.py``):

* default (dense) backend, cdf sampling -> served theta is **bit-equal**
  to ``cgs_infer`` run with the same key, for any bucketing and any batch
  composition;
* native backends (``zen_cdf``, ``zen_pallas``) match the oracle
  statistically (dominant topic + posterior-mean distance);
* bucket padding and batch-mates never change a request's result;
* empty / unknown-vocabulary / over-long documents are handled;
* trained models round-trip through the model checkpoint.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import algorithms
from repro.core.inference import cgs_infer
from repro.core.trainer import LDATrainer, TrainConfig
from repro.core.types import LDAHyperParams
from repro.serving import (
    FrozenLDAModel,
    LDAEngine,
    LDAServeConfig,
    doc_completion_perplexity,
    docs_from_corpus,
)
from repro.train.checkpoint import load_lda_model, save_lda_model


def _sharp_model(k=4, w=40, weight=100):
    """Topics with disjoint vocabulary blocks (same as test_inference)."""
    n_wk = np.zeros((w, k), np.int32)
    block = w // k
    for t in range(k):
        n_wk[t * block : (t + 1) * block, t] = weight
    n_k = n_wk.sum(0).astype(np.int32)
    hyper = LDAHyperParams(num_topics=k, alpha=0.1, beta=0.01)
    return FrozenLDAModel(
        n_wk=jnp.asarray(n_wk), n_k=jnp.asarray(n_k), hyper=hyper
    )


def _mixed_docs(rng, n, w=40, lo=1, hi=24):
    return [
        rng.integers(0, w, size=rng.integers(lo, hi)).astype(np.int32)
        for _ in range(n)
    ]


def _serve_one(model, doc, key, *, buckets, algorithm="zen", num_sweeps=10,
               batch_mates=(), seed=0):
    eng = LDAEngine(
        model,
        LDAServeConfig(buckets=buckets, max_batch=8, num_sweeps=num_sweeps,
                       algorithm=algorithm),
        seed=seed,
    )
    uid = eng.submit(doc, key=key)
    for mate in batch_mates:
        eng.submit(mate)
    return {r.uid: r for r in eng.run_until_done()}[uid].theta


def test_engine_matches_oracle_bitwise():
    """64 mixed-length docs through the batched, bucketed engine in one
    process == cgs_infer per doc, to float tolerance (the chains are
    integer-identical; theta arithmetic is np vs jnp). Every doc's theta
    is checked against the oracle for a subset of docs covering all
    buckets (the eager oracle is the slow side); all 64 are served."""
    model = _sharp_model()
    rng = np.random.default_rng(0)
    docs = _mixed_docs(rng, 64)
    keys = [jax.random.key(100 + i) for i in range(len(docs))]
    eng = LDAEngine(
        model,
        LDAServeConfig(buckets=(8, 16, 32), max_batch=4, num_sweeps=10,
                       algorithm="zen"),
        seed=0,
    )
    uids = [eng.submit(d, key=k) for d, k in zip(docs, keys)]
    done = {r.uid: r for r in eng.run_until_done()}
    assert len(done) == len(docs) and eng.docs_done == 64
    for theta in (done[u].theta for u in uids):
        np.testing.assert_allclose(theta.sum(), 1.0, atol=1e-3)
    for i in range(0, len(docs), 4):
        oracle = np.asarray(
            cgs_infer(keys[i], model.n_wk, model.n_k, jnp.asarray(docs[i]),
                      model.hyper, num_sweeps=10)
        )
        np.testing.assert_allclose(done[uids[i]].theta, oracle, atol=1e-6)


@pytest.mark.parametrize("algorithm", ["zen", "zen_cdf"])
def test_bucket_padding_never_changes_results(algorithm):
    model = _sharp_model()
    doc = np.random.default_rng(2).integers(0, 40, size=10).astype(np.int32)
    key = jax.random.key(42)
    thetas = [
        _serve_one(model, doc, key, buckets=buckets, algorithm=algorithm)
        for buckets in [(16,), (32,), (64, 128)]
    ]
    for theta in thetas[1:]:
        np.testing.assert_array_equal(thetas[0], theta)


@pytest.mark.parametrize("algorithm", ["zen", "zen_cdf"])
def test_batch_composition_never_changes_results(algorithm):
    model = _sharp_model()
    rng = np.random.default_rng(3)
    doc = rng.integers(0, 40, size=9).astype(np.int32)
    key = jax.random.key(7)
    alone = _serve_one(model, doc, key, buckets=(16,), algorithm=algorithm)
    crowded = _serve_one(model, doc, key, buckets=(16,),
                         algorithm=algorithm,
                         batch_mates=_mixed_docs(rng, 5, lo=1, hi=14))
    np.testing.assert_array_equal(alone, crowded)


@pytest.mark.parametrize("algorithm", ["zen_cdf", "zen_pallas"])
def test_native_backends_match_oracle_statistically(algorithm):
    """Native infer_sweep overrides: dominant topic always recovered and
    theta within posterior-mean tolerance of the oracle."""
    model = _sharp_model()
    rng = np.random.default_rng(1)
    docs, doms = [], []
    for i in range(8):
        t = i % 4
        docs.append(
            rng.integers(t * 10, (t + 1) * 10, size=15).astype(np.int32)
        )
        doms.append(t)
    eng = LDAEngine(
        model,
        LDAServeConfig(buckets=(16, 32), max_batch=8, num_sweeps=15,
                       algorithm=algorithm),
        seed=3,
    )
    thetas = eng.infer_batch(docs)
    assert [int(np.argmax(t)) for t in thetas] == doms
    for i in (0, 5):
        oracle = np.mean(
            [
                np.asarray(cgs_infer(jax.random.key(s), model.n_wk,
                                     model.n_k, jnp.asarray(docs[i]),
                                     model.hyper, num_sweeps=15))
                for s in range(6)
            ],
            axis=0,
        )
        assert np.abs(oracle - thetas[i]).sum() < 0.15


def test_zen_pallas_sweeps_stay_random_with_vacant_slots():
    """Regression: the kernel seed must keep changing across sweeps even
    when batch mates finish early and their slots hold the engine's
    constant dummy key (a fixed seed degenerates the chain into an
    iterated deterministic map)."""
    model = _sharp_model()
    rng = np.random.default_rng(11)
    doc = rng.integers(0, 10, size=15).astype(np.int32)  # topic-0 block
    key = jax.random.key(5)
    oracle = np.mean(
        [
            np.asarray(cgs_infer(jax.random.key(s), model.n_wk, model.n_k,
                                 jnp.asarray(doc), model.hyper,
                                 num_sweeps=12))
            for s in range(6)
        ],
        axis=0,
    )
    mate = rng.integers(10, 20, size=8).astype(np.int32)
    for mate_sweeps in (12, 3):  # lockstep mate / mate finishes early
        eng = LDAEngine(
            model,
            LDAServeConfig(buckets=(16,), max_batch=4, num_sweeps=12,
                           algorithm="zen_pallas"),
            seed=0,
        )
        uid = eng.submit(doc, key=key)
        eng.submit(mate, num_sweeps=mate_sweeps)
        theta = {r.uid: r for r in eng.run_until_done()}[uid].theta
        assert int(np.argmax(theta)) == 0
        assert np.abs(oracle - theta).sum() < 0.2


def test_every_registered_backend_serves():
    """The registry contract: every backend serves through the default
    ``infer_sweep`` derivation (overrides or not) with sane output."""
    assert algorithms.get("zen_cdf").native_infer
    assert algorithms.get("zen_pallas").native_infer
    assert not algorithms.get("zen").native_infer
    model = _sharp_model()
    doc = np.arange(10, dtype=np.int32)  # the topic-0 vocabulary block
    for name in algorithms.registered():
        eng = LDAEngine(
            model,
            LDAServeConfig(buckets=(16,), max_batch=2, num_sweeps=6,
                           algorithm=name),
            seed=0,
        )
        theta = eng.infer_batch([doc])[0]
        assert theta.shape == (4,), name
        np.testing.assert_allclose(theta.sum(), 1.0, atol=1e-3, err_msg=name)
        assert int(np.argmax(theta)) == 0, name


def test_edge_cases_empty_unknown_overlong():
    model = _sharp_model()
    eng = LDAEngine(
        model, LDAServeConfig(buckets=(8,), max_batch=2, num_sweeps=5),
        seed=0,
    )
    rng = np.random.default_rng(4)
    u_empty = eng.submit([])
    u_unknown = eng.submit([999, -3, 10_000])
    u_long = eng.submit(rng.integers(0, 40, size=50).astype(np.int32))
    u_mixed = eng.submit([2, 999, 3])  # unknown ids dropped, rest served
    done = {r.uid: r for r in eng.run_until_done()}
    assert set(done) == {u_empty, u_unknown, u_long, u_mixed}
    assert eng.docs_done == 4  # instant-path requests count as served

    prior = np.asarray(model.hyper.alpha_k(model.n_k))
    np.testing.assert_allclose(done[u_empty].theta, prior / prior.sum(),
                               atol=1e-6)
    assert done[u_unknown].dropped_unknown == 3
    np.testing.assert_allclose(done[u_unknown].theta.sum(), 1.0, atol=1e-3)
    assert done[u_long].truncated and done[u_long].words.shape == (8,)
    assert done[u_mixed].dropped_unknown == 1
    assert done[u_mixed].words.tolist() == [2, 3]
    np.testing.assert_allclose(done[u_mixed].theta.sum(), 1.0, atol=1e-3)


def test_zero_sweeps_matches_oracle_init():
    model = _sharp_model()
    doc = np.arange(6, dtype=np.int32)
    key = jax.random.key(9)
    eng = LDAEngine(
        model, LDAServeConfig(buckets=(8,), max_batch=2, num_sweeps=0),
        seed=0,
    )
    uid = eng.submit(doc, key=key)
    theta = {r.uid: r for r in eng.run_until_done()}[uid].theta
    oracle = np.asarray(
        cgs_infer(key, model.n_wk, model.n_k, jnp.asarray(doc), model.hyper,
                  num_sweeps=0)
    )
    np.testing.assert_allclose(theta, oracle, atol=1e-6)


def test_burn_in_thinning_posterior_mean():
    model = _sharp_model()
    rng = np.random.default_rng(5)
    docs = _mixed_docs(rng, 4, lo=6, hi=16)
    eng = LDAEngine(
        model,
        LDAServeConfig(buckets=(16,), max_batch=4, num_sweeps=12, burn_in=4,
                       thin=2),
        seed=1,
    )
    uids = [eng.submit(d) for d in docs]
    done = {r.uid: r for r in eng.run_until_done()}
    for uid in uids:
        req = done[uid]
        assert req.theta_samples == 4  # sweeps 6, 8, 10, 12
        np.testing.assert_allclose(req.theta.sum(), 1.0, atol=1e-3)


def test_queue_overflow_drains():
    """More docs than slots: continuous admission refills freed slots."""
    model = _sharp_model()
    rng = np.random.default_rng(6)
    docs = _mixed_docs(rng, 20, lo=1, hi=14)
    eng = LDAEngine(
        model,
        LDAServeConfig(buckets=(16,), max_batch=3, num_sweeps=4),
        seed=0,
    )
    thetas = eng.infer_batch(docs)
    assert thetas.shape == (20, 4)
    assert eng.docs_done == 20


def test_model_checkpoint_roundtrip(tmp_path, tiny_corpus, tiny_hyper):
    """Trainer -> save_model -> FrozenLDAModel.from_checkpoint -> serve."""
    trainer = LDATrainer(tiny_corpus, tiny_hyper, TrainConfig(
        algorithm="zen", checkpoint_dir=str(tmp_path / "ck"),
    ))
    state = trainer.train(jax.random.key(0), 3)
    n_wk, n_k, hyper, meta, step = load_lda_model(str(tmp_path / "ck"))
    np.testing.assert_array_equal(np.asarray(state.n_wk), n_wk)
    np.testing.assert_array_equal(np.asarray(state.n_k), n_k)
    assert hyper == tiny_hyper and step == 3
    assert meta["algorithm"] == "zen"

    model = FrozenLDAModel.from_checkpoint(str(tmp_path / "ck"))
    docs = docs_from_corpus(tiny_corpus)[:6]
    eng = LDAEngine(
        model, LDAServeConfig(buckets=(32, 64), num_sweeps=5), seed=0,
    )
    thetas = eng.infer_batch(docs)
    assert thetas.shape == (6, tiny_hyper.num_topics)
    np.testing.assert_allclose(thetas.sum(1), 1.0, atol=1e-3)


def test_load_lda_model_missing_or_wrong_kind(tmp_path):
    with pytest.raises(FileNotFoundError):
        load_lda_model(str(tmp_path / "nope"))
    # a non-model checkpoint is rejected, not silently served
    from repro.train.checkpoint import CheckpointManager

    CheckpointManager(str(tmp_path / "lm")).save(0, {"n_k": np.zeros(2),
                                                     "n_wk": np.zeros((3, 2))})
    with pytest.raises(FileNotFoundError):
        load_lda_model(str(tmp_path / "lm"))


def test_save_load_lda_model_direct(tmp_path):
    model = _sharp_model()
    save_lda_model(str(tmp_path), np.asarray(model.n_wk),
                   np.asarray(model.n_k), model.hyper, step=7)
    n_wk, n_k, hyper, _meta, step = load_lda_model(str(tmp_path))
    np.testing.assert_array_equal(n_wk, np.asarray(model.n_wk))
    np.testing.assert_array_equal(n_k, np.asarray(model.n_k))
    assert hyper == model.hyper and step == 7


def test_doc_completion_perplexity_sane():
    """The held-out score prefers the true model over a flat one."""
    model = _sharp_model()
    rng = np.random.default_rng(8)
    docs = [
        rng.integers(t * 10, (t + 1) * 10, size=20).astype(np.int32)
        for t in (0, 1, 2, 3) for _ in range(3)
    ]
    cfg = LDAServeConfig(buckets=(16,), max_batch=8, num_sweeps=10)
    ppl = doc_completion_perplexity(LDAEngine(model, cfg, seed=0), docs)
    flat = FrozenLDAModel(
        n_wk=jnp.ones_like(model.n_wk), n_k=jnp.full_like(model.n_k, 40),
        hyper=model.hyper,
    )
    ppl_flat = doc_completion_perplexity(LDAEngine(flat, cfg, seed=0), docs)
    assert 0 < ppl < ppl_flat
    # sharp model: topic block has 10 live words -> ppl near 10, far from W=40
    assert ppl < 20
