"""One quality evaluator for sessions, compare tables, and benchmarks.

``QualityEval`` owns the corpus-side statistics (built once) and turns a
frozen model snapshot ``(n_wk, n_k)`` into the standard quality record::

    {"coherence_umass", "coherence_npmi", "l2r_llh", "l2r_per_token"}

(the left-to-right keys only when ``l2r_docs > 0``). ``TrainSession``
fires it as the "quality" schedule action on the ``quality_every``
cadence, ``launch/compare.py --sessions`` prints the trajectories, and
``benchmarks/bench_quality.py`` records them per backend into
``BENCH_quality.json`` — so backend/knob choices are judged on quality
curves, not just docs/sec.

Determinism contract: with the same seed everything here is
bit-reproducible — the coherence stats are a pure function of the
corpus, and the left-to-right particles draw from a generator seeded
from ``(seed, iteration, doc)`` so two identical runs produce identical
trajectories (tested per backend in ``tests/test_eval_quality.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.eval.coherence import (
    CoherenceStats,
    npmi_coherence,
    top_topic_words,
    umass_coherence,
)
from repro.eval.left_to_right import left_to_right_llh


@dataclasses.dataclass(frozen=True)
class QualityConfig:
    """Knobs of one quality evaluation (see ``RunConfig`` mirrors)."""

    top_n: int = 10  # words per topic entering the coherence pairs
    npmi_window: int = 10  # sliding-window size (<=0 skips NPMI)
    l2r_docs: int = 0  # held-out docs for left-to-right (0 = skip)
    l2r_particles: int = 20  # particles per document
    l2r_max_len: int = 32  # truncate eval docs to this many tokens
    l2r_seed: int = 0  # base seed of the particle streams


class QualityEval:
    """Reusable evaluator: corpus stats built once, queried per tick."""

    def __init__(self, corpus, hyper, cfg: QualityConfig):
        self.hyper = hyper
        self.cfg = cfg
        self.stats = CoherenceStats.from_corpus(
            corpus, window=max(1, cfg.npmi_window)
        )
        # left-to-right eval docs: the longest-first ``l2r_docs`` doc ids
        # would bias toward heavy docs; take evenly spaced doc ids instead
        # (deterministic, covers the corpus) and truncate long ones
        self._l2r_docs: List[np.ndarray] = []
        if cfg.l2r_docs > 0:
            n = min(cfg.l2r_docs, corpus.num_docs)
            ids = np.linspace(0, corpus.num_docs - 1, n).astype(int)
            for d in ids:
                toks = self.stats.docs[int(d)]
                if len(toks) == 0:
                    continue
                self._l2r_docs.append(toks[: cfg.l2r_max_len])

    def evaluate(self, n_wk: np.ndarray, n_k: np.ndarray,
                 iteration: int = 0) -> Dict[str, float]:
        """Score one frozen model snapshot; returns the quality record."""
        cfg = self.cfg
        n_wk = np.asarray(n_wk)
        n_k = np.asarray(n_k)
        top = top_topic_words(n_wk, cfg.top_n)
        out: Dict[str, float] = {}
        umass, _ = umass_coherence(self.stats, top)
        out["coherence_umass"] = umass
        if cfg.npmi_window > 0:
            npmi, _ = npmi_coherence(self.stats, top)
            out["coherence_npmi"] = npmi
        if self._l2r_docs:
            total = 0.0
            tokens = 0
            for i, toks in enumerate(self._l2r_docs):
                rng = np.random.default_rng(
                    (cfg.l2r_seed, int(iteration), i)
                )
                total += left_to_right_llh(
                    n_wk, n_k, toks, self.hyper,
                    num_particles=cfg.l2r_particles, rng=rng,
                )
                tokens += len(toks)
            out["l2r_llh"] = total
            out["l2r_per_token"] = total / max(1, tokens)
        return out

    @classmethod
    def from_run_config(cls, corpus, hyper, run_cfg,
                        ) -> Optional["QualityEval"]:
        """Build from ``RunConfig`` quality fields; None when disabled."""
        if run_cfg.quality_every <= 0:
            return None
        return cls(corpus, hyper, QualityConfig(
            top_n=run_cfg.quality_top_n,
            npmi_window=run_cfg.quality_npmi_window,
            l2r_docs=run_cfg.quality_l2r_docs,
            l2r_particles=run_cfg.quality_l2r_particles,
        ))
