"""zamba2-1.2b [hybrid]: 38 mamba2 blocks d_model=2048 + shared attention
block (32H) every 6 layers, d_ff=8192, vocab=32000, ssm_state=64.
[arXiv:2411.15242; hf]

Hybrid/SSM -> long_500k RUNS (O(1) mamba state; attention KV only at the
shared blocks).
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    ssm=SSMConfig(version=2, state_dim=64, conv_dim=4, expand=2,
                  head_dim=64, chunk=128),
    hybrid_attn_every=6,
    tie_embeddings=True,
)
