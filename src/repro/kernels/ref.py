"""Pure-jnp oracles for the Pallas kernels (bit-exact where stated)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.zen_sampler import gumbel_noise


def zen_sample_ref(
    nwk_rows: jax.Array,
    nkd_rows: jax.Array,
    z_old: jax.Array,
    alpha_k: jax.Array,
    n_k: jax.Array,
    seed: jax.Array,
    *,
    beta: float,
    w_beta: float,
) -> jax.Array:
    """Bit-exact oracle of ``zen_sample_pallas`` (same hash, same math)."""
    t, k = nwk_rows.shape
    rows = jax.lax.broadcasted_iota(jnp.int32, (t, k), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, k), 1)
    self_hit = (cols == z_old[:, None]).astype(jnp.float32)
    nw = nwk_rows.astype(jnp.float32) - self_hit
    nd = nkd_rows.astype(jnp.float32) - self_hit
    nk = n_k.astype(jnp.float32)[None, :] - self_hit
    a = alpha_k.astype(jnp.float32)[None, :]
    p = (a * beta + nw * a + nd * (nw + beta)) / (nk + w_beta)
    g = gumbel_noise(jnp.asarray(seed, jnp.int32), rows, cols)
    score = jnp.log(jnp.maximum(p, 1e-30)) + g
    return jnp.argmax(score, axis=-1).astype(jnp.int32)


def zen_probs_ref(
    nwk_rows, nkd_rows, z_old, alpha_k, n_k, *, beta: float, w_beta: float
) -> jax.Array:
    """The exact ¬dw conditional the sampler draws from (for statistical
    tests: chi-square of empirical sampling frequencies)."""
    t, k = nwk_rows.shape
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, k), 1)
    self_hit = (cols == z_old[:, None]).astype(jnp.float32)
    nw = nwk_rows.astype(jnp.float32) - self_hit
    nd = nkd_rows.astype(jnp.float32) - self_hit
    nk = n_k.astype(jnp.float32)[None, :] - self_hit
    a = alpha_k.astype(jnp.float32)[None, :]
    p = (a * beta + nw * a + nd * (nw + beta)) / (nk + w_beta)
    return p / jnp.sum(p, axis=-1, keepdims=True)


def zen_infer_sample_ref(
    nwk_rows: jax.Array,
    nkd_rows: jax.Array,
    z_old: jax.Array,
    seeds: jax.Array,
    alpha_k: jax.Array,
    n_k: jax.Array,
    *,
    beta: float,
    w_beta: float,
) -> jax.Array:
    """Bit-exact oracle of ``zen_infer_sample_pallas`` (frozen-model
    serving variant): doc-side-only exclusion, frozen word/topic totals,
    per-token seeds with (seed, topic) noise coordinates."""
    t, k = nwk_rows.shape
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, k), 1)
    self_hit = (cols == z_old[:, None]).astype(jnp.float32)
    nw = nwk_rows.astype(jnp.float32)
    nd = nkd_rows.astype(jnp.float32) - self_hit
    a = alpha_k.astype(jnp.float32)[None, :]
    p = (nd + a) * (nw + beta) / (n_k.astype(jnp.float32)[None, :] + w_beta)
    g = gumbel_noise(
        seeds.astype(jnp.int32)[:, None], jnp.zeros((t, 1), jnp.uint32), cols
    )
    score = jnp.log(jnp.maximum(p, 1e-30)) + g
    return jnp.argmax(score, axis=-1).astype(jnp.int32)


def zen_fused_sample_ref(
    n_wk: jax.Array,
    n_kd: jax.Array,
    word: jax.Array,
    doc: jax.Array,
    z_old: jax.Array,
    alpha_k: jax.Array,
    n_k: jax.Array,
    seed: jax.Array,
    *,
    beta: float,
    w_beta: float,
) -> jax.Array:
    """Bit-exact oracle of ``ops.zen_fused_sample``: gather the rows, then
    run the v1 oracle — the fused kernel's whole claim is that skipping the
    materialized gather changes nothing."""
    return zen_sample_ref(
        n_wk[word], n_kd[doc], z_old, alpha_k, n_k, seed,
        beta=beta, w_beta=w_beta,
    )


def zen_fused_infer_sample_ref(
    n_wk: jax.Array,
    n_kd: jax.Array,
    word: jax.Array,
    slot: jax.Array,
    z_old: jax.Array,
    seeds: jax.Array,
    alpha_k: jax.Array,
    n_k: jax.Array,
    *,
    beta: float,
    w_beta: float,
) -> jax.Array:
    """Bit-exact oracle of ``ops.zen_fused_infer_sample`` (gather + v1
    serving oracle)."""
    return zen_infer_sample_ref(
        n_wk[word], n_kd[slot], z_old, seeds, alpha_k, n_k,
        beta=beta, w_beta=w_beta,
    )


def cdf_row_search_ref(
    counts: jax.Array,
    rows: jax.Array,
    term: jax.Array,
    targets: jax.Array,
    *,
    bk: int = 512,
) -> jax.Array:
    """Tile-accurate oracle of ``ops.cdf_row_search``: same K-tile walk,
    same carry adds, same op order — so float round-off matches the kernel
    bit for bit at the same ``bk``. (A whole-row ``searchsorted`` would be
    the *mathematical* spec but could disagree on round-off at tile
    boundaries; the tiled walk IS the kernel's contract.)"""
    t = rows.shape[0]
    k = counts.shape[1]
    pad = (-k) % bk
    vals = counts[rows].astype(jnp.float32) * term.astype(jnp.float32)[None, :]
    if pad:
        vals = jnp.pad(vals, ((0, 0), (0, pad)))
    tgt = targets.astype(jnp.float32)[:, None]
    acc = jnp.zeros((t,), jnp.float32)
    cnt = jnp.zeros((t,), jnp.int32)
    for j in range(0, k + pad, bk):
        tile = vals[:, j:j + bk]
        cdf = acc[:, None] + jnp.cumsum(tile, axis=1)
        cnt = cnt + jnp.sum((cdf < tgt).astype(jnp.int32), axis=1)
        acc = acc + jnp.sum(tile, axis=1)
    return jnp.minimum(cnt, k - 1)


def sparse_row_sample_ref(
    vals: jax.Array,
    topics: jax.Array,
    targets: jax.Array,
) -> jax.Array:
    """Bit-exact oracle of ``ops.sparse_row_sample``. Lane padding in the
    wrapper is provably inert (weight-0 lanes leave every real prefix sum
    bitwise unchanged and the clamp stops at the last real lane), so the
    oracle needs no padding replication."""
    j = vals.shape[1]
    vals_f = vals.astype(jnp.float32)
    cdf = jnp.cumsum(vals_f, axis=1)
    tgt = targets.astype(jnp.float32)[:, None]
    cnt = jnp.sum((cdf < tgt).astype(jnp.int32), axis=1)
    pos = jnp.minimum(cnt, j - 1)
    return jnp.take_along_axis(
        topics.astype(jnp.int32), pos[:, None], axis=1
    )[:, 0]


def topic_histogram_ref(
    rows: jax.Array,
    z_old: jax.Array,
    z_new: jax.Array,
    inc: jax.Array,
    num_rows: int,
    num_topics: int,
) -> jax.Array:
    """Naive scatter-add oracle of ``topic_histogram_pallas``."""
    out = jnp.zeros((num_rows, num_topics), jnp.int32)
    out = out.at[rows, z_new].add(inc)
    out = out.at[rows, z_old].add(-inc)
    return out
