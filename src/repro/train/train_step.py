"""LM train step: loss + grad + optimizer, microbatched, mesh-aware.

``make_train_step`` returns a jittable ``(state, batch) -> (state, metrics)``
with donated state. Gradient accumulation scans over microbatches (knob for
the memory/throughput trade — §Perf). All sharding comes from in_shardings
at jit time (see ``repro.sharding``): XLA SPMD inserts the DP grad
all-reduce, FSDP all-gathers, and TP collectives from the layout alone.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.model import loss_fn
from repro.train.optimizer import OptConfig, make_optimizer


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jax.Array


def init_train_state(rng: jax.Array, cfg: ArchConfig,
                     opt_cfg: Optional[OptConfig] = None) -> TrainState:
    from repro.models.model import init_params

    opt_cfg = opt_cfg or OptConfig()
    params = init_params(rng, cfg)
    opt_init, _ = make_optimizer(cfg.optimizer, opt_cfg)
    return TrainState(params=params, opt_state=opt_init(params),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(
    cfg: ArchConfig,
    opt_cfg: Optional[OptConfig] = None,
    num_microbatches: int = 1,
):
    opt_cfg = opt_cfg or OptConfig()
    _, opt_update = make_optimizer(cfg.optimizer, opt_cfg)

    def compute_grads(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch), has_aux=True
        )(params)
        return loss, metrics, grads

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        params = state.params
        if num_microbatches == 1:
            loss, metrics, grads = compute_grads(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % num_microbatches == 0
                return x.reshape((num_microbatches, b // num_microbatches)
                                 + x.shape[1:])

            micro = jax.tree.map(split, batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )

            def acc(carry, mb):
                g_acc, l_acc = carry
                loss, _, grads = compute_grads(params, mb)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, grads
                )
                return (g_acc, l_acc + loss), None

            (grads, loss), _ = jax.lax.scan(
                acc, (zeros, jnp.zeros((), jnp.float32)), micro
            )
            grads = jax.tree.map(lambda g: g / num_microbatches, grads)
            loss = loss / num_microbatches
            metrics = {"ce": loss, "aux": jnp.zeros((), jnp.float32)}

        new_params, new_opt, opt_metrics = opt_update(
            params, grads, state.opt_state
        )
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return (
            TrainState(params=new_params, opt_state=new_opt,
                       step=state.step + 1),
            metrics,
        )

    return train_step
