"""Single-box LDA trainer: algorithm selection + the optimization toggles.

This is the "driver program" layer (paper §2.3): pick a sampling algorithm
(zen / zen_sparse / zen_hybrid / sparselda / lightlda / std), pick the
initialization, toggle token exclusion / delta aggregation, and iterate.
The distributed path (``repro.core.distributed``) reuses the same sweep
functions under ``shard_map``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import counts as counts_lib
from repro.core import init as init_lib
from repro.core.baselines import build_doc_index, lightlda_sweep, sparselda_sweep
from repro.core.exclusion import ExclusionConfig, active_mask, update_exclusion_stats
from repro.core.likelihood import joint_llh, perplexity, predictive_llh
from repro.core.sampler import cgs_sweep_stale
from repro.core.types import CGSState, Corpus, LDAHyperParams
from repro.core.zen_sparse import zen_sparse_sweep


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    algorithm: str = "zen"  # zen | zen_sparse | zen_hybrid | sparselda |
    #                         lightlda | std
    init: str = "random"  # random | sparse_word | sparse_doc
    sparse_init_degree: float = 0.1
    sampling_method: str = "cdf"  # cdf | gumbel  (dense paths)
    exclusion: ExclusionConfig = ExclusionConfig()
    max_kw: int = 0  # 0 -> auto from data (padded-sparse paths)
    max_kd: int = 0
    num_mh: int = 8  # LightLDA MH steps (paper uses 8)
    token_chunk: Optional[int] = None


def _auto_pad(n: jax.Array, multiple: int = 8) -> int:
    m = int(jax.device_get(n))
    return max(multiple, ((m + multiple - 1) // multiple) * multiple)


class LDATrainer:
    def __init__(self, corpus: Corpus, hyper: LDAHyperParams, cfg: TrainConfig):
        self.corpus = corpus
        self.hyper = hyper
        self.cfg = cfg
        self._doc_index = None
        if cfg.algorithm == "lightlda":
            self._doc_index = build_doc_index(corpus)

    # -- initialization ----------------------------------------------------
    def init_state(self, rng: jax.Array) -> CGSState:
        c, h = self.corpus, self.hyper
        if self.cfg.init == "random":
            return init_lib.random_init(rng, c, h)
        if self.cfg.init == "sparse_word":
            return init_lib.sparse_word_init(rng, c, h, self.cfg.sparse_init_degree)
        if self.cfg.init == "sparse_doc":
            return init_lib.sparse_doc_init(rng, c, h, self.cfg.sparse_init_degree)
        raise ValueError(self.cfg.init)

    # -- one iteration -----------------------------------------------------
    def _pads(self, state: CGSState):
        from repro.core.zen_sparse import max_row_nnz

        max_kw = self.cfg.max_kw or _auto_pad(max_row_nnz(state.n_wk))
        max_kd = self.cfg.max_kd or _auto_pad(max_row_nnz(state.n_kd))
        return max_kw, max_kd

    def sweep(self, state: CGSState) -> jax.Array:
        c, h, cfg = self.corpus, self.hyper, self.cfg
        alg = cfg.algorithm
        if alg in ("zen", "std"):
            return cgs_sweep_stale(
                state, c, h, method=cfg.sampling_method,
                decomposition=alg, token_chunk=cfg.token_chunk,
            )
        if alg == "zen_sparse":
            max_kw, max_kd = self._pads(state)
            return zen_sparse_sweep(state, c, h, max_kw, max_kd)
        if alg == "zen_hybrid":
            # Hybrid = zen_sparse with the roles of word/doc rows swapped for
            # tokens whose word row is sparser than their doc row. Realized
            # as two-group dispatch so measured work tracks min(K_d, K_w).
            return self._hybrid_sweep(state)
        if alg == "sparselda":
            max_kw, max_kd = self._pads(state)
            return sparselda_sweep(state, c, h, max_kw, max_kd)
        if alg == "lightlda":
            max_kw, _ = self._pads(state)
            return lightlda_sweep(
                state, c, h, self._doc_index, max_kw, num_mh=cfg.num_mh
            )
        raise ValueError(alg)

    def _hybrid_sweep(self, state: CGSState) -> jax.Array:
        """ZenLDAHybrid (§3.1): per-token pick the decomposition whose fresh
        term ranges over the sparser row; here realized by routing tokens to
        the zen sweep (fresh term over K_d) or the sparselda sweep (fresh
        term over K_w) by comparing row nnz."""
        c, h = self.corpus, self.hyper
        max_kw, max_kd = self._pads(state)
        kd_nnz = jnp.sum(state.n_kd > 0, axis=-1)[c.doc]
        kw_nnz = jnp.sum(state.n_wk > 0, axis=-1)[c.word]
        use_zen = kd_nnz <= kw_nnz
        z_zen = zen_sparse_sweep(state, c, h, max_kw, max_kd)
        z_alt = sparselda_sweep(state, c, h, max_kw, max_kd)
        return jnp.where(use_zen, z_zen, z_alt)

    def step(self, state: CGSState) -> CGSState:
        c, h, cfg = self.corpus, self.hyper, self.cfg
        key = jax.random.fold_in(state.rng, 2**20 + state.iteration)
        mask = active_mask(state, cfg.exclusion, key)
        z_new_all = self.sweep(state)
        z_new = jnp.where(mask, z_new_all, state.topic)
        d_wk, d_kd, d_k = counts_lib.delta_counts(
            c.word, c.doc, state.topic, z_new, c.num_words, c.num_docs,
            h.num_topics,
        )
        i_new, t_new = update_exclusion_stats(state, z_new, mask)
        return CGSState(
            topic=z_new,
            prev_topic=state.topic,
            n_wk=state.n_wk + d_wk,
            n_kd=state.n_kd + d_kd,
            n_k=state.n_k + d_k,
            rng=state.rng,
            iteration=state.iteration + 1,
            stale_iters=i_new,
            same_count=t_new,
        )

    # -- metrics -----------------------------------------------------------
    def llh(self, state: CGSState) -> float:
        return float(predictive_llh(state, self.corpus, self.hyper,
                                     token_chunk=self.cfg.token_chunk))

    def llh_split(self, state: CGSState):
        return joint_llh(state, self.corpus, self.hyper)

    def perplexity(self, state: CGSState) -> float:
        return float(perplexity(state, self.corpus, self.hyper,
                                 token_chunk=self.cfg.token_chunk))

    def change_rate(self, state: CGSState) -> float:
        """Fraction of tokens whose topic changed last iteration (Fig. 9a)."""
        return float(jnp.mean((state.topic != state.prev_topic).astype(jnp.float32)))

    # -- training loop with flexible termination (§4.3 utilities) ----------
    def train(
        self,
        rng: jax.Array,
        num_iterations: int,
        state: Optional[CGSState] = None,  # incremental training entry
        llh_every: int = 0,
        callback: Optional[Callable[[CGSState, dict], None]] = None,
        target_perplexity: Optional[float] = None,
    ) -> CGSState:
        if state is None:
            state = self.init_state(rng)
        for it in range(num_iterations):
            state = self.step(state)
            metrics = {}
            if llh_every and (it + 1) % llh_every == 0:
                metrics["llh"] = self.llh(state)
                metrics["change_rate"] = self.change_rate(state)
            if callback is not None:
                callback(state, metrics)
            if target_perplexity is not None and llh_every and metrics:
                if self.perplexity(state) <= target_perplexity:
                    break
        return state
