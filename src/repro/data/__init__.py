from repro.data.corpus import (  # noqa: F401
    load_libsvm,
    save_libsvm,
    skip_libsvm_docs,
    synthetic_corpus,
    synthetic_lda_corpus,
)
from repro.data.stream import (  # noqa: F401
    CorpusSource,
    DriftSource,
    LibsvmStreamSource,
    ReplaySource,
    Window,
    make_source,
)
