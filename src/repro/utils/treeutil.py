"""Pytree helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_param_count(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    total = 0
    for x in jax.tree_util.tree_leaves(tree):
        dt = jnp.dtype(x.dtype) if hasattr(x, "dtype") else jnp.dtype(jnp.float32)
        total += int(np.prod(x.shape)) * dt.itemsize
    return total
