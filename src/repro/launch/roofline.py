"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell, TPU v5e constants:

  compute    = FLOPs_per_device / 197e12            (bf16 MXU peak)
  memory     = HBM_bytes_per_device / 819e9
  collective = collective_bytes_per_device / 50e9   (per-link ICI)

``cost_analysis()`` on the SPMD-partitioned module reports *per-device*
flops/bytes, so terms divide by single-chip peaks (equivalent to the global
formula: global = per-device x chips on both sides).

collective_bytes is not in cost_analysis: ``collective_bytes`` parses the
compiled HLO and sums the result-shape bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op
(while-loop bodies count once per iteration via the trip count when
statically known; scanned layers therefore multiply correctly).
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s/link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of 'f32[8,128]' / tuple '(f32[8], s32[8])' strings."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _line_result_shape(line: str) -> str:
    """The result shape of an HLO instruction line ('%x = SHAPE op(...)')."""
    eq = line.find(" = ")
    if eq < 0:
        return ""
    rest = line[eq + 3 :]
    # result shape is everything up to the op name token
    op = rest.find(" ")
    # tuples contain spaces: find the op name by the first collective token
    return rest


def collective_bytes(compiled: Any) -> float:
    """Per-device bytes moved by collectives in one step, weighted by
    while-loop trip counts where statically known."""
    try:
        text = compiled.as_text()
    except Exception:
        return 0.0
    return collective_bytes_from_text(text)


def _while_trip_counts(text: str) -> Dict[str, int]:
    """computation name -> trip count for statically-bounded while bodies.

    XLA annotates scan-derived loops e.g. 'trip_count=34' in backend_config
    or via known constants; we conservatively look for
    '...while(... ), body=%NAME..., ... trip_count=N' hints. When absent,
    count 1 (documented under-estimate).
    """
    counts: Dict[str, int] = {}
    for m in re.finditer(
        r"body=([%\w.\-]+).*?trip_count[=\":]+(\d+)", text
    ):
        counts[m.group(1).lstrip("%")] = int(m.group(2))
    # known_trip_count style: {"known_trip_count":{"n":"34"}}
    for m in re.finditer(
        r"body=([%\w.\-]+).*?known_trip_count[^\d]*(\d+)", text
    ):
        counts[m.group(1).lstrip("%")] = int(m.group(2))
    return counts


def collective_bytes_from_text(text: str) -> float:
    trip = _while_trip_counts(text)
    total = 0.0
    current_comp = None
    comp_mult: Dict[str, float] = {}
    # build computation multiplier: body computations execute trip_count times
    for name, n in trip.items():
        comp_mult[name] = float(n)
    for line in text.splitlines():
        stripped = line.strip()
        m = re.match(r"%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->", stripped)
        if stripped.endswith("{") and (" " in stripped):
            first = stripped.split()[0].lstrip("%")
            current_comp = first
        if " = " not in stripped:
            continue
        lowered = stripped
        for op in _COLLECTIVES:
            # match op name as the instruction (e.g. ' all-reduce(' or
            # ' all-gather-start(')
            if re.search(rf"\s{op}(-start)?\(", lowered):
                rhs = lowered.split(" = ", 1)[1]
                # result shape = text before the op token
                idx = re.search(rf"\s{op}(-start)?\(", rhs).start()
                shape_str = rhs[:idx]
                nbytes = _shape_bytes(shape_str)
                mult = comp_mult.get(current_comp or "", 1.0)
                total += nbytes * mult
                break
    return total


def memory_summary(mem: Any) -> Optional[Dict[str, float]]:
    """Extract fields from compiled.memory_analysis() defensively (CPU
    backends may not populate everything)."""
    if mem is None:
        return None
    out = {}
    for field in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "peak_memory_in_bytes",
    ):
        v = getattr(mem, field, None)
        if v is not None:
            out[field] = float(v)
    return out or {"repr": str(mem)[:500]}


def roofline_terms(record: Dict[str, Any]) -> Dict[str, float]:
    """The three seconds-valued terms + bottleneck for one dry-run record."""
    compute = record.get("flops_per_device", 0.0) / PEAK_FLOPS
    memory = record.get("bytes_per_device", 0.0) / HBM_BW
    coll = record.get("collective_bytes_per_device", 0.0) / ICI_BW
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": coll}
    terms["bottleneck"] = max(terms, key=lambda k: terms[k])[: -2]
    terms["step_lower_bound_s"] = max(compute, memory, coll)
    return terms


def model_flops(cfg: Any, shape: Any) -> float:
    """MODEL_FLOPS: 6*N*D (dense) / 6*N_active*D (MoE) / sampler-work (LDA)."""
    from repro.configs.base import ArchConfig, LDAArchConfig

    if isinstance(cfg, LDAArchConfig):
        # dense fused sampler: ~4 flops per (token, topic) + O(max_kd) terms
        return cfg.tokens_per_step * (4.0 * cfg.num_topics)
    assert isinstance(cfg, ArchConfig)
    import jax
    import numpy as np

    from repro.launch.specs import params_abstract

    shapes = params_abstract(cfg)
    total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
    n = total
    if cfg.moe is not None:
        e = cfg.moe.num_experts
        # active = non-expert params + top_k/E of expert params
        expert, other = 0, 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
            size = int(np.prod(leaf.shape))
            if any(getattr(p, "key", None) == "moe" for p in path) and leaf.ndim >= 3:
                expert += size
            else:
                other += size
        n = other + expert * cfg.moe.top_k / e
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "decode":
        tokens = shape.global_batch  # one new token per sequence
        return 2.0 * n * tokens  # forward only
    if shape.kind == "prefill":
        return 2.0 * n * tokens
    return 6.0 * n * tokens  # fwd + bwd
