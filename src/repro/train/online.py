"""Windowed online training: a ``TrainSession``-shaped driver over a
:class:`~repro.data.stream.CorpusSource` (DESIGN.md §7).

Batch training materializes the whole corpus and sweeps it per
iteration; this module trains *window by window* instead — the paper's
§3.1 doc-window rotation turned into an ingestion loop. Per window:

1. **compose** — the window's tokens get topic assignments (fresh random
   draws keyed by the window's stream index, or the assignments retained
   from the window's previous visit), and a transient ``CGSState`` is
   built from the resident global ``N_wk``/``N_k`` plus the window-local
   ``N_kd`` block;
2. **sweep** — ``window_sweeps`` CGS iterations through the *unchanged*
   ``SingleBoxPlan`` step (whatever backend the run configures), which
   folds the window's deltas into the composed counts;
3. **retire** — the updated ``N_wk``/``N_k`` become the new global
   model, the window's ``N_kd`` block and token arrays are dropped, so
   resident doc-side state is O(window), never O(corpus).

The ``decay`` knob is the online-CGS forgetting factor: at every window
transition the global counts are scaled by ``(1 - decay)`` (rounded,
``N_k`` re-derived), so old windows' evidence washes out geometrically
and the model tracks a drifting stream. Two regimes fall out:

* ``decay == 0`` over a replaying source — the *rotation* regime: each
  window's assignments are retained (host-side) and reused on its next
  visit, so re-sampling updates counts by exact deltas; a full epoch
  re-samples every token once, which is batch CGS processed
  window-sequentially. The batch-equivalence regression test pins the
  perplexity trend (``tests/test_streaming.py``).
* ``decay > 0`` (or a non-replaying source) — the *streaming* regime:
  every window arrives fresh, folds in once, and is forgotten at the
  decayed rate; nothing per-window is retained anywhere.

Checkpoint/resume: ``train_checkpoint_dir`` stores the global counts,
the window cursor, and any retained assignments (atomic + checksummed via
``CheckpointManager``); ``run()`` auto-resumes from the newest committed
one, and because every window's randomness is keyed by
``fold_in(rng, window.index)`` — never by wall-clock position — a
resumed run is bit-identical to an uninterrupted one. ``checkpoint_dir``
writes the *serving* model artifact on a window cadence, which is the
producing half of the live pipeline: ``LDAEngine.watch_checkpoint_dir``
hot-reloads those checkpoints into a running server.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import counts as counts_lib
from repro.core.likelihood import predictive_llh
from repro.core.types import CGSState, Corpus, LDAHyperParams
from repro.data.stream import CorpusSource, ReplaySource, Window
from repro.train.session import RunConfig, SingleBoxPlan

_STREAM_KIND = "lda_stream"


class StreamingSession:
    """Drive windowed online training from a :class:`CorpusSource`.

    The session surface mirrors :class:`~repro.train.session.TrainSession`
    where it can — ``run(rng, callback)``, ``save_model()``, a metrics
    dict per unit of work — but the unit is a *window*, not a
    full-corpus iteration, and ``cfg.num_iterations`` bounds the
    **absolute window cursor** (0 = run until the source exhausts), so
    resume needs no arithmetic, exactly like the batch session.

    The resident model is ``n_wk (W, K)`` / ``n_k (K,)`` — the same
    arrays a batch run would hold — while doc-side state exists only for
    the window being swept.
    """

    def __init__(self, source: CorpusSource, hyper: LDAHyperParams,
                 cfg: RunConfig):
        if cfg.mesh_shape is not None:
            raise ValueError(
                "StreamingSession is single-box; windowed mesh execution "
                "is a roadmap follow-up (shard the window, not the corpus)"
            )
        if not 0.0 <= cfg.decay < 1.0:
            raise ValueError(f"decay must be in [0, 1), got {cfg.decay}")
        if cfg.window_sweeps <= 0:
            raise ValueError(
                f"window_sweeps must be > 0, got {cfg.window_sweeps}"
            )
        self.source = source
        self.hyper = hyper
        # windows never run the batch-iteration exclusion warmup: the
        # stale/same statistics are meaningless across O(1)-sweep visits
        self.cfg = cfg
        self._window_cfg = dataclasses.replace(
            cfg,
            exclusion_start=0,
            mesh_shape=None,
            # single-box plan default, mirroring TrainSession's resolution
            sampling_method=cfg.sampling_method or "cdf",
        )
        k = hyper.num_topics
        self.n_wk = jnp.zeros((source.num_words, k), jnp.int32)
        self.n_k = jnp.zeros((k,), jnp.int32)
        self.windows_done = 0
        # exact documents consumed — the resume cursor for sources whose
        # final window may be truncated at EOF (supports_doc_resume)
        self.docs_consumed = 0
        # rotation-regime assignment retention (host-side, uid-keyed)
        self._retain = bool(source.replays) and cfg.decay == 0.0
        self._retained: Dict[str, np.ndarray] = {}
        self._plans: Dict[str, SingleBoxPlan] = {}
        self._base_key: Optional[jax.Array] = None
        self._last_model_save: Optional[int] = None
        self._ckpt = None
        if cfg.train_checkpoint_dir:
            from repro.train.checkpoint import CheckpointManager

            self._ckpt = CheckpointManager(cfg.train_checkpoint_dir)

    # -- per-window machinery ----------------------------------------------
    def _plan_for(self, window: Window) -> SingleBoxPlan:
        """A ``SingleBoxPlan`` for the window's corpus — THE reuse point:
        the plan's step is the bit-tested batch step (backend sweep +
        exclusion mask + delta fold), just driven over a window-sized
        corpus. Plans are cached per uid on replaying sources so
        ``backend.prepare`` is paid once per distinct window."""
        if self._retain and window.uid in self._plans:
            return self._plans[window.uid]
        plan = SingleBoxPlan(window.corpus, self.hyper, self._window_cfg)
        if self._retain:
            self._plans[window.uid] = plan
        return plan

    def _apply_decay(self) -> None:
        """Forgetting at a window transition: scale the global counts by
        ``(1 - decay)`` (host-side, rounded to nearest) and re-derive
        ``n_k`` so the model invariant ``n_k == n_wk.sum(0)`` holds
        exactly."""
        if self.cfg.decay <= 0.0:
            return
        scaled = np.rint(
            np.asarray(self.n_wk, np.float64) * (1.0 - self.cfg.decay)
        ).astype(np.int32)
        self.n_wk = jnp.asarray(scaled)
        self.n_k = jnp.asarray(scaled.sum(axis=0).astype(np.int32))

    def run_window(self, window: Window) -> Dict[str, Any]:
        """Sweep one window against the resident model; fold and retire.

        Returns the window's metrics dict: ``llh``/``perplexity`` over
        the window's own tokens under the post-sweep composed counts,
        ``docs_per_sec`` for the visit, and ``resident_kd_bytes`` — the
        doc-side count state this window kept resident (the O(window)
        claim, measured)."""
        if self._base_key is None:
            self._base_key = jax.random.key(0)
        cw = window.corpus
        k = self.hyper.num_topics
        key = jax.random.fold_in(self._base_key, window.index)
        if self.windows_done > 0:
            self._apply_decay()
        t0 = time.perf_counter()
        retained = self._retained.get(window.uid) if self._retain else None
        if retained is None:
            z0 = jax.random.randint(key, (cw.num_tokens,), 0, k,
                                    dtype=jnp.int32)
        else:
            z0 = jnp.asarray(retained, jnp.int32)
        n_wk_w, n_kd_w, n_k_w = counts_lib.build_counts(
            cw.word, cw.doc, z0, cw.num_words, cw.num_docs, k
        )
        if retained is None:
            # first visit: the window's own tokens join the model counts
            n_wk, n_k = self.n_wk + n_wk_w, self.n_k + n_k_w
        else:
            # revisit (rotation regime): the global counts already carry
            # this window's last-visit contribution — re-adding it would
            # double-count; the step's delta fold keeps it exact
            n_wk, n_k = self.n_wk, self.n_k
        zeros = jnp.zeros((cw.num_tokens,), jnp.int32)
        state = CGSState(
            topic=z0, prev_topic=z0, n_wk=n_wk, n_kd=n_kd_w, n_k=n_k,
            rng=key, iteration=0, stale_iters=zeros, same_count=zeros,
        )
        plan = self._plan_for(window)
        for _ in range(self.cfg.window_sweeps):
            state = plan.step(state)
        jax.block_until_ready(state.n_wk)
        dt = time.perf_counter() - t0
        llh = plan.llh(state)
        # retire: the model keeps only N_wk/N_k; doc-side state rolls
        self.n_wk, self.n_k = state.n_wk, state.n_k
        if self._retain:
            self._retained[window.uid] = np.asarray(state.topic)
        self.windows_done = window.index + 1
        self.docs_consumed += cw.num_docs
        return {
            "window": window.index,
            "uid": window.uid,
            "docs": cw.num_docs,
            "tokens": cw.num_tokens,
            "llh": llh,
            "perplexity": math.exp(-llh / max(1, cw.num_tokens)),
            "change_rate": plan.change_rate(state),
            "docs_per_sec": cw.num_docs / dt if dt > 0 else float("inf"),
            "resident_kd_bytes": int(cw.num_docs) * int(k) * 4,
        }

    # -- the loop ------------------------------------------------------------
    def run(
        self,
        rng: Optional[jax.Array] = None,
        callback: Optional[Callable[["StreamingSession", Dict], None]] = None,
    ) -> CGSState:
        """Consume the source from the (possibly restored) cursor.

        ``cfg.num_iterations`` bounds the absolute window cursor (0 =
        until the source exhausts); ``callback(session, metrics)`` fires
        after every window. Returns a host-side summary state carrying
        the final global counts (``n_wk``/``n_k``)."""
        cfg = self.cfg
        if rng is not None:
            self._base_key = rng
        elif self._base_key is None:
            self._base_key = jax.random.key(0)
        self._maybe_restore()
        limit = cfg.num_iterations
        src_kwargs = {}
        if getattr(self.source, "supports_doc_resume", False):
            # resume at the exact document cursor: a file source whose
            # last window was truncated at EOF must neither re-read it
            # nor skip documents appended since
            src_kwargs["start_docs"] = self.docs_consumed
        for window in self.source.windows(start=self.windows_done,
                                          **src_kwargs):
            if limit and window.index >= limit:
                break
            metrics = self.run_window(window)
            if callback is not None:
                callback(self, metrics)
            if self._ckpt is not None and cfg.train_checkpoint_every > 0 \
                    and self.windows_done % cfg.train_checkpoint_every == 0:
                self.save_stream_checkpoint()
            if cfg.checkpoint_dir and cfg.checkpoint_every > 0 \
                    and self.windows_done % cfg.checkpoint_every == 0:
                self.save_model()
        if cfg.checkpoint_dir and self._last_model_save != self.windows_done:
            self.save_model()
        if self._ckpt is not None:
            self.save_stream_checkpoint()
        return self.model_state()

    # -- model surfaces ------------------------------------------------------
    def model_state(self):
        """The resident global model as a tiny namespace with
        ``n_wk``/``n_k`` (what ``FrozenLDAModel.from_state`` wants)."""
        return CGSState(
            topic=jnp.zeros((0,), jnp.int32),
            prev_topic=jnp.zeros((0,), jnp.int32),
            n_wk=self.n_wk,
            n_kd=jnp.zeros((0, self.hyper.num_topics), jnp.int32),
            n_k=self.n_k,
            rng=self._base_key if self._base_key is not None
            else jax.random.key(0),
            iteration=self.windows_done,
        )

    def save_model(self, directory: Optional[str] = None) -> str:
        """Checkpoint the current global model for serving — the same
        artifact ``TrainSession.save_model`` writes, stamped with the
        window cursor as the step so ``LDAEngine.watch_checkpoint_dir``
        sees a monotonically increasing stream of model versions."""
        from repro.train.checkpoint import save_lda_model

        directory = directory or self.cfg.checkpoint_dir
        if not directory:
            raise ValueError("no checkpoint directory configured")
        path = save_lda_model(
            directory,
            np.asarray(jax.device_get(self.n_wk)),
            np.asarray(jax.device_get(self.n_k)),
            self.hyper,
            step=self.windows_done,
            extra_metadata={
                "algorithm": self.cfg.algorithm,
                "stream": True,
                "windows_done": self.windows_done,
                "decay": self.cfg.decay,
            },
        )
        self._last_model_save = self.windows_done
        return path

    # -- stream checkpoints --------------------------------------------------
    def save_stream_checkpoint(self) -> str:
        """Atomic mid-stream checkpoint: global counts + window cursor +
        (rotation regime) every retained assignment array."""
        tree: Dict[str, Any] = {
            "n_wk": np.asarray(jax.device_get(self.n_wk)),
            "n_k": np.asarray(jax.device_get(self.n_k)),
            "cursor": np.asarray(self.windows_done, np.int64),
            "doc_cursor": np.asarray(self.docs_consumed, np.int64),
        }
        for uid, z in self._retained.items():
            tree[f"z:{uid}"] = z
        return self._ckpt.save(
            self.windows_done, tree,
            {"kind": _STREAM_KIND, "cursor": self.windows_done,
             "decay": self.cfg.decay},
        )

    def _maybe_restore(self) -> bool:
        if self._ckpt is None:
            return False
        got = self._ckpt.restore_latest_named()
        if got is None:
            return False
        named, meta, _step = got
        if meta.get("kind") != _STREAM_KIND:
            return False
        self.n_wk = jnp.asarray(named["n_wk"], jnp.int32)
        self.n_k = jnp.asarray(named["n_k"], jnp.int32)
        self.windows_done = int(named["cursor"])
        # pre-doc-cursor checkpoints: assume every window was full (the
        # old arithmetic, still exact unless the run died mid-window)
        self.docs_consumed = int(named.get(
            "doc_cursor", self.windows_done * self.source.window_docs
        ))
        self._retained = {
            name[2:]: np.asarray(arr, np.int32)
            for name, arr in named.items() if name.startswith("z:")
        }
        return True

    # -- rotation-regime evaluation -------------------------------------------
    def assembled_state(self) -> CGSState:
        """Reassemble a full-corpus ``CGSState`` from the retained
        per-window assignments (rotation regime over a
        :class:`ReplaySource` only) — the bridge back to batch-side
        evaluation: the returned state is exactly what a batch run whose
        assignments matched the retained windows would hold."""
        if not isinstance(self.source, ReplaySource) or not self._retain:
            raise ValueError(
                "assembled_state() needs decay=0 over a ReplaySource "
                "(the rotation regime retains assignments)"
            )
        corpus = self.source.corpus
        z = np.zeros(corpus.num_tokens, np.int32)
        for s in range(self.source.windows_per_epoch):
            w = self.source.window_slice(s)
            if w.uid not in self._retained:
                raise ValueError(
                    f"window {w.uid} has no retained assignments yet "
                    f"(cursor {self.windows_done})"
                )
            z[w.token_index] = self._retained[w.uid]
        zt = jnp.asarray(z)
        n_wk, n_kd, n_k = counts_lib.build_counts(
            corpus.word, corpus.doc, zt, corpus.num_words, corpus.num_docs,
            self.hyper.num_topics,
        )
        zeros = jnp.zeros((corpus.num_tokens,), jnp.int32)
        return CGSState(
            topic=zt, prev_topic=zt, n_wk=n_wk, n_kd=n_kd, n_k=n_k,
            rng=self._base_key, iteration=self.windows_done,
            stale_iters=zeros, same_count=zeros,
        )

    def full_perplexity(self) -> float:
        """Whole-corpus perplexity of the assembled state — the number
        the batch-equivalence test compares against a
        ``SingleBoxPlan`` run's perplexity on the same corpus."""
        corpus = self.source.corpus
        state = self.assembled_state()
        llh = float(predictive_llh(state, corpus, self.hyper))
        return math.exp(-llh / corpus.num_tokens)
