"""Shared test utilities.

1. ``run_with_devices`` — subprocess helper for multi-device tests (device
   count locks at first jax init, so distributed tests run in children with
   their own XLA_FLAGS).
2. ``given`` / ``settings`` / ``st`` — re-exports of hypothesis, with a tiny
   deterministic fallback shim when hypothesis is not installed (it is a dev
   dependency, see requirements-dev.txt): the property tests then run a
   fixed number of seeded random examples instead of erroring the whole
   suite at collection.
"""
import functools
import inspect
import os
import random
import subprocess
import sys
import zlib

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# hypothesis, or a seeded-random stand-in with the same surface
# ---------------------------------------------------------------------------

class _Strategy:
    """A draw function wrapped with the bit of hypothesis API the tests use."""

    def __init__(self, draw_fn):
        self._draw = draw_fn

    def example_with(self, rnd: random.Random):
        return self._draw(rnd)


class _StrategiesShim:
    """Deterministic mini-`hypothesis.strategies`: just what the suite needs
    (integers, floats, booleans, lists, composite)."""

    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rnd: rnd.randint(min_value, max_value))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda rnd: rnd.uniform(min_value, max_value))

    @staticmethod
    def booleans():
        return _Strategy(lambda rnd: rnd.random() < 0.5)

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        def draw(rnd):
            n = rnd.randint(min_size, max_size)
            return [elements.example_with(rnd) for _ in range(n)]

        return _Strategy(draw)

    @staticmethod
    def composite(fn):
        def build(*args, **kwargs):
            def draw_outer(rnd):
                return fn(lambda s: s.example_with(rnd), *args, **kwargs)

            return _Strategy(draw_outer)

        return build


def _shim_settings(max_examples=10, deadline=None, **_ignored):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn

    return deco


def _shim_given(*strategies):
    """Run the test body over ``max_examples`` seeded draws. Drawn values
    fill the RIGHTMOST parameters (hypothesis semantics), so pytest fixtures
    on the left keep working; the wrapper's signature hides the drawn params
    from pytest's fixture resolution."""

    def deco(fn):
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        n_drawn = len(strategies)
        drawn_names = [p.name for p in params[len(params) - n_drawn:]]

        @functools.wraps(fn)
        def runner(*args, **kwargs):
            seed = zlib.crc32(fn.__qualname__.encode())
            rnd = random.Random(seed)
            for _ in range(getattr(runner, "_shim_max_examples", 10)):
                drawn = {
                    name: s.example_with(rnd)
                    for name, s in zip(drawn_names, strategies)
                }
                fn(*args, **{**kwargs, **drawn})

        runner.__signature__ = sig.replace(
            parameters=params[: len(params) - n_drawn]
        )
        return runner

    return deco


try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False
    given = _shim_given
    settings = _shim_settings
    st = _StrategiesShim()


def run_with_devices(code: str, n_devices: int = 4, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", code],
        env=env, capture_output=True, text=True, timeout=timeout,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout
