"""Pallas TPU kernels for the paper's compute hot spots.

Kernel suite v1 (PR 1):

* ``zen_sampler``     — fused three-term CGS probability + Gumbel-max topic
  sampling, streaming K tiles through VMEM (the paper's sampling inner loop).
* ``topic_histogram`` — scatter-free signed count-delta histogram via
  rank-one-hot MXU contraction (the paper's count-update step).

Kernel suite v2 (PR 6) — in-register gathers, no HBM intermediates:

* ``fused_gather``    — gather+sample fusion: per-token word/doc row ids ride
  in as scalar-prefetch operands and count rows are tiled straight out of the
  resident matrices, eliminating the ``(T, K)`` gathered-row materialization
  (training + frozen-model serving variants; CuLDA_CGS's fusion on TPU).
* ``cdf_search``      — zen_cdf's term-2 lower-bound search fused with the
  row gather and term multiply as a running-carry count over K tiles.
* ``sparse_row``      — whole-row CDF inversion over the Alg. 2 compact
  ``(T, max_k)`` sentinel-masked rows (SaberLDA-style vectorized sparsity).

Each kernel ships ``ref.py`` pure-jnp oracles (bit-exact, tile-accurate
where the carry order matters) and jitted padding wrappers in ``ops.py``.
Validation runs in ``interpret=True`` on CPU; Mosaic lowering on real TPUs.
Backend dispatch is policy-gated by ``SamplerKnobs.kernels``
(see ``repro.algorithms.base.kernel_dispatch``).
"""
from repro.kernels.ops import (  # noqa: F401
    cdf_row_search,
    sparse_row_sample,
    topic_histogram,
    zen_fused_infer_sample,
    zen_fused_sample,
    zen_infer_sample,
    zen_sample,
)
