"""``lightlda`` — LightLDA (Yuan et al.) cycle Metropolis-Hastings on the
shared substrate (paper §7.2). ``prepare`` builds the CSR doc->token index
that realizes the O(1) doc proposal."""
from __future__ import annotations

from repro.algorithms.base import SamplerBackend, SamplerKnobs
from repro.algorithms.registry import register
from repro.core.baselines import build_doc_index, lightlda_sweep


@register("lightlda")
class LightLDA(SamplerBackend):
    """Alternating word/doc proposals, ``num_mh`` MH steps per token."""

    needs_doc_index = True
    needs_row_pads = True

    def prepare(self, corpus, hyper, knobs: SamplerKnobs):
        return build_doc_index(corpus)

    def sweep(self, state, corpus, hyper, knobs: SamplerKnobs, aux=None):
        assert aux is not None, "lightlda needs prepare()'s doc index"
        return lightlda_sweep(
            state, corpus, hyper, aux, knobs.max_kw, num_mh=knobs.num_mh
        )
