"""LDA serving driver: restore a trained model, serve documents.

Loads the model checkpoint written by ``launch/train.py --checkpoint-dir``
(N_wk/N_k + hyper), builds the bucketed :class:`~repro.serving.LDAEngine`
in either execution mode, and pushes a libsvm stream or a synthetic load
through the async ticket front.

    PYTHONPATH=src python -m repro.launch.serve_lda \
        --checkpoint-dir /tmp/lda_ckpt \
        [--mode throughput|latency] \
        [--corpus path.libsvm | --synthetic-docs 64] \
        [--algorithm zen] [--buckets 32,64,128,256] [--max-batch 32] \
        [--sweeps 10] [--rtlda-sweeps 2] [--burn-in -1] [--thin 1] \
        [--tick-period 0] [--max-slot-wait 0] [--eval] [--show 5] \
        [--mesh-shape 1,2] [--replicas 1] \
        [--autopilot] [--autopilot-window 16] \
        [--metrics-out serve.jsonl] [--pace 0.002]

Every document goes through ``submit_async`` -> ``result``, so the driver
reports per-request latency percentiles (p50/p99 of submit-to-done) next
to throughput (docs/sec, decode dispatches) in both modes — the numbers
DESIGN.md §5.1 trades against each other. ``--tick-period > 0`` runs the
background admission ticker instead of caller-driven ticks. With
``--eval``, also prints the doc-completion held-out perplexity, the
serving-quality number.

``--follow`` turns the driver into the consuming half of the live
pipeline (DESIGN.md §7): a checkpoint watcher polls ``--checkpoint-dir``
every ``--watch-period`` seconds and hot-reloads each new model the
trainer commits (``launch/train.py --stream`` is the producing half); the
query load replays for ``--rounds`` rounds, printing the model versions
each round's requests decoded under.

Scaling flags (DESIGN.md §5.4): ``--mesh-shape 1,m`` serves the model
*sharded* — word rows laid over an m-way device mesh, every bucket sweep
a ``shard_map`` dispatch; ``--replicas n`` fronts n engine replicas with
the load-balancing :class:`~repro.serving.LDARouter` (one ticket
namespace, broadcast reloads). The two compose: each replica decodes
against the sharded model.
"""
import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--checkpoint-dir", required=True,
                    help="model checkpoint dir from train --checkpoint-dir")
    ap.add_argument("--mode", default="throughput",
                    choices=["throughput", "latency"],
                    help="chain CGS sweeps vs the deterministic RT-LDA "
                         "fast path (DESIGN.md §5.1)")
    ap.add_argument("--corpus", default=None,
                    help="libsvm documents to serve (docs are the queries)")
    ap.add_argument("--synthetic-docs", type=int, default=64,
                    help="synthetic query load (when --corpus is not given)")
    ap.add_argument("--synthetic-len", type=int, default=60)
    ap.add_argument("--algorithm", default="zen",
                    help="any registered sampler backend (throughput mode)")
    ap.add_argument("--buckets", default="32,64,128,256",
                    help="comma-separated bucket lengths")
    ap.add_argument("--max-batch", type=int, default=32,
                    help="slots per bucket")
    ap.add_argument("--sweeps", type=int, default=10,
                    help="chain sweeps per request (throughput mode)")
    ap.add_argument("--rtlda-sweeps", type=int, default=2,
                    help="fused deterministic passes (latency mode)")
    ap.add_argument("--burn-in", type=int, default=-1,
                    help="-1 = final-sweep theta; >=0 = posterior mean")
    ap.add_argument("--thin", type=int, default=1)
    ap.add_argument("--sampling-method", default="cdf",
                    choices=["cdf", "gumbel"])
    ap.add_argument("--tick-period", type=float, default=0.0,
                    help="> 0: run the background admission ticker at this "
                         "period (seconds); 0: drive ticks inline")
    ap.add_argument("--max-slot-wait", type=int, default=0,
                    help="ticks a request waits for its preferred bucket "
                         "before spilling into a wider one (0 = never)")
    ap.add_argument("--eval", action="store_true",
                    help="doc-completion held-out perplexity")
    ap.add_argument("--show", type=int, default=5,
                    help="print top topics for the first N docs")
    ap.add_argument("--follow", action="store_true",
                    help="watch --checkpoint-dir and hot-reload every new "
                         "model checkpoint while serving (live pipeline)")
    ap.add_argument("--watch-period", type=float, default=0.5,
                    help="checkpoint poll cadence in seconds (--follow)")
    ap.add_argument("--rounds", type=int, default=1,
                    help="serve the query load this many rounds (pair with "
                         "--follow to observe reloads between rounds)")
    ap.add_argument("--mesh-shape", default=None,
                    help="serve the model sharded over a device mesh, "
                         "e.g. 1,2 (data dim must be 1; throughput mode)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="engine replicas behind the serving router")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write windowed serving telemetry JSONL here")
    ap.add_argument("--autopilot", action="store_true",
                    help="derive tick_period / max_slot_wait / buckets "
                         "from the observed arrival process")
    ap.add_argument("--autopilot-window", type=int, default=0,
                    help="arrivals per telemetry window (0 = default 64); "
                         "smaller windows decide sooner on light loads")
    ap.add_argument("--pace", type=float, default=0.0,
                    help="> 0: open-loop load — sleep this many seconds "
                         "between submits (an arrival process the "
                         "autopilot can measure) instead of submitting "
                         "the whole round at once")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax.numpy as jnp
    import numpy as np

    from repro.data import synthetic_corpus
    from repro.data.corpus import load_libsvm
    from repro.observe import summarize_latencies
    from repro.serving import (
        FrozenLDAModel,
        LDAEngine,
        LDARouter,
        LDAServeConfig,
        doc_completion_perplexity,
        docs_from_corpus,
    )
    from repro.train.checkpoint import load_lda_model

    n_wk, n_k, hyper, _meta, step0 = load_lda_model(args.checkpoint_dir)
    model = FrozenLDAModel(
        n_wk=jnp.asarray(n_wk, jnp.int32),
        n_k=jnp.asarray(n_k, jnp.int32),
        hyper=hyper,
    )
    print(f"model: W={model.num_words} K={model.num_topics} "
          f"tokens={int(np.asarray(model.n_k).sum())} "
          f"step={step0} from {args.checkpoint_dir}")

    if args.corpus:
        corpus = load_libsvm(args.corpus)
    else:
        corpus = synthetic_corpus(args.seed + 1,
                                  num_docs=args.synthetic_docs,
                                  num_words=model.num_words,
                                  avg_doc_len=args.synthetic_len, zipf_a=1.2)
    docs = docs_from_corpus(corpus)

    cfg = LDAServeConfig(
        buckets=tuple(int(b) for b in args.buckets.split(",")),
        max_batch=args.max_batch,
        num_sweeps=args.sweeps,
        burn_in=args.burn_in,
        thin=args.thin,
        algorithm=args.algorithm,
        sampling_method=args.sampling_method,
        mode=args.mode,
        rtlda_sweeps=args.rtlda_sweeps,
        tick_period=args.tick_period,
        max_slot_wait=args.max_slot_wait,
        mesh_shape=(tuple(int(d) for d in args.mesh_shape.split(","))
                    if args.mesh_shape else None),
        metrics_out=args.metrics_out,
        autopilot=args.autopilot,
        autopilot_window=args.autopilot_window,
    )
    engine = LDARouter(model, cfg, replicas=args.replicas, seed=args.seed)
    plan = (f"rtlda_sweeps={cfg.rtlda_sweeps} (deterministic)"
            if args.mode == "latency" else
            f"algorithm={args.algorithm} sweeps={cfg.num_sweeps}")
    print(f"engine: mode={args.mode} {plan} buckets={cfg.buckets} "
          f"max_batch={cfg.max_batch} replicas={args.replicas}")
    if cfg.mesh_shape is not None:
        sharded = engine.model  # ShardedFrozenLDAModel after wrap
        print(f"sharded: {sharded.num_shards} word shards x "
              f"{sharded.words_per_shard} rows "
              f"(W={sharded.num_words} padded to "
              f"{sharded.num_shards * sharded.words_per_shard})")

    # warm every bucket's jit cache (one doc per width) so the latency
    # distribution reflects steady-state serving, not XLA compilation
    engine.warm()

    if args.tick_period > 0:
        engine.start(args.tick_period)
    if args.follow:
        engine.watch_checkpoint_dir(
            args.checkpoint_dir, period=args.watch_period,
            initial_step=step0,
        )

    thetas = []
    for rnd in range(max(1, args.rounds)):
        sweeps0 = engine.sweeps_run
        t0 = time.perf_counter()
        tickets = []
        for d in docs:
            tickets.append(engine.submit_async(d))
            if args.pace > 0:
                time.sleep(args.pace)
        reqs = [engine.request(t) for t in tickets]  # refs survive the reap
        thetas = [engine.result(t) for t in tickets]
        dt = time.perf_counter() - t0

        stats = summarize_latencies(
            (r.t_done - r.t_submit) * 1e3 for r in reqs
        )
        versions = sorted({r.model_version for r in reqs})
        tag = f"round {rnd}  " if args.rounds > 1 else ""
        print(f"{tag}served {len(docs)} docs in {dt:.3f}s "
              f"({len(docs) / dt:.1f} docs/sec, "
              f"{engine.sweeps_run - sweeps0} bucket dispatches)  "
              f"model versions {versions}")
        print(f"latency ms: p50={stats['p50']:.2f} "
              f"p99={stats['p99']:.2f} max={stats['max']:.2f}")
        if args.follow and rnd < args.rounds - 1:
            time.sleep(args.watch_period)

    if args.autopilot:
        # surface where the measured knobs settled (replica 0 speaks for
        # a homogeneous fleet — every replica sees the same process)
        e0 = engine.engines[0] if hasattr(engine, "engines") else engine
        print(f"autopilot: tick_period={e0.tick_period * 1e3:.2f}ms "
              f"max_slot_wait={e0.max_slot_wait} "
              f"buckets={e0.bucket_widths} spills={e0.spills}")
    if args.metrics_out:
        print(f"telemetry: {args.metrics_out}")

    if args.follow:
        engine.stop_watching()
    if args.tick_period > 0:
        engine.stop()

    for i in range(min(args.show, len(docs))):
        top = np.argsort(-thetas[i])[:3]
        pretty = " ".join(f"k{t}:{thetas[i][t]:.3f}" for t in top)
        print(f"doc {i:4d} len {len(docs[i]):4d}  {pretty}")

    if args.eval:
        ppl = doc_completion_perplexity(
            LDAEngine(model, cfg, seed=args.seed + 7), docs
        )
        print(f"doc-completion perplexity: {ppl:.2f}")


if __name__ == "__main__":
    main()
