"""Autopilot policies: windows of metrics in, typed decisions out.

Training side (``TrainAutopilot``): the paper's hybrid argument (§3.2)
says per-token sampling cost is the decomposition's row density — ``K``
dense, ``K_d`` doc-side, ``K_w`` word-side, ``min`` for the hybrid. The
static version of that argument picks a backend once at config time;
the autopilot re-evaluates it on the rebuild cadence against the row-nnz
stats ``TrainTelemetry`` measured from the LIVE counts, and also turns
the same degree stats into padded-row capacity targets (quantile +
slack, lane-rounded) instead of trusting a user's global ``max_kw``/
``max_kd`` guess.

Serving side (``ServeAutopilot``): derive the SLA knobs from the
observed arrival process — tick at a fraction of the median
inter-arrival time (ticking much faster burns CPU on empty admissions,
much slower adds avoidable queueing latency), allow bucket spill when
requests measurably wait at saturated buckets, and re-cut bucket widths
from the measured document-length distribution when the static grid
truncates or wastes.

Both policies are deliberately conservative: relative-change hysteresis
plus a dwell counter, so one noisy window never flips a knob and two
knobs never fight each other tick over tick. Every ``decide`` returns
only the decisions whose application would actually change something.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple


def _lane_round(n: int, multiple: int = 8) -> int:
    n = max(1, int(n))
    return ((n + multiple - 1) // multiple) * multiple


# ---------------------------------------------------------------------------
# typed decisions
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Decision:
    """Base: every decision serializes as one ``kind="decision"`` JSONL
    record carrying its type, payload, and the measured reason."""

    reason: str

    def to_record(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        reason = d.pop("reason")
        return {"kind": "decision", "decision": type(self).__name__,
                "reason": reason, **d}


@dataclasses.dataclass(frozen=True)
class BackendSwitch(Decision):
    """Re-pick the registry backend from measured row sparsity. Applied
    by the session at a rebuild tick — the swap is the same re-jit move
    as a repad (``MeshPlan._build_step``)."""

    backend: str = ""


@dataclasses.dataclass(frozen=True)
class RowRepad(Decision):
    """Set padded-row capacities to measured-degree targets (quantile +
    slack, lane-rounded, clamped to K) instead of a static global
    max-nnz. Applied through the plan's repad machinery."""

    max_kw: int = 0
    max_kd: int = 0


@dataclasses.dataclass(frozen=True)
class ServeRetune(Decision):
    """New serving SLA knobs; ``None`` fields keep the current value.
    Applied by the engine between admission ticks (bucket changes wait
    for every bucket to drain — the hot-reload slot-swap discipline)."""

    tick_period: Optional[float] = None
    max_slot_wait: Optional[int] = None
    buckets: Optional[Tuple[int, ...]] = None


# ---------------------------------------------------------------------------
# training policy
# ---------------------------------------------------------------------------

# per-token cost of each decomposition as a function of measured row
# density (PAPER.md §3.2): which nnz statistic prices one token draw
_DENSE = "dense"
_DOC_SIDE = "doc"
_WORD_SIDE = "word"
_HYBRID = "hybrid"

BACKEND_COST_CLASS: Dict[str, str] = {
    "zen": _DENSE,
    "zen_dense": _DENSE,
    "std": _DENSE,
    "zen_cdf": _DENSE,
    "zen_pallas": _DENSE,
    "zen_sparse": _DOC_SIDE,
    "sparselda": _WORD_SIDE,
    "zen_hybrid": _HYBRID,
    "lightlda": _HYBRID,  # cycle-MH proposals draw from both sides
}


def backend_cost(name: str, mean_kw: float, mean_kd: float,
                 num_topics: int) -> float:
    """Estimated per-token sampling cost (in topic-row entries touched)."""
    klass = BACKEND_COST_CLASS.get(name, _DENSE)
    if klass == _DENSE:
        return float(num_topics)
    if klass == _DOC_SIDE:
        return float(mean_kd)
    if klass == _WORD_SIDE:
        return float(mean_kw)
    return float(min(mean_kw, mean_kd))


class TrainAutopilot:
    """Backend re-pick + row-capacity targets from a telemetry window.

    Args:
        candidates: backend names the switch may choose among (the
            session restricts this to registered backends compatible
            with its plan — e.g. ``supports_shard_map`` on a mesh).
        switch_ratio: only switch when the best candidate's estimated
            cost is below this fraction of the current backend's.
        dwell: decisions to sit out after a switch (hysteresis).
        pad_quantile: which measured row-nnz statistic sets the
            capacity target ("max" never truncates; "p99" trades a
            tail of truncated rows for smaller pads).
        pad_slack: extra topic lanes added above the target before
            lane rounding.
    """

    def __init__(self, candidates: Sequence[str],
                 switch_ratio: float = 0.8, dwell: int = 2,
                 pad_quantile: str = "max", pad_slack: int = 8):
        if not candidates:
            raise ValueError("TrainAutopilot needs at least one candidate")
        if pad_quantile not in ("max", "p99"):
            raise ValueError(f"pad_quantile must be 'max' or 'p99', "
                             f"got {pad_quantile!r}")
        self.candidates = tuple(candidates)
        self.switch_ratio = float(switch_ratio)
        self.dwell = int(dwell)
        self.pad_quantile = pad_quantile
        self.pad_slack = int(pad_slack)
        self._cooldown = 0

    def decide(self, window: Sequence[Dict[str, Any]], *,
               current_backend: str, current_pads: Tuple[int, int],
               num_topics: int,
               pads_tunable: bool = True) -> List[Decision]:
        """Decisions for one rebuild tick (possibly empty).

        ``window`` is ``TrainTelemetry.window()`` — recent ``train_iter``
        records; the LAST record's row stats are the current measured
        state (they come from the live counts, so no averaging is
        needed — each record is already exact at its iteration).
        """
        if self._cooldown > 0:
            self._cooldown -= 1
            return []
        recs = [r for r in window if r.get("kind") == "train_iter"]
        if not recs:
            return []
        last = recs[-1]
        word, doc = last.get("word_rows"), last.get("doc_rows")
        if not word or not doc:
            return []
        mean_kw, mean_kd = float(word["mean"]), float(doc["mean"])
        decisions: List[Decision] = []

        # (a) backend re-pick: cheapest decomposition at measured density
        cur_cost = backend_cost(current_backend, mean_kw, mean_kd,
                                num_topics)
        best = min(
            self.candidates,
            key=lambda n: backend_cost(n, mean_kw, mean_kd, num_topics),
        )
        best_cost = backend_cost(best, mean_kw, mean_kd, num_topics)
        if (best != current_backend
                and best_cost < self.switch_ratio * cur_cost):
            decisions.append(BackendSwitch(
                backend=best,
                reason=(f"measured K_w≈{mean_kw:.1f} K_d≈{mean_kd:.1f} "
                        f"K={num_topics}: {best} costs ~{best_cost:.1f}"
                        f"/token vs {current_backend} ~{cur_cost:.1f}"),
            ))
            self._cooldown = self.dwell

        # (b) row capacities from degree stats: quantile + slack,
        # lane-rounded, clamped to K. Skip entirely when the plan's pads
        # are already auto-resolved (pads_tunable=False).
        if pads_tunable:
            q = self.pad_quantile
            target_kw = min(
                _lane_round(int(word[q]) + self.pad_slack), num_topics)
            target_kd = min(
                _lane_round(int(doc[q]) + self.pad_slack), num_topics)
            if (target_kw, target_kd) != tuple(current_pads):
                decisions.append(RowRepad(
                    max_kw=target_kw, max_kd=target_kd,
                    reason=(f"row-nnz {q}: word={word[q]} doc={doc[q]} "
                            f"(+{self.pad_slack} slack, lane-rounded) vs "
                            f"pads {tuple(current_pads)}"),
                ))
        return decisions


# ---------------------------------------------------------------------------
# serving policy
# ---------------------------------------------------------------------------

class ServeAutopilot:
    """SLA knobs from the observed arrival process, one window at a time.

    Args:
        period_fraction: target ``tick_period`` as a fraction of the
            median inter-arrival time (0.5 = tick twice per arrival:
            admission adds at most ~half an inter-arrival of delay while
            batches still form).
        min_period / max_period: clamp on the derived tick period.
        hysteresis: minimum relative change before a new period applies.
        retune_buckets: whether bucket-width decisions are allowed
            (they wait for a full drain, so latency-sensitive callers
            may prefer them off).
    """

    def __init__(self, period_fraction: float = 0.5,
                 min_period: float = 5e-4, max_period: float = 0.1,
                 hysteresis: float = 0.25, retune_buckets: bool = True):
        self.period_fraction = float(period_fraction)
        self.min_period = float(min_period)
        self.max_period = float(max_period)
        self.hysteresis = float(hysteresis)
        self.retune_buckets = bool(retune_buckets)

    def decide(self, summary: Dict[str, Any], *, tick_period: float,
               max_slot_wait: int,
               buckets: Sequence[int]) -> Optional[ServeRetune]:
        """One closed ``serve_window`` summary in, at most one
        ``ServeRetune`` out (None when every knob is already right)."""
        if summary.get("kind") != "serve_window":
            return None
        new_period = self._derive_period(summary, tick_period)
        new_wait = self._derive_wait(summary, max_slot_wait)
        new_buckets = (self._derive_buckets(summary, buckets)
                       if self.retune_buckets else None)
        if new_period is None and new_wait is None and new_buckets is None:
            return None
        reasons = []
        inter_p50 = summary["interarrival_ms"]["p50"]
        if new_period is not None:
            reasons.append(f"interarrival p50={inter_p50:.2f}ms -> "
                           f"tick {new_period * 1e3:.2f}ms")
        if new_wait is not None:
            reasons.append(f"wait_ticks p90={summary['wait_ticks_p90']}"
                           f" -> max_slot_wait={new_wait}")
        if new_buckets is not None:
            reasons.append(f"doc_len p99={summary['doc_len']['p99']:.0f}"
                           f" -> buckets={list(new_buckets)}")
        return ServeRetune(
            tick_period=new_period, max_slot_wait=new_wait,
            buckets=new_buckets, reason="; ".join(reasons),
        )

    # -- knob derivations ----------------------------------------------------
    def _derive_period(self, summary: Dict[str, Any],
                       current: float) -> Optional[float]:
        inter = summary.get("interarrival_ms", {})
        p50_ms = inter.get("p50")
        if not p50_ms or inter.get("count", 0) < 4:
            return None  # not enough arrivals to estimate a process
        target = p50_ms * 1e-3 * self.period_fraction
        target = min(self.max_period, max(self.min_period, target))
        if current > 0 and abs(target - current) / current < self.hysteresis:
            return None
        return target

    def _derive_wait(self, summary: Dict[str, Any],
                     current: int) -> Optional[int]:
        # requests measurably queue at their preferred bucket: open the
        # spill valve at the observed p90 wait so only the stuck tail
        # spills into wider buckets
        p90 = float(summary.get("wait_ticks_p90") or 0.0)
        if p90 >= 2.0:
            target = max(2, int(p90))
            if target != current:
                return target
        return None

    def _derive_buckets(self, summary: Dict[str, Any],
                        current: Sequence[int]) -> Optional[Tuple[int, ...]]:
        dl = summary.get("doc_len", {})
        if dl.get("count", 0) < 8:
            return None
        p50, p99, mx = dl.get("p50"), dl.get("p99"), dl.get("max")
        if not mx:
            return None
        cur = tuple(sorted(int(b) for b in current))
        truncating = mx > cur[-1]
        wasteful = cur[0] >= 4 * max(1.0, p50)
        if not (truncating or wasteful):
            return None
        widths = sorted({
            _lane_round(p50), _lane_round(p99), _lane_round(mx),
        })
        proposal = tuple(widths)
        if proposal == cur:
            return None
        return proposal
