"""Config registry sanity: exact assigned figures + derived param counts."""
import jax
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_archs, shapes_for
from repro.configs.base import ArchConfig, LDAArchConfig

# param-count bands (B) derived from the arch names
EXPECTED_B = {
    "gemma3-4b": (3.5, 4.5),
    "qwen1.5-4b": (3.5, 4.5),
    "qwen3-8b": (7.5, 8.7),
    "minicpm3-4b": (3.5, 4.5),
    "zamba2-1.2b": (0.9, 1.4),
    "whisper-medium": (0.6, 0.9),
    "grok-1-314b": (290, 330),
    "arctic-480b": (450, 500),
    "falcon-mamba-7b": (6.5, 7.7),
    "qwen2-vl-2b": (1.3, 2.2),
}


def test_ten_archs_plus_lda():
    archs = list_archs()
    assert len([a for a in archs if not a.startswith("zenlda")]) == 10
    assert "zenlda-nytimes" in archs and "zenlda-webchunk" in archs


@pytest.mark.parametrize("arch", list(EXPECTED_B))
def test_param_counts_match_names(arch):
    cfg = get_config(arch)
    from repro.launch.specs import params_abstract

    shapes = params_abstract(cfg)
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes)) / 1e9
    lo, hi = EXPECTED_B[arch]
    assert lo <= n <= hi, (arch, n)


def test_assigned_figures_exact():
    g = get_config("gemma3-4b")
    assert (g.num_layers, g.d_model, g.num_heads, g.num_kv_heads,
            g.d_ff, g.vocab_size) == (34, 2560, 8, 4, 10240, 262144)
    assert g.local_global_pattern == 5
    q = get_config("qwen3-8b")
    assert q.qk_norm and q.num_kv_heads == 8 and q.d_ff == 12288
    a = get_config("arctic-480b")
    assert a.moe.num_experts == 128 and a.moe.top_k == 2
    assert a.moe.dense_residual
    gk = get_config("grok-1-314b")
    assert gk.moe.num_experts == 8 and gk.d_ff == 32768
    f = get_config("falcon-mamba-7b")
    assert f.ssm.version == 1 and f.ssm.state_dim == 16 and f.d_ff == 0
    z = get_config("zamba2-1.2b")
    assert z.ssm.version == 2 and z.ssm.state_dim == 64
    v = get_config("qwen2-vl-2b")
    assert v.mrope and v.num_kv_heads == 2
    w = get_config("whisper-medium")
    assert w.encoder_decoder and w.norm_style == "layernorm"
    m = get_config("minicpm3-4b")
    assert m.mla is not None and m.num_layers == 62
    q15 = get_config("qwen1.5-4b")
    assert q15.qkv_bias and q15.num_kv_heads == 20


def test_shape_skip_rules():
    """long_500k only for sub-quadratic archs (DESIGN.md §4)."""
    for arch in list_archs(lm_only=True):
        cfg = get_config(arch)
        runs_long = "long_500k" in shapes_for(cfg)
        assert runs_long == cfg.is_sub_quadratic, arch
    # the three expected archs
    assert set(
        a for a in list_archs(lm_only=True)
        if "long_500k" in shapes_for(get_config(a))
    ) == {"gemma3-4b", "zamba2-1.2b", "falcon-mamba-7b"}


def test_cell_count():
    """40 assigned cells = 10 archs x 4 shapes; skips are documented,
    runnable cells = 33 + 2 LDA."""
    total = 0
    runnable = 0
    for arch in list_archs(lm_only=True):
        cfg = get_config(arch)
        total += 4
        runnable += len(shapes_for(cfg))
    assert total == 40
    assert runnable == 33


def test_smoke_configs_are_small():
    for arch in list_archs(lm_only=True):
        cfg = get_config(arch + "-smoke")
        assert cfg.d_model <= 128 and cfg.vocab_size <= 512
        assert cfg.family == get_config(arch).family
