"""Depth-fit roofline costs: recover true per-step FLOPs/bytes/collectives.

``cost_analysis`` on a scanned module counts the scan body ONCE (XLA while
loops have no static trip weighting), so full-depth compiles understate
compute by ~L x. Fix: compile shallow *unrolled* variants (2-3 depths, same
widths/batch) and linear-fit

    cost(L) = fixed + L * per_layer            (uniform stacks)
    cost    = fixed + G * per_group + R * per_unit   (patterned/hybrid)

then evaluate at the production depth. Every point is a real 512-device
compile of the same program modulo depth; the fit is exact for costs that
are affine in depth (layer compute, optimizer elementwise work, per-layer
collectives — all are).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict

import jax
import numpy as np

from repro.configs import SHAPES, get_config
from repro.configs.base import ArchConfig


def _cell_costs(cfg: ArchConfig, shape_name: str, mesh) -> Dict[str, float]:
    """Compile one (possibly shallow) variant and return raw per-device
    costs."""
    from repro.launch import roofline
    from repro.launch.dryrun import build_step
    from repro.launch.specs import lm_cell_specs

    shape = SHAPES[shape_name]
    kind, inputs, shardings = lm_cell_specs(cfg, shape, mesh)
    step = build_step(cfg, kind)
    in_sh = tuple(shardings[k] for k in inputs)
    out_sh = (shardings["state"], None) if kind == "train" else None
    t0 = time.time()
    donate = (0,) if kind == "train" else ((2,) if kind == "decode" else ())
    compiled = (
        jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                donate_argnums=donate)
        .lower(*inputs.values())
        .compile()
    )
    cost = compiled.cost_analysis() or {}
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": roofline.collective_bytes(compiled),
        "compile_s": time.time() - t0,
    }


def _depth_variant(cfg: ArchConfig, num_layers: int) -> ArchConfig:
    changes: Dict[str, Any] = {"num_layers": num_layers, "unroll_layers": True}
    if cfg.family == "encdec":
        changes["num_encoder_layers"] = num_layers
    return dataclasses.replace(cfg, **changes)


def fit_cell(arch: str, shape_name: str, mesh) -> Dict[str, Any]:
    """Fitted per-device costs for the production depth of ``arch``."""
    cfg = get_config(arch)
    assert isinstance(cfg, ArchConfig)
    out: Dict[str, Any] = {"arch": arch, "shape": shape_name, "points": {}}

    def rec(tag, c):
        out["points"][tag] = c

    if cfg.local_global_pattern or cfg.hybrid_attn_every:
        group = (
            cfg.local_global_pattern + 1
            if cfg.local_global_pattern
            else cfg.hybrid_attn_every
        )
        c1 = _cell_costs(_depth_variant(cfg, group), shape_name, mesh)
        c2 = _cell_costs(_depth_variant(cfg, 2 * group), shape_name, mesh)
        c3 = _cell_costs(_depth_variant(cfg, group + 1), shape_name, mesh)
        rec(f"L{group}", c1), rec(f"L{2*group}", c2), rec(f"L{group+1}", c3)
        n_groups = cfg.num_layers // group
        rem = cfg.num_layers - n_groups * group
        fitted = {}
        for key in ("flops", "bytes", "coll"):
            per_group = c2[key] - c1[key]
            per_unit = c3[key] - c1[key]  # one trailing local/mamba layer
            fixed = c1[key] - per_group
            fitted[key] = fixed + n_groups * per_group + rem * per_unit
        out["fitted"] = fitted
    else:
        c1 = _cell_costs(_depth_variant(cfg, 2), shape_name, mesh)
        c2 = _cell_costs(_depth_variant(cfg, 4), shape_name, mesh)
        rec("L2", c1), rec("L4", c2)
        fitted = {}
        for key in ("flops", "bytes", "coll"):
            per_layer = (c2[key] - c1[key]) / 2.0
            fixed = c1[key] - 2.0 * per_layer
            fitted[key] = fixed + cfg.num_layers * per_layer
        out["fitted"] = fitted
    out["flops_per_device"] = out["fitted"]["flops"]
    out["bytes_per_device"] = out["fitted"]["bytes"]
    out["collective_bytes_per_device"] = out["fitted"]["coll"]
    return out
