"""Self-tuning autopilot: decide from measured windows (DESIGN.md §8).

``repro.observe`` measures; this package decides. The policies consume
windows of telemetry records and emit *typed decisions* — they never
touch an engine or a plan themselves. Actuation stays with the owner of
the safety contract: ``TrainSession``'s ``autopilot`` schedule action
applies training decisions at rebuild ticks (the same re-jit move as a
repad), and ``LDAEngine`` applies serving decisions atomically between
admission ticks (the same slot-swap discipline as hot reload).
"""
from repro.autotune.policy import (  # noqa: F401
    BackendSwitch,
    Decision,
    RowRepad,
    ServeAutopilot,
    ServeRetune,
    TrainAutopilot,
)
