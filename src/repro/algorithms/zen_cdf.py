"""``zen_cdf`` — the TPU-native faithful ZenLDA backend (moved here from
``core.distributed``).

Per-iteration precomputed CDFs replace alias tables (log K binary-search
gathers beat alias-table random gathers on TPU), the fresh dSparse term runs
over top-``max_kd`` sparse doc rows (O(K_d) gathers per token, the paper's
complexity), and staleness in gDense/wSparse is remedied by the paper's
resampling trick (§3.1).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.algorithms.base import CellBackend, SamplerKnobs, kernel_dispatch
from repro.algorithms.registry import register
from repro.algorithms.zen_dense import _searchsorted_rows
from repro.core.decompositions import precompute_zen_terms

# sparse doc-row width when the config leaves max_kd = 0 (auto): inside
# shard_map the width must be static, so auto means this default.
DEFAULT_MAX_KD = 64


def _bsearch_gather(
    mat: jax.Array,  # (R, K) row-wise ascending CDFs
    rows: jax.Array,  # (T,) row id per query
    targets: jax.Array,  # (T,)
) -> jax.Array:
    """True O(log K) lower-bound per query: one scalar gather per halving
    step, never materializing (T, K). This is the TPU rendering of the
    paper's BSearch samplers (Table 1)."""
    k = mat.shape[1]
    pos = jnp.zeros(rows.shape, jnp.int32)
    step = 1 << (k - 1).bit_length()
    while step > 0:
        cand = pos + step
        safe = jnp.minimum(cand - 1, k - 1)
        vals = mat[rows, safe]
        take = (cand <= k) & (vals < targets)
        pos = jnp.where(take, cand, pos)
        step //= 2
    return jnp.minimum(pos, k - 1)


def _bsearch_shared(cdf: jax.Array, targets: jax.Array) -> jax.Array:
    """Lower-bound of each target in one shared ascending CDF (K,)."""
    return jnp.minimum(
        jnp.searchsorted(cdf, targets).astype(jnp.int32), cdf.shape[0] - 1
    )


def zen_cdf_cell(
    key, word_l, doc_l, z_old, mask, n_wk_l, n_kd_l, n_k, hyper,
    num_words_pad: int, max_kd: int,
    use_kernel: bool = False, bt: int = 256, bk: int = 512,
):
    """TPU-native faithful ZenLDA: precomputed CDFs + sparse doc rows.

    Work per token: O(log K) (terms 1-2) + O(max_kd) (term 3); per-iteration
    precompute: two passes over the local N_w|k block.

    ``use_kernel`` routes the term-2 draw through the fused CDF-search
    kernel (``kernels.cdf_search``): the ``(Ws, K)`` float ``w_cdf`` matrix
    becomes a matvec for the branch masses and the per-token search fuses
    gather + term-multiply + lower-bound inside the kernel. Same
    lower-bound semantics, different float summation order than the
    whole-row cumsum — distribution-equal, not bit-equal, to the XLA path
    (zen_cdf's cross-path contract is statistical; see DESIGN.md §2.3).
    """
    k = hyper.num_topics
    terms = precompute_zen_terms(n_k, hyper, num_words_pad)

    # --- per-iteration precompute (the "build tables" stage, Alg. 2 l.5-13)
    g_cdf = jnp.cumsum(terms.g_dense)  # (K,)
    m1 = g_cdf[-1]
    if use_kernel:
        # branch masses only — no (Ws, K) float CDF matrix in HBM
        n_wk_i = n_wk_l.astype(jnp.int32)
        m2_all = n_wk_l.astype(jnp.float32) @ terms.t4  # (Ws,)
        w_cdf = None
    else:
        w_vals = n_wk_l.astype(jnp.float32) * terms.t4[None, :]  # (Ws, K)
        w_cdf = jnp.cumsum(w_vals, axis=-1)
        m2_all = w_cdf[:, -1]  # (Ws,)
    # sparse doc rows: top-max_kd topics by count. approx_max_k lowers to
    # the TPU PartialReduce unit (one pass over the block); exact top_k
    # lowers to a full row sort (§Perf iteration l2)
    kd_cnt, kd_idx = jax.lax.approx_max_k(
        n_kd_l.astype(jnp.float32), min(max_kd, k), recall_target=0.95
    )
    kd_cnt = kd_cnt.astype(jnp.int32)

    # --- per-token terms
    rows_idx = kd_idx[doc_l]  # (T, max_kd)
    rows_cnt = kd_cnt[doc_l]
    nwk_at = n_wk_l[word_l[:, None], rows_idx]  # (T, max_kd) gathers
    d_vals = (
        rows_cnt.astype(jnp.float32)
        * (nwk_at.astype(jnp.float32) + hyper.beta)
        * terms.t1[rows_idx]
    )
    d_vals = jnp.where(rows_cnt > 0, d_vals, 0.0)
    d_cdf = jnp.cumsum(d_vals, axis=-1)
    m3 = d_cdf[:, -1]
    m2 = m2_all[word_l]

    def draw(key):
        ku, kr = jax.random.split(key)
        u = jax.random.uniform(ku, word_l.shape) * (m1 + m2 + m3)
        # term 1: shared global CDF (replaces gTable) — O(log K)
        z_g = _bsearch_shared(g_cdf, u)
        # term 2: per-word CDF row (replaces wTable) — O(log K) scalar
        # gathers per token; the dense form gathered (T, K) rows (31 GB at
        # webchunk scale — §Perf iteration l1)
        t2_target = jnp.maximum(u - m1, 0.0)
        if use_kernel:
            from repro.kernels.ops import cdf_row_search

            z_w = cdf_row_search(
                n_wk_i, word_l, terms.t4, t2_target, bt=bt, bk=bk
            )
        else:
            z_w = _bsearch_gather(w_cdf, word_l, t2_target)
        # term 3: doc sparse row CDF (paper's dSparse + BSearch) — rows are
        # only max_kd wide, dense compare is the cheaper form here
        t3_target = jnp.maximum(u - m1 - m2, 0.0)
        pos = _searchsorted_rows(d_cdf, t3_target)
        z_d = jnp.take_along_axis(rows_idx, pos[:, None], -1)[:, 0]
        branch = jnp.where(u < m1, 0, jnp.where(u < m1 + m2, 1, 2))
        z = jnp.where(branch == 0, z_g, jnp.where(branch == 1, z_w, z_d))
        return jnp.minimum(z, k - 1).astype(jnp.int32), branch

    key_a, key_b, key_r = jax.random.split(key, 3)
    z1, branch = draw(key_a)
    z2, _ = draw(key_b)

    # resampling remedy (§3.1) for the staleness of terms 2 and 3
    nw_prev = jnp.maximum(
        n_wk_l[word_l, z_old].astype(jnp.float32), 1.0
    )
    nd_prev = jnp.maximum(
        n_kd_l[doc_l, z_old].astype(jnp.float32), 1.0
    )
    p_w = 1.0 / nw_prev
    p_d = jnp.clip(1.0 / nd_prev + (nd_prev + nw_prev - 1.0) / (nd_prev * nw_prev), 0.0, 1.0)
    remedy_p = jnp.where(branch == 1, p_w, jnp.where(branch == 2, p_d, 0.0))
    u_r = jax.random.uniform(key_r, z1.shape)
    return jnp.where((z1 == z_old) & (u_r < remedy_p), z2, z1)


class FrozenCdfTables(NamedTuple):
    """Sampling-ready frozen model: the per-word prior-term CDFs.

    Because the model never moves while serving, the per-iteration "build
    tables" stage of training (Alg. 2 l.5-13) collapses to a one-time
    precompute: ``a_cdf[w]`` is the cumulative of the doc-independent term
    alpha_k * (N_w|k + beta) * t1, so branch-1 draws are O(log K) scalar
    gathers per token for the engine's whole lifetime.
    """

    a_cdf: jax.Array  # (W, K) f32 row-wise CDF of the prior term
    a_mass: jax.Array  # (W,) f32 row masses
    t1: jax.Array  # (K,) f32 1 / (N_k + W*beta)
    alpha_k: jax.Array  # (K,) f32


def zen_cdf_infer_sweep(
    keys, words, mask, z_old, n_kd, n_wk, n_k, hyper,
    max_kd: int, tables: FrozenCdfTables,
):
    """Frozen-model sweep via the two-branch CDF decomposition.

    With phi frozen the Eq. 3 conditional splits into a doc-independent
    prior term (precomputed per-word CDFs, branch 1) and the sparse doc
    term over the slot's at-most-L live topics (branch 2):

        p(k) = [alpha_k + N_k|d^(-t)] * (N_w|k + beta) * t1

    Randomness is drawn per slot (``keys[b]`` -> one uniform per token
    position), so slots are independent and draws are prefix-stable in the
    bucket pad.
    """
    b, l = words.shape
    k = hyper.num_topics
    kd = min(max_kd, k)

    # sparse doc rows: exact top-kd per slot (serving docs hold <= L live
    # topics; exact top_k keeps the engine's oracle comparison clean)
    kd_cnt, kd_idx = jax.lax.top_k(n_kd, kd)  # (B, kd)

    slot = jax.lax.broadcasted_iota(jnp.int32, (b, l), 0).reshape(-1)
    w = words.reshape(-1)
    z = z_old.reshape(-1)
    live = mask.reshape(-1)

    rows_idx = kd_idx[slot]  # (BL, kd)
    rows_cnt = kd_cnt[slot]
    # exact doc-side ¬t exclusion: drop the token's own current assignment
    self_hit = (rows_idx == z[:, None]) & live[:, None]
    rows_cnt = rows_cnt - self_hit.astype(rows_cnt.dtype)
    nwk_at = n_wk[w[:, None], rows_idx].astype(jnp.float32)
    d_vals = (
        rows_cnt.astype(jnp.float32)
        * (nwk_at + hyper.beta)
        * tables.t1[rows_idx]
    )
    d_vals = jnp.where(rows_cnt > 0, d_vals, 0.0)
    d_cdf = jnp.cumsum(d_vals, axis=-1)
    m_d = d_cdf[:, -1]
    m_a = tables.a_mass[w]

    # one uniform per token, drawn from the token's *slot* key
    u01 = jax.vmap(lambda kk: jax.random.uniform(kk, (l,)))(keys).reshape(-1)
    u = u01 * (m_a + m_d)
    z_a = _bsearch_gather(tables.a_cdf, w, jnp.minimum(u, m_a))
    pos = _searchsorted_rows(d_cdf, jnp.maximum(u - m_a, 0.0))
    z_d = jnp.take_along_axis(rows_idx, pos[:, None], -1)[:, 0]
    z_new = jnp.where(u < m_a, z_a, z_d)
    return jnp.minimum(z_new, k - 1).astype(jnp.int32).reshape(b, l)


@register("zen_cdf")
class ZenCdf(CellBackend):
    """Precomputed-CDF ZenLDA; works single-box (one cell) and sharded."""

    native_infer = True
    # the frozen tables' word-indexed leaves: under sharded serving the
    # per-word CDF rows live with the word shard; t1/alpha_k replicate
    infer_aux_word_fields = ("a_cdf", "a_mass")

    def resolve_cell_knobs(self, knobs: SamplerKnobs, hyper):
        return dataclasses.replace(
            knobs,
            max_kd=min(knobs.max_kd or DEFAULT_MAX_KD, hyper.num_topics),
        )

    def cell_sweep(
        self, key, word, doc, z_old, mask, n_wk, n_kd, n_k, hyper,
        num_words_pad, knobs: SamplerKnobs,
    ):
        return zen_cdf_cell(
            key, word, doc, z_old, mask, n_wk, n_kd, n_k, hyper,
            num_words_pad, knobs.max_kd or DEFAULT_MAX_KD,
            use_kernel=kernel_dispatch(knobs.kernels),
            bt=knobs.bt, bk=knobs.bk,
        )

    def prepare_infer(self, n_wk, n_k, hyper, knobs: SamplerKnobs,
                      num_words_total=None):
        # sharded builds pass the true W: n_wk is then one shard's row
        # block, and the t1 denominator must still be N_k + W*beta
        w_total = (n_wk.shape[0] if num_words_total is None
                   else num_words_total)
        alpha_k = hyper.alpha_k(n_k)
        t1 = 1.0 / (n_k.astype(jnp.float32) + w_total * hyper.beta)
        a_vals = (n_wk.astype(jnp.float32) + hyper.beta) * (alpha_k * t1)
        a_cdf = jnp.cumsum(a_vals, axis=-1)
        return FrozenCdfTables(
            a_cdf=a_cdf, a_mass=a_cdf[:, -1], t1=t1, alpha_k=alpha_k
        )

    def infer_sweep(
        self, keys, words, mask, z_old, n_kd, n_wk, n_k, hyper,
        knobs: SamplerKnobs, aux=None, num_words_total=None,
    ):
        if aux is None:
            aux = self.prepare_infer(n_wk, n_k, hyper, knobs,
                                     num_words_total=num_words_total)
        return zen_cdf_infer_sweep(
            keys, words, mask, z_old, n_kd, n_wk, n_k, hyper,
            knobs.max_kd or DEFAULT_MAX_KD, aux,
        )
