"""Tile autotuner for the kernel suite (ISSUE 6 tentpole (d)).

The Pallas kernels expose three tiling knobs — ``bt`` (token rows per
tile), ``bk`` (topic lanes per tile), ``bs`` (sparse-row lane alignment)
— whose best values depend on K, the row widths, and the part (VMEM size,
DMA latency) far more than on the corpus. Rather than guess, the
autotuner times the real kernels on a caller-supplied workload across a
small tile grid and hands back a ``SamplerKnobs`` with the winners
(``apply_best``), which flows through the normal ``knobs_from`` plumbing
— the sweep result IS a config, not a side channel.

Timings are wall-clock medians over jitted calls (``block_until_ready``);
on CPU the kernels run in interpret mode, so absolute numbers are only
meaningful on a real TPU — the benchmark harness records both regimes,
labeled (``benchmarks/bench_kernels.py``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterable, List, Optional, Sequence

import jax

from repro.algorithms.base import SamplerKnobs


@dataclasses.dataclass(frozen=True)
class TileTiming:
    """One timed (kernel, tile config) point. ``bk`` is 0 for the sparse
    kernel (it has no topic tiling), ``bs`` is 0 for the K-tiled kernels."""

    kernel: str  # fused_sample | fused_infer | cdf_search | sparse_row
    bt: int
    bk: int
    bs: int
    us_per_call: float
    tokens_per_sec: float


def _time_call(fn, iters: int, warmup: int) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def autotune_fused(
    n_wk, n_kd, word, doc, z_old, alpha_k, n_k, seed,
    *,
    beta: float,
    w_beta: float,
    bts: Sequence[int] = (128, 256),
    bks: Sequence[int] = (256, 512),
    iters: int = 3,
    warmup: int = 1,
    interpret: Optional[bool] = None,
) -> List[TileTiming]:
    """Sweep (bt, bk) over the fused gather+sample training kernel."""
    from repro.kernels.ops import zen_fused_sample

    t = word.shape[0]
    out = []
    for bt in bts:
        for bk in bks:
            us = _time_call(
                lambda: zen_fused_sample(
                    n_wk, n_kd, word, doc, z_old, alpha_k, n_k, seed,
                    beta=beta, w_beta=w_beta, bt=bt, bk=bk,
                    interpret=interpret,
                ),
                iters, warmup,
            )
            out.append(TileTiming("fused_sample", bt, bk, 0, us, t / us * 1e6))
    return out


def autotune_cdf(
    counts, rows, term, targets,
    *,
    bts: Sequence[int] = (128, 256),
    bks: Sequence[int] = (256, 512),
    iters: int = 3,
    warmup: int = 1,
    interpret: Optional[bool] = None,
) -> List[TileTiming]:
    """Sweep (bt, bk) over the CDF lower-bound search kernel."""
    from repro.kernels.ops import cdf_row_search

    t = rows.shape[0]
    out = []
    for bt in bts:
        for bk in bks:
            us = _time_call(
                lambda: cdf_row_search(
                    counts, rows, term, targets, bt=bt, bk=bk,
                    interpret=interpret,
                ),
                iters, warmup,
            )
            out.append(TileTiming("cdf_search", bt, bk, 0, us, t / us * 1e6))
    return out


def autotune_sparse(
    vals, topics, targets,
    *,
    bts: Sequence[int] = (128, 256),
    bss: Sequence[int] = (128, 256),
    iters: int = 3,
    warmup: int = 1,
    interpret: Optional[bool] = None,
) -> List[TileTiming]:
    """Sweep (bt, bs) over the padded-sparse row kernel. ``bs`` widens the
    lane pad of the compact rows, standing in for the ``max_kw``-style
    row-width axis of the sweep (the padded width is what the kernel
    actually streams)."""
    from repro.kernels.ops import sparse_row_sample

    t = vals.shape[0]
    out = []
    for bt in bts:
        for bs in bss:
            us = _time_call(
                lambda: sparse_row_sample(
                    vals, topics, targets, bt=bt, bs=bs, interpret=interpret,
                ),
                iters, warmup,
            )
            out.append(TileTiming("sparse_row", bt, 0, bs, us, t / us * 1e6))
    return out


def apply_best(
    timings: Iterable[TileTiming], knobs: SamplerKnobs
) -> SamplerKnobs:
    """Fold a sweep's winners into a ``SamplerKnobs``.

    Per-kernel argmin of ``us_per_call``; the K-tiled kernels set
    ``bt``/``bk``, the sparse kernel sets ``bs``. When both families were
    swept, the K-tiled winner owns ``bt`` (the fused sampler dominates
    sweep cost; the sparse kernel's bt sensitivity is second-order).
    Validation in ``SamplerKnobs.__post_init__`` re-checks the winners, so
    a sweep can never smuggle in an illegal tile.
    """
    best = {}
    for tt in timings:
        cur = best.get(tt.kernel)
        if cur is None or tt.us_per_call < cur.us_per_call:
            best[tt.kernel] = tt
    updates = {}
    sparse = best.pop("sparse_row", None)
    if sparse is not None:
        updates["bs"] = sparse.bs
        updates["bt"] = sparse.bt
    if best:  # any K-tiled kernel: fused_sample / fused_infer / cdf_search
        win = min(best.values(), key=lambda tt: tt.us_per_call)
        updates["bt"] = win.bt
        updates["bk"] = win.bk
    return dataclasses.replace(knobs, **updates) if updates else knobs
