"""Model-quality trajectories per backend: coherence + held-out llh.

The quality-scenario counterpart of the docs/sec benchmarks (ISSUE:
backend/knob choices must be judged on quality curves, not just
throughput). For each backend, one ``TrainSession`` run on a shared
synthetic corpus records the eval + quality trajectory — llh,
perplexity, UMass/NPMI coherence over the top-N words, and Wallach
left-to-right held-out llh per token — via the session's own schedule
actions (``eval_every`` / ``quality_every``). Emits CSV rows through
the run.py contract plus ``BENCH_quality.json`` for CI:

    PYTHONPATH=src:. python benchmarks/run.py --only quality

Scale knobs (env, for CI-sized runs): BENCH_QUALITY_D (docs),
BENCH_QUALITY_W (vocab), BENCH_QUALITY_K (topics), BENCH_QUALITY_ITERS
(iterations), BENCH_QUALITY_EVERY (eval cadence), BENCH_QUALITY_BACKENDS
(comma list, default "zen,zen_sparse").
"""
from __future__ import annotations

import json
import os

from benchmarks.common import bench_out_path, row

NUM_DOCS = int(os.environ.get("BENCH_QUALITY_D", 200))
NUM_WORDS = int(os.environ.get("BENCH_QUALITY_W", 300))
NUM_TOPICS = int(os.environ.get("BENCH_QUALITY_K", 16))
ITERS = int(os.environ.get("BENCH_QUALITY_ITERS", 12))
EVERY = int(os.environ.get("BENCH_QUALITY_EVERY", 4))
BACKENDS = os.environ.get("BENCH_QUALITY_BACKENDS", "zen,zen_sparse")


def main() -> None:
    import time

    import jax

    from repro.core.types import LDAHyperParams
    from repro.data import synthetic_lda_corpus
    from repro.train.session import RunConfig, TrainSession

    corpus, _phi = synthetic_lda_corpus(
        seed=0, num_docs=NUM_DOCS, num_words=NUM_WORDS,
        num_topics=NUM_TOPICS, avg_doc_len=40,
    )
    hyper = LDAHyperParams(num_topics=NUM_TOPICS)
    records = []
    for backend in [b for b in BACKENDS.split(",") if b]:
        cfg = RunConfig(
            algorithm=backend, num_iterations=ITERS,
            eval_every=EVERY, quality_every=EVERY,
            quality_l2r_docs=4, quality_l2r_particles=10,
        )
        session = TrainSession(corpus, hyper, cfg)
        traj = []

        def cb(st, m):
            if "llh" in m or "coherence_umass" in m:
                traj.append({
                    "iteration": int(st.iteration),
                    **{k: m[k] for k in (
                        "llh", "perplexity", "coherence_umass",
                        "coherence_npmi", "l2r_llh", "l2r_per_token",
                    ) if k in m},
                })

        t0 = time.perf_counter()
        session.run(jax.random.key(0), callback=cb)
        dt = time.perf_counter() - t0
        last = traj[-1] if traj else {}
        row(f"quality/{backend}", dt / max(1, ITERS) * 1e6,
            f"umass={last.get('coherence_umass', float('nan')):.3f} "
            f"npmi={last.get('coherence_npmi', float('nan')):.3f} "
            f"l2r_tok={last.get('l2r_per_token', float('nan')):.3f} "
            f"ppl={last.get('perplexity', float('nan')):.1f}")
        records.append({
            "name": backend, "iters": ITERS, "topics": NUM_TOPICS,
            "docs": NUM_DOCS, "trajectory": traj,
        })

    with open(bench_out_path("BENCH_quality.json"), "w") as f:
        json.dump(records, f, indent=2)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
