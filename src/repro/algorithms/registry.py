"""The sampler-backend registry (DESIGN.md §4).

Adding a CGS algorithm to the whole system — trainer, distributed mesh,
launch CLIs, benchmarks — is one module that subclasses ``SamplerBackend``
and decorates it with ``@register("name")``. Every driver resolves names
through ``get()``, so there is exactly one dispatch point.
"""
from __future__ import annotations

from typing import Dict, List, Tuple, Type

from repro.algorithms.base import SamplerBackend

# name -> backend instance. Aliases map to the *same* instance, so
# get("zen_pallas") is get("zen_dense_kernel") — one registry entry.
_REGISTRY: Dict[str, SamplerBackend] = {}
_PRIMARY: List[str] = []  # registration order, aliases excluded


def register(name: str, *aliases: str):
    """Class decorator: instantiate the backend and register it under
    ``name`` (listed by ``registered()``) plus any legacy aliases."""

    def deco(cls: Type[SamplerBackend]) -> Type[SamplerBackend]:
        # validate every name before inserting any, so a collision can't
        # leave the registry half-populated
        for n in (name,) + aliases:
            if n in _REGISTRY:
                raise ValueError(f"sampler backend {n!r} already registered")
        instance = cls()
        instance.name = name
        for n in (name,) + aliases:
            _REGISTRY[n] = instance
        _PRIMARY.append(name)
        return cls

    return deco


def get(name: str) -> SamplerBackend:
    """Resolve an algorithm name; unknown names raise with the full list."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown sampler backend {name!r}; registered backends: "
            f"{', '.join(sorted(_REGISTRY))}"
        ) from None


def registered() -> Tuple[str, ...]:
    """Primary backend names in registration order (aliases excluded)."""
    return tuple(_PRIMARY)


def describe() -> List[Tuple[str, SamplerBackend, Tuple[str, ...]]]:
    """(primary name, backend, aliases) for every entry — CLI listings."""
    out = []
    for name in _PRIMARY:
        b = _REGISTRY[name]
        aliases = tuple(
            n for n, inst in _REGISTRY.items() if inst is b and n != name
        )
        out.append((name, b, aliases))
    return out
