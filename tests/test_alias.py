"""Alias tables + F+ tree: exactness and distribution properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from helpers import given, settings, st  # hypothesis, or the fallback shim

from repro.core.alias import (
    alias_pmf,
    build_alias,
    build_alias_counts,
    ftree_build,
    ftree_sample,
    ftree_total,
    ftree_update,
    sample_alias,
    sample_alias_reuse,
)


@settings(max_examples=25, deadline=None)
@given(
    st.lists(st.floats(0.0, 100.0), min_size=2, max_size=200),
    st.integers(0, 10),
)
def test_alias_pmf_exact(probs, seed):
    """The realized table pmf equals the input pmf (Vose exactness)."""
    p = np.asarray(probs, np.float32)
    if p.sum() == 0:
        p[0] = 1.0
    table = build_alias(jnp.asarray(p))
    np.testing.assert_allclose(
        np.asarray(alias_pmf(table)), p / p.sum(), atol=3e-5
    )


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 1000), min_size=2, max_size=100))
def test_alias_counts_integer_exact(counts):
    """Paper §5.3 integer alias: exactly rational, zero float drift."""
    c = np.asarray(counts, np.int64)
    if c.sum() == 0:
        c[0] = 5
    prob_num, alias, total = build_alias_counts(c)
    k = c.shape[0]
    num = prob_num.copy()
    spill = np.zeros(k, np.int64)
    np.add.at(spill, alias, total - prob_num)
    # realized pmf numerators over k*total must equal c * k * total / sum
    realized = num + spill
    expected = c * k  # both over denominator k*total after scaling by sum
    np.testing.assert_array_equal(realized * c.sum() // total, expected * c.sum() // total)
    np.testing.assert_allclose(realized / (k * total), c / c.sum(), atol=1e-12)


def test_alias_sampling_distribution(key):
    p = np.asarray([0.5, 0.0, 0.2, 0.05, 0.25], np.float32)
    table = build_alias(jnp.asarray(p))
    n = 200_000
    k1, k2 = jax.random.split(key)
    s = sample_alias(
        table, jax.random.uniform(k1, (n,)), jax.random.uniform(k2, (n,))
    )
    emp = np.bincount(np.asarray(s), minlength=5) / n
    np.testing.assert_allclose(emp, p, atol=5e-3)
    assert emp[1] == 0.0  # zero-probability topic never sampled


def test_alias_sampling_reuse_single_uniform(key):
    """§5.3 random-number reuse: one uniform for bin + split."""
    p = np.asarray([0.3, 0.3, 0.4], np.float32)
    table = build_alias(jnp.asarray(p))
    s = sample_alias_reuse(table, jax.random.uniform(key, (200_000,)))
    emp = np.bincount(np.asarray(s), minlength=3) / 200_000
    np.testing.assert_allclose(emp, p, atol=5e-3)


def test_ftree_sample_and_update(key, rng):
    p = rng.gamma(1.0, size=37).astype(np.float32)
    t = ftree_build(jnp.asarray(p))
    np.testing.assert_allclose(float(ftree_total(t)), p.sum(), rtol=1e-5)
    u = jnp.asarray(rng.random(150_000).astype(np.float32))
    emp = np.bincount(np.asarray(ftree_sample(t, u)), minlength=37) / 150_000
    np.testing.assert_allclose(emp, p / p.sum(), atol=6e-3)
    # O(log K) update
    t2 = ftree_update(t, jnp.int32(5), jnp.float32(10.0))
    p2 = p.copy()
    p2[5] = 10.0
    emp2 = np.bincount(np.asarray(ftree_sample(t2, u)), minlength=37) / 150_000
    np.testing.assert_allclose(emp2, p2 / p2.sum(), atol=6e-3)


def test_alias_jit_and_vmap():
    """Table build is jittable and vmappable (per-word wTables)."""
    ps = jnp.asarray(np.random.default_rng(1).gamma(0.5, size=(16, 64)),
                     jnp.float32)
    tables = jax.jit(jax.vmap(build_alias))(ps)
    pmfs = jax.vmap(alias_pmf)(tables)
    np.testing.assert_allclose(
        np.asarray(pmfs), np.asarray(ps / ps.sum(1, keepdims=True)), atol=3e-5
    )
