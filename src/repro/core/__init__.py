"""ZenLDA core — the paper's primary contribution in JAX.

Layers:
  types/counts            state + count-matrix maintenance
  decompositions          the CGS formula decompositions (paper Table 1)
  alias                   Vose alias tables + F+ tree samplers
  sampler                 dense vectorized sweeps (oracle + TPU dense path)
  zen_sparse              faithful padded-sparse ZenLDA (Alg. 2)
  baselines               SparseLDA / LightLDA on the same substrate
  init/exclusion          sparse initialization, converged-token exclusion
  likelihood/inference    metrics + RT-LDA serving inference
  hyper/compactvector     topic dedup, CompactVector (Alg. 4)
  graph/distributed       partitioning (DBH+) + multi-device iteration
  trainer                 deprecated single-box shims (LDATrainer)

Algorithm dispatch lives one level up in ``repro.algorithms``: every CGS
sampler (including the fused Pallas kernel) is a registered
``SamplerBackend`` resolved through ``algorithms.get(name)`` (DESIGN.md
§4). The *driver* lives in ``repro.train.session`` (DESIGN.md §6): a
``TrainSession`` + declarative ``RunConfig`` runs both the single-box and
the mesh plan behind one schedule-driven interface; ``LDATrainer`` /
``TrainConfig`` below are thin deprecation shims over it.
"""
from repro.core.types import CGSState, Corpus, LDAHyperParams  # noqa: F401
from repro.core.trainer import LDATrainer, TrainConfig  # noqa: F401
