"""Faithful padded-sparse ZenLDA sampler (paper Alg. 2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.decompositions import precompute_zen_terms
from repro.core.init import random_init
from repro.core.types import LDAHyperParams
from repro.core.zen_sparse import (
    build_tables,
    densify_rows,
    lookup_rows,
    max_row_nnz,
    sparsify_rows,
    zen_sample_tokens,
    zen_sparse_sweep,
)


def test_sparsify_roundtrip(rng):
    dense = jnp.asarray(rng.integers(0, 3, (20, 17)), jnp.int32)
    m = int(max_row_nnz(dense))
    rows = sparsify_rows(dense, m)
    np.testing.assert_array_equal(np.asarray(densify_rows(rows)),
                                  np.asarray(dense))


def test_lookup_rows(rng):
    dense = jnp.asarray(rng.integers(0, 4, (10, 23)), jnp.int32)
    rows = sparsify_rows(dense, int(max_row_nnz(dense)))
    rids = jnp.asarray(rng.integers(0, 10, (6,)), jnp.int32)
    topics = jnp.asarray(rng.integers(0, 23, (6, 5)), jnp.int32)
    got = lookup_rows(rows, rids, topics)
    expect = np.asarray(dense)[np.asarray(rids)[:, None], np.asarray(topics)]
    np.testing.assert_array_equal(np.asarray(got), expect)


def test_term_masses_equal_dense_sum(key, tiny_corpus, tiny_hyper):
    """m1 + m2[w] + m3[token] == sum_k of the stale dense ZenLDA p —
    the two-level sampler draws from exactly the decomposed mass."""
    state = random_init(key, tiny_corpus, tiny_hyper)
    max_kw = int(max_row_nnz(state.n_wk))
    max_kd = int(max_row_nnz(state.n_kd))
    tables = build_tables(
        state.n_wk, state.n_kd, state.n_k, tiny_hyper,
        tiny_corpus.num_words, max_kw, max_kd,
    )
    from repro.core.decompositions import zen_probs
    from repro.core.zen_sparse import _d_sparse

    terms = precompute_zen_terms(state.n_k, tiny_hyper, tiny_corpus.num_words)
    p_dense = zen_probs(
        state.n_wk[tiny_corpus.word], state.n_kd[tiny_corpus.doc], terms,
        tiny_hyper.beta,
    )
    d_vals, _ = _d_sparse(tables, tiny_corpus.word, tiny_corpus.doc,
                          tiny_hyper.beta)
    total_sparse = (
        tables.terms.g_mass
        + tables.w_mass[tiny_corpus.word]
        + jnp.sum(d_vals, axis=-1)
    )
    np.testing.assert_allclose(
        np.asarray(total_sparse), np.asarray(jnp.sum(p_dense, -1)), rtol=1e-4
    )


def test_sweep_samples_valid_topics(key, tiny_corpus, tiny_hyper):
    state = random_init(key, tiny_corpus, tiny_hyper)
    z = zen_sparse_sweep(state, tiny_corpus, tiny_hyper, max_kw=48, max_kd=48)
    z = np.asarray(z)
    assert z.min() >= 0 and z.max() < tiny_hyper.num_topics


def test_sweep_distribution_matches_dense(key, tiny_corpus, tiny_hyper):
    """Empirical topic histogram of the sparse sampler tracks the dense
    stale ZenLDA sampler (same decomposition, different machinery)."""
    from repro.core.sampler import cgs_sweep_stale

    state = random_init(key, tiny_corpus, tiny_hyper)
    z_sparse = zen_sparse_sweep(state, tiny_corpus, tiny_hyper, 48, 48)
    z_dense = cgs_sweep_stale(state, tiny_corpus, tiny_hyper,
                              exclude_self=False)
    h1 = np.bincount(np.asarray(z_sparse), minlength=tiny_hyper.num_topics)
    h2 = np.bincount(np.asarray(z_dense), minlength=tiny_hyper.num_topics)
    assert np.abs(h1 - h2).sum() < 0.15 * tiny_corpus.num_tokens


def test_convergence(key, tiny_corpus, tiny_hyper):
    from repro.core import LDATrainer, TrainConfig
    from repro.core.likelihood import predictive_llh

    tr = LDATrainer(tiny_corpus, tiny_hyper,
                    TrainConfig(algorithm="zen_sparse"))
    st = tr.init_state(key)
    llh0 = tr.llh(st)
    for _ in range(8):
        st = tr.step(st)
    st.check_invariants(tiny_corpus)
    assert tr.llh(st) > llh0
