"""Paper Fig. 10: redundant-computation elimination (Alg. 5).

The eliminated version precomputes t1..t5/gDense once per iteration as
K-vectors; the naive version recomputes alpha_k and the 1/(N_k+W*beta)
denominators inside the per-token probability. Paper reports ~11%."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.core.decompositions import precompute_zen_terms, zen_probs
from repro.core.init import random_init
from repro.core.types import LDAHyperParams
from repro.data import synthetic_lda_corpus


def main():
    corpus, _ = synthetic_lda_corpus(
        5, num_docs=500, num_words=800, num_topics=128, avg_doc_len=60
    )
    hyper = LDAHyperParams(num_topics=128, alpha=0.05, beta=0.01)
    state = random_init(jax.random.key(0), corpus, hyper)
    w, d = corpus.word, corpus.doc
    wb = corpus.num_words * hyper.beta

    @jax.jit
    def eliminated(n_wk, n_kd, n_k):
        terms = precompute_zen_terms(n_k, hyper, corpus.num_words)
        return zen_probs(n_wk[w], n_kd[d], terms, hyper.beta)

    @jax.jit
    def naive(n_wk, n_kd, n_k):
        # recompute everything per token row (no loop-invariant hoisting)
        nw = n_wk[w].astype(jnp.float32)
        nd = n_kd[d].astype(jnp.float32)
        n_total = jnp.sum(n_k.astype(jnp.float32))
        kk = float(hyper.num_topics)
        alpha_k = (kk * hyper.alpha) * (
            n_k.astype(jnp.float32) + hyper.alpha_prime / kk
        ) / (n_total + hyper.alpha_prime)
        denom = n_k.astype(jnp.float32)[None, :] + wb
        return (
            alpha_k[None, :] * hyper.beta / denom
            + nw * alpha_k[None, :] / denom
            + nd * (nw + hyper.beta) / denom
        )

    t_elim = time_fn(eliminated, state.n_wk, state.n_kd, state.n_k, iters=5)
    t_naive = time_fn(naive, state.n_wk, state.n_kd, state.n_k, iters=5)
    row("fig10_eliminated", t_elim, "")
    row("fig10_naive", t_naive,
        f"improvement={(t_naive - t_elim) / t_naive:.1%} (paper: ~11%)")


if __name__ == "__main__":
    main()
