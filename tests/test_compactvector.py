"""CompactVector (paper Alg. 4) vs dense oracle."""
import numpy as np
from helpers import given, settings, st  # hypothesis, or the fallback shim

from repro.core.compactvector import CompactVector


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(0, 5), min_size=1, max_size=60))
def test_get_matches_dense(dense):
    dense = np.asarray(dense)
    cv = CompactVector.from_dense(dense)
    np.testing.assert_array_equal(cv.to_dense(), dense)


def test_compact_beats_sparse_on_runs():
    """Paper claim: smaller than (idx, val) sparse when E/N >= 2."""
    dense = np.zeros(100, np.int64)
    dense[10:40] = 7  # one run of 30 nonzeros
    cv = CompactVector.from_dense(dense)
    sparse_bytes = 30 * 8 * 2  # idx + val arrays
    assert cv.nbytes() < sparse_bytes
    assert cv.empty_starts.size == 2  # two empty runs


def test_insert_roundtrip():
    dense = np.array([0, 3, 0, 0, 5])
    cv = CompactVector.from_dense(dense).insert(2, 9)
    dense[2] = 9
    np.testing.assert_array_equal(cv.to_dense(), dense)
