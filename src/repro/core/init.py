"""Topic-assignment initialization (paper §5.1 "Sparse model initialization").

* ``random_init``       — standard: every token draws uniformly from K.
* ``sparse_word_init``  — SparseWord: each *word* first samples a private
  subset S of size ceil(deg*K); its tokens draw uniformly from S only.
* ``sparse_doc_init``   — SparseDoc: same per *document*.

Sparse init bounds the nnz of the word-topic (resp. doc-topic) rows, which
shrinks the first iterations' memory/compute/collective footprint — the
paper's fix for "the first several iterations are the bottleneck".
The β-boost neutralization for never-assigned topics (§5.1.1 last sentence)
is exposed as ``beta_boost`` and consumed by the samplers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import counts as counts_lib
from repro.core.types import CGSState, Corpus, LDAHyperParams


def _make_state(
    topic: jax.Array, corpus: Corpus, hyper: LDAHyperParams, rng: jax.Array
) -> CGSState:
    n_wk, n_kd, n_k = counts_lib.build_counts(
        corpus.word, corpus.doc, topic,
        corpus.num_words, corpus.num_docs, hyper.num_topics,
    )
    e = corpus.num_tokens
    return CGSState(
        topic=topic, prev_topic=topic, n_wk=n_wk, n_kd=n_kd, n_k=n_k,
        rng=rng, iteration=0,
        stale_iters=jnp.zeros((e,), jnp.int32),
        same_count=jnp.zeros((e,), jnp.int32),
    )


def random_init(
    rng: jax.Array, corpus: Corpus, hyper: LDAHyperParams
) -> CGSState:
    key, state_key = jax.random.split(rng)
    topic = jax.random.randint(
        key, (corpus.num_tokens,), 0, hyper.num_topics, dtype=jnp.int32
    )
    return _make_state(topic, corpus, hyper, state_key)


def _subset_init(
    rng: jax.Array,
    corpus: Corpus,
    hyper: LDAHyperParams,
    group: jax.Array,  # (E,) the vertex id each token belongs to (word or doc)
    num_groups: int,
    degree: float,
) -> CGSState:
    """Each group g gets a random topic subset of size s = ceil(degree*K);
    tokens of g sample uniformly within the subset.

    Subsets are realized without materializing (num_groups, K): group g's
    subset is {perm_g(j) : j < s} where perm_g is a per-group pseudorandom
    permutation of [0, K) built from a random offset + coprime stride —
    cheap, uniform enough, and O(E) total.
    """
    k = hyper.num_topics
    s = max(1, int(round(degree * k)))
    key_off, key_stride, key_j, state_key = jax.random.split(rng, 4)
    offsets = jax.random.randint(key_off, (num_groups,), 0, k, dtype=jnp.int32)
    # odd strides are coprime with any power-of-two >= k; for general k use
    # strides from a set of values coprime to k.
    strides = 2 * jax.random.randint(
        key_stride, (num_groups,), 0, max(1, k // 2), dtype=jnp.int32
    ) + 1
    j = jax.random.randint(key_j, (corpus.num_tokens,), 0, s, dtype=jnp.int32)
    topic = (offsets[group] + j * strides[group]) % k
    return _make_state(topic.astype(jnp.int32), corpus, hyper, state_key)


def sparse_word_init(
    rng: jax.Array, corpus: Corpus, hyper: LDAHyperParams, degree: float = 0.1
) -> CGSState:
    return _subset_init(rng, corpus, hyper, corpus.word, corpus.num_words, degree)


def sparse_doc_init(
    rng: jax.Array, corpus: Corpus, hyper: LDAHyperParams, degree: float = 0.1
) -> CGSState:
    return _subset_init(rng, corpus, hyper, corpus.doc, corpus.num_docs, degree)


def beta_boost(state: CGSState, hyper: LDAHyperParams, boost: float = 2.0) -> jax.Array:
    """Per-(w,k) effective beta: boosted where the topic was never assigned
    to the word during initialization (paper §5.1: 'neutralize the side
    effect by increasing the β value ... for those topics that are not
    assigned during initialization'). Returns (W, K) float32."""
    unassigned = state.n_wk == 0
    return jnp.where(unassigned, hyper.beta * boost, hyper.beta).astype(jnp.float32)
