"""Paper Figs. 3 + 4: every registered CGS backend — time/iteration and
log-likelihood after equal iterations, all on the shared substrate
("the only difference is the algorithm").

The sweep list IS the registry: a newly registered backend shows up here
with zero benchmark changes."""
from __future__ import annotations

import time

import jax

from benchmarks.common import row
from repro import algorithms
from repro.core import LDATrainer, TrainConfig, LDAHyperParams
from repro.data import synthetic_lda_corpus


def main(iters: int = 10):
    corpus, _ = synthetic_lda_corpus(
        0, num_docs=400, num_words=800, num_topics=32, avg_doc_len=64
    )
    hyper = LDAHyperParams(num_topics=32, alpha=0.05, beta=0.01)
    results = {}
    for alg in algorithms.registered():
        tr = LDATrainer(
            corpus, hyper,
            TrainConfig(algorithm=alg, max_kw=64, max_kd=64, num_mh=8),
        )
        st = tr.init_state(jax.random.key(0))
        st = tr.step(st)  # warm compile
        t0 = time.perf_counter()
        for _ in range(iters):
            st = tr.step(st)
        dt = (time.perf_counter() - t0) / iters
        llh = tr.llh(st)
        results[alg] = (dt, llh)
        row(f"fig3_time_per_iter_{alg}", dt * 1e6, f"llh={llh:.1f}")
    # headline ratios (paper: 2-6x over LightLDA, ~14x over SparseLDA for
    # the customized-scale corpora; CPU-vectorized small-corpus ratios are
    # reported as measured)
    z = results["zen_sparse"][0]
    row("fig3_speedup_vs_lightlda", 0.0,
        f"ratio={results['lightlda'][0] / z:.2f}")
    row("fig3_speedup_vs_sparselda", 0.0,
        f"ratio={results['sparselda'][0] / z:.2f}")
    row("fig4_llh_zen_minus_lightlda", 0.0,
        f"delta={results['zen_sparse'][1] - results['lightlda'][1]:.1f}")


if __name__ == "__main__":
    main()
