"""Attention flavors for the zoo: GQA (+bias, +qk-norm), sliding-window,
MLA (latent attention), and cached decode.

All paths are pure jnp einsums so XLA SPMD partitions them from the
in_shardings (heads over `model`, batch over data axes); the HLO collective
schedule these induce is what the roofline harness measures.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import apply_mrope, apply_rope, rmsnorm


class KVCache(NamedTuple):
    k: jax.Array  # (B, S_max, KVH, D) — or MLA: (B, S_max, 1, c_kv+rope)
    v: Optional[jax.Array]  # None for MLA (latent holds both)
    length: jax.Array  # () int32 — tokens currently valid


def _mask_bias(
    q_pos: jax.Array,  # (B, Sq)
    kv_pos: jax.Array,  # (B, Skv)
    causal: bool,
    window: int,
    kv_valid: Optional[jax.Array] = None,  # (B, Skv) bool
) -> jax.Array:
    """Additive mask (B, 1, Sq, Skv)."""
    dq = q_pos[:, :, None]
    dk = kv_pos[:, None, :]
    ok = jnp.ones(dq.shape[:1] + (dq.shape[1], dk.shape[2]), bool)
    if causal:
        ok &= dk <= dq
    if window > 0:
        ok &= dk > dq - window
    if kv_valid is not None:
        ok &= kv_valid[:, None, :]
    return jnp.where(ok, 0.0, -1e30)[:, None, :, :]


def attend(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Skv, KVH, D)
    v: jax.Array,  # (B, Skv, KVH, Dv)
    mask_bias: jax.Array,  # (B, 1, Sq, Skv)
    scale: Optional[float] = None,
) -> jax.Array:
    """GQA attention core; H must be a multiple of KVH."""
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    groups = h // kvh
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, sq, kvh, groups, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    logits = logits + mask_bias[:, :, None, :, :]
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhe->bqhge", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h, v.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# Standard (GQA) attention block
# ---------------------------------------------------------------------------

def init_attn(key, cfg: ArchConfig, dtype) -> dict:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    h, kvh = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    # FLAT projection layouts (d, h*hd): head counts below the model-axis
    # width (e.g. gemma3's 8 q / 4 kv heads on a 16-way axis) still shard
    # evenly on the flattened dim; layers reshape activations instead.
    p = {
        "wq": (jax.random.normal(ks[0], (d, h * hd)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, kvh * hd)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, kvh * hd)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (h * hd, d)) * ((h * hd) ** -0.5)).astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kvh * hd,), dtype)
        p["bv"] = jnp.zeros((kvh * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    return p


def _qkv(x, params, cfg: ArchConfig, positions, theta: float):
    b, s, _ = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,dk->bsk", x, params["wq"])
    k = jnp.einsum("bsd,dk->bsk", x, params["wk"])
    v = jnp.einsum("bsd,dk->bsk", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kvh, hd)
    v = v.reshape(b, s, kvh, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, params["k_norm"], cfg.norm_eps)
    if cfg.mrope:
        if positions.ndim == 2:  # text-only stream: t == h == w
            positions = jnp.broadcast_to(
                positions[..., None], positions.shape + (3,)
            )
        q = apply_mrope(q, positions, theta)
        k = apply_mrope(k, positions, theta)
    else:
        pos = positions if positions.ndim == 2 else positions[..., 0]
        q = apply_rope(q, pos, theta)
        k = apply_rope(k, pos, theta)
    return q, k, v


def attn_block(
    x: jax.Array,  # (B, S, D)
    params: dict,
    cfg: ArchConfig,
    positions: jax.Array,  # (B, S) or (B, S, 3)
    *,
    causal: bool = True,
    window: int = 0,
    theta: Optional[float] = None,
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
) -> jax.Array:
    """Full-sequence attention (train / prefill)."""
    theta = theta if theta is not None else cfg.rope_theta
    pos2d = positions if positions.ndim == 2 else positions[..., 0]
    if cross_kv is None:
        q, k, v = _qkv(x, params, cfg, positions, theta)
        bias = _mask_bias(pos2d, pos2d, causal, window)
    else:
        b, s, _ = x.shape
        h, hd = cfg.num_heads, cfg.resolved_head_dim
        q = jnp.einsum("bsd,dk->bsk", x, params["wq"])
        if cfg.qkv_bias:
            q = q + params["bq"]
        q = q.reshape(b, s, h, hd)
        k, v = cross_kv
        bias = jnp.zeros((x.shape[0], 1, x.shape[1], k.shape[1]), jnp.float32)
    out = attend(q, k, v, bias)
    b, sq = out.shape[:2]
    return jnp.einsum("bsk,kd->bsd", out.reshape(b, sq, -1), params["wo"])


def cross_kv(
    enc: jax.Array, params: dict, kvh: int, hd: int
) -> Tuple[jax.Array, jax.Array]:
    """Encoder-side K/V projections for cross-attention (whisper)."""
    b, s, _ = enc.shape
    k = jnp.einsum("bsd,dk->bsk", enc, params["wk"]).reshape(b, s, kvh, hd)
    v = jnp.einsum("bsd,dk->bsk", enc, params["wv"]).reshape(b, s, kvh, hd)
    return k, v


def attn_decode(
    x: jax.Array,  # (B, 1, D)
    params: dict,
    cfg: ArchConfig,
    cache: KVCache,
    *,
    window: int = 0,
    theta: Optional[float] = None,
) -> Tuple[jax.Array, KVCache]:
    """One-token cached decode; cache seq axis may be sharded (flash-decode
    style combine is induced by XLA from the seq-sharded einsum + softmax)."""
    theta = theta if theta is not None else cfg.rope_theta
    b = x.shape[0]
    pos = cache.length  # scalar current position
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new = _qkv(x, params, cfg, positions, theta)
    s_max = cache.k.shape[1]
    if window > 0 and s_max == window:
        # sliding-window ring cache: overwrite slot pos % window
        slot = jnp.mod(pos, window)
    else:
        slot = pos
    k = jax.lax.dynamic_update_slice(cache.k, k_new, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new, (0, slot, 0, 0))
    kv_pos = jnp.broadcast_to(jnp.arange(s_max, dtype=jnp.int32)[None, :], (b, s_max))
    if window > 0 and s_max == window:
        valid = kv_pos < jnp.minimum(pos + 1, window)
        bias = _mask_bias(positions, kv_pos, False, 0, valid)
    else:
        valid = kv_pos <= pos
        bias = _mask_bias(positions, kv_pos, False, 0, valid)
    out = attend(q, k, v, bias)
    out = jnp.einsum(
        "bsk,kd->bsd", out.reshape(b, 1, -1), params["wo"]
    )
    return out, KVCache(k=k, v=v, length=cache.length + 1)


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (MiniCPM3)
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ArchConfig, dtype) -> dict:
    m = cfg.mla
    d = cfg.d_model
    h = cfg.num_heads
    ks = jax.random.split(key, 8)
    s = d ** -0.5
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    # flat layouts (see init_attn): the head axis folds into the column dim
    return {
        # query low-rank path
        "wq_a": (jax.random.normal(ks[0], (d, m.q_lora_rank)) * s).astype(dtype),
        "q_a_norm": jnp.zeros((m.q_lora_rank,), jnp.float32),
        "wq_b": (
            jax.random.normal(ks[1], (m.q_lora_rank, h * qk_head))
            * (m.q_lora_rank ** -0.5)
        ).astype(dtype),
        # kv latent path: compressed c_kv plus shared rope key channel
        "wkv_a": (
            jax.random.normal(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim)) * s
        ).astype(dtype),
        "kv_a_norm": jnp.zeros((m.kv_lora_rank,), jnp.float32),
        "wkv_b": (
            jax.random.normal(
                ks[3], (m.kv_lora_rank, h * (m.qk_nope_head_dim + m.v_head_dim))
            )
            * (m.kv_lora_rank ** -0.5)
        ).astype(dtype),
        "wo": (
            jax.random.normal(ks[4], (h * m.v_head_dim, d))
            * ((h * m.v_head_dim) ** -0.5)
        ).astype(dtype),
    }


def mla_block(
    x: jax.Array,
    params: dict,
    cfg: ArchConfig,
    positions: jax.Array,
    *,
    causal: bool = True,
) -> jax.Array:
    """MLA attention (train/prefill). The KV cache would store only the
    latent (kv_lora_rank + rope) per token — the memory win MiniCPM3 exists
    for; decode path in ``mla_decode``."""
    m = cfg.mla
    h = cfg.num_heads
    pos2d = positions if positions.ndim == 2 else positions[..., 0]
    # queries
    b, sl, _ = x.shape
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    q_lat = rmsnorm(
        jnp.einsum("bsd,dr->bsr", x, params["wq_a"]), params["q_a_norm"],
        cfg.norm_eps,
    )
    q = jnp.einsum("bsr,rk->bsk", q_lat, params["wq_b"]).reshape(
        b, sl, h, qk_head
    )
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, pos2d, cfg.rope_theta)
    # latent kv
    kv_a = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"])
    c_kv, k_rope = jnp.split(kv_a, [m.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(c_kv, params["kv_a_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], pos2d, cfg.rope_theta)
    kv = jnp.einsum("bsr,rk->bsk", c_kv, params["wkv_b"]).reshape(
        b, sl, h, m.qk_nope_head_dim + m.v_head_dim
    )
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, k_nope.shape[:3] + (m.qk_rope_head_dim,))],
        axis=-1,
    )
    qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
    bias = _mask_bias(pos2d, pos2d, causal, 0)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    out = attend(qfull, k, v, bias, scale=scale)
    return jnp.einsum(
        "bsk,kd->bsd", out.reshape(b, sl, -1), params["wo"]
    )


def mla_decode(
    x: jax.Array,  # (B, 1, D)
    params: dict,
    cfg: ArchConfig,
    cache: KVCache,  # cache.k: (B, S_max, 1, kv_lora+rope) latent; v None
) -> Tuple[jax.Array, KVCache]:
    m = cfg.mla
    b = x.shape[0]
    pos = cache.length
    positions = jnp.full((b, 1), pos, jnp.int32)
    kv_a = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"])
    c_new, krope_new = jnp.split(kv_a, [m.kv_lora_rank], axis=-1)
    c_new = rmsnorm(c_new, params["kv_a_norm"], cfg.norm_eps)
    krope_new = apply_rope(krope_new[:, :, None, :], positions, cfg.rope_theta)
    latent_new = jnp.concatenate([c_new[:, :, None, :], krope_new], axis=-1)
    lat = jax.lax.dynamic_update_slice(cache.k, latent_new, (0, pos, 0, 0))
    s_max = lat.shape[1]
    # expand latents for attention (dense expansion; the absorbed-matmul
    # optimization is a §Perf candidate)
    h = cfg.num_heads
    c_all, krope_all = jnp.split(lat[:, :, 0, :], [m.kv_lora_rank], axis=-1)
    kv = jnp.einsum("bsr,rk->bsk", c_all, params["wkv_b"]).reshape(
        b, s_max, h, m.qk_nope_head_dim + m.v_head_dim
    )
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    k = jnp.concatenate(
        [
            k_nope,
            jnp.broadcast_to(
                krope_all[:, :, None, :], k_nope.shape[:3] + (m.qk_rope_head_dim,)
            ),
        ],
        axis=-1,
    )
    q_lat = rmsnorm(
        jnp.einsum("bsd,dr->bsr", x, params["wq_a"]), params["q_a_norm"],
        cfg.norm_eps,
    )
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    q = jnp.einsum("bsr,rk->bsk", q_lat, params["wq_b"]).reshape(
        b, 1, h, qk_head
    )
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
    kv_pos = jnp.broadcast_to(jnp.arange(s_max, dtype=jnp.int32)[None, :], (b, s_max))
    bias = _mask_bias(positions, kv_pos, False, 0, kv_pos <= pos)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    out = attend(qfull, k, v, bias, scale=scale)
    out = jnp.einsum("bsk,kd->bsd", out.reshape(b, 1, -1), params["wo"])
    return out, KVCache(k=lat, v=None, length=cache.length + 1)
