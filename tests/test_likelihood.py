"""Log-likelihood / perplexity metrics."""
import math

import jax.numpy as jnp
import numpy as np

from repro.core.init import random_init
from repro.core.likelihood import joint_llh, perplexity, predictive_llh


def test_predictive_llh_finite_and_negative(key, tiny_corpus, tiny_hyper):
    state = random_init(key, tiny_corpus, tiny_hyper)
    llh = float(predictive_llh(state, tiny_corpus, tiny_hyper))
    assert np.isfinite(llh) and llh < 0


def test_chunked_llh_matches(key, tiny_corpus, tiny_hyper):
    state = random_init(key, tiny_corpus, tiny_hyper)
    full = float(predictive_llh(state, tiny_corpus, tiny_hyper))
    e = tiny_corpus.num_tokens
    e4 = e - (e % 4)
    import dataclasses

    from repro.core.types import Corpus

    c4 = Corpus(word=tiny_corpus.word[:e4], doc=tiny_corpus.doc[:e4],
                num_words=tiny_corpus.num_words,
                num_docs=tiny_corpus.num_docs)
    s4 = dataclasses.replace(state, topic=state.topic[:e4],
                             prev_topic=state.prev_topic[:e4],
                             stale_iters=None, same_count=None)
    a = float(predictive_llh(s4, c4, tiny_hyper))
    b = float(predictive_llh(s4, c4, tiny_hyper, token_chunk=e4 // 4))
    np.testing.assert_allclose(a, b, rtol=1e-5)


def test_joint_llh_split(key, tiny_corpus, tiny_hyper):
    """Fig. 7 metric: total == word part + doc part, all finite."""
    state = random_init(key, tiny_corpus, tiny_hyper)
    llh = joint_llh(state, tiny_corpus, tiny_hyper)
    np.testing.assert_allclose(
        float(llh.total), float(llh.word) + float(llh.doc), rtol=1e-6
    )
    assert np.isfinite(float(llh.word)) and np.isfinite(float(llh.doc))


def test_perplexity_definition(key, tiny_corpus, tiny_hyper):
    state = random_init(key, tiny_corpus, tiny_hyper)
    llh = float(predictive_llh(state, tiny_corpus, tiny_hyper))
    ppl = float(perplexity(state, tiny_corpus, tiny_hyper))
    np.testing.assert_allclose(
        ppl, np.exp(-llh / tiny_corpus.num_tokens), rtol=1e-5
    )
    # random assignment perplexity must be below vocab size, above 1
    assert 1.0 < ppl <= tiny_corpus.num_words * 2


# ---------------------------------------------------------------------------
# hand-computed pins on a 2-doc / 3-word / 2-topic corpus
#
# word = [0,1,1,2,2], doc = [0,0,1,1,1], z = [0,1,1,0,1]
#   n_wk = [[1,0],[0,2],[1,1]]   n_kd = [[1,1],[1,2]]   n_k = [2,3]
# Every expected value below is recomputed in-test with plain Python
# loops over the definitions (footnote-6 predictive; collapsed joint) —
# an oracle independent of the jax implementation.
# ---------------------------------------------------------------------------

def _pin_fixture(asymmetric):
    import dataclasses as dc

    import jax

    from repro.core.types import CGSState, Corpus, LDAHyperParams

    corpus = Corpus(word=jnp.array([0, 1, 1, 2, 2], jnp.int32),
                    doc=jnp.array([0, 0, 1, 1, 1], jnp.int32),
                    num_words=3, num_docs=2)
    hyper = LDAHyperParams(num_topics=2, alpha=0.5, beta=0.25,
                           alpha_prime=1.0, asymmetric_alpha=asymmetric)
    state = CGSState(
        topic=jnp.array([0, 1, 1, 0, 1], jnp.int32),
        prev_topic=jnp.array([0, 1, 1, 0, 1], jnp.int32),
        n_wk=jnp.array([[1, 0], [0, 2], [1, 1]], jnp.int32),
        n_kd=jnp.array([[1, 1], [1, 2]], jnp.int32),
        n_k=jnp.array([2, 3], jnp.int32),
        rng=jax.random.key(0),
    )
    del dc
    return corpus, hyper, state


def _pin_reference(asymmetric):
    """Pure-python re-derivation of both llh definitions."""
    n_wk = [[1, 0], [0, 2], [1, 1]]
    n_kd = [[1, 1], [1, 2]]
    n_k = [2, 3]
    n_d = [2, 3]
    w, k, beta = 3, 2, 0.25
    if asymmetric:
        # alpha_k = K*alpha*(n_k + alpha'/K)/(N + alpha')
        alpha_k = [2 * 0.5 * (n + 1.0 / 2) / (5 + 1.0) for n in n_k]
    else:
        alpha_k = [0.5, 0.5]
    a_sum = sum(alpha_k)
    pred = 0.0
    for wd, d in zip([0, 1, 1, 2, 2], [0, 0, 1, 1, 1]):
        p = sum(
            (n_kd[d][t] + alpha_k[t]) / (n_d[d] + a_sum)
            * (n_wk[wd][t] + beta) / (n_k[t] + w * beta)
            for t in range(k)
        )
        pred += math.log(p)
    lg = math.lgamma
    word_part = (
        k * lg(w * beta) - sum(lg(n + w * beta) for n in n_k)
        + sum(lg(c + beta) for row in n_wk for c in row) - k * w * lg(beta)
    )
    doc_part = (
        2 * lg(a_sum) - sum(lg(n + a_sum) for n in n_d)
        + sum(lg(n_kd[d][t] + alpha_k[t]) for d in range(2) for t in range(k))
        - 2 * sum(lg(a) for a in alpha_k)
    )
    return pred, word_part, doc_part


def test_predictive_llh_hand_computed_symmetric():
    corpus, hyper, state = _pin_fixture(asymmetric=False)
    pred, _, _ = _pin_reference(asymmetric=False)
    np.testing.assert_allclose(pred, -5.2430152746, rtol=1e-9)  # literal pin
    got = float(predictive_llh(state, corpus, hyper))
    np.testing.assert_allclose(got, pred, rtol=1e-5)


def test_predictive_llh_hand_computed_asymmetric():
    corpus, hyper, state = _pin_fixture(asymmetric=True)
    pred, _, _ = _pin_reference(asymmetric=True)
    np.testing.assert_allclose(pred, -5.2329003404, rtol=1e-9)  # literal pin
    got = float(predictive_llh(state, corpus, hyper))
    np.testing.assert_allclose(got, pred, rtol=1e-5)


def test_joint_llh_hand_computed_symmetric():
    corpus, hyper, state = _pin_fixture(asymmetric=False)
    _, word, doc = _pin_reference(asymmetric=False)
    np.testing.assert_allclose(word, -6.8775022358, rtol=1e-9)
    np.testing.assert_allclose(doc, -4.8520302639, rtol=1e-9)
    got = joint_llh(state, corpus, hyper)
    np.testing.assert_allclose(float(got.word), word, rtol=5e-4)
    np.testing.assert_allclose(float(got.doc), doc, rtol=5e-4)
    np.testing.assert_allclose(float(got.total), word + doc, rtol=5e-4)


def test_joint_llh_hand_computed_asymmetric():
    corpus, hyper, state = _pin_fixture(asymmetric=True)
    _, word, doc = _pin_reference(asymmetric=True)
    np.testing.assert_allclose(doc, -4.8543047966, rtol=1e-9)
    got = joint_llh(state, corpus, hyper)
    np.testing.assert_allclose(float(got.word), word, rtol=5e-4)
    np.testing.assert_allclose(float(got.doc), doc, rtol=5e-4)


def test_perplexity_hand_computed():
    corpus, hyper, state = _pin_fixture(asymmetric=False)
    pred, _, _ = _pin_reference(asymmetric=False)
    got = float(perplexity(state, corpus, hyper))
    np.testing.assert_allclose(got, math.exp(-pred / 5), rtol=1e-5)


def test_llh_improves_with_training(key, tiny_corpus, tiny_hyper):
    from repro.core import LDATrainer, TrainConfig

    tr = LDATrainer(tiny_corpus, tiny_hyper, TrainConfig(algorithm="zen"))
    st = tr.init_state(key)
    l0 = tr.llh(st)
    j0 = tr.llh_split(st)
    for _ in range(10):
        st = tr.step(st)
    assert tr.llh(st) > l0
    j1 = tr.llh_split(st)
    assert float(j1.total) > float(j0.total)
