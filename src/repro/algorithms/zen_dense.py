"""Dense (T, K) three-term backends: ``zen`` (+ ``zen_dense`` alias) and
``std``.

The zen cell sweep is the distributed runtime's hillclimb baseline (moved
here from ``core.distributed``): per-token dense probabilities with exact
¬dw self-exclusion, sampled by Gumbel-max or inverse CDF. Simple;
memory-bound at large K (the gathered rows dominate HBM traffic).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.algorithms.base import (
    CellBackend,
    SamplerBackend,
    SamplerKnobs,
    chunked_token_map,
)
from repro.algorithms.registry import register
from repro.core.sampler import cgs_sweep_stale


def _searchsorted_rows(cdf: jax.Array, targets: jax.Array) -> jax.Array:
    """Row-wise lower bound: cdf (T, N) ascending, targets (T,) -> (T,)."""
    return jnp.minimum(
        jnp.sum(cdf < targets[:, None], axis=-1), cdf.shape[-1] - 1
    ).astype(jnp.int32)


def zen_dense_cell(
    key, word_l, doc_l, z_old, mask, n_wk_l, n_kd_l, n_k, hyper,
    num_words_pad: int, method: str, token_chunk: int,
):
    """Dense per-token (T, K) three-term probabilities; exact ¬dw."""
    k = hyper.num_topics

    def chunk(args):
        w, d, z, subkey = args
        onehot = jax.nn.one_hot(z, k, dtype=jnp.int32)
        nw = (n_wk_l[w] - onehot).astype(jnp.float32)
        nd = (n_kd_l[d] - onehot).astype(jnp.float32)
        nk = (n_k[None, :] - onehot).astype(jnp.float32)
        alpha_k = hyper.alpha_k(n_k)[None, :]
        w_beta = num_words_pad * hyper.beta
        t1 = 1.0 / (nk + w_beta)
        p = (alpha_k * hyper.beta + nw * alpha_k + nd * (nw + hyper.beta)) * t1
        if method == "gumbel":
            g = jax.random.gumbel(subkey, p.shape, dtype=jnp.float32)
            return jnp.argmax(jnp.log(jnp.maximum(p, 1e-30)) + g, -1).astype(jnp.int32)
        cdf = jnp.cumsum(p, axis=-1)
        u = jax.random.uniform(subkey, (p.shape[0], 1)) * cdf[:, -1:]
        return _searchsorted_rows(cdf, u[:, 0])

    return chunked_token_map(chunk, key, (word_l, doc_l, z_old), token_chunk)


@register("zen", "zen_dense")
class ZenDense(CellBackend):
    """ZenLDA three-term decomposition over dense rows (paper Eq. 3)."""

    decomposition = "zen"

    def sweep(self, state, corpus, hyper, knobs: SamplerKnobs, aux=None):
        # single-box path keeps the oracle sweep (identical math; preserves
        # the reference RNG stream used by the statistical tests)
        return cgs_sweep_stale(
            state, corpus, hyper, method=knobs.sampling_method,
            decomposition=self.decomposition,
            token_chunk=knobs.chunk_or_none(),
        )

    def cell_sweep(
        self, key, word, doc, z_old, mask, n_wk, n_kd, n_k, hyper,
        num_words_pad, knobs: SamplerKnobs,
    ):
        return zen_dense_cell(
            key, word, doc, z_old, mask, n_wk, n_kd, n_k, hyper,
            num_words_pad, knobs.sampling_method, knobs.token_chunk,
        )


@register("std")
class StdDense(SamplerBackend):
    """Textbook (non-decomposed) Eq. 3 conditional — dense, single-box."""

    def sweep(self, state, corpus, hyper, knobs: SamplerKnobs, aux=None):
        return cgs_sweep_stale(
            state, corpus, hyper, method=knobs.sampling_method,
            decomposition="std", token_chunk=knobs.chunk_or_none(),
        )
