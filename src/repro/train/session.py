"""Unified training sessions: one schedule-driven driver for single-box
and mesh LDA training (DESIGN.md §6).

The paper's workflow is one loop with periodic structural events — model
sync, exact count rebuild (Fig. 2), "converged" token exclusion (§5.1),
duplicate-topic merging (§4.3), capacity-sensitive sparse tables (§4-5).
This module drives that loop through exactly one API:

* ``RunConfig`` — the declarative run description (supersedes the old
  ``TrainConfig`` + ``DistConfig`` + ``LoopConfig`` triple): algorithm +
  sampler knobs (one ``SamplerKnobs`` derivation via
  ``algorithms.knobs_from``), initialization, the execution plan
  (``mesh_shape=None`` = single-box, ``(rows, cols)`` = SPMD mesh), and
  every event cadence. ``to_json``/``from_json`` round-trip, so a run is a
  file (``launch/train.py --config run.json``).

* ``TrainSession`` — resolves the backend once, selects an execution plan
  — single-box as a whole-corpus one-cell plan, mesh via
  ``grid_partition`` + ``make_dist_step`` — and exposes the same
  ``init() / step() / run() / metrics() / save_model()`` surface for both.
  Events are first-class ``Schedule`` actions (``repro.train.schedule``):
  llh/perplexity eval (with ``target_perplexity`` early stop derived from
  the *already computed* llh — no second likelihood pass), model and
  elastic training checkpoints, exclusion enablement at
  ``exclusion_start``, exact count rebuild, duplicate-topic merge, and
  periodic row-capacity re-resolution: on the ``rebuild_every`` cadence
  the padded-sparse widths are re-resolved against the *current* counts
  (``resolve_dist_row_pads``) and the jitted step is rebuilt when they
  changed, so rows that outgrow their init-frozen capacity stop being
  truncated and sharpened rows shed oversized pads.

The deprecated ``repro.core.LDATrainer`` / ``TrainConfig`` are thin shims
delegating here; new code should construct sessions directly.
"""
from __future__ import annotations

import dataclasses
import json
import math
import signal
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import algorithms
from repro.algorithms import SamplerKnobs, knobs_from
from repro.core import counts as counts_lib
from repro.core import init as init_lib
from repro.core.exclusion import (
    ExclusionConfig,
    active_mask,
    update_exclusion_stats,
)
from repro.core.hyper import duplicate_topic_map, merge_topics
from repro.core.likelihood import joint_llh, predictive_llh
from repro.core.types import CGSState, Corpus, LDAHyperParams
from repro.train.schedule import ActionContext, Schedule, ScheduledAction


# ---------------------------------------------------------------------------
# RunConfig
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Declarative description of one training run (both plans).

    ``mesh_shape=None`` selects the single-box plan; ``(rows, cols)``
    selects the SPMD mesh plan (data x model axes). Cadences count
    post-step iterations (the first step is iteration 1); 0 disables.
    ``num_iterations`` is the *absolute* target iteration, so resuming a
    checkpointed run needs no arithmetic.

    Field reference (grouped as below; see DESIGN.md §6.1 for rationale):

    * ``algorithm`` — any ``algorithms.registered()`` backend name.
    * ``sampling_method`` — dense-path inversion, ``"cdf"``/``"gumbel"``;
      ``None`` = the plan default (cdf single-box, gumbel mesh).
    * ``max_kw``/``max_kd`` — padded-sparse row widths (topics per
      word/doc row); 0 = resolve from the counts (or static cell
      defaults on the mesh).
    * ``num_mh`` — LightLDA cycle-MH proposals per token.
    * ``token_chunk`` — bound peak memory by sweeping tokens in chunks
      of this size; 0 = whole sweep at once.
    * ``bt``/``bk``/``bs`` — Pallas kernel tiles: token rows, topic
      lanes, and the sparse-row lane-alignment tile (kernel suite v2).
    * ``kernels`` — Pallas kernel dispatch policy, ``"auto"`` (kernels
      on TPU, legacy XLA elsewhere) / ``"on"`` / ``"off"``.
    * ``init``/``sparse_init_degree`` — topic init strategy (paper §5.1).
    * ``mesh_shape``/``delta_dtype``/``kd_dtype`` — execution plan and
      mesh payload widths.
    * ``num_iterations`` — absolute target iteration for :meth:`TrainSession.run`.
    * ``eval_every``/``target_perplexity`` — eval cadence and the
      early-stop threshold checked on those evals.
    * ``exclusion_start``/``exclusion_min_prob`` — "converged" token
      exclusion (paper §5.1): enable iteration and resample floor.
    * ``rebuild_every`` — exact count rebuild + row re-pad cadence.
    * ``merge_every``/``merge_threshold`` — duplicate-topic merge
      (paper §4.3) cadence and L1 closeness threshold.
    * ``checkpoint_dir``/``checkpoint_every`` — serving model
      checkpoints (``launch/serve_lda.py`` loads these); 0 = final only.
    * ``train_checkpoint_dir``/``train_checkpoint_every`` — elastic
      training checkpoints (assignments; ``run()`` auto-resumes).
    * ``window_docs``/``window_sweeps``/``decay``/``stream_source`` —
      windowed online training (``repro.train.online.StreamingSession``,
      DESIGN.md §7): docs per window, CGS sweeps per window visit, the
      forgetting factor applied to the global counts at each window
      transition, and the ``CorpusSource`` spec string
      (``replay`` | ``libsvm:<path>`` | ``drift[:<seed>]``). In
      streaming mode the cadences count *windows*, not iterations, and
      ``num_iterations`` bounds the absolute window cursor (0 = run to
      source exhaustion). Batch ``TrainSession`` ignores these fields.
    * ``metrics_out``/``metrics_every`` — per-iteration telemetry JSONL
      via ``repro.observe`` (path, record cadence). ``autopilot``/
      ``autopilot_every`` — ``repro.autotune`` backend + row-capacity
      re-pick from the measured counts on a cadence (DESIGN.md §8).
      All four are inert by default: with ``metrics_out=None`` and
      ``autopilot=False`` no telemetry is built and the schedule is
      bit-identical to a pre-observability session (pinned by test).
    * ``quality_every`` + ``quality_*`` — model-quality evaluation
      (``repro.eval``, DESIGN.md §9): UMass/NPMI topic coherence over
      the top ``quality_top_n`` words per topic and (when
      ``quality_l2r_docs > 0``) Wallach left-to-right held-out
      log-likelihood, contributed to the iteration metrics as
      ``coherence_umass``/``coherence_npmi``/``l2r_llh``/
      ``l2r_per_token``. 0 disables (no evaluator is built).
    * ``hyper_every``/``hyper_alpha``/``hyper_beta_anneal``/
      ``hyper_beta_floor`` — Alg. 5 hyper-parameter optimization as a
      schedule action: a Minka fixed-point step on the scalar alpha
      concentration and geometric beta annealing toward a floor, fired
      on the cadence; compiled steps rebuild when hypers change.
      ``hyper_every=0`` disables and is pinned bit-identical to a
      no-hyper run (same contract as the autopilot).
    """

    # -- algorithm + sampler knobs (one SamplerKnobs derivation) ----------
    algorithm: str = "zen"  # any algorithms.registered() name
    # dense-path inversion method: cdf | gumbel. None = the plan default
    # (cdf single-box, gumbel on the mesh — the historical defaults of
    # TrainConfig and DistConfig, kept so neither path silently changes
    # samplers); TrainSession resolves it at construction.
    sampling_method: Optional[str] = None
    max_kw: int = 0  # padded-sparse word-row width (0 = auto from counts)
    max_kd: int = 0  # padded-sparse doc-row width (0 = auto)
    num_mh: int = 8  # LightLDA cycle-MH steps (paper uses 8)
    token_chunk: int = 0  # 0 = whole sweep at once (memory knob)
    bt: int = 256  # Pallas token tile
    bk: int = 512  # Pallas topic tile
    bs: int = 128  # sparse-row lane tile (kernel suite v2)
    kernels: str = "auto"  # Pallas kernel dispatch: auto | on | off
    # -- initialization ---------------------------------------------------
    init: str = "random"  # random | sparse_word | sparse_doc
    sparse_init_degree: float = 0.1
    # -- execution plan ---------------------------------------------------
    mesh_shape: Optional[Tuple[int, int]] = None  # None = single-box
    delta_dtype: str = "int32"  # mesh psum payload: int32 | int16 | int8
    kd_dtype: str = "int32"  # mesh doc-topic state width: int32 | int16
    # -- run length + schedule cadences -----------------------------------
    num_iterations: int = 100
    eval_every: int = 0  # llh/perplexity eval cadence
    target_perplexity: Optional[float] = None  # early stop on eval ticks
    exclusion_start: int = 0  # 0 = disabled; else iteration to enable at
    exclusion_min_prob: float = 0.0  # floor on the resample probability
    rebuild_every: int = 0  # exact count rebuild + row re-pad cadence
    merge_every: int = 0  # duplicate-topic merge cadence (paper §4.3)
    merge_threshold: float = 0.05  # L1 distance below which topics merge
    checkpoint_dir: Optional[str] = None  # model ckpts (serving artifact)
    checkpoint_every: int = 0  # 0 = final only (when checkpoint_dir set)
    train_checkpoint_dir: Optional[str] = None  # elastic training ckpts
    train_checkpoint_every: int = 0
    # -- streaming (repro.train.online.StreamingSession; DESIGN.md §7) ----
    window_docs: int = 0  # docs per stream window (0 = batch training)
    window_sweeps: int = 1  # CGS sweeps per window visit
    decay: float = 0.0  # online forgetting: counts *= (1-decay) per window
    stream_source: Optional[str] = None  # replay | libsvm:<path> | drift[:<seed>]
    # -- observability + autopilot (DESIGN.md §8) --------------------------
    metrics_out: Optional[str] = None  # telemetry JSONL path (None = off)
    metrics_every: int = 1  # telemetry record cadence (iterations)
    autopilot: bool = False  # measured backend/capacity re-pick when True
    autopilot_every: int = 0  # decision cadence (0 = rebuild_every, else 10)
    # -- model-quality evaluation (repro.eval, DESIGN.md §9) ----------------
    quality_every: int = 0  # coherence (+ left-to-right) cadence (0 = off)
    quality_top_n: int = 10  # top words per topic for coherence
    quality_npmi_window: int = 10  # NPMI sliding-window size (0 = UMass only)
    quality_l2r_docs: int = 0  # left-to-right held-out docs (0 = skip l2r)
    quality_l2r_particles: int = 20  # particles per left-to-right doc
    # -- Alg. 5 hyper-parameter optimization (DESIGN.md §9.3) ---------------
    hyper_every: int = 0  # Minka alpha + beta anneal cadence (0 = off)
    hyper_alpha: bool = True  # run the Minka fixed-point alpha step
    hyper_beta_anneal: float = 1.0  # beta *= this per firing (1.0 = off)
    hyper_beta_floor: float = 1e-4  # annealing floor for beta

    def knobs(self) -> SamplerKnobs:
        return knobs_from(self)

    def exclusion(self) -> ExclusionConfig:
        return ExclusionConfig(
            enabled=self.exclusion_start > 0,
            start_iteration=self.exclusion_start,
            min_sample_prob=self.exclusion_min_prob,
        )

    # -- serialization ----------------------------------------------------
    def to_json(self, indent: Optional[int] = 2) -> str:
        d = dataclasses.asdict(self)
        if d["mesh_shape"] is not None:
            d["mesh_shape"] = list(d["mesh_shape"])
        return json.dumps(d, indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "RunConfig":
        d = json.loads(text)
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(d) - known)
        if unknown:
            raise ValueError(f"unknown RunConfig fields: {', '.join(unknown)}")
        if d.get("mesh_shape") is not None:
            d["mesh_shape"] = tuple(int(x) for x in d["mesh_shape"])
        return cls(**d)


# ---------------------------------------------------------------------------
# Execution plans
# ---------------------------------------------------------------------------

class ExecutionPlan:
    """What a ``TrainSession`` needs from a substrate: init/step/metrics
    plus the structural-event hooks the schedule fires. Both plans keep
    the paper's contract — the backend is only the per-token draw; the
    plan owns masking, the delta merge, and the state update."""

    backend: algorithms.SamplerBackend

    def init(self, rng: jax.Array, init_topics=None):
        raise NotImplementedError

    def step(self, state):
        raise NotImplementedError

    def llh(self, state) -> float:
        raise NotImplementedError

    def change_rate(self, state) -> float:
        raise NotImplementedError

    @property
    def num_tokens(self) -> int:
        raise NotImplementedError

    # -- structural events -------------------------------------------------
    def enable_exclusion(self) -> None:
        raise NotImplementedError

    def rebuild(self, state):
        """Exact count rebuild from the assignments (drift fix)."""
        raise NotImplementedError

    def repad(self, state) -> bool:
        """Re-resolve padded-row capacities against the current counts;
        rebuild the step when they changed. Returns True on a rebuild."""
        return False

    @property
    def row_pads(self) -> Tuple[int, int]:
        """(max_kw, max_kd) currently in effect (0 = per-sweep auto)."""
        raise NotImplementedError

    def apply_row_pads(self, max_kw: int, max_kd: int) -> bool:
        """Set explicit padded-row capacities (autopilot actuation).
        Returns True when the widths changed (and any compiled step was
        rebuilt); same re-jit move as :meth:`repad` with the targets
        decided by policy instead of re-resolved from the counts."""
        raise NotImplementedError

    def set_backend(self, name: str, state) -> bool:
        """Swap the sampler backend in place (autopilot actuation).
        Returns True when the backend actually changed. The swap reuses
        the repad machinery: rebuild whatever the old backend prepared
        (aux tables, compiled step) under the new registry entry."""
        raise NotImplementedError

    def merge(self, state, topic_map):
        """Apply a duplicate-topic map (remap assignments, merge counts)."""
        raise NotImplementedError

    def set_hyper(self, hyper: LDAHyperParams) -> None:
        """Swap the model hyper-parameters in place (the Alg. 5 "hyper"
        action). Anything compiled against the old values — backend aux
        tables, the mesh plan's jitted step/llh/rebuild — is rebuilt."""
        raise NotImplementedError

    def host_n_wk(self, state) -> np.ndarray:
        """N_w|k in original word ids (host) — merge detection, save_model."""
        raise NotImplementedError

    # -- checkpoint surfaces -----------------------------------------------
    def model_arrays(self, state) -> Tuple[np.ndarray, np.ndarray]:
        """(n_wk, n_k) in original word ids — the serving artifact."""
        raise NotImplementedError

    def checkpoint_tree(self, state) -> Dict[str, Any]:
        """Elastic training checkpoint: assignments only (counts rebuild)."""
        raise NotImplementedError

    def restore(self, state, tree):
        raise NotImplementedError


class SingleBoxPlan(ExecutionPlan):
    """The whole corpus as one cell: the paper's driver program on one
    device. Numerics are kept in lockstep with the historical
    ``LDATrainer`` (same key schedule, same delta merge) — the session
    bit-equality tests pin this."""

    def __init__(self, corpus: Corpus, hyper: LDAHyperParams, cfg: RunConfig):
        self.corpus = corpus
        self.hyper = hyper
        self.cfg = cfg
        self.backend = algorithms.get(cfg.algorithm)
        self._knobs = cfg.knobs()
        self._aux = self.backend.prepare(corpus, hyper, self._knobs)
        # single-box warmup is handled in-trace by ``active_mask`` (the
        # historical behavior — keeps direct ``step()`` loops exact), so
        # the schedule's "exclusion_on" firing is a recorded no-op here;
        # on the mesh plan it swaps the compiled step for real
        self._excl = cfg.exclusion()

    # -- lifecycle ---------------------------------------------------------
    def init(self, rng: jax.Array, init_topics=None) -> CGSState:
        c, h, cfg = self.corpus, self.hyper, self.cfg
        if init_topics is not None:
            topic = jnp.asarray(init_topics, jnp.int32).reshape(-1)
            n_wk, n_kd, n_k = counts_lib.build_counts(
                c.word, c.doc, topic, c.num_words, c.num_docs, h.num_topics
            )
            zeros = jnp.zeros((c.num_tokens,), jnp.int32)
            return CGSState(
                topic=topic, prev_topic=topic, n_wk=n_wk, n_kd=n_kd,
                n_k=n_k, rng=rng, iteration=0,
                stale_iters=zeros, same_count=zeros,
            )
        if cfg.init == "random":
            return init_lib.random_init(rng, c, h)
        if cfg.init == "sparse_word":
            return init_lib.sparse_word_init(rng, c, h, cfg.sparse_init_degree)
        if cfg.init == "sparse_doc":
            return init_lib.sparse_doc_init(rng, c, h, cfg.sparse_init_degree)
        raise ValueError(cfg.init)

    def sweep(self, state: CGSState) -> jax.Array:
        knobs = self._knobs
        if self.backend.needs_row_pads:
            # host-side auto pads from the current counts (0 = auto):
            # single-box re-resolves every sweep, so row growth never
            # truncates here (the mesh plan re-pads on the rebuild cadence)
            knobs = algorithms.resolve_row_pads(state, knobs)
        return self.backend.sweep(state, self.corpus, self.hyper, knobs,
                                  self._aux)

    def step(self, state: CGSState) -> CGSState:
        c, h = self.corpus, self.hyper
        key = jax.random.fold_in(state.rng, 2**20 + state.iteration)
        mask = active_mask(state, self._excl, key)
        z_new_all = self.sweep(state)
        z_new = jnp.where(mask, z_new_all, state.topic)
        d_wk, d_kd, d_k = counts_lib.delta_counts(
            c.word, c.doc, state.topic, z_new, c.num_words, c.num_docs,
            h.num_topics,
        )
        i_new, t_new = update_exclusion_stats(state, z_new, mask)
        return CGSState(
            topic=z_new,
            prev_topic=state.topic,
            n_wk=state.n_wk + d_wk,
            n_kd=state.n_kd + d_kd,
            n_k=state.n_k + d_k,
            rng=state.rng,
            iteration=state.iteration + 1,
            stale_iters=i_new,
            same_count=t_new,
        )

    # -- metrics -----------------------------------------------------------
    def llh(self, state: CGSState) -> float:
        return float(predictive_llh(state, self.corpus, self.hyper,
                                    token_chunk=self._knobs.chunk_or_none()))

    def llh_split(self, state: CGSState):
        return joint_llh(state, self.corpus, self.hyper)

    def change_rate(self, state: CGSState) -> float:
        return float(jnp.mean(
            (state.topic != state.prev_topic).astype(jnp.float32)
        ))

    @property
    def num_tokens(self) -> int:
        return self.corpus.num_tokens

    # -- structural events -------------------------------------------------
    def enable_exclusion(self) -> None:
        self._excl = self.cfg.exclusion()  # idempotent (in-trace warmup)

    def rebuild(self, state: CGSState) -> CGSState:
        c, h = self.corpus, self.hyper
        n_wk, n_kd, n_k = counts_lib.build_counts(
            c.word, c.doc, state.topic, c.num_words, c.num_docs, h.num_topics
        )
        return dataclasses.replace(state, n_wk=n_wk, n_kd=n_kd, n_k=n_k)

    @property
    def row_pads(self) -> Tuple[int, int]:
        return (self._knobs.max_kw, self._knobs.max_kd)

    def apply_row_pads(self, max_kw: int, max_kd: int) -> bool:
        if (self._knobs.max_kw, self._knobs.max_kd) == (max_kw, max_kd):
            return False
        # explicit widths stick: ``resolve_row_pads`` honors nonzero
        # values, so the per-sweep auto-resolution stops overriding them
        self._knobs = dataclasses.replace(
            self._knobs, max_kw=int(max_kw), max_kd=int(max_kd)
        )
        return True

    def set_backend(self, name: str, state: CGSState) -> bool:
        if name == self.backend.name:
            return False
        self.backend = algorithms.get(name)
        self._aux = self.backend.prepare(self.corpus, self.hyper,
                                         self._knobs)
        return True

    def set_hyper(self, hyper: LDAHyperParams) -> None:
        self.hyper = hyper
        # aux tables may encode beta/alpha (alias tables, frozen CDFs)
        self._aux = self.backend.prepare(self.corpus, hyper, self._knobs)

    def merge(self, state: CGSState, topic_map) -> CGSState:
        tm = jnp.asarray(topic_map, jnp.int32)
        new_topic, n_wk, n_kd, n_k = merge_topics(
            state.topic, state.n_wk, state.n_kd, state.n_k, tm
        )
        return dataclasses.replace(
            state, topic=new_topic,
            prev_topic=tm[state.prev_topic].astype(jnp.int32),
            n_wk=n_wk, n_kd=n_kd, n_k=n_k,
        )

    def host_n_wk(self, state: CGSState) -> np.ndarray:
        return np.asarray(jax.device_get(state.n_wk))

    # -- checkpoint surfaces -----------------------------------------------
    def model_arrays(self, state: CGSState):
        return (np.asarray(jax.device_get(state.n_wk)),
                np.asarray(jax.device_get(state.n_k)))

    def checkpoint_tree(self, state: CGSState) -> Dict[str, Any]:
        return {"topic": state.topic, "iteration": jnp.asarray(state.iteration)}

    def restore(self, state: CGSState, tree) -> CGSState:
        restored = dataclasses.replace(
            state,
            topic=jnp.asarray(tree["topic"], jnp.int32),
            prev_topic=jnp.asarray(tree["topic"], jnp.int32),
            iteration=int(tree["iteration"]),
            stale_iters=jnp.zeros_like(state.topic),
            same_count=jnp.zeros_like(state.topic),
        )
        return self.rebuild(restored)


class MeshPlan(ExecutionPlan):
    """SPMD mesh execution: ``grid_partition`` lays the corpus out on a
    (data x model) grid, ``make_dist_step`` builds the shard_map iteration
    (paper Fig. 2), and structural events that change the compiled step's
    static workspace — exclusion enablement, row-capacity re-resolution —
    rebuild the jitted step in place."""

    def __init__(self, corpus: Corpus, hyper: LDAHyperParams, cfg: RunConfig,
                 mesh=None):
        from repro.core.distributed import DistConfig
        from repro.core.graph import grid_partition
        from repro.launch.mesh import make_mesh

        self.corpus = corpus
        self.hyper = hyper
        self.cfg = cfg
        self.backend = algorithms.get(cfg.algorithm)
        if not self.backend.supports_shard_map:
            raise ValueError(
                f"backend {cfg.algorithm!r} does not support shard_map "
                f"cells; mesh-capable backends: "
                f"{', '.join(n for n in algorithms.registered() if algorithms.get(n).supports_shard_map)}"
            )
        rows, cols = cfg.mesh_shape
        self.mesh = mesh if mesh is not None else make_mesh(
            (rows, cols), ("data", "model")
        )
        self.grid = grid_partition(corpus, rows, cols)
        # the user's explicit widths; 0 stays "auto" across re-resolutions
        self._user_kw, self._user_kd = cfg.max_kw, cfg.max_kd
        self.dcfg = DistConfig(
            algorithm=cfg.algorithm,
            sampling_method=cfg.sampling_method,
            max_kd=cfg.max_kd, max_kw=cfg.max_kw, num_mh=cfg.num_mh,
            delta_dtype=cfg.delta_dtype,
            rebuild_every=cfg.rebuild_every,
            exclusion_start=0,  # enabled by the schedule action
            token_chunk=cfg.token_chunk, kd_dtype=cfg.kd_dtype,
            bt=cfg.bt, bk=cfg.bk, bs=cfg.bs, kernels=cfg.kernels,
        )
        self._step_fn = None
        self._data = None
        self._llh_fn = None
        self._rebuild_fn = None
        self._kd_dtype = jnp.int16 if cfg.kd_dtype == "int16" else jnp.int32

    # -- lifecycle ---------------------------------------------------------
    def init(self, rng: jax.Array, init_topics=None):
        from repro.core.distributed import (
            init_dist_state,
            make_dist_llh,
            make_rebuild_counts,
            resolve_dist_row_pads,
        )

        state, data = init_dist_state(
            rng, self.mesh, self.grid, self.hyper,
            init_topics=init_topics, kd_dtype=self._kd_dtype,
        )
        self._data = data
        # shard-relative padded-row capacities from the *init* counts; the
        # repad action re-resolves them on the rebuild cadence
        self.dcfg = resolve_dist_row_pads(state, self.dcfg)
        self._llh_fn = make_dist_llh(
            self.mesh, self.hyper, self.grid.words_per_shard,
            self.grid.docs_per_shard,
        )
        self._rebuild_fn = make_rebuild_counts(
            self.mesh, self.hyper, self.grid.words_per_shard,
            self.grid.docs_per_shard,
        )
        self._build_step()
        return state

    def _build_step(self) -> None:
        from repro.core.distributed import make_dist_step

        self._step_fn = make_dist_step(
            self.mesh, self.hyper, self.dcfg, self.grid.words_per_shard,
            self.grid.docs_per_shard,
        )

    def step(self, state):
        return self._step_fn(state, self._data)

    # -- metrics -----------------------------------------------------------
    def llh(self, state) -> float:
        return float(self._llh_fn(state, self._data))

    def change_rate(self, state) -> float:
        changed = (state.topic != state.prev_topic) & jnp.asarray(
            self.grid.mask
        )
        return float(jnp.sum(changed) / self.num_tokens)

    @property
    def num_tokens(self) -> int:
        return int(self.grid.mask.sum())

    # -- structural events -------------------------------------------------
    def enable_exclusion(self) -> None:
        if self.dcfg.exclusion_start == self.cfg.exclusion_start:
            return
        self.dcfg = dataclasses.replace(
            self.dcfg, exclusion_start=self.cfg.exclusion_start
        )
        self._build_step()

    def rebuild(self, state):
        return self._rebuild_fn(state, self._data)

    def repad(self, state) -> bool:
        """The PR-3 follow-up: re-resolve shard row capacities against the
        CURRENT counts and re-jit when the padded widths changed. Widths
        are frozen into the compiled step, so without this a row that
        grows past its init capacity is truncated by the sparse tables
        (sampling-quality bias) and a row that sharpens leaves its pad
        oversized; re-resolving fixes both directions."""
        from repro.core.distributed import resolve_dist_row_pads

        if not self.backend.needs_row_pads or (self._user_kw and self._user_kd):
            return False
        probe = dataclasses.replace(
            self.dcfg, max_kw=self._user_kw, max_kd=self._user_kd
        )
        probe = resolve_dist_row_pads(state, probe)
        if (probe.max_kw, probe.max_kd) == (self.dcfg.max_kw, self.dcfg.max_kd):
            return False
        self.dcfg = probe
        self._build_step()
        return True

    @property
    def row_pads(self) -> Tuple[int, int]:
        return (self.dcfg.max_kw, self.dcfg.max_kd)

    def apply_row_pads(self, max_kw: int, max_kd: int) -> bool:
        if (self.dcfg.max_kw, self.dcfg.max_kd) == (max_kw, max_kd):
            return False
        self.dcfg = dataclasses.replace(
            self.dcfg, max_kw=int(max_kw), max_kd=int(max_kd)
        )
        self._build_step()
        return True

    def set_backend(self, name: str, state) -> bool:
        if name == self.dcfg.algorithm:
            return False
        backend = algorithms.get(name)
        if not backend.supports_shard_map:
            raise ValueError(
                f"backend {name!r} does not support shard_map cells; "
                f"cannot swap onto a mesh plan"
            )
        self.backend = backend
        self.dcfg = dataclasses.replace(self.dcfg, algorithm=name)
        if backend.needs_row_pads and not (self.dcfg.max_kw
                                           and self.dcfg.max_kd):
            # coming from a padless backend: resolve capacities against
            # the CURRENT counts before the new step compiles
            from repro.core.distributed import resolve_dist_row_pads

            self.dcfg = resolve_dist_row_pads(state, self.dcfg)
        self._build_step()
        return True

    def set_hyper(self, hyper: LDAHyperParams) -> None:
        from repro.core.distributed import make_dist_llh, make_rebuild_counts

        self.hyper = hyper
        if self._data is None:
            return  # pre-init: init() builds everything against self.hyper
        # the compiled step, llh, and rebuild all close over hyper
        self._llh_fn = make_dist_llh(
            self.mesh, hyper, self.grid.words_per_shard,
            self.grid.docs_per_shard,
        )
        self._rebuild_fn = make_rebuild_counts(
            self.mesh, hyper, self.grid.words_per_shard,
            self.grid.docs_per_shard,
        )
        self._build_step()

    def merge(self, state, topic_map):
        tm = jnp.asarray(topic_map, jnp.int32)
        state = state._replace(
            topic=tm[state.topic],
            prev_topic=tm[state.prev_topic],
        )
        # counts follow the assignments exactly (reuses the rebuild step)
        return self.rebuild(state)

    def host_n_wk(self, state) -> np.ndarray:
        return np.asarray(jax.device_get(state.n_wk))[self.grid.word_perm]

    # -- checkpoint surfaces -----------------------------------------------
    def model_arrays(self, state):
        n_wk = self.host_n_wk(state)
        n_k = np.asarray(jax.device_get(state.n_k))
        return n_wk, n_k

    def checkpoint_tree(self, state) -> Dict[str, Any]:
        return {"topic": state.topic, "iteration": state.iteration}

    def restore(self, state, tree):
        state = state._replace(
            topic=jax.device_put(tree["topic"], state.topic.sharding),
            iteration=jnp.asarray(tree["iteration"]),
        )
        return self.rebuild(state)


# ---------------------------------------------------------------------------
# TrainSession
# ---------------------------------------------------------------------------

class TrainSession:
    """One training run behind one interface, whichever substrate executes
    it. Resolves the backend once, selects the execution plan from
    ``cfg.mesh_shape``, and fires the event schedule after every step."""

    def __init__(self, corpus: Corpus, hyper: LDAHyperParams, cfg: RunConfig,
                 mesh=None, plan: Optional[ExecutionPlan] = None):
        if cfg.sampling_method is None:
            cfg = dataclasses.replace(
                cfg,
                sampling_method="cdf" if cfg.mesh_shape is None else "gumbel",
            )
        self.corpus = corpus
        self.hyper = hyper
        self.cfg = cfg
        self.backend = algorithms.get(cfg.algorithm)  # one resolution
        if plan is not None:
            # an already-prepared plan (see ``with_run_params``); the
            # caller guarantees it was built from the same non-run fields
            self.plan = plan
        elif cfg.mesh_shape is None:
            self.plan = SingleBoxPlan(corpus, hyper, cfg)
        else:
            self.plan = MeshPlan(corpus, hyper, cfg, mesh=mesh)
        # observability + autopilot (DESIGN.md §8) — built ONLY when
        # enabled: with metrics_out=None and autopilot=False nothing here
        # exists and the schedule below is exactly the pre-PR one
        self.telemetry = None
        self._autopilot_policy = None
        self._metrics_sink = None
        if cfg.metrics_out or cfg.autopilot:
            from repro.observe import JsonlSink, MetricsRegistry, TrainTelemetry

            self._metrics_sink = (JsonlSink(cfg.metrics_out)
                                  if cfg.metrics_out else None)
            self.telemetry = TrainTelemetry(
                MetricsRegistry(self._metrics_sink)
            )
        if cfg.autopilot:
            from repro.autotune import TrainAutopilot

            self._autopilot_policy = TrainAutopilot(
                self._autopilot_candidates()
            )
        # model-quality evaluator (repro.eval, DESIGN.md §9) — built ONLY
        # when the cadence is on; corpus stats are computed once here
        self._quality = None
        if cfg.quality_every > 0:
            from repro.eval import QualityEval

            self._quality = QualityEval.from_run_config(corpus, hyper, cfg)
        self.schedule = self._build_schedule()
        self._last_model_save: Optional[int] = None
        self._train_ckpt = None
        if cfg.train_checkpoint_dir:
            from repro.train.checkpoint import CheckpointManager

            self._train_ckpt = CheckpointManager(cfg.train_checkpoint_dir)

    def with_run_params(
        self,
        num_iterations: Optional[int] = None,
        eval_every: Optional[int] = None,
        target_perplexity: Optional[float] = None,
    ) -> "TrainSession":
        """A session sharing this one's prepared plan (backend aux, grid,
        compiled steps) with only run-length / eval schedule fields
        replaced — none of which the plan depends on. This is how the
        deprecated ``LDATrainer.train`` re-parameterizes per call without
        paying ``backend.prepare`` again."""
        cfg = self.cfg
        cfg = dataclasses.replace(
            cfg,
            num_iterations=cfg.num_iterations if num_iterations is None
            else num_iterations,
            eval_every=cfg.eval_every if eval_every is None else eval_every,
            target_perplexity=target_perplexity,
        )
        return TrainSession(self.corpus, self.hyper, cfg, plan=self.plan)

    # -- the session surface -----------------------------------------------
    def init(self, rng: jax.Array, init_topics=None):
        """Build the initial training state for this session's plan.

        Args:
            rng: a JAX PRNG key; seeds the topic-assignment init and the
                per-iteration sampling streams.
            init_topics: optional (E,) int32 initial topic per token
                (corpus edge order) — e.g. from ``repro.core.init``'s
                sparse initializers. Default: uniform random topics.

        Returns:
            The plan's state object — a ``CGSState`` (single-box: arrays
            ``n_wk (W, K)``, ``n_kd (D, K)``, ``n_k (K,)``, ``topic
            (E,)``) or the mesh plan's sharded equivalent. Treat it as
            opaque: pass it to ``step``/``run``/``metrics``/``save_model``.
        """
        return self.plan.init(rng, init_topics=init_topics)

    def step(self, state):
        """Run exactly one CGS iteration (every token resampled once).

        Args:
            state: the state returned by :meth:`init` or a previous
                ``step``.

        Returns:
            The post-iteration state, with ``state.iteration``
            incremented. No schedule actions fire — that is :meth:`run`'s
            job; ``step`` is the raw sampling move for callers that drive
            their own loop (benchmarks, tests).
        """
        return self.plan.step(state)

    def llh(self, state) -> float:
        """Joint log-likelihood of the current counts (one full pass)."""
        return self.plan.llh(state)

    def perplexity(self, state) -> float:
        """``exp(-llh / num_tokens)`` — one likelihood pass, lower is
        better."""
        return math.exp(-self.plan.llh(state) / self.plan.num_tokens)

    def metrics(self, state) -> Dict[str, float]:
        """Evaluate the state once; return the standard metric dict.

        Returns:
            ``{"llh", "perplexity", "change_rate"}`` — joint
            log-likelihood (one pass, perplexity derived from it, never a
            second pass) and the fraction of tokens whose topic changed
            in the last iteration (the paper's convergence signal).
        """
        llh = self.plan.llh(state)
        return {
            "llh": llh,
            "perplexity": math.exp(-llh / self.plan.num_tokens),
            "change_rate": self.plan.change_rate(state),
        }

    @property
    def row_pads(self) -> Tuple[int, int]:
        return self.plan.row_pads

    def save_model(self, state, directory: Optional[str] = None) -> str:
        """Checkpoint the trained model (N_wk/N_k + hyper) for serving —
        ``launch/serve_lda.py`` / ``FrozenLDAModel.from_checkpoint`` load
        exactly this artifact; the mesh plan un-permutes the grid's
        relabeled word ids first."""
        from repro.train.checkpoint import save_lda_model

        directory = directory or self.cfg.checkpoint_dir
        if not directory:
            raise ValueError("no checkpoint directory configured")
        n_wk, n_k = self.plan.model_arrays(state)
        extra = {"algorithm": self.cfg.algorithm}
        if self.cfg.mesh_shape is not None:
            extra["mesh"] = list(self.cfg.mesh_shape)
        path = save_lda_model(
            directory, n_wk, n_k, self.hyper,
            step=int(state.iteration), extra_metadata=extra,
        )
        self._last_model_save = int(state.iteration)
        return path

    def merge_duplicates(self, state):
        """Detect + merge duplicate topics (paper §4.3). Host-side
        detection on the current N_w|k; a trivial map is a no-op."""
        topic_map = duplicate_topic_map(
            self.plan.host_n_wk(state), self.cfg.merge_threshold
        )
        if (topic_map == np.arange(topic_map.shape[0])).all():
            return state
        return self.plan.merge(state, topic_map)

    # -- schedule construction ----------------------------------------------
    def _build_schedule(self) -> Schedule:
        cfg = self.cfg
        sched = Schedule()
        # structural events first, so evals/checkpoints on the same
        # iteration observe post-event state
        if cfg.exclusion_start > 0:
            sched.add(ScheduledAction(
                "exclusion_on",
                lambda ctx, st: (self.plan.enable_exclusion(), st)[1],
                at=cfg.exclusion_start,
            ))
        if cfg.rebuild_every > 0:
            sched.add(ScheduledAction(
                "rebuild", lambda ctx, st: self.plan.rebuild(st),
                every=cfg.rebuild_every,
            ))
            # with the autopilot on, row capacity belongs to policy (its
            # RowRepad decisions) — registering the measured re-pad too
            # would have two owners fighting over the same knob
            if (self.backend.needs_row_pads
                    and not (cfg.max_kw and cfg.max_kd)
                    and not cfg.autopilot):
                def _repad(ctx, st):
                    if self.plan.repad(st):
                        ctx.metrics["row_pads"] = self.plan.row_pads
                    return st

                sched.add(ScheduledAction(
                    "repad", _repad, every=cfg.rebuild_every,
                ))
        if cfg.autopilot:
            sched.add(ScheduledAction(
                "autopilot", self._autopilot_action,
                every=cfg.autopilot_every or cfg.rebuild_every or 10,
            ))
        if cfg.hyper_every > 0:
            # structural: evals/quality on the same iteration score the
            # post-update hypers (same convention as rebuild/merge)
            sched.add(ScheduledAction(
                "hyper", self._hyper_action, every=cfg.hyper_every,
            ))
        if cfg.merge_every > 0:
            sched.add(ScheduledAction(
                "merge", lambda ctx, st: self.merge_duplicates(st),
                every=cfg.merge_every,
            ))
        if cfg.eval_every > 0:
            def _eval(ctx, st):
                # one likelihood pass; perplexity derives from it (the
                # old trainer paid a SECOND full pass for the target
                # check) — ``metrics()`` is the single derivation
                ctx.metrics.update(self.metrics(st))
                if (cfg.target_perplexity is not None
                        and ctx.metrics["perplexity"]
                        <= cfg.target_perplexity):
                    ctx.stop = True
                return st

            sched.add(ScheduledAction("eval", _eval, every=cfg.eval_every))
        if cfg.quality_every > 0:
            sched.add(ScheduledAction(
                "quality", self._quality_action, every=cfg.quality_every,
            ))
        if cfg.checkpoint_dir and cfg.checkpoint_every > 0:
            sched.add(ScheduledAction(
                "model_checkpoint",
                lambda ctx, st: (self.save_model(st), st)[1],
                every=cfg.checkpoint_every,
            ))
        if self.cfg.train_checkpoint_dir and cfg.train_checkpoint_every > 0:
            sched.add(ScheduledAction(
                "train_checkpoint",
                lambda ctx, st: (self._save_train_ckpt(st), st)[1],
                every=cfg.train_checkpoint_every,
            ))
        if self.telemetry is not None:
            # last, so the record carries whatever the earlier actions
            # contributed this iteration (eval metrics, decisions)
            sched.add(ScheduledAction(
                "telemetry", self._telemetry_action,
                every=max(1, cfg.metrics_every),
            ))
        return sched

    # -- autopilot actuation (DESIGN.md §8.4) --------------------------------
    def _autopilot_candidates(self) -> Tuple[str, ...]:
        """Backends the autopilot may pick among: the configured one plus
        the three decomposition representatives (doc-side, word-side,
        hybrid), restricted to mesh-capable ones on a mesh plan."""
        cands = [self.cfg.algorithm]
        for name in ("zen_sparse", "sparselda", "zen_hybrid"):
            if name in cands or name not in algorithms.registered():
                continue
            if (self.cfg.mesh_shape is not None
                    and not algorithms.get(name).supports_shard_map):
                continue
            cands.append(name)
        return tuple(cands)

    def _autopilot_action(self, ctx: ActionContext, state):
        """Measure → decide → act, at a rebuild point. The safety
        contract: counts are rebuilt exactly from the assignments FIRST,
        so a backend swap or capacity change never bakes in count drift;
        the swap itself is the plan's repad re-jit move."""
        state = self.plan.rebuild(state)
        plan = self.plan
        if isinstance(plan, MeshPlan):
            # mesh widths are frozen into the compiled step — always
            # policy-owned when the backend uses padded rows
            pads_tunable = plan.backend.needs_row_pads
        else:
            # single-box auto pads (0) re-resolve every sweep already;
            # only explicit (possibly mis-sized) widths are worth tuning
            pads_tunable = (plan.backend.needs_row_pads
                            and all(p > 0 for p in plan.row_pads))
        decisions = self._autopilot_policy.decide(
            self.telemetry.window(),
            current_backend=plan.backend.name,
            current_pads=plan.row_pads,
            num_topics=self.hyper.num_topics,
            pads_tunable=pads_tunable,
        )
        from repro.autotune.policy import BackendSwitch, RowRepad

        for d in decisions:
            if isinstance(d, BackendSwitch):
                applied = plan.set_backend(d.backend, state)
                if applied:
                    self.backend = plan.backend
            elif isinstance(d, RowRepad):
                applied = plan.apply_row_pads(d.max_kw, d.max_kd)
            else:  # pragma: no cover - no other training decision types
                applied = False
            rec = d.to_record()
            rec.update(iteration=int(state.iteration), applied=applied)
            self.telemetry.emit_decision(rec)
            ctx.metrics.setdefault("autopilot", []).append(rec)
        return state

    # -- model quality + Alg. 5 hyper actions (DESIGN.md §9) -----------------
    def _quality_action(self, ctx: ActionContext, state):
        """Score the frozen model snapshot (coherence + left-to-right)
        into the iteration metrics; read-only, never touches state."""
        n_wk, n_k = self.plan.model_arrays(state)
        ctx.metrics.update(
            self._quality.evaluate(n_wk, n_k, int(state.iteration))
        )
        return state

    def _hyper_action(self, ctx: ActionContext, state):
        """One Alg. 5 hyper move: Minka fixed-point alpha + beta anneal
        against the CURRENT doc-topic counts. A changed hyper rebuilds
        whatever the plan compiled against the old one (``set_hyper``);
        an unchanged one is a recorded no-op."""
        from repro.core.hyper import optimize_hyper

        cfg = self.cfg
        n_kd = np.asarray(jax.device_get(state.n_kd))
        new_hyper = optimize_hyper(
            self.hyper, n_kd,
            update_alpha=cfg.hyper_alpha,
            beta_anneal=cfg.hyper_beta_anneal,
            beta_floor=cfg.hyper_beta_floor,
        )
        if new_hyper is not self.hyper:
            self.hyper = new_hyper
            self.plan.set_hyper(new_hyper)
            if self._quality is not None:
                self._quality.hyper = new_hyper  # l2r alpha_k follows
            ctx.metrics["hyper"] = {
                "alpha": new_hyper.alpha, "beta": new_hyper.beta,
            }
        return state

    def _telemetry_action(self, ctx: ActionContext, state):
        self.telemetry.record_iteration(
            self.plan, state, int(state.iteration), ctx.metrics
        )
        return state

    # -- elastic training checkpoints ---------------------------------------
    def _save_train_ckpt(self, state) -> None:
        self._train_ckpt.save(
            int(state.iteration), self.plan.checkpoint_tree(state), {}
        )

    def _maybe_restore(self, state):
        if self._train_ckpt is None:
            return state
        target = jax.tree_util.tree_map(lambda _: 0,
                                        self.plan.checkpoint_tree(state))
        got = self._train_ckpt.restore_latest(target)
        if got is None:
            return state
        tree, _meta, _step = got
        return self.plan.restore(state, tree)

    # -- the loop ------------------------------------------------------------
    def run(
        self,
        rng: Optional[jax.Array] = None,
        state=None,
        callback: Optional[Callable[[Any, Dict], None]] = None,
        init_topics=None,
    ):
        """Run to ``cfg.num_iterations`` (absolute), firing the schedule
        after every step. ``callback(state, metrics)`` is invoked each
        iteration with whatever the due actions contributed (empty dict on
        quiet iterations). Returns the final state."""
        cfg = self.cfg
        if state is None:
            if rng is None:
                raise ValueError("run() needs an rng or an initial state")
            state = self.init(rng, init_topics=init_topics)
        state = self._maybe_restore(state)
        if cfg.exclusion_start and int(state.iteration) >= cfg.exclusion_start:
            self.plan.enable_exclusion()  # resumed past the enable point
        ctx = ActionContext(session=self)
        restore_signals = self._install_signals(ctx)
        try:
            while int(state.iteration) < cfg.num_iterations and not ctx.stop:
                state = self.plan.step(state)
                ctx.metrics = {}
                state = self.schedule.fire(ctx, state, int(state.iteration))
                if callback is not None:
                    callback(state, ctx.metrics)
        finally:
            restore_signals()
        # final surfaces: model checkpoint if not already saved at this
        # iteration; training checkpoint on preemption-style stops
        if cfg.checkpoint_dir and self._last_model_save != int(state.iteration):
            self.save_model(state)
        if self._train_ckpt is not None and ctx.stop:
            self._save_train_ckpt(state)
        return state

    def _install_signals(self, ctx: ActionContext):
        """SIGTERM/SIGINT -> finish the current iteration, checkpoint, and
        return (preemption handling). Returns a restore callback — the
        previous handlers come back once the loop exits, so a library
        caller's Ctrl-C behaves normally between runs."""

        def handler(signum, frame):
            ctx.stop = True

        try:
            prev = {
                sig: signal.signal(sig, handler)
                for sig in (signal.SIGTERM, signal.SIGINT)
            }
        except ValueError:
            return lambda: None  # not in the main thread (tests)

        def restore():
            for sig, old in prev.items():
                try:
                    signal.signal(sig, old)
                except (ValueError, TypeError):
                    pass

        return restore
