"""HLO byte/flop breakdown by opcode — the dry-run 'profiler'.

With no TPU wall-clock, the per-op result-shape bytes of the compiled HLO
are the profile: they show *where* the memory roofline term comes from
(e.g. S^2 attention materialization) and which collectives move the bytes.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Tuple

from repro.launch.roofline import _SHAPE_RE, _DTYPE_BYTES, _while_trip_counts

_OP_RE = re.compile(r"=\s*((?:\([^)]*\)|[\w\[\],{}:#\s*]+?))\s+([\w\-]+)\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def bytes_by_op(hlo_text: str, top: int = 25) -> Dict[str, int]:
    """Sum result-shape bytes per opcode (fusion-unaware upper bound —
    mirrors what cost_analysis 'bytes accessed' counts)."""
    agg: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        line = line.strip()
        if " = " not in line:
            continue
        rhs = line.split(" = ", 1)[1]
        m = re.match(r"((?:\([^)]*\)|[^ ]+))\s+([\w\-]+)\(", rhs)
        if not m:
            continue
        shape_str, op = m.groups()
        agg[op] += _shape_bytes(shape_str)
    return dict(sorted(agg.items(), key=lambda kv: -kv[1])[:top])


def biggest_tensors(hlo_text: str, top: int = 15):
    """The largest individual result buffers with their op + shape."""
    rows = []
    for line in hlo_text.splitlines():
        line = line.strip()
        if " = " not in line:
            continue
        rhs = line.split(" = ", 1)[1]
        m = re.match(r"((?:\([^)]*\)|[^ ]+))\s+([\w\-]+)\(", rhs)
        if not m:
            continue
        shape_str, op = m.groups()
        b = _shape_bytes(shape_str)
        rows.append((b, op, shape_str[:80]))
    rows.sort(reverse=True)
    return rows[:top]
