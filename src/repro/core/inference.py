"""Model inference for new documents (paper §4.3 "Model inference").

* ``cgs_infer``   — run CGS sweeps over a new document's tokens with the
  word-topic model frozen; returns the inferred doc-topic distribution.
* ``rtlda_infer`` — RT-LDA (paper [27]): replace the sampling operation with
  ``argmax`` of the conditional — deterministic, one pass per sweep, built
  for millisecond-latency online serving.
* ``rtlda_assign`` — the masked padded-row form of the RT-LDA decode that
  the serving engine's latency mode vmaps over slot batches
  (``repro.serving.lda_engine``, DESIGN.md §5.1): returns the final topic
  assignments and doc-topic counts instead of theta, and ignores padding
  positions exactly, so a padded decode is bit-identical to the unpadded
  ``rtlda_infer`` on the live prefix.

``cgs_infer`` is the **single-document oracle** for the batched serving
subsystem (``repro.serving.lda_engine``): the default backend
``infer_sweep`` (``repro.algorithms.base._dense_infer_sweep``) replicates
its conditional, cdf inversion, and key schedule draw-for-draw, and
``tests/test_lda_engine.py`` asserts the served thetas are bit-equal to
this function. Change the sampling math or RNG layout here only in
lockstep with that default. ``rtlda_assign`` is the corresponding oracle
for the engine's **latency mode** (``tests/test_latency_serving.py``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import LDAHyperParams


def _doc_conditional(
    n_wk: jax.Array,  # (W, K) frozen model
    n_k: jax.Array,  # (K,)
    n_kd: jax.Array,  # (K,) current doc-topic counts
    words: jax.Array,  # (L,) token word ids
    hyper: LDAHyperParams,
) -> jax.Array:
    w_total = n_wk.shape[0]
    alpha_k = hyper.alpha_k(n_k)
    denom = n_k.astype(jnp.float32) + w_total * hyper.beta
    phi = (n_wk[words].astype(jnp.float32) + hyper.beta) / denom[None, :]
    return phi * (n_kd.astype(jnp.float32) + alpha_k)[None, :]


def cgs_infer(
    rng: jax.Array,
    n_wk: jax.Array,
    n_k: jax.Array,
    words: jax.Array,
    hyper: LDAHyperParams,
    num_sweeps: int = 10,
) -> jax.Array:
    """Infer theta (K,) for one document of ``words`` by CGS with frozen phi."""
    l = words.shape[0]
    k = hyper.num_topics
    z0 = jax.random.randint(rng, (l,), 0, k, dtype=jnp.int32)
    n_kd0 = jnp.zeros((k,), jnp.int32).at[z0].add(1)

    w_total = n_wk.shape[0]
    alpha_k = hyper.alpha_k(n_k)
    denom = n_k.astype(jnp.float32) + w_total * hyper.beta
    phi = (n_wk[words].astype(jnp.float32) + hyper.beta) / denom[None, :]

    def sweep(carry, key):
        z, n_kd = carry
        # phi is frozen; self-exclusion applies to n_kd only.
        onehot = jax.nn.one_hot(z, k, dtype=jnp.int32)
        n_kd_excl = (n_kd[None, :] - onehot).astype(jnp.float32)
        probs = phi * (n_kd_excl + alpha_k[None, :])
        cdf = jnp.cumsum(probs, axis=-1)
        u = jax.random.uniform(key, (l, 1))
        z_new = jnp.minimum(
            jnp.sum(cdf < u * cdf[:, -1:], axis=-1), k - 1
        ).astype(jnp.int32)
        n_kd_new = (
            n_kd
            + jnp.zeros_like(n_kd).at[z_new].add(1)
            - jnp.zeros_like(n_kd).at[z].add(1)
        )
        return (z_new, n_kd_new), None

    keys = jax.random.split(rng, num_sweeps)
    (z, n_kd), _ = jax.lax.scan(sweep, (z0, n_kd0), keys)
    theta = (n_kd.astype(jnp.float32) + alpha_k) / (l + jnp.sum(alpha_k))
    return theta


def rtlda_assign(
    n_wk: jax.Array,
    n_k: jax.Array,
    words: jax.Array,
    mask: jax.Array,
    hyper: LDAHyperParams,
    num_sweeps: int = 3,
) -> tuple:
    """RT-LDA decode on one (possibly padded) token row.

    Args:
        n_wk: ``(W, K)`` int32 frozen word-topic counts.
        n_k: ``(K,)`` int32 frozen topic totals.
        words: ``(L,)`` int32 token word ids; padding positions may hold
            any in-vocabulary id (they are ignored via ``mask``).
        mask: ``(L,)`` bool; True marks live tokens. Padding never enters
            the doc-topic counts, so the result on the live prefix is
            bit-identical for every pad width (the latency-mode
            padding-exactness contract, DESIGN.md §5.1).
        hyper: model hyper-parameters (``num_topics``, alpha, beta).
        num_sweeps: full deterministic argmax passes after the greedy
            initial assignment; 0 returns the initial assignment.

    Returns:
        ``(z, n_kd)``: ``z`` ``(L,)`` int32 final topic per position
        (garbage at padding — mask it), ``n_kd`` ``(K,)`` int32 doc-topic
        counts over live tokens only.

    Every step is a deterministic argmax of the frozen-phi conditional
    ``(N_w|k + beta)/(N_k + W*beta) * (N_k|d + alpha_k)`` — no RNG, no
    burn-in, no thinning. One fused ``scan`` of ``num_sweeps`` passes, so
    a jitted caller pays a single dispatch per decode.
    """
    k = hyper.num_topics
    live = mask.astype(jnp.int32)

    def count(z):
        return jnp.zeros((k,), jnp.int32).at[z].add(live)

    probs0 = _doc_conditional(
        n_wk, n_k, jnp.zeros((k,), jnp.int32), words, hyper
    )
    z = jnp.argmax(probs0, axis=-1).astype(jnp.int32)

    def sweep(z, _):
        probs = _doc_conditional(n_wk, n_k, count(z), words, hyper)
        return jnp.argmax(probs, axis=-1).astype(jnp.int32), None

    z, _ = jax.lax.scan(sweep, z, None, length=num_sweeps)
    return z, count(z)


def rtlda_infer(
    n_wk: jax.Array,
    n_k: jax.Array,
    words: jax.Array,
    hyper: LDAHyperParams,
    num_sweeps: int = 3,
) -> jax.Array:
    """RT-LDA: deterministic max-assignment sweeps (paper §4.3).

    Args:
        n_wk: ``(W, K)`` int32 frozen word-topic counts.
        n_k: ``(K,)`` int32 frozen topic totals.
        words: ``(L,)`` int32 token word ids of one document.
        hyper: model hyper-parameters.
        num_sweeps: deterministic passes (see :func:`rtlda_assign`).

    Returns:
        theta ``(K,)`` float32 — the smoothed doc-topic distribution
        ``(N_k|d + alpha_k) / (L + sum(alpha))``.
    """
    l = words.shape[0]
    _, n_kd = rtlda_assign(
        n_wk, n_k, words, jnp.ones((l,), bool), hyper, num_sweeps
    )
    alpha_k = hyper.alpha_k(n_k)
    return (n_kd.astype(jnp.float32) + alpha_k) / (l + jnp.sum(alpha_k))
