"""Render the §Roofline table from results/dryrun.json.

    PYTHONPATH=src python -m repro.launch.table [--results results/dryrun.json]

Per (arch x shape), single-pod mesh: the three roofline terms (seconds),
dominant bottleneck, MODEL_FLOPS, useful-compute fraction, and the v5e
roofline fraction (model flops per device / (peak * step lower bound)).
LM terms use the depth-fitted costs (rooffit.py); LDA cells use the raw
compile (no scan undercount).
"""
from __future__ import annotations

import argparse
import json
from typing import Dict

from repro.configs import SHAPES, get_config, list_archs, shapes_for
from repro.configs.base import LDAArchConfig
from repro.launch.roofline import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    model_flops,
    roofline_terms,
)

CHIPS = 256  # single-pod roofline table (16 x 16)


def _advice(bottleneck: str, arch: str, shape: str, ratio: float) -> str:
    if bottleneck == "collective":
        return ("shrink collective payload: delta/grad compression, "
                "overlap collectives with compute, rebalance TP vs DP")
    if bottleneck == "memory":
        if "decode" in shape or "long" in shape:
            return ("KV/cache traffic bound: shrink cache dtype (int8/fp8), "
                    "latent KV (MLA-style), or raise batch to amortize "
                    "weight reads")
        return ("fuse elementwise chains; avoid remat over matmul-heavy "
                "blocks; bf16 activations end-to-end")
    if ratio < 0.5:
        return ("compute-bound but <50% useful: reduce remat recompute "
                "and one-hot/capacity MoE overhead")
    return "compute-bound and mostly useful work: near roofline for this mix"


def build_rows(results: Dict) -> list:
    rows = []
    for arch in list_archs():
        cfg = get_config(arch)
        for shape_name in shapes_for(cfg):
            base_key = f"{arch}|{shape_name}|single"
            fit_key = f"{arch}|{shape_name}|fit"
            rec = results.get(base_key)
            if rec is None or not rec.get("ok"):
                continue
            fit = results.get(fit_key)
            use = dict(rec)
            fitted = False
            if fit is not None and fit.get("ok"):
                use.update({
                    "flops_per_device": fit["flops_per_device"],
                    "bytes_per_device": fit["bytes_per_device"],
                    "collective_bytes_per_device":
                        fit["collective_bytes_per_device"],
                })
                fitted = True
            terms = roofline_terms(use)
            if isinstance(cfg, LDAArchConfig):
                mf = model_flops(cfg, None)
            else:
                mf = model_flops(cfg, SHAPES[shape_name])
            mf_dev = mf / CHIPS
            hlo = use["flops_per_device"]
            useful = mf_dev / hlo if hlo else 0.0
            bound = terms["step_lower_bound_s"]
            roofline_frac = (mf_dev / PEAK_FLOPS) / bound if bound else 0.0
            rows.append({
                "arch": arch,
                "shape": shape_name,
                "fitted": fitted,
                "compute_s": terms["compute_s"],
                "memory_s": terms["memory_s"],
                "collective_s": terms["collective_s"],
                "bottleneck": terms["bottleneck"],
                "model_flops_dev": mf_dev,
                "useful_frac": useful,
                "roofline_frac": roofline_frac,
                "advice": _advice(terms["bottleneck"], arch, shape_name,
                                  useful),
                "mem_analysis": rec.get("memory_analysis") or {},
            })
    return rows


def render(rows: list) -> str:
    out = ["| arch | shape | compute (s) | memory (s) | collective (s) | "
           "bottleneck | MODEL_FLOPs/dev | useful | roofline |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"**{r['bottleneck']}** | {r['model_flops_dev']:.2e} | "
            f"{r['useful_frac']:.2f} | {r['roofline_frac']:.2f} |"
        )
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results/dryrun.json")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    with open(args.results) as f:
        results = json.load(f)
    rows = build_rows(results)
    print(render(rows))
    print()
    for r in rows:
        print(f"- {r['arch']} x {r['shape']}: {r['bottleneck']}-bound -> "
              f"{r['advice']}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
