"""``zen_sparse`` — the faithful padded-sparse ZenLDA sampler (paper Alg. 2)
behind the backend interface. The heavy lifting stays in
``core.zen_sparse``; this wrapper only adapts the contract.

Mesh-capable: the sampler is a ``CellBackend``, so the same padded-row
machinery runs per (word-shard x doc-shard) cell under ``shard_map`` —
tables are built from the *local* count blocks with shard-relative padded
capacities, and the single-box sweep is the whole corpus as one cell.
"""
from __future__ import annotations

from repro.algorithms.base import CellBackend, SamplerKnobs, kernel_dispatch
from repro.algorithms.registry import register
from repro.core.zen_sparse import zen_sparse_cell


@register("zen_sparse")
class ZenSparse(CellBackend):
    """Alias tables + padded-sparse rows; work/token tracks O(K_d)."""

    needs_row_pads = True

    def cell_sweep(
        self, key, word, doc, z_old, mask, n_wk, n_kd, n_k, hyper,
        num_words_pad, knobs: SamplerKnobs,
    ):
        knobs = self.resolve_cell_knobs(knobs, hyper)
        return zen_sparse_cell(
            key, word, doc, z_old, n_wk, n_kd, n_k, hyper, num_words_pad,
            knobs.max_kw, knobs.max_kd,
            use_kernel=kernel_dispatch(knobs.kernels),
            bt=knobs.bt, bs=knobs.bs,
        )
