"""Training substrate: sessions/schedules (LDA), LM train step, optimizers,
checkpointing, and the legacy fault-tolerant loop.

Re-exports are lazy (PEP 562) so importing one corner — e.g.
``repro.train.session`` from the core trainer shim — never pulls the LM
model stack in.
"""
_EXPORTS = {
    "RunConfig": ("repro.train.session", "RunConfig"),
    "TrainSession": ("repro.train.session", "TrainSession"),
    "StreamingSession": ("repro.train.online", "StreamingSession"),
    "Schedule": ("repro.train.schedule", "Schedule"),
    "ScheduledAction": ("repro.train.schedule", "ScheduledAction"),
    "adafactor_init": ("repro.train.optimizer", "adafactor_init"),
    "adafactor_update": ("repro.train.optimizer", "adafactor_update"),
    "adamw_init": ("repro.train.optimizer", "adamw_init"),
    "adamw_update": ("repro.train.optimizer", "adamw_update"),
    "make_optimizer": ("repro.train.optimizer", "make_optimizer"),
    "make_train_step": ("repro.train.train_step", "make_train_step"),
    "TrainState": ("repro.train.train_step", "TrainState"),
}


def __getattr__(name):
    try:
        module, attr = _EXPORTS[name]
    except KeyError:
        raise AttributeError(name) from None
    import importlib

    return getattr(importlib.import_module(module), attr)


def __dir__():
    return sorted(_EXPORTS)
