"""Count maintenance: build/delta conservation invariants (property)."""
import jax.numpy as jnp
import numpy as np
from helpers import given, settings, st  # hypothesis, or the fallback shim

from repro.core.counts import build_counts, delta_counts, doc_lengths


@st.composite
def assignments(draw):
    e = draw(st.integers(1, 60))
    w = draw(st.integers(2, 10))
    d = draw(st.integers(2, 10))
    k = draw(st.integers(2, 8))
    word = draw(st.lists(st.integers(0, w - 1), min_size=e, max_size=e))
    doc = draw(st.lists(st.integers(0, d - 1), min_size=e, max_size=e))
    z0 = draw(st.lists(st.integers(0, k - 1), min_size=e, max_size=e))
    z1 = draw(st.lists(st.integers(0, k - 1), min_size=e, max_size=e))
    return w, d, k, np.asarray(word, np.int32), np.asarray(doc, np.int32), \
        np.asarray(z0, np.int32), np.asarray(z1, np.int32)


@settings(max_examples=40, deadline=None)
@given(assignments())
def test_build_and_delta_conservation(data):
    w, d, k, word, doc, z0, z1 = data
    n_wk, n_kd, n_k = build_counts(
        jnp.asarray(word), jnp.asarray(doc), jnp.asarray(z0), w, d, k
    )
    e = word.shape[0]
    assert int(jnp.sum(n_wk)) == e
    assert int(jnp.sum(n_kd)) == e
    np.testing.assert_array_equal(np.asarray(jnp.sum(n_wk, 0)), np.asarray(n_k))
    np.testing.assert_array_equal(np.asarray(jnp.sum(n_kd, 0)), np.asarray(n_k))

    d_wk, d_kd, d_k = delta_counts(
        jnp.asarray(word), jnp.asarray(doc), jnp.asarray(z0), jnp.asarray(z1),
        w, d, k,
    )
    n_wk2, n_kd2, n_k2 = build_counts(
        jnp.asarray(word), jnp.asarray(doc), jnp.asarray(z1), w, d, k
    )
    # delta aggregation (§5.2) reconstructs the new counts exactly
    np.testing.assert_array_equal(np.asarray(n_wk + d_wk), np.asarray(n_wk2))
    np.testing.assert_array_equal(np.asarray(n_kd + d_kd), np.asarray(n_kd2))
    np.testing.assert_array_equal(np.asarray(n_k + d_k), np.asarray(n_k2))


@settings(max_examples=20, deadline=None)
@given(assignments())
def test_delta_zero_where_unchanged(data):
    w, d, k, word, doc, z0, _ = data
    d_wk, d_kd, d_k = delta_counts(
        jnp.asarray(word), jnp.asarray(doc), jnp.asarray(z0), jnp.asarray(z0),
        w, d, k,
    )
    assert int(jnp.sum(jnp.abs(d_wk))) == 0
    assert int(jnp.sum(jnp.abs(d_kd))) == 0
    assert int(jnp.sum(jnp.abs(d_k))) == 0


def test_masked_tokens_inert():
    word = jnp.asarray([0, 1, 1], jnp.int32)
    doc = jnp.asarray([0, 0, 1], jnp.int32)
    z = jnp.asarray([0, 1, 2], jnp.int32)
    mask = jnp.asarray([True, True, False])
    n_wk, n_kd, n_k = build_counts(word, doc, z, 2, 2, 3, mask=mask)
    assert int(jnp.sum(n_k)) == 2
    np.testing.assert_array_equal(
        np.asarray(doc_lengths(doc, 2, mask=mask)), [2, 0]
    )
