"""Fault-tolerant checkpointing with elastic restore.

Format: one directory per step, ``step_<n>/`` containing
  manifest.json   — pytree structure, shapes, dtypes, sha256 per leaf, and
                    user metadata (mesh shape, config name, rng, iteration)
  <leaf_id>.npy   — raw leaf data (written atomically: tmp + rename)
  COMMITTED       — sentinel written last; restores ignore uncommitted dirs

Elasticity: leaves are saved as *global* arrays (gathered); on restore they
are device_put against whatever shardings the *new* mesh prescribes — so a
job can restart on a different pod count (DESIGN.md §3.2). For LDA, the
checkpoint stores only per-edge topic assignments + rng: counts are rebuilt
by ``make_rebuild_counts`` for any partitioning, which makes LDA restore
trivially elastic.

``CheckpointManager.restore_latest`` scans for the newest committed step,
verifying checksums — a torn/corrupt checkpoint (killed mid-write) is
skipped, which is the node-failure story: the job resumes from the last
good step.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _leaves_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        out.append((name or "leaf", leaf))
    return out, treedef


def save_checkpoint(
    directory: str,
    step: int,
    tree: Any,
    metadata: Optional[Dict] = None,
) -> str:
    """Atomic, checksummed save of a pytree of (possibly sharded) arrays."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat, treedef = _leaves_with_paths(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "metadata": metadata or {},
        "leaves": [],
    }
    for i, (name, leaf) in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {
                "name": name,
                "file": fname,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "sha256": hashlib.sha256(arr.tobytes()).hexdigest(),
            }
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "COMMITTED"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def _verify_and_load(path: str) -> Tuple[list, Dict]:
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves = []
    for entry in manifest["leaves"]:
        arr = np.load(os.path.join(path, entry["file"]))
        if hashlib.sha256(arr.tobytes()).hexdigest() != entry["sha256"]:
            raise IOError(f"checksum mismatch in {path}/{entry['file']}")
        leaves.append(arr)
    return leaves, manifest


def restore_checkpoint(
    path: str,
    target: Any,
    shardings: Optional[Any] = None,
) -> Tuple[Any, Dict]:
    """Restore into the structure of ``target``; device_put against
    ``shardings`` (pytree matching target) if given — the elastic path."""
    leaves, manifest = _verify_and_load(path)
    treedef = jax.tree_util.tree_structure(target)
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: hasattr(x, "spec")
        )
        leaves = [
            jax.device_put(l, s) for l, s in zip(leaves, sh_leaves)
        ]
    else:
        leaves = [jax.numpy.asarray(l) for l in leaves]
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["metadata"]


# -- trained-model checkpoints (the serving handoff) -----------------------
# A *model* checkpoint is the frozen serving artifact: N_w|k + N_k + the
# hyper-parameters, unlike the training checkpoints above which store
# assignments (counts rebuild elastically). ``launch/train.py
# --checkpoint-dir`` writes these; ``serving.FrozenLDAModel.from_checkpoint``
# / ``launch/serve_lda.py`` read them.
_LDA_MODEL_KIND = "lda_model"


def save_lda_model(
    directory: str,
    n_wk,
    n_k,
    hyper,
    step: int = 0,
    extra_metadata: Optional[Dict] = None,
    keep: int = 3,
) -> str:
    """Checkpoint a trained model for serving (atomic + checksummed)."""
    meta = {
        "kind": _LDA_MODEL_KIND,
        "hyper": dataclasses.asdict(hyper),
        **(extra_metadata or {}),
    }
    manager = CheckpointManager(directory, keep=keep)
    return manager.save(step, {"n_k": n_k, "n_wk": n_wk}, meta)


def load_lda_model(directory: str):
    """Newest committed model checkpoint -> (n_wk, n_k, hyper, meta, step).

    Raises ``FileNotFoundError`` when the directory holds no valid model
    checkpoint.
    """
    from repro.core.types import LDAHyperParams

    manager = CheckpointManager(directory)
    # placeholder leaves (None would flatten to an empty pytree)
    got = manager.restore_latest({"n_k": 0, "n_wk": 0})
    if got is None:
        raise FileNotFoundError(
            f"no committed LDA model checkpoint under {directory!r}"
        )
    tree, meta, step = got
    if meta.get("kind") != _LDA_MODEL_KIND:
        raise FileNotFoundError(
            f"checkpoint under {directory!r} is not an LDA model "
            f"(kind={meta.get('kind')!r}); train with --checkpoint-dir"
        )
    hyper = LDAHyperParams(**meta["hyper"])
    return tree["n_wk"], tree["n_k"], hyper, meta, step


def _parse_step(dirname: str) -> Optional[int]:
    """``step_<n>`` -> n, or None for anything else (tmp dirs, stray
    names like ``step_final``). Restores must never crash on a foreign
    directory that happens to share the prefix."""
    if not dirname.startswith("step_") or dirname.endswith(".tmp"):
        return None
    try:
        return int(dirname[5:])
    except ValueError:
        return None


def committed_steps(directory: str):
    """All committed checkpoint dirs under ``directory`` as ``(step,
    path)`` pairs, sorted **numerically by parsed step** — never
    lexicographically by dirname, so step 10 restores after step 9 and
    step 100 after step 99 (zero-padded names happen to sort either way,
    but un-padded writers exist and the restore order must not depend on
    the padding). Safe on a missing directory (returns [])."""
    try:
        names = os.listdir(directory)
    except OSError:
        return []
    out = []
    for d in names:
        step = _parse_step(d)
        full = os.path.join(directory, d)
        if step is not None and os.path.exists(
            os.path.join(full, "COMMITTED")
        ):
            out.append((step, full))
    return sorted(out, key=lambda sp: sp[0])


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def save(self, step: int, tree: Any, metadata: Optional[Dict] = None):
        path = save_checkpoint(self.directory, step, tree, metadata)
        self._gc()
        return path

    def _steps(self):
        return committed_steps(self.directory)

    def _gc(self):
        steps = self._steps()
        for _, path in steps[: -self.keep]:
            shutil.rmtree(path, ignore_errors=True)

    def restore_latest(
        self, target: Any, shardings: Optional[Any] = None
    ) -> Optional[Tuple[Any, Dict, int]]:
        """Newest committed + checksum-valid checkpoint, or None."""
        for step, path in reversed(self._steps()):
            try:
                tree, meta = restore_checkpoint(path, target, shardings)
                return tree, meta, step
            except (IOError, ValueError, KeyError):
                continue  # torn checkpoint: fall back to the previous one
        return None

    def restore_latest_named(
        self,
    ) -> Optional[Tuple[Dict[str, np.ndarray], Dict, int]]:
        """Newest committed checkpoint as a flat ``{name: array}`` dict.

        ``restore_latest`` needs a structure-matching target, which a
        reader whose tree shape varies per run (e.g. streaming
        checkpoints carrying a retained-assignment entry per visited
        window) cannot provide up front. This variant reads the manifest
        leaf names instead — host arrays, no device placement."""
        for step, path in reversed(self._steps()):
            try:
                leaves, manifest = _verify_and_load(path)
            except (IOError, ValueError, KeyError):
                continue  # torn checkpoint: fall back to the previous one
            named = {
                entry["name"]: leaf
                for entry, leaf in zip(manifest["leaves"], leaves)
            }
            return named, manifest["metadata"], step
        return None
