"""Hyper-parameter maintenance: duplicate-topic merging + Alg. 5 moves.

Two families of model-structure moves live here:

* Topic-duplicate merging (paper §4.3 "Merge duplicated topics"): the
  asymmetric prior already biases similar topics toward merging; on top
  of that, topics whose L1 distance between word distributions falls
  below a threshold are explicitly clustered and merged (union of
  counts, remapped assignments). ``duplicate_topic_map`` refuses to
  collapse below ``min_topics`` surviving clusters — an
  all-below-threshold distance matrix must not merge everything into
  topic 0 (degenerate K=1 model).

* Alg. 5 hyper-parameter optimization: ``minka_alpha_update`` is one
  Minka fixed-point step on the scalar alpha concentration (the
  asymmetric alpha_k shape stays count-derived via
  ``LDAHyperParams.alpha_k``), ``anneal_beta`` geometrically anneals
  beta toward a floor. ``TrainSession`` fires both as the "hyper"
  schedule action on the ``hyper_every`` cadence (DESIGN.md §9.3).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def topic_l1_distances(n_wk: jax.Array) -> jax.Array:
    """Pairwise L1 distance between topic word distributions. (K, K)."""
    col = n_wk.astype(jnp.float32)
    col = col / jnp.maximum(jnp.sum(col, axis=0, keepdims=True), 1e-30)
    # (K, K) pairwise |phi_i - phi_j|_1; K is moderate so this is fine.
    return jnp.sum(jnp.abs(col[:, :, None] - col[:, None, :]), axis=0)


def duplicate_topic_map(
    n_wk: np.ndarray, threshold: float, min_topics: int = 2
) -> np.ndarray:
    """Map each topic to its cluster representative (lowest id wins).

    Host-side union-find over the below-threshold pairs; returns (K,) int32.
    A lower threshold removes more duplicates (paper's knob).

    Pairs merge in ascending-distance order and the merging stops at
    ``min_topics`` surviving clusters: a degenerate distance matrix
    (every pair below threshold — e.g. a freshly initialized model with
    near-uniform topics) keeps the closest duplicates merged but never
    collapses the model below the floor. ``min_topics=1`` restores the
    unguarded behavior.
    """
    dist = np.asarray(topic_l1_distances(jnp.asarray(n_wk)))
    k = dist.shape[0]
    parent = np.arange(k)
    clusters = k
    floor = max(1, min(int(min_topics), k))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    ii, jj = np.where((dist < threshold) & (np.arange(k)[:, None] < np.arange(k)))
    # closest pairs first, so hitting the floor keeps the true duplicates
    # merged and drops only the marginal ones (deterministic: distance,
    # then pair ids break ties)
    order = np.lexsort((jj, ii, dist[ii, jj]))
    for a, b in zip(ii[order], jj[order]):
        if clusters <= floor:
            break
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[max(ra, rb)] = min(ra, rb)
            clusters -= 1
    return np.array([find(x) for x in range(k)], dtype=np.int32)


def merge_topics(
    topic: jax.Array,
    n_wk: jax.Array,
    n_kd: jax.Array,
    n_k: jax.Array,
    topic_map: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Apply a duplicate map: remap assignments, merge count columns."""
    k = n_k.shape[0]
    new_topic = topic_map[topic]
    onehot = jax.nn.one_hot(topic_map, k, dtype=n_wk.dtype)  # (K_old, K_new)
    return (
        new_topic.astype(jnp.int32),
        n_wk @ onehot,
        n_kd @ onehot,
        n_k @ onehot,
    )


# ---------------------------------------------------------------------------
# Alg. 5 hyper-parameter optimization (Minka fixed point + beta anneal)
# ---------------------------------------------------------------------------

def minka_alpha_update(
    n_kd: np.ndarray, alpha: float,
    alpha_min: float = 1e-5, alpha_max: float = 1e3,
) -> float:
    """One Minka fixed-point step on the scalar alpha concentration.

    The symmetric-Dirichlet fixed point (Minka 2000, "Estimating a
    Dirichlet distribution", eq. 55) on the doc-topic counts::

        alpha' = alpha * sum_{d,k} [psi(n_kd + a) - psi(a)]
                       / (K * sum_d [psi(n_d + K a) - psi(K a)])

    The asymmetric alpha_k *shape* stays derived from the topic counts
    (``LDAHyperParams.alpha_k``, whose per-topic values sum to
    ``K * alpha`` exactly), so updating the scalar updates the total
    prior mass — the quantity Alg. 5's t2/t4 terms are scaled by.

    Host-side; ``n_kd`` may carry all-zero padding rows (mesh layouts) —
    ``psi(0 + a) - psi(a) == 0`` so they contribute nothing. Returns the
    clamped new scalar (a degenerate window keeps the old value).
    """
    from scipy.special import digamma

    n_kd = np.asarray(n_kd, np.float64)
    a = float(alpha)
    k = n_kd.shape[1]
    n_d = n_kd.sum(axis=1)
    num = float(np.sum(digamma(n_kd + a)) - n_kd.size * digamma(a))
    den = float(k * (np.sum(digamma(n_d + k * a))
                     - n_d.shape[0] * digamma(k * a)))
    if not np.isfinite(num) or not np.isfinite(den) or den <= 0 or num <= 0:
        return a
    return float(np.clip(a * num / den, alpha_min, alpha_max))


def anneal_beta(beta: float, factor: float, floor: float) -> float:
    """Geometric beta annealing toward a floor: ``max(beta*factor, floor)``.

    ``factor=1`` is the identity (annealing off). Shrinking beta as the
    model sharpens concentrates phi on the words each topic actually
    owns — the paper's accuracy-side counterpart to the efficiency
    approximations the quality suite audits.
    """
    if factor == 1.0:
        return float(beta)
    return float(max(beta * factor, floor))


def optimize_hyper(
    hyper, n_kd: np.ndarray,
    update_alpha: bool = True,
    beta_anneal: float = 1.0,
    beta_floor: float = 1e-4,
):
    """Apply one Alg. 5 hyper move; returns a new ``LDAHyperParams``.

    The session's "hyper" schedule action calls this with the host
    doc-topic counts; a no-op move returns ``hyper`` unchanged (same
    object), so callers can cheaply detect whether the compiled steps
    must rebuild.
    """
    import dataclasses

    alpha = minka_alpha_update(n_kd, hyper.alpha) if update_alpha \
        else hyper.alpha
    beta = anneal_beta(hyper.beta, beta_anneal, beta_floor)
    if alpha == hyper.alpha and beta == hyper.beta:
        return hyper
    return dataclasses.replace(hyper, alpha=alpha, beta=beta)
