"""Distributed LDA runtime under shard_map (subprocess: own device count)."""
import pytest

from helpers import run_with_devices

COMMON = """
import warnings; warnings.filterwarnings('ignore')
import jax, jax.numpy as jnp, numpy as np
from repro.data import synthetic_lda_corpus
from repro.core.types import LDAHyperParams
from repro.core.graph import grid_partition
from repro.core import counts as counts_lib
from repro.launch.mesh import make_mesh
from repro.core.distributed import (DistConfig, init_dist_state,
                                    make_dist_step, make_dist_llh,
                                    make_rebuild_counts)
corpus, _ = synthetic_lda_corpus(0, num_docs=50, num_words=80, num_topics=8,
                                 avg_doc_len=30)
hyper = LDAHyperParams(num_topics=8, alpha=0.1, beta=0.05)
"""


def test_distributed_counts_match_serial():
    """Distributed rebuild == single-box build_counts on the same data."""
    run_with_devices(COMMON + """
mesh = make_mesh((2, 2), ('data', 'model'))
grid = grid_partition(corpus, 2, 2)
state, data = init_dist_state(jax.random.key(0), mesh, grid, hyper)
# reference: flatten grid tokens and build on one box
w = jnp.asarray(grid.word.reshape(-1)); d = jnp.asarray(grid.doc.reshape(-1))
m = jnp.asarray(grid.mask.reshape(-1)); z = state.topic.reshape(-1)
n_wk, n_kd, n_k = counts_lib.build_counts(
    w, d, z, grid.num_words_padded, grid.num_docs_padded, 8, mask=m)
np.testing.assert_array_equal(np.asarray(state.n_wk), np.asarray(n_wk))
np.testing.assert_array_equal(np.asarray(state.n_kd), np.asarray(n_kd))
np.testing.assert_array_equal(np.asarray(state.n_k), np.asarray(n_k))
print('MATCH')
""")


@pytest.mark.parametrize("alg", ["zen_dense", "zen_cdf", "zen_dense_kernel"])
def test_distributed_invariants_and_convergence(alg):
    run_with_devices(COMMON + f"""
mesh = make_mesh((2, 2), ('data', 'model'))
grid = grid_partition(corpus, 2, 2)
E = int(grid.mask.sum())
state, data = init_dist_state(jax.random.key(0), mesh, grid, hyper)
step = make_dist_step(mesh, hyper, DistConfig(algorithm='{alg}', max_kd=8),
                      grid.words_per_shard, grid.docs_per_shard)
llh = make_dist_llh(mesh, hyper, grid.words_per_shard, grid.docs_per_shard)
l0 = float(llh(state, data))
for _ in range(10):
    state = step(state, data)
assert int(jnp.sum(state.n_k)) == E
np.testing.assert_array_equal(np.asarray(jnp.sum(state.n_wk, 0)),
                              np.asarray(state.n_k))
np.testing.assert_array_equal(np.asarray(jnp.sum(state.n_kd, 0)),
                              np.asarray(state.n_k))
l1 = float(llh(state, data))
assert l1 > l0, (l0, l1)
print('OK', l0, l1)
""", timeout=900)


def test_delta_compression_preserves_counts():
    """int16/int8 compressed psums keep exact totals on this workload."""
    run_with_devices(COMMON + """
mesh = make_mesh((2, 2), ('data', 'model'))
grid = grid_partition(corpus, 2, 2)
E = int(grid.mask.sum())
for dd in ('int16', 'int8'):
    state, data = init_dist_state(jax.random.key(0), mesh, grid, hyper)
    step = make_dist_step(mesh, hyper,
                          DistConfig(algorithm='zen_cdf', max_kd=8,
                                     delta_dtype=dd),
                          grid.words_per_shard, grid.docs_per_shard)
    for _ in range(6):
        state = step(state, data)
    assert int(jnp.sum(state.n_k)) == E, dd
print('COMPRESSION OK')
""")


def test_elastic_rescale():
    """Train on 2x2, checkpoint assignments, restore on 1x4 and 4x1 —
    counts rebuild correctly and training continues (DESIGN.md §3.2)."""
    run_with_devices(COMMON + """
mesh_a = make_mesh((2, 2), ('data', 'model'))
grid_a = grid_partition(corpus, 2, 2)
E = int(grid_a.mask.sum())
state, data = init_dist_state(jax.random.key(0), mesh_a, grid_a, hyper)
step = make_dist_step(mesh_a, hyper, DistConfig(algorithm='zen_cdf', max_kd=8),
                      grid_a.words_per_shard, grid_a.docs_per_shard)
for _ in range(4):
    state = step(state, data)
# checkpoint = per-token assignments keyed by ORIGINAL (word, doc) ids
def inverse_perm(perm, padded_size):
    inv = np.full(padded_size, -1, np.int64)
    inv[perm] = np.arange(perm.shape[0])
    return inv

z_grid = np.asarray(state.topic)
mask = grid_a.mask
w_flat = grid_a.word[mask]; d_flat = grid_a.doc[mask]; z_flat = z_grid[mask]
inv_wa = inverse_perm(grid_a.word_perm, grid_a.num_words_padded)
inv_da = inverse_perm(grid_a.doc_perm, grid_a.num_docs_padded)
wa = inv_wa[w_flat]; da = inv_da[d_flat]
key_a = wa * 10**6 + da
order_a = np.argsort(key_a, kind='stable')
saved = z_flat[order_a]

# "new cluster": different mesh shape
for shape in [(1, 4), (4, 1)]:
    mesh_b = make_mesh(shape, ('data', 'model'))
    grid_b = grid_partition(corpus, shape[0], shape[1])
    wb = grid_b.word[grid_b.mask]; db = grid_b.doc[grid_b.mask]
    inv_wb = inverse_perm(grid_b.word_perm, grid_b.num_words_padded)
    inv_db = inverse_perm(grid_b.doc_perm, grid_b.num_docs_padded)
    key_b = inv_wb[wb] * 10**6 + inv_db[db]
    np.testing.assert_array_equal(np.sort(key_a), np.sort(key_b))
    # tokens of identical (w,d) are exchangeable: assign saved z by key order
    order_b = np.argsort(key_b, kind='stable')
    z_b = np.zeros(key_b.shape[0], np.int32)
    z_b[order_b] = saved
    init_topics = np.zeros(grid_b.word.shape, np.int32)
    init_topics[grid_b.mask] = z_b
    state_b, data_b = init_dist_state(jax.random.key(1), mesh_b, grid_b,
                                      hyper, init_topics=init_topics)
    assert int(jnp.sum(state_b.n_k)) == E
    # identical global topic histogram after re-sharding
    np.testing.assert_array_equal(np.asarray(state_b.n_k),
                                  np.asarray(state.n_k))
    step_b = make_dist_step(mesh_b, hyper,
                            DistConfig(algorithm='zen_cdf', max_kd=8),
                            grid_b.words_per_shard, grid_b.docs_per_shard)
    state_b = step_b(state_b, data_b)  # continues training
    assert int(jnp.sum(state_b.n_k)) == E
print('ELASTIC OK')
""", timeout=900)


def test_three_axis_pod_mesh():
    run_with_devices(COMMON + """
mesh = make_mesh((2, 1, 2), ('pod', 'data', 'model'))
grid = grid_partition(corpus, 2, 2)  # pod*data rows = 2
E = int(grid.mask.sum())
state, data = init_dist_state(jax.random.key(0), mesh, grid, hyper)
step = make_dist_step(mesh, hyper, DistConfig(algorithm='zen_cdf', max_kd=8),
                      grid.words_per_shard, grid.docs_per_shard)
for _ in range(4):
    state = step(state, data)
assert int(jnp.sum(state.n_k)) == E
print('POD OK')
""")
