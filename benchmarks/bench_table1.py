"""Paper Table 1: per-algorithm computing/sampling work.

Measured proxies for the complexity entries: per-iteration wall time split
into (build tables, sample) for the padded-sparse paths at two corpus
sparsity regimes (dense word rows vs long-tail), plus the analytic work
model per token for each decomposition at the measured K_d / K_w.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.core import LDAHyperParams
from repro.core.init import random_init
from repro.core.zen_sparse import build_tables, max_row_nnz, zen_sample_tokens
from repro.data import synthetic_lda_corpus


def main():
    corpus, _ = synthetic_lda_corpus(
        6, num_docs=400, num_words=600, num_topics=64, avg_doc_len=50
    )
    hyper = LDAHyperParams(num_topics=64, alpha=0.05, beta=0.01)
    state = random_init(jax.random.key(0), corpus, hyper)
    kd = int(max_row_nnz(state.n_kd))
    kw = int(max_row_nnz(state.n_wk))
    row("table1_measured_Kd", 0.0, f"max_kd={kd}")
    row("table1_measured_Kw", 0.0, f"max_kw={kw}")
    k = hyper.num_topics
    # analytic work per token (Table 1 complexity columns, at measured K_*)
    row("table1_work_std", 0.0, f"per_token~O(K)={k}")
    row("table1_work_zen", 0.0, f"per_token~O(K_d)={kd}+O(1)+O(1)")
    row("table1_work_hybrid", 0.0, f"per_token~O(min(Kd,Kw))={min(kd, kw)}")
    row("table1_work_sparselda", 0.0, f"per_token~O(K_w)={kw}")
    row("table1_work_lightlda", 0.0, "per_token~O(#MH)=8")

    # measured build-vs-sample split for the faithful ZenLDA path
    mk_w = ((kw + 7) // 8) * 8
    mk_d = ((kd + 7) // 8) * 8
    build = jax.jit(lambda a, b, c: build_tables(
        a, b, c, hyper, corpus.num_words, mk_w, mk_d))
    tables = build(state.n_wk, state.n_kd, state.n_k)
    jax.block_until_ready(tables)
    t0 = time.perf_counter()
    for _ in range(3):
        tables = build(state.n_wk, state.n_kd, state.n_k)
        jax.block_until_ready(tables)
    t_build = (time.perf_counter() - t0) / 3
    sample = jax.jit(lambda t, key: zen_sample_tokens(
        key, t, corpus.word, corpus.doc, state.topic, hyper))
    z = sample(tables, jax.random.key(1))
    jax.block_until_ready(z)
    t0 = time.perf_counter()
    for _ in range(3):
        z = sample(tables, jax.random.key(1))
        jax.block_until_ready(z)
    t_sample = (time.perf_counter() - t0) / 3
    row("table1_zen_build_tables", t_build * 1e6,
        "alias gTable+wTable (Alg.2 l.5-13)")
    row("table1_zen_sample", t_sample * 1e6,
        f"per_token_ns={t_sample / corpus.num_tokens * 1e9:.0f}")
