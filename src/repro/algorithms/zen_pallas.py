"""``zen_pallas`` — the fused Gumbel-max Pallas kernel as a first-class
backend (headline hot path; ``zen_dense_kernel`` kept as the legacy alias).

One fused VMEM pass streams K-tiles of the three-term conditional and keeps
only a running (max, argmax) carry per token: no normalization, no
materialized (T, K) probability matrix in HBM, no second pass (see
``kernels/zen_sampler.py`` and DESIGN.md §2). On CPU the same kernel runs
in interpret mode, bit-identical to the ``kernels/ref.py`` oracle, so the
backend is selectable everywhere: kernel on TPU, interpreted ref on CPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.algorithms.base import CellBackend, SamplerKnobs, chunked_token_map
from repro.algorithms.registry import register


@register("zen_pallas", "zen_dense_kernel")
class ZenPallas(CellBackend):
    """Fused three-term Gumbel-max sampler (Pallas TPU kernel)."""

    def cell_sweep(
        self, key, word, doc, z_old, mask, n_wk, n_kd, n_k, hyper,
        num_words_pad, knobs: SamplerKnobs,
    ):
        # lazy: keep pallas out of the import path of everything that
        # never selects this backend
        from repro.kernels.ops import zen_sample

        alpha_k = hyper.alpha_k(n_k)
        n_k_f = n_k.astype(jnp.float32)
        w_beta = num_words_pad * hyper.beta

        def chunk(args):
            w, d, z, subkey = args
            seed = jax.random.randint(
                subkey, (), 0, jnp.iinfo(jnp.int32).max, dtype=jnp.int32
            )
            # int32 casts: the kernel tiles assume 4-byte count rows (the
            # distributed path may hold N_kd in int16)
            return zen_sample(
                n_wk[w].astype(jnp.int32), n_kd[d].astype(jnp.int32), z,
                alpha_k, n_k_f, seed,
                beta=hyper.beta, w_beta=w_beta, bt=knobs.bt, bk=knobs.bk,
            )

        # chunking bounds the gathered (chunk, K) row tiles in HBM
        return chunked_token_map(
            chunk, key, (word, doc, z_old), knobs.token_chunk
        )
