"""Model inference: CGS inference + RT-LDA (paper §4.3)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.inference import cgs_infer, rtlda_infer
from repro.core.types import LDAHyperParams


def _sharp_model(k=4, w=40):
    """Topics with disjoint vocabulary blocks."""
    n_wk = np.zeros((w, k), np.int32)
    block = w // k
    for t in range(k):
        n_wk[t * block : (t + 1) * block, t] = 100
    n_k = n_wk.sum(0).astype(np.int32)
    return jnp.asarray(n_wk), jnp.asarray(n_k)


def test_rtlda_recovers_dominant_topic(key):
    n_wk, n_k = _sharp_model()
    hyper = LDAHyperParams(num_topics=4, alpha=0.1, beta=0.01)
    words = jnp.asarray([0, 1, 2, 3, 4, 5], jnp.int32)  # all topic-0 words
    theta = rtlda_infer(n_wk, n_k, words, hyper)
    assert int(jnp.argmax(theta)) == 0
    np.testing.assert_allclose(float(jnp.sum(theta)), 1.0, atol=1e-3)


def test_cgs_infer_recovers_dominant_topic(key):
    n_wk, n_k = _sharp_model()
    hyper = LDAHyperParams(num_topics=4, alpha=0.1, beta=0.01)
    words = jnp.asarray([20, 21, 22, 23, 24], jnp.int32)  # topic-2 words
    theta = cgs_infer(key, n_wk, n_k, words, hyper, num_sweeps=20)
    assert int(jnp.argmax(theta)) == 2
    np.testing.assert_allclose(float(jnp.sum(theta)), 1.0, atol=1e-3)


def test_rtlda_deterministic(key):
    n_wk, n_k = _sharp_model()
    hyper = LDAHyperParams(num_topics=4)
    words = jnp.asarray([0, 11, 12, 13], jnp.int32)
    t1 = rtlda_infer(n_wk, n_k, words, hyper)
    t2 = rtlda_infer(n_wk, n_k, words, hyper)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


def test_mixed_document(key):
    """A half/half document should spread theta across both topics."""
    n_wk, n_k = _sharp_model()
    hyper = LDAHyperParams(num_topics=4, alpha=0.1, beta=0.01)
    words = jnp.asarray([0, 1, 2, 10, 11, 12], jnp.int32)
    theta = np.asarray(cgs_infer(key, n_wk, n_k, words, hyper, num_sweeps=25))
    assert theta[0] > 0.2 and theta[1] > 0.2
    assert theta[2] < 0.2 and theta[3] < 0.2
