from repro.sharding.partition import (  # noqa: F401
    batch_sharding,
    batch_spec,
    cache_sharding,
    data_axes_of,
    param_shardings,
    param_specs,
)
