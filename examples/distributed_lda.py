"""Multi-device distributed ZenLDA (the Fig. 2 workflow, on host devices).

Re-executes itself with XLA_FLAGS so the demo works from a plain
``python examples/distributed_lda.py [--devices 8]``.
"""
import argparse
import os
import subprocess
import sys

BODY = """
import warnings; warnings.filterwarnings('ignore')
import time
import jax, jax.numpy as jnp, numpy as np
from repro.core.distributed import (DistConfig, init_dist_state,
                                    make_dist_llh, make_dist_step)
from repro.core.graph import grid_partition
from repro.core.types import LDAHyperParams
from repro.data import synthetic_lda_corpus
from repro.launch.mesh import make_mesh

rows, cols = ROWS, COLS
corpus, _ = synthetic_lda_corpus(0, num_docs=400, num_words=600,
                                 num_topics=16, avg_doc_len=60)
hyper = LDAHyperParams(num_topics=16, alpha=0.05, beta=0.01)
mesh = make_mesh((rows, cols), ('data', 'model'))
grid = grid_partition(corpus, rows, cols)
print(f'devices={len(jax.devices())} mesh={rows}x{cols} '
      f'tokens={int(grid.mask.sum())} pad_overhead={grid.padding_overhead:.2%}')
state, data = init_dist_state(jax.random.key(0), mesh, grid, hyper)
step = make_dist_step(mesh, hyper,
                      DistConfig(algorithm='zen_cdf', max_kd=24,
                                 delta_dtype='int16'),
                      grid.words_per_shard, grid.docs_per_shard)
llh = make_dist_llh(mesh, hyper, grid.words_per_shard, grid.docs_per_shard)
print(f'llh0 = {float(llh(state, data)):.1f}')
for it in range(1, 21):
    t0 = time.time()
    state = step(state, data)
    if it % 5 == 0:
        print(f'iter {it:2d}  {(time.time()-t0)*1e3:6.1f} ms  '
              f'llh {float(llh(state, data)):12.1f}')
print('count conservation:', int(jnp.sum(state.n_k)) == int(grid.mask.sum()))
"""


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=4)
    args = ap.parse_args()
    rows = max(1, args.devices // 2)
    cols = args.devices // rows
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={args.devices}"
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(repo, "src")
    code = BODY.replace("ROWS", str(rows)).replace("COLS", str(cols))
    sys.exit(subprocess.run([sys.executable, "-c", code], env=env).returncode)


if __name__ == "__main__":
    main()
