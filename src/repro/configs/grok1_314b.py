"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2. [hf:xai-org/grok-1; unverified]

Giant MoE: trains with Adafactor (factored second moment) so optimizer
state fits the 16 GB/chip budget at 256 chips. Pure full attention ->
long_500k skipped.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    moe=MoEConfig(num_experts=8, top_k=2),
    tie_embeddings=True,
    optimizer="adafactor",
    skip_shapes=("long_500k",),
)
