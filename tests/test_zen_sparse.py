"""Faithful padded-sparse ZenLDA sampler (paper Alg. 2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import given, settings, st

from repro.core.decompositions import precompute_zen_terms
from repro.core.init import random_init
from repro.core.types import LDAHyperParams
from repro.core.zen_sparse import (
    build_tables,
    densify_rows,
    lookup_rows,
    max_row_nnz,
    shard_row_capacity,
    sparsify_rows,
    zen_sample_tokens,
    zen_sparse_sweep,
)


def test_sparsify_roundtrip(rng):
    dense = jnp.asarray(rng.integers(0, 3, (20, 17)), jnp.int32)
    m = int(max_row_nnz(dense))
    rows = sparsify_rows(dense, m)
    np.testing.assert_array_equal(np.asarray(densify_rows(rows)),
                                  np.asarray(dense))


def test_lookup_rows(rng):
    dense = jnp.asarray(rng.integers(0, 4, (10, 23)), jnp.int32)
    rows = sparsify_rows(dense, int(max_row_nnz(dense)))
    rids = jnp.asarray(rng.integers(0, 10, (6,)), jnp.int32)
    topics = jnp.asarray(rng.integers(0, 23, (6, 5)), jnp.int32)
    got = lookup_rows(rows, rids, topics)
    expect = np.asarray(dense)[np.asarray(rids)[:, None], np.asarray(topics)]
    np.testing.assert_array_equal(np.asarray(got), expect)


def test_term_masses_equal_dense_sum(key, tiny_corpus, tiny_hyper):
    """m1 + m2[w] + m3[token] == sum_k of the stale dense ZenLDA p —
    the two-level sampler draws from exactly the decomposed mass."""
    state = random_init(key, tiny_corpus, tiny_hyper)
    max_kw = int(max_row_nnz(state.n_wk))
    max_kd = int(max_row_nnz(state.n_kd))
    tables = build_tables(
        state.n_wk, state.n_kd, state.n_k, tiny_hyper,
        tiny_corpus.num_words, max_kw, max_kd,
    )
    from repro.core.decompositions import zen_probs
    from repro.core.zen_sparse import _d_sparse

    terms = precompute_zen_terms(state.n_k, tiny_hyper, tiny_corpus.num_words)
    p_dense = zen_probs(
        state.n_wk[tiny_corpus.word], state.n_kd[tiny_corpus.doc], terms,
        tiny_hyper.beta,
    )
    d_vals, _ = _d_sparse(tables, tiny_corpus.word, tiny_corpus.doc,
                          tiny_hyper.beta)
    total_sparse = (
        tables.terms.g_mass
        + tables.w_mass[tiny_corpus.word]
        + jnp.sum(d_vals, axis=-1)
    )
    np.testing.assert_allclose(
        np.asarray(total_sparse), np.asarray(jnp.sum(p_dense, -1)), rtol=1e-4
    )


def test_sweep_samples_valid_topics(key, tiny_corpus, tiny_hyper):
    state = random_init(key, tiny_corpus, tiny_hyper)
    z = zen_sparse_sweep(state, tiny_corpus, tiny_hyper, max_kw=48, max_kd=48)
    z = np.asarray(z)
    assert z.min() >= 0 and z.max() < tiny_hyper.num_topics


def test_sweep_distribution_matches_dense(key, tiny_corpus, tiny_hyper):
    """Empirical topic histogram of the sparse sampler tracks the dense
    stale ZenLDA sampler (same decomposition, different machinery)."""
    from repro.core.sampler import cgs_sweep_stale

    state = random_init(key, tiny_corpus, tiny_hyper)
    z_sparse = zen_sparse_sweep(state, tiny_corpus, tiny_hyper, 48, 48)
    z_dense = cgs_sweep_stale(state, tiny_corpus, tiny_hyper,
                              exclude_self=False)
    h1 = np.bincount(np.asarray(z_sparse), minlength=tiny_hyper.num_topics)
    h2 = np.bincount(np.asarray(z_dense), minlength=tiny_hyper.num_topics)
    assert np.abs(h1 - h2).sum() < 0.15 * tiny_corpus.num_tokens


def test_convergence(key, tiny_corpus, tiny_hyper):
    from repro.core import LDATrainer, TrainConfig
    from repro.core.likelihood import predictive_llh

    tr = LDATrainer(tiny_corpus, tiny_hyper,
                    TrainConfig(algorithm="zen_sparse"))
    st = tr.init_state(key)
    llh0 = tr.llh(st)
    for _ in range(8):
        st = tr.step(st)
    st.check_invariants(tiny_corpus)
    assert tr.llh(st) > llh0


# ---------------------------------------------------------------------------
# Property tests: the shard-relative padded-row builder (mesh cell sweeps
# sparsify each shard's local count block at its own capacity)
# ---------------------------------------------------------------------------


def _random_shard_slices(rnd_matrix, r, nshards, rng):
    """Cut a dense (R, K) matrix into <= nshards contiguous row slices at
    arbitrary (possibly degenerate/empty) boundaries."""
    cuts = sorted(int(c) for c in rng.integers(0, r + 1, size=nshards - 1))
    bounds = [0] + cuts + [r]
    return [rnd_matrix[a:b] for a, b in zip(bounds[:-1], bounds[1:])]


@settings(max_examples=25, deadline=None)
@given(
    st.integers(1, 24),  # rows
    st.integers(1, 20),  # topics K
    st.integers(0, 10**6),  # data seed
    st.integers(1, 6),  # shard count
)
def test_shard_slices_never_drop_or_duplicate_counts(r, k, seed, nshards):
    """Sparsify each arbitrary shard slice at its own per-shard capacity,
    densify, reassemble: every (row, topic) count survives exactly once."""
    rng = np.random.default_rng(seed)
    dense = rng.integers(0, 4, size=(r, k)).astype(np.int32)
    parts = []
    for block in _random_shard_slices(dense, r, nshards, rng):
        if block.shape[0] == 0:
            parts.append(block)
            continue
        cap = shard_row_capacity(jnp.asarray(block))
        rows = sparsify_rows(jnp.asarray(block), cap)
        parts.append(np.asarray(densify_rows(rows)))
    rebuilt = np.concatenate([p for p in parts if p.shape[0]] or
                             [np.zeros((0, k), np.int32)], axis=0)
    np.testing.assert_array_equal(rebuilt, dense)


@settings(max_examples=25, deadline=None)
@given(
    st.integers(1, 24),
    st.integers(1, 64),
    st.integers(0, 10**6),
)
def test_shard_row_capacity_bounds_are_tight(r, k, seed):
    """Per-shard capacity is sufficient (>= max row nnz) and tight (within
    one lane-rounding multiple of it, never past K)."""
    rng = np.random.default_rng(seed)
    # mix dense and sparse rows so max nnz spans the whole [0, k] range
    dense = rng.integers(0, 3, size=(r, k)).astype(np.int32)
    dense[rng.random(r) < 0.3] = 0
    block = jnp.asarray(dense)
    m = int(max_row_nnz(block))
    cap = shard_row_capacity(block)
    assert cap >= min(max(m, 1), k)  # sufficient: nothing truncates
    assert cap <= k  # never explodes past K
    assert cap <= max(8, m + 7)  # tight: one rounding multiple at most
    # sufficiency is functional, not just numeric: round-trip is exact
    np.testing.assert_array_equal(
        np.asarray(densify_rows(sparsify_rows(block, cap))), dense
    )
