"""Multi-device distributed ZenLDA (the Fig. 2 workflow, on host devices).

Re-executes itself with XLA_FLAGS so the demo works from a plain
``python examples/distributed_lda.py [--devices 8]``.
"""
import argparse
import os
import subprocess
import sys

BODY = """
import warnings; warnings.filterwarnings('ignore')
import time
import jax, jax.numpy as jnp
from repro.core.types import LDAHyperParams
from repro.data import synthetic_lda_corpus
from repro.train.session import RunConfig, TrainSession

rows, cols = ROWS, COLS
corpus, _ = synthetic_lda_corpus(0, num_docs=400, num_words=600,
                                 num_topics=16, avg_doc_len=60)
hyper = LDAHyperParams(num_topics=16, alpha=0.05, beta=0.01)
cfg = RunConfig(algorithm='zen_cdf', mesh_shape=(rows, cols), max_kd=24,
                delta_dtype='int16', num_iterations=20, eval_every=5)
session = TrainSession(corpus, hyper, cfg)
grid = session.plan.grid
print(f'devices={len(jax.devices())} mesh={rows}x{cols} '
      f'tokens={int(grid.mask.sum())} pad_overhead={grid.padding_overhead:.2%}')
state = session.init(jax.random.key(0))
print(f'llh0 = {session.llh(state):.1f}')
t0 = [time.time()]
def cb(st, metrics):
    if metrics:
        print(f'iter {int(st.iteration):2d}  '
              f'{(time.time() - t0[0]) * 1e3:6.1f} ms  '
              f'llh {metrics["llh"]:12.1f}')
    t0[0] = time.time()
state = session.run(state=state, callback=cb)
print('count conservation:', int(jnp.sum(state.n_k)) == int(grid.mask.sum()))
"""


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=4)
    args = ap.parse_args()
    rows = max(1, args.devices // 2)
    cols = args.devices // rows
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={args.devices}"
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(repo, "src")
    code = BODY.replace("ROWS", str(rows)).replace("COLS", str(cols))
    sys.exit(subprocess.run([sys.executable, "-c", code], env=env).returncode)


if __name__ == "__main__":
    main()
