"""Layer-stack assembly for every architecture family.

All stacks run under ``lax.scan`` over layer-stacked params (O(1) compile
depth). Heterogeneous patterns are realized as *group scans* over
homogeneous sub-stacks:

  gemma3   groups of (5 local sliding-window layers, 1 global layer),
           plus a local remainder — each sub-stack scanned with its own
           static window/theta
  zamba2   groups of (`every` mamba2 layers, 1 shared attention block) —
           the attention block's params are shared across groups
  whisper  encoder scan + decoder scan (self + cross attention)

Remat: each scanned layer body is wrapped in ``jax.checkpoint`` per
``cfg.remat_policy`` so activation memory is O(sqrt)-ish instead of O(L).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.attention import (
    KVCache,
    attn_block,
    attn_decode,
    cross_kv,
    init_attn,
    init_mla,
    mla_block,
    mla_decode,
)
from repro.models.layers import init_mlp, init_norm, mlp, norm
from repro.models.moe import init_moe, moe_block
from repro.models.ssm import (
    SSMCache,
    init_mamba1,
    init_mamba2,
    mamba1_block,
    mamba1_decode,
    mamba2_block,
    mamba2_decode,
)


def scan_or_unroll(cfg: ArchConfig, f, init, xs):
    """lax.scan, or an unrolled python loop when cfg.unroll_layers (the
    roofline fit-compiles need per-layer costs visible to cost_analysis —
    scan bodies are otherwise counted once)."""
    if not cfg.unroll_layers:
        return jax.lax.scan(f, init, xs)
    length = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    carry = init
    for i in range(length):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = f(carry, x_i)
        ys.append(y)
    if all(y is None for y in ys):
        return carry, None
    ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    return carry, ys


def _remat(fn, cfg: ArchConfig):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


def _stack_init(key, n: int, init_fn):
    """vmap an init over n layers -> stacked params."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


# ---------------------------------------------------------------------------
# Decoder layers (dense / moe / vlm families)
# ---------------------------------------------------------------------------

def init_decoder_layer(key, cfg: ArchConfig, dtype) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "ln1": init_norm(ks[0], cfg.d_model, cfg),
        "ln2": init_norm(ks[1], cfg.d_model, cfg),
    }
    if cfg.mla is not None:
        p["attn"] = init_mla(ks[2], cfg, dtype)
    else:
        p["attn"] = init_attn(ks[2], cfg, dtype)
    if cfg.moe is not None:
        p["moe"] = init_moe(ks[3], cfg, dtype)
        if cfg.moe.dense_residual:
            p["mlp"] = init_mlp(jax.random.fold_in(ks[3], 1), cfg.d_model,
                                cfg.d_ff, cfg, dtype)
    else:
        p["mlp"] = init_mlp(ks[3], cfg.d_model, cfg.d_ff, cfg, dtype)
    return p


def decoder_layer(
    x: jax.Array,
    lp: dict,
    cfg: ArchConfig,
    positions: jax.Array,
    *,
    window: int = 0,
    theta: Optional[float] = None,
    causal: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    h = norm(x, lp["ln1"], cfg)
    if cfg.mla is not None:
        a = mla_block(h, lp["attn"], cfg, positions, causal=causal)
    else:
        a = attn_block(h, lp["attn"], cfg, positions, causal=causal,
                       window=window, theta=theta)
    x = x + a
    h2 = norm(x, lp["ln2"], cfg)
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe is not None:
        y, aux = moe_block(h2, lp["moe"], cfg)
        if cfg.moe.dense_residual:
            y = y + mlp(h2, lp["mlp"], cfg)
    else:
        y = mlp(h2, lp["mlp"], cfg)
    return x + y, aux


def decoder_layer_decode(
    x: jax.Array,
    lp: dict,
    cfg: ArchConfig,
    cache: KVCache,
    *,
    window: int = 0,
    theta: Optional[float] = None,
) -> Tuple[jax.Array, KVCache, jax.Array]:
    h = norm(x, lp["ln1"], cfg)
    if cfg.mla is not None:
        a, cache = mla_decode(h, lp["attn"], cfg, cache)
    else:
        a, cache = attn_decode(h, lp["attn"], cfg, cache, window=window,
                               theta=theta)
    x = x + a
    h2 = norm(x, lp["ln2"], cfg)
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe is not None:
        y, aux = moe_block(h2, lp["moe"], cfg)
        if cfg.moe.dense_residual:
            y = y + mlp(h2, lp["mlp"], cfg)
    else:
        y = mlp(h2, lp["mlp"], cfg)
    return x + y, cache, aux


def _scan_layers(body, x, stacked, cfg: ArchConfig):
    """scan a (x, aux) carry over layer-stacked params."""
    body = _remat(body, cfg)

    def f(carry, lp):
        x, aux = carry
        x, a = body(x, lp)
        return (x, aux + a), None

    (x, aux), _ = scan_or_unroll(cfg, f, (x, jnp.zeros((), jnp.float32)),
                                 stacked)
    return x, aux


def _scan_layers_cache(body, x, stacked, caches, cfg: ArchConfig = None):
    """scan over (params, cache) pairs, emitting updated caches."""

    def f(x, inp):
        lp, cache = inp
        x, new_cache, _ = body(x, lp, cache)
        return x, new_cache

    if cfg is not None and cfg.unroll_layers:
        return scan_or_unroll(cfg, f, x, (stacked, caches))
    x, new_caches = jax.lax.scan(f, x, (stacked, caches))
    return x, new_caches


# ---------------------------------------------------------------------------
# gemma3-style local:global pattern
# ---------------------------------------------------------------------------

class PatternedStacks(NamedTuple):
    """Layer stacks for the N-local:1-global repeating pattern."""

    local: dict  # stacked (n_local, ...)
    global_: dict  # stacked (n_global, ...)


def pattern_counts(cfg: ArchConfig) -> Tuple[int, int, int]:
    """(n_groups, n_global, n_trailing_local) for the repeating pattern."""
    n = cfg.local_global_pattern
    group = n + 1
    n_groups = cfg.num_layers // group
    rem = cfg.num_layers - n_groups * group
    return n_groups, n_groups, rem  # rem trailing layers are local


def patterned_forward(
    params: dict, cfg: ArchConfig, x: jax.Array, positions: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    n = cfg.local_global_pattern
    n_groups, _, rem = pattern_counts(cfg)
    theta_g = cfg.rope_theta_global or cfg.rope_theta
    local = params["local"]
    glob = params["global"]

    def local_body(x, lp):
        return decoder_layer(x, lp, cfg, positions,
                             window=cfg.sliding_window, theta=cfg.rope_theta)

    def global_body(x, lp):
        return decoder_layer(x, lp, cfg, positions, window=0, theta=theta_g)

    # group scan: (n local, 1 global) x n_groups
    grouped_local = jax.tree.map(
        lambda a: a[: n_groups * n].reshape((n_groups, n) + a.shape[1:]), local
    )

    def group(carry, inp):
        x, aux = carry
        lp_loc, lp_glob = inp
        x, a1 = _scan_layers(local_body, x, lp_loc, cfg)
        x, a2 = _remat(global_body, cfg)(x, lp_glob)
        return (x, aux + a1 + a2), None

    (x, aux), _ = scan_or_unroll(
        cfg, group, (x, jnp.zeros((), jnp.float32)), (grouped_local, glob)
    )
    if rem:
        trailing = jax.tree.map(lambda a: a[n_groups * n :], local)
        x, a3 = _scan_layers(local_body, x, trailing, cfg)
        aux = aux + a3
    return x, aux


def patterned_decode(
    params: dict, cfg: ArchConfig, x: jax.Array, caches: dict
) -> Tuple[jax.Array, dict]:
    n = cfg.local_global_pattern
    n_groups, _, rem = pattern_counts(cfg)
    theta_g = cfg.rope_theta_global or cfg.rope_theta
    local = params["local"]
    glob = params["global"]

    def local_body(x, lp, c):
        return decoder_layer_decode(x, lp, cfg, c,
                                    window=cfg.sliding_window,
                                    theta=cfg.rope_theta)

    def global_body(x, lp, c):
        return decoder_layer_decode(x, lp, cfg, c, window=0, theta=theta_g)

    grouped_local = jax.tree.map(
        lambda a: a[: n_groups * n].reshape((n_groups, n) + a.shape[1:]), local
    )
    grouped_lcache = jax.tree.map(
        lambda a: a[: n_groups * n].reshape((n_groups, n) + a.shape[1:]),
        caches["local"],
    )

    def group(x, inp):
        lp_loc, lc, lp_glob, gc = inp
        x, lc_new = _scan_layers_cache(local_body, x, lp_loc, lc, cfg)
        x, gc_new, _ = global_body(x, lp_glob, gc)
        return x, (lc_new, gc_new)

    x, (lcaches, gcaches) = scan_or_unroll(
        cfg, group, x, (grouped_local, grouped_lcache, glob, caches["global"])
    )
    lcaches = jax.tree.map(
        lambda a: a.reshape((n_groups * n,) + a.shape[2:]), lcaches
    )
    if rem:
        trailing_p = jax.tree.map(lambda a: a[n_groups * n :], local)
        trailing_c = jax.tree.map(lambda a: a[n_groups * n :], caches["local"])
        x, tc = _scan_layers_cache(local_body, x, trailing_p, trailing_c, cfg)
        lcaches = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b], axis=0), lcaches, tc
        )
    return x, {"local": lcaches, "global": gcaches}


# ---------------------------------------------------------------------------
# zamba2-style hybrid (mamba2 + shared attention block)
# ---------------------------------------------------------------------------

def hybrid_forward(
    params: dict, cfg: ArchConfig, x: jax.Array, positions: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    every = cfg.hybrid_attn_every
    n_groups = cfg.num_layers // every
    rem = cfg.num_layers - n_groups * every
    mamba = params["mamba"]
    shared = params["shared_attn"]  # ONE param set reused per group

    def mamba_body(x, lp):
        h = norm(x, lp["ln"], cfg)
        return x + mamba2_block(h, lp["m"], cfg), jnp.zeros((), jnp.float32)

    grouped = jax.tree.map(
        lambda a: a[: n_groups * every].reshape((n_groups, every) + a.shape[1:]),
        mamba,
    )

    def shared_body(x):
        h = norm(x, shared["ln1"], cfg)
        x = x + attn_block(h, shared["attn"], cfg, positions, causal=True)
        h2 = norm(x, shared["ln2"], cfg)
        return x + mlp(h2, shared["mlp"], cfg)

    def group(carry, lp_grp):
        x, aux = carry
        x, a = _scan_layers(mamba_body, x, lp_grp, cfg)
        x = _remat(shared_body, cfg)(x)
        return (x, aux + a), None

    (x, aux), _ = scan_or_unroll(cfg, group,
                                 (x, jnp.zeros((), jnp.float32)), grouped)
    if rem:
        trailing = jax.tree.map(lambda a: a[n_groups * every :], mamba)
        x, a = _scan_layers(mamba_body, x, trailing, cfg)
        aux = aux + a
    return x, aux


def hybrid_decode(
    params: dict, cfg: ArchConfig, x: jax.Array, caches: dict
) -> Tuple[jax.Array, dict]:
    every = cfg.hybrid_attn_every
    n_groups = cfg.num_layers // every
    rem = cfg.num_layers - n_groups * every
    mamba = params["mamba"]
    shared = params["shared_attn"]

    def mamba_body(x, lp, c):
        h = norm(x, lp["ln"], cfg)
        y, c2 = mamba2_decode(h, lp["m"], cfg, c)
        return x + y, c2, None

    grouped_p = jax.tree.map(
        lambda a: a[: n_groups * every].reshape((n_groups, every) + a.shape[1:]),
        mamba,
    )
    grouped_c = jax.tree.map(
        lambda a: a[: n_groups * every].reshape((n_groups, every) + a.shape[1:]),
        caches["mamba"],
    )

    def group(carry, inp):
        x = carry
        lp_grp, c_grp, ac = inp
        x, c_new = _scan_layers_cache(mamba_body, x, lp_grp, c_grp, cfg)
        h = norm(x, shared["ln1"], cfg)
        a, ac_new = attn_decode(h, shared["attn"], cfg, ac)
        x = x + a
        h2 = norm(x, shared["ln2"], cfg)
        x = x + mlp(h2, shared["mlp"], cfg)
        return x, (c_new, ac_new)

    x, (mcaches, acaches) = scan_or_unroll(
        cfg, group, x, (grouped_p, grouped_c, caches["attn"])
    )
    mcaches = jax.tree.map(
        lambda a: a.reshape((n_groups * every,) + a.shape[2:]), mcaches
    )
    if rem:
        tp = jax.tree.map(lambda a: a[n_groups * every :], mamba)
        tc = jax.tree.map(lambda a: a[n_groups * every :], caches["mamba"])
        x, tnew = _scan_layers_cache(mamba_body, x, tp, tc, cfg)
        mcaches = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b], axis=0), mcaches, tnew
        )
    return x, {"mamba": mcaches, "attn": acaches}


# ---------------------------------------------------------------------------
# whisper-style encoder-decoder
# ---------------------------------------------------------------------------

def encdec_forward(
    params: dict,
    cfg: ArchConfig,
    enc_embeds: jax.Array,  # (B, S_enc, D) — stub frontend output
    dec_x: jax.Array,  # (B, S_dec, D)
    enc_positions: jax.Array,
    dec_positions: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (decoder hidden states, aux)."""

    def enc_body(x, lp):
        h = norm(x, lp["ln1"], cfg)
        x = x + attn_block(h, lp["attn"], cfg, enc_positions, causal=False)
        h2 = norm(x, lp["ln2"], cfg)
        return x + mlp(h2, lp["mlp"], cfg), jnp.zeros((), jnp.float32)

    enc, _ = _scan_layers(enc_body, enc_embeds, params["encoder"], cfg)
    enc = norm(enc, params["enc_norm"], cfg)

    def dec_body(x, lp):
        h = norm(x, lp["ln1"], cfg)
        x = x + attn_block(h, lp["self_attn"], cfg, dec_positions, causal=True)
        h2 = norm(x, lp["ln_x"], cfg)
        kv = cross_kv(enc, lp["cross_attn"], cfg.num_kv_heads,
                      cfg.resolved_head_dim)
        x = x + attn_block(h2, lp["cross_attn"], cfg, dec_positions,
                           cross_kv=kv)
        h3 = norm(x, lp["ln2"], cfg)
        return x + mlp(h3, lp["mlp"], cfg), jnp.zeros((), jnp.float32)

    dec, aux = _scan_layers(dec_body, dec_x, params["decoder"], cfg)
    return dec, aux


def encdec_decode(
    params: dict,
    cfg: ArchConfig,
    x: jax.Array,  # (B, 1, D)
    caches: dict,  # {"self": stacked KVCache, "cross_k": (L,B,S,H,D), ...}
) -> Tuple[jax.Array, dict]:
    def dec_body(x, inp):
        lp, cache, ck, cv = inp
        h = norm(x, lp["ln1"], cfg)
        a, cache2 = attn_decode(h, lp["self_attn"], cfg, cache)
        x = x + a
        h2 = norm(x, lp["ln_x"], cfg)
        x = x + attn_block(h2, lp["cross_attn"], cfg,
                           jnp.zeros((x.shape[0], 1), jnp.int32),
                           cross_kv=(ck, cv))
        h3 = norm(x, lp["ln2"], cfg)
        return x + mlp(h3, lp["mlp"], cfg), cache2

    def f(x, inp):
        x, c2 = dec_body(x, inp)
        return x, c2

    x, new_self = scan_or_unroll(
        cfg, f, x,
        (params["decoder"], caches["self"], caches["cross_k"], caches["cross_v"]),
    )
    caches = dict(caches)
    caches["self"] = new_self
    return x, caches
