"""launch/compare.py — both modes, end to end through main().

* ``--sessions``: two RunConfig JSONs run on a shared synthetic corpus
  via TrainSession; the printed trajectory table must parse and carry
  the quality columns when ``--quality-every`` is set.
* store diff (default): two dry-run JSON stores; the roofline-term
  table must show the cells that moved and honor ``--min-ratio``.
"""
import json
import re

import pytest

from repro.launch import compare
from repro.train.session import RunConfig


def _run_main(monkeypatch, capsys, argv):
    monkeypatch.setattr("sys.argv", ["compare.py"] + argv)
    compare.main()
    return capsys.readouterr().out


def _table_rows(out):
    """Parse `| iter | ... |` body rows into lists of cell strings."""
    rows = []
    for line in out.splitlines():
        if line.startswith("|") and not line.startswith("|---") \
                and "iter" not in line:
            rows.append([c.strip() for c in line.strip("|").split("|")])
    return rows


@pytest.fixture()
def session_configs(tmp_path):
    paths = []
    for name, algo in [("base.json", "zen"), ("opt.json", "zen_sparse")]:
        cfg = RunConfig(algorithm=algo, num_iterations=2, eval_every=1)
        p = tmp_path / name
        p.write_text(cfg.to_json())
        paths.append(str(p))
    return paths


def test_sessions_mode_end_to_end(monkeypatch, capsys, session_configs):
    base, opt = session_configs
    out = _run_main(monkeypatch, capsys, [
        "--sessions", base, opt, "--topics", "4",
        "--synthetic-docs", "30", "--synthetic-words", "40",
        "--synthetic-len", "12",
    ])
    assert "algorithm=zen " in out and "algorithm=zen_sparse" in out
    header = next(l for l in out.splitlines() if l.startswith("| iter |"))
    assert "baseline llh" in header and "optimized ppl" in header
    assert "umass" not in header  # no quality flag -> no quality columns
    rows = _table_rows(out)
    assert [r[0] for r in rows] == ["1", "2"]
    for r in rows:  # llh/ppl cells are floats for both runs
        assert all(re.fullmatch(r"-?\d+\.\d+", c) for c in r[1:]), r


def test_sessions_mode_quality_columns(monkeypatch, capsys, session_configs):
    base, opt = session_configs
    out = _run_main(monkeypatch, capsys, [
        "--sessions", base, opt, "--topics", "4", "--quality-every", "2",
        "--synthetic-docs", "30", "--synthetic-words", "40",
        "--synthetic-len", "12",
    ])
    header = next(l for l in out.splitlines() if l.startswith("| iter |"))
    for label in ("umass", "npmi"):
        assert f"baseline {label}" in header and f"optimized {label}" in header
    rows = _table_rows(out)
    # iteration 1: eval only -> quality cells are "-"; iteration 2: filled
    assert rows[0][0] == "1" and "-" in rows[0]
    umass_col = 1 + 2 * 2  # after llh/ppl pairs: baseline umass
    assert re.fullmatch(r"-?\d+\.\d+", rows[1][umass_col])


def _store(flops, coll):
    return {
        "zenlda|4096x64|single": {
            "ok": True, "flops_per_device": flops,
            "bytes_per_device": 1e9, "collective_bytes_per_device": coll,
        },
    }


def test_store_diff_mode(monkeypatch, capsys, tmp_path):
    a, b = tmp_path / "a.json", tmp_path / "b.json"
    a.write_text(json.dumps(_store(2e12, 0.0)))
    b.write_text(json.dumps(_store(1e12, 0.0)))
    out = _run_main(monkeypatch, capsys, [str(a), str(b)])
    # compute moved 2x -> row printed; collective is 0 -> skipped
    row = next(l for l in out.splitlines() if "zenlda|4096x64|single" in l)
    assert "compute" in row and " 2.00 |" in row
    assert "collective" not in out


def test_store_diff_min_ratio_filters(monkeypatch, capsys, tmp_path):
    a, b = tmp_path / "a.json", tmp_path / "b.json"
    a.write_text(json.dumps(_store(1.02e12, 0.0)))
    b.write_text(json.dumps(_store(1e12, 0.0)))
    out = _run_main(monkeypatch, capsys, [str(a), str(b)])
    assert "compute" not in out  # 1.02x under the default 1.05 gate
    out = _run_main(monkeypatch, capsys,
                    [str(a), str(b), "--min-ratio", "1.01"])
    assert "compute" in out


def test_store_diff_skips_failed_cells(monkeypatch, capsys, tmp_path):
    a, b = tmp_path / "a.json", tmp_path / "b.json"
    bad = _store(2e12, 0.0)
    bad["zenlda|4096x64|single"]["ok"] = False
    a.write_text(json.dumps(bad))
    b.write_text(json.dumps(_store(1e12, 0.0)))
    out = _run_main(monkeypatch, capsys, [str(a), str(b)])
    assert "zenlda|4096x64|single" not in [
        l.split("|")[1].strip() for l in out.splitlines()
        if l.startswith("| zen")
    ]
