"""Count-matrix maintenance for CGS LDA.

On CPU/reference paths counts are maintained with scatter-adds; the TPU hot
path replaces the scatter with the one-hot-matmul Pallas histogram kernel
(``repro.kernels.topic_histogram``) because scatter lowers poorly on TPU while
an (E_tile, K) one-hot @ segment-selector matmul runs on the MXU.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def build_counts(
    word: jax.Array,
    doc: jax.Array,
    topic: jax.Array,
    num_words: int,
    num_docs: int,
    num_topics: int,
    mask: jax.Array | None = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Build (n_wk, n_kd, n_k) from scratch from token assignments.

    ``mask`` (optional, bool (E,)) marks *real* tokens; padded dummy tokens
    contribute nothing. Rebuilding counts from assignments is the elastic
    restore path: any re-partitioning of tokens yields identical counts.
    """
    ones = jnp.ones_like(topic) if mask is None else mask.astype(jnp.int32)
    n_wk = jnp.zeros((num_words, num_topics), jnp.int32).at[word, topic].add(ones)
    n_kd = jnp.zeros((num_docs, num_topics), jnp.int32).at[doc, topic].add(ones)
    n_k = jnp.zeros((num_topics,), jnp.int32).at[topic].add(ones)
    return n_wk, n_kd, n_k


def delta_counts(
    word: jax.Array,
    doc: jax.Array,
    old_topic: jax.Array,
    new_topic: jax.Array,
    num_words: int,
    num_docs: int,
    num_topics: int,
    mask: jax.Array | None = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Delta aggregation (paper §5.2): counts change only where topic changed.

    Returns (d_wk, d_kd, d_k) such that ``new_counts = old_counts + delta``.
    Tokens with ``old == new`` contribute exactly zero, so the aggregate
    becomes sparser as training converges — this is what the compressed
    collective in ``repro.core.distributed`` exploits.
    """
    changed = old_topic != new_topic
    if mask is not None:
        changed = changed & mask
    inc = changed.astype(jnp.int32)
    d_wk = (
        jnp.zeros((num_words, num_topics), jnp.int32)
        .at[word, new_topic].add(inc)
        .at[word, old_topic].add(-inc)
    )
    d_kd = (
        jnp.zeros((num_docs, num_topics), jnp.int32)
        .at[doc, new_topic].add(inc)
        .at[doc, old_topic].add(-inc)
    )
    d_k = (
        jnp.zeros((num_topics,), jnp.int32)
        .at[new_topic].add(inc)
        .at[old_topic].add(-inc)
    )
    return d_wk, d_kd, d_k


def doc_lengths(doc: jax.Array, num_docs: int, mask: jax.Array | None = None) -> jax.Array:
    ones = jnp.ones_like(doc) if mask is None else mask.astype(jnp.int32)
    return jnp.zeros((num_docs,), jnp.int32).at[doc].add(ones)


def word_frequencies(word: jax.Array, num_words: int, mask: jax.Array | None = None) -> jax.Array:
    ones = jnp.ones_like(word) if mask is None else mask.astype(jnp.int32)
    return jnp.zeros((num_words,), jnp.int32).at[word].add(ones)
