""""Converged" token exclusion (paper §5.1)."""
import jax
import jax.numpy as jnp
import numpy as np
from helpers import given, settings, st  # hypothesis, or the fallback shim

from repro.core.exclusion import (
    ExclusionConfig,
    active_mask,
    compact_active,
    update_exclusion_stats,
)
from repro.core.types import CGSState


def _mkstate(e, iteration=0, i=None, t=None):
    z = jnp.zeros((e,), jnp.int32)
    return CGSState(
        topic=z, prev_topic=z, n_wk=jnp.zeros((2, 2), jnp.int32),
        n_kd=jnp.zeros((2, 2), jnp.int32), n_k=jnp.zeros((2,), jnp.int32),
        rng=jax.random.key(0), iteration=iteration,
        stale_iters=jnp.zeros((e,), jnp.int32) if i is None else i,
        same_count=jnp.zeros((e,), jnp.int32) if t is None else t,
    )


def test_disabled_means_all_active(key):
    state = _mkstate(100)
    mask = active_mask(state, ExclusionConfig(enabled=False), key)
    assert bool(jnp.all(mask))


def test_warmup_all_active(key):
    state = _mkstate(100, iteration=10)
    cfg = ExclusionConfig(enabled=True, start_iteration=30)
    assert bool(jnp.all(active_mask(state, cfg, key)))


def test_probability_2_pow_i_minus_t(key):
    """P(resample) = 2^(i-t): t=3,i=0 -> 1/8 expected activity."""
    e = 40_000
    state = _mkstate(
        e, iteration=100,
        i=jnp.zeros((e,), jnp.int32),
        t=jnp.full((e,), 3, jnp.int32),
    )
    cfg = ExclusionConfig(enabled=True, start_iteration=1)
    frac = float(jnp.mean(active_mask(state, cfg, key).astype(jnp.float32)))
    np.testing.assert_allclose(frac, 0.125, atol=0.01)


def test_stats_update_rules():
    state = _mkstate(4, i=jnp.asarray([1, 1, 5, 0], jnp.int32),
                     t=jnp.asarray([2, 2, 1, 0], jnp.int32))
    new_topic = jnp.asarray([0, 1, 0, 0], jnp.int32)  # token 1 changed
    mask = jnp.asarray([True, True, False, True])
    i, t = update_exclusion_stats(state, new_topic, mask)
    # processed unchanged -> i=0, t+1 ; processed changed -> 0,0 ;
    # skipped -> i+1, t ; processed unchanged -> 0, t+1
    np.testing.assert_array_equal(np.asarray(i), [0, 0, 6, 0])
    np.testing.assert_array_equal(np.asarray(t), [3, 0, 1, 1])


@settings(max_examples=30, deadline=None)
@given(st.lists(st.booleans(), min_size=1, max_size=64))
def test_compact_active_partition(mask):
    mask_j = jnp.asarray(mask)
    vals = jnp.arange(len(mask), dtype=jnp.int32)
    perm, (vals_p,), num_active = compact_active(mask_j, vals)
    n = int(num_active)
    assert n == sum(mask)
    # active tokens occupy the prefix, stable order
    active_vals = [i for i, m in enumerate(mask) if m]
    np.testing.assert_array_equal(np.asarray(vals_p[:n]), active_vals)
    # permutation is a bijection
    assert sorted(np.asarray(perm).tolist()) == list(range(len(mask)))


def test_exclusion_reduces_work_but_keeps_quality(key, tiny_corpus, tiny_hyper):
    """Fig. 9: with exclusion on, fewer tokens are resampled per iteration
    while llh stays comparable."""
    from repro.core import LDATrainer, TrainConfig

    base = LDATrainer(tiny_corpus, tiny_hyper, TrainConfig(algorithm="zen"))
    excl = LDATrainer(
        tiny_corpus, tiny_hyper,
        TrainConfig(algorithm="zen",
                    exclusion=ExclusionConfig(enabled=True, start_iteration=4)),
    )
    sb = base.init_state(key)
    se = excl.init_state(key)
    for _ in range(12):
        sb = base.step(sb)
        se = excl.step(se)
    se.check_invariants(tiny_corpus)
    lb, le = base.llh(sb), excl.llh(se)
    assert abs(lb - le) / abs(lb) < 0.05
    # activity must have dropped below 100% late in training
    frac_active = float(jnp.mean((se.stale_iters == 0).astype(jnp.float32)))
    assert frac_active < 0.995
