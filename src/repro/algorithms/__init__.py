"""Pluggable CGS sampler backends behind one registry (DESIGN.md §4).

The paper's generality claim — switching the CGS algorithm is "a few lines
of code change" on a shared substrate — is this package's architecture:
every algorithm (single-box, distributed, Pallas-fused) implements the
``SamplerBackend`` contract and registers under a name; the trainer, the
shard_map cell step, the launch CLIs, and the benchmarks all resolve through
``algorithms.get(name)``.

Adding an algorithm = one module with ``@register("name")``. Nothing else
in the system changes.
"""
# NOTE: base + registry must be fully imported before the backend modules —
# the backends pull in repro.core, whose __init__ imports the trainer, which
# imports SamplerKnobs/get from this (then partially-initialized) package.
from repro.algorithms.base import (  # noqa: F401
    CellBackend,
    SamplerBackend,
    SamplerKnobs,
    auto_pad,
    fill_cell_row_pads,
    knobs_from,
    resolve_row_pads,
)
from repro.algorithms.registry import (  # noqa: F401
    describe,
    get,
    register,
    registered,
)

# importing a backend module registers it (order = registered() order)
from repro.algorithms import zen_dense  # noqa: F401,E402  zen, zen_dense, std
from repro.algorithms import zen_sparse  # noqa: F401,E402
from repro.algorithms import zen_hybrid  # noqa: F401,E402
from repro.algorithms import sparselda  # noqa: F401,E402
from repro.algorithms import lightlda  # noqa: F401,E402
from repro.algorithms import zen_cdf  # noqa: F401,E402
from repro.algorithms import zen_pallas  # noqa: F401,E402  + zen_dense_kernel
