"""Formula decompositions: every variant must equal Eq. 3 when fresh."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.decompositions import (
    precompute_zen_terms,
    sparselda_buckets,
    std_probs,
    zen_probs,
)
from repro.core.types import LDAHyperParams


@pytest.fixture()
def setup(rng):
    k, w_total, t = 16, 50, 12
    n_wk = jnp.asarray(rng.integers(0, 30, (t, k)), jnp.int32)
    n_kd = jnp.asarray(rng.integers(0, 10, (t, k)), jnp.int32)
    n_k = jnp.asarray(rng.integers(20, 400, (k,)), jnp.int32)
    hyper = LDAHyperParams(num_topics=k, alpha=0.05, beta=0.01)
    return n_wk, n_kd, n_k, hyper, w_total


def _eq3(n_wk, n_kd, n_k, alpha_k, beta, w_total):
    denom = n_k.astype(jnp.float32)[None, :] + w_total * beta
    return (
        (n_wk.astype(jnp.float32) + beta) / denom
        * (n_kd.astype(jnp.float32) + alpha_k[None, :])
    )


def test_zen_decomposition_equals_eq3(setup):
    """gDense + wSparse + dSparse == Eq. 3 (paper §3.1)."""
    n_wk, n_kd, n_k, hyper, w_total = setup
    terms = precompute_zen_terms(n_k, hyper, w_total)
    p_zen = zen_probs(n_wk, n_kd, terms, hyper.beta)
    p_ref = _eq3(n_wk, n_kd, n_k, terms.alpha_k, hyper.beta, w_total)
    np.testing.assert_allclose(np.asarray(p_zen), np.asarray(p_ref), rtol=2e-5)


def test_sparselda_buckets_equal_eq3(setup):
    """s + r + q == Eq. 3 (Table 1, SparseLDA column)."""
    n_wk, n_kd, n_k, hyper, w_total = setup
    terms = precompute_zen_terms(n_k, hyper, w_total)
    s, r, q = sparselda_buckets(n_wk, n_kd, terms, hyper.beta)
    p_ref = _eq3(n_wk, n_kd, n_k, terms.alpha_k, hyper.beta, w_total)
    np.testing.assert_allclose(
        np.asarray(s + r + q), np.asarray(p_ref), rtol=2e-5
    )


def test_std_probs_equals_eq3(setup):
    n_wk, n_kd, n_k, hyper, w_total = setup
    terms = precompute_zen_terms(n_k, hyper, w_total)
    p = std_probs(n_wk, n_kd, n_k, terms.alpha_k, hyper.beta, w_total)
    p_ref = _eq3(n_wk, n_kd, n_k, terms.alpha_k, hyper.beta, w_total)
    np.testing.assert_allclose(np.asarray(p), np.asarray(p_ref), rtol=2e-5)


def test_alg5_redundancy_elimination_identity(setup):
    """Paper Alg. 5: t4 = t2 + (t2*t3).*t1 equals alpha_k/(N_k+W*beta)."""
    n_wk, n_kd, n_k, hyper, w_total = setup
    terms = precompute_zen_terms(n_k, hyper, w_total)
    alpha_direct = hyper.alpha_k(n_k)
    t4_direct = alpha_direct / (n_k.astype(jnp.float32) + w_total * hyper.beta)
    np.testing.assert_allclose(
        np.asarray(terms.t4), np.asarray(t4_direct), rtol=2e-5
    )
    np.testing.assert_allclose(
        np.asarray(terms.alpha_k), np.asarray(alpha_direct), rtol=2e-5
    )


def test_asymmetric_prior_sums_to_k_alpha(setup):
    """Wallach approximation: sum_k alpha_k ~= K * alpha * N/(N+alpha')."""
    _, _, n_k, hyper, _ = setup
    alpha_k = hyper.alpha_k(n_k)
    n = float(jnp.sum(n_k))
    expected = hyper.num_topics * hyper.alpha * (
        (n + hyper.alpha_prime) / (n + hyper.alpha_prime)
    )
    np.testing.assert_allclose(
        float(jnp.sum(alpha_k)), hyper.num_topics * hyper.alpha, rtol=1e-5
    )
    # hot topics get proportionally more prior mass
    order_alpha = np.argsort(np.asarray(alpha_k))
    order_nk = np.argsort(np.asarray(n_k))
    np.testing.assert_array_equal(order_alpha, order_nk)
