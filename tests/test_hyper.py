"""Topic-duplicate merging (paper §4.3)."""
import jax.numpy as jnp
import numpy as np

from repro.core.hyper import duplicate_topic_map, merge_topics, topic_l1_distances


def test_l1_distances():
    n_wk = jnp.asarray([[10, 10, 0], [0, 0, 10], [10, 10, 0]], jnp.int32)
    d = np.asarray(topic_l1_distances(n_wk))
    assert d[0, 1] < 1e-6  # identical distributions
    assert d[0, 2] > 1.0  # disjoint -> L1 distance 2


def test_duplicate_map_and_merge():
    # topics 0 and 1 identical; 2 distinct
    n_wk = np.array([[5, 5, 0], [5, 5, 0], [0, 0, 10], [2, 2, 0]], np.int32)
    tmap = duplicate_topic_map(n_wk, threshold=0.1)
    assert tmap[1] == tmap[0] == 0
    assert tmap[2] == 2

    topic = jnp.asarray([0, 1, 2, 1], jnp.int32)
    n_kd = jnp.asarray([[1, 1, 1], [1, 1, 0]], jnp.int32)
    n_k = jnp.asarray(np.asarray(n_wk).sum(0), jnp.int32)
    new_topic, m_wk, m_kd, m_k = merge_topics(
        topic, jnp.asarray(n_wk), n_kd, n_k, jnp.asarray(tmap)
    )
    # conservation
    assert int(jnp.sum(m_wk)) == int(np.asarray(n_wk).sum())
    assert int(jnp.sum(m_k)) == int(np.asarray(n_wk).sum())
    # merged column got both topics' mass; old column emptied
    assert int(m_k[0]) == int(n_k[0] + n_k[1])
    assert int(m_k[1]) == 0
    np.testing.assert_array_equal(np.asarray(new_topic), [0, 0, 2, 0])


def test_lower_threshold_merges_more():
    rng = np.random.default_rng(0)
    n_wk = rng.integers(0, 5, (30, 8)).astype(np.int32)
    m_strict = duplicate_topic_map(n_wk, threshold=0.01)
    m_loose = duplicate_topic_map(n_wk, threshold=2.1)
    assert len(np.unique(m_loose)) <= len(np.unique(m_strict))
