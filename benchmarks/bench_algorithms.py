"""Paper Figs. 3 + 4: every registered CGS backend — time/iteration and
log-likelihood after equal iterations, all on the shared substrate
("the only difference is the algorithm").

The sweep list IS the registry: a newly registered backend shows up here
with zero benchmark changes — on BOTH axes: the single-box sweep below,
and a mesh x backend sweep that times the distributed step for every
``supports_shard_map`` backend on a simulated 2-device CPU mesh. The mesh
cells run in a subprocess because the host device count locks at first
jax init (same trick as tests/helpers.py)."""
from __future__ import annotations

import os
import subprocess
import sys
import time

import jax

from benchmarks.common import row
from repro import algorithms
from repro.core import LDATrainer, TrainConfig, LDAHyperParams
from repro.data import synthetic_lda_corpus

_MESH_CHILD = """
import warnings; warnings.filterwarnings('ignore')
import time
import jax, jax.numpy as jnp, numpy as np
from repro.data import synthetic_lda_corpus
from repro.core.types import LDAHyperParams
from repro.core.graph import grid_partition
from repro.launch.mesh import make_mesh
from repro.core.distributed import (DistConfig, init_dist_state,
                                    make_dist_step, resolve_dist_row_pads)
corpus, _ = synthetic_lda_corpus(0, num_docs=400, num_words=800,
                                 num_topics=32, avg_doc_len=64)
hyper = LDAHyperParams(num_topics=32, alpha=0.05, beta=0.01)
mesh = make_mesh((1, 2), ('data', 'model'))
grid = grid_partition(corpus, 1, 2)
state, data = init_dist_state(jax.random.key(0), mesh, grid, hyper)
cfg = resolve_dist_row_pads(state, DistConfig(algorithm={alg!r},
                                              max_kd=0, max_kw=0))
step = make_dist_step(mesh, hyper, cfg, grid.words_per_shard,
                      grid.docs_per_shard)
state = step(state, data)  # warm compile
jax.block_until_ready(state.n_k)
t0 = time.perf_counter()
for _ in range({iters}):
    state = step(state, data)
jax.block_until_ready(state.n_k)
print('US_PER_ITER', (time.perf_counter() - t0) / {iters} * 1e6)
"""


def mesh_sweep(iters: int = 5) -> None:
    """fig3 mesh axis: distributed step time for every mesh-capable
    backend, 2 simulated CPU devices, (1, 2) data x model mesh."""
    import repro

    # repro is a namespace package (no __init__.py): locate src via __path__
    src = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=2 "
        + env.get("XLA_FLAGS", "")
    ).strip()
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    from repro.launch.mesh import mesh_backends

    for alg in mesh_backends():
        # a bad cell (timeout, crash, missing marker) records an error row
        # and the sweep moves on — one backend never aborts the whole run
        try:
            out = subprocess.run(
                [sys.executable, "-c",
                 _MESH_CHILD.format(alg=alg, iters=iters)],
                env=env, capture_output=True, text=True, timeout=1800,
            )
        except subprocess.TimeoutExpired:
            row(f"fig3_mesh2dev_time_per_iter_{alg}", float("nan"),
                "error=timeout")
            continue
        us = next(
            (float(line.split()[1]) for line in out.stdout.splitlines()
             if line.startswith("US_PER_ITER")),
            None,
        )
        if out.returncode != 0 or us is None:
            err = out.stderr.strip().splitlines()
            row(f"fig3_mesh2dev_time_per_iter_{alg}", float("nan"),
                "error=" + err[-1][:80] if err else "error")
            continue
        row(f"fig3_mesh2dev_time_per_iter_{alg}", us)


def main(iters: int = 10):
    corpus, _ = synthetic_lda_corpus(
        0, num_docs=400, num_words=800, num_topics=32, avg_doc_len=64
    )
    hyper = LDAHyperParams(num_topics=32, alpha=0.05, beta=0.01)
    results = {}
    for alg in algorithms.registered():
        tr = LDATrainer(
            corpus, hyper,
            TrainConfig(algorithm=alg, max_kw=64, max_kd=64, num_mh=8),
        )
        st = tr.init_state(jax.random.key(0))
        st = tr.step(st)  # warm compile
        t0 = time.perf_counter()
        for _ in range(iters):
            st = tr.step(st)
        dt = (time.perf_counter() - t0) / iters
        llh = tr.llh(st)
        results[alg] = (dt, llh)
        row(f"fig3_time_per_iter_{alg}", dt * 1e6, f"llh={llh:.1f}")
    # headline ratios (paper: 2-6x over LightLDA, ~14x over SparseLDA for
    # the customized-scale corpora; CPU-vectorized small-corpus ratios are
    # reported as measured)
    z = results["zen_sparse"][0]
    row("fig3_speedup_vs_lightlda", 0.0,
        f"ratio={results['lightlda'][0] / z:.2f}")
    row("fig3_speedup_vs_sparselda", 0.0,
        f"ratio={results['sparselda'][0] / z:.2f}")
    row("fig4_llh_zen_minus_lightlda", 0.0,
        f"delta={results['zen_sparse'][1] - results['lightlda'][1]:.1f}")
    mesh_sweep()


if __name__ == "__main__":
    main()
