"""Model-quality evaluation subsystem (DESIGN.md §9).

Perplexity alone cannot audit the paper's efficiency-vs-accuracy
tradeoffs (unsynchronized model, sparse init, token exclusion); this
package adds the two standard independent quality signals plus the
hyper-parameter optimization that the quality curves are sensitive to:

* ``repro.eval.coherence`` — topic coherence over the frozen model's
  top-N words per topic: UMass (document co-occurrence) and NPMI
  (sliding-window PMI), both computed host-side from the corpus.
* ``repro.eval.left_to_right`` — Wallach-style particle-based
  left-to-right held-out log-likelihood, next to the doc-completion
  perplexity in ``repro.core.likelihood``; ``exhaustive_llh`` is the
  exact-enumeration oracle the tests pin it against.
* ``repro.eval.quality`` — ``QualityConfig``/``QualityEval``: one
  evaluator the ``TrainSession`` "quality" schedule action, the
  ``launch/compare.py --sessions`` table, and ``benchmarks/run.py
  --only quality`` all share.

The Alg. 5 hyper-parameter moves (Minka fixed-point alpha, beta
annealing) live in ``repro.core.hyper`` and fire as the session's
"hyper" schedule action — disabled they are pinned bit-identical to a
no-hyper run.
"""
from repro.eval.coherence import (  # noqa: F401
    CoherenceStats,
    npmi_coherence,
    top_topic_words,
    umass_coherence,
)
from repro.eval.left_to_right import (  # noqa: F401
    exhaustive_llh,
    left_to_right_llh,
)
from repro.eval.quality import QualityConfig, QualityEval  # noqa: F401
