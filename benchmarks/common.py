"""Shared benchmark helpers: timing + the CSV contract of run.py."""
from __future__ import annotations

import os
import time
from typing import Callable

import jax


def time_fn(fn: Callable, *args, iters: int = 3, warmup: int = 1) -> float:
    """Median wall time per call in microseconds (block_until_ready)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def row(name: str, us_per_call: float, derived: str = "") -> str:
    line = f"{name},{us_per_call:.1f},{derived}"
    print(line, flush=True)
    return line


def bench_out_path(name: str) -> str:
    """Where ``BENCH_*.json`` artifacts land: ``$BENCH_OUT_DIR``
    (default ``benchmarks/results/``), created on demand. Benchmarks
    must write machine-readable output through this — never the repo
    root (``run.py --out-dir`` overrides the env)."""
    out_dir = os.environ.get("BENCH_OUT_DIR") or os.path.join(
        "benchmarks", "results"
    )
    os.makedirs(out_dir, exist_ok=True)
    return os.path.join(out_dir, name)
