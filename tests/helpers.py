"""Subprocess helper for multi-device tests (device count locks at first
jax init, so distributed tests run in children with their own XLA_FLAGS)."""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n_devices: int = 4, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", code],
        env=env, capture_output=True, text=True, timeout=timeout,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout
