"""Jitted public wrappers for the Pallas kernels (padding, dtype glue).

``interpret`` defaults to True on CPU (validation) and False on TPU
(production); callers can force either.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.topic_histogram import topic_histogram_pallas
from repro.kernels.zen_sampler import (
    zen_infer_sample_pallas,
    zen_sample_pallas,
)


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x: jax.Array, axis: int, multiple: int, value=0) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(
    jax.jit,
    static_argnames=("beta", "w_beta", "bt", "bk", "interpret"),
)
def zen_sample(
    nwk_rows: jax.Array,
    nkd_rows: jax.Array,
    z_old: jax.Array,
    alpha_k: jax.Array,
    n_k: jax.Array,
    seed: jax.Array,
    *,
    beta: float,
    w_beta: float,
    bt: int = 256,
    bk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused three-term CGS sample per token (see zen_sampler.py).

    Pads T to bt and K to bk; K padding gets p=0 rows (alpha_k=0, counts 0)
    so padded topics can never win the argmax.
    """
    if interpret is None:
        interpret = _on_cpu()
    t, k = nwk_rows.shape
    bt_eff = min(bt, max(8, t))
    nwk_p = _pad_to(_pad_to(nwk_rows, 0, bt_eff), 1, bk)
    nkd_p = _pad_to(_pad_to(nkd_rows, 0, bt_eff), 1, bk)
    z_p = _pad_to(z_old, 0, bt_eff)
    # padded topics: alpha_k = 0 and n_k large => p == 0 there
    a_p = _pad_to(alpha_k.astype(jnp.float32), 0, bk, value=0.0)
    nk_p = _pad_to(n_k.astype(jnp.float32), 0, bk, value=1e9)
    out = zen_sample_pallas(
        nwk_p, nkd_p, z_p, a_p, nk_p, seed,
        beta=beta, w_beta=w_beta, bt=bt_eff, bk=bk, interpret=interpret,
    )
    return out[:t]


@functools.partial(
    jax.jit,
    static_argnames=("beta", "w_beta", "bt", "bk", "interpret"),
)
def zen_infer_sample(
    nwk_rows: jax.Array,
    nkd_rows: jax.Array,
    z_old: jax.Array,
    seeds: jax.Array,
    alpha_k: jax.Array,
    n_k: jax.Array,
    *,
    beta: float,
    w_beta: float,
    bt: int = 256,
    bk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Frozen-model serving sample (see ``_zen_infer_kernel``): doc-side
    exclusion only, per-token counter-based seeds.

    Pads T to bt (inert seed-0 tokens, sliced off) and K to bk; K padding
    gets alpha_k = 0 and zero doc counts, so p == 0 there and a padded
    topic can never win the argmax.
    """
    if interpret is None:
        interpret = _on_cpu()
    t, k = nwk_rows.shape
    bt_eff = min(bt, max(8, t))
    nwk_p = _pad_to(_pad_to(nwk_rows, 0, bt_eff), 1, bk)
    nkd_p = _pad_to(_pad_to(nkd_rows, 0, bt_eff), 1, bk)
    z_p = _pad_to(z_old, 0, bt_eff)
    s_p = _pad_to(seeds, 0, bt_eff)
    a_p = _pad_to(alpha_k.astype(jnp.float32), 0, bk, value=0.0)
    nk_p = _pad_to(n_k.astype(jnp.float32), 0, bk, value=1e9)
    out = zen_infer_sample_pallas(
        nwk_p, nkd_p, z_p, s_p, a_p, nk_p,
        beta=beta, w_beta=w_beta, bt=bt_eff, bk=bk, interpret=interpret,
    )
    return out[:t]


@functools.partial(
    jax.jit,
    static_argnames=("num_rows", "num_topics", "bt", "bk", "interpret"),
)
def topic_histogram(
    rows_sorted: jax.Array,
    z_old: jax.Array,
    z_new: jax.Array,
    inc: jax.Array,
    num_rows: int,
    num_topics: int,
    *,
    bt: int = 256,
    bk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Signed delta histogram (num_rows, num_topics); see topic_histogram.py.

    Padding tokens get inc=0 (inert) and row = last row (stays sorted).
    """
    if interpret is None:
        interpret = _on_cpu()
    t = rows_sorted.shape[0]
    bt_eff = min(bt, max(8, t))
    last_row = rows_sorted[-1]
    rows_p = _pad_to(rows_sorted, 0, bt_eff)
    pad = rows_p.shape[0] - t
    if pad:
        rows_p = rows_p.at[t:].set(last_row)
    z_old_p = _pad_to(z_old, 0, bt_eff)
    z_new_p = _pad_to(z_new, 0, bt_eff)
    inc_p = _pad_to(inc, 0, bt_eff)  # zero => inert
    k_pad = (-num_topics) % bk
    out = topic_histogram_pallas(
        rows_p, z_old_p, z_new_p, inc_p, num_rows, num_topics + k_pad,
        bt=bt_eff, bk=bk, interpret=interpret,
    )
    return out[:, :num_topics]
