"""``zen_hybrid`` — ZenLDAHybrid (paper §3.1): per-token pick the
decomposition whose fresh term ranges over the sparser row.

Realized as two-group dispatch over the *registry's own* ``zen_sparse``
(fresh term over K_d) and ``sparselda`` (fresh term over K_w) backends, so
measured work tracks min(K_d, K_w) and the hybrid automatically follows any
improvement to either constituent backend.

The switch is evaluated on the rows each constituent will *actually
sample*: the raw row nnz is clamped to the padded capacity the constituent
sparsifies at (``max_kd`` for the doc side, ``max_kw`` for the word side),
and — under ``shard_map`` — the nnz comes from the shard-local count
blocks, not any global density. A doc row with 100 live topics truncated
to a 16-wide pad costs 16, not 100, and the route must price it that way.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.algorithms.base import CellBackend, SamplerKnobs
from repro.algorithms.registry import get, register


def hybrid_route_doc_side(
    n_wk: jax.Array,  # (Ws, K) the block the word side will sparsify
    n_kd: jax.Array,  # (Ds, K) the block the doc side will sparsify
    word: jax.Array,  # (T,)
    doc: jax.Array,  # (T,)
    max_kw: int,
    max_kd: int,
) -> jax.Array:
    """True where the doc-side decomposition (zen_sparse) samples the
    narrower *effective* row — nnz clamped to the constituent's padded
    capacity, computed on the exact count blocks the constituents get."""
    kd_eff = jnp.minimum(jnp.sum(n_kd > 0, axis=-1), max_kd)[doc]
    kw_eff = jnp.minimum(jnp.sum(n_wk > 0, axis=-1), max_kw)[word]
    return kd_eff <= kw_eff


@register("zen_hybrid")
class ZenHybrid(CellBackend):
    """Route each token to the sparser of the two decompositions."""

    needs_row_pads = True

    def cell_sweep(
        self, key, word, doc, z_old, mask, n_wk, n_kd, n_k, hyper,
        num_words_pad, knobs: SamplerKnobs,
    ):
        knobs = self.resolve_cell_knobs(knobs, hyper)
        use_zen = hybrid_route_doc_side(
            n_wk, n_kd, word, doc, knobs.max_kw, knobs.max_kd
        )
        z_zen = get("zen_sparse").cell_sweep(
            key, word, doc, z_old, mask, n_wk, n_kd, n_k, hyper,
            num_words_pad, knobs,
        )
        z_alt = get("sparselda").cell_sweep(
            key, word, doc, z_old, mask, n_wk, n_kd, n_k, hyper,
            num_words_pad, knobs,
        )
        return jnp.where(use_zen, z_zen, z_alt)
