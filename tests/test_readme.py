"""The README quickstart must execute verbatim (docs-rot guard).

Extracts the first ``bash`` fenced block under "## Quickstart" from the
repo-root README.md and runs it through a real shell from the repo root,
exactly as a reader would. A plain local ``pytest`` run includes it; in
CI it runs ONLY as its own dedicated workflow step — the tier-1 CI step
passes ``--ignore=tests/test_readme.py`` so the train->serve subprocess
pipeline is not paid twice per CI run.
"""
import pathlib
import re
import subprocess

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _quickstart_snippet() -> str:
    readme = (ROOT / "README.md").read_text()
    section = readme.split("## Quickstart", 1)
    assert len(section) == 2, "README.md lost its Quickstart section"
    m = re.search(r"```bash\n(.*?)```", section[1], re.S)
    assert m, "Quickstart section lost its bash snippet"
    return m.group(1)


def test_readme_quickstart_runs_verbatim():
    snippet = _quickstart_snippet()
    proc = subprocess.run(
        ["bash", "-euo", "pipefail", "-c", snippet],
        cwd=ROOT,
        capture_output=True,
        text=True,
        timeout=900,
    )
    assert proc.returncode == 0, (
        f"README quickstart failed\nstdout:\n{proc.stdout}\n"
        f"stderr:\n{proc.stderr}"
    )
    assert "latency ms: p50=" in proc.stdout, proc.stdout
