"""Core dataclasses for ZenLDA: hyper-parameters, corpus, and sampler state.

The CGS Markov state is exactly ``(topic assignments, rng)`` — all count
matrices are derived — which is what makes checkpointing and elastic
re-sharding cheap (see ``repro.train.checkpoint``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LDAHyperParams:
    """Hyper-parameters of the (asymmetric-prior) LDA model, paper Eq. 3."""

    num_topics: int
    alpha: float = 0.01
    beta: float = 0.01
    # Wallach-style asymmetric document-topic prior strength (paper's alpha').
    alpha_prime: float = 1.0
    # Use the asymmetric alpha_k = K*alpha*(N_k + alpha'/K)/(N + alpha')
    # approximation.  If False, alpha_k == alpha (symmetric).
    asymmetric_alpha: bool = True

    def alpha_k(self, n_k: jax.Array) -> jax.Array:
        """Per-topic alpha_k from the asymmetric prior (paper Alg. 5, t2/t4)."""
        if not self.asymmetric_alpha:
            return jnp.full(self.num_topics, self.alpha, dtype=jnp.float32)
        n_k = n_k.astype(jnp.float32)
        n_total = jnp.sum(n_k)
        k = float(self.num_topics)
        return (k * self.alpha) * (n_k + self.alpha_prime / k) / (
            n_total + self.alpha_prime
        )


@dataclasses.dataclass(frozen=True)
class Corpus:
    """A token-level (edge list) corpus.

    One row per token occurrence; this is the flattened form of the paper's
    bipartite graph where an edge (w, d) carries an *array* of topic slots
    (one per occurrence).
    """

    word: jax.Array  # (E,) int32 word id per token
    doc: jax.Array  # (E,) int32 doc id per token
    num_words: int  # W
    num_docs: int  # D

    @property
    def num_tokens(self) -> int:
        return int(self.word.shape[0])

    def validate(self) -> None:
        assert self.word.shape == self.doc.shape
        assert self.word.dtype == jnp.int32 and self.doc.dtype == jnp.int32


@dataclasses.dataclass
class CGSState:
    """Full sampler state: assignments + derived counts + RNG.

    ``topic`` is the per-token topic assignment z_dw (edge attribute).
    ``prev_topic`` is the assignment from the previous iteration — needed by
    delta aggregation (paper §5.2: "requires to store the old topic sampled
    last time ... doubles the attribute size in edge").
    ``stale_iters``/``same_count`` drive "converged" token exclusion (§5.1):
    i = iterations not processed, t = times processed with unchanged topic.
    """

    topic: jax.Array  # (E,) int32
    prev_topic: jax.Array  # (E,) int32
    n_wk: jax.Array  # (W, K) int32
    n_kd: jax.Array  # (D, K) int32
    n_k: jax.Array  # (K,) int32
    rng: jax.Array
    iteration: int = 0
    stale_iters: Optional[jax.Array] = None  # (E,) int32, token-exclusion "i"
    same_count: Optional[jax.Array] = None  # (E,) int32, token-exclusion "t"

    def check_invariants(self, corpus: Corpus) -> None:
        """Count-conservation invariants (used by property tests)."""
        import numpy as np

        n_wk = np.asarray(self.n_wk)
        n_kd = np.asarray(self.n_kd)
        n_k = np.asarray(self.n_k)
        assert n_wk.sum() == corpus.num_tokens
        assert n_kd.sum() == corpus.num_tokens
        assert n_k.sum() == corpus.num_tokens
        np.testing.assert_array_equal(n_wk.sum(axis=0), n_k)
        np.testing.assert_array_equal(n_kd.sum(axis=0), n_k)
        assert (n_wk >= 0).all() and (n_kd >= 0).all() and (n_k >= 0).all()
