"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the real
single CPU device; multi-device tests spawn subprocesses (helpers.py)."""
import jax
import numpy as np
import pytest

from repro.core.types import LDAHyperParams
from repro.data import synthetic_lda_corpus


@pytest.fixture(scope="session")
def tiny_corpus():
    corpus, phi = synthetic_lda_corpus(
        seed=0, num_docs=40, num_words=60, num_topics=6, avg_doc_len=30
    )
    return corpus


@pytest.fixture(scope="session")
def tiny_hyper():
    return LDAHyperParams(num_topics=6, alpha=0.1, beta=0.05)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.key(0)
