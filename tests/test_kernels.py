"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + statistics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from helpers import given, settings, st  # hypothesis, or the fallback shim

from repro.kernels.ops import (
    _pad_to,
    cdf_row_search,
    sparse_row_sample,
    topic_histogram,
    zen_fused_infer_sample,
    zen_fused_sample,
    zen_infer_sample,
    zen_sample,
)
from repro.kernels.ref import (
    cdf_row_search_ref,
    sparse_row_sample_ref,
    topic_histogram_ref,
    zen_fused_infer_sample_ref,
    zen_fused_sample_ref,
    zen_infer_sample_ref,
    zen_probs_ref,
    zen_sample_ref,
)
from repro.kernels.zen_sampler import hash_uniform


@pytest.mark.parametrize(
    "t,k,bt,bk",
    [
        (64, 128, 64, 128),
        (128, 256, 64, 128),
        (9, 33, 8, 128),  # unaligned -> padding path
        (300, 700, 64, 128),
        (256, 1024, 128, 256),
        (1, 5, 8, 128),
    ],
)
def test_zen_sampler_bit_exact(t, k, bt, bk, rng):
    nwk = jnp.asarray(rng.integers(0, 50, (t, k)), jnp.int32)
    nkd = jnp.asarray(rng.integers(0, 20, (t, k)), jnp.int32)
    z = jnp.asarray(rng.integers(0, k, (t,)), jnp.int32)
    nk = jnp.asarray(np.asarray(nwk).sum(0) + 1, jnp.float32)
    ak = jnp.asarray(rng.random(k) + 0.01, jnp.float32)
    out = zen_sample(nwk, nkd, z, ak, nk, jnp.int32(7), beta=0.01,
                     w_beta=5.0, bt=bt, bk=bk)
    ref = zen_sample_ref(nwk, nkd, z, ak, nk, jnp.int32(7), beta=0.01,
                         w_beta=5.0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize(
    "t,k,bt,bk",
    [
        (64, 128, 64, 128),
        (9, 33, 8, 128),  # unaligned -> padding path
        (300, 700, 64, 128),
        (1, 5, 8, 128),
    ],
)
def test_zen_infer_sampler_bit_exact(t, k, bt, bk, rng):
    """Frozen-model serving variant == its pure-jnp oracle, bit for bit
    (doc-side-only exclusion, per-token seeds)."""
    nwk = jnp.asarray(rng.integers(0, 50, (t, k)), jnp.int32)
    nkd = jnp.asarray(rng.integers(0, 20, (t, k)), jnp.int32)
    z = jnp.asarray(rng.integers(0, k, (t,)), jnp.int32)
    seeds = jnp.asarray(rng.integers(0, 2 ** 31 - 1, (t,)), jnp.int32)
    nk = jnp.asarray(np.asarray(nwk).sum(0) + 1, jnp.float32)
    ak = jnp.asarray(rng.random(k) + 0.01, jnp.float32)
    out = zen_infer_sample(nwk, nkd, z, seeds, ak, nk, beta=0.01,
                           w_beta=5.0, bt=bt, bk=bk)
    ref = zen_infer_sample_ref(nwk, nkd, z, seeds, ak, nk, beta=0.01,
                               w_beta=5.0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 80), st.integers(2, 200), st.integers(0, 2 ** 20))
def test_zen_sampler_property_sweep(t, k, seed):
    rng = np.random.default_rng(seed)
    nwk = jnp.asarray(rng.integers(0, 9, (t, k)), jnp.int32)
    nkd = jnp.asarray(rng.integers(0, 5, (t, k)), jnp.int32)
    z = jnp.asarray(rng.integers(0, k, (t,)), jnp.int32)
    nk = jnp.asarray(np.asarray(nwk).sum(0) + 1, jnp.float32)
    ak = jnp.asarray(rng.random(k) + 0.01, jnp.float32)
    out = zen_sample(nwk, nkd, z, ak, nk, jnp.int32(seed % 97), beta=0.05,
                     w_beta=2.0, bt=8, bk=128)
    ref = zen_sample_ref(nwk, nkd, z, ak, nk, jnp.int32(seed % 97),
                         beta=0.05, w_beta=2.0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_zen_sampler_distribution_chi_square(rng):
    """The Gumbel-max draw follows the exact ¬dw conditional."""
    k = 16
    reps = 4000
    nwk = jnp.asarray(np.tile(rng.integers(0, 20, (1, k)), (reps, 1)), jnp.int32)
    nkd = jnp.asarray(np.tile(rng.integers(0, 8, (1, k)), (reps, 1)), jnp.int32)
    z = jnp.full((reps,), 3, jnp.int32)
    nk = jnp.asarray(np.asarray(nwk)[0] * 50 + 10, jnp.float32)
    ak = jnp.asarray(rng.random(k) + 0.05, jnp.float32)
    # different seed per batch -> independent draws of the same conditional
    draws = []
    for seed in range(6):
        out = zen_sample(nwk, nkd, z, ak, nk, jnp.int32(seed), beta=0.01,
                         w_beta=3.0, bt=8, bk=128)
        draws.append(np.asarray(out))
    emp = np.bincount(np.concatenate(draws), minlength=k) / (reps * 6)
    p = np.asarray(
        zen_probs_ref(nwk[:1], nkd[:1], z[:1], ak, nk, beta=0.01, w_beta=3.0)
    )[0]
    chi2 = ((emp - p) ** 2 / np.maximum(p, 1e-9)).sum() * reps * 6
    assert chi2 < 3 * k, (chi2, emp, p)  # loose 3x dof bound


def test_hash_uniform_statistics():
    """The in-kernel counter hash is uniform enough: mean/var/KS checks."""
    rows = jnp.arange(1 << 12, dtype=jnp.int32)[:, None]
    cols = jnp.arange(64, dtype=jnp.int32)[None, :]
    u = np.asarray(hash_uniform(jnp.int32(123), rows, cols)).ravel()
    assert 0.0 < u.min() and u.max() < 1.0
    np.testing.assert_allclose(u.mean(), 0.5, atol=2e-3)
    np.testing.assert_allclose(u.var(), 1.0 / 12, atol=2e-3)
    # no obvious correlation between adjacent counters
    c = np.corrcoef(u[:-1], u[1:])[0, 1]
    assert abs(c) < 0.02


@pytest.mark.parametrize(
    "t,k,r",
    [(256, 512, 40), (100, 48, 7), (1024, 256, 200), (8, 16, 1), (33, 9, 5)],
)
def test_topic_histogram_exact(t, k, r, rng):
    rows = np.sort(rng.integers(0, r, t)).astype(np.int32)
    zo = rng.integers(0, k, t).astype(np.int32)
    zn = rng.integers(0, k, t).astype(np.int32)
    inc = rng.integers(0, 2, t).astype(np.int32)
    out = topic_histogram(
        jnp.asarray(rows), jnp.asarray(zo), jnp.asarray(zn),
        jnp.asarray(inc), r, k, bt=64, bk=128,
    )
    ref = topic_histogram_ref(
        jnp.asarray(rows), jnp.asarray(zo), jnp.asarray(zn),
        jnp.asarray(inc), r, k,
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 120), st.integers(2, 60), st.integers(1, 30),
       st.integers(0, 2 ** 20))
def test_topic_histogram_property_sweep(t, k, r, seed):
    rng = np.random.default_rng(seed)
    rows = np.sort(rng.integers(0, r, t)).astype(np.int32)
    zo = rng.integers(0, k, t).astype(np.int32)
    zn = rng.integers(0, k, t).astype(np.int32)
    inc = rng.integers(0, 2, t).astype(np.int32)
    out = topic_histogram(
        jnp.asarray(rows), jnp.asarray(zo), jnp.asarray(zn),
        jnp.asarray(inc), r, k, bt=16, bk=128,
    )
    ref = topic_histogram_ref(
        jnp.asarray(rows), jnp.asarray(zo), jnp.asarray(zn),
        jnp.asarray(inc), r, k,
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    # row sums are zero: a move is (-1, +1) within the same row
    np.testing.assert_array_equal(np.asarray(jnp.sum(out, 1)),
                                  np.zeros(r, np.int32))


# ---------------------------------------------------------------------------
# kernel suite v2: fused gather+sample, CDF search, padded-sparse rows
# ---------------------------------------------------------------------------

def _fused_inputs(rng, t, k, w, d):
    n_wk = jnp.asarray(rng.integers(0, 50, (w, k)), jnp.int32)
    n_kd = jnp.asarray(rng.integers(0, 20, (d, k)), jnp.int32)
    word = jnp.asarray(rng.integers(0, w, (t,)), jnp.int32)
    doc = jnp.asarray(rng.integers(0, d, (t,)), jnp.int32)
    z = jnp.asarray(rng.integers(0, k, (t,)), jnp.int32)
    nk = jnp.asarray(np.asarray(n_wk).sum(0) + 1, jnp.float32)
    ak = jnp.asarray(rng.random(k) + 0.01, jnp.float32)
    return n_wk, n_kd, word, doc, z, nk, ak


@pytest.mark.parametrize(
    "t,k,w,d,bt,bk",
    [
        (64, 128, 40, 30, 64, 128),
        (9, 33, 40, 30, 8, 128),  # unaligned -> padding path
        (300, 700, 100, 50, 64, 128),
        (1, 5, 7, 3, 8, 128),
    ],
)
def test_zen_fused_sample_bit_exact(t, k, w, d, bt, bk, rng):
    """Fused gather+sample == the gather-then-oracle ref AND the v1
    gather-then-kernel wrapper, bit for bit: skipping the materialized
    (T, K) gather changes nothing."""
    n_wk, n_kd, word, doc, z, nk, ak = _fused_inputs(rng, t, k, w, d)
    out = zen_fused_sample(n_wk, n_kd, word, doc, z, ak, nk, jnp.int32(7),
                           beta=0.01, w_beta=5.0, bt=bt, bk=bk)
    ref = zen_fused_sample_ref(n_wk, n_kd, word, doc, z, ak, nk, jnp.int32(7),
                               beta=0.01, w_beta=5.0)
    legacy = zen_sample(n_wk[word], n_kd[doc], z, ak, nk, jnp.int32(7),
                        beta=0.01, w_beta=5.0, bt=bt, bk=bk)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(legacy))


@pytest.mark.parametrize(
    "t,k,w,d,bt,bk",
    [
        (64, 128, 40, 30, 64, 128),
        (9, 33, 40, 30, 8, 128),  # unaligned -> padding path
        (300, 700, 100, 50, 64, 128),
        (1, 5, 7, 3, 8, 128),
    ],
)
def test_zen_fused_infer_sample_bit_exact(t, k, w, d, bt, bk, rng):
    """Fused serving variant == gather-then-oracle AND the v1 gathered
    wrapper (doc-side-only exclusion, per-token seeds)."""
    n_wk, n_kd, word, slot, z, nk, ak = _fused_inputs(rng, t, k, w, d)
    seeds = jnp.asarray(rng.integers(0, 2 ** 31 - 1, (t,)), jnp.int32)
    out = zen_fused_infer_sample(n_wk, n_kd, word, slot, z, seeds, ak, nk,
                                 beta=0.01, w_beta=5.0, bt=bt, bk=bk)
    ref = zen_fused_infer_sample_ref(n_wk, n_kd, word, slot, z, seeds, ak, nk,
                                     beta=0.01, w_beta=5.0)
    legacy = zen_infer_sample(n_wk[word], n_kd[slot], z, seeds, ak, nk,
                              beta=0.01, w_beta=5.0, bt=bt, bk=bk)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(legacy))


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 60), st.integers(2, 150), st.integers(2, 40),
       st.integers(1, 20), st.integers(0, 2 ** 20))
def test_zen_fused_sample_property_sweep(t, k, w, d, seed):
    rng = np.random.default_rng(seed)
    n_wk, n_kd, word, doc, z, nk, ak = _fused_inputs(rng, t, k, w, d)
    s = jnp.int32(seed % 89)
    out = zen_fused_sample(n_wk, n_kd, word, doc, z, ak, nk, s,
                           beta=0.05, w_beta=2.0, bt=8, bk=128)
    ref = zen_fused_sample_ref(n_wk, n_kd, word, doc, z, ak, nk, s,
                               beta=0.05, w_beta=2.0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize(
    "t,k,w,bt,bk",
    [
        (64, 128, 40, 64, 128),
        (9, 33, 12, 8, 128),  # unaligned -> padding path
        (300, 700, 80, 64, 128),
        (128, 256, 64, 64, 256),
        (1, 5, 3, 8, 128),
    ],
)
def test_cdf_row_search_bit_exact(t, k, w, bt, bk, rng):
    """Fused CDF lower-bound search == the tile-accurate ref at the same
    bk, including targets past the total row mass (clamp to K-1)."""
    counts = jnp.asarray(rng.integers(0, 50, (w, k)), jnp.int32)
    rows = jnp.asarray(rng.integers(0, w, (t,)), jnp.int32)
    term = jnp.asarray(rng.random(k) + 1e-3, jnp.float32)
    mass = jnp.sum(counts[rows].astype(jnp.float32) * term[None, :], 1)
    # * 1.1: ~10% of targets overshoot the total mass -> clamp path
    targets = jnp.asarray(rng.random(t), jnp.float32) * mass * 1.1
    out = cdf_row_search(counts, rows, term, targets, bt=bt, bk=bk)
    ref = cdf_row_search_ref(counts, rows, term, targets, bk=bk)
    assert out.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert (np.asarray(out) >= 0).all() and (np.asarray(out) < k).all()


@pytest.mark.parametrize(
    "t,j,bt,bs",
    [
        (64, 32, 64, 128),
        (9, 5, 8, 128),  # unaligned -> padding path
        (300, 200, 64, 128),
        (40, 300, 8, 256),
        (1, 1, 8, 128),
    ],
)
def test_sparse_row_sample_bit_exact(t, j, bt, bs, rng):
    """Padded-sparse row inversion == its oracle, bit for bit, including
    zero-weight lanes and targets past the row mass."""
    vals = jnp.asarray(
        rng.random((t, j)) * (rng.random((t, j)) < 0.6), jnp.float32
    )
    topics = jnp.asarray(rng.integers(0, 50, (t, j)), jnp.int32)
    targets = jnp.asarray(rng.random(t), jnp.float32) * \
        jnp.sum(vals, 1) * 1.05
    out = sparse_row_sample(vals, topics, targets, bt=bt, bs=bs)
    ref = sparse_row_sample_ref(vals, topics, targets)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 80), st.integers(1, 60), st.integers(0, 2 ** 20))
def test_sparse_row_sample_property_sweep(t, j, seed):
    rng = np.random.default_rng(seed)
    vals = jnp.asarray(
        rng.random((t, j)) * (rng.random((t, j)) < 0.5), jnp.float32
    )
    topics = jnp.asarray(rng.integers(0, 30, (t, j)), jnp.int32)
    targets = jnp.asarray(rng.random(t), jnp.float32) * jnp.sum(vals, 1)
    out = sparse_row_sample(vals, topics, targets, bt=8, bs=128)
    ref = sparse_row_sample_ref(vals, topics, targets)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ---------------------------------------------------------------------------
# padding contracts: _pad_to invariants + tile-choice inertness
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1), st.integers(1, 40), st.integers(1, 40),
       st.integers(1, 13), st.integers(-5, 5), st.integers(0, 2 ** 20))
def test_pad_to_properties(axis, n, m, multiple, value, seed):
    """ops._pad_to: minimal padding to the multiple, original values are an
    untouched prefix, every padded entry equals the fill value."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-100, 100, (n, m)), jnp.int32)
    y = _pad_to(x, axis, multiple, value)
    assert y.shape[axis] % multiple == 0
    assert 0 <= y.shape[axis] - x.shape[axis] < multiple
    assert y.shape[1 - axis] == x.shape[1 - axis]
    sl = [slice(None)] * 2
    sl[axis] = slice(0, x.shape[axis])
    np.testing.assert_array_equal(np.asarray(y[tuple(sl)]), np.asarray(x))
    sl[axis] = slice(x.shape[axis], None)
    pad = np.asarray(y[tuple(sl)])
    assert pad.size == 0 or (pad == value).all()
    if x.shape[axis] % multiple == 0:
        assert y is x  # no-copy fast path


@settings(max_examples=6, deadline=None)
@given(st.integers(1, 50), st.integers(2, 80), st.integers(0, 2 ** 20))
def test_fused_sample_inert_across_tile_grid(t, k, seed):
    """Tile choice only changes padding amounts, never the samples: the
    fused training kernel is bit-stable across the legal (bt, bk) grid
    (exact f32 compare in the running-max carry, padded topics p == 0)."""
    rng = np.random.default_rng(seed)
    n_wk, n_kd, word, doc, z, nk, ak = _fused_inputs(rng, t, k, 20, 10)
    s = jnp.int32(seed % 101)
    outs = [
        np.asarray(zen_fused_sample(
            n_wk, n_kd, word, doc, z, ak, nk, s,
            beta=0.03, w_beta=3.0, bt=bt, bk=bk,
        ))
        for bt in (8, 64, 256) for bk in (128, 256)
    ]
    for o in outs[1:]:
        np.testing.assert_array_equal(o, outs[0])


@settings(max_examples=6, deadline=None)
@given(st.integers(1, 50), st.integers(2, 80), st.integers(0, 2 ** 20))
def test_cdf_search_inert_across_bt(t, k, seed):
    """Token tiling is inert for the CDF search (rows are independent);
    only bk participates in the float carry, so bt sweeps at fixed bk must
    agree bit for bit."""
    rng = np.random.default_rng(seed)
    counts = jnp.asarray(rng.integers(0, 40, (16, k)), jnp.int32)
    rows = jnp.asarray(rng.integers(0, 16, (t,)), jnp.int32)
    term = jnp.asarray(rng.random(k) + 1e-3, jnp.float32)
    mass = jnp.sum(counts[rows].astype(jnp.float32) * term[None, :], 1)
    targets = jnp.asarray(rng.random(t), jnp.float32) * mass * 1.1
    outs = [
        np.asarray(cdf_row_search(counts, rows, term, targets, bt=bt, bk=128))
        for bt in (8, 16, 64, 256)
    ]
    for o in outs[1:]:
        np.testing.assert_array_equal(o, outs[0])


@settings(max_examples=6, deadline=None)
@given(st.integers(1, 50), st.integers(1, 60), st.integers(0, 2 ** 20))
def test_sparse_row_inert_across_tile_grid(t, j, seed):
    """The sparse-row kernel is bit-stable across (bt, bs): lane padding
    adds weight-0 lanes the clamp can never land on, token padding is
    sliced off."""
    rng = np.random.default_rng(seed)
    vals = jnp.asarray(
        rng.random((t, j)) * (rng.random((t, j)) < 0.5), jnp.float32
    )
    topics = jnp.asarray(rng.integers(0, 30, (t, j)), jnp.int32)
    targets = jnp.asarray(rng.random(t), jnp.float32) * jnp.sum(vals, 1)
    outs = [
        np.asarray(sparse_row_sample(vals, topics, targets, bt=bt, bs=bs))
        for bt in (8, 64, 256) for bs in (128, 256)
    ]
    for o in outs[1:]:
        np.testing.assert_array_equal(o, outs[0])
