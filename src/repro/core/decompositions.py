"""Formula decompositions of the CGS conditional (paper §3.1, Table 1).

All decompositions target the same conditional (paper Eq. 3):

    p(z=k) ∝ (N_w|k + β) / (N_k + Wβ) * (N_k|d + α_k)

with the asymmetric-prior α_k = Kα(N_k + α'/K)/(ΣN_k + α').

``precompute_zen_terms`` implements the redundant-computation elimination of
paper Alg. 5 verbatim (t1..t6): every per-iteration loop-invariant is
computed once as a K-vector so that the inner loops are pure vector FMAs —
the paper's SIMD `.*` maps to VPU lane-parallel ops on TPU.
"""
from __future__ import annotations

import enum
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.types import LDAHyperParams


class Decomposition(enum.Enum):
    """Which sampling algorithm / decomposition to use (paper Table 1)."""

    STD = "std"  # O(K) standard CGS, fresh
    ZEN = "zen"  # gDense + wSparse + dSparse (paper's choice)
    ZEN_HYBRID = "zen_hybrid"  # per-token min(K_d, K_w) alternation
    SPARSE_LDA = "sparselda"  # s + r + q buckets, LSearch
    ALIAS_LDA = "aliaslda"  # stale alias + fresh K_d term, MH
    LIGHT_LDA = "lightlda"  # cycle MH word/doc proposals


class ZenTerms(NamedTuple):
    """Per-iteration loop invariants (paper Alg. 5)."""

    t1: jax.Array  # (K,) 1 / (N_k + W*beta)
    t4: jax.Array  # (K,) alpha_k / (N_k + W*beta)
    t5: jax.Array  # (K,) beta / (N_k + W*beta)
    g_dense: jax.Array  # (K,) alpha_k * beta / (N_k + W*beta)   [term 1]
    alpha_k: jax.Array  # (K,)
    g_mass: jax.Array  # () sum of g_dense


def precompute_zen_terms(
    n_k: jax.Array, hyper: LDAHyperParams, num_words: int
) -> ZenTerms:
    """Paper Alg. 5 lines 1-6: t1..t5 and gDense, all K-vectors, once/iter."""
    n_k = n_k.astype(jnp.float32)
    w_beta = num_words * hyper.beta
    t1 = 1.0 / (n_k + w_beta)
    if hyper.asymmetric_alpha:
        n_total = jnp.sum(n_k)
        k = float(hyper.num_topics)
        t2 = k * hyper.alpha / (n_total + hyper.alpha_prime)
        t3 = hyper.alpha_prime / k - w_beta
        # t4 = alpha_k * t1 = t2 + (t2 * t3) .* t1     (Alg. 5 line 4)
        t4 = t2 + (t2 * t3) * t1
        alpha_k = t4 * (n_k + w_beta)
    else:
        alpha_k = jnp.full_like(n_k, hyper.alpha)
        t4 = alpha_k * t1
    t5 = hyper.beta * t1
    g_dense = hyper.beta * t4
    return ZenTerms(
        t1=t1, t4=t4, t5=t5, g_dense=g_dense, alpha_k=alpha_k,
        g_mass=jnp.sum(g_dense),
    )


def zen_probs(
    n_wk_rows: jax.Array,  # (T, K) gathered word-topic rows
    n_kd_rows: jax.Array,  # (T, K) gathered doc-topic rows
    terms: ZenTerms,
    beta: float,
) -> jax.Array:
    """Unnormalized p (T, K) via the ZenLDA three-term decomposition.

    p = gDense + N_wk .* t4 + N_kd .* (N_wk + beta) .* t1
    Identical to Eq. 3 when counts are fresh; with stale counts this is the
    paper's approximation (remedied by resampling, see ``zen_sparse``).
    """
    n_wk_rows = n_wk_rows.astype(jnp.float32)
    n_kd_rows = n_kd_rows.astype(jnp.float32)
    w_sparse = n_wk_rows * terms.t4[None, :]
    d_sparse = n_kd_rows * (n_wk_rows + beta) * terms.t1[None, :]
    return terms.g_dense[None, :] + w_sparse + d_sparse


def std_probs(
    n_wk_rows: jax.Array,
    n_kd_rows: jax.Array,
    n_k: jax.Array,
    alpha_k: jax.Array,
    beta: float,
    num_words: int,
) -> jax.Array:
    """Unnormalized p (T, K) straight from Eq. 3 — no decomposition.

    ``n_k`` may be (K,) or already per-token (T, K) (¬dw-decremented).
    """
    denom = n_k.astype(jnp.float32) + num_words * beta
    return (
        (n_wk_rows.astype(jnp.float32) + beta)
        / denom
        * (n_kd_rows.astype(jnp.float32) + alpha_k)
    )


def sparselda_buckets(
    n_wk_rows: jax.Array,
    n_kd_rows: jax.Array,
    terms: ZenTerms,
    beta: float,
):
    """SparseLDA's s/r/q buckets (Table 1 rightmost column).

    s = alpha_k*beta*t1 (dense), r = N_kd*beta*t1 (K_d sparse),
    q = N_wk*(N_kd+alpha_k)*t1 (K_w sparse). Sum equals Eq. 3.
    """
    s = terms.g_dense[None, :] * jnp.ones_like(n_kd_rows, dtype=jnp.float32)
    r = n_kd_rows.astype(jnp.float32) * terms.t5[None, :]
    q = n_wk_rows.astype(jnp.float32) * (
        n_kd_rows.astype(jnp.float32) * terms.t1[None, :] + terms.t4[None, :]
    )
    return s, r, q
