"""Kernel suite v2 microbenchmarks (ISSUE 6): each new kernel vs its
pre-fusion baseline, across a small tile sweep, recorded as
``BENCH_kernels.json``.

Rows (CSV via common.row + JSON):

* ``fused_sample``  vs baseline = HBM gather + v1 ``zen_sample``
* ``fused_infer``   vs baseline = HBM gather + v1 ``zen_infer_sample``
* ``cdf_search``    vs baseline = (Ws, K) float CDF build + XLA bsearch
* ``sparse_row``    vs baseline = XLA cumsum/count/take over padded rows

Sizes are env-tunable (``BENCH_KERNELS_T`` / ``_K`` / ``_W`` / ``_D`` /
``_J``, tile lists ``_BTS`` / ``_BKS`` / ``_BSS`` as comma ints) and
default tiny so the CI smoke finishes in seconds; on CPU the kernels run
in interpret mode (recorded in the JSON — absolute numbers are only
meaningful on a real TPU, the *relative* tile sweep and the baseline
contrast are what the row exists to track).
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn


def _env_int(name: str, default: int) -> int:
    return int(os.environ.get(name, default))


def _env_ints(name: str, default: tuple) -> tuple:
    raw = os.environ.get(name)
    return tuple(int(x) for x in raw.split(",")) if raw else default


def main() -> None:
    from repro.algorithms.zen_cdf import _bsearch_gather
    from repro.kernels.autotune import (
        autotune_cdf,
        autotune_fused,
        autotune_sparse,
    )
    from repro.kernels.ops import (
        zen_fused_infer_sample,
        zen_infer_sample,
        zen_sample,
    )

    t = _env_int("BENCH_KERNELS_T", 256)
    k = _env_int("BENCH_KERNELS_K", 128)
    w = _env_int("BENCH_KERNELS_W", 96)
    d = _env_int("BENCH_KERNELS_D", 64)
    j = _env_int("BENCH_KERNELS_J", 64)
    bts = _env_ints("BENCH_KERNELS_BTS", (64, 128))
    bks = _env_ints("BENCH_KERNELS_BKS", (128,))
    bss = _env_ints("BENCH_KERNELS_BSS", (128,))

    rng = np.random.default_rng(0)
    n_wk = jnp.asarray(rng.integers(0, 50, (w, k)), jnp.int32)
    n_kd = jnp.asarray(rng.integers(0, 20, (d, k)), jnp.int32)
    word = jnp.asarray(rng.integers(0, w, (t,)), jnp.int32)
    doc = jnp.asarray(rng.integers(0, d, (t,)), jnp.int32)
    z = jnp.asarray(rng.integers(0, k, (t,)), jnp.int32)
    seeds = jnp.asarray(rng.integers(0, 2**31 - 1, (t,)), jnp.int32)
    n_k = jnp.asarray(np.asarray(n_wk).sum(0) + 1, jnp.float32)
    alpha_k = jnp.asarray(rng.random(k) + 0.01, jnp.float32)
    seed = jnp.int32(7)
    beta, w_beta = 0.01, k * 0.01

    records = []

    def record(kernel, label, us, tok, baseline, bt=0, bk=0, bs=0):
        records.append(dict(
            kernel=kernel, label=label, us_per_call=us,
            tokens_per_sec=tok / us * 1e6, baseline=baseline,
            bt=bt, bk=bk, bs=bs,
            t=t, k=k, w=w, d=d, j=j,
            backend=jax.default_backend(),
            interpret=jax.default_backend() == "cpu",
        ))
        row(f"kernels/{kernel}/{label}", us, f"tok/s={tok / us * 1e6:.0f}")

    # --- fused gather+sample vs gather-then-v1 ---------------------------
    bt0, bk0 = bts[0], bks[0]
    us = time_fn(
        lambda: zen_sample(
            n_wk[word], n_kd[doc], z, alpha_k, n_k, seed,
            beta=beta, w_beta=w_beta, bt=bt0, bk=bk0,
        )
    )
    record("fused_sample", "baseline_gather_v1", us, t, True, bt=bt0, bk=bk0)
    for tt in autotune_fused(
        n_wk, n_kd, word, doc, z, alpha_k, n_k, seed,
        beta=beta, w_beta=w_beta, bts=bts, bks=bks,
    ):
        record("fused_sample", f"bt{tt.bt}_bk{tt.bk}", tt.us_per_call, t,
               False, bt=tt.bt, bk=tt.bk)

    # --- fused infer variant vs gather-then-v1-infer ---------------------
    us = time_fn(
        lambda: zen_infer_sample(
            n_wk[word], n_kd[doc], z, seeds, alpha_k, n_k,
            beta=beta, w_beta=w_beta, bt=bt0, bk=bk0,
        )
    )
    record("fused_infer", "baseline_gather_v1", us, t, True, bt=bt0, bk=bk0)
    us = time_fn(
        lambda: zen_fused_infer_sample(
            n_wk, n_kd, word, doc, z, seeds, alpha_k, n_k,
            beta=beta, w_beta=w_beta, bt=bt0, bk=bk0,
        )
    )
    record("fused_infer", f"bt{bt0}_bk{bk0}", us, t, False, bt=bt0, bk=bk0)

    # --- cdf search vs materialized w_cdf + XLA bsearch ------------------
    term = jnp.asarray(rng.random(k) + 1e-3, jnp.float32)
    mass = jnp.sum(n_wk[word].astype(jnp.float32) * term[None, :], 1)
    targets = jnp.asarray(rng.random(t), jnp.float32) * mass

    @jax.jit
    def cdf_baseline():
        w_cdf = jnp.cumsum(
            n_wk.astype(jnp.float32) * term[None, :], axis=-1
        )
        return _bsearch_gather(w_cdf, word, targets)

    us = time_fn(cdf_baseline)
    record("cdf_search", "baseline_wcdf_bsearch", us, t, True)
    for tt in autotune_cdf(n_wk, word, term, targets, bts=bts, bks=bks):
        record("cdf_search", f"bt{tt.bt}_bk{tt.bk}", tt.us_per_call, t,
               False, bt=tt.bt, bk=tt.bk)

    # --- sparse row vs XLA cumsum/count/take -----------------------------
    vals = jnp.asarray(
        rng.random((t, j)) * (rng.random((t, j)) < 0.5), jnp.float32
    )
    topics = jnp.asarray(rng.integers(0, k, (t, j)), jnp.int32)
    s_targets = jnp.asarray(rng.random(t), jnp.float32) * jnp.sum(vals, 1)

    @jax.jit
    def sparse_baseline():
        cdf = jnp.cumsum(vals, axis=-1)
        pos = jnp.sum(cdf < s_targets[:, None], axis=-1)
        pos = jnp.minimum(pos, vals.shape[-1] - 1)
        return jnp.take_along_axis(topics, pos[:, None], axis=-1)[:, 0]

    us = time_fn(sparse_baseline)
    record("sparse_row", "baseline_xla", us, t, True)
    for tt in autotune_sparse(vals, topics, s_targets, bts=bts, bss=bss):
        record("sparse_row", f"bt{tt.bt}_bs{tt.bs}", tt.us_per_call, t,
               False, bt=tt.bt, bs=tt.bs)

    from benchmarks.common import bench_out_path

    with open(bench_out_path("BENCH_kernels.json"), "w") as f:
        json.dump(records, f, indent=2)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
