"""Jitted public wrappers for the Pallas kernels (padding, dtype glue).

``interpret`` defaults to True on CPU (validation) and False on TPU
(production); callers can force either.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.cdf_search import cdf_row_search_pallas
from repro.kernels.fused_gather import (
    zen_fused_infer_sample_pallas,
    zen_fused_sample_pallas,
)
from repro.kernels.sparse_row import sparse_row_sample_pallas
from repro.kernels.topic_histogram import topic_histogram_pallas
from repro.kernels.zen_sampler import (
    zen_infer_sample_pallas,
    zen_sample_pallas,
)

# Whole-row sparse kernel VMEM budget: bt shrinks until a (bt, J) f32 tile
# plus its int32 twin fit comfortably (2 * 4B * 2^18 = 2 MiB of VMEM).
_SPARSE_ROW_BUDGET = 1 << 18


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x: jax.Array, axis: int, multiple: int, value=0) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(
    jax.jit,
    static_argnames=("beta", "w_beta", "bt", "bk", "interpret"),
)
def zen_sample(
    nwk_rows: jax.Array,
    nkd_rows: jax.Array,
    z_old: jax.Array,
    alpha_k: jax.Array,
    n_k: jax.Array,
    seed: jax.Array,
    *,
    beta: float,
    w_beta: float,
    bt: int = 256,
    bk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused three-term CGS sample per token (see zen_sampler.py).

    Pads T to bt and K to bk; K padding gets p=0 rows (alpha_k=0, counts 0)
    so padded topics can never win the argmax.
    """
    if interpret is None:
        interpret = _on_cpu()
    t, k = nwk_rows.shape
    bt_eff = min(bt, max(8, t))
    nwk_p = _pad_to(_pad_to(nwk_rows, 0, bt_eff), 1, bk)
    nkd_p = _pad_to(_pad_to(nkd_rows, 0, bt_eff), 1, bk)
    z_p = _pad_to(z_old, 0, bt_eff)
    # padded topics: alpha_k = 0 and n_k large => p == 0 there
    a_p = _pad_to(alpha_k.astype(jnp.float32), 0, bk, value=0.0)
    nk_p = _pad_to(n_k.astype(jnp.float32), 0, bk, value=1e9)
    out = zen_sample_pallas(
        nwk_p, nkd_p, z_p, a_p, nk_p, seed,
        beta=beta, w_beta=w_beta, bt=bt_eff, bk=bk, interpret=interpret,
    )
    return out[:t]


@functools.partial(
    jax.jit,
    static_argnames=("beta", "w_beta", "bt", "bk", "interpret"),
)
def zen_infer_sample(
    nwk_rows: jax.Array,
    nkd_rows: jax.Array,
    z_old: jax.Array,
    seeds: jax.Array,
    alpha_k: jax.Array,
    n_k: jax.Array,
    *,
    beta: float,
    w_beta: float,
    bt: int = 256,
    bk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Frozen-model serving sample (see ``_zen_infer_kernel``): doc-side
    exclusion only, per-token counter-based seeds.

    Pads T to bt (inert seed-0 tokens, sliced off) and K to bk; K padding
    gets alpha_k = 0 and zero doc counts, so p == 0 there and a padded
    topic can never win the argmax.
    """
    if interpret is None:
        interpret = _on_cpu()
    t, k = nwk_rows.shape
    bt_eff = min(bt, max(8, t))
    nwk_p = _pad_to(_pad_to(nwk_rows, 0, bt_eff), 1, bk)
    nkd_p = _pad_to(_pad_to(nkd_rows, 0, bt_eff), 1, bk)
    z_p = _pad_to(z_old, 0, bt_eff)
    s_p = _pad_to(seeds, 0, bt_eff)
    a_p = _pad_to(alpha_k.astype(jnp.float32), 0, bk, value=0.0)
    nk_p = _pad_to(n_k.astype(jnp.float32), 0, bk, value=1e9)
    out = zen_infer_sample_pallas(
        nwk_p, nkd_p, z_p, s_p, a_p, nk_p,
        beta=beta, w_beta=w_beta, bt=bt_eff, bk=bk, interpret=interpret,
    )
    return out[:t]


@functools.partial(
    jax.jit,
    static_argnames=("beta", "w_beta", "bt", "bk", "interpret"),
)
def zen_fused_sample(
    n_wk: jax.Array,
    n_kd: jax.Array,
    word: jax.Array,
    doc: jax.Array,
    z_old: jax.Array,
    alpha_k: jax.Array,
    n_k: jax.Array,
    seed: jax.Array,
    *,
    beta: float,
    w_beta: float,
    bt: int = 256,
    bk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused gather+sample (see fused_gather.py): ``zen_sample`` without
    the ``(T, K)`` gathered-row HBM intermediate — the per-token word/doc
    ids are scalar-prefetched and the count rows are tiled straight out of
    the resident matrices. Bit-identical to
    ``zen_sample(n_wk[word], n_kd[doc], ...)`` for real tokens.

    Pads T to bt (row-0 tokens, sliced off) and K to bk on the resident
    matrices; K padding gets alpha_k = 0 / counts 0 / n_k = 1e9 so p == 0
    there and a padded topic can never win the argmax.
    """
    if interpret is None:
        interpret = _on_cpu()
    t = word.shape[0]
    bt_eff = min(bt, max(8, t))
    nwk_p = _pad_to(n_wk.astype(jnp.int32), 1, bk)
    nkd_p = _pad_to(n_kd.astype(jnp.int32), 1, bk)
    w_p = _pad_to(word, 0, bt_eff)
    d_p = _pad_to(doc, 0, bt_eff)
    z_p = _pad_to(z_old, 0, bt_eff)
    a_p = _pad_to(alpha_k.astype(jnp.float32), 0, bk, value=0.0)
    nk_p = _pad_to(n_k.astype(jnp.float32), 0, bk, value=1e9)
    out = zen_fused_sample_pallas(
        nwk_p, nkd_p, w_p, d_p, z_p, a_p, nk_p, seed,
        beta=beta, w_beta=w_beta, bt=bt_eff, bk=bk, interpret=interpret,
    )
    return out[:t]


@functools.partial(
    jax.jit,
    static_argnames=("beta", "w_beta", "bt", "bk", "interpret"),
)
def zen_fused_infer_sample(
    n_wk: jax.Array,
    n_kd: jax.Array,
    word: jax.Array,
    slot: jax.Array,
    z_old: jax.Array,
    seeds: jax.Array,
    alpha_k: jax.Array,
    n_k: jax.Array,
    *,
    beta: float,
    w_beta: float,
    bt: int = 256,
    bk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused gather + frozen-model serving sample: ``zen_infer_sample``
    without the gathered-row intermediates. Bit-identical to
    ``zen_infer_sample(n_wk[word], n_kd[slot], ...)`` for real tokens.

    Padding contract matches ``zen_infer_sample``: T pads to bt with
    row-0/seed-0 tokens (sliced off), K pads to bk with alpha_k = 0 /
    counts 0 / n_k = 1e9.
    """
    if interpret is None:
        interpret = _on_cpu()
    t = word.shape[0]
    bt_eff = min(bt, max(8, t))
    nwk_p = _pad_to(n_wk.astype(jnp.int32), 1, bk)
    nkd_p = _pad_to(n_kd.astype(jnp.int32), 1, bk)
    w_p = _pad_to(word, 0, bt_eff)
    s_p = _pad_to(slot, 0, bt_eff)
    z_p = _pad_to(z_old, 0, bt_eff)
    seeds_p = _pad_to(seeds, 0, bt_eff)
    a_p = _pad_to(alpha_k.astype(jnp.float32), 0, bk, value=0.0)
    nk_p = _pad_to(n_k.astype(jnp.float32), 0, bk, value=1e9)
    out = zen_fused_infer_sample_pallas(
        nwk_p, nkd_p, w_p, s_p, z_p, seeds_p, a_p, nk_p,
        beta=beta, w_beta=w_beta, bt=bt_eff, bk=bk, interpret=interpret,
    )
    return out[:t]


@functools.partial(
    jax.jit,
    static_argnames=("bt", "bk", "interpret"),
)
def cdf_row_search(
    counts: jax.Array,
    rows: jax.Array,
    term: jax.Array,
    targets: jax.Array,
    *,
    bt: int = 256,
    bk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused gather + CDF lower-bound search (see cdf_search.py): the
    index of ``targets[t]`` in ``cumsum(counts[rows[t]] * term)``, clamped
    to K-1, without materializing the float CDF matrix or the gathered
    rows. Bit-identical to ``ref.cdf_row_search_ref`` at the same bk.

    Pads T to bt (row-0 tokens, sliced off) and K to bk with term = 0, so
    padded topics add no mass; the in-kernel clamp keeps any counts past
    K-1 from escaping.
    """
    if interpret is None:
        interpret = _on_cpu()
    t = rows.shape[0]
    k = counts.shape[1]
    bt_eff = min(bt, max(8, t))
    counts_p = _pad_to(counts.astype(jnp.int32), 1, bk)
    rows_p = _pad_to(rows, 0, bt_eff)
    term_p = _pad_to(term.astype(jnp.float32), 0, bk, value=0.0)
    tgt_p = _pad_to(targets.astype(jnp.float32), 0, bt_eff)
    out = cdf_row_search_pallas(
        counts_p, rows_p, term_p, tgt_p,
        k_real=k, bt=bt_eff, bk=bk, interpret=interpret,
    )
    return out[:t]


@functools.partial(
    jax.jit,
    static_argnames=("bt", "bs", "interpret"),
)
def sparse_row_sample(
    vals: jax.Array,
    topics: jax.Array,
    targets: jax.Array,
    *,
    bt: int = 256,
    bs: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Whole-row sparse CDF inversion (see sparse_row.py): the topic id at
    the lower-bound position of ``targets[t]`` in ``cumsum(vals[t])``,
    clamped to the last real lane. Bit-identical to
    ``ref.sparse_row_sample_ref``.

    Pads the lane dim to a multiple of bs with weight-0 lanes (inert: they
    add no mass and the clamp can never land on them) and T to the
    effective bt; bt halves while a (bt, J) tile would overflow the VMEM
    row budget.
    """
    if interpret is None:
        interpret = _on_cpu()
    t, j = vals.shape
    vals_p = _pad_to(vals.astype(jnp.float32), 1, bs)
    topics_p = _pad_to(topics.astype(jnp.int32), 1, bs)
    jp = vals_p.shape[1]
    bt_eff = min(bt, max(8, t))
    while bt_eff > 8 and bt_eff * jp > _SPARSE_ROW_BUDGET:
        bt_eff = max(8, bt_eff // 2)
    vals_p = _pad_to(vals_p, 0, bt_eff)
    topics_p = _pad_to(topics_p, 0, bt_eff)
    tgt_p = _pad_to(targets.astype(jnp.float32), 0, bt_eff)
    out = sparse_row_sample_pallas(
        vals_p, topics_p, tgt_p,
        j_real=j, bt=bt_eff, interpret=interpret,
    )
    return out[:t]


@functools.partial(
    jax.jit,
    static_argnames=("num_rows", "num_topics", "bt", "bk", "interpret"),
)
def topic_histogram(
    rows_sorted: jax.Array,
    z_old: jax.Array,
    z_new: jax.Array,
    inc: jax.Array,
    num_rows: int,
    num_topics: int,
    *,
    bt: int = 256,
    bk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Signed delta histogram (num_rows, num_topics); see topic_histogram.py.

    Padding tokens get inc=0 (inert) and row = last row (stays sorted).
    """
    if interpret is None:
        interpret = _on_cpu()
    t = rows_sorted.shape[0]
    bt_eff = min(bt, max(8, t))
    last_row = rows_sorted[-1]
    rows_p = _pad_to(rows_sorted, 0, bt_eff)
    pad = rows_p.shape[0] - t
    if pad:
        rows_p = rows_p.at[t:].set(last_row)
    z_old_p = _pad_to(z_old, 0, bt_eff)
    z_new_p = _pad_to(z_new, 0, bt_eff)
    inc_p = _pad_to(inc, 0, bt_eff)  # zero => inert
    k_pad = (-num_topics) % bk
    out = topic_histogram_pallas(
        rows_p, z_old_p, z_new_p, inc_p, num_rows, num_topics + k_pad,
        bt=bt_eff, bk=bk, interpret=interpret,
    )
    return out[:, :num_topics]
