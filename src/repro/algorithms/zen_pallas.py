"""``zen_pallas`` — the fused Gumbel-max Pallas kernel as a first-class
backend (headline hot path; ``zen_dense_kernel`` kept as the legacy alias).

One fused VMEM pass streams K-tiles of the three-term conditional and keeps
only a running (max, argmax) carry per token: no normalization, no
materialized (T, K) probability matrix in HBM, no second pass (see
``kernels/zen_sampler.py`` and DESIGN.md §2). On CPU the same kernel runs
in interpret mode, bit-identical to the ``kernels/ref.py`` oracle, so the
backend is selectable everywhere: kernel on TPU, interpreted ref on CPU.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.algorithms.base import (
    CellBackend,
    SamplerKnobs,
    chunked_token_map,
    kernel_dispatch,
)
from repro.algorithms.registry import register


class FrozenPallasModel(NamedTuple):
    """One-time ``prepare_infer`` precompute for the serving kernel: the
    per-topic vectors the frozen-model variant streams as (1, bk) tiles.
    Tiny, but hoisting them out of the sweep keeps every ``infer_sweep``
    dispatch free of the alpha_k derivation and float casts."""

    alpha_k: jax.Array  # (K,) f32
    n_k_f: jax.Array  # (K,) f32 frozen topic totals


@register("zen_pallas", "zen_dense_kernel")
class ZenPallas(CellBackend):
    """Fused three-term Gumbel-max sampler (Pallas TPU kernel)."""

    native_infer = True

    def prepare_infer(self, n_wk, n_k, hyper, knobs: SamplerKnobs,
                      num_words_total=None):
        """Freeze the per-topic serving vectors (see
        :class:`FrozenPallasModel`). The count rows themselves stay in
        the engine's ``FrozenLDAModel`` — the kernel gathers them
        per-sweep, uncompensated (the frozen-model kernel variant needs
        no word-side one-hot add)."""
        return FrozenPallasModel(
            alpha_k=hyper.alpha_k(n_k),
            n_k_f=n_k.astype(jnp.float32),
        )

    def infer_sweep(
        self, keys, words, mask, z_old, n_kd, n_wk, n_k, hyper,
        knobs: SamplerKnobs, aux=None, num_words_total=None,
    ):
        """Frozen-model serving through the dedicated kernel variant
        (``kernels.zen_sampler._zen_infer_kernel``).

        Unlike the training kernel (which applies ¬dw exclusion to all
        three counts in-register, and previously forced this path to
        pre-compensate the gathered word rows with a (T, K) one-hot add
        plus an N_k off-by-one approximation), the frozen variant
        excludes on the **doc side only** — exactly the frozen-phi
        conditional, no compensation rows, no denominator skew.

        Randomness: per-token seeds are hashed from the token's *slot*
        key and in-doc position (``kernels.zen_sampler.golden_seed``), so
        a request's draws depend only on its own key and tokens — the
        same padding-exactness / batch-composition-independence contract
        as the default derivation, just under the kernel's counter-based
        hash instead of threefry (so it is not draw-for-draw comparable
        with ``cgs_infer``, but it IS bit-stable across batch layouts;
        ``tests/test_latency_serving.py`` pins both properties).
        """
        from repro.kernels.ops import zen_fused_infer_sample, zen_infer_sample

        if aux is None:
            aux = self.prepare_infer(n_wk, n_k, hyper, knobs)
        b, l = words.shape
        slot = jax.lax.broadcasted_iota(jnp.int32, (b, l), 0).reshape(-1)
        w = words.reshape(-1)
        z = z_old.reshape(-1)

        from repro.kernels.zen_sampler import golden_seed

        bits = jax.random.key_data(keys).astype(jnp.uint32)  # (B, 2)
        pos = jax.lax.broadcasted_iota(jnp.uint32, (1, l), 1)
        seeds = golden_seed(
            bits[:, :1], bits[:, 1:], pos
        ).reshape(-1)  # (B*L,) int32, counter-based in (slot key, pos)

        # w_beta stays a static python float (jit static arg), so it is
        # derived from shapes/hyper here, never threaded through the aux;
        # sharded dispatch passes the true W (n_wk is then a row block)
        w_total = (n_wk.shape[0] if num_words_total is None
                   else num_words_total)
        if kernel_dispatch(knobs.kernels):
            # fused gather+sample: scalar-prefetched word/slot ids, count
            # rows tiled from the resident matrices — no (B*L, K) gathered
            # intermediates. Bit-identical to the legacy path below.
            out = zen_fused_infer_sample(
                n_wk.astype(jnp.int32), n_kd.astype(jnp.int32), w, slot, z,
                seeds, aux.alpha_k, aux.n_k_f,
                beta=hyper.beta, w_beta=w_total * hyper.beta,
                bt=knobs.bt, bk=knobs.bk,
            )
        else:
            out = zen_infer_sample(
                n_wk[w].astype(jnp.int32), n_kd[slot].astype(jnp.int32), z,
                seeds, aux.alpha_k, aux.n_k_f,
                beta=hyper.beta, w_beta=w_total * hyper.beta,
                bt=knobs.bt, bk=knobs.bk,
            )
        return out.reshape(b, l)

    def cell_sweep(
        self, key, word, doc, z_old, mask, n_wk, n_kd, n_k, hyper,
        num_words_pad, knobs: SamplerKnobs,
    ):
        # lazy: keep pallas out of the import path of everything that
        # never selects this backend
        from repro.kernels.ops import zen_fused_sample, zen_sample

        # scalar prep + count-matrix dtype casts hoisted out of the chunk
        # fn (the FrozenPallasModel pattern for the training path): a
        # token_chunk run re-enters chunk() per chunk, but alpha_k / n_k_f
        # / the int32 casts depend only on sweep-start state. The kernel
        # tiles assume 4-byte count rows (the distributed path may hold
        # N_kd in int16), so the casts happen exactly once per sweep here.
        alpha_k = hyper.alpha_k(n_k)
        n_k_f = n_k.astype(jnp.float32)
        w_beta = num_words_pad * hyper.beta
        n_wk_i = n_wk.astype(jnp.int32)
        n_kd_i = n_kd.astype(jnp.int32)
        use_kernel = kernel_dispatch(knobs.kernels)

        def chunk(args):
            w, d, z, subkey = args
            seed = jax.random.randint(
                subkey, (), 0, jnp.iinfo(jnp.int32).max, dtype=jnp.int32
            )
            if use_kernel:
                # fused gather+sample: no (chunk, K) gathered rows in HBM;
                # bit-identical to the legacy gather-then-sample path
                return zen_fused_sample(
                    n_wk_i, n_kd_i, w, d, z, alpha_k, n_k_f, seed,
                    beta=hyper.beta, w_beta=w_beta,
                    bt=knobs.bt, bk=knobs.bk,
                )
            return zen_sample(
                n_wk_i[w], n_kd_i[d], z, alpha_k, n_k_f, seed,
                beta=hyper.beta, w_beta=w_beta, bt=knobs.bt, bk=knobs.bk,
            )

        # chunking bounds the per-chunk workspace (and, on the legacy
        # path, the gathered (chunk, K) row tiles in HBM)
        return chunked_token_map(
            chunk, key, (word, doc, z_old), knobs.token_chunk
        )
