"""Paper Figs. 7 + 8: sparse initialization — llh (total/word/doc split)
and early-iteration sampling time vs random init."""
from __future__ import annotations

import time

import jax

from benchmarks.common import row
from repro.core import LDATrainer, TrainConfig, LDAHyperParams
from repro.data import synthetic_lda_corpus


def main(iters: int = 8):
    corpus, _ = synthetic_lda_corpus(
        3, num_docs=400, num_words=700, num_topics=32, avg_doc_len=60
    )
    hyper = LDAHyperParams(num_topics=32, alpha=0.05, beta=0.01)
    for init in ("random", "sparse_word", "sparse_doc"):
        tr = LDATrainer(
            corpus, hyper,
            TrainConfig(algorithm="zen_sparse", init=init,
                        sparse_init_degree=0.15, max_kw=64, max_kd=64),
        )
        st = tr.init_state(jax.random.key(0))
        # early-iteration time (Fig. 8: the bottleneck the paper targets)
        t0 = time.perf_counter()
        st = tr.step(st)
        first_iter = time.perf_counter() - t0
        for _ in range(iters - 1):
            st = tr.step(st)
        split = tr.llh_split(st)
        row(
            f"fig7_8_init_{init}", first_iter * 1e6,
            f"llh_total={float(split.total):.1f};"
            f"llh_word={float(split.word):.1f};"
            f"llh_doc={float(split.doc):.1f}",
        )


if __name__ == "__main__":
    main()
