"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.py).

  fig3/4   ZenLDA vs LightLDA vs SparseLDA time + llh   (bench_algorithms)
  fig5/6   scalability: partitions and topic count       (bench_scaling)
  fig7/8   sparse initialization                         (bench_init)
  fig9     converged-token exclusion + §5.2 delta agg    (bench_exclusion)
  fig10    redundant-computation elimination (Alg. 5)    (bench_redundant)
  table1   per-algorithm work terms (complexity model)   (bench_table1)
  sec41    partitioner quality (DBH+ et al.)             (bench_partition)
  infer    serving throughput + latency/throughput frontier (bench_infer)
  kernels  kernel suite v2 vs pre-fusion baselines; writes
           BENCH_kernels.json                            (bench_kernels)
  streaming windowed online vs batch: docs/sec + resident doc-side
           state; writes BENCH_streaming.json            (bench_streaming)
  autopilot mis-configured vs hand-tuned vs autopilot recovery for
           training and serving; writes BENCH_autopilot.json
                                                         (bench_autopilot)
  quality  per-backend quality trajectories: UMass/NPMI coherence +
           left-to-right held-out llh; writes BENCH_quality.json
                                                         (bench_quality)

Machine-readable ``BENCH_*.json`` artifacts all land under one output
dir — ``--out-dir`` (or ``$BENCH_OUT_DIR``, default
``benchmarks/results/``) — never the repo root.
"""
import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated section list (e.g. fig3,fig9)")
    ap.add_argument("--out-dir", default=None,
                    help="directory for BENCH_*.json artifacts "
                         "(default $BENCH_OUT_DIR or benchmarks/results)")
    args = ap.parse_args()
    if args.out_dir:
        os.environ["BENCH_OUT_DIR"] = args.out_dir
    sections = {
        "fig3": lambda: __import__("benchmarks.bench_algorithms",
                                   fromlist=["main"]).main(),
        "fig5": lambda: __import__("benchmarks.bench_scaling",
                                   fromlist=["main"]).main(),
        "fig7": lambda: __import__("benchmarks.bench_init",
                                   fromlist=["main"]).main(),
        "fig9": lambda: __import__("benchmarks.bench_exclusion",
                                   fromlist=["main"]).main(),
        "fig10": lambda: __import__("benchmarks.bench_redundant",
                                    fromlist=["main"]).main(),
        "table1": lambda: __import__("benchmarks.bench_table1",
                                     fromlist=["main"]).main(),
        "sec41": lambda: __import__("benchmarks.bench_partition",
                                    fromlist=["main"]).main(),
        "infer": lambda: __import__("benchmarks.bench_infer",
                                    fromlist=["main"]).main(),
        "kernels": lambda: __import__("benchmarks.bench_kernels",
                                      fromlist=["main"]).main(),
        "streaming": lambda: __import__("benchmarks.bench_streaming",
                                        fromlist=["main"]).main(),
        "autopilot": lambda: __import__("benchmarks.bench_autopilot",
                                        fromlist=["main"]).main(),
        "quality": lambda: __import__("benchmarks.bench_quality",
                                      fromlist=["main"]).main(),
    }
    wanted = args.only.split(",") if args.only else list(sections)
    print("name,us_per_call,derived")
    for name in wanted:
        sections[name]()


if __name__ == "__main__":
    main()
