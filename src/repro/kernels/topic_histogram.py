"""Scatter-free topic-count histogram — Pallas TPU kernel.

Count updates (ΔN_w|k, ΔN_k|d) are scatter-adds over (row=vertex, col=topic)
pairs; scatter lowers to serialized updates on TPU. This kernel replaces it
with the MXU-native pattern (also used for MoE dispatch): tokens arrive
sorted by row (the word-by-word order the paper already mandates for wTable
lifetime), so a tile of ``bt`` tokens touches at most ``bt`` *distinct* rows.
ops.py precomputes each token's rank among its tile's distinct rows; the
kernel one-hot-expands rank (bt × bt) and signed topic deltas (bt × bk) and
contracts them on the MXU:

    partial[r, k] = Σ_t onehot_rank[t, r] · (inc_t·[k=z_new] − inc_t·[k=z_old])

yielding (tiles, bt, K) partials whose scatter back to global rows touches
``T/bt``× fewer rows than the naive scatter (256× at defaults).

f32 accumulation is exact: per-tile partial magnitudes are ≤ bt < 2^24.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.utils.compat import pallas_tpu_compiler_params


def _hist_kernel(
    rank_ref,  # (bt, 1) int32 — token's row-rank within its tile
    zold_ref,  # (bt, 1) int32
    znew_ref,  # (bt, 1) int32
    inc_ref,  # (bt, 1) int32 — 1 where the token actually changed & is real
    out_ref,  # (bt, bk) int32 — per-tile partial histogram (rank-indexed)
    *,
    bt: int,
    bk: int,
):
    j = pl.program_id(1)
    cols = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bt, bk), 1)
    inc = inc_ref[...].astype(jnp.float32)
    delta = (
        (cols == znew_ref[...]).astype(jnp.float32)
        - (cols == zold_ref[...]).astype(jnp.float32)
    ) * inc  # (bt, bk) signed one-hot deltas
    ranks = jax.lax.broadcasted_iota(jnp.int32, (bt, bt), 1)
    sel = (ranks == rank_ref[...]).astype(jnp.float32)  # (bt_tok, bt_rank)
    # (bt_rank, bt_tok) @ (bt_tok, bk) on the MXU
    partial = jax.lax.dot_general(
        sel, delta, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    out_ref[...] = partial.astype(jnp.int32)


def tile_ranks(rows: jax.Array, bt: int) -> tuple[jax.Array, jax.Array]:
    """Precompute (rank per token, row id per (tile, rank) slot).

    ``rows`` must be sorted (tokens in word-by-word order). Pure jnp; this is
    the ops.py companion of the kernel.
    Returns rank (T,) int32 and rank_rows (T//bt, bt) int32 (sentinel -1 on
    unused slots).
    """
    t = rows.shape[0]
    assert t % bt == 0
    tiles = rows.reshape(-1, bt)
    first = jnp.concatenate([tiles[:, :1], tiles[:, :-1]], axis=1)
    is_new = tiles != first
    is_new = is_new.at[:, 0].set(False)
    rank = jnp.cumsum(is_new.astype(jnp.int32), axis=1)  # (tiles, bt)
    # rows of each rank slot: scatter row ids by rank
    n_tiles = tiles.shape[0]
    rank_rows = jnp.full((n_tiles, bt), -1, jnp.int32)
    tile_ids = jax.lax.broadcasted_iota(jnp.int32, (n_tiles, bt), 0)
    rank_rows = rank_rows.at[tile_ids, rank].set(tiles.astype(jnp.int32))
    return rank.reshape(-1).astype(jnp.int32), rank_rows


def topic_histogram_pallas(
    rows_sorted: jax.Array,  # (T,) int32 — sorted row (word/doc local) ids
    z_old: jax.Array,  # (T,) int32
    z_new: jax.Array,  # (T,) int32
    inc: jax.Array,  # (T,) int32 — 1 for changed & real tokens else 0
    num_rows: int,
    num_topics: int,
    *,
    bt: int = 256,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Signed delta histogram (num_rows, num_topics) int32."""
    t = rows_sorted.shape[0]
    k = num_topics
    assert t % bt == 0 and k % bk == 0, (t, k, bt, bk)
    rank, rank_rows = tile_ranks(rows_sorted, bt)
    grid = (t // bt, k // bk)
    kernel = functools.partial(_hist_kernel, bt=bt, bk=bk)
    partials = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bt, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, k), jnp.int32),
        interpret=interpret,
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel"),
        ),
    )(rank[:, None], z_old[:, None], z_new[:, None], inc[:, None])
    # combine tile partials: one scatter over (tiles * bt) rank rows —
    # T/bt x fewer scattered rows than the naive per-token scatter.
    flat_rows = rank_rows.reshape(-1)
    safe = jnp.maximum(flat_rows, 0)
    out = jnp.zeros((num_rows, k), jnp.int32)
    contrib = jnp.where(flat_rows[:, None] >= 0, partials, 0)
    return out.at[safe].add(contrib)
