from repro.train.optimizer import adafactor_init, adafactor_update, adamw_init, adamw_update, make_optimizer  # noqa: F401
from repro.train.train_step import make_train_step, TrainState  # noqa: F401
