"""Fused ZenLDA Gumbel-max sampler — Pallas TPU kernel.

The TPU adaptation of the paper's sampling core (DESIGN.md §2): instead of
alias tables + per-token CDFs (random gathers, table builds), one fused pass
streams K-tiles of the three-term conditional

    p[t, k] = (α_k·β + N_w|k·α_k + N_k|d·(N_w|k+β)) / (N_k + Wβ)     (Eq. 3)

through VMEM and samples with the Gumbel-max trick:

    z_t = argmax_k ( log p[t,k] + g[t,k] ),   g ~ Gumbel(0,1)

which needs only a running (max, argmax) carry per token — no normalization,
no materialized (T, K) probability matrix in HBM, no second pass. The ¬dw
self-exclusion is applied exactly in-register (subtract the token's previous
topic from all three counts).

Gumbel noise comes from a counter-based integer hash of
(seed, token_id, topic_id) computed in-kernel on the VPU — zero HBM noise
traffic, bit-identical to the pure-jnp oracle in ``ref.py`` (the TPU-native
``pltpu.prng_*`` path is not used so that interpret-mode CPU validation is
exact).

Block layout: token tile ``bt`` (sublane-aligned, default 256) × topic tile
``bk`` (lane-aligned, default 512). Grid = (T/bt, K/bk), K innermost so the
(bt, 1) running-max scratch carries across K tiles. VMEM per step ≈
2·bt·bk·4B (count tiles) + 4·bk·4B (per-topic vectors) + noise tile
≈ 1.1 MB at defaults — comfortably under the ~16 MB/core budget, and the
MXU-free VPU pipeline is the right unit since this is elementwise math +
reductions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.utils.compat import pallas_tpu_compiler_params

# Murmur3-style finalizer constants (avalanche mixing). Plain ints: traced
# jnp constants would be captured as closure constants, which pallas rejects.
_M1 = 0x85EBCA6B
_M2 = 0xC2B2AE35
_GOLD = 0x9E3779B9


def _mix(x: jax.Array) -> jax.Array:
    x = (x ^ (x >> 16)) * jnp.asarray(_M1, jnp.uint32)
    x = (x ^ (x >> 13)) * jnp.asarray(_M2, jnp.uint32)
    return x ^ (x >> 16)


def hash_uniform(seed: jax.Array, row: jax.Array, col: jax.Array) -> jax.Array:
    """Counter-based U(0,1) from integer coordinates. Shared by kernel + ref.

    24-bit mantissa construction keeps the value in (0, 1) exactly the same
    way on TPU and CPU.
    """
    h = _mix(
        seed.astype(jnp.uint32)
        ^ (row.astype(jnp.uint32) * jnp.asarray(_GOLD, jnp.uint32))
        ^ _mix(col.astype(jnp.uint32))
    )
    return (h >> 8).astype(jnp.float32) * (1.0 / (1 << 24)) + (0.5 / (1 << 24))


def gumbel_noise(seed, row, col):
    u = hash_uniform(seed, row, col)
    return -jnp.log(-jnp.log(u))


def mix32(x: jax.Array) -> jax.Array:
    """The kernel's avalanche mixer on plain uint32 arrays (public form).

    The serving path uses it *outside* the kernel to derive per-token
    seeds from per-slot PRNG keys: the derivation is pure elementwise
    hashing of (slot key bits, token position), so it is counter-based by
    construction — prefix-stable in the bucket pad and independent of
    batch composition, unlike shaped ``jax.random`` draws under
    non-partitionable threefry.
    """
    return _mix(x.astype(jnp.uint32))


def golden_seed(key_bits_hi: jax.Array, key_bits_lo: jax.Array,
                pos: jax.Array) -> jax.Array:
    """Per-token int32 seeds from split per-slot key words + positions.

    ``seed[b, p] = mix(hi[b] ^ mix(lo[b]) ^ p * GOLDEN)`` with the high
    bit cleared (the kernels take non-negative int32 seeds). Broadcasts:
    pass ``hi``/``lo`` shaped ``(B, 1)`` and ``pos`` shaped ``(1, L)`` to
    get the ``(B, L)`` serving seed grid.
    """
    h = mix32(
        key_bits_hi.astype(jnp.uint32)
        ^ mix32(key_bits_lo)
        ^ (pos.astype(jnp.uint32) * jnp.asarray(_GOLD, jnp.uint32))
    )
    return (h & jnp.uint32(0x7FFFFFFF)).astype(jnp.int32)


def _zen_sampler_kernel(
    # scalar prefetch
    seed_ref,
    # inputs
    nwk_ref,  # (bt, bk) int32 — gathered word-topic rows, this K tile
    nkd_ref,  # (bt, bk) int32 — gathered doc-topic rows
    zold_ref,  # (bt, 1) int32 — previous assignment (¬dw exclusion)
    alpha_ref,  # (1, bk) f32 — alpha_k
    nk_ref,  # (1, bk) f32 — N_k
    # output
    out_ref,  # (bt, 1) int32 — sampled topic
    # scratch
    m_ref,  # (bt, 1) f32 — running max of log p + g
    a_ref,  # (bt, 1) i32 — running argmax
    *,
    beta: float,
    w_beta: float,
    bt: int,
    bk: int,
):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        a_ref[...] = jnp.zeros_like(a_ref)

    # global coordinates of this tile
    rows = i * bt + jax.lax.broadcasted_iota(jnp.int32, (bt, bk), 0)
    cols = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bt, bk), 1)

    # exact ¬dw: subtract the token's own previous assignment
    self_hit = (cols == zold_ref[...]).astype(jnp.float32)
    nw = nwk_ref[...].astype(jnp.float32) - self_hit
    nd = nkd_ref[...].astype(jnp.float32) - self_hit
    nk = nk_ref[...] - self_hit
    alpha_k = alpha_ref[...]

    # three-term ZenLDA decomposition, fused (paper Alg. 5 FMAs)
    p = (alpha_k * beta + nw * alpha_k + nd * (nw + beta)) / (nk + w_beta)

    g = gumbel_noise(seed_ref[0], rows, cols)
    score = jnp.log(jnp.maximum(p, 1e-30)) + g

    tile_max = jnp.max(score, axis=1, keepdims=True)  # (bt, 1)
    tile_arg = jnp.argmax(score, axis=1).astype(jnp.int32)[:, None] + j * bk

    better = tile_max > m_ref[...]
    a_ref[...] = jnp.where(better, tile_arg, a_ref[...])
    m_ref[...] = jnp.where(better, tile_max, m_ref[...])

    @pl.when(j == pl.num_programs(1) - 1)
    def _done():
        out_ref[...] = a_ref[...]


def zen_sample_pallas(
    nwk_rows: jax.Array,  # (T, K) int32
    nkd_rows: jax.Array,  # (T, K) int32
    z_old: jax.Array,  # (T,) int32
    alpha_k: jax.Array,  # (K,) f32
    n_k: jax.Array,  # (K,) f32/int32
    seed: jax.Array,  # () int32 — iteration/device-folded seed
    *,
    beta: float,
    w_beta: float,
    bt: int = 256,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Sample one topic per token. T % bt == 0 and K % bk == 0 required
    (ops.py pads)."""
    t, k = nwk_rows.shape
    assert t % bt == 0 and k % bk == 0, (t, k, bt, bk)
    grid = (t // bt, k // bk)
    kernel = functools.partial(
        _zen_sampler_kernel, beta=beta, w_beta=w_beta, bt=bt, bk=bk
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bt, bk), lambda i, j, *_: (i, j)),
                pl.BlockSpec((bt, bk), lambda i, j, *_: (i, j)),
                pl.BlockSpec((bt, 1), lambda i, j, *_: (i, 0)),
                pl.BlockSpec((1, bk), lambda i, j, *_: (0, j)),
                pl.BlockSpec((1, bk), lambda i, j, *_: (0, j)),
            ],
            out_specs=pl.BlockSpec((bt, 1), lambda i, j, *_: (i, 0)),
            scratch_shapes=[
                pltpu.VMEM((bt, 1), jnp.float32),
                pltpu.VMEM((bt, 1), jnp.int32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((t, 1), jnp.int32),
        interpret=interpret,
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
    )(
        jnp.asarray([seed], jnp.int32),
        nwk_rows,
        nkd_rows,
        z_old[:, None],
        alpha_k[None, :].astype(jnp.float32),
        n_k[None, :].astype(jnp.float32),
    )
    return out[:, 0]


def _zen_infer_kernel(
    # inputs
    nwk_ref,  # (bt, bk) int32 — frozen word-topic rows, this K tile
    nkd_ref,  # (bt, bk) int32 — gathered per-slot doc-topic rows
    zold_ref,  # (bt, 1) int32 — previous assignment (doc-side ¬t)
    seed_ref,  # (bt, 1) int32 — per-token counter-based seeds
    alpha_ref,  # (1, bk) f32 — alpha_k
    nk_ref,  # (1, bk) f32 — frozen N_k
    # output
    out_ref,  # (bt, 1) int32 — sampled topic
    # scratch
    m_ref,  # (bt, 1) f32 — running max of log p + g
    a_ref,  # (bt, 1) i32 — running argmax
    *,
    beta: float,
    w_beta: float,
    bt: int,
    bk: int,
):
    """Frozen-model serving variant of ``_zen_sampler_kernel``.

    Differences from the training kernel, both serving-exact:

    * **No word-side exclusion** — phi is frozen, the query's tokens were
      never counted in ``N_w|k``/``N_k``, so only the doc side excludes
      the token's own assignment. This removes the training path's
      pre-compensation of the gathered word rows (one (T, K) int32 add)
      *and* its N_k off-by-one denominator approximation.
    * **Per-token seeds** — noise coordinates are (seed[t], topic), with
      seed[t] derived outside from the token's *slot* key and in-doc
      position (``golden_seed``). A token's draw therefore never depends
      on the flat batch coordinates, so serving is padding-exact and
      batch-composition-independent here too (DESIGN.md §5.1/§5.2).
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        a_ref[...] = jnp.zeros_like(a_ref)

    cols = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bt, bk), 1)

    # doc-side-only exact ¬t exclusion; word side stays frozen
    self_hit = (cols == zold_ref[...]).astype(jnp.float32)
    nw = nwk_ref[...].astype(jnp.float32)
    nd = nkd_ref[...].astype(jnp.float32) - self_hit
    alpha_k = alpha_ref[...]

    # frozen-phi conditional: (N_k|d^(¬t) + alpha_k)(N_w|k + beta)/(N_k + Wβ)
    p = (nd + alpha_k) * (nw + beta) / (nk_ref[...] + w_beta)

    g = gumbel_noise(seed_ref[...], jnp.zeros((bt, 1), jnp.uint32), cols)
    score = jnp.log(jnp.maximum(p, 1e-30)) + g

    tile_max = jnp.max(score, axis=1, keepdims=True)  # (bt, 1)
    tile_arg = jnp.argmax(score, axis=1).astype(jnp.int32)[:, None] + j * bk

    better = tile_max > m_ref[...]
    a_ref[...] = jnp.where(better, tile_arg, a_ref[...])
    m_ref[...] = jnp.where(better, tile_max, m_ref[...])

    @pl.when(j == pl.num_programs(1) - 1)
    def _done():
        out_ref[...] = a_ref[...]


def zen_infer_sample_pallas(
    nwk_rows: jax.Array,  # (T, K) int32 frozen gathered word rows
    nkd_rows: jax.Array,  # (T, K) int32 per-slot doc rows
    z_old: jax.Array,  # (T,) int32
    seeds: jax.Array,  # (T,) int32 per-token counter-based seeds
    alpha_k: jax.Array,  # (K,) f32
    n_k: jax.Array,  # (K,) f32/int32 frozen
    *,
    beta: float,
    w_beta: float,
    bt: int = 256,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Frozen-model Gumbel-max sample, one topic per token. T % bt == 0
    and K % bk == 0 required (``ops.zen_infer_sample`` pads)."""
    t, k = nwk_rows.shape
    assert t % bt == 0 and k % bk == 0, (t, k, bt, bk)
    grid = (t // bt, k // bk)
    kernel = functools.partial(
        _zen_infer_kernel, beta=beta, w_beta=w_beta, bt=bt, bk=bk
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bt, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bk), lambda i, j: (0, j)),
            pl.BlockSpec((1, bk), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bt, 1), jnp.float32),
            pltpu.VMEM((bt, 1), jnp.int32),
        ],
        out_shape=jax.ShapeDtypeStruct((t, 1), jnp.int32),
        interpret=interpret,
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
    )(
        nwk_rows,
        nkd_rows,
        z_old[:, None],
        seeds[:, None],
        alpha_k[None, :].astype(jnp.float32),
        n_k[None, :].astype(jnp.float32),
    )
    return out[:, 0]
