""""Converged" token exclusion (paper §5.1).

A token is *converged* when its sampled topic equals the previous sample.
Converged tokens are still resampled, but only with probability 2^(i - t)
where i = iterations since last processed and t = consecutive times processed
with an unchanged topic (both reset when the topic changes).

TPU adaptation (DESIGN.md §2): masked-out lanes do not save vector time, so
the immediate win is the smaller delta traffic + count-update work; a
compaction mode (sort-by-active + bounded window) recovers the compute win
and is used by the distributed runtime when the active fraction is low.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import CGSState


class ExclusionConfig(NamedTuple):
    enabled: bool = False
    start_iteration: int = 30  # paper turns it on after iteration 30
    min_sample_prob: float = 0.0  # floor on the resample probability


def active_mask(
    state: CGSState, cfg: ExclusionConfig, key: jax.Array
) -> jax.Array:
    """Bool (E,): which tokens are sampled this iteration."""
    if not cfg.enabled:
        return jnp.ones_like(state.topic, dtype=bool)
    i = state.stale_iters.astype(jnp.float32)
    t = state.same_count.astype(jnp.float32)
    prob = jnp.clip(jnp.exp2(i - t), cfg.min_sample_prob, 1.0)
    u = jax.random.uniform(key, state.topic.shape)
    sampled = u < prob
    warmup = state.iteration < cfg.start_iteration
    return sampled | warmup


def update_exclusion_stats(
    state: CGSState,
    new_topic: jax.Array,
    mask: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """New (stale_iters, same_count) after an iteration.

    Processed & changed   -> i=0, t=0
    Processed & unchanged -> i=0, t+1
    Skipped               -> i+1, t
    """
    changed = new_topic != state.topic
    i = jnp.where(mask, 0, state.stale_iters + 1)
    t = jnp.where(mask, jnp.where(changed, 0, state.same_count + 1),
                  state.same_count)
    return i.astype(jnp.int32), t.astype(jnp.int32)


def compact_active(
    mask: jax.Array, *arrays: jax.Array
) -> Tuple[jax.Array, Tuple[jax.Array, ...], jax.Array]:
    """Stable-partition tokens so active ones are contiguous at the front.

    Returns (perm, permuted arrays, num_active). Downstream kernels can then
    process ceil(num_active / tile) * tile tokens instead of E — this is how
    the paper's "largely reduce the workload per iteration" is realized on a
    SIMD machine. The permutation is its own inverse-aware companion:
    ``unpermute = jnp.argsort(perm)``.
    """
    e = mask.shape[0]
    # stable: sort by (1 - active) keeps relative order within groups
    perm = jnp.argsort(jnp.where(mask, 0, 1), stable=True).astype(jnp.int32)
    num_active = jnp.sum(mask.astype(jnp.int32))
    return perm, tuple(a[perm] for a in arrays), num_active
