"""Dense vectorized CGS samplers — the reference ("oracle") path.

Two sweeps are provided:

* ``cgs_sweep_stale``  — the paper's production semantics: all tokens are
  sampled against the counts frozen at the start of the iteration
  ("unsynchronized model", §4.1), with the token's *own* previous assignment
  excluded exactly (the ¬dw correction), and counts merged once at the end.
  This is embarrassingly parallel over tokens and is what the distributed
  runtime and the Pallas kernel implement.

* ``cgs_sweep_serial`` — the textbook sequential collapsed Gibbs chain
  (paper Alg. 1): counts are decremented/incremented token by token inside a
  ``lax.scan``. Slow, used as the statistical oracle in tests/benchmarks.

Sampling methods: inverse-CDF (paper's samplers reduce to this on dense
rows) and Gumbel-max (the TPU-native adaptation — one pass, one reduction,
no normalization, no table; see DESIGN.md §2).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import counts as counts_lib
from repro.core.decompositions import (
    ZenTerms,
    precompute_zen_terms,
    std_probs,
    zen_probs,
)
from repro.core.types import CGSState, Corpus, LDAHyperParams


def sample_categorical(
    key: jax.Array, probs: jax.Array, method: str = "cdf"
) -> jax.Array:
    """Draw one sample per row from unnormalized ``probs`` (T, K)."""
    if method == "cdf":
        cdf = jnp.cumsum(probs, axis=-1)
        total = cdf[:, -1:]
        u = jax.random.uniform(key, (probs.shape[0], 1), dtype=probs.dtype)
        # searchsorted per row == the paper's BSearch over the CDF
        idx = jnp.sum(cdf < u * total, axis=-1)
        return jnp.minimum(idx, probs.shape[-1] - 1).astype(jnp.int32)
    elif method == "gumbel":
        g = jax.random.gumbel(key, probs.shape, dtype=jnp.float32)
        logits = jnp.log(jnp.maximum(probs.astype(jnp.float32), 1e-30))
        return jnp.argmax(logits + g, axis=-1).astype(jnp.int32)
    raise ValueError(f"unknown sampling method {method!r}")


def _gather_rows(
    state: CGSState, word: jax.Array, doc: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    return state.n_wk[word], state.n_kd[doc]


def conditional_probs(
    state: CGSState,
    corpus: Corpus,
    hyper: LDAHyperParams,
    exclude_self: bool = True,
    decomposition: str = "zen",
) -> jax.Array:
    """Eq. 3 conditional for every token, (E, K), vectorized.

    With ``exclude_self`` the token's own previous topic is removed from all
    counts (the exact ¬dw semantics). Without it, the stale approximation the
    paper pairs with resampling remedies is produced.
    """
    n_wk_rows, n_kd_rows = _gather_rows(state, corpus.word, corpus.doc)
    n_k = state.n_k
    if exclude_self:
        onehot = jax.nn.one_hot(state.topic, hyper.num_topics, dtype=jnp.int32)
        n_wk_rows = n_wk_rows - onehot
        n_kd_rows = n_kd_rows - onehot
        n_k = n_k[None, :] - onehot
    else:
        n_k = n_k[None, :]
    terms = precompute_zen_terms(state.n_k, hyper, corpus.num_words)
    if decomposition == "std":
        return std_probs(
            n_wk_rows, n_kd_rows, n_k, terms.alpha_k, hyper.beta, corpus.num_words
        )
    # ZenLDA decomposition. When excluding self we must recompute t1 rows
    # against the decremented n_k — do it directly from Eq. 3 pieces.
    w_beta = corpus.num_words * hyper.beta
    t1 = 1.0 / (n_k.astype(jnp.float32) + w_beta)
    alpha_k = terms.alpha_k[None, :]
    nw = n_wk_rows.astype(jnp.float32)
    nd = n_kd_rows.astype(jnp.float32)
    return (alpha_k * hyper.beta + nw * alpha_k + nd * (nw + hyper.beta)) * t1


def cgs_sweep_stale(
    state: CGSState,
    corpus: Corpus,
    hyper: LDAHyperParams,
    method: str = "cdf",
    exclude_self: bool = True,
    decomposition: str = "zen",
    token_chunk: int | None = None,
) -> jax.Array:
    """Sample a new topic for every token against iteration-start counts.

    Returns new topics (E,). ``token_chunk`` bounds peak memory by mapping
    over chunks of tokens (E must be divisible by it).
    """
    key = jax.random.fold_in(state.rng, state.iteration)

    def chunk_fn(args):
        w, d, z, keys = args
        sub = CGSState(
            topic=z, prev_topic=z, n_wk=state.n_wk, n_kd=state.n_kd,
            n_k=state.n_k, rng=state.rng, iteration=state.iteration,
        )
        sub_corpus = Corpus(word=w, doc=d, num_words=corpus.num_words,
                            num_docs=corpus.num_docs)
        probs = conditional_probs(sub, sub_corpus, hyper,
                                  exclude_self=exclude_self,
                                  decomposition=decomposition)
        return sample_categorical(keys, probs, method=method)

    e = corpus.word.shape[0]
    if token_chunk is None or token_chunk >= e:
        return chunk_fn((corpus.word, corpus.doc, state.topic, key))
    assert e % token_chunk == 0, (e, token_chunk)
    n_chunks = e // token_chunk
    keys = jax.random.split(key, n_chunks)
    args = (
        corpus.word.reshape(n_chunks, token_chunk),
        corpus.doc.reshape(n_chunks, token_chunk),
        state.topic.reshape(n_chunks, token_chunk),
        keys,
    )
    out = jax.lax.map(chunk_fn, args)
    return out.reshape(e)


def cgs_sweep_serial(
    state: CGSState, corpus: Corpus, hyper: LDAHyperParams
) -> CGSState:
    """True sequential collapsed Gibbs sweep (paper Alg. 1). O(E*K), scan."""
    key = jax.random.fold_in(state.rng, state.iteration)
    e = corpus.word.shape[0]
    keys = jax.random.split(key, e)

    def body(carry, inputs):
        n_wk, n_kd, n_k, topics = carry
        w, d, i, k_i = inputs
        z_old = topics[i]
        n_wk = n_wk.at[w, z_old].add(-1)
        n_kd = n_kd.at[d, z_old].add(-1)
        n_k = n_k.at[z_old].add(-1)
        w_beta = corpus.num_words * hyper.beta
        alpha_k = hyper.alpha_k(n_k)
        p = (
            (n_wk[w].astype(jnp.float32) + hyper.beta)
            / (n_k.astype(jnp.float32) + w_beta)
            * (n_kd[d].astype(jnp.float32) + alpha_k)
        )
        z_new = sample_categorical(k_i, p[None, :], method="cdf")[0]
        n_wk = n_wk.at[w, z_new].add(1)
        n_kd = n_kd.at[d, z_new].add(1)
        n_k = n_k.at[z_new].add(1)
        topics = topics.at[i].set(z_new)
        return (n_wk, n_kd, n_k, topics), None

    init = (state.n_wk, state.n_kd, state.n_k, state.topic)
    idx = jnp.arange(e, dtype=jnp.int32)
    (n_wk, n_kd, n_k, topics), _ = jax.lax.scan(
        body, init, (corpus.word, corpus.doc, idx, keys)
    )
    return CGSState(
        topic=topics, prev_topic=state.topic, n_wk=n_wk, n_kd=n_kd, n_k=n_k,
        rng=state.rng, iteration=state.iteration + 1,
        stale_iters=state.stale_iters, same_count=state.same_count,
    )


def gibbs_iteration(
    state: CGSState,
    corpus: Corpus,
    hyper: LDAHyperParams,
    method: str = "cdf",
    exclude_self: bool = True,
    decomposition: str = "zen",
    token_chunk: int | None = None,
) -> CGSState:
    """One full single-box iteration: stale sweep + delta merge (paper Fig 2,
    collapsed to one device)."""
    new_topic = cgs_sweep_stale(
        state, corpus, hyper, method=method, exclude_self=exclude_self,
        decomposition=decomposition, token_chunk=token_chunk,
    )
    d_wk, d_kd, d_k = counts_lib.delta_counts(
        corpus.word, corpus.doc, state.topic, new_topic,
        corpus.num_words, corpus.num_docs, hyper.num_topics,
    )
    return CGSState(
        topic=new_topic,
        prev_topic=state.topic,
        n_wk=state.n_wk + d_wk,
        n_kd=state.n_kd + d_kd,
        n_k=state.n_k + d_k,
        rng=state.rng,
        iteration=state.iteration + 1,
        stale_iters=state.stale_iters,
        same_count=state.same_count,
    )
