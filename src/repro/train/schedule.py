"""Training schedules: periodic/one-shot structural events as data (§6).

The paper's training workflow is one sampling loop plus periodic
*structural* events — model synchronization, exact count rebuild,
"converged" token exclusion enablement (§5.1), duplicate-topic merging
(§4.3), capacity re-resolution for the padded-sparse tables. A
``Schedule`` makes those events first-class: each is a ``ScheduledAction``
with a name, a cadence (``every``/``start``) or a one-shot trigger
(``at``), and a callback ``(ctx, state) -> state``. ``TrainSession`` builds
its schedule from ``RunConfig`` and fires it after every iteration.

Determinism contract (property-tested in ``tests/test_session.py``):

* an action fires at iteration ``n`` iff ``due(n)`` — a pure function of
  the action's own fields, never of other actions;
* within one iteration, actions fire in *registration order* (structural
  events are registered before observational ones, so an eval always sees
  post-rebuild/post-merge counts);
* every firing is appended to ``ctx.fired`` as ``(iteration, name)``, so a
  run's event history is replayable and assertable.

Iterations are counted the way the drivers do: ``state.iteration`` *after*
a step, i.e. the first step produces iteration 1.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ScheduledAction:
    """One named training event.

    Exactly one trigger form is used:
      * periodic — ``every > 0``: fires when ``iteration % every == 0`` and
        ``iteration >= start``;
      * one-shot — ``at is not None``: fires when ``iteration == at``.

    ``fn(ctx, state)`` returns the (possibly replaced) state; returning
    ``None`` keeps the incoming state (side-effect-only actions).
    """

    name: str
    fn: Callable[["ActionContext", Any], Any]
    every: int = 0
    start: int = 1
    at: Optional[int] = None

    def __post_init__(self) -> None:
        if self.at is not None and self.every:
            raise ValueError(
                f"action {self.name!r}: 'at' and 'every' are exclusive"
            )

    def due(self, iteration: int) -> bool:
        if self.at is not None:
            return iteration == self.at
        return (
            self.every > 0
            and iteration >= self.start
            and iteration % self.every == 0
        )


@dataclasses.dataclass
class ActionContext:
    """Mutable per-run context threaded through every action firing.

    ``metrics`` is reset by the driver each iteration; actions contribute
    keys (the eval action writes ``llh``/``perplexity``/``change_rate``).
    ``stop`` requests loop termination after the current iteration (e.g.
    target perplexity reached). ``fired`` is the append-only event log.
    """

    session: Any = None
    metrics: Dict[str, Any] = dataclasses.field(default_factory=dict)
    stop: bool = False
    fired: List[Tuple[int, str]] = dataclasses.field(default_factory=list)


class Schedule:
    """An ordered, name-unique collection of ``ScheduledAction``s."""

    def __init__(self, actions: Tuple[ScheduledAction, ...] = ()):
        self._actions: List[ScheduledAction] = []
        for a in actions:
            self.add(a)

    def add(self, action: ScheduledAction) -> "Schedule":
        if any(a.name == action.name for a in self._actions):
            raise ValueError(f"duplicate schedule action {action.name!r}")
        self._actions.append(action)
        return self

    def replace(self, action: ScheduledAction) -> "Schedule":
        """Swap the same-named action in place, preserving its position
        (and therefore its firing order). This is how a cadence changes
        mid-run — the autopilot's actuation path depends on it. Raises
        ``KeyError`` when no action with that name is registered."""
        for i, a in enumerate(self._actions):
            if a.name == action.name:
                self._actions[i] = action
                return self
        raise KeyError(f"no schedule action named {action.name!r}")

    def remove(self, name: str) -> "Schedule":
        """Drop a registered action by name (``KeyError`` if absent)."""
        for i, a in enumerate(self._actions):
            if a.name == name:
                del self._actions[i]
                return self
        raise KeyError(f"no schedule action named {name!r}")

    @property
    def actions(self) -> Tuple[ScheduledAction, ...]:
        return tuple(self._actions)

    def names(self) -> Tuple[str, ...]:
        return tuple(a.name for a in self._actions)

    def due(self, iteration: int) -> Tuple[str, ...]:
        """Names of the actions that fire at ``iteration``, in order."""
        return tuple(a.name for a in self._actions if a.due(iteration))

    def fire(self, ctx: ActionContext, state: Any, iteration: int) -> Any:
        """Run every due action in registration order; returns the state."""
        for action in self._actions:
            if not action.due(iteration):
                continue
            out = action.fn(ctx, state)
            if out is not None:
                state = out
            ctx.fired.append((iteration, action.name))
        return state
