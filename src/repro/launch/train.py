"""Distributed LDA training driver (launch-level CLI).

On a real TPU slice this runs under `jax.distributed` with the production
mesh; on CPU hosts pass --host-devices to simulate N devices.

    PYTHONPATH=src python -m repro.launch.train \
        --rows 2 --cols 2 --host-devices 4 --iters 50 \
        [--corpus path.libsvm] [--ckpt DIR] [--algorithm zen_cdf]
        [--delta-dtype int16] [--exclusion-start 30]
"""
import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=2, help="data-parallel rows")
    ap.add_argument("--cols", type=int, default=2, help="model-parallel cols")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="simulate N host devices (CPU bring-up)")
    ap.add_argument("--corpus", default=None, help="libsvm corpus path")
    ap.add_argument("--topics", type=int, default=64)
    ap.add_argument("--iters", type=int, default=50)
    ap.add_argument("--algorithm", default="zen_cdf",
                    choices=["zen_cdf", "zen_dense", "zen_dense_kernel"])
    ap.add_argument("--max-kd", type=int, default=64)
    ap.add_argument("--delta-dtype", default="int32",
                    choices=["int32", "int16", "int8"])
    ap.add_argument("--exclusion-start", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--llh-every", type=int, default=10)
    args = ap.parse_args()

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices} "
            + os.environ.get("XLA_FLAGS", "")
        )

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.distributed import (
        DistConfig,
        init_dist_state,
        make_dist_llh,
        make_dist_step,
        make_rebuild_counts,
    )
    from repro.core.graph import grid_partition
    from repro.core.types import LDAHyperParams
    from repro.data import load_libsvm, synthetic_corpus
    from repro.launch.mesh import make_mesh
    from repro.train.checkpoint import CheckpointManager
    from repro.train.loop import LoopConfig, TrainLoop

    if args.corpus:
        corpus = load_libsvm(args.corpus)
    else:
        corpus = synthetic_corpus(0, num_docs=1000, num_words=2000,
                                  avg_doc_len=80, zipf_a=1.2)
    hyper = LDAHyperParams(num_topics=args.topics)
    mesh = make_mesh((args.rows, args.cols), ("data", "model"))
    grid = grid_partition(corpus, args.rows, args.cols)
    print(f"mesh {args.rows}x{args.cols}  tokens={int(grid.mask.sum())}  "
          f"pad={grid.padding_overhead:.2%}")
    dcfg = DistConfig(
        algorithm=args.algorithm, max_kd=args.max_kd,
        delta_dtype=args.delta_dtype, exclusion_start=args.exclusion_start,
    )
    state, data = init_dist_state(jax.random.key(0), mesh, grid, hyper)
    step = make_dist_step(mesh, hyper, dcfg, grid.words_per_shard,
                          grid.docs_per_shard)
    llh = make_dist_llh(mesh, hyper, grid.words_per_shard,
                        grid.docs_per_shard)

    def loop_step(state):
        state = step(state, data)
        metrics = {}
        it = int(state.iteration)
        if args.llh_every and it % args.llh_every == 0:
            metrics["llh"] = float(llh(state, data))
        return state, metrics

    # checkpoint = assignments only (counts rebuild on restore; elastic)
    rebuild = make_rebuild_counts(mesh, hyper, grid.words_per_shard,
                                  grid.docs_per_shard)

    def restore(state, tree):
        state = state._replace(
            topic=jax.device_put(tree["topic"], state.topic.sharding),
            iteration=jnp.asarray(tree["iteration"]),
        )
        return rebuild(state, data)

    loop = TrainLoop(
        loop_step,
        LoopConfig(num_steps=args.iters, checkpoint_every=25,
                   checkpoint_dir=args.ckpt, log_every=args.llh_every),
        checkpoint_tree_fn=lambda s: {
            "topic": s.topic, "iteration": s.iteration,
        },
        restore_fn=restore if args.ckpt else None,
    )
    import logging

    logging.basicConfig(level=logging.INFO)
    final = loop.run(state)
    print(f"finished at iteration {int(final.iteration)}; "
          f"final llh {float(llh(final, data)):.1f}")


if __name__ == "__main__":
    main()
