"""Corpus-graph partitioning (paper §4.1) — the data/model co-partitioner.

The corpus is the bipartite word-doc graph; distribution = partitioning it.
Host-side (numpy) because this is a data-pipeline step, exactly where the
paper runs it (a Spark stage before training).

Vertex-cut strategies implemented (paper's GraphX menu + its contribution):
  * random_vertex_cut  — hash(src, dst)
  * edge_partition_1d  — hash(word) (co-locates a word's edges)
  * edge_partition_2d  — "rectangle" grid partition, the 2*sqrt(P)
                          replication bound
  * dbh                — degree-based hashing [Xie et al.]: cut the
                          higher-degree endpoint
  * dbh_plus           — paper Alg. 3: like DBH, but when BOTH degrees are
                          below a threshold, co-locate with the *higher*-
                          degree endpoint instead (locality beats balance
                          for cold edges)

For the TPU SPMD runtime the 2D grid is the physical layout (DESIGN.md §2):
``grid_partition`` relabels words/docs so each mesh column owns a contiguous,
token-count-balanced word range (greedy LPT bin-packing — hot words spread
first) and each mesh row owns a contiguous doc range, then pads every cell
to a uniform edge count. Replication factor and balance metrics quantify
what DBH+ buys (evaluated in benchmarks/bench_partition.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

from repro.core.types import Corpus


# ---------------------------------------------------------------------------
# Classic vertex-cut partitioners (edge -> partition id)
# ---------------------------------------------------------------------------

def _hash(x: np.ndarray, seed: int = 0x9E3779B9) -> np.ndarray:
    x = x.astype(np.uint64)
    x = (x ^ (x >> 16)) * np.uint64(0x45D9F3B + seed)
    x = (x ^ (x >> 13)) * np.uint64(0xC2B2AE35)
    return x ^ (x >> 16)


def random_vertex_cut(word: np.ndarray, doc: np.ndarray, p: int) -> np.ndarray:
    return ((_hash(word) ^ _hash(doc, 17)) % p).astype(np.int32)


def edge_partition_1d(word: np.ndarray, doc: np.ndarray, p: int) -> np.ndarray:
    return (_hash(word) % p).astype(np.int32)


def edge_partition_2d(word: np.ndarray, doc: np.ndarray, p: int) -> np.ndarray:
    rows = int(np.floor(np.sqrt(p)))
    while p % rows:
        rows -= 1
    cols = p // rows
    return ((_hash(doc) % rows) * cols + (_hash(word, 5) % cols)).astype(np.int32)


def dbh(word: np.ndarray, doc: np.ndarray, p: int) -> np.ndarray:
    """Degree-based hashing: assign the edge by hashing its lower-degree
    endpoint (i.e. the higher-degree vertex gets cut/replicated)."""
    w_deg = np.bincount(word, minlength=word.max() + 1)[word]
    d_deg = np.bincount(doc, minlength=doc.max() + 1)[doc]
    use_word = w_deg <= d_deg
    return np.where(
        use_word, _hash(word) % p, (_hash(doc, 17) % p)
    ).astype(np.int32)


def dbh_plus(
    word: np.ndarray, doc: np.ndarray, p: int, threshold: int = 8
) -> np.ndarray:
    """Paper Alg. 3 (DBH+): DBH, except when max(deg_w, deg_d) < threshold
    the edge follows the *higher*-degree endpoint — for cold edges locality
    (fewer replicas) matters more than cutting the bigger vertex."""
    w_deg = np.bincount(word, minlength=word.max() + 1)[word]
    d_deg = np.bincount(doc, minlength=doc.max() + 1)[doc]
    both_cold = np.maximum(w_deg, d_deg) < threshold
    # hot edges: hash lower-degree endpoint (cut the hub)
    use_word_hot = w_deg <= d_deg
    # cold edges: hash HIGHER-degree endpoint (keep the small star together)
    use_word_cold = w_deg >= d_deg
    use_word = np.where(both_cold, use_word_cold, use_word_hot)
    return np.where(
        use_word, _hash(word) % p, (_hash(doc, 17) % p)
    ).astype(np.int32)


PARTITIONERS = {
    "random_vertex_cut": random_vertex_cut,
    "edge_partition_1d": edge_partition_1d,
    "edge_partition_2d": edge_partition_2d,
    "dbh": dbh,
    "dbh_plus": dbh_plus,
}


def partition_metrics(
    word: np.ndarray, doc: np.ndarray, part: np.ndarray, p: int
) -> Dict[str, float]:
    """Balance + replication metrics (PowerGraph's cost model, paper §4.1):
    workload ∝ edges per partition; comms ∝ total vertex mirrors."""
    edges_per = np.bincount(part, minlength=p)
    # replication factor: how many partitions each vertex appears in
    wp = np.unique(np.stack([word, part]), axis=1).shape[1]
    dp = np.unique(np.stack([doc, part]), axis=1).shape[1]
    n_w = np.unique(word).size
    n_d = np.unique(doc).size
    return {
        "edge_balance": float(edges_per.max() / max(edges_per.mean(), 1e-9)),
        "word_replication": float(wp / n_w),
        "doc_replication": float(dp / n_d),
        "total_replication": float((wp + dp) / (n_w + n_d)),
    }


# ---------------------------------------------------------------------------
# SPMD grid partition (the physical layout for the TPU mesh)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class GridPartition:
    """Relabeled, padded 2D layout of a corpus for a (data x model) mesh.

    Arrays are global-view; axis 0 is `data*model` cells ordered row-major
    (cell = row * model + col) so sharding over ('data','model') flattened
    works with a simple reshape.
    """

    word: np.ndarray  # (cells, e_cell) int32 — NEW (relabeled) word ids
    doc: np.ndarray  # (cells, e_cell) int32 — NEW doc ids
    mask: np.ndarray  # (cells, e_cell) bool — False on padding
    data_parallel: int
    model_parallel: int
    words_per_shard: int  # W_pad / model_parallel
    docs_per_shard: int  # D_pad / data_parallel
    word_perm: np.ndarray  # old -> new word id (W,)
    doc_perm: np.ndarray  # old -> new doc id (D,)

    @property
    def num_words_padded(self) -> int:
        return self.words_per_shard * self.model_parallel

    @property
    def num_docs_padded(self) -> int:
        return self.docs_per_shard * self.data_parallel

    @property
    def padding_overhead(self) -> float:
        return float(self.mask.size / max(self.mask.sum(), 1)) - 1.0


def _balanced_ranges(loads: np.ndarray, bins: int) -> np.ndarray:
    """Greedy LPT bin-packing: assign items (sorted by descending load) to
    the least-loaded bin. Returns bin id per item. This is the DBH+ insight
    applied to static ranges: hot items get spread first."""
    order = np.argsort(-loads, kind="stable")
    bin_load = np.zeros(bins, dtype=np.int64)
    assign = np.zeros(loads.shape[0], dtype=np.int32)
    for it in order:
        b = int(np.argmin(bin_load))
        assign[it] = b
        bin_load[b] += int(loads[it])
    return assign


def grid_partition(
    corpus: Corpus,
    data_parallel: int,
    model_parallel: int,
    e_cell_multiple: int = 8,
    balance: str = "lpt",  # lpt | hash
    sort_tokens_by: str = "word",  # word-by-word process order (paper §3.1)
) -> GridPartition:
    word = np.asarray(corpus.word)
    doc = np.asarray(corpus.doc)
    w_tok = np.bincount(word, minlength=corpus.num_words)
    d_tok = np.bincount(doc, minlength=corpus.num_docs)

    if balance == "lpt":
        w_col = _balanced_ranges(w_tok, model_parallel)
        d_row = _balanced_ranges(d_tok, data_parallel)
    else:
        w_col = (_hash(np.arange(corpus.num_words)) % model_parallel).astype(np.int32)
        d_row = (_hash(np.arange(corpus.num_docs), 17) % data_parallel).astype(np.int32)

    # Relabel so each column's words are contiguous & uniform-width.
    def relabel(assign: np.ndarray, bins: int) -> Tuple[np.ndarray, int]:
        counts = np.bincount(assign, minlength=bins)
        per = int(counts.max())
        perm = np.empty(assign.shape[0], dtype=np.int64)
        for b in range(bins):
            ids = np.where(assign == b)[0]
            perm[ids] = b * per + np.arange(ids.size)
        return perm, per

    word_perm, words_per_shard = relabel(w_col, model_parallel)
    doc_perm, docs_per_shard = relabel(d_row, data_parallel)

    new_word = word_perm[word]
    new_doc = doc_perm[doc]
    row = (new_doc // docs_per_shard).astype(np.int64)
    col = (new_word // words_per_shard).astype(np.int64)
    cell = row * model_parallel + col
    cells = data_parallel * model_parallel

    cell_counts = np.bincount(cell, minlength=cells)
    e_cell = int(cell_counts.max())
    e_cell = ((e_cell + e_cell_multiple - 1) // e_cell_multiple) * e_cell_multiple
    e_cell = max(e_cell, e_cell_multiple)

    out_w = np.zeros((cells, e_cell), dtype=np.int32)
    out_d = np.zeros((cells, e_cell), dtype=np.int32)
    out_m = np.zeros((cells, e_cell), dtype=bool)
    order = np.lexsort(
        (new_doc, new_word, cell) if sort_tokens_by == "word"
        else (new_word, new_doc, cell)
    )
    sw, sd, sc = new_word[order], new_doc[order], cell[order]
    starts = np.searchsorted(sc, np.arange(cells))
    ends = np.searchsorted(sc, np.arange(cells) + 1)
    for c in range(cells):
        n = ends[c] - starts[c]
        out_w[c, :n] = sw[starts[c] : ends[c]]
        out_d[c, :n] = sd[starts[c] : ends[c]]
        out_m[c, :n] = True
        # padding tokens point at the cell's own (word, doc) range so local
        # index arithmetic stays in-bounds; mask keeps them inert.
        r, cc = divmod(c, model_parallel)
        out_w[c, n:] = cc * words_per_shard
        out_d[c, n:] = r * docs_per_shard

    return GridPartition(
        word=out_w, doc=out_d, mask=out_m,
        data_parallel=data_parallel, model_parallel=model_parallel,
        words_per_shard=words_per_shard, docs_per_shard=docs_per_shard,
        word_perm=word_perm.astype(np.int64),
        doc_perm=doc_perm.astype(np.int64),
    )
