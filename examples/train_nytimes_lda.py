"""End-to-end LDA training driver (the paper's NYTimes experiment, scaled
to this container): sparse initialization, converged-token exclusion after
iteration 30, asymmetric prior, periodic checkpoints with resume, llh
logging — several hundred iterations by default.

    PYTHONPATH=src python examples/train_nytimes_lda.py \
        [--iters 200] [--quick] [--ckpt /tmp/zenlda_ckpt]
"""
import argparse
import time

import jax
import numpy as np

from repro.core import LDAHyperParams, LDATrainer, TrainConfig
from repro.core.exclusion import ExclusionConfig
from repro.data import synthetic_corpus
from repro.train.checkpoint import CheckpointManager


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=200)
    ap.add_argument("--quick", action="store_true",
                    help="small corpus + 40 iterations (CI-sized)")
    ap.add_argument("--ckpt", default="/tmp/zenlda_nytimes_ckpt")
    ap.add_argument("--topics", type=int, default=0)
    args = ap.parse_args()

    if args.quick:
        corpus = synthetic_corpus(0, num_docs=300, num_words=500,
                                  avg_doc_len=60, zipf_a=1.2)
        k = args.topics or 32
        iters = min(args.iters, 40)
        excl_start = 10
    else:
        # NYTimes-shaped (scaled ~100x down for one CPU core): the paper's
        # corpus is 300k docs x 102k words x 100M tokens, K=1000
        corpus = synthetic_corpus(0, num_docs=3000, num_words=5000,
                                  avg_doc_len=120, zipf_a=1.15)
        k = args.topics or 100
        iters = args.iters
        excl_start = 30  # the paper enables exclusion after iteration 30
    hyper = LDAHyperParams(num_topics=k, alpha=0.05, beta=0.01,
                           asymmetric_alpha=True)
    trainer = LDATrainer(
        corpus, hyper,
        TrainConfig(
            algorithm="zen",
            init="sparse_word", sparse_init_degree=0.2,
            exclusion=ExclusionConfig(enabled=True,
                                      start_iteration=excl_start),
            token_chunk=0,  # 0 = whole sweep (shared knob vocabulary)
        ),
    )
    mgr = CheckpointManager(args.ckpt, keep=2)

    # resume: the checkpoint is (assignments, iteration) — counts rebuild
    state = trainer.init_state(jax.random.key(0))
    got = mgr.restore_latest({"topic": state.topic})
    start = 0
    if got is not None:
        tree, meta, start = got
        from repro.core import counts as counts_lib

        n_wk, n_kd, n_k = counts_lib.build_counts(
            corpus.word, corpus.doc, tree["topic"],
            corpus.num_words, corpus.num_docs, k,
        )
        import dataclasses

        state = dataclasses.replace(
            state, topic=tree["topic"], prev_topic=tree["topic"],
            n_wk=n_wk, n_kd=n_kd, n_k=n_k, iteration=start,
        )
        print(f"resumed from iteration {start}")

    print(f"tokens={corpus.num_tokens} K={k} iterations={iters}")
    t_start = time.time()
    for it in range(start + 1, iters + 1):
        t0 = time.time()
        state = trainer.step(state)
        dt = time.time() - t0
        if it % 10 == 0 or it == 1:
            llh = trainer.llh(state)
            print(f"iter {it:4d}  {dt*1e3:7.1f} ms  llh {llh:14.1f}  "
                  f"ppl {trainer.perplexity(state):9.2f}  "
                  f"change {trainer.change_rate(state):.3f}", flush=True)
        if it % 50 == 0:
            mgr.save(it, {"topic": state.topic}, {"iteration": it})
    mgr.save(iters, {"topic": state.topic}, {"iteration": iters})
    print(f"done in {time.time()-t_start:.1f}s; checkpoints in {args.ckpt}")


if __name__ == "__main__":
    main()
