"""Roofline tooling: HLO collective parser, shape-bytes math, terms."""
import numpy as np

from repro.launch.hloprof import bytes_by_op
from repro.launch.roofline import (
    _shape_bytes,
    collective_bytes_from_text,
    roofline_terms,
)

HLO = """
HloModule jit_step

fused_computation {
  p0 = f32[8,128]{1,0} parameter(0)
  ROOT m = f32[8,128]{1,0} multiply(p0, p0)
}

ENTRY main {
  x = f32[8,128]{1,0} parameter(0)
  ar = f32[8,128]{1,0} all-reduce(x), replica_groups={}, to_apply=add
  ag = bf16[16,256]{1,0} all-gather(x), dimensions={0}
  rs = (f32[4,128]{1,0}, f32[4,128]{1,0}) reduce-scatter(x, x), dimensions={0}
  cp = f32[8,128]{1,0} collective-permute(x), source_target_pairs={{0,1}}
  f = f32[8,128]{1,0} fusion(x), kind=kLoop, calls=fused_computation
  d = f32[8,8]{1,0} dot(x, x), lhs_contracting_dims={1}, rhs_contracting_dims={1}
  ROOT t = tuple(ar, ag, rs, cp, f, d)
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[8,128]") == 8 * 128 * 4
    assert _shape_bytes("bf16[16,256]") == 16 * 256 * 2
    assert _shape_bytes("(f32[4,128], f32[4,128])") == 2 * 4 * 128 * 4
    assert _shape_bytes("pred[3]") == 3
    assert _shape_bytes("s32[]") == 4


def test_collective_bytes_sums_all_collective_ops():
    got = collective_bytes_from_text(HLO)
    expect = (
        8 * 128 * 4  # all-reduce
        + 16 * 256 * 2  # all-gather
        + 2 * 4 * 128 * 4  # reduce-scatter tuple
        + 8 * 128 * 4  # collective-permute
    )
    assert got == expect, (got, expect)


def test_bytes_by_op_buckets():
    agg = bytes_by_op(HLO)
    assert agg["all-reduce"] == 8 * 128 * 4
    assert agg["dot"] == 8 * 8 * 4
    assert "fusion" in agg


def test_roofline_terms_and_bottleneck():
    rec = {
        "flops_per_device": 197e12,  # exactly 1 second of compute
        "bytes_per_device": 819e9 * 2,  # 2 seconds of HBM
        "collective_bytes_per_device": 50e9 * 0.5,
    }
    t = roofline_terms(rec)
    np.testing.assert_allclose(t["compute_s"], 1.0)
    np.testing.assert_allclose(t["memory_s"], 2.0)
    np.testing.assert_allclose(t["collective_s"], 0.5)
    assert t["bottleneck"] == "memory"
    np.testing.assert_allclose(t["step_lower_bound_s"], 2.0)
