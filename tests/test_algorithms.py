"""The sampler-backend registry: parity + conservation across every
registered backend, capability flags, and error reporting (DESIGN.md §4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import algorithms
from repro.core import LDATrainer, TrainConfig
from repro.core import counts as counts_lib
from repro.launch.mesh import make_mesh


def test_registry_lists_all_expected_backends():
    names = algorithms.registered()
    for expected in ("zen", "std", "zen_sparse", "zen_hybrid", "sparselda",
                     "lightlda", "zen_cdf", "zen_pallas"):
        assert expected in names, names


def test_unknown_name_raises_with_registered_list():
    with pytest.raises(ValueError) as ei:
        algorithms.get("definitely_not_an_algorithm")
    msg = str(ei.value)
    assert "definitely_not_an_algorithm" in msg
    for name in algorithms.registered():
        assert name in msg


def test_aliases_resolve_to_the_same_entry():
    """One registry entry per backend: TrainConfig's 'zen_pallas' and
    DistConfig's legacy 'zen_dense_kernel' are the same object, as are the
    single-box 'zen' and the distributed 'zen_dense'."""
    assert algorithms.get("zen_pallas") is algorithms.get("zen_dense_kernel")
    assert algorithms.get("zen") is algorithms.get("zen_dense")
    # aliases are not double-listed
    assert "zen_dense_kernel" not in algorithms.registered()


def test_capability_flags():
    assert algorithms.get("zen_cdf").supports_shard_map
    assert algorithms.get("zen_pallas").supports_shard_map
    assert algorithms.get("zen").supports_shard_map
    # the padded-sparse backends are mesh-capable since their cell-local
    # refactor; only the textbook std path stays single-box
    for name in ("zen_sparse", "zen_hybrid", "sparselda", "lightlda"):
        assert algorithms.get(name).supports_shard_map, name
        assert algorithms.get(name).needs_row_pads, name
    assert not algorithms.get("std").supports_shard_map
    assert algorithms.get("lightlda").needs_doc_index


@pytest.mark.parametrize("name", algorithms.registered())
def test_backend_parity_on_tiny_corpus(name, key, tiny_corpus, tiny_hyper):
    """Every registered backend (including zen_pallas in interpret mode)
    produces valid topics and conserves n_k totals after the delta merge."""
    tr = LDATrainer(tiny_corpus, tiny_hyper, TrainConfig(algorithm=name))
    st = tr.init_state(key)

    # raw sweep output: one valid topic per token
    z_new = tr.sweep(st)
    z = np.asarray(z_new)
    assert z.shape == (tiny_corpus.num_tokens,)
    assert z.dtype == np.int32
    assert (z >= 0).all() and (z < tiny_hyper.num_topics).all()

    # delta merge conserves every total (the backend contract: the driver
    # owns the merge, so any backend output must keep counts consistent)
    d_wk, d_kd, d_k = counts_lib.delta_counts(
        tiny_corpus.word, tiny_corpus.doc, st.topic, z_new,
        tiny_corpus.num_words, tiny_corpus.num_docs, tiny_hyper.num_topics,
    )
    assert int(jnp.sum(st.n_k + d_k)) == tiny_corpus.num_tokens
    np.testing.assert_array_equal(
        np.asarray(jnp.sum(st.n_wk + d_wk, axis=0)), np.asarray(st.n_k + d_k)
    )
    np.testing.assert_array_equal(
        np.asarray(jnp.sum(st.n_kd + d_kd, axis=0)), np.asarray(st.n_k + d_k)
    )

    # two full trainer iterations end-to-end (the acceptance round trip)
    for _ in range(2):
        st = tr.step(st)
    st.check_invariants(tiny_corpus)


def test_zen_pallas_matches_ref_oracle(key, tiny_corpus, tiny_hyper):
    """Single-box zen_pallas sweep == kernels/ref.py oracle bit-for-bit
    (interpret mode on CPU; the same contract the TPU kernel satisfies)."""
    from repro.kernels.ref import zen_sample_ref

    tr = LDATrainer(tiny_corpus, tiny_hyper, TrainConfig(algorithm="zen_pallas"))
    st = tr.init_state(key)
    z_backend = tr.sweep(st)

    # reproduce the backend's seed derivation, then call the pure-jnp oracle
    k_cell = jax.random.fold_in(st.rng, st.iteration)
    seed = jax.random.randint(
        k_cell, (), 0, jnp.iinfo(jnp.int32).max, dtype=jnp.int32
    )
    ref = zen_sample_ref(
        st.n_wk[tiny_corpus.word], st.n_kd[tiny_corpus.doc], st.topic,
        tiny_hyper.alpha_k(st.n_k), st.n_k.astype(jnp.float32), seed,
        beta=tiny_hyper.beta,
        w_beta=tiny_corpus.num_words * tiny_hyper.beta,
    )
    np.testing.assert_array_equal(np.asarray(z_backend), np.asarray(ref))


def test_dist_config_resolves_same_registry_entry(key, tiny_corpus, tiny_hyper):
    """DistConfig and TrainConfig reach zen_pallas through the same entry:
    a 1x1 mesh dist step runs the kernel backend and conserves counts."""
    from repro.core.distributed import (
        DistConfig, init_dist_state, make_dist_step,
    )
    from repro.core.graph import grid_partition

    mesh = make_mesh((1, 1), ("data", "model"))
    grid = grid_partition(tiny_corpus, 1, 1)
    e = int(grid.mask.sum())
    state, data = init_dist_state(key, mesh, grid, tiny_hyper)
    step = make_dist_step(
        mesh, tiny_hyper, DistConfig(algorithm="zen_pallas", max_kd=8),
        grid.words_per_shard, grid.docs_per_shard,
    )
    for _ in range(2):
        state = step(state, data)
    assert int(jnp.sum(state.n_k)) == e
    np.testing.assert_array_equal(
        np.asarray(jnp.sum(state.n_wk, 0)), np.asarray(state.n_k)
    )


def test_dist_step_rejects_single_box_only_backends(key, tiny_corpus, tiny_hyper):
    from repro.core.distributed import DistConfig, make_dist_step
    from repro.core.graph import grid_partition

    mesh = make_mesh((1, 1), ("data", "model"))
    grid = grid_partition(tiny_corpus, 1, 1)
    with pytest.raises(ValueError, match="shard_map"):
        make_dist_step(
            mesh, tiny_hyper, DistConfig(algorithm="std"),
            grid.words_per_shard, grid.docs_per_shard,
        )


def test_hybrid_switch_uses_effective_rows():
    """Regression (crafted corpus): the hybrid's switch prices each
    constituent by the rows it will ACTUALLY sample — raw nnz clamped to
    the padded capacity it sparsifies at — not by global row density."""
    import jax.numpy as jnp

    from repro.algorithms.zen_hybrid import hybrid_route_doc_side

    k = 16
    # doc 0: 10 live topics; word 0: 12 live topics. On raw density the
    # doc side looks sparser (10 <= 12) — but with the word rows padded to
    # 4 slots the word side samples a 4-wide row and must win.
    n_kd = jnp.zeros((1, k), jnp.int32).at[0, :10].set(1)
    n_wk = jnp.zeros((1, k), jnp.int32).at[0, :12].set(1)
    word = jnp.zeros((3,), jnp.int32)
    doc = jnp.zeros((3,), jnp.int32)

    raw = hybrid_route_doc_side(n_wk, n_kd, word, doc, max_kw=16, max_kd=16)
    assert bool(raw.all())  # unclamped: doc side (the old global decision)
    clamped = hybrid_route_doc_side(n_wk, n_kd, word, doc, max_kw=4, max_kd=16)
    assert not bool(clamped.any())  # truncated word rows are cheaper: switch
    # symmetric: clamp the doc side instead and the doc side wins again
    back = hybrid_route_doc_side(n_wk, n_kd, word, doc, max_kw=4, max_kd=2)
    assert bool(back.all())


def test_hybrid_cell_sweep_composes_constituents_by_route(
    key, tiny_corpus, tiny_hyper
):
    """Integration: ZenHybrid.cell_sweep IS where(route, zen_sparse draw,
    sparselda draw) — same key, same blocks, same (clamped) widths. Run
    with a width split (max_kw < max_kd) so both routes are exercised and
    a cell_sweep that mis-passed widths or re-derived the route inline
    would produce different draws."""
    import dataclasses

    import jax.numpy as jnp

    from repro.algorithms.zen_hybrid import hybrid_route_doc_side
    from repro.core.init import random_init

    st = random_init(key, tiny_corpus, tiny_hyper)
    hybrid = algorithms.get("zen_hybrid")
    # clamp the doc side below K while word-row nnz spans [1, K] (zipf
    # vocabulary: rare words hold few topics), so both routes are taken
    knobs = dataclasses.replace(
        TrainConfig().knobs(), max_kw=tiny_hyper.num_topics,
        max_kd=tiny_hyper.num_topics // 2,
    )
    k_cell = jax.random.key(3)
    mask = jnp.ones(tiny_corpus.word.shape, bool)
    args = (k_cell, tiny_corpus.word, tiny_corpus.doc, st.topic, mask,
            st.n_wk, st.n_kd, st.n_k, tiny_hyper, tiny_corpus.num_words,
            knobs)
    z_hybrid = hybrid.cell_sweep(*args)

    route = hybrid_route_doc_side(
        st.n_wk, st.n_kd, tiny_corpus.word, tiny_corpus.doc,
        knobs.max_kw, knobs.max_kd,
    )
    assert bool(route.any()) and not bool(route.all())  # both routes live
    z_zen = algorithms.get("zen_sparse").cell_sweep(*args)
    z_alt = algorithms.get("sparselda").cell_sweep(*args)
    np.testing.assert_array_equal(
        np.asarray(z_hybrid), np.asarray(jnp.where(route, z_zen, z_alt))
    )


def test_shared_knobs_unify_all_driver_configs():
    """Every driver config builds its SamplerKnobs through the single
    ``algorithms.knobs_from`` derivation (RunConfig owns it; the
    deprecated TrainConfig/DistConfig shims delegate), and the
    token_chunk vocabulary is unified (0 = disabled everywhere)."""
    from repro.core.distributed import DistConfig
    from repro.train.session import RunConfig

    tk = TrainConfig().knobs()
    dk = DistConfig().knobs()
    rk = RunConfig().knobs()
    assert type(tk) is type(dk) is type(rk) is algorithms.SamplerKnobs
    assert tk.token_chunk == dk.token_chunk == rk.token_chunk == 0
    # RunConfig's None sampling_method = "plan default" (TrainSession
    # resolves it to cdf single-box / gumbel mesh — the two shims'
    # historical defaults, preserved)
    assert rk.sampling_method is None
    assert tk.sampling_method == "cdf" and dk.sampling_method == "gumbel"
    # identical field-for-field knobs from identical settings, whichever
    # config carries them
    assert TrainConfig(max_kw=32, token_chunk=128).knobs() == \
        RunConfig(max_kw=32, token_chunk=128,
                  sampling_method="cdf").knobs() == \
        DistConfig(max_kw=32, token_chunk=128,
                   sampling_method="cdf").knobs()
