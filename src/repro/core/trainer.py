"""Deprecated single-box driver shims: ``LDATrainer`` / ``TrainConfig``.

The real driver is ``repro.train.session.TrainSession`` driven by a
declarative ``RunConfig`` (DESIGN.md §6) — one schedule-driven loop for
single-box AND mesh training. These shims keep the historical single-box
surface alive (``LDATrainer(corpus, hyper, TrainConfig(...))`` with
``init_state/sweep/step/llh/train``) by delegating every call to a
session whose single-box plan reproduces the old numerics bit-for-bit
(same key schedule, same delta merge — pinned by
``tests/test_session.py``). New code should construct ``TrainSession``
directly:

    from repro.train.session import RunConfig, TrainSession
    session = TrainSession(corpus, hyper, RunConfig(algorithm="zen", ...))
    final = session.run(jax.random.key(0))
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax

from repro.algorithms import SamplerKnobs, knobs_from
from repro.core.exclusion import ExclusionConfig
from repro.core.types import CGSState, Corpus, LDAHyperParams

# NOTE: repro.train.session is imported lazily inside the shims — the
# session module itself imports repro.algorithms, whose backend modules
# import repro.core, whose __init__ imports this module; a top-level
# import here would close that cycle on a partially-initialized module.


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Deprecated: the single-box slice of ``RunConfig`` (kept for the
    historical call sites; every field maps 1:1 via ``to_run_config``)."""

    algorithm: str = "zen"  # any algorithms.registered() name
    init: str = "random"  # random | sparse_word | sparse_doc
    sparse_init_degree: float = 0.1
    sampling_method: str = "cdf"  # cdf | gumbel  (dense paths)
    exclusion: ExclusionConfig = ExclusionConfig()
    max_kw: int = 0  # 0 -> auto from data (padded-sparse paths)
    max_kd: int = 0
    num_mh: int = 8  # LightLDA MH steps (paper uses 8)
    token_chunk: int = 0  # 0 = whole sweep at once (memory knob)
    bt: int = 256  # Pallas token tile
    bk: int = 512  # Pallas topic tile
    bs: int = 128  # sparse-row lane tile (kernel suite v2)
    kernels: str = "auto"  # Pallas kernel dispatch: auto | on | off
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 0

    def knobs(self) -> SamplerKnobs:
        return knobs_from(self)  # the one shared derivation

    def to_run_config(
        self,
        num_iterations: int = 0,
        eval_every: int = 0,
        target_perplexity: Optional[float] = None,
    ) -> "RunConfig":
        from repro.train.session import RunConfig

        # legacy (enabled=True, start_iteration=0) means "on from the
        # start"; RunConfig's 0 means disabled, and enabling at iteration
        # 1 is bit-identical (fresh stats give resample probability 1)
        excl_start = 0
        if self.exclusion.enabled:
            excl_start = max(int(self.exclusion.start_iteration), 1)
        return RunConfig(
            algorithm=self.algorithm,
            sampling_method=self.sampling_method,
            max_kw=self.max_kw, max_kd=self.max_kd, num_mh=self.num_mh,
            token_chunk=self.token_chunk, bt=self.bt, bk=self.bk,
            bs=self.bs, kernels=self.kernels,
            init=self.init, sparse_init_degree=self.sparse_init_degree,
            mesh_shape=None,
            num_iterations=num_iterations,
            eval_every=eval_every,
            target_perplexity=target_perplexity,
            exclusion_start=excl_start,
            exclusion_min_prob=self.exclusion.min_sample_prob,
            checkpoint_dir=self.checkpoint_dir,
            checkpoint_every=self.checkpoint_every,
        )


class LDATrainer:
    """Deprecated: a thin veneer over a single-box ``TrainSession``."""

    def __init__(self, corpus: Corpus, hyper: LDAHyperParams, cfg: TrainConfig):
        from repro.train.session import TrainSession

        self.corpus = corpus
        self.hyper = hyper
        self.cfg = cfg
        self._session = TrainSession(corpus, hyper, cfg.to_run_config())
        self.backend = self._session.backend

    # -- initialization ----------------------------------------------------
    def init_state(self, rng: jax.Array) -> CGSState:
        return self._session.init(rng)

    # -- one iteration -----------------------------------------------------
    def sweep(self, state: CGSState) -> jax.Array:
        return self._session.plan.sweep(state)

    def step(self, state: CGSState) -> CGSState:
        return self._session.step(state)

    # -- metrics -----------------------------------------------------------
    def llh(self, state: CGSState) -> float:
        return self._session.llh(state)

    def llh_split(self, state: CGSState):
        return self._session.plan.llh_split(state)

    def perplexity(self, state: CGSState) -> float:
        return self._session.perplexity(state)

    def change_rate(self, state: CGSState) -> float:
        """Fraction of tokens whose topic changed last iteration (Fig. 9a)."""
        return self._session.plan.change_rate(state)

    # -- model checkpointing (serving handoff) ------------------------------
    def save_model(self, state: CGSState, directory: Optional[str] = None) -> str:
        return self._session.save_model(state, directory)

    # -- training loop ------------------------------------------------------
    def train(
        self,
        rng: jax.Array,
        num_iterations: int,
        state: Optional[CGSState] = None,  # incremental training entry
        llh_every: int = 0,
        callback: Optional[Callable[[CGSState, dict], None]] = None,
        target_perplexity: Optional[float] = None,
    ) -> CGSState:
        """Delegates to ``TrainSession.run`` (sharing the already-prepared
        plan). ``num_iterations`` counts *additional* steps from the given
        state (the historical semantics); the session's own config counts
        absolute iterations. ``target_perplexity`` is honored on every
        eval tick, derived from the eval's already-computed llh (no second
        likelihood pass). One deliberate deviation: eval/checkpoint ticks
        fire on *absolute*-iteration multiples of the cadence (so a
        resumed run fires on the same grid as an uninterrupted one),
        where the old loop counted relative to the resume point."""
        start = 0 if state is None else int(state.iteration)
        session = self._session.with_run_params(
            num_iterations=start + num_iterations,
            eval_every=llh_every,
            target_perplexity=target_perplexity,
        )
        return session.run(rng=rng, state=state, callback=callback)
