"""Model-quality suite (repro.eval + the session's quality/hyper actions).

Every metric lands with an independent oracle, not a smoke run:

* UMass and NPMI coherence are pinned against hand-computed values on a
  3-document corpus (doc frequencies and window sets enumerable by eye).
* Left-to-right held-out llh is cross-checked against exhaustive K^L
  enumeration on short documents (exact for L=1, tight tolerance for
  L=3 with many particles).
* The Minka alpha fixed point is pinned against the harmonic-sum
  identity psi(n + a) - psi(a) = sum_{i<n} 1/(a + i) — no digamma in
  the oracle.
* The Alg. 5 "hyper" schedule action is pinned bit-identical to a
  no-hyper run when disabled (the autopilot inertness contract), and
  the quality trajectory is bit-reproducible per backend.
"""
import math

import jax
import numpy as np
import pytest

from repro import algorithms
from repro.core.hyper import anneal_beta, minka_alpha_update, optimize_hyper
from repro.core.types import LDAHyperParams
from repro.data import synthetic_lda_corpus
from repro.eval import (
    CoherenceStats,
    QualityConfig,
    QualityEval,
    exhaustive_llh,
    left_to_right_llh,
    npmi_coherence,
    top_topic_words,
    umass_coherence,
)
from repro.train.session import RunConfig, TrainSession


# ---------------------------------------------------------------------------
# coherence: hand-computed oracles
# ---------------------------------------------------------------------------

def _tiny_stats(window=2):
    # doc0 = [0, 1, 2], doc1 = [0, 1], doc2 = [2, 3]
    word = np.array([0, 1, 2, 0, 1, 2, 3], np.int32)
    doc = np.array([0, 0, 0, 1, 1, 2, 2], np.int32)
    return CoherenceStats(word, doc, 3, window=window)


def test_umass_hand_computed():
    """D(0)=D(1)=D(2)=2, D(3)=1; D(0,1)=2, D(2,3)=1 — by eye."""
    stats = _tiny_stats()
    assert stats.doc_freq(0) == 2 and stats.doc_freq(3) == 1
    assert stats.co_doc_freq(0, 1) == 2 and stats.co_doc_freq(2, 3) == 1
    assert stats.co_doc_freq(0, 3) == 0
    top = np.array([[0, 1], [2, 3]])
    mean, per_topic = umass_coherence(stats, top)
    # topic0: log((D(1,0)+1)/D(0)) = log(3/2); topic1: log((1+1)/2) = 0
    np.testing.assert_allclose(per_topic, [math.log(1.5), 0.0], rtol=1e-12)
    np.testing.assert_allclose(mean, math.log(1.5) / 2, rtol=1e-12)


def test_umass_skips_absent_denominator():
    """A zero-count word in the top-N must not divide by zero."""
    stats = _tiny_stats()
    top = np.array([[7, 0]])  # word 7 never occurs; D(7) = 0
    mean, per_topic = umass_coherence(stats, top)
    # the (0, 7) pair is skipped -> score 0, not -inf/nan
    assert per_topic[0] == 0.0 and np.isfinite(mean)


def test_npmi_hand_computed():
    """Windows (size 2): {0,1},{1,2} from doc0; {0,1} doc1; {2,3} doc2."""
    stats = _tiny_stats(window=2)
    assert stats.num_windows == 4
    np.testing.assert_allclose(stats.window_prob(0), 2 / 4)
    np.testing.assert_allclose(stats.window_prob(1), 3 / 4)
    np.testing.assert_allclose(stats.co_window_prob(0, 1), 2 / 4)
    top = np.array([[0, 1], [2, 3]])
    mean, per_topic = npmi_coherence(stats, top)
    # (0,1): log((1/2)/((1/2)(3/4)))/(-log(1/2)) = log(4/3)/log 2
    # (2,3): log((1/4)/((1/2)(1/4)))/(-log(1/4)) = log 2/(2 log 2) = 1/2
    expect0 = math.log(4 / 3) / math.log(2)
    np.testing.assert_allclose(per_topic, [expect0, 0.5], rtol=1e-12)
    np.testing.assert_allclose(mean, (expect0 + 0.5) / 2, rtol=1e-12)


def test_npmi_never_cooccurring_pair_is_minus_one():
    stats = _tiny_stats(window=2)
    mean, per_topic = npmi_coherence(stats, np.array([[0, 3]]))
    assert per_topic[0] == -1.0


def test_top_topic_words_order_and_ties():
    n_wk = np.array([[5, 1], [9, 1], [5, 7], [0, 7]], np.int64)
    top = top_topic_words(n_wk, 3)
    # topic 0: counts [5,9,5,0] -> 1, then tie 5/5 -> lower word id first
    np.testing.assert_array_equal(top[0], [1, 0, 2])
    # topic 1: tie 7/7 -> word 2 before 3
    np.testing.assert_array_equal(top[1], [2, 3, 0])


# ---------------------------------------------------------------------------
# left-to-right vs exhaustive enumeration
# ---------------------------------------------------------------------------

_LLH_MODEL = dict(
    n_wk=np.array([[8, 1], [1, 8], [4, 4]], np.int64),
    n_k=np.array([13, 13], np.int64),
)


def test_l2r_single_token_exact():
    """L=1 has no assignment uncertainty: the estimate IS the exact
    marginal, independent of particle count."""
    hyper = LDAHyperParams(num_topics=2, alpha=0.3, beta=0.2)
    words = np.array([1])
    exact = exhaustive_llh(**_LLH_MODEL, words=words, hyper=hyper)
    est = left_to_right_llh(**_LLH_MODEL, words=words, hyper=hyper,
                            num_particles=3,
                            rng=np.random.default_rng(0))
    np.testing.assert_allclose(est, exact, rtol=1e-12)


@pytest.mark.parametrize("asymmetric", [False, True])
def test_l2r_matches_exhaustive_three_tokens(asymmetric):
    """The tentpole oracle: particle estimate vs K^3 enumeration."""
    hyper = LDAHyperParams(num_topics=2, alpha=0.3, beta=0.2,
                           asymmetric_alpha=asymmetric)
    words = np.array([0, 1, 2])
    exact = exhaustive_llh(**_LLH_MODEL, words=words, hyper=hyper)
    est = left_to_right_llh(**_LLH_MODEL, words=words, hyper=hyper,
                            num_particles=4000,
                            rng=np.random.default_rng(0))
    assert abs(est - exact) < 0.05, (est, exact)


def test_exhaustive_llh_two_tokens_hand_expansion():
    """Cross-check the oracle itself on L=2 against the explicit
    4-term sum written out by hand."""
    hyper = LDAHyperParams(num_topics=2, alpha=0.5, beta=0.25,
                           asymmetric_alpha=False)
    n_wk = np.array([[2, 0], [1, 3]], np.int64)
    n_k = np.array([3, 3], np.int64)
    words = np.array([0, 1])
    w_beta = 2 * 0.25
    phi = [[(2 + .25) / (3 + w_beta), (0 + .25) / (3 + w_beta)],
           [(1 + .25) / (3 + w_beta), (3 + .25) / (3 + w_beta)]]
    a = [0.5, 0.5]
    total = 0.0
    for z0 in range(2):
        for z1 in range(2):
            p = (a[z0] / 1.0) * phi[0][z0]
            p *= ((1.0 if z1 == z0 else 0.0) + a[z1]) / (1 + 1.0) * phi[1][z1]
            total += p
    got = exhaustive_llh(n_wk, n_k, words, hyper)
    np.testing.assert_allclose(got, math.log(total), rtol=1e-12)


def test_l2r_empty_doc():
    hyper = LDAHyperParams(num_topics=2)
    assert left_to_right_llh(**_LLH_MODEL, words=np.array([], np.int32),
                             hyper=hyper,
                             rng=np.random.default_rng(0)) == 0.0


def test_l2r_seeded_reproducible():
    hyper = LDAHyperParams(num_topics=2, alpha=0.3, beta=0.2)
    words = np.array([0, 1, 2, 1])
    a = left_to_right_llh(**_LLH_MODEL, words=words, hyper=hyper,
                          num_particles=50, rng=np.random.default_rng(7))
    b = left_to_right_llh(**_LLH_MODEL, words=words, hyper=hyper,
                          num_particles=50, rng=np.random.default_rng(7))
    assert a == b


# ---------------------------------------------------------------------------
# Minka fixed point + beta annealing (Alg. 5)
# ---------------------------------------------------------------------------

def test_minka_alpha_harmonic_sum_oracle():
    """psi(n + a) - psi(a) == sum_{i<n} 1/(a + i) for integer n — the
    oracle needs no digamma at all."""
    n_kd = np.array([[3, 1], [2, 4], [0, 2]], np.int64)
    alpha = 0.5
    k = 2

    def rising(n, a):
        return sum(1.0 / (a + i) for i in range(int(n)))

    num = sum(rising(n, alpha) for n in n_kd.ravel())
    den = k * sum(rising(n, k * alpha) for n in n_kd.sum(axis=1))
    expect = alpha * num / den
    got = minka_alpha_update(n_kd, alpha)
    np.testing.assert_allclose(got, expect, rtol=1e-10)


def test_minka_alpha_padding_rows_inert():
    """All-zero doc rows (mesh padding) must not move the update."""
    n_kd = np.array([[3, 1], [2, 4]], np.int64)
    padded = np.vstack([n_kd, np.zeros((5, 2), np.int64)])
    np.testing.assert_allclose(
        minka_alpha_update(n_kd, 0.4), minka_alpha_update(padded, 0.4),
        rtol=1e-12,
    )


def test_minka_alpha_degenerate_keeps_value():
    assert minka_alpha_update(np.zeros((3, 2), np.int64), 0.3) == 0.3


def test_anneal_beta():
    assert anneal_beta(0.01, 1.0, 1e-4) == 0.01
    np.testing.assert_allclose(anneal_beta(0.01, 0.5, 1e-4), 0.005)
    assert anneal_beta(0.01, 0.5, 0.008) == 0.008  # floor clamps


def test_optimize_hyper_noop_returns_same_object():
    hyper = LDAHyperParams(num_topics=2, alpha=0.3, beta=0.2)
    out = optimize_hyper(hyper, np.zeros((2, 2), np.int64),
                         update_alpha=True, beta_anneal=1.0)
    assert out is hyper


# ---------------------------------------------------------------------------
# session integration: quality + hyper actions
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def quality_corpus():
    corpus, _phi = synthetic_lda_corpus(
        seed=0, num_docs=30, num_words=40, num_topics=4, avg_doc_len=20
    )
    return corpus


_HYPER = LDAHyperParams(num_topics=4, alpha=0.1, beta=0.05)


def test_quality_action_fires_on_cadence(quality_corpus):
    cfg = RunConfig(algorithm="zen", num_iterations=4, quality_every=2,
                    quality_l2r_docs=2, quality_l2r_particles=4)
    session = TrainSession(quality_corpus, _HYPER, cfg)
    assert "quality" in session.schedule.names()
    ticks = []
    session.run(jax.random.key(0), callback=lambda st, m: ticks.append(
        (int(st.iteration), m)) if m else None)
    assert [i for i, _ in ticks] == [2, 4]
    for _, m in ticks:
        for key in ("coherence_umass", "coherence_npmi", "l2r_llh",
                    "l2r_per_token"):
            assert key in m and np.isfinite(m[key]), m


def test_quality_disabled_builds_nothing(quality_corpus):
    session = TrainSession(quality_corpus, _HYPER,
                           RunConfig(algorithm="zen", num_iterations=1))
    assert session._quality is None
    assert "quality" not in session.schedule.names()
    assert "hyper" not in session.schedule.names()


def test_hyper_disabled_bit_identical(quality_corpus):
    """The Alg. 5 contract: hyper_every=0 is INERT — same schedule,
    bit-identical assignments and counts as a config that never heard
    of hyper optimization (whatever the other hyper knobs say)."""
    base = TrainSession(quality_corpus, _HYPER,
                        RunConfig(algorithm="zen", num_iterations=4))
    off = TrainSession(quality_corpus, _HYPER, RunConfig(
        algorithm="zen", num_iterations=4,
        hyper_every=0, hyper_beta_anneal=0.5, hyper_alpha=False,
    ))
    assert base.schedule.names() == off.schedule.names()
    fa = base.run(jax.random.key(0))
    fb = off.run(jax.random.key(0))
    np.testing.assert_array_equal(np.asarray(fa.topic), np.asarray(fb.topic))
    np.testing.assert_array_equal(np.asarray(fa.n_wk), np.asarray(fb.n_wk))
    assert off.hyper.beta == _HYPER.beta  # never annealed


def test_hyper_action_updates_and_conserves(quality_corpus):
    cfg = RunConfig(algorithm="zen", num_iterations=4, hyper_every=2,
                    hyper_beta_anneal=0.9)
    session = TrainSession(quality_corpus, _HYPER, cfg)
    seen = []
    final = session.run(jax.random.key(0), callback=lambda st, m: seen.append(
        m["hyper"]) if "hyper" in m else None)
    assert len(seen) == 2  # fired at 2 and 4
    np.testing.assert_allclose(session.hyper.beta, _HYPER.beta * 0.9 ** 2,
                               rtol=1e-12)
    assert session.hyper.alpha != _HYPER.alpha  # Minka moved it
    final.check_invariants(quality_corpus)  # counts still conserve
    assert np.isfinite(session.llh(final))


def test_quality_eval_reusable_and_deterministic(quality_corpus):
    qe = QualityEval(quality_corpus, _HYPER,
                     QualityConfig(top_n=5, l2r_docs=3, l2r_particles=6))
    n_wk = np.random.default_rng(0).integers(
        0, 9, (quality_corpus.num_words, 4))
    n_k = n_wk.sum(axis=0)
    a = qe.evaluate(n_wk, n_k, iteration=3)
    b = qe.evaluate(n_wk, n_k, iteration=3)
    assert a == b
    # a different iteration reseeds the particles: coherence identical,
    # l2r at most jitters within the estimator variance
    c = qe.evaluate(n_wk, n_k, iteration=4)
    assert c["coherence_umass"] == a["coherence_umass"]


# ---------------------------------------------------------------------------
# cross-backend quality determinism (satellite)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", algorithms.registered())
def test_quality_trajectory_bit_reproducible(backend, quality_corpus):
    """Same seed + same backend => bit-identical eval + quality
    trajectory across two independent TrainSession.run() invocations
    (extends the mesh-parity replay contract to the quality metrics)."""
    cfg = RunConfig(algorithm=backend, num_iterations=2, eval_every=1,
                    quality_every=1, quality_top_n=5,
                    quality_l2r_docs=2, quality_l2r_particles=4)
    trajs = []
    for _ in range(2):
        session = TrainSession(quality_corpus, _HYPER, cfg)
        traj = []
        session.run(jax.random.key(0),
                    callback=lambda st, m: traj.append(
                        (int(st.iteration), dict(m))))
        trajs.append(traj)
    assert trajs[0] == trajs[1]
    # and the trajectory actually carries the quality keys
    assert any("coherence_umass" in m for _, m in trajs[0])
