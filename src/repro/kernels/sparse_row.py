"""Padded-sparse row sampler — kernel suite v2, kernel (c).

The four Alg. 2 sparse backends (``zen_sparse``, ``zen_hybrid``,
``sparselda``, ``lightlda``) all end their hot loops the same way: a
token holds a compact ``(max_k,)`` row of (topic id, weight) pairs —
sentinel-masked, lane-aligned, the exact layout ``resolve_dist_row_pads``
produces — and must invert a uniform target through the row's running
sum, returning the *topic id* stored at the landing position. This
kernel is that primitive: cumsum, lower-bound count, clamp, one-hot
topic select, all on a ``(bt, J)`` tile resident in VMEM (SaberLDA's
sparsity-aware vectorized sampling, PAPERS.md).

Deliberately a whole-row kernel — grid is ``(T/bt,)`` with no J tiling.
Compact rows are short (``max_kw``/``max_kd`` ≲ a few hundred lanes) so
a row always fits; tiling J would reintroduce a cross-tile clamp hazard
(a tile-local clamp cannot know the search landed in an earlier tile),
and a 1-D grid keeps interpret mode cheap enough to dispatch in tests.
Padding is inert by construction: padded lanes carry weight 0 (no mass,
no count change below target) and sentinel topic ids that the
``min(cnt, j_real - 1)`` clamp can never select. Bit-identical to
``ref.sparse_row_sample_ref`` at every (bt, pad) shape.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.utils.compat import pallas_tpu_compiler_params


def _sparse_row_kernel(
    vals_ref,  # (bt, J) f32 — per-lane weights, 0 on padded lanes
    topics_ref,  # (bt, J) int32 — per-lane topic ids, sentinel on padding
    tgt_ref,  # (bt, 1) f32 — per-token inversion target
    out_ref,  # (bt, 1) int32 — selected topic id
    *,
    j_real: int,
):
    vals = vals_ref[...]
    cdf = jnp.cumsum(vals, axis=1)
    cnt = jnp.sum((cdf < tgt_ref[...]).astype(jnp.int32), axis=1)
    pos = jnp.minimum(cnt, j_real - 1)
    lanes = jax.lax.broadcasted_iota(jnp.int32, vals.shape, 1)
    hit = (lanes == pos[:, None]).astype(jnp.int32)
    out_ref[...] = jnp.sum(topics_ref[...] * hit, axis=1, keepdims=True)


def sparse_row_sample_pallas(
    vals: jax.Array,  # (T, J) f32 — compact row weights
    topics: jax.Array,  # (T, J) int32 — compact row topic ids
    targets: jax.Array,  # (T,) f32 — inversion targets
    *,
    j_real: int,
    bt: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Per-token CDF inversion over compact sparse rows: topic id at the
    lower-bound position of ``targets`` in ``cumsum(vals, 1)``, clamped
    to ``j_real - 1``. T % bt == 0 required (``ops.sparse_row_sample``
    pads and manages the VMEM row budget)."""
    t, j = vals.shape
    assert t % bt == 0, (t, bt)
    assert topics.shape == (t, j)
    kernel = functools.partial(_sparse_row_kernel, j_real=j_real)
    out = pl.pallas_call(
        kernel,
        grid=(t // bt,),
        in_specs=[
            pl.BlockSpec((bt, j), lambda i: (i, 0)),
            pl.BlockSpec((bt, j), lambda i: (i, 0)),
            pl.BlockSpec((bt, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bt, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, 1), jnp.int32),
        interpret=interpret,
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel",),
        ),
    )(
        vals.astype(jnp.float32),
        topics.astype(jnp.int32),
        targets[:, None].astype(jnp.float32),
    )
    return out[:, 0]
