"""LDAEngine/LDARouter telemetry: the per-tick ``serve_window`` emitter.

The engine already stamps every request (``t_submit``/``t_done``
monotonic stamps, ``ticks_waited``); this hook aggregates those stamps
plus the per-tick queue/bucket state into *windowed* summary records —
one JSONL line per window, not per tick, so a 1 ms ticker doesn't write
a thousand lines a second. A window closes after ``window_ticks``
admission ticks or ``window_arrivals`` arrivals, whichever first.

Every ``serve_window`` record carries the measured arrival process
(inter-arrival times), queueing state (depth, slot occupancy, spills,
ticks waited), the end-to-end latency summary of the requests that
finished inside the window, and the knob values in effect — exactly the
inputs ``repro.autotune.ServeAutopilot`` derives ``tick_period`` /
``max_slot_wait`` / bucket widths from. All entry points are called by
the engine UNDER its lock; no locking here.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.observe.metrics import (
    MetricsRegistry,
    latency_percentile,
    summarize_latencies,
)


class ServeTelemetry:
    """Windowed measurement hook for an ``LDAEngine``.

    Args:
        registry: the metrics registry (its sink receives the JSONL).
        window_ticks: close a window after this many admission ticks.
        window_arrivals: ... or after this many arrivals, whichever first.
    """

    def __init__(self, registry: MetricsRegistry, window_ticks: int = 256,
                 window_arrivals: int = 64):
        self.registry = registry
        self.window_ticks = max(1, int(window_ticks))
        self.window_arrivals = max(1, int(window_arrivals))
        self.last_window: Optional[Dict[str, Any]] = None
        self._reset_window()
        self._prev_arrival_t: Optional[float] = None
        self._windows_emitted = 0

    def _reset_window(self) -> None:
        self._ticks = 0
        self._interarrivals_ms: List[float] = []
        self._doc_lens: List[int] = []
        self._latencies_ms: List[float] = []
        self._wait_ticks: List[int] = []
        self._queue_depths: List[int] = []
        self._occupancies: List[int] = []
        self._spills_at_open: Optional[int] = None

    # -- submit-side --------------------------------------------------------
    def record_submit(self, t_submit: float, doc_len: int) -> None:
        """One arrival (engine ``_submit``, under the engine lock)."""
        if self._prev_arrival_t is not None:
            self._interarrivals_ms.append(
                (t_submit - self._prev_arrival_t) * 1e3)
        self._prev_arrival_t = t_submit
        self._doc_lens.append(int(doc_len))
        self.registry.counter("serve.arrivals").inc()

    # -- tick-side ----------------------------------------------------------
    def record_tick(
        self,
        *,
        queue_depth: int,
        occupancy: int,
        finished: Sequence,
        spills_total: int,
        tick_period: float,
        max_slot_wait: int,
        bucket_widths: Sequence[int],
        model_version: int,
    ) -> Optional[Dict[str, Any]]:
        """One admission tick (engine ``step``, under the engine lock).

        ``finished`` are the ``InferRequest``s this tick completed
        (``t_submit``/``t_done``/``ticks_waited`` are read off them);
        ``spills_total`` is the engine's cumulative spill counter — the
        window reports the delta. Returns the closed window's summary
        record when this tick closed one, else None.
        """
        self._ticks += 1
        if self._spills_at_open is None:
            self._spills_at_open = int(spills_total)
        self._queue_depths.append(int(queue_depth))
        self._occupancies.append(int(occupancy))
        for req in finished:
            if req.t_done and req.t_submit:
                self._latencies_ms.append((req.t_done - req.t_submit) * 1e3)
            self._wait_ticks.append(int(req.ticks_waited))
        self.registry.gauge("serve.queue_depth").set(queue_depth)
        self.registry.gauge("serve.occupancy").set(occupancy)
        if (self._ticks < self.window_ticks
                and len(self._doc_lens) < self.window_arrivals):
            return None
        return self._close_window(
            spills_total=int(spills_total),
            tick_period=tick_period,
            max_slot_wait=max_slot_wait,
            bucket_widths=bucket_widths,
            model_version=model_version,
        )

    def _close_window(self, *, spills_total: int, tick_period: float,
                      max_slot_wait: int, bucket_widths: Sequence[int],
                      model_version: int) -> Dict[str, Any]:
        inter = sorted(self._interarrivals_ms)
        waits = sorted(self._wait_ticks)
        depths = self._queue_depths
        occ = self._occupancies
        self._windows_emitted += 1
        rec: Dict[str, Any] = {
            "kind": "serve_window",
            "window": self._windows_emitted,
            "ticks": self._ticks,
            "arrivals": len(self._doc_lens),
            "finished": len(self._wait_ticks),
            "interarrival_ms": summarize_latencies(inter),
            "latency_ms": summarize_latencies(self._latencies_ms),
            "doc_len": summarize_latencies(self._doc_lens),
            "queue_depth": {
                "mean": float(np.mean(depths)) if depths else 0.0,
                "max": int(max(depths)) if depths else 0,
            },
            "occupancy": {
                "mean": float(np.mean(occ)) if occ else 0.0,
                "max": int(max(occ)) if occ else 0,
            },
            "wait_ticks_p90": (latency_percentile(waits, 0.90)
                               if waits else 0.0),
            "wait_ticks_max": int(max(waits)) if waits else 0,
            "spills": spills_total - (self._spills_at_open or 0),
            "knobs": {
                "tick_period": tick_period,
                "max_slot_wait": int(max_slot_wait),
                "buckets": [int(b) for b in bucket_widths],
            },
            "model_version": int(model_version),
        }
        self.registry.counter("serve.windows").inc()
        self.registry.emit(rec)
        self.last_window = rec
        self._reset_window()
        return rec

    # -- decision + router emitters -----------------------------------------
    def emit_decision(self, record: Dict[str, Any]) -> None:
        """Log one applied (or rejected) autopilot decision."""
        self.registry.counter("serve.decisions").inc()
        self.registry.emit(record)

    def emit_router_loads(self, loads: Sequence[int]) -> None:
        """Per-replica load snapshot (``LDARouter`` admission balance)."""
        self.registry.emit({
            "kind": "router_load",
            "loads": [int(x) for x in loads],
            "total": int(sum(loads)),
        })
