"""CompactVector (paper §5.3, Alg. 4) — run-length sparse vector storage.

Representation of a length-``size`` vector: a ``values`` array holding the
non-empty elements in order, plus an index array of (s, n) pairs where ``s``
is the starting index of an *empty* run and ``n`` is the number of non-empty
elements strictly before position ``s``. GetValue is O(log N) in the number
of runs N (<= number of nonzeros E, so never worse than SparseVector's
O(log E); smaller whenever nonzeros cluster into runs, E/N >= 2).

This is the faithful data-structure reproduction (property-tested against a
dense oracle); the TPU hot path uses fixed-shape padded-sparse rows instead
(DESIGN.md §2) — CompactVector is host-side, as in the paper (JVM).
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np


@dataclass
class CompactVector:
    size: int
    empty_starts: np.ndarray  # (N,) int — start index of each empty run
    nnz_before: np.ndarray  # (N,) int — non-empty count before that start
    values: np.ndarray  # (E,) the non-empty values in order

    @staticmethod
    def from_dense(dense: Sequence) -> "CompactVector":
        dense = np.asarray(dense)
        size = dense.shape[0]
        nz = dense != 0
        values = dense[nz]
        empty_starts: List[int] = []
        nnz_before: List[int] = []
        count = 0
        in_empty = False
        for i in range(size):
            if nz[i]:
                count += 1
                in_empty = False
            else:
                if not in_empty:
                    empty_starts.append(i)
                    nnz_before.append(count)
                    in_empty = True
        return CompactVector(
            size=size,
            empty_starts=np.asarray(empty_starts, dtype=np.int64),
            nnz_before=np.asarray(nnz_before, dtype=np.int64),
            values=values,
        )

    def get(self, x: int):
        """Paper Alg. 4 GetValue: O(log N) binary search over empty runs."""
        if not (0 <= x < self.size):
            raise IndexError(x)
        if self.empty_starts.size == 0:
            return self.values[x]
        # position of the last empty-run start <= x
        j = bisect.bisect_right(self.empty_starts.tolist(), x) - 1
        if j < 0:
            # before any empty run: x indexes values directly
            return self.values[x]
        s_j = int(self.empty_starts[j])
        n_j = int(self.nnz_before[j])
        # length of empty run j = (index of next nonzero) - s_j; x is inside
        # run j iff fewer than (x - s_j + 1) nonzeros materialized after s_j.
        # Number of nonzeros at positions < x is n_j + max(0, x - (s_j + run_len))
        # Compute run length from the next run's bookkeeping:
        if j + 1 < self.empty_starts.size:
            nnz_next = int(self.nnz_before[j + 1])
            next_start = int(self.empty_starts[j + 1])
            run_len = (next_start - s_j) - (nnz_next - n_j)
        else:
            total_nnz = int(self.values.size)
            run_len = (self.size - s_j) - (total_nnz - n_j)
        if x < s_j + run_len:
            return self.values.dtype.type(0)
        return self.values[n_j + (x - (s_j + run_len))]

    def to_dense(self) -> np.ndarray:
        return np.array([self.get(i) for i in range(self.size)])

    def nbytes(self) -> int:
        return int(
            self.empty_starts.nbytes + self.nnz_before.nbytes + self.values.nbytes
        )

    def insert(self, x: int, value) -> "CompactVector":
        """O(N + E) insert (paper: 'insertion is much costly with O(N)')."""
        dense = self.to_dense()
        dense[x] = value
        return CompactVector.from_dense(dense)
