from repro.serving.engine import ServeConfig, ServingEngine  # noqa: F401
from repro.serving.lda_engine import (  # noqa: F401
    CheckpointWatcher,
    FrozenLDAModel,
    InferRequest,
    LDAEngine,
    LDAServeConfig,
    doc_completion_perplexity,
    docs_from_corpus,
    latency_percentile,
)
from repro.serving.router import LDARouter  # noqa: F401
from repro.serving.sharded import ShardedFrozenLDAModel  # noqa: F401
