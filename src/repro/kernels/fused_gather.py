"""Fused gather+sample — kernel suite v2, kernel (a).

The first-generation sampler (``zen_sampler.py``) consumes *gathered*
``(T, K)`` word/doc count rows: the backend materializes ``n_wk[word]`` and
``n_kd[doc]`` in HBM before the kernel ever runs — at webchunk scale that is
two full token-by-topic matrices of traffic per sweep that exist only to be
streamed once. This kernel removes the materialization: the per-token
word/doc *row indices* ride in as scalar-prefetch operands
(``pltpu.PrefetchScalarGridSpec``), and each grid step's BlockSpec
``index_map`` uses them to pull the token's ``(1, bk)`` count-row tile
straight out of the resident ``N_w|k`` / ``N_k|d`` matrices — the gather
happens in the DMA engine, tile by tile, never as an HBM intermediate
(CuLDA_CGS's fused gather+sample+update, rendered for the TPU memory
system; see DESIGN.md §2.3).

Grid = (T/bt, bt, K/bk): the middle dimension walks tokens within a token
tile (one token per step, so the index map can address a single matrix
row), the innermost walks K tiles with the same running (max, argmax)
carry as the v1 kernel — now held in a ``(1, 1)`` scalar scratch per
token. Math, noise coordinates (global token id, topic id), and tie-break
order are identical to ``_zen_sampler_kernel`` term for term, so the
fused path is **bit-identical** to the v1 gather-then-sample path (and to
``ref.zen_fused_sample_ref``) — dispatch choice can never change a run.

Two variants, mirroring v1:

* ``zen_fused_sample_pallas`` — training: exact ¬dw self-exclusion on all
  three counts, one scalar seed, noise rows = global token index.
* ``zen_fused_infer_sample_pallas`` — frozen-model serving: doc-side-only
  exclusion, per-token counter-based seeds (``golden_seed``), noise rows
  pinned to 0 (DESIGN.md §5.1 layout-stability contract).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.zen_sampler import gumbel_noise
from repro.utils.compat import pallas_tpu_compiler_params


def _fused_sample_kernel(
    # scalar prefetch
    seed_ref,  # (1,) int32
    wids_ref,  # (T,) int32 — per-token word row in N_wk
    dids_ref,  # (T,) int32 — per-token doc row in N_kd
    # inputs
    nwk_ref,  # (1, bk) int32 — word row tile, DMA'd via wids[token]
    nkd_ref,  # (1, bk) int32 — doc row tile, DMA'd via dids[token]
    zold_ref,  # (bt, 1) int32 — previous assignment (¬dw exclusion)
    alpha_ref,  # (1, bk) f32 — alpha_k
    nk_ref,  # (1, bk) f32 — N_k
    # output
    out_ref,  # (bt, 1) int32 — sampled topic
    # scratch
    m_ref,  # (1, 1) f32 — running max of log p + g for this token
    a_ref,  # (1, 1) i32 — running argmax
    *,
    beta: float,
    w_beta: float,
    bt: int,
    bk: int,
):
    i = pl.program_id(0)
    t = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[0, 0] = -jnp.inf
        a_ref[0, 0] = 0

    tok = i * bt + t  # global token index — v1's noise row coordinate
    cols = j * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)

    # exact ¬dw: subtract the token's own previous assignment
    self_hit = (cols == zold_ref[t, 0]).astype(jnp.float32)
    nw = nwk_ref[...].astype(jnp.float32) - self_hit
    nd = nkd_ref[...].astype(jnp.float32) - self_hit
    nk = nk_ref[...] - self_hit
    alpha_k = alpha_ref[...]

    # three-term ZenLDA decomposition, fused (paper Alg. 5 FMAs)
    p = (alpha_k * beta + nw * alpha_k + nd * (nw + beta)) / (nk + w_beta)

    g = gumbel_noise(seed_ref[0], tok, cols)
    score = jnp.log(jnp.maximum(p, 1e-30)) + g

    tile_max = jnp.max(score)
    tile_arg = jnp.argmax(score[0]).astype(jnp.int32) + j * bk

    better = tile_max > m_ref[0, 0]
    a_ref[0, 0] = jnp.where(better, tile_arg, a_ref[0, 0])
    m_ref[0, 0] = jnp.where(better, tile_max, m_ref[0, 0])

    @pl.when(j == pl.num_programs(2) - 1)
    def _done():
        out_ref[t, 0] = a_ref[0, 0]


def zen_fused_sample_pallas(
    n_wk: jax.Array,  # (W, K) int32 — resident word-topic matrix
    n_kd: jax.Array,  # (D, K) int32 — resident doc-topic matrix
    word: jax.Array,  # (T,) int32 row ids into n_wk
    doc: jax.Array,  # (T,) int32 row ids into n_kd
    z_old: jax.Array,  # (T,) int32
    alpha_k: jax.Array,  # (K,) f32
    n_k: jax.Array,  # (K,) f32/int32
    seed: jax.Array,  # () int32 — iteration/device-folded seed
    *,
    beta: float,
    w_beta: float,
    bt: int = 256,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Sample one topic per token, gathering count rows in-register.
    T % bt == 0 and K % bk == 0 required (``ops.zen_fused_sample`` pads)."""
    t, k = word.shape[0], n_wk.shape[1]
    assert t % bt == 0 and k % bk == 0, (t, k, bt, bk)
    assert n_kd.shape[1] == k, (n_wk.shape, n_kd.shape)
    grid = (t // bt, bt, k // bk)
    kernel = functools.partial(
        _fused_sample_kernel, beta=beta, w_beta=w_beta, bt=bt, bk=bk
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bk), lambda i, t, j, s, w, d: (w[i * bt + t], j)),
                pl.BlockSpec((1, bk), lambda i, t, j, s, w, d: (d[i * bt + t], j)),
                pl.BlockSpec((bt, 1), lambda i, t, j, s, w, d: (i, 0)),
                pl.BlockSpec((1, bk), lambda i, t, j, s, w, d: (0, j)),
                pl.BlockSpec((1, bk), lambda i, t, j, s, w, d: (0, j)),
            ],
            out_specs=pl.BlockSpec((bt, 1), lambda i, t, j, s, w, d: (i, 0)),
            scratch_shapes=[
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, 1), jnp.int32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((t, 1), jnp.int32),
        interpret=interpret,
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
    )(
        jnp.asarray([seed], jnp.int32),
        word.astype(jnp.int32),
        doc.astype(jnp.int32),
        n_wk,
        n_kd,
        z_old[:, None],
        alpha_k[None, :].astype(jnp.float32),
        n_k[None, :].astype(jnp.float32),
    )
    return out[:, 0]


def _fused_infer_kernel(
    # scalar prefetch
    wids_ref,  # (T,) int32 — per-token word row in the frozen N_wk
    dids_ref,  # (T,) int32 — per-token slot row in the slot-batch N_kd
    # inputs
    nwk_ref,  # (1, bk) int32 — frozen word row tile
    nkd_ref,  # (1, bk) int32 — slot doc row tile
    zold_ref,  # (bt, 1) int32 — previous assignment (doc-side ¬t)
    seed_ref,  # (bt, 1) int32 — per-token counter-based seeds
    alpha_ref,  # (1, bk) f32 — alpha_k
    nk_ref,  # (1, bk) f32 — frozen N_k
    # output
    out_ref,  # (bt, 1) int32
    # scratch
    m_ref,  # (1, 1) f32
    a_ref,  # (1, 1) i32
    *,
    beta: float,
    w_beta: float,
    bt: int,
    bk: int,
):
    """Frozen-model serving variant: doc-side-only exclusion, per-token
    seeds with (seed, 0, topic) noise coordinates — the exact contract of
    ``_zen_infer_kernel``, minus its gathered-row inputs."""
    t = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[0, 0] = -jnp.inf
        a_ref[0, 0] = 0

    cols = j * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)

    self_hit = (cols == zold_ref[t, 0]).astype(jnp.float32)
    nw = nwk_ref[...].astype(jnp.float32)
    nd = nkd_ref[...].astype(jnp.float32) - self_hit
    alpha_k = alpha_ref[...]

    # frozen-phi conditional: (N_k|d^(¬t) + alpha_k)(N_w|k + beta)/(N_k + Wβ)
    p = (nd + alpha_k) * (nw + beta) / (nk_ref[...] + w_beta)

    g = gumbel_noise(seed_ref[t, 0], jnp.uint32(0), cols)
    score = jnp.log(jnp.maximum(p, 1e-30)) + g

    tile_max = jnp.max(score)
    tile_arg = jnp.argmax(score[0]).astype(jnp.int32) + j * bk

    better = tile_max > m_ref[0, 0]
    a_ref[0, 0] = jnp.where(better, tile_arg, a_ref[0, 0])
    m_ref[0, 0] = jnp.where(better, tile_max, m_ref[0, 0])

    @pl.when(j == pl.num_programs(2) - 1)
    def _done():
        out_ref[t, 0] = a_ref[0, 0]


def zen_fused_infer_sample_pallas(
    n_wk: jax.Array,  # (W, K) int32 frozen word-topic matrix
    n_kd: jax.Array,  # (B, K) int32 per-slot doc-topic counts
    word: jax.Array,  # (T,) int32 row ids into n_wk
    slot: jax.Array,  # (T,) int32 row ids into n_kd
    z_old: jax.Array,  # (T,) int32
    seeds: jax.Array,  # (T,) int32 per-token counter-based seeds
    alpha_k: jax.Array,  # (K,) f32
    n_k: jax.Array,  # (K,) f32/int32 frozen
    *,
    beta: float,
    w_beta: float,
    bt: int = 256,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Frozen-model Gumbel-max sample with in-register row gather.
    T % bt == 0 and K % bk == 0 required (``ops.zen_fused_infer_sample``
    pads)."""
    t, k = word.shape[0], n_wk.shape[1]
    assert t % bt == 0 and k % bk == 0, (t, k, bt, bk)
    assert n_kd.shape[1] == k, (n_wk.shape, n_kd.shape)
    grid = (t // bt, bt, k // bk)
    kernel = functools.partial(
        _fused_infer_kernel, beta=beta, w_beta=w_beta, bt=bt, bk=bk
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bk), lambda i, t, j, w, d: (w[i * bt + t], j)),
                pl.BlockSpec((1, bk), lambda i, t, j, w, d: (d[i * bt + t], j)),
                pl.BlockSpec((bt, 1), lambda i, t, j, w, d: (i, 0)),
                pl.BlockSpec((bt, 1), lambda i, t, j, w, d: (i, 0)),
                pl.BlockSpec((1, bk), lambda i, t, j, w, d: (0, j)),
                pl.BlockSpec((1, bk), lambda i, t, j, w, d: (0, j)),
            ],
            out_specs=pl.BlockSpec((bt, 1), lambda i, t, j, w, d: (i, 0)),
            scratch_shapes=[
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, 1), jnp.int32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((t, 1), jnp.int32),
        interpret=interpret,
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
    )(
        word.astype(jnp.int32),
        slot.astype(jnp.int32),
        n_wk,
        n_kd,
        z_old[:, None],
        seeds[:, None],
        alpha_k[None, :].astype(jnp.float32),
        n_k[None, :].astype(jnp.float32),
    )
    return out[:, 0]
