"""whisper-medium [audio]: enc-dec, 24L each side, d_model=1024 16H
d_ff=4096 vocab=51865, conv frontend STUB (input_specs supplies precomputed
frame embeddings). [arXiv:2212.04356; unverified]

Backbone-only per the assignment. LayerNorm + GELU (non-gated) MLPs.
Decoder decodes with self+cross KV; full attention -> long_500k skipped.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="encdec",
    encoder_decoder=True,
    num_layers=24,
    num_encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    norm_style="layernorm",
    act="gelu",
    glu=False,
    tie_embeddings=True,
    skip_shapes=("long_500k",),
)
