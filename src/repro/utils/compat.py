"""JAX version compatibility shims (the repo targets both the pinned
container jax and current releases).

* ``shard_map`` — ``jax.shard_map(..., check_vma=)`` on new jax,
  ``jax.experimental.shard_map.shard_map(..., check_rep=)`` on old.
* ``make_mesh`` — newer ``jax.make_mesh`` takes ``axis_types``; older
  versions don't have the kwarg (or ``jax.sharding.AxisType`` at all).
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """Explicit-axes mesh across jax versions."""
    shape, axes = tuple(shape), tuple(axes)
    axis_type = getattr(getattr(jax.sharding, "AxisType", None), "Auto", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(axis_type,) * len(axes))
        except TypeError:
            pass
    return jax.make_mesh(shape, axes)


def pallas_tpu_compiler_params(**kwargs):
    """jax renamed ``pltpu.TPUCompilerParams`` -> ``CompilerParams``."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams"
    )
    return cls(**kwargs)


def abstract_mesh(shape, axes):
    """Device-less mesh for spec math: newer jax takes (sizes, names),
    older takes one ((name, size), ...) shape tuple."""
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))


def shard_map(f, mesh, in_specs, out_specs, check: bool = False):
    """Per-device SPMD mapping; replication checking off by default (the
    LDA steps mix replicated and sharded outputs on purpose)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check)
