"""Pure-jnp oracles for the Pallas kernels (bit-exact where stated)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.zen_sampler import gumbel_noise


def zen_sample_ref(
    nwk_rows: jax.Array,
    nkd_rows: jax.Array,
    z_old: jax.Array,
    alpha_k: jax.Array,
    n_k: jax.Array,
    seed: jax.Array,
    *,
    beta: float,
    w_beta: float,
) -> jax.Array:
    """Bit-exact oracle of ``zen_sample_pallas`` (same hash, same math)."""
    t, k = nwk_rows.shape
    rows = jax.lax.broadcasted_iota(jnp.int32, (t, k), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, k), 1)
    self_hit = (cols == z_old[:, None]).astype(jnp.float32)
    nw = nwk_rows.astype(jnp.float32) - self_hit
    nd = nkd_rows.astype(jnp.float32) - self_hit
    nk = n_k.astype(jnp.float32)[None, :] - self_hit
    a = alpha_k.astype(jnp.float32)[None, :]
    p = (a * beta + nw * a + nd * (nw + beta)) / (nk + w_beta)
    g = gumbel_noise(jnp.asarray(seed, jnp.int32), rows, cols)
    score = jnp.log(jnp.maximum(p, 1e-30)) + g
    return jnp.argmax(score, axis=-1).astype(jnp.int32)


def zen_probs_ref(
    nwk_rows, nkd_rows, z_old, alpha_k, n_k, *, beta: float, w_beta: float
) -> jax.Array:
    """The exact ¬dw conditional the sampler draws from (for statistical
    tests: chi-square of empirical sampling frequencies)."""
    t, k = nwk_rows.shape
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, k), 1)
    self_hit = (cols == z_old[:, None]).astype(jnp.float32)
    nw = nwk_rows.astype(jnp.float32) - self_hit
    nd = nkd_rows.astype(jnp.float32) - self_hit
    nk = n_k.astype(jnp.float32)[None, :] - self_hit
    a = alpha_k.astype(jnp.float32)[None, :]
    p = (a * beta + nw * a + nd * (nw + beta)) / (nk + w_beta)
    return p / jnp.sum(p, axis=-1, keepdims=True)


def zen_infer_sample_ref(
    nwk_rows: jax.Array,
    nkd_rows: jax.Array,
    z_old: jax.Array,
    seeds: jax.Array,
    alpha_k: jax.Array,
    n_k: jax.Array,
    *,
    beta: float,
    w_beta: float,
) -> jax.Array:
    """Bit-exact oracle of ``zen_infer_sample_pallas`` (frozen-model
    serving variant): doc-side-only exclusion, frozen word/topic totals,
    per-token seeds with (seed, topic) noise coordinates."""
    t, k = nwk_rows.shape
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, k), 1)
    self_hit = (cols == z_old[:, None]).astype(jnp.float32)
    nw = nwk_rows.astype(jnp.float32)
    nd = nkd_rows.astype(jnp.float32) - self_hit
    a = alpha_k.astype(jnp.float32)[None, :]
    p = (nd + a) * (nw + beta) / (n_k.astype(jnp.float32)[None, :] + w_beta)
    g = gumbel_noise(
        seeds.astype(jnp.int32)[:, None], jnp.zeros((t, 1), jnp.uint32), cols
    )
    score = jnp.log(jnp.maximum(p, 1e-30)) + g
    return jnp.argmax(score, axis=-1).astype(jnp.int32)


def topic_histogram_ref(
    rows: jax.Array,
    z_old: jax.Array,
    z_new: jax.Array,
    inc: jax.Array,
    num_rows: int,
    num_topics: int,
) -> jax.Array:
    """Naive scatter-add oracle of ``topic_histogram_pallas``."""
    out = jnp.zeros((num_rows, num_topics), jnp.int32)
    out = out.at[rows, z_new].add(inc)
    out = out.at[rows, z_old].add(-inc)
    return out
