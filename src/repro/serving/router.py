"""Multi-engine serving router: N :class:`~repro.serving.lda_engine.LDAEngine`
replicas behind one ticket namespace (DESIGN.md §5.4).

Sharding (``LDAServeConfig.mesh_shape``) scales a *single* decode across
devices; the router scales *throughput* across independent replicas — the
two compose: each replica may itself be a sharded engine. The router owns

* **load-aware admission** — every submit goes to the replica with the
  least queued + in-flight work (``LDAEngine.load``), ties broken by
  replica order so routing is deterministic under equal load;
* **one ticket namespace** — router tickets are engine-agnostic ints;
  callers never learn which replica decodes them, and the full ticket
  lifecycle (``poll``/``result``/``cancel``/``request``) delegates to the
  owning replica;
* **broadcast reload** — :meth:`reload` pushes a new model to every
  replica under one version tag, so ``model_version`` is coherent across
  the fleet and the per-engine reload invariants (in-flight requests
  finish on their admitted version, nothing dropped) hold per replica.

Statistical note: replicas are constructed with distinct engine seeds, so
auto-derived request keys differ across replicas — two submits of the
same document may land on different replicas and draw different chains
(same distribution). Callers that need bit-reproducible routing-
independent results pass explicit per-request ``key``\\ s, exactly as with
a single engine (the parity property ``tests/test_sharded_serving.py``
pins).
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.lda_engine import (
    CheckpointWatcher,
    FrozenLDAModel,
    InferRequest,
    LDAEngine,
    LDAServeConfig,
)


class LDARouter:
    """N engine replicas, one serving front (same call surface as
    :class:`LDAEngine`'s async API, plus the blocking ``infer_batch``)."""

    def __init__(self, model: FrozenLDAModel, cfg: LDAServeConfig,
                 replicas: int = 1, seed: int = 0):
        if replicas < 1:
            raise ValueError(f"need at least one replica, got {replicas}")
        # distinct seeds: auto-derived request keys must differ between
        # replicas, or co-submitted identical docs would draw identical
        # chains and the fleet would under-sample the posterior
        self.engines: List[LDAEngine] = [
            LDAEngine(model, cfg, seed=seed + 1000 * i)
            for i in range(replicas)
        ]
        self.cfg = cfg
        self._lock = threading.RLock()
        self._tickets: Dict[int, Tuple[LDAEngine, int]] = {}
        self._next_ticket = 0
        self._watcher: Optional[CheckpointWatcher] = None
        # per-replica load records ride on replica 0's telemetry sink
        # (all replicas share one cfg, so one JSONL per fleet, not N);
        # None when observability is off — zero work on the submit path
        self._fleet_telemetry = self.engines[0]._telemetry
        self._load_emit_every = max(1, (cfg.autopilot_window or 64) // 2)
        self._submits = 0

    # -- fleet state -------------------------------------------------------
    @property
    def num_replicas(self) -> int:
        return len(self.engines)

    @property
    def model(self):
        """The model new admissions decode under (coherent across the
        fleet after any :meth:`reload`)."""
        return self.engines[0].model

    @property
    def model_version(self) -> int:
        return self.engines[0].model_version

    @property
    def docs_done(self) -> int:
        return sum(e.docs_done for e in self.engines)

    @property
    def sweeps_run(self) -> int:
        return sum(e.sweeps_run for e in self.engines)

    @property
    def loads(self) -> List[int]:
        """Per-replica queued + in-flight counts (admission snapshot)."""
        return [e.load for e in self.engines]

    def _least_loaded(self) -> LDAEngine:
        return min(self.engines, key=lambda e: e.load)

    # -- ticket lifecycle --------------------------------------------------
    def submit_async(self, words, **submit_kw) -> int:
        """Queue one document on the least-loaded replica; returns a
        router ticket (fleet-unique, engine-agnostic)."""
        with self._lock:
            engine = self._least_loaded()
            inner = engine.submit_async(words, **submit_kw)
            self._next_ticket += 1
            self._tickets[self._next_ticket] = (engine, inner)
            if self._fleet_telemetry is not None:
                self._submits += 1
                if self._submits % self._load_emit_every == 0:
                    self._fleet_telemetry.emit_router_loads(self.loads)
            return self._next_ticket

    def _route(self, ticket: int) -> Tuple[LDAEngine, int]:
        entry = self._tickets.get(ticket)
        if entry is None:
            raise KeyError(f"unknown or reaped router ticket {ticket}")
        return entry

    def poll(self, ticket: int) -> str:
        with self._lock:
            engine, inner = self._route(ticket)
        return engine.poll(inner)

    def result(self, ticket: int, timeout: Optional[float] = None
               ) -> np.ndarray:
        """Block on the owning replica's result; reaps the router ticket
        on success (a ``TimeoutError`` leaves it claimable, same contract
        as :meth:`LDAEngine.result`)."""
        with self._lock:
            engine, inner = self._route(ticket)
        theta = engine.result(inner, timeout=timeout)
        with self._lock:
            self._tickets.pop(ticket, None)
        return theta

    def cancel(self, ticket: int) -> bool:
        with self._lock:
            entry = self._tickets.pop(ticket, None)
        if entry is None:
            return False
        engine, inner = entry
        return engine.cancel(inner)

    def request(self, ticket: int) -> InferRequest:
        with self._lock:
            engine, inner = self._route(ticket)
        return engine.request(inner)

    def infer_batch(self, docs: Sequence, **submit_kw) -> np.ndarray:
        """Submit many documents across the fleet, return (N, K) thetas
        in submission order. Without background tickers each ``result``
        drives its owning replica's ticks itself."""
        tickets = [self.submit_async(d, **submit_kw) for d in docs]
        return np.stack([self.result(t) for t in tickets])

    # -- fleet control -----------------------------------------------------
    def reload(self, model: FrozenLDAModel,
               version: Optional[int] = None) -> int:
        """Broadcast a hot reload to every replica under one version tag.

        Each replica applies its own atomic swap (in-flight requests
        finish on the version their bucket pinned); the shared tag keeps
        ``model_version`` coherent fleet-wide even if replicas were
        constructed at different versions.
        """
        with self._lock:
            target = (max(e.model_version for e in self.engines) + 1
                      if version is None else int(version))
            for engine in self.engines:
                engine.reload(model, version=target)
            return target

    def start(self, tick_period: Optional[float] = None) -> None:
        for engine in self.engines:
            engine.start(tick_period)

    def stop(self) -> None:
        for engine in self.engines:
            engine.stop()

    def warm(self) -> None:
        """Compile every replica's bucket programs before traffic.
        Replicas of one router share jitted programs only through jax's
        global compilation cache — warming all of them is still the
        cheap, predictable option."""
        for engine in self.engines:
            engine.warm()

    def watch_checkpoint_dir(
        self,
        directory: str,
        period: float = 1.0,
        initial_step: Optional[int] = None,
        max_failures: int = 8,
    ) -> None:
        """One :class:`CheckpointWatcher` for the whole fleet: every new
        committed step broadcasts through :meth:`reload` (same failure
        policy as the engine's watcher)."""
        with self._lock:
            if self._watcher is not None and self._watcher.is_alive():
                return
            self._watcher = CheckpointWatcher(
                self.reload, directory, period=period,
                initial_step=initial_step, max_failures=max_failures,
            ).start()

    @property
    def watch_error(self) -> Optional[Exception]:
        watcher = self._watcher
        return None if watcher is None else watcher.error

    def stop_watching(self) -> Optional[Exception]:
        watcher = self._watcher
        if watcher is None:
            return None
        err = watcher.stop()
        self._watcher = None
        return err
