"""Single-box LDA trainer: registry-resolved algorithm + optimization
toggles.

This is the "driver program" layer (paper §2.3): resolve a sampling backend
by name through ``repro.algorithms`` (``algorithms.registered()`` lists
them — zen / zen_sparse / zen_hybrid / sparselda / lightlda / std plus the
distributed-native zen_cdf and the fused-kernel zen_pallas), pick the
initialization, toggle token exclusion / delta aggregation, and iterate.
The distributed path (``repro.core.distributed``) resolves the *same*
registry entries for its ``shard_map`` cell step.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro import algorithms
from repro.algorithms import SamplerKnobs
from repro.core import counts as counts_lib
from repro.core import init as init_lib
from repro.core.exclusion import ExclusionConfig, active_mask, update_exclusion_stats
from repro.core.likelihood import joint_llh, perplexity, predictive_llh
from repro.core.types import CGSState, Corpus, LDAHyperParams


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    algorithm: str = "zen"  # any algorithms.registered() name
    init: str = "random"  # random | sparse_word | sparse_doc
    sparse_init_degree: float = 0.1
    sampling_method: str = "cdf"  # cdf | gumbel  (dense paths)
    exclusion: ExclusionConfig = ExclusionConfig()
    max_kw: int = 0  # 0 -> auto from data (padded-sparse paths)
    max_kd: int = 0
    num_mh: int = 8  # LightLDA MH steps (paper uses 8)
    token_chunk: int = 0  # 0 = whole sweep at once (memory knob)
    bt: int = 256  # zen_pallas token tile
    bk: int = 512  # zen_pallas topic tile
    # model checkpointing (the serving handoff): save N_wk/N_k + hyper to
    # this directory every checkpoint_every iterations (0 = final only)
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 0

    def knobs(self) -> SamplerKnobs:
        """The shared backend knob dataclass (same one DistConfig builds)."""
        return SamplerKnobs(
            sampling_method=self.sampling_method,
            max_kw=self.max_kw,
            max_kd=self.max_kd,
            num_mh=self.num_mh,
            token_chunk=self.token_chunk or 0,  # tolerate legacy None
            bt=self.bt,
            bk=self.bk,
        )


class LDATrainer:
    def __init__(self, corpus: Corpus, hyper: LDAHyperParams, cfg: TrainConfig):
        self.corpus = corpus
        self.hyper = hyper
        self.cfg = cfg
        self.backend = algorithms.get(cfg.algorithm)
        self._knobs = cfg.knobs()
        self._aux = self.backend.prepare(corpus, hyper, self._knobs)

    # -- initialization ----------------------------------------------------
    def init_state(self, rng: jax.Array) -> CGSState:
        c, h = self.corpus, self.hyper
        if self.cfg.init == "random":
            return init_lib.random_init(rng, c, h)
        if self.cfg.init == "sparse_word":
            return init_lib.sparse_word_init(rng, c, h, self.cfg.sparse_init_degree)
        if self.cfg.init == "sparse_doc":
            return init_lib.sparse_doc_init(rng, c, h, self.cfg.sparse_init_degree)
        raise ValueError(self.cfg.init)

    # -- one iteration -----------------------------------------------------
    def sweep(self, state: CGSState) -> jax.Array:
        knobs = self._knobs
        if self.backend.needs_row_pads:
            # host-side auto pads from the current counts (0 = auto)
            knobs = algorithms.resolve_row_pads(state, knobs)
        return self.backend.sweep(
            state, self.corpus, self.hyper, knobs, self._aux
        )

    def step(self, state: CGSState) -> CGSState:
        c, h, cfg = self.corpus, self.hyper, self.cfg
        key = jax.random.fold_in(state.rng, 2**20 + state.iteration)
        mask = active_mask(state, cfg.exclusion, key)
        z_new_all = self.sweep(state)
        z_new = jnp.where(mask, z_new_all, state.topic)
        d_wk, d_kd, d_k = counts_lib.delta_counts(
            c.word, c.doc, state.topic, z_new, c.num_words, c.num_docs,
            h.num_topics,
        )
        i_new, t_new = update_exclusion_stats(state, z_new, mask)
        return CGSState(
            topic=z_new,
            prev_topic=state.topic,
            n_wk=state.n_wk + d_wk,
            n_kd=state.n_kd + d_kd,
            n_k=state.n_k + d_k,
            rng=state.rng,
            iteration=state.iteration + 1,
            stale_iters=i_new,
            same_count=t_new,
        )

    # -- metrics -----------------------------------------------------------
    def llh(self, state: CGSState) -> float:
        return float(predictive_llh(state, self.corpus, self.hyper,
                                     token_chunk=self._knobs.chunk_or_none()))

    def llh_split(self, state: CGSState):
        return joint_llh(state, self.corpus, self.hyper)

    def perplexity(self, state: CGSState) -> float:
        return float(perplexity(state, self.corpus, self.hyper,
                                 token_chunk=self._knobs.chunk_or_none()))

    def change_rate(self, state: CGSState) -> float:
        """Fraction of tokens whose topic changed last iteration (Fig. 9a)."""
        return float(jnp.mean((state.topic != state.prev_topic).astype(jnp.float32)))

    # -- model checkpointing (serving handoff) ------------------------------
    def save_model(self, state: CGSState, directory: Optional[str] = None) -> str:
        """Checkpoint the trained model (N_wk/N_k + hyper) for serving.

        ``launch/serve_lda.py`` / ``FrozenLDAModel.from_checkpoint`` load
        exactly this artifact.
        """
        from repro.train.checkpoint import save_lda_model

        directory = directory or self.cfg.checkpoint_dir
        if not directory:
            raise ValueError("no checkpoint directory configured")
        return save_lda_model(
            directory, state.n_wk, state.n_k, self.hyper,
            step=int(state.iteration),
            extra_metadata={"algorithm": self.cfg.algorithm},
        )

    # -- training loop with flexible termination (§4.3 utilities) ----------
    def train(
        self,
        rng: jax.Array,
        num_iterations: int,
        state: Optional[CGSState] = None,  # incremental training entry
        llh_every: int = 0,
        callback: Optional[Callable[[CGSState, dict], None]] = None,
        target_perplexity: Optional[float] = None,
    ) -> CGSState:
        if state is None:
            state = self.init_state(rng)
        ckpt_dir, ckpt_every = self.cfg.checkpoint_dir, self.cfg.checkpoint_every
        last_saved = -1
        for it in range(num_iterations):
            state = self.step(state)
            metrics = {}
            if llh_every and (it + 1) % llh_every == 0:
                metrics["llh"] = self.llh(state)
                metrics["change_rate"] = self.change_rate(state)
            if callback is not None:
                callback(state, metrics)
            if ckpt_dir and ckpt_every and (it + 1) % ckpt_every == 0:
                self.save_model(state)
                last_saved = int(state.iteration)
            if target_perplexity is not None and llh_every and metrics:
                if self.perplexity(state) <= target_perplexity:
                    break
        if ckpt_dir and int(state.iteration) != last_saved:
            self.save_model(state)
        return state
