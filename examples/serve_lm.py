"""Serve a small LM with batched requests + RT-LDA topic inference side by
side (the paper's online-inference story, §4.3).

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import LDAHyperParams, LDATrainer, TrainConfig
from repro.core.inference import rtlda_infer
from repro.data import synthetic_lda_corpus
from repro.models.model import init_params
from repro.serving import ServeConfig, ServingEngine


def serve_lm():
    import dataclasses

    cfg = dataclasses.replace(get_config("qwen2-vl-2b-smoke"), num_layers=2)
    params = init_params(jax.random.key(0), cfg)
    engine = ServingEngine(params, cfg, ServeConfig(max_batch=4, max_len=64))
    prompts = [[1, 2, 3], [9, 8], [100, 50, 25, 12], [7]]
    t0 = time.time()
    for p in prompts:
        engine.submit(p, max_new=8)
    done = engine.run_until_done()
    dt = time.time() - t0
    print(f"LM serving: {len(done)} requests, "
          f"{sum(len(r.out) for r in done)} tokens in {dt:.2f}s")
    for r in sorted(done, key=lambda r: r.uid):
        print(f"  req {r.uid}: prompt {r.prompt} -> {r.out}")


def serve_rtlda():
    corpus, _ = synthetic_lda_corpus(0, num_docs=150, num_words=200,
                                     num_topics=8, avg_doc_len=40)
    hyper = LDAHyperParams(num_topics=8, alpha=0.1, beta=0.01)
    tr = LDATrainer(corpus, hyper, TrainConfig(algorithm="zen"))
    st = tr.init_state(jax.random.key(0))
    for _ in range(20):
        st = tr.step(st)
    # millisecond-scale inference for "queries" (new docs)
    infer = jax.jit(lambda words: rtlda_infer(st.n_wk, st.n_k, words, hyper))
    query = jnp.asarray(np.random.default_rng(1).integers(0, 200, 12),
                        jnp.int32)
    theta = infer(query)  # compile
    t0 = time.time()
    for _ in range(50):
        theta = infer(query)
    jax.block_until_ready(theta)
    dt = (time.time() - t0) / 50
    print(f"RT-LDA inference: {dt*1e3:.2f} ms/query, "
          f"theta argmax topic {int(jnp.argmax(theta))}")


if __name__ == "__main__":
    serve_lm()
    serve_rtlda()
