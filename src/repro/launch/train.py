"""LDA training driver (launch-level CLI) — any registered sampler backend.

The algorithm name resolves through the ``repro.algorithms`` registry.
Every backend with ``supports_shard_map`` runs the distributed mesh path —
the dense paths (zen_cdf, zen_dense, zen_pallas) *and* the padded-sparse
ones (zen_sparse, zen_hybrid, sparselda, lightlda); only backends without
a cell sweep (std) fall back to the single-box trainer. On a real TPU
slice the mesh path runs under `jax.distributed`; on CPU hosts pass
--host-devices to simulate N devices.

    PYTHONPATH=src python -m repro.launch.train \
        --rows 2 --cols 2 --host-devices 4 --iters 50 \
        [--corpus path.libsvm] [--ckpt DIR] [--algorithm <registered-name>]
        [--delta-dtype int16] [--exclusion-start 30]
    PYTHONPATH=src python -m repro.launch.train --list-algorithms

``--checkpoint-dir`` writes *model* checkpoints (N_wk/N_k + hyper) on both
paths — the artifact ``launch/serve_lda.py`` serves from. (``--ckpt`` on
the mesh path remains the elastic *training* checkpoint: assignments only.)
"""
import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=2, help="data-parallel rows")
    ap.add_argument("--cols", type=int, default=2, help="model-parallel cols")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="simulate N host devices (CPU bring-up)")
    ap.add_argument("--corpus", default=None, help="libsvm corpus path")
    ap.add_argument("--topics", type=int, default=64)
    ap.add_argument("--iters", type=int, default=50)
    ap.add_argument("--algorithm", default="zen_cdf",
                    help="any name from --list-algorithms")
    ap.add_argument("--list-algorithms", action="store_true",
                    help="print the registered sampler backends and exit")
    ap.add_argument("--single-box", action="store_true",
                    help="force the single-box trainer path")
    ap.add_argument("--max-kd", type=int, default=None,
                    help="sparse doc-row width (default: auto — resolved "
                         "from the sharded counts on the mesh path, from "
                         "the state on the single-box path)")
    ap.add_argument("--max-kw", type=int, default=None,
                    help="sparse word-row width (padded-sparse backends; "
                         "default: auto, like --max-kd)")
    ap.add_argument("--delta-dtype", default="int32",
                    choices=["int32", "int16", "int8"])
    ap.add_argument("--exclusion-start", type=int, default=0)
    ap.add_argument("--ckpt", default=None,
                    help="mesh-path training checkpoints (assignments)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="model checkpoints (N_wk/N_k + hyper) for serving")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="model-checkpoint cadence (0 = final only)")
    ap.add_argument("--llh-every", type=int, default=10)
    ap.add_argument("--synthetic-docs", type=int, default=1000,
                    help="synthetic corpus size (when --corpus is not given)")
    ap.add_argument("--synthetic-words", type=int, default=2000)
    ap.add_argument("--synthetic-len", type=int, default=80)
    args = ap.parse_args()

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices} "
            + os.environ.get("XLA_FLAGS", "")
        )

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import algorithms

    if args.list_algorithms:
        for name, backend, aliases in algorithms.describe():
            mesh = "mesh+single-box" if backend.supports_shard_map \
                else "single-box"
            alias_s = f" (aliases: {', '.join(aliases)})" if aliases else ""
            print(f"{name:12s} {mesh}{alias_s}")
        return

    backend = algorithms.get(args.algorithm)  # one registry resolution

    from repro.core.types import LDAHyperParams
    from repro.data import load_libsvm, synthetic_corpus

    if args.corpus:
        corpus = load_libsvm(args.corpus)
    else:
        corpus = synthetic_corpus(0, num_docs=args.synthetic_docs,
                                  num_words=args.synthetic_words,
                                  avg_doc_len=args.synthetic_len, zipf_a=1.2)
    hyper = LDAHyperParams(num_topics=args.topics)

    if args.single_box or not backend.supports_shard_map:
        # single-box round trip: same registry entry, LDATrainer driver
        from repro.core import LDATrainer, TrainConfig
        from repro.core.exclusion import ExclusionConfig

        if not backend.supports_shard_map and not args.single_box:
            print(f"note: backend {args.algorithm!r} has no shard_map cell "
                  f"sweep; running the single-box trainer")
        ignored = [flag for flag, default, val in (
            ("--ckpt", None, args.ckpt),
            ("--delta-dtype", "int32", args.delta_dtype),
            ("--rows/--cols", (2, 2), (args.rows, args.cols)),
        ) if val != default]
        if ignored:
            print(f"note: single-box path ignores {', '.join(ignored)}")
        excl = ExclusionConfig(enabled=args.exclusion_start > 0,
                               start_iteration=args.exclusion_start)
        tr = LDATrainer(corpus, hyper, TrainConfig(
            algorithm=args.algorithm,
            max_kd=args.max_kd or 0,  # 0 = auto-size from the counts
            max_kw=args.max_kw or 0,
            exclusion=excl,
            checkpoint_dir=args.checkpoint_dir,
            checkpoint_every=args.checkpoint_every,
        ))
        print(f"single-box  algorithm={args.algorithm}  "
              f"tokens={corpus.num_tokens}")

        def cb(state, metrics):
            if metrics:
                print(f"iter {int(state.iteration):4d}  "
                      f"llh {metrics['llh']:.1f}  "
                      f"change {metrics['change_rate']:.3f}")

        final = tr.train(jax.random.key(0), args.iters,
                         llh_every=args.llh_every, callback=cb)
        print(f"finished at iteration {int(final.iteration)}; "
              f"final llh {tr.llh(final):.1f}")
        if args.checkpoint_dir:
            print(f"model checkpoint: {args.checkpoint_dir} "
                  f"(serve with: python -m repro.launch.serve_lda "
                  f"--checkpoint-dir {args.checkpoint_dir})")
        return

    from repro.core.distributed import (
        DistConfig,
        init_dist_state,
        make_dist_llh,
        make_dist_step,
        make_rebuild_counts,
        resolve_dist_row_pads,
    )
    from repro.core.graph import grid_partition
    from repro.launch.mesh import make_mesh
    from repro.train.checkpoint import CheckpointManager
    from repro.train.loop import LoopConfig, TrainLoop

    mesh = make_mesh((args.rows, args.cols), ("data", "model"))
    grid = grid_partition(corpus, args.rows, args.cols)
    print(f"mesh {args.rows}x{args.cols}  tokens={int(grid.mask.sum())}  "
          f"pad={grid.padding_overhead:.2%}")
    dcfg = DistConfig(
        algorithm=args.algorithm,
        max_kd=args.max_kd or 0,  # 0 = auto (resolved below / by backend)
        max_kw=args.max_kw or 0,
        delta_dtype=args.delta_dtype, exclusion_start=args.exclusion_start,
    )
    state, data = init_dist_state(jax.random.key(0), mesh, grid, hyper)
    # shard-relative padded-row capacities for the sparse backends: fill
    # auto widths from the sharded init counts (per-shard maxima, not a
    # global gather), so the cell workspaces are sized to the data
    dcfg = resolve_dist_row_pads(state, dcfg)
    if backend.needs_row_pads:
        print(f"padded-row widths: max_kw={dcfg.max_kw} max_kd={dcfg.max_kd}")
    step = make_dist_step(mesh, hyper, dcfg, grid.words_per_shard,
                          grid.docs_per_shard)
    llh = make_dist_llh(mesh, hyper, grid.words_per_shard,
                        grid.docs_per_shard)

    def loop_step(state):
        state = step(state, data)
        metrics = {}
        it = int(state.iteration)
        if args.llh_every and it % args.llh_every == 0:
            metrics["llh"] = float(llh(state, data))
        return state, metrics

    # checkpoint = assignments only (counts rebuild on restore; elastic)
    rebuild = make_rebuild_counts(mesh, hyper, grid.words_per_shard,
                                  grid.docs_per_shard)

    def restore(state, tree):
        state = state._replace(
            topic=jax.device_put(tree["topic"], state.topic.sharding),
            iteration=jnp.asarray(tree["iteration"]),
        )
        return rebuild(state, data)

    loop = TrainLoop(
        loop_step,
        LoopConfig(num_steps=args.iters, checkpoint_every=25,
                   checkpoint_dir=args.ckpt, log_every=args.llh_every),
        checkpoint_tree_fn=lambda s: {
            "topic": s.topic, "iteration": s.iteration,
        },
        restore_fn=restore if args.ckpt else None,
    )
    import logging

    logging.basicConfig(level=logging.INFO)
    final = loop.run(state)
    print(f"finished at iteration {int(final.iteration)}; "
          f"final llh {float(llh(final, data)):.1f}")
    if args.checkpoint_dir:
        # gather the (padded) sharded model and map the grid's relabeled
        # word ids back to the corpus vocabulary
        from repro.train.checkpoint import save_lda_model

        n_wk_grid = np.asarray(jax.device_get(final.n_wk))
        n_wk = n_wk_grid[grid.word_perm]  # (W, K) in original word ids
        n_k = np.asarray(jax.device_get(final.n_k))
        path = save_lda_model(
            args.checkpoint_dir, n_wk, n_k, hyper,
            step=int(final.iteration),
            extra_metadata={"algorithm": args.algorithm,
                            "mesh": [args.rows, args.cols]},
        )
        print(f"model checkpoint: {path}")


if __name__ == "__main__":
    main()
