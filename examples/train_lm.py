"""Train an LM from the zoo on synthetic data with the fault-tolerant loop.

    PYTHONPATH=src python examples/train_lm.py --arch qwen3-8b-smoke \
        [--steps 100] [--ckpt /tmp/lm_ckpt]

Any of the 10 assigned architectures works with ``--arch <id>-smoke``
(reduced widths; the full configs need the TPU mesh).
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.optimizer import OptConfig
from repro.train.train_step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b-smoke")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    opt = OptConfig(learning_rate=1e-3)
    state = init_train_state(jax.random.key(0), cfg, opt)
    step = jax.jit(make_train_step(cfg, opt))

    rng = np.random.default_rng(0)

    def make_batch():
        # synthetic LM data: structured Markov-ish tokens (learnable)
        base = rng.integers(0, cfg.vocab_size // 4, (args.batch, args.seq))
        tokens = (base + np.arange(args.seq)[None, :] % 7).astype(np.int32)
        b = {
            "tokens": jnp.asarray(tokens) % cfg.vocab_size,
            "labels": jnp.asarray(np.roll(tokens, -1, 1)) % cfg.vocab_size,
        }
        if cfg.family == "encdec":
            b["enc_embeds"] = jnp.asarray(
                rng.normal(size=(args.batch, args.seq, cfg.d_model)),
                cfg.dtype,
            )
        return b

    def loop_step(state):
        state, metrics = step(state, make_batch())
        return state, {"loss": float(metrics["loss"])}

    loop = TrainLoop(
        loop_step,
        LoopConfig(num_steps=args.steps, checkpoint_every=25,
                   checkpoint_dir=args.ckpt, log_every=10),
        checkpoint_tree_fn=lambda s: {"params": s.params, "step": s.step},
        restore_fn=(lambda s, tree: s._replace(params=tree["params"],
                                               step=tree["step"]))
        if args.ckpt else None,
    )
    import logging

    logging.basicConfig(level=logging.INFO)
    final = loop.run(state)
    print(f"finished at step {int(final.step)}")


if __name__ == "__main__":
    main()
