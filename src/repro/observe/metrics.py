"""Counter/gauge/histogram registry, span timers, and the JSONL sink.

This is deliberately a micrometrics library, not a client for an external
metrics system: everything is in-process, numpy-cheap, and serializable
as one JSON object per line so a run's telemetry is a file you can grep.

JSONL schema (DESIGN.md §8.2): every record is one flat JSON object with

* ``t``    — wall-clock seconds (``time.time()``; ordering within one
  producer additionally follows the monotonic clock used for all
  *durations*),
* ``kind`` — the record type (``train_iter`` | ``serve_window`` |
  ``router_load`` | ``decision`` | ``span`` | ``snapshot``),
* kind-specific payload fields (see the emitters in
  ``repro.observe.train_hooks`` / ``repro.observe.serve_hooks`` and the
  decision records in ``repro.autotune.policy``).

Percentile math: ``latency_percentile`` is THE nearest-rank definition
used across the repo (``launch/serve_lda.py``, ``benchmarks/bench_infer.py``
and the serving engine re-export it) and ``summarize_latencies`` is the
one shared p50/p99/max/mean summary they all report — factored here so
every latency figure in the repo is computed identically.
"""
from __future__ import annotations

import dataclasses
import json
import math
import threading
import time
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# shared latency math
# ---------------------------------------------------------------------------

def latency_percentile(sorted_vals: Sequence[float], q: float) -> float:
    """Nearest-rank percentile of an ASCENDING sample.

    THE percentile definition for latency reporting — every p50/p99
    figure in the repo comes through here, so numbers from the serving
    CLI, the benchmarks, and the telemetry windows are comparable.
    Returns NaN on empty input.
    """
    if not sorted_vals:
        return float("nan")
    i = min(len(sorted_vals) - 1, int(round(q * (len(sorted_vals) - 1))))
    return sorted_vals[i]


def summarize_latencies(latencies: Iterable[float]) -> Dict[str, float]:
    """The one shared latency summary: ``{count, p50, p99, max, mean}``.

    Accepts any iterable of numbers in any order (callers pass
    milliseconds by convention); sorts once and applies the nearest-rank
    ``latency_percentile``. Empty input yields ``count=0`` and NaN
    statistics; a single element is its own p50/p99/max/mean — the edge
    cases ``tests/test_observe.py`` pins with known answers.
    """
    vals = sorted(float(v) for v in latencies)
    if not vals:
        nan = float("nan")
        return {"count": 0, "p50": nan, "p99": nan, "max": nan, "mean": nan}
    return {
        "count": len(vals),
        "p50": latency_percentile(vals, 0.50),
        "p99": latency_percentile(vals, 0.99),
        "max": vals[-1],
        "mean": float(sum(vals) / len(vals)),
    }


def nnz_row_stats(counts: np.ndarray) -> Dict[str, float]:
    """Row-sparsity summary of a (R, K) count matrix: per-row nnz
    mean/p50/p99/max plus K — the measured form of the paper's
    ``K_w``/``K_d`` quantities the hybrid decomposition argument (§3.2)
    and the autopilot's backend re-pick run on."""
    counts = np.asarray(counts)
    nnz = np.count_nonzero(counts > 0, axis=-1)
    if nnz.size == 0:
        nan = float("nan")
        return {"mean": nan, "p50": nan, "p99": nan, "max": 0,
                "num_topics": int(counts.shape[-1])}
    return {
        "mean": float(nnz.mean()),
        "p50": float(np.percentile(nnz, 50)),
        "p99": float(np.percentile(nnz, 99)),
        "max": int(nnz.max()),
        "num_topics": int(counts.shape[-1]),
    }


# ---------------------------------------------------------------------------
# metric primitives
# ---------------------------------------------------------------------------

class Counter:
    """Monotonically increasing count (events, spills, decisions)."""

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def snapshot(self) -> Dict[str, Any]:
        return {"kind": "counter", "name": self.name, "value": self.value}


class Gauge:
    """Last-written value (queue depth, row pads, tick period)."""

    def __init__(self, name: str):
        self.name = name
        self.value: Optional[float] = None

    def set(self, v: float) -> None:
        self.value = v

    def snapshot(self) -> Dict[str, Any]:
        return {"kind": "gauge", "name": self.name, "value": self.value}


class Histogram:
    """Fixed-bound histogram plus running count/sum/min/max.

    ``bounds`` are the inclusive upper edges of each bucket; values above
    the last bound land in a final overflow bucket, so ``counts`` has
    ``len(bounds) + 1`` entries. ``observe_array`` bulk-bins a numpy
    array (the row-nnz path) without a Python loop.
    """

    def __init__(self, name: str, bounds: Sequence[float]):
        if not bounds or list(bounds) != sorted(bounds):
            raise ValueError(f"histogram {name!r}: bounds must be "
                             f"non-empty ascending, got {bounds!r}")
        self.name = name
        self.bounds: Tuple[float, ...] = tuple(float(b) for b in bounds)
        self.counts = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def observe(self, v: float, n: int = 1) -> None:
        i = int(np.searchsorted(self.bounds, v, side="left"))
        self.counts[i] += n
        self.count += n
        self.sum += v * n
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)

    def observe_array(self, arr: np.ndarray) -> None:
        arr = np.asarray(arr).ravel()
        if arr.size == 0:
            return
        idx = np.searchsorted(self.bounds, arr, side="left")
        binned = np.bincount(idx, minlength=len(self.counts))
        for i, n in enumerate(binned):
            self.counts[i] += int(n)
        self.count += int(arr.size)
        self.sum += float(arr.sum())
        lo, hi = float(arr.min()), float(arr.max())
        self.min = lo if self.min is None else min(self.min, lo)
        self.max = hi if self.max is None else max(self.max, hi)

    def snapshot(self) -> Dict[str, Any]:
        return {
            "kind": "histogram", "name": self.name,
            "bounds": list(self.bounds), "counts": list(self.counts),
            "count": self.count, "sum": self.sum,
            "min": self.min, "max": self.max,
        }


class SpanTimer:
    """Monotonic-clock span: ``with registry.timer("jit_rebuild"): ...``
    records the wall duration (seconds) into a histogram and, when the
    registry has a sink, emits one ``kind="span"`` record per exit."""

    def __init__(self, hist: Histogram, emit=None):
        self._hist = hist
        self._emit = emit
        self._t0: Optional[float] = None
        self.last: Optional[float] = None

    def __enter__(self) -> "SpanTimer":
        self._t0 = time.monotonic()
        return self

    def __exit__(self, *exc) -> None:
        self.last = time.monotonic() - self._t0
        self._hist.observe(self.last)
        if self._emit is not None:
            self._emit({"kind": "span", "name": self._hist.name,
                        "seconds": self.last})


# default span-duration bounds: 100us .. ~2min, roughly x4 apart
_SPAN_BOUNDS = (1e-4, 4e-4, 1.6e-3, 6.4e-3, 2.56e-2, 0.1, 0.4, 1.6, 6.4,
                25.6, 102.4)


class MetricsRegistry:
    """Name-unique metric store + optional sink. Thread-safe: the engine
    and its background ticker share one registry."""

    def __init__(self, sink: Optional["JsonlSink"] = None):
        self.sink = sink
        self._lock = threading.Lock()
        self._metrics: Dict[str, Any] = {}

    def _get(self, name: str, factory):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = factory()
            return m

    def counter(self, name: str) -> Counter:
        m = self._get(name, lambda: Counter(name))
        if not isinstance(m, Counter):
            raise TypeError(f"metric {name!r} is {type(m).__name__}")
        return m

    def gauge(self, name: str) -> Gauge:
        m = self._get(name, lambda: Gauge(name))
        if not isinstance(m, Gauge):
            raise TypeError(f"metric {name!r} is {type(m).__name__}")
        return m

    def histogram(self, name: str,
                  bounds: Sequence[float] = _SPAN_BOUNDS) -> Histogram:
        m = self._get(name, lambda: Histogram(name, bounds))
        if not isinstance(m, Histogram):
            raise TypeError(f"metric {name!r} is {type(m).__name__}")
        return m

    def timer(self, name: str) -> SpanTimer:
        return SpanTimer(self.histogram(name), emit=self.emit)

    def emit(self, record: Dict[str, Any]) -> None:
        """Write one timestamped record to the sink (no-op without one)."""
        if self.sink is not None:
            self.sink.write(record)

    def snapshot(self) -> List[Dict[str, Any]]:
        with self._lock:
            return [m.snapshot() for m in self._metrics.values()]

    def emit_snapshot(self) -> None:
        self.emit({"kind": "snapshot", "metrics": self.snapshot()})


class JsonlSink:
    """Append-only JSONL file: one complete, flushed line per record.

    Writes hold a lock and flush immediately, so records from multiple
    threads (trainer loop, engine ticker, checkpoint watcher) never
    interleave mid-line and a crashed run keeps everything emitted up to
    the crash. Every record gets a wall-clock ``t`` stamp unless the
    caller provided one.
    """

    def __init__(self, path: str):
        self.path = path
        self._lock = threading.Lock()
        self._fh = open(path, "a")

    def write(self, record: Dict[str, Any]) -> None:
        record = dict(record)
        record.setdefault("t", time.time())
        line = json.dumps(_sanitize(record), default=_json_default)
        with self._lock:
            self._fh.write(line + "\n")
            self._fh.flush()

    def close(self) -> None:
        with self._lock:
            if not self._fh.closed:
                self._fh.close()

    def __enter__(self) -> "JsonlSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def _sanitize(obj):
    """Strict-JSON payloads: non-finite floats become null (json.dumps
    would otherwise emit the nonstandard ``NaN`` token and break any
    non-Python consumer of the file)."""
    if isinstance(obj, float):
        return obj if math.isfinite(obj) else None
    if isinstance(obj, dict):
        return {k: _sanitize(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_sanitize(v) for v in obj]
    return obj


def _json_default(obj):
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        v = float(obj)
        return None if math.isnan(v) else v
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return dataclasses.asdict(obj)
    raise TypeError(f"not JSONL-serializable: {type(obj).__name__}")


def read_jsonl(path: str) -> List[Dict[str, Any]]:
    """Parse a metrics JSONL file back into records (test/CI helper)."""
    out = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
