"""Before/after comparison — dry-run result stores, or live training runs.

Two modes, both going through the unified driver stack:

* store diff (default): compare two dry-run JSON stores (§Perf evidence)

      PYTHONPATH=src python -m repro.launch.compare \
          results/dryrun_baseline.json results/dryrun_opt.json

* session compare (``--sessions``): the positional arguments are
  ``RunConfig`` JSON files (the ``launch/train.py --dump-config``
  artifact); each runs on a shared synthetic corpus through
  ``TrainSession.run()`` — no hand-assembled ``make_dist_step``/loop
  wiring — and the eval trajectories print side by side

      PYTHONPATH=src python -m repro.launch.compare --sessions \
          run_baseline.json run_opt.json [--topics 32] [--eval-every 5] \
          [--quality-every 5]

  ``--quality-every`` (or ``quality_every`` in either config) adds the
  model-quality columns — UMass/NPMI coherence and left-to-right
  held-out llh per token (``repro.eval``, DESIGN.md §9) — so a knob or
  backend choice is judged on quality curves, not just docs/sec.
"""
from __future__ import annotations

import argparse
import json

from repro.launch.roofline import roofline_terms


def compare_sessions(args) -> None:
    """Run two RunConfigs via TrainSession on one corpus; print the eval
    trajectories side by side — llh/perplexity always, plus the quality
    columns (UMass/NPMI coherence, left-to-right llh) whenever either
    config runs the quality action (``quality_every`` / --quality-every)."""
    import dataclasses

    import jax

    from repro.core.types import LDAHyperParams
    from repro.data import synthetic_corpus
    from repro.train.session import RunConfig, TrainSession

    corpus = synthetic_corpus(
        0, num_docs=args.synthetic_docs, num_words=args.synthetic_words,
        avg_doc_len=args.synthetic_len, zipf_a=1.2,
    )
    hyper = LDAHyperParams(num_topics=args.topics)
    runs = {}
    for path in (args.baseline, args.optimized):
        with open(path) as f:
            cfg = RunConfig.from_json(f.read())
        if args.eval_every:
            cfg = dataclasses.replace(cfg, eval_every=args.eval_every)
        if args.quality_every:
            cfg = dataclasses.replace(cfg, quality_every=args.quality_every)
        session = TrainSession(corpus, hyper, cfg)
        traj = []
        session.run(
            jax.random.key(args.seed),
            callback=lambda st, m: traj.append(
                dict(m, iteration=int(st.iteration))
            ) if ("llh" in m or "coherence_umass" in m) else None,
        )
        runs[path] = traj
        plan = "single-box" if cfg.mesh_shape is None else \
            f"mesh {cfg.mesh_shape[0]}x{cfg.mesh_shape[1]}"
        print(f"# {path}: algorithm={cfg.algorithm} plan={plan}")
    a, b = runs[args.baseline], runs[args.optimized]
    # quality columns appear when any tick of either run carried them
    cols = [("llh", "llh", "{:.1f}"), ("perplexity", "ppl", "{:.2f}")]
    for key, label, fmt in (
        ("coherence_umass", "umass", "{:.3f}"),
        ("coherence_npmi", "npmi", "{:.3f}"),
        ("l2r_per_token", "l2r/tok", "{:.3f}"),
    ):
        if any(key in m for m in a + b):
            cols.append((key, label, fmt))
    header = "| iter |" + "".join(
        f" baseline {label} | optimized {label} |" for _, label, _ in cols
    )
    print(header)
    print("|---|" + "---|" * (2 * len(cols)))
    for ma, mb in zip(a, b):
        ia, ib = ma["iteration"], mb["iteration"]
        it = ia if ia == ib else f"{ia}/{ib}"
        cells = []
        for key, _, fmt in cols:
            for m in (ma, mb):
                cells.append(fmt.format(m[key]) if key in m else "-")
        print(f"| {it} | " + " | ".join(cells) + " |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("optimized")
    ap.add_argument("--min-ratio", type=float, default=1.05,
                    help="only print cells that moved by this factor")
    ap.add_argument("--sessions", action="store_true",
                    help="treat the positionals as RunConfig JSONs and "
                         "compare live TrainSession runs")
    ap.add_argument("--topics", type=int, default=32)
    ap.add_argument("--eval-every", type=int, default=0,
                    help="override both configs' eval cadence")
    ap.add_argument("--quality-every", type=int, default=0,
                    help="override both configs' quality-eval cadence "
                         "(coherence + left-to-right columns)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--synthetic-docs", type=int, default=400)
    ap.add_argument("--synthetic-words", type=int, default=800)
    ap.add_argument("--synthetic-len", type=int, default=64)
    args = ap.parse_args()
    if args.sessions:
        compare_sessions(args)
        return
    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.optimized) as f:
        opt = json.load(f)

    # legend: resolve each LDA arch's sampler through the backend registry
    # (the same algorithms.get() the trainer / mesh step / dryrun use).
    # Best-effort — the jax-backed imports stay inside a try so the plain
    # JSON diff below never blocks on them.
    try:
        from repro import algorithms
        from repro.configs import get_config
        from repro.configs.base import LDAArchConfig
        from repro.launch.mesh import mesh_backends
    except Exception as e:  # pragma: no cover - jax-less environments
        print(f"# (algorithm legend unavailable: {e})")
    else:
        print(f"# mesh-capable backends: {', '.join(mesh_backends())}")
        for arch in sorted({k.split("|")[0] for k in base if "|" in k}):
            try:
                cfg = get_config(arch)
                if isinstance(cfg, LDAArchConfig):
                    backend = algorithms.get(cfg.algorithm)
                    print(f"# {arch}: sampler backend {backend.name!r} "
                          f"(shard_map={backend.supports_shard_map})")
            except Exception as e:  # best-effort; never block the diff
                print(f"# {arch}: (algorithm legend unavailable: {e})")

    def effective(store, key):
        """fitted record if present, else the raw cell record."""
        arch, shape, mesh = key.split("|")
        rec = store.get(key)
        fit = store.get(f"{arch}|{shape}|fit")
        if rec is None or not rec.get("ok"):
            return None
        if mesh == "single" and fit is not None and fit.get("ok"):
            rec = dict(rec)
            for k in ("flops_per_device", "bytes_per_device",
                      "collective_bytes_per_device"):
                rec[k] = fit[k]
        return rec

    print("| cell | term | baseline (s) | optimized (s) | x |")
    print("|---|---|---|---|---|")
    keys = sorted(k for k in base if k.count("|") == 2
                  and not k.endswith("|fit"))
    for key in keys:
        b = effective(base, key)
        o = effective(opt, key)
        if b is None or o is None:
            continue
        tb = roofline_terms(b)
        to = roofline_terms(o)
        for term in ("compute_s", "memory_s", "collective_s"):
            if to[term] <= 0:
                continue
            ratio = tb[term] / max(to[term], 1e-12)
            if ratio >= args.min_ratio or ratio <= 1 / args.min_ratio:
                print(f"| {key} | {term[:-2]} | {tb[term]:.3e} | "
                      f"{to[term]:.3e} | {ratio:5.2f} |")


if __name__ == "__main__":
    main()
