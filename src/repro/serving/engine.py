"""Batched serving engine: continuous-batching-lite over prefill/decode.

Requests queue up; the engine packs up to ``max_batch`` active sequences
into one decode batch (fixed shape — finished slots are refilled by new
requests each step, the continuous-batching idea with static shapes).
Prefill runs per-request (right-padded to the bucket) and its KV is packed
into the slot cache. Greedy or temperature sampling.

This is the LM-serving analogue of the paper's RT-LDA low-latency inference
path (``repro.core.inference``): both are served from the same engine
process in examples/serve_lm.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.model import decode_step, init_cache, prefill_with_cache


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_len: int = 256
    temperature: float = 0.0  # 0 => greedy
    eos_id: int = -1  # -1 => never stop early


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, params: Any, cfg: ArchConfig, serve_cfg: ServeConfig):
        self.params = params
        self.cfg = cfg
        self.scfg = serve_cfg
        b, s = serve_cfg.max_batch, serve_cfg.max_len
        self.caches = init_cache(cfg, b, s)
        self.tokens = np.zeros((b,), np.int32)
        self.active: List[Optional[Request]] = [None] * b
        self.queue: List[Request] = []
        self._uid = 0
        self._decode = jax.jit(
            lambda p, t, c: decode_step(p, self.cfg, t, c)
        )

    def submit(self, prompt: List[int], max_new: int = 32) -> int:
        self._uid += 1
        self.queue.append(Request(self._uid, prompt, max_new))
        return self._uid

    def _admit(self):
        """Fill empty slots: prefill the prompt token-by-token into the slot
        cache (single-slot prefill keeps every family supported; the dense
        fast path uses prefill_with_cache)."""
        for slot in range(self.scfg.max_batch):
            if self.active[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            # zero the slot's cache region by decoding from scratch
            self._reset_slot(slot)
            tok = jnp.asarray(self.tokens)
            for t in req.prompt[:-1]:
                self.tokens[slot] = t
                logits, self.caches = self._decode(
                    self.params, jnp.asarray(self.tokens), self.caches
                )
            self.tokens[slot] = req.prompt[-1]
            self.active[slot] = req

    def _reset_slot(self, slot: int):
        def zero_slot(x):
            if x is None or x.ndim < 2:
                return x
            if x.shape[0] == self.scfg.max_batch:  # (B, ...)
                return x.at[slot].set(0)
            if x.ndim >= 3 and x.shape[1] == self.scfg.max_batch:  # (L,B,...)
                return x.at[:, slot].set(0)
            return x
        # per-slot lengths are global scalars in this simple cache layout;
        # a slot reset therefore restarts the whole batch's cache when any
        # slot is recycled mid-flight. Acceptable for the example engine.
        if all(a is None for a in self.active):
            self.caches = init_cache(
                self.cfg, self.scfg.max_batch, self.scfg.max_len
            )

    def step(self) -> List[Request]:
        """One decode step for all active slots; returns finished requests."""
        self._admit()
        if all(a is None for a in self.active):
            return []
        logits, self.caches = self._decode(
            self.params, jnp.asarray(self.tokens), self.caches
        )
        logits = np.asarray(logits, np.float32)
        finished = []
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            if self.scfg.temperature > 0:
                p = np.exp(
                    (logits[slot] - logits[slot].max()) / self.scfg.temperature
                )
                p /= p.sum()
                nxt = int(np.random.choice(p.shape[0], p=p))
            else:
                nxt = int(np.argmax(logits[slot]))
            req.out.append(nxt)
            self.tokens[slot] = nxt
            if len(req.out) >= req.max_new or nxt == self.scfg.eos_id:
                req.done = True
                finished.append(req)
                self.active[slot] = None
        return finished

    def run_until_done(self, max_steps: int = 10_000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_steps):
            done.extend(self.step())
            if not self.queue and all(a is None for a in self.active):
                break
        return done
