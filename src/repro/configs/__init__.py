"""Config registry: ``get_config(arch_id)`` / ``list_archs()``.

One module per assigned architecture (exact figures from the assignment) +
the paper's own LDA configs. ``get_config('<id>-smoke')`` returns the
reduced smoke variant.
"""
from __future__ import annotations

from typing import Dict, List, Union

from repro.configs.base import ArchConfig, LDAArchConfig, ShapeConfig

# input-shape cells (assignment: LM shapes are seq_len x global_batch)
SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def _registry() -> Dict[str, Union[ArchConfig, LDAArchConfig]]:
    from repro.configs import (
        arctic_480b,
        falcon_mamba_7b,
        gemma3_4b,
        grok1_314b,
        minicpm3_4b,
        qwen1_5_4b,
        qwen2_vl_2b,
        qwen3_8b,
        whisper_medium,
        zamba2_1_2b,
        zenlda,
    )

    cfgs = [
        gemma3_4b.CONFIG,
        qwen1_5_4b.CONFIG,
        qwen3_8b.CONFIG,
        minicpm3_4b.CONFIG,
        zamba2_1_2b.CONFIG,
        whisper_medium.CONFIG,
        grok1_314b.CONFIG,
        arctic_480b.CONFIG,
        falcon_mamba_7b.CONFIG,
        qwen2_vl_2b.CONFIG,
        zenlda.NYTIMES,
        zenlda.WEBCHUNK,
    ]
    return {c.name: c for c in cfgs}


def get_config(name: str) -> Union[ArchConfig, LDAArchConfig]:
    reg = _registry()
    if name.endswith("-smoke"):
        base = reg[name[: -len("-smoke")]]
        assert isinstance(base, ArchConfig)
        return base.reduced()
    return reg[name]


def list_archs(lm_only: bool = False) -> List[str]:
    return [
        k for k, v in _registry().items()
        if not (lm_only and isinstance(v, LDAArchConfig))
    ]


def shapes_for(cfg: Union[ArchConfig, LDAArchConfig]) -> List[str]:
    """The shape cells this arch runs (assignment skip rules)."""
    if isinstance(cfg, LDAArchConfig):
        return ["train_lda"]
    return [s for s in SHAPES if s not in cfg.skip_shapes]
