from repro.serving.engine import ServeConfig, ServingEngine  # noqa: F401
from repro.serving.lda_engine import (  # noqa: F401
    FrozenLDAModel,
    InferRequest,
    LDAEngine,
    LDAServeConfig,
    doc_completion_perplexity,
    docs_from_corpus,
    latency_percentile,
)
