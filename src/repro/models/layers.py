"""Shared LM layers: norms, RoPE (+M-RoPE), MLPs, embedding.

Parameters are plain dict pytrees; layer stacks carry a leading ``layers``
axis and run under ``lax.scan`` (compile time O(1) in depth — essential for
the 512-device dry-runs of 34-64 layer models).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layernorm(
    x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-6
) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * scale + bias
    return out.astype(x.dtype)


def norm(x: jax.Array, params: dict, cfg: ArchConfig) -> jax.Array:
    if cfg.norm_style == "layernorm":
        return layernorm(x, params["scale"], params["bias"], cfg.norm_eps)
    return rmsnorm(x, params["scale"], cfg.norm_eps)


def init_norm(key, d: int, cfg: ArchConfig) -> dict:
    if cfg.norm_style == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.zeros((d,), jnp.float32)}


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(
    x: jax.Array,  # (B, S, H, D)
    positions: jax.Array,  # (B, S)
    theta: float,
) -> jax.Array:
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    sin = jnp.sin(angles)[:, :, None, :]
    cos = jnp.cos(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array,  # (B, S, H, D)
    positions: jax.Array,  # (B, S, 3) — temporal/height/width (qwen2-vl)
    theta: float,
    sections=(2, 1, 1),  # fraction of rope channels per component (t, h, w)
) -> jax.Array:
    """Multimodal RoPE: rope channel groups take positions from different
    components. Text tokens have t == h == w so M-RoPE == RoPE there."""
    d = x.shape[-1]
    half = d // 2
    total = sum(sections)
    split = [half * s // total for s in sections]
    split[-1] = half - sum(split[:-1])
    freqs = rope_freqs(d, theta)  # (half,)
    comp = jnp.concatenate(
        [jnp.full((n,), i, jnp.int32) for i, n in enumerate(split)]
    )  # (half,) which position component drives each channel
    pos = positions.astype(jnp.float32)[:, :, comp]  # (B, S, half)
    angles = pos * freqs
    sin = jnp.sin(angles)[:, :, None, :]
    cos = jnp.cos(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def _act(x: jax.Array, kind: str) -> jax.Array:
    if kind == "gelu":
        return jax.nn.gelu(x)
    return jax.nn.silu(x)


def mlp(x: jax.Array, params: dict, cfg: ArchConfig) -> jax.Array:
    """Gated (SwiGLU-style) or plain 2-layer MLP."""
    if cfg.glu:
        gate = _act(jnp.einsum("...d,df->...f", x, params["w_gate"]), cfg.act)
        up = jnp.einsum("...d,df->...f", x, params["w_up"])
        return jnp.einsum("...f,fd->...d", gate * up, params["w_down"])
    h = _act(jnp.einsum("...d,df->...f", x, params["w_up"]), cfg.act)
    return jnp.einsum("...f,fd->...d", h, params["w_down"])


def init_mlp(key, d_model: int, d_ff: int, cfg: ArchConfig, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = d_model ** -0.5
    scale_out = d_ff ** -0.5
    p = {
        "w_up": (jax.random.normal(k2, (d_model, d_ff)) * scale_in).astype(dtype),
        "w_down": (jax.random.normal(k3, (d_ff, d_model)) * scale_out).astype(dtype),
    }
    if cfg.glu:
        p["w_gate"] = (
            jax.random.normal(k1, (d_model, d_ff)) * scale_in
        ).astype(dtype)
    return p


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    return table[tokens]


def unembed(x: jax.Array, table: jax.Array) -> jax.Array:
    """Logits; table (V, D) shared (tied) or separate."""
    return jnp.einsum("...d,vd->...v", x, table)


def init_embed(key, vocab: int, d_model: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d_model)) * (d_model ** -0.5)).astype(
        dtype
    )
