"""Streaming corpus sources: document windows over a stable vocabulary.

The paper's §3.1 document-window partitioning frames training as a
rotation over doc slices: the word-topic model stays resident while the
doc window (and its ``N_k|d`` block) rolls. This module is the ingestion
side of that contract — a :class:`CorpusSource` yields :class:`Window`\\ s,
each a self-contained :class:`~repro.core.types.Corpus` whose doc ids are
local to the window (``[0, window.corpus.num_docs)``) and whose
``num_words`` equals the source's global vocabulary. The *vocabulary
contract* is what makes windows composable into one model: every window
indexes the same ``(W, K)`` word-topic count matrix.

Three implementations:

* :class:`ReplaySource` — in-memory rotation over a materialized
  ``Corpus``: the corpus is sliced into ``ceil(D / window_docs)`` doc
  windows, iterated ``epochs`` times. Windows keep a stable ``uid``
  across epochs, so the online trainer can retain their assignments and
  a ``decay=0`` replay run is the windowed equivalent of batch training
  (``repro.train.online``).
* :class:`LibsvmStreamSource` — chunked tailing of a libsvm file through
  one open handle (``load_libsvm(f, max_docs=...)``): each window reads
  the next ``window_docs`` documents, nothing is re-read, nothing but
  the current window is ever resident.
* :class:`DriftSource` — a synthetic non-stationary stream for tests and
  benchmarks: every window is generated from LDA topics that random-walk
  between windows (``drift`` mixes fresh Dirichlet noise into phi), so a
  model that never forgets goes stale measurably. Fully deterministic in
  ``(seed, window index)`` — ``windows(start=k)`` replays the drift
  chain silently up to ``k``, which is what makes mid-stream checkpoint
  resume exact.

``windows(start=k)`` is the resume contract all sources honor: the
iterator yields windows ``k, k+1, ...`` identical to the tail of a
``start=0`` iteration.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.types import Corpus
from repro.data.corpus import load_libsvm, skip_libsvm_docs


@dataclasses.dataclass(frozen=True)
class Window:
    """One streamed document window.

    ``corpus`` is self-contained: doc ids are ``[0, corpus.num_docs)``
    and ``corpus.num_words`` is the source's global vocabulary.
    ``index`` is the 0-based position in the stream (the resume cursor).
    ``uid`` is the window's *identity*: a replaying source reuses the
    uid when the same doc slice comes around again (how the online
    trainer knows to reuse retained assignments instead of folding the
    window's counts in twice). ``token_index``, when present, maps the
    window's tokens back to edge indices of the source's original corpus
    (``ReplaySource`` only — used to reassemble a full-corpus state).
    """

    corpus: Corpus
    index: int
    uid: str
    token_index: Optional[np.ndarray] = None


class CorpusSource:
    """Protocol: iterate document windows under a stable vocabulary.

    ``replays`` declares whether a uid can come around more than once
    (only then is retaining per-window assignments worthwhile).
    ``supports_doc_resume`` declares that ``windows`` accepts a
    ``start_docs`` cursor — the exact number of documents already
    consumed — and resumes there instead of assuming every prior window
    was full. Sources that derive windows deterministically from the
    index alone (replay/drift) don't need it; a tailing file source does:
    its final window may be truncated at EOF, so ``start * window_docs``
    over-skips once the file grows (see :class:`LibsvmStreamSource`).
    """

    num_words: int
    window_docs: int
    replays: bool = False
    supports_doc_resume: bool = False

    def windows(self, start: int = 0) -> Iterator[Window]:
        raise NotImplementedError


class ReplaySource(CorpusSource):
    """Rotate over an in-memory ``Corpus`` in doc windows.

    The corpus is split into ``ceil(num_docs / window_docs)`` slices;
    one epoch yields each slice once, in order, and the stream is
    ``epochs`` epochs long. Slice ``s`` keeps uid ``w<s>`` in every
    epoch.
    """

    replays = True

    def __init__(self, corpus: Corpus, window_docs: int, epochs: int = 1):
        if window_docs <= 0:
            raise ValueError(f"window_docs must be > 0, got {window_docs}")
        if epochs <= 0:
            raise ValueError(f"epochs must be > 0, got {epochs}")
        self.corpus = corpus
        self.window_docs = int(window_docs)
        self.epochs = int(epochs)
        self.num_words = corpus.num_words
        # doc-major token order, computed once; per-window token slices
        # are contiguous ranges of this permutation
        docs = np.asarray(corpus.doc)
        self._order = np.argsort(docs, kind="stable")
        self._docs = docs[self._order]
        self._words = np.asarray(corpus.word)[self._order]
        self._bounds = np.searchsorted(
            self._docs, np.arange(corpus.num_docs + 1)
        )
        self.windows_per_epoch = -(-corpus.num_docs // self.window_docs)

    @property
    def num_windows(self) -> int:
        return self.windows_per_epoch * self.epochs

    def window_slice(self, slice_index: int) -> Window:
        """The windowed ``Corpus`` for doc slice ``slice_index`` (epoch-
        independent; ``windows()`` stamps the per-epoch stream index)."""
        d0 = slice_index * self.window_docs
        d1 = min(d0 + self.window_docs, self.corpus.num_docs)
        t0, t1 = self._bounds[d0], self._bounds[d1]
        cw = Corpus(
            word=jnp.asarray(self._words[t0:t1]),
            doc=jnp.asarray((self._docs[t0:t1] - d0).astype(np.int32)),
            num_words=self.num_words,
            num_docs=d1 - d0,
        )
        return Window(
            corpus=cw, index=slice_index, uid=f"w{slice_index}",
            token_index=self._order[t0:t1],
        )

    def windows(self, start: int = 0) -> Iterator[Window]:
        for i in range(start, self.num_windows):
            w = self.window_slice(i % self.windows_per_epoch)
            yield dataclasses.replace(w, index=i)


class LibsvmStreamSource(CorpusSource):
    """Tail a libsvm file in document windows through one open handle.

    Each window is the next ``window_docs`` documents
    (``load_libsvm(f, num_words, max_docs=window_docs)``); the handle is
    never rewound, so a window is read exactly once and only the current
    window is resident. ``num_words`` is required — a chunked read cannot
    infer the global vocabulary from one window (the stability
    contract). ``windows(start=k)`` fast-forwards by skipping
    ``k * window_docs`` documents without materializing them — unless
    the caller passes ``start_docs``, the exact document cursor, which
    is the correct resume point when the file ended mid-window on the
    previous run: a truncated final window consumed fewer than
    ``window_docs`` documents, so the window-count arithmetic would
    over-skip (dropping documents appended since) while a checkpoint
    that predates the partial window would re-read it. The streaming
    session checkpoints this cursor (``supports_doc_resume``).
    """

    supports_doc_resume = True

    def __init__(self, path: str, window_docs: int, num_words: int):
        if window_docs <= 0:
            raise ValueError(f"window_docs must be > 0, got {window_docs}")
        if num_words <= 0:
            raise ValueError(
                "LibsvmStreamSource needs the global vocabulary size "
                f"(num_words > 0), got {num_words}"
            )
        self.path = path
        self.window_docs = int(window_docs)
        self.num_words = int(num_words)

    def windows(
        self, start: int = 0, start_docs: Optional[int] = None
    ) -> Iterator[Window]:
        with open(self.path) as f:
            skip = (start * self.window_docs if start_docs is None
                    else int(start_docs))
            if skip:
                skip_libsvm_docs(f, skip)
            index = start
            while True:
                cw = load_libsvm(
                    f, num_words=self.num_words, max_docs=self.window_docs
                )
                if cw.num_docs == 0:
                    return
                yield Window(corpus=cw, index=index, uid=f"w{index}")
                index += 1


class DriftSource(CorpusSource):
    """Synthetic non-stationary stream: LDA windows whose topics drift.

    Window ``i`` is generated from topic-word distributions
    ``phi_i = normalize((1 - drift) * phi_{i-1} + drift * noise_i)``
    (fresh Dirichlet noise per window), documents drawn per-window from
    fresh Dirichlet thetas. Everything is seeded from
    ``(seed, window index)``, and ``windows(start=k)`` recomputes the
    phi chain ``0..k-1`` without emitting windows — deterministic
    resume.
    """

    def __init__(
        self,
        seed: int,
        window_docs: int,
        num_windows: int,
        num_words: int,
        num_topics: int = 8,
        avg_doc_len: int = 40,
        drift: float = 0.25,
        alpha: float = 0.1,
        beta: float = 0.05,
    ):
        if window_docs <= 0:
            raise ValueError(f"window_docs must be > 0, got {window_docs}")
        if not 0.0 <= drift <= 1.0:
            raise ValueError(f"drift must be in [0, 1], got {drift}")
        self.seed = int(seed)
        self.window_docs = int(window_docs)
        self.num_windows = int(num_windows)
        self.num_words = int(num_words)
        self.num_topics = int(num_topics)
        self.avg_doc_len = int(avg_doc_len)
        self.drift = float(drift)
        self.alpha = float(alpha)
        self.beta = float(beta)

    def _rng(self, index: int, stream: int) -> np.random.Generator:
        return np.random.default_rng([self.seed, stream, index])

    def _phi(self, index: int) -> np.ndarray:
        """The drift chain up to window ``index`` ((K, W), rows sum 1)."""
        phi = self._rng(0, 0).dirichlet(
            np.full(self.num_words, self.beta), size=self.num_topics
        )
        for i in range(1, index + 1):
            noise = self._rng(i, 0).dirichlet(
                np.full(self.num_words, self.beta), size=self.num_topics
            )
            phi = (1.0 - self.drift) * phi + self.drift * noise
            phi /= phi.sum(axis=1, keepdims=True)
        return phi

    def _window(self, index: int, phi: np.ndarray) -> Window:
        rng = self._rng(index, 1)
        theta = rng.dirichlet(
            np.full(self.num_topics, self.alpha), size=self.window_docs
        )
        lengths = np.maximum(1, rng.poisson(self.avg_doc_len,
                                            size=self.window_docs))
        words_list, docs_list = [], []
        for d in range(self.window_docs):
            zs = rng.choice(self.num_topics, size=lengths[d], p=theta[d])
            for z in np.unique(zs):
                n = int((zs == z).sum())
                words_list.append(rng.choice(self.num_words, size=n,
                                             p=phi[z]))
                docs_list.append(np.full(n, d, dtype=np.int32))
        cw = Corpus(
            word=jnp.asarray(np.concatenate(words_list).astype(np.int32)),
            doc=jnp.asarray(np.concatenate(docs_list).astype(np.int32)),
            num_words=self.num_words,
            num_docs=self.window_docs,
        )
        return Window(corpus=cw, index=index, uid=f"w{index}")

    def windows(self, start: int = 0) -> Iterator[Window]:
        if start >= self.num_windows:
            return
        phi = self._phi(start)
        for i in range(start, self.num_windows):
            if i > start:
                noise = self._rng(i, 0).dirichlet(
                    np.full(self.num_words, self.beta), size=self.num_topics
                )
                phi = (1.0 - self.drift) * phi + self.drift * noise
                phi /= phi.sum(axis=1, keepdims=True)
            yield self._window(i, phi)


def make_source(
    spec: str,
    window_docs: int,
    *,
    corpus: Optional[Corpus] = None,
    num_words: Optional[int] = None,
    epochs: int = 1,
    num_windows: int = 8,
    seed: int = 0,
) -> CorpusSource:
    """Build a :class:`CorpusSource` from a ``RunConfig.stream_source``
    spec string — the declarative form the CLI and run JSONs use.

    * ``"replay"`` — :class:`ReplaySource` over ``corpus`` (required).
    * ``"libsvm:<path>"`` — :class:`LibsvmStreamSource`; needs
      ``num_words``.
    * ``"drift"`` / ``"drift:<seed>"`` — :class:`DriftSource` with
      ``num_windows`` windows; needs ``num_words``.
    """
    kind, _, arg = spec.partition(":")
    if kind == "replay":
        if corpus is None:
            raise ValueError("stream_source 'replay' needs a corpus")
        return ReplaySource(corpus, window_docs, epochs=epochs)
    if kind == "libsvm":
        if not arg:
            raise ValueError("stream_source 'libsvm:<path>' needs a path")
        if not num_words:
            raise ValueError("stream_source 'libsvm' needs num_words")
        return LibsvmStreamSource(arg, window_docs, num_words)
    if kind == "drift":
        if not num_words:
            raise ValueError("stream_source 'drift' needs num_words")
        return DriftSource(
            seed=int(arg) if arg else seed,
            window_docs=window_docs,
            num_windows=num_windows,
            num_words=num_words,
        )
    raise ValueError(
        f"unknown stream_source {spec!r}: expected replay | "
        f"libsvm:<path> | drift[:<seed>]"
    )
