"""The paper's own workloads as dry-runnable configs.

NYTIMES mirrors the paper's small dataset (Table 2: 101,636 words, ~100M
tokens, K=1000); WEBCHUNK mirrors BingWebC1Mon (302,098 words, K=10,000)
with a 1M-document streaming window per iteration (the Spark analogue
holds partitions in executor memory; we hold one streamed doc window in
HBM — DESIGN.md §3.1).
"""
from repro.configs.base import LDAArchConfig

NYTIMES = LDAArchConfig(
    name="zenlda-nytimes",
    num_words=101_636,
    num_topics=1000,
    docs_per_step=299_752,
    avg_doc_len=332,
    algorithm="zen_cdf",
    max_kd=128,
)

WEBCHUNK = LDAArchConfig(
    name="zenlda-webchunk",
    num_words=302_098,
    num_topics=10_000,
    docs_per_step=1_048_576,
    avg_doc_len=192,
    algorithm="zen_cdf",
    max_kd=128,
    delta_dtype="int16",  # §Perf l3: halves the count-sync collectives
    kd_dtype="int16",  # §Perf l4: halves every N_kd pass
)
