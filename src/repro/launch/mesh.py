"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before any jax call.
"""
from __future__ import annotations

from repro.utils.compat import make_mesh as _make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / small runs)."""
    return _make_mesh(shape, axes)


def mesh_backends():
    """Registered sampler backends that can run on the mesh path (i.e.
    declare a ``cell_sweep``). Since the padded-sparse backends went
    cell-local this is every algorithm except the textbook ``std`` — the
    launch CLIs no longer gate ``--algorithm`` choices beyond this list."""
    from repro import algorithms

    return tuple(
        n for n in algorithms.registered()
        if algorithms.get(n).supports_shard_map
    )
