"""TrainSession telemetry: the per-iteration ``train_iter`` emitter.

``TrainTelemetry.record_iteration`` is called by the session's
``telemetry`` schedule action (registered only when
``RunConfig.metrics_out``/``autopilot`` is set — the hook is inert by
default) and turns one finished iteration into one JSONL record:

* throughput — tokens/sec from monotonic-clock deltas between records
  (the state is synced by the host transfer below, so the delta is an
  honest wall measurement, not a dispatch time);
* sparsity — per-backend row-nnz summaries of the LIVE counts
  (``nnz_row_stats`` of N_w|k and N_k|d), i.e. the measured ``K_w``/``K_d``
  the paper's hybrid decomposition argument (§3.2) keys on;
* capacity — the padded-row widths currently in effect;
* quality — whatever the eval action already computed this iteration
  (llh / perplexity / change_rate), merged without a second pass.

A bounded deque of recent records is the *window* the
``repro.autotune.TrainAutopilot`` consumes; this module never decides.
"""
from __future__ import annotations

import collections
import time
from typing import Any, Deque, Dict, List, Optional

import jax
import numpy as np

from repro.observe.metrics import MetricsRegistry, nnz_row_stats


class TrainTelemetry:
    """Per-iteration measurement hook for a ``TrainSession``.

    Args:
        registry: the metrics registry (its sink receives the JSONL).
        window: how many recent iteration records to retain for the
            autopilot's decision window.
        nnz_every: compute the (host-transfer-paying) row-nnz summaries
            every N records; other records carry the last-known stats.
    """

    def __init__(self, registry: MetricsRegistry, window: int = 32,
                 nnz_every: int = 1):
        self.registry = registry
        self.records: Deque[Dict[str, Any]] = collections.deque(maxlen=window)
        self.nnz_every = max(1, int(nnz_every))
        self._n_records = 0
        self._t_last: Optional[float] = None
        self._last_nnz: Dict[str, Dict[str, float]] = {}

    # -- the hook ------------------------------------------------------------
    def record_iteration(self, plan, state, iteration: int,
                         metrics: Dict[str, Any]) -> Dict[str, Any]:
        """Measure one finished iteration; emit + retain the record.

        ``plan`` is the session's ``ExecutionPlan`` (for ``num_tokens``,
        ``row_pads``, backend identity and the host count accessors),
        ``metrics`` is the schedule's per-iteration ``ctx.metrics`` dict
        (already holding eval results when the eval action fired).
        """
        self._n_records += 1
        if self._n_records % self.nnz_every == 0 or not self._last_nnz:
            self._last_nnz = {
                "word_rows": nnz_row_stats(plan.host_n_wk(state)),
                "doc_rows": nnz_row_stats(
                    np.asarray(jax.device_get(state.n_kd))),
            }
        # stamp AFTER the host transfers above: device_get blocks on the
        # async dispatch, so t_now - t_last covers the real step work
        t_now = time.monotonic()
        dt = None if self._t_last is None else t_now - self._t_last
        self._t_last = t_now
        kw, kd = plan.row_pads
        rec: Dict[str, Any] = {
            "kind": "train_iter",
            "iteration": int(iteration),
            "backend": plan.backend.name,
            "dt_s": dt,
            "tokens_per_s": (plan.num_tokens / dt) if dt else None,
            "row_pads": {"max_kw": int(kw), "max_kd": int(kd)},
            "word_rows": self._last_nnz["word_rows"],
            "doc_rows": self._last_nnz["doc_rows"],
        }
        for k in ("llh", "perplexity", "change_rate"):
            if k in metrics:
                rec[k] = float(metrics[k])
        self.records.append(rec)
        self.registry.gauge("train.tokens_per_s").set(rec["tokens_per_s"])
        self.registry.counter("train.iterations").inc()
        self.registry.emit(rec)
        return rec

    # -- the autopilot's view --------------------------------------------------
    def window(self) -> List[Dict[str, Any]]:
        return list(self.records)

    def emit_decision(self, record: Dict[str, Any]) -> None:
        """Log one applied (or rejected) autopilot decision."""
        self.registry.counter("train.decisions").inc()
        self.registry.emit(record)
