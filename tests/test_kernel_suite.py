"""Kernel suite v2 integration: dispatch policy, knob validation, the
jaxpr memory contract (no (T, K) gathered intermediate on the fused
path), kernels-on/off backend identity, forced-kernel mesh parity, and
the tile autotuner.

The memory claim of the tentpole is pinned structurally, not by timing:
tracing ``zen_pallas.cell_sweep`` with kernels forced on must produce a
jaxpr in which NO intermediate value (recursively, through pjit and the
pallas_call kernel body) has a (>=T, >=K) shape — the gathered-row
matrices are exactly what the fused kernel exists to eliminate. The
legacy path is the positive control: its jaxpr DOES contain them, so the
walker is proven able to see the thing it asserts absent.
"""
import dataclasses

import jax
import jax.core
import jax.numpy as jnp
import numpy as np
import pytest
import test_mesh_parity

from repro import algorithms
from repro.algorithms.base import SamplerKnobs, kernel_dispatch, knobs_from
from repro.core.types import CGSState, LDAHyperParams
from repro.core import counts as counts_lib
from repro.data import synthetic_lda_corpus


# ---------------------------------------------------------------------------
# knob validation (satellite: reject bad tiles at config time)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "bad",
    [
        dict(bt=4),  # below the 8-sublane floor
        dict(bt=0),
        dict(bt=-8),
        dict(bk=64),  # below one lane
        dict(bk=129),  # not lane-aligned
        dict(bs=0),
        dict(bs=200),  # not lane-aligned
        dict(kernels="maybe"),
    ],
)
def test_knob_validation_rejects(bad):
    with pytest.raises(ValueError):
        SamplerKnobs(**bad)


def test_knob_validation_fires_through_replace_and_knobs_from():
    """The same check guards every construction route: direct, replace,
    and the config -> knobs derivation each driver uses."""
    good = SamplerKnobs()
    with pytest.raises(ValueError):
        dataclasses.replace(good, bk=100)

    from repro.core.distributed import DistConfig
    from repro.core.trainer import TrainConfig
    from repro.train.session import RunConfig

    for cfg in (RunConfig(bt=4), DistConfig(bt=4), TrainConfig(bt=4)):
        with pytest.raises(ValueError):
            knobs_from(cfg)


def test_kernel_knobs_plumb_through_every_config():
    """bs/kernels reach SamplerKnobs from all four driver configs."""
    from repro.core.distributed import DistConfig
    from repro.core.trainer import TrainConfig
    from repro.serving.lda_engine import LDAServeConfig
    from repro.train.session import RunConfig

    for cfg in (
        RunConfig(bs=256, kernels="off"),
        DistConfig(bs=256, kernels="off"),
        TrainConfig(bs=256, kernels="off"),
    ):
        kn = knobs_from(cfg)
        assert kn.bs == 256 and kn.kernels == "off", type(cfg).__name__
    assert TrainConfig(bs=256, kernels="off").to_run_config().kernels == "off"
    assert LDAServeConfig(kernels="off").knobs().kernels == "off"


# ---------------------------------------------------------------------------
# dispatch policy
# ---------------------------------------------------------------------------

def test_kernel_dispatch_policy(monkeypatch):
    monkeypatch.delenv("REPRO_KERNELS", raising=False)
    assert kernel_dispatch("auto") == (jax.default_backend() == "tpu")
    assert kernel_dispatch("on") is True
    assert kernel_dispatch("off") is False
    with pytest.raises(ValueError):
        kernel_dispatch("sometimes")
    # the env var overrides the knob (read at call time, not import time)
    monkeypatch.setenv("REPRO_KERNELS", "on")
    assert kernel_dispatch("off") is True
    monkeypatch.setenv("REPRO_KERNELS", "off")
    assert kernel_dispatch("on") is False
    monkeypatch.setenv("REPRO_KERNELS", "bogus")
    with pytest.raises(ValueError):
        kernel_dispatch("auto")


# ---------------------------------------------------------------------------
# jaxpr memory contract: the fused path has no (T, K) intermediates
# ---------------------------------------------------------------------------

def _collect_avals(jaxpr, out):
    """All eqn output avals, recursing into sub-jaxprs (pjit bodies from
    the @jax.jit ops wrappers, scan/while carries, pallas kernel bodies)."""
    for eqn in jaxpr.eqns:
        out.extend(v.aval for v in eqn.outvars)
        for val in eqn.params.values():
            for sub in _sub_jaxprs(val):
                _collect_avals(sub, out)


def _sub_jaxprs(val):
    if isinstance(val, jax.core.ClosedJaxpr):
        return [val.jaxpr]
    if isinstance(val, jax.core.Jaxpr):
        return [val]
    if isinstance(val, (list, tuple)):
        subs = []
        for v in val:
            subs.extend(_sub_jaxprs(v))
        return subs
    return []


def test_fused_cell_path_never_materializes_token_by_topic(monkeypatch):
    """Tentpole acceptance: with kernels on, no value anywhere in the
    traced cell sweep has shape (>=T, >=K) — the gathered count rows (and
    anything else token-by-topic) stay virtual. The legacy path is the
    positive control proving the walker sees such values when they exist."""
    monkeypatch.delenv("REPRO_KERNELS", raising=False)
    t, k, w, d = 192, 16, 37, 23  # t > w, d and k < all row counts
    be = algorithms.get("zen_pallas")
    hyper = LDAHyperParams(num_topics=k, alpha=0.1, beta=0.05)
    mask = jnp.ones((t,), bool)

    def trace(mode):
        kn = SamplerKnobs(kernels=mode)

        def fn(key, word, doc, z, n_wk, n_kd, n_k):
            return be.cell_sweep(
                key, word, doc, z, mask, n_wk, n_kd, n_k, hyper, w, kn
            )

        return jax.make_jaxpr(fn)(
            jax.random.key(0),
            jnp.zeros((t,), jnp.int32), jnp.zeros((t,), jnp.int32),
            jnp.zeros((t,), jnp.int32),
            jnp.zeros((w, k), jnp.int32), jnp.zeros((d, k), jnp.int32),
            jnp.zeros((k,), jnp.int32),
        )

    def token_by_topic(aval):
        shape = getattr(aval, "shape", ())
        return (len(shape) == 2 and isinstance(shape[0], int)
                and shape[0] >= t and shape[1] >= k)

    legacy = []
    _collect_avals(trace("off").jaxpr, legacy)
    assert any(token_by_topic(a) for a in legacy), \
        "positive control failed: legacy gather path should materialize (T, K)"

    fused = []
    _collect_avals(trace("on").jaxpr, fused)
    offenders = [a for a in fused if token_by_topic(a)]
    assert not offenders, offenders


def test_fused_infer_path_never_materializes_token_by_topic(monkeypatch):
    """Same contract for the serving sweep: (B*L, K) gathered rows exist
    only on the legacy path."""
    monkeypatch.delenv("REPRO_KERNELS", raising=False)
    b, l, k, w = 24, 16, 8, 30  # B*L = 384 tokens
    be = algorithms.get("zen_pallas")
    hyper = LDAHyperParams(num_topics=k, alpha=0.1, beta=0.05)
    mask = jnp.ones((b, l), bool)

    def trace(mode):
        kn = SamplerKnobs(kernels=mode)

        def fn(keys, words, z, n_kd, n_wk, n_k):
            return be.infer_sweep(
                keys, words, mask, z, n_kd, n_wk, n_k, hyper, kn
            )

        return jax.make_jaxpr(fn)(
            jax.random.split(jax.random.key(0), b),
            jnp.zeros((b, l), jnp.int32), jnp.zeros((b, l), jnp.int32),
            jnp.zeros((b, k), jnp.int32), jnp.zeros((w, k), jnp.int32),
            jnp.zeros((k,), jnp.int32),
        )

    def token_by_topic(aval):
        shape = getattr(aval, "shape", ())
        return (len(shape) == 2 and isinstance(shape[0], int)
                and shape[0] >= b * l and shape[1] >= k)

    legacy = []
    _collect_avals(trace("off").jaxpr, legacy)
    assert any(token_by_topic(a) for a in legacy)
    fused = []
    _collect_avals(trace("on").jaxpr, fused)
    offenders = [a for a in fused if token_by_topic(a)]
    assert not offenders, offenders


# ---------------------------------------------------------------------------
# kernels on vs off through the real backends
# ---------------------------------------------------------------------------

def _tiny_problem(seed=0):
    corpus, _ = synthetic_lda_corpus(
        seed, num_docs=30, num_words=50, num_topics=8, avg_doc_len=20
    )
    hyper = LDAHyperParams(num_topics=8, alpha=0.1, beta=0.05)
    rng = np.random.default_rng(seed)
    z = jnp.asarray(
        rng.integers(0, 8, corpus.num_tokens).astype(np.int32)
    )
    n_wk, n_kd, n_k = counts_lib.build_counts(
        corpus.word, corpus.doc, z, corpus.num_words, corpus.num_docs, 8
    )
    zeros = jnp.zeros((corpus.num_tokens,), jnp.int32)
    state = CGSState(
        topic=z, prev_topic=z, n_wk=n_wk, n_kd=n_kd, n_k=n_k,
        rng=jax.random.key(3), iteration=jnp.int32(2),
        stale_iters=zeros, same_count=zeros,
    )
    return corpus, hyper, state


BIT_IDENTICAL_BACKENDS = ["zen_pallas", "zen_sparse", "sparselda",
                          "zen_hybrid"]


@pytest.mark.parametrize("alg", BIT_IDENTICAL_BACKENDS)
def test_sweep_dispatch_bit_identity(alg, monkeypatch):
    """For the backends whose kernel replaces an identical op sequence
    (fused gather+sample; cumsum/count/clamp/take row inversion), the
    kernels="on" sweep equals the kernels="off" sweep bit for bit."""
    monkeypatch.delenv("REPRO_KERNELS", raising=False)
    corpus, hyper, state = _tiny_problem()
    be = algorithms.get(alg)
    outs = {}
    for mode in ("off", "on"):
        knobs = be.resolve_cell_knobs(SamplerKnobs(kernels=mode), hyper)
        aux = be.prepare(corpus, hyper, knobs)
        outs[mode] = np.asarray(
            be.sweep(state, corpus, hyper, knobs, aux)
        )
    np.testing.assert_array_equal(outs["on"], outs["off"])


@pytest.mark.parametrize("alg", ["zen_cdf", "lightlda"])
def test_sweep_dispatch_distribution_equal(alg, monkeypatch):
    """zen_cdf (bk-tiled float carry) and lightlda (CDF inversion replaces
    the alias walk) are distribution-equal, not bitwise: the kernel sweep
    must be a valid draw — in range, and mostly agreeing with the legacy
    sweep from the same counts (same conditional, shared randomness for
    zen_cdf's term choice)."""
    monkeypatch.delenv("REPRO_KERNELS", raising=False)
    corpus, hyper, state = _tiny_problem()
    be = algorithms.get(alg)
    outs = {}
    for mode in ("off", "on"):
        knobs = be.resolve_cell_knobs(SamplerKnobs(kernels=mode), hyper)
        aux = be.prepare(corpus, hyper, knobs)
        outs[mode] = np.asarray(be.sweep(state, corpus, hyper, knobs, aux))
    for mode, z in outs.items():
        assert z.dtype == np.int32, (alg, mode)
        assert (z >= 0).all() and (z < hyper.num_topics).all(), (alg, mode)
    # same conditional, same target draws -> the paths disagree only where
    # round-off (zen_cdf) or proposal-chain divergence (lightlda) bites
    diff = float((outs["on"] != outs["off"]).mean())
    assert diff < 0.8, (alg, diff)


def test_zen_pallas_infer_dispatch_bit_identity(monkeypatch):
    """The serving sweep dispatches identically: fused == gathered."""
    monkeypatch.delenv("REPRO_KERNELS", raising=False)
    b, l, k, w = 6, 12, 8, 25
    rng = np.random.default_rng(5)
    be = algorithms.get("zen_pallas")
    hyper = LDAHyperParams(num_topics=k, alpha=0.1, beta=0.05)
    keys = jax.random.split(jax.random.key(11), b)
    words = jnp.asarray(rng.integers(0, w, (b, l)), jnp.int32)
    mask = jnp.asarray(rng.random((b, l)) < 0.9)
    z = jnp.asarray(rng.integers(0, k, (b, l)), jnp.int32)
    n_kd = jnp.asarray(rng.integers(0, 6, (b, k)), jnp.int32)
    n_wk = jnp.asarray(rng.integers(0, 40, (w, k)), jnp.int32)
    n_k = jnp.asarray(np.asarray(n_wk).sum(0), jnp.int32)
    outs = {
        mode: np.asarray(be.infer_sweep(
            keys, words, mask, z, n_kd, n_wk, n_k, hyper,
            SamplerKnobs(kernels=mode),
        ))
        for mode in ("off", "on")
    }
    np.testing.assert_array_equal(outs["on"], outs["off"])


def test_zen_cdf_forced_kernel_training_trend(monkeypatch):
    """A short zen_cdf run with kernels forced on keeps its invariants and
    improves the likelihood — the CDF-search kernel is a drop-in sampler,
    not just a unit-level match."""
    monkeypatch.setenv("REPRO_KERNELS", "on")
    from repro.core import LDATrainer, TrainConfig

    corpus, hyper, state = _tiny_problem()
    tr = LDATrainer(corpus, hyper, TrainConfig(algorithm="zen_cdf"))
    l0 = tr.llh(state)
    st = state
    for _ in range(5):
        st = tr.step(st)
    st.check_invariants(corpus)
    assert tr.llh(st) > l0, (l0, tr.llh(st))


# ---------------------------------------------------------------------------
# forced-kernel mesh parity: the Alg. 2 backends through the UNCHANGED
# harness with the sparse kernel dispatched (acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "alg", ["zen_sparse", "zen_hybrid", "sparselda", "lightlda"]
)
def test_forced_kernel_mesh_parity(alg, monkeypatch):
    """run_with_devices copies os.environ, so setting REPRO_KERNELS here
    forces kernel dispatch inside the subprocess's shard_map cells while
    the parity harness itself stays byte-for-byte unchanged."""
    monkeypatch.setenv("REPRO_KERNELS", "on")
    test_mesh_parity.test_mesh_matches_single_box(alg)


# ---------------------------------------------------------------------------
# autotune
# ---------------------------------------------------------------------------

def test_autotune_sweep_and_apply_best():
    from repro.kernels.autotune import (
        apply_best,
        autotune_cdf,
        autotune_fused,
        autotune_sparse,
    )

    rng = np.random.default_rng(0)
    t, k, w, d, j = 32, 16, 12, 8, 10
    n_wk = jnp.asarray(rng.integers(0, 30, (w, k)), jnp.int32)
    n_kd = jnp.asarray(rng.integers(0, 10, (d, k)), jnp.int32)
    word = jnp.asarray(rng.integers(0, w, (t,)), jnp.int32)
    doc = jnp.asarray(rng.integers(0, d, (t,)), jnp.int32)
    z = jnp.asarray(rng.integers(0, k, (t,)), jnp.int32)
    n_k = jnp.asarray(np.asarray(n_wk).sum(0) + 1, jnp.float32)
    alpha_k = jnp.asarray(rng.random(k) + 0.01, jnp.float32)
    term = jnp.asarray(rng.random(k) + 1e-3, jnp.float32)
    targets = jnp.asarray(rng.random(t) * 5, jnp.float32)
    vals = jnp.asarray(rng.random((t, j)), jnp.float32)
    topics = jnp.asarray(rng.integers(0, k, (t, j)), jnp.int32)

    timings = []
    timings += autotune_fused(
        n_wk, n_kd, word, doc, z, alpha_k, n_k, jnp.int32(7),
        beta=0.01, w_beta=0.16, bts=(8, 16), bks=(128,),
        iters=1, warmup=0,
    )
    timings += autotune_cdf(
        n_wk, word, term, targets, bts=(8, 16), bks=(128,),
        iters=1, warmup=0,
    )
    timings += autotune_sparse(
        vals, topics, targets, bts=(8,), bss=(128, 256),
        iters=1, warmup=0,
    )
    assert len(timings) == 6
    assert {tt.kernel for tt in timings} == \
        {"fused_sample", "cdf_search", "sparse_row"}
    assert all(tt.us_per_call > 0 and tt.tokens_per_sec > 0
               for tt in timings)

    tuned = apply_best(timings, SamplerKnobs())
    # winners land in the swept grid, and re-validation passed (no raise)
    assert tuned.bt in (8, 16)
    assert tuned.bk == 128
    assert tuned.bs in (128, 256)
    assert apply_best([], SamplerKnobs()) == SamplerKnobs()
