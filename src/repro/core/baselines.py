"""Baseline CGS algorithms implemented in the same framework (paper §7.2).

The paper's generality claim is that switching the CGS algorithm is "a few
lines of code change" on the shared substrate: both baselines below consume
the same counts/corpus state and return new per-token topics, so the
iteration driver, distribution, exclusion, metrics, etc. are shared.

* SparseLDA (Yao et al.) — s/r/q three-bucket decomposition with linear
  search; fresh counts (exact ¬dw on the gathered values).
* LightLDA (Yuan et al.) — cycle Metropolis-Hastings alternating the word
  proposal (N_wk+β)/(N_k+Wβ) (alias, stale) and the doc proposal N_kd+α
  (O(1) via a random token of the same doc — the paper's lookup-table trick).

Both use iteration-start (stale) counts, matching how the paper runs them
distributed ("the only difference is the algorithm").
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.alias import AliasTable, build_alias, sample_alias
from repro.core.decompositions import precompute_zen_terms
from repro.core.types import CGSState, Corpus, LDAHyperParams
from repro.core.zen_sparse import SparseRows, lookup_rows, sparsify_rows


# ---------------------------------------------------------------------------
# SparseLDA
# ---------------------------------------------------------------------------

def sparselda_cell(
    key: jax.Array,
    word: jax.Array,  # (T,) shard-local word ids
    doc: jax.Array,  # (T,) shard-local doc ids
    z_old: jax.Array,  # (T,)
    n_wk: jax.Array,  # (Ws, K) local block
    n_kd: jax.Array,  # (Ds, K) local block
    n_k: jax.Array,  # (K,) replicated
    hyper: LDAHyperParams,
    num_words: int,  # global (padded) vocabulary — the W in W*beta
    max_kw: int,
    max_kd: int,
    use_kernel: bool = False,
    bt: int = 256,
    bs: int = 128,
) -> jax.Array:
    """One SparseLDA pass over a cell's tokens (stale counts, exact
    self-exclusion on the gathered values) -> (T,). Shard-relative: the
    padded s/r/q rows are sparsified from the local count blocks only.

    ``use_kernel`` routes the r/q bucket inversions through the
    padded-sparse Pallas kernel (``kernels.sparse_row``), whose op
    sequence matches the XLA form below exactly — dispatch is
    bit-identical. The shared dense s bucket stays on XLA (one (K,) CDF
    for the whole sweep; nothing to fuse)."""
    terms = precompute_zen_terms(n_k, hyper, num_words)
    kd_rows = sparsify_rows(n_kd, max_kd)
    wk_rows = sparsify_rows(n_wk, max_kw)
    w, d, z = word, doc, z_old
    k = hyper.num_topics
    beta = hyper.beta

    t1 = jnp.concatenate([terms.t1, jnp.zeros((1,), jnp.float32)])
    t5 = jnp.concatenate([terms.t5, jnp.zeros((1,), jnp.float32)])
    t4 = jnp.concatenate([terms.t4, jnp.zeros((1,), jnp.float32)])
    alpha_pad = jnp.concatenate([terms.alpha_k, jnp.zeros((1,), jnp.float32)])

    # --- bucket s: alpha_k*beta*t1, dense over K (shared by all tokens) ---
    s_vals = terms.g_dense  # (K,)
    s_mass = jnp.sum(s_vals)

    # --- bucket r: N_kd*beta*t1 over the doc's padded slots (self-excl) ---
    kd_idx = kd_rows.idx[d]  # (T, max_kd)
    kd_cnt = kd_rows.cnt[d]
    self_kd = (kd_idx == z[:, None]).astype(jnp.int32)
    kd_cnt_x = kd_cnt - self_kd
    r_vals = kd_cnt_x.astype(jnp.float32) * t5[kd_idx]
    r_mass = jnp.sum(r_vals, axis=-1)

    # --- bucket q: N_wk*(N_kd+alpha_k)*t1 over the word's padded slots ---
    wk_idx = wk_rows.idx[w]  # (T, max_kw)
    wk_cnt = wk_rows.cnt[w]
    self_wk = (wk_idx == z[:, None]).astype(jnp.int32)
    wk_cnt_x = wk_cnt - self_wk
    n_kd_at = lookup_rows(kd_rows, d, wk_idx)
    n_kd_at = n_kd_at - (wk_idx == z[:, None]).astype(jnp.int32)
    q_coef = n_kd_at.astype(jnp.float32) * t1[wk_idx] + t4[wk_idx]
    q_vals = wk_cnt_x.astype(jnp.float32) * q_coef
    q_mass = jnp.sum(q_vals, axis=-1)

    total = s_mass + r_mass + q_mass
    k_u, k_s = jax.random.split(key)
    u = jax.random.uniform(k_u, w.shape) * total

    # LSearch within each bucket (vectorized as CDF + count; complexity
    # modeled as O(K)/O(K_d)/O(K_w) per Table 1).
    s_cdf = jnp.cumsum(s_vals)
    z_s = jnp.minimum(jnp.sum(s_cdf[None, :] < u[:, None], axis=-1), k - 1)

    r_target = jnp.maximum(u - s_mass, 0.0)
    q_target = jnp.maximum(u - s_mass - r_mass, 0.0)
    if use_kernel:
        from repro.kernels.ops import sparse_row_sample

        z_r = sparse_row_sample(r_vals, kd_idx, r_target, bt=bt, bs=bs)
        z_q = sparse_row_sample(q_vals, wk_idx, q_target, bt=bt, bs=bs)
    else:
        r_cdf = jnp.cumsum(r_vals, axis=-1)
        r_pos = jnp.minimum(
            jnp.sum(r_cdf < r_target[:, None], axis=-1), r_vals.shape[-1] - 1
        )
        z_r = jnp.take_along_axis(kd_idx, r_pos[:, None], axis=-1)[:, 0]
        q_cdf = jnp.cumsum(q_vals, axis=-1)
        q_pos = jnp.minimum(
            jnp.sum(q_cdf < q_target[:, None], axis=-1), q_vals.shape[-1] - 1
        )
        z_q = jnp.take_along_axis(wk_idx, q_pos[:, None], axis=-1)[:, 0]

    z_new = jnp.where(
        u < s_mass, z_s, jnp.where(u < s_mass + r_mass, z_r, z_q)
    )
    return jnp.minimum(z_new, k - 1).astype(jnp.int32)


def sparselda_sweep(
    state: CGSState,
    corpus: Corpus,
    hyper: LDAHyperParams,
    max_kw: int,
    max_kd: int,
    use_kernel: bool = False,
    bt: int = 256,
    bs: int = 128,
) -> jax.Array:
    """One SparseLDA sweep (stale counts, exact self-exclusion). -> (E,)."""
    key = jax.random.fold_in(state.rng, state.iteration)
    return sparselda_cell(
        key, corpus.word, corpus.doc, state.topic,
        state.n_wk, state.n_kd, state.n_k, hyper, corpus.num_words,
        max_kw, max_kd, use_kernel=use_kernel, bt=bt, bs=bs,
    )


# ---------------------------------------------------------------------------
# LightLDA
# ---------------------------------------------------------------------------

class DocIndex(NamedTuple):
    """CSR doc->token index for the O(1) doc proposal (LightLDA's lookup
    table: 'stores the corresponding topic for its word occurrences')."""

    token_of: jax.Array  # (E,) token ids sorted by doc
    offsets: jax.Array  # (D+1,) start of each doc's slice in token_of
    lengths: jax.Array  # (D,)


def build_doc_index(corpus: Corpus) -> DocIndex:
    return build_cell_doc_index(
        corpus.doc, jnp.ones(corpus.doc.shape, bool), corpus.num_docs
    )


def build_cell_doc_index(
    doc: jax.Array, mask: jax.Array, num_docs: int
) -> DocIndex:
    """Trace-compatible ``DocIndex`` over one cell's (possibly padded)
    tokens: masked-out tokens sort to the end behind a sentinel doc id and
    contribute no length, so a doc's slice holds only its live local
    tokens. With an all-true mask this reproduces ``build_doc_index``."""
    sort_key = jnp.where(mask, doc, num_docs)
    order = jnp.argsort(sort_key, stable=True).astype(jnp.int32)
    lengths = (
        jnp.zeros((num_docs,), jnp.int32).at[doc].add(mask.astype(jnp.int32))
    )
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(lengths).astype(jnp.int32)]
    )
    return DocIndex(token_of=order, offsets=offsets, lengths=lengths)


def _true_prob(
    n_wk_m, n_kd_m, n_k_v, w, d, z_self, ks, hyper: LDAHyperParams,
    num_words: int,
):
    """Exact Eq. 3 p(k) at candidate topics ks (T,) with ¬dw exclusion."""
    self_hit = (ks == z_self).astype(jnp.float32)
    n_wk = n_wk_m[w, ks].astype(jnp.float32) - self_hit
    n_kd = n_kd_m[d, ks].astype(jnp.float32) - self_hit
    n_k = n_k_v[ks].astype(jnp.float32) - self_hit
    alpha_k = hyper.alpha_k(n_k_v)[ks]
    return (
        (n_wk + hyper.beta) / (n_k + num_words * hyper.beta) * (n_kd + alpha_k)
    )


def lightlda_cell(
    key: jax.Array,
    word: jax.Array,  # (T,) shard-local word ids
    doc: jax.Array,  # (T,) shard-local doc ids
    z_old: jax.Array,  # (T,)
    mask: jax.Array,  # (T,) bool — False on cell padding
    n_wk: jax.Array,  # (Ws, K) local block
    n_kd: jax.Array,  # (Ds, K) local block
    n_k: jax.Array,  # (K,) replicated
    hyper: LDAHyperParams,
    num_words: int,  # global (padded) vocabulary — the W in W*beta
    doc_index: DocIndex,  # over THIS cell's tokens (shard-local doc ids)
    max_kw: int,
    num_mh: int = 8,
    use_kernel: bool = False,
    bt: int = 256,
    bs: int = 128,
) -> jax.Array:
    """One LightLDA pass over a cell's tokens: ``num_mh`` cycle-MH steps
    per token -> (T,).

    ``use_kernel`` replaces the word proposal's sparse-branch *alias*
    draw with CDF inversion through the padded-sparse Pallas kernel
    (``kernels.sparse_row``) over the same ``N_wk * t1`` density — and
    skips building the per-word alias tables entirely. The proposal
    distribution is unchanged (alias and CDF inversion sample the same
    pmf), so ``word_q`` still describes what was proposed and the MH
    chain stays valid; draws differ bitwise (different uniforms-to-topic
    mapping), matching the backend's statistical cross-path contract.

    Shard-relative: the word-proposal alias rows come from the local
    ``n_wk`` block, and the O(1) doc proposal draws from the doc's tokens
    *within this cell* (its word-shard slice). The proposal's MH density
    must describe what was actually proposed, so ``doc_q`` is evaluated on
    the cell-local doc-topic histogram of ``z_old`` — NOT the synced
    ``n_kd`` block, which counts tokens on other word shards the proposal
    can never draw. Acceptance targets the true conditional from the
    synced blocks, so the chain is a valid MH sampler of Eq. 3 with a
    locality-restricted proposal. Single-box (one cell, all tokens live)
    the histogram equals ``n_kd`` exactly and draws are unchanged.
    """
    k = hyper.num_topics
    beta = hyper.beta
    w, d = word, doc
    terms = precompute_zen_terms(n_k, hyper, num_words)
    alpha_bar = jnp.mean(terms.alpha_k)  # doc proposal uses symmetric alpha
    # the density the doc proposal actually samples from: this cell's live
    # (doc, topic) histogram (== n_kd when the cell is the whole corpus)
    n_kd_cell = (
        jnp.zeros(n_kd.shape, jnp.int32)
        .at[doc, z_old].add(mask.astype(jnp.int32))
    )

    # word proposal = mixture of sparse part N_wk*t1 (per-word alias) and
    # dense part beta*t1 (one global alias shared by every word).
    wk_rows = sparsify_rows(n_wk, max_kw)
    t1 = jnp.concatenate([terms.t1, jnp.zeros((1,), jnp.float32)])
    w_vals = wk_rows.cnt.astype(jnp.float32) * t1[wk_rows.idx]
    # kernel path draws the sparse branch by CDF inversion instead — the
    # per-word alias build (a vmapped O(max_kw) fixpoint per word) is the
    # single biggest table-build cost and is skipped entirely
    w_alias = None if use_kernel else jax.vmap(build_alias)(w_vals)
    w_sparse_mass = jnp.sum(w_vals, axis=-1)  # (W,)
    dense_tab = build_alias(terms.t5)
    dense_mass = jnp.sum(terms.t5)

    n_d = doc_index.lengths.astype(jnp.float32)

    def word_proposal(key, w_ids):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        m_s = w_sparse_mass[w_ids]
        pick_sparse = jax.random.uniform(k1, w_ids.shape) * (m_s + dense_mass) < m_s
        nbins = wk_rows.idx.shape[-1]
        u1 = jax.random.uniform(k2, w_ids.shape)
        u2 = jax.random.uniform(k3, w_ids.shape)
        if use_kernel:
            from repro.kernels.ops import sparse_row_sample

            z_sparse = sparse_row_sample(
                w_vals[w_ids], wk_rows.idx[w_ids], u1 * m_s, bt=bt, bs=bs
            )
        else:
            bins = jnp.minimum((u1 * nbins).astype(jnp.int32), nbins - 1)
            probs = jnp.take_along_axis(w_alias.prob[w_ids], bins[:, None], -1)[:, 0]
            aliases = jnp.take_along_axis(w_alias.alias[w_ids], bins[:, None], -1)[:, 0]
            slot = jnp.where(u2 < probs, bins, aliases)
            z_sparse = jnp.take_along_axis(
                wk_rows.idx[w_ids], slot[:, None], -1
            )[:, 0]
        z_dense = sample_alias(
            dense_tab, jax.random.uniform(k4, w_ids.shape),
            jax.random.uniform(jax.random.fold_in(k4, 1), w_ids.shape),
        )
        z = jnp.where(pick_sparse, z_sparse, z_dense)
        return jnp.minimum(z, k - 1).astype(jnp.int32)

    def word_q(w_ids, ks, z_self):
        """q_w(k) ∝ (N_wk + beta) * t1[k], with self-exclusion skipped —
        LightLDA proposals are stale by construction."""
        return (n_wk[w_ids, ks].astype(jnp.float32) + beta) * terms.t1[ks]

    def doc_proposal(key, d_ids):
        k1, k2, k3 = jax.random.split(key, 3)
        mass_doc = n_d[d_ids]
        pick_doc = (
            jax.random.uniform(k1, d_ids.shape) * (mass_doc + k * alpha_bar)
            < mass_doc
        )
        # O(1): topic of a uniformly random token of the same doc
        u = jax.random.uniform(k2, d_ids.shape)
        tok = doc_index.offsets[d_ids] + jnp.minimum(
            (u * jnp.maximum(mass_doc, 1.0)).astype(jnp.int32),
            jnp.maximum(doc_index.lengths[d_ids] - 1, 0),
        )
        z_doc = z_old[doc_index.token_of[tok]]
        z_unif = jax.random.randint(k3, d_ids.shape, 0, k, dtype=jnp.int32)
        return jnp.where(pick_doc, z_doc, z_unif)

    def doc_q(d_ids, ks):
        return n_kd_cell[d_ids, ks].astype(jnp.float32) + alpha_bar

    z0 = z_old

    def mh_step(i, carry):
        z_cur, key = carry
        key, k_prop, k_acc = jax.random.split(key, 3)
        use_word = (i % 2) == 0  # cycle proposal: word, doc, word, doc ...

        z_w = word_proposal(k_prop, w)
        z_d = doc_proposal(k_prop, d)
        z_new = jnp.where(use_word, z_w, z_d)

        p_new = _true_prob(n_wk, n_kd, n_k, w, d, z0, z_new, hyper, num_words)
        p_old = _true_prob(n_wk, n_kd, n_k, w, d, z0, z_cur, hyper, num_words)
        q_new = jnp.where(use_word, word_q(w, z_new, z0), doc_q(d, z_new))
        q_old = jnp.where(use_word, word_q(w, z_cur, z0), doc_q(d, z_cur))
        ratio = (p_new * q_old) / jnp.maximum(p_old * q_new, 1e-30)
        accept = jax.random.uniform(k_acc, z_cur.shape) < jnp.minimum(ratio, 1.0)
        return jnp.where(accept, z_new, z_cur), key

    z, _ = jax.lax.fori_loop(0, num_mh, mh_step, (z0, key))
    return z.astype(jnp.int32)


def lightlda_sweep(
    state: CGSState,
    corpus: Corpus,
    hyper: LDAHyperParams,
    doc_index: DocIndex,
    max_kw: int,
    num_mh: int = 8,
    use_kernel: bool = False,
    bt: int = 256,
    bs: int = 128,
) -> jax.Array:
    """One LightLDA sweep: ``num_mh`` cycle-MH steps per token. -> (E,)."""
    key = jax.random.fold_in(state.rng, state.iteration)
    mask = jnp.ones(corpus.word.shape, bool)
    return lightlda_cell(
        key, corpus.word, corpus.doc, state.topic, mask,
        state.n_wk, state.n_kd, state.n_k, hyper, corpus.num_words,
        doc_index, max_kw, num_mh=num_mh,
        use_kernel=use_kernel, bt=bt, bs=bs,
    )
