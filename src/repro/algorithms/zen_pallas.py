"""``zen_pallas`` — the fused Gumbel-max Pallas kernel as a first-class
backend (headline hot path; ``zen_dense_kernel`` kept as the legacy alias).

One fused VMEM pass streams K-tiles of the three-term conditional and keeps
only a running (max, argmax) carry per token: no normalization, no
materialized (T, K) probability matrix in HBM, no second pass (see
``kernels/zen_sampler.py`` and DESIGN.md §2). On CPU the same kernel runs
in interpret mode, bit-identical to the ``kernels/ref.py`` oracle, so the
backend is selectable everywhere: kernel on TPU, interpreted ref on CPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.algorithms.base import CellBackend, SamplerKnobs, chunked_token_map
from repro.algorithms.registry import register


@register("zen_pallas", "zen_dense_kernel")
class ZenPallas(CellBackend):
    """Fused three-term Gumbel-max sampler (Pallas TPU kernel)."""

    native_infer = True

    def infer_sweep(
        self, keys, words, mask, z_old, n_kd, n_wk, n_k, hyper,
        knobs: SamplerKnobs, aux=None,
    ):
        """Frozen-model serving through the unchanged fused kernel.

        The kernel applies exact ¬dw exclusion to all three counts
        in-register; for frozen-phi inference only the *doc* side may be
        excluded, so the gathered word rows are pre-compensated with the
        token's own one-hot (the kernel's subtraction then restores the
        frozen N_w|k exactly). N_k is shared across the batch and cannot
        be compensated per token, so the denominator is off by one at the
        token's current topic — a < 1/N_k relative approximation the
        serving tests bound statistically.

        Randomness caveat: the kernel draws counter-based noise from ONE
        scalar seed and the flat token coordinates, so this backend does
        not honor the per-slot-key bit-stability contract of the default
        derivation — results are statistically exchangeable but depend on
        batch layout. The seed mixes *every* slot's key (not just
        keys[0]) so it changes every sweep even when some slots are
        vacant and holding the engine's constant dummy key (a fixed seed
        would degenerate the Gibbs chain into an iterated deterministic
        map). A frozen-model kernel variant with per-slot seeds is a
        ROADMAP follow-up.
        """
        from repro.kernels.ops import zen_sample

        b, l = words.shape
        k = hyper.num_topics
        slot = jax.lax.broadcasted_iota(jnp.int32, (b, l), 0).reshape(-1)
        w = words.reshape(-1)
        z = z_old.reshape(-1)
        live = mask.reshape(-1).astype(jnp.int32)

        onehot = jax.nn.one_hot(z, k, dtype=jnp.int32) * live[:, None]
        nwk_rows = n_wk[w].astype(jnp.int32) + onehot
        nkd_rows = n_kd[slot].astype(jnp.int32)
        alpha_k = hyper.alpha_k(n_k)
        w_beta = n_wk.shape[0] * hyper.beta
        # fold the slot index in before XOR-mixing so identical keys in two
        # slots (or the engine's repeated dummy key) can never cancel out
        mixed = jax.vmap(jax.random.fold_in)(keys, jnp.arange(b))
        key_bits = jax.random.key_data(mixed).astype(jnp.uint32).reshape(-1)
        folded = jax.lax.reduce(
            key_bits, jnp.uint32(0), jax.lax.bitwise_xor, (0,)
        )
        seed = (folded & jnp.uint32(0x7FFFFFFF)).astype(jnp.int32)
        out = zen_sample(
            nwk_rows, nkd_rows, z, alpha_k, n_k.astype(jnp.float32), seed,
            beta=hyper.beta, w_beta=w_beta, bt=knobs.bt, bk=knobs.bk,
        )
        return out.reshape(b, l)

    def cell_sweep(
        self, key, word, doc, z_old, mask, n_wk, n_kd, n_k, hyper,
        num_words_pad, knobs: SamplerKnobs,
    ):
        # lazy: keep pallas out of the import path of everything that
        # never selects this backend
        from repro.kernels.ops import zen_sample

        alpha_k = hyper.alpha_k(n_k)
        n_k_f = n_k.astype(jnp.float32)
        w_beta = num_words_pad * hyper.beta

        def chunk(args):
            w, d, z, subkey = args
            seed = jax.random.randint(
                subkey, (), 0, jnp.iinfo(jnp.int32).max, dtype=jnp.int32
            )
            # int32 casts: the kernel tiles assume 4-byte count rows (the
            # distributed path may hold N_kd in int16)
            return zen_sample(
                n_wk[w].astype(jnp.int32), n_kd[d].astype(jnp.int32), z,
                alpha_k, n_k_f, seed,
                beta=hyper.beta, w_beta=w_beta, bt=knobs.bt, bk=knobs.bk,
            )

        # chunking bounds the gathered (chunk, K) row tiles in HBM
        return chunked_token_map(
            chunk, key, (word, doc, z_old), knobs.token_chunk
        )
