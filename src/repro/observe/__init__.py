"""Observability layer: measure first, then decide (DESIGN.md §8).

The paper's central systems argument is that the right sampler/layout is
a function of *measured* state — the hybrid backend picks its
decomposition per word by row sparsity (§3.2), and the scheduling stance
of the related model-parallel serving work extends the same argument to
admission knobs. This package is the shared measurement half of that
loop: a lightweight counter/gauge/histogram registry with
monotonic-clock span timers and a JSONL sink (``repro.observe.metrics``),
plus two built-in emitters —

* ``TrainTelemetry`` (``repro.observe.train_hooks``): a per-iteration
  ``TrainSession`` hook recording tokens/sec, per-backend row-nnz
  histograms from the live counts, the padded-row widths in effect, and
  whatever the eval action computed (llh/perplexity/change rate);
* ``ServeTelemetry`` (``repro.observe.serve_hooks``): a per-admission-tick
  ``LDAEngine`` hook recording arrival inter-times (from the existing
  ``t_submit``/``t_done`` stamps), queue depth, bucket occupancy, spill
  counts, and windowed latency summaries; ``LDARouter`` adds per-replica
  load records on the same sink.

The deciding half lives in ``repro.autotune`` (the ``Autopilot``); this
package never *acts*, it only measures and serializes.
"""
from repro.observe.metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    JsonlSink,
    MetricsRegistry,
    SpanTimer,
    latency_percentile,
    nnz_row_stats,
    summarize_latencies,
)
from repro.observe.serve_hooks import ServeTelemetry  # noqa: F401
from repro.observe.train_hooks import TrainTelemetry  # noqa: F401
