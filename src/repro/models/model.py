"""Public model API: init / forward / decode / cache construction.

``forward`` covers train and prefill (full-sequence) compute; ``decode_step``
is the cached single-token serving step. Families dispatch on the config:

  dense | moe | vlm   single scanned decoder stack (gemma3 pattern included)
  ssm                 mamba1 stack (falcon-mamba)
  hybrid              mamba2 + shared attention (zamba2)
  encdec              whisper encoder-decoder (stub frontend embeddings)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.attention import KVCache, cross_kv, init_attn
from repro.models.layers import embed, init_embed, init_mlp, init_norm, norm, unembed
from repro.models.ssm import SSMCache, d_inner_of, init_mamba1, init_mamba2
from repro.models.transformer import (
    _scan_layers,
    _scan_layers_cache,
    decoder_layer,
    decoder_layer_decode,
    encdec_decode,
    encdec_forward,
    hybrid_decode,
    hybrid_forward,
    init_decoder_layer,
    pattern_counts,
    patterned_decode,
    patterned_forward,
    _stack_init,
)


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(rng: jax.Array, cfg: ArchConfig) -> Dict[str, Any]:
    dtype = _dtype(cfg)
    keys = jax.random.split(rng, 8)
    params: Dict[str, Any] = {
        "embed": init_embed(keys[0], cfg.padded_vocab_size, cfg.d_model, dtype),
        "final_norm": init_norm(keys[1], cfg.d_model, cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_embed(
            keys[2], cfg.padded_vocab_size, cfg.d_model, dtype
        )

    def dec_layer(k):
        return init_decoder_layer(k, cfg, dtype)

    if cfg.family in ("dense", "moe", "vlm"):
        if cfg.local_global_pattern:
            n_groups, n_global, rem = pattern_counts(cfg)
            n_local = cfg.num_layers - n_global
            params["local"] = _stack_init(keys[3], n_local, dec_layer)
            params["global"] = _stack_init(keys[4], n_global, dec_layer)
        else:
            params["layers"] = _stack_init(keys[3], cfg.num_layers, dec_layer)
    elif cfg.family == "ssm":
        def ssm_layer(k):
            return {
                "ln": init_norm(k, cfg.d_model, cfg),
                "m": init_mamba1(k, cfg, dtype),
            }

        params["layers"] = _stack_init(keys[3], cfg.num_layers, ssm_layer)
    elif cfg.family == "hybrid":
        def m2_layer(k):
            return {
                "ln": init_norm(k, cfg.d_model, cfg),
                "m": init_mamba2(k, cfg, dtype),
            }

        params["mamba"] = _stack_init(keys[3], cfg.num_layers, m2_layer)
        ks = jax.random.split(keys[4], 4)
        params["shared_attn"] = {
            "ln1": init_norm(ks[0], cfg.d_model, cfg),
            "attn": init_attn(ks[1], cfg, dtype),
            "ln2": init_norm(ks[2], cfg.d_model, cfg),
            "mlp": init_mlp(ks[3], cfg.d_model, cfg.d_ff, cfg, dtype),
        }
    elif cfg.family == "encdec":
        def enc_layer(k):
            ks = jax.random.split(k, 3)
            return {
                "ln1": init_norm(ks[0], cfg.d_model, cfg),
                "attn": init_attn(ks[1], cfg, dtype),
                "ln2": init_norm(ks[2], cfg.d_model, cfg),
                "mlp": init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg, dtype),
            }

        def dec_layer_ed(k):
            ks = jax.random.split(k, 5)
            return {
                "ln1": init_norm(ks[0], cfg.d_model, cfg),
                "self_attn": init_attn(ks[1], cfg, dtype),
                "ln_x": init_norm(ks[2], cfg.d_model, cfg),
                "cross_attn": init_attn(ks[3], cfg, dtype),
                "ln2": init_norm(ks[4], cfg.d_model, cfg),
                "mlp": init_mlp(ks[4], cfg.d_model, cfg.d_ff, cfg, dtype),
            }

        params["encoder"] = _stack_init(
            keys[3], cfg.num_encoder_layers or cfg.num_layers, enc_layer
        )
        params["enc_norm"] = init_norm(keys[5], cfg.d_model, cfg)
        params["decoder"] = _stack_init(keys[4], cfg.num_layers, dec_layer_ed)
    else:
        raise ValueError(cfg.family)
    return params


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def forward(
    params: Dict[str, Any],
    cfg: ArchConfig,
    tokens: Optional[jax.Array] = None,  # (B, S) — None for pure-embeds input
    embeds: Optional[jax.Array] = None,  # (B, S, D) stub-frontend output
    positions: Optional[jax.Array] = None,  # (B, S) or (B, S, 3) for M-RoPE
    enc_embeds: Optional[jax.Array] = None,  # (B, S_enc, D) whisper frontend
) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward. Returns (logits (B,S,V), aux_loss ())."""
    if embeds is None:
        x = embed(tokens, params["embed"])
    else:
        x = embeds
    b, s = x.shape[:2]
    if positions is None:
        base = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        positions = (
            jnp.broadcast_to(base[..., None], (b, s, 3)) if cfg.mrope else base
        )

    if cfg.family == "encdec":
        assert enc_embeds is not None, "whisper needs frontend embeddings"
        ep = jnp.broadcast_to(
            jnp.arange(enc_embeds.shape[1], dtype=jnp.int32)[None],
            enc_embeds.shape[:2],
        )
        x, aux = encdec_forward(params, cfg, enc_embeds, x, ep, positions)
    elif cfg.family == "hybrid":
        x, aux = hybrid_forward(params, cfg, x, positions)
    elif cfg.family == "ssm":
        from repro.models.ssm import mamba1_block

        def body(x, lp):
            h = norm(x, lp["ln"], cfg)
            return x + mamba1_block(h, lp["m"], cfg), jnp.zeros((), jnp.float32)

        x, aux = _scan_layers(body, x, params["layers"], cfg)
    elif cfg.local_global_pattern:
        x, aux = patterned_forward(params, cfg, x, positions)
    else:
        def body(x, lp):
            return decoder_layer(x, lp, cfg, positions,
                                 window=cfg.sliding_window)

        x, aux = _scan_layers(body, x, params["layers"], cfg)

    x = norm(x, params["final_norm"], cfg)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return unembed(x, head), aux


def loss_fn(
    params: Dict[str, Any],
    cfg: ArchConfig,
    batch: Dict[str, jax.Array],
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token cross-entropy (+ MoE aux)."""
    logits, aux = forward(
        params, cfg,
        tokens=batch.get("tokens"),
        embeds=batch.get("embeds"),
        positions=batch.get("positions"),
        enc_embeds=batch.get("enc_embeds"),
    )
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    logits = logits.astype(jnp.float32)
    if cfg.padded_vocab_size != cfg.vocab_size:
        # vocab-padding columns can never be predicted. Masked with an iota
        # compare: elementwise, so the sharded vocab dim is untouched (a
        # concat/slice at a non-shard boundary forces a full reshard and
        # batch replication — measured 40 GB/buffer in the dry-run profile,
        # EXPERIMENTS.md §Perf iteration q1).
        vocab_ids = jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, logits.ndim - 1
        )
        logits = jnp.where(vocab_ids < cfg.vocab_size, logits, -1e30)
    logz = jax.nn.logsumexp(logits, axis=-1)
    # gold logit via one-hot contraction: keeps the vocab-sharded layout
    # (take_along_axis gathers on the sharded dim and ends in a reshard)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        denom = jnp.maximum(jnp.sum(mask), 1.0)
    else:
        denom = float(np.prod(labels.shape))
    ce = jnp.sum(nll) / denom
    return ce + aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# caches + decode
# ---------------------------------------------------------------------------

def init_cache(
    cfg: ArchConfig,
    batch: int,
    s_max: int,
    length: int = 0,
    s_enc: int = 0,
    abstract: bool = False,
) -> Any:
    """Zeroed (or abstract ShapeDtypeStruct) decode cache pytree."""
    dtype = _dtype(cfg)
    kvh = cfg.num_kv_heads
    hd = cfg.resolved_head_dim

    def make(shape, dt=dtype):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dt)
        return jnp.zeros(shape, dt)

    def length_arr(n):
        if abstract:
            return jax.ShapeDtypeStruct((n,), jnp.int32)
        return jnp.full((n,), length, jnp.int32)

    if cfg.family in ("dense", "moe", "vlm") and not cfg.local_global_pattern:
        l = cfg.num_layers
        if cfg.mla is not None:
            m = cfg.mla
            lat = m.kv_lora_rank + m.qk_rope_head_dim
            return KVCache(
                k=make((l, batch, s_max, 1, lat)), v=None, length=length_arr(l)
            )
        return KVCache(
            k=make((l, batch, s_max, kvh, hd)),
            v=make((l, batch, s_max, kvh, hd)),
            length=length_arr(l),
        )
    if cfg.local_global_pattern:
        n_groups, n_global, rem = pattern_counts(cfg)
        n_local = cfg.num_layers - n_global
        s_loc = min(cfg.sliding_window, s_max) if cfg.sliding_window else s_max
        return {
            "local": KVCache(
                k=make((n_local, batch, s_loc, kvh, hd)),
                v=make((n_local, batch, s_loc, kvh, hd)),
                length=length_arr(n_local),
            ),
            "global": KVCache(
                k=make((n_global, batch, s_max, kvh, hd)),
                v=make((n_global, batch, s_max, kvh, hd)),
                length=length_arr(n_global),
            ),
        }
    if cfg.family == "ssm":
        l = cfg.num_layers
        di = d_inner_of(cfg)
        return SSMCache(
            conv=make((l, batch, cfg.ssm.conv_dim - 1, di)),
            state=make((l, batch, di, cfg.ssm.state_dim), jnp.float32),
        )
    if cfg.family == "hybrid":
        l = cfg.num_layers
        di = d_inner_of(cfg)
        h = di // cfg.ssm.head_dim
        n_groups = l // cfg.hybrid_attn_every
        return {
            "mamba": SSMCache(
                conv=make((l, batch, cfg.ssm.conv_dim - 1,
                           di + 2 * cfg.ssm.state_dim)),
                state=make(
                    (l, batch, h, cfg.ssm.state_dim, cfg.ssm.head_dim),
                    jnp.float32,
                ),
            ),
            "attn": KVCache(
                k=make((n_groups, batch, s_max, kvh, hd)),
                v=make((n_groups, batch, s_max, kvh, hd)),
                length=length_arr(n_groups),
            ),
        }
    if cfg.family == "encdec":
        l = cfg.num_layers
        return {
            "self": KVCache(
                k=make((l, batch, s_max, kvh, hd)),
                v=make((l, batch, s_max, kvh, hd)),
                length=length_arr(l),
            ),
            "cross_k": make((l, batch, s_enc, kvh, hd)),
            "cross_v": make((l, batch, s_enc, kvh, hd)),
        }
    raise ValueError(cfg.family)


def decode_step(
    params: Dict[str, Any],
    cfg: ArchConfig,
    token: jax.Array,  # (B,) int32
    caches: Any,
) -> Tuple[jax.Array, Any]:
    """One cached decode step. Returns (logits (B, V), new caches)."""
    x = embed(token[:, None], params["embed"])

    if cfg.family == "encdec":
        x, caches = encdec_decode(params, cfg, x, caches)
    elif cfg.family == "hybrid":
        x, caches = hybrid_decode(params, cfg, x, caches)
    elif cfg.family == "ssm":
        from repro.models.ssm import mamba1_decode

        def body(x, lp, c):
            h = norm(x, lp["ln"], cfg)
            y, c2 = mamba1_decode(h, lp["m"], cfg, c)
            return x + y, c2, None

        x, caches = _scan_layers_cache(body, x, params["layers"], caches,
                                       cfg)
    elif cfg.local_global_pattern:
        x, caches = patterned_decode(params, cfg, x, caches)
    else:
        def body(x, lp, c):
            return decoder_layer_decode(x, lp, cfg, c,
                                        window=cfg.sliding_window)

        x, caches = _scan_layers_cache(body, x, params["layers"], caches,
                                       cfg)

    x = norm(x, params["final_norm"], cfg)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return unembed(x[:, 0], head)[..., : cfg.vocab_size], caches


def prefill_with_cache(
    params: Dict[str, Any],
    cfg: ArchConfig,
    tokens: jax.Array,  # (B, S)
    s_max: int,
) -> Tuple[jax.Array, Any]:
    """Forward + KV cache emission (plain dense/GQA stacks only — the
    serving-engine path; other families decode from an empty cache)."""
    assert cfg.family in ("dense", "vlm", "moe")
    assert not cfg.local_global_pattern and cfg.mla is None
    from repro.models.attention import _qkv

    b, s = tokens.shape
    x = embed(tokens, params["embed"])
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    pos3 = (
        jnp.broadcast_to(positions[..., None], (b, s, 3)) if cfg.mrope
        else positions
    )

    def body(x, lp):
        h = norm(x, lp["ln1"], cfg)
        q, k, v = _qkv(h, lp["attn"], cfg, pos3, cfg.rope_theta)
        from repro.models.attention import _mask_bias, attend

        bias = _mask_bias(positions, positions, True, cfg.sliding_window)
        o = attend(q, k, v, bias)
        x = x + jnp.einsum(
            "bsk,kd->bsd", o.reshape(b, s, -1), lp["attn"]["wo"]
        )
        h2 = norm(x, lp["ln2"], cfg)
        if cfg.moe is not None:
            from repro.models.moe import moe_block
            from repro.models.layers import mlp as mlp_fn

            y, _ = moe_block(h2, lp["moe"], cfg)
            if cfg.moe.dense_residual:
                y = y + mlp_fn(h2, lp["mlp"], cfg)
        else:
            from repro.models.layers import mlp as mlp_fn

            y = mlp_fn(h2, lp["mlp"], cfg)
        pad = s_max - s
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return x + y, (kc, vc)

    from repro.models.transformer import scan_or_unroll

    x, (ks, vs) = scan_or_unroll(cfg, body, x, params["layers"])
    x = norm(x, params["final_norm"], cfg)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(x[:, -1], head)
    caches = KVCache(
        k=ks, v=vs, length=jnp.full((cfg.num_layers,), s, jnp.int32)
    )
    return logits, caches
