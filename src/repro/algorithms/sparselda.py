"""``sparselda`` — SparseLDA (Yao et al.) on the shared substrate (paper
§7.2): s/r/q three-bucket decomposition with linear search, fresh counts."""
from __future__ import annotations

from repro.algorithms.base import SamplerBackend, SamplerKnobs
from repro.algorithms.registry import register
from repro.core.baselines import sparselda_sweep


@register("sparselda")
class SparseLDA(SamplerBackend):
    """s/r/q bucket sampler; work/token tracks O(K_d + K_w)."""

    needs_row_pads = True

    def sweep(self, state, corpus, hyper, knobs: SamplerKnobs, aux=None):
        return sparselda_sweep(
            state, corpus, hyper, knobs.max_kw, knobs.max_kd
        )
