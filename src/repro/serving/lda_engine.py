"""Batched topic-inference serving engine over a frozen trained model.

This is the deployment half of the paper's system (§4.3 "Model
inference"): training produces ``N_w|k``/``N_k``; downstream traffic is
unseen documents whose topic mixture theta must be inferred at high
throughput — or, for millisecond SLAs, at low latency. The engine:

* freezes the trained counts into a :class:`FrozenLDAModel` (plus any
  backend-specific sampling tables via ``SamplerBackend.prepare_infer`` —
  e.g. ``zen_cdf`` builds its per-word CDFs once, for the engine's whole
  lifetime);
* packs incoming documents into **length-bucketed padded batches** — one
  slot array per bucket width, so every jitted sweep sees a fixed shape
  and XLA compiles each bucket exactly once;
* decodes through one of two execution plans (DESIGN.md §5.1):

  - ``mode="throughput"`` (default) — continuously-admitting
    multi-document CGS sweeps through the ``repro.algorithms`` registry's
    ``infer_sweep`` capability: one sweep per step, finished slots are
    refilled from the queue every step (continuous batching applied to
    Gibbs chains);
  - ``mode="latency"`` — the RT-LDA fast path: each admission tick runs a
    **single fused** deterministic decode per non-empty bucket
    (``repro.core.inference.rtlda_assign`` vmapped over slots — argmax
    sweeps, no burn-in chains, no thinning, no RNG), so every admitted
    request completes in that same tick. One dispatch per decode instead
    of ``num_sweeps`` chained dispatches.

* fronts both plans with an **async ticket API** — :meth:`LDAEngine.submit_async`
  returns a ticket immediately, :meth:`LDAEngine.poll` reports the ticket
  lifecycle (``queued -> admitted -> done``), and :meth:`LDAEngine.result`
  blocks (with optional timeout) and reaps. Requests arriving between
  ticks coalesce into the next tick's batch instead of blocking the
  caller; an optional background ticker (:meth:`LDAEngine.start`) drives
  admission at a fixed ``tick_period``;

* supports **hot model reload** (DESIGN.md §7): :meth:`LDAEngine.reload`
  atomically swaps in a new :class:`FrozenLDAModel` between admission
  ticks. Versioned model slots make the swap safe under load — every
  request is stamped with the version it decodes under
  (``InferRequest.model_version``), a bucket's in-flight slots always
  finish on the model they were admitted under (the bucket pins its
  model slot until it drains), and a request admitted after the swap
  decodes under the new model. :meth:`LDAEngine.watch_checkpoint_dir`
  turns this into a live train→serve pipeline: poll a model checkpoint
  directory and reload every new step the trainer commits.

Statistical contract (throughput mode): each request's chain consumes
randomness only from its own key, with the same schedule as the
single-doc oracle ``repro.core.inference.cgs_infer`` (z0 from
``randint(key)``, sweep j from ``split(key)[j]``). For the default
(dense) backend with cdf sampling this makes a served document's theta
*bit-identical* to ``cgs_infer(key, ...)`` regardless of bucket padding
or batch composition — the property ``tests/test_lda_engine.py`` pins
down. Latency mode is fully deterministic: the same document always
yields bit-identical topic assignments for every bucketing, batch
composition, submission order, and engine seed — engine-to-engine thetas
are therefore bit-equal too, and they match the single-doc
``rtlda_infer`` oracle to float tolerance (the engine's theta arithmetic
is numpy, the oracle's is XLA; the count inputs are integer-identical)
(``tests/test_latency_serving.py``).
"""
from __future__ import annotations

import dataclasses
import json
import logging
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import algorithms
from repro.algorithms import SamplerKnobs
from repro.core.inference import rtlda_assign
from repro.core.types import LDAHyperParams
# canonical home of the percentile math is the observability layer; the
# import keeps the historical ``repro.serving.latency_percentile`` working
from repro.observe.metrics import latency_percentile  # noqa: F401
from repro.serving.sharded import (
    ShardedFrozenLDAModel,
    layout_key,
    make_sharded_sweep_fn,
    sharded_prepare_infer,
)

logger = logging.getLogger(__name__)


@dataclasses.dataclass(frozen=True)
class FrozenLDAModel:
    """A trained LDA model frozen for serving.

    Attributes:
        n_wk: ``(W, K)`` int32 word-topic counts from training.
        n_k: ``(K,)`` int32 per-topic totals (``n_wk.sum(0)``).
        hyper: the :class:`~repro.core.types.LDAHyperParams` the model was
            trained with (``num_topics``, alpha, beta).

    The counts never change while serving; backends may precompute
    sampling tables from them once (``SamplerBackend.prepare_infer``).
    Build one with :meth:`from_state` (from a live trainer state) or
    :meth:`from_checkpoint` (from the artifact ``launch/train.py
    --checkpoint-dir`` writes).
    """

    n_wk: jax.Array  # (W, K) int32 word-topic counts
    n_k: jax.Array  # (K,) int32 topic totals
    hyper: LDAHyperParams

    @property
    def num_words(self) -> int:
        """Vocabulary size W (token ids outside ``[0, W)`` are unknown)."""
        return int(self.n_wk.shape[0])

    @property
    def num_topics(self) -> int:
        """Topic count K — the length of every served theta."""
        return int(self.n_wk.shape[1])

    def phi(self) -> jax.Array:
        """Smoothed topic-word distributions, (W, K) column-normalized."""
        w_beta = self.num_words * self.hyper.beta
        return (self.n_wk.astype(jnp.float32) + self.hyper.beta) / (
            self.n_k.astype(jnp.float32) + w_beta
        )[None, :]

    @classmethod
    def from_state(cls, state, hyper: LDAHyperParams) -> "FrozenLDAModel":
        """Freeze a trainer ``CGSState`` (single-box or gathered).

        Args:
            state: any object with ``n_wk``/``n_k`` count arrays (a
                ``CGSState`` or the session's gathered model arrays).
            hyper: the hyper-parameters used in training.
        """
        return cls(
            n_wk=jnp.asarray(state.n_wk, jnp.int32),
            n_k=jnp.asarray(state.n_k, jnp.int32),
            hyper=hyper,
        )

    @classmethod
    def from_checkpoint(cls, directory: str) -> "FrozenLDAModel":
        """Load the newest committed model checkpoint (see
        ``repro.train.checkpoint.save_lda_model``)."""
        from repro.train.checkpoint import load_lda_model

        n_wk, n_k, hyper, _meta, _step = load_lda_model(directory)
        return cls(
            n_wk=jnp.asarray(n_wk, jnp.int32),
            n_k=jnp.asarray(n_k, jnp.int32),
            hyper=hyper,
        )


@dataclasses.dataclass(frozen=True)
class LDAServeConfig:
    """Engine knobs.

    Execution plan: ``mode="throughput"`` (default) runs chain-based CGS
    sweeps through the registry backend ``algorithm``; ``mode="latency"``
    runs the deterministic RT-LDA fast path (``rtlda_sweeps`` fused argmax
    passes, one dispatch per bucket per tick, no RNG — per-request
    ``key``/``num_sweeps``/``burn_in``/``thin`` are ignored).

    Chain estimator (throughput mode): ``burn_in < 0`` (default)
    reproduces the oracle estimator — theta from the final sweep's
    doc-topic counts. ``burn_in >= 0`` switches to the posterior-mean
    estimator: counts are sampled every ``thin`` sweeps after the first
    ``burn_in`` and theta is their average — better quality per sweep, no
    longer bit-comparable to ``cgs_infer``.

    SLA knobs (DESIGN.md §5.1): ``tick_period`` is the background
    ticker's admission cadence in seconds (:meth:`LDAEngine.start`; 0
    picks a 1 ms default); ``max_slot_wait`` bounds queueing at a
    saturated bucket — a request that has waited that many ticks for its
    preferred (smallest-fit) bucket may spill into any wider bucket with
    a free slot (0 = strict smallest-fit forever).

    Sharded serving (DESIGN.md §5.4): ``mesh_shape`` = ``(1, m)`` lays
    the frozen model's word rows over an ``m``-way ``model`` axis
    (:class:`~repro.serving.sharded.ShardedFrozenLDAModel`) and runs
    every bucket sweep as a ``shard_map`` dispatch. The data dim must be
    1 — replica parallelism comes from ``serving.router.LDARouter``, not
    a data axis — and latency mode (RT-LDA) does not shard. ``None``
    (default) serves single-host.
    """

    buckets: Tuple[int, ...] = (32, 64, 128, 256)
    max_batch: int = 32  # slots per bucket
    num_sweeps: int = 10
    burn_in: int = -1  # < 0 => final-sweep theta (oracle-compatible)
    thin: int = 1
    algorithm: str = "zen"  # any algorithms.registered() name
    sampling_method: str = "cdf"  # cdf | gumbel (dense default path)
    max_kd: int = 0  # zen_cdf doc-row width (0 = backend default)
    mode: str = "throughput"  # throughput | latency (RT-LDA fast path)
    rtlda_sweeps: int = 2  # latency mode: fused deterministic passes
    tick_period: float = 0.0  # background ticker cadence, s (0 = 1 ms)
    max_slot_wait: int = 0  # ticks before bucket spill (0 = never spill)
    kernels: str = "auto"  # Pallas kernel dispatch: auto | on | off
    mesh_shape: Optional[Tuple[int, int]] = None  # (1, m) word shards
    # -- observability + autopilot (DESIGN.md §8): all inert by default ----
    metrics_out: Optional[str] = None  # telemetry JSONL path (None = off)
    autopilot: bool = False  # derive tick_period/max_slot_wait/buckets
    autopilot_window: int = 0  # arrivals per decision window (0 = 64)

    def knobs(self) -> SamplerKnobs:
        return SamplerKnobs(
            sampling_method=self.sampling_method, max_kd=self.max_kd,
            kernels=self.kernels,
        )

    # -- serialization (mirrors RunConfig: a serving setup is a file) ------
    def to_json(self, indent: Optional[int] = 2) -> str:
        d = dataclasses.asdict(self)
        d["buckets"] = list(d["buckets"])
        if d["mesh_shape"] is not None:
            d["mesh_shape"] = list(d["mesh_shape"])
        return json.dumps(d, indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "LDAServeConfig":
        d = json.loads(text)
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(d) - known)
        if unknown:
            raise ValueError(
                f"unknown LDAServeConfig fields: {', '.join(unknown)}"
            )
        if d.get("buckets") is not None:
            d["buckets"] = tuple(int(x) for x in d["buckets"])
        if d.get("mesh_shape") is not None:
            d["mesh_shape"] = tuple(int(x) for x in d["mesh_shape"])
        return cls(**d)


@dataclasses.dataclass
class InferRequest:
    """One in-flight (or finished) serving request.

    ``theta`` is the (K,) doc-topic distribution once ``done``; ``z`` is
    the final per-token assignment (latency mode only). ``t_submit`` /
    ``t_done`` are ``time.monotonic`` stamps for latency accounting.
    ``model_version`` is the version tag of the model the request decoded
    under (stamped at admission — or at submit for instantly-completed
    requests; ``-1`` until then), the diagnostic that makes hot reloads
    auditable per request.
    """

    uid: int
    words: np.ndarray  # filtered (and possibly truncated) token ids
    key: Optional[jax.Array]  # whole-chain PRNG key (throughput mode)
    num_sweeps: int
    burn_in: int
    thin: int
    orig_len: int = 0
    truncated: bool = False
    dropped_unknown: int = 0
    theta: Optional[np.ndarray] = None
    done: bool = False
    # lifecycle / SLA bookkeeping
    admitted: bool = False
    ticks_waited: int = 0
    model_version: int = -1
    t_submit: float = 0.0
    t_done: float = 0.0
    # in-flight bookkeeping
    sweeps_done: int = 0
    theta_sum: Optional[np.ndarray] = None
    theta_samples: int = 0
    z: Optional[np.ndarray] = None  # final assignments (latency mode)


@dataclasses.dataclass
class _ModelSlot:
    """One servable model version: the frozen counts plus everything the
    decode paths derive from them (backend tables, the asymmetric-prior
    alpha_k, and the per-bucket jitted programs). ``reload`` builds a new
    slot and swaps the engine's current pointer; buckets still decoding
    pin the slot they were admitted under, so an old version stays alive
    exactly as long as its in-flight requests."""

    model: FrozenLDAModel
    aux: Any
    alpha_k: np.ndarray
    version: int
    # jit caches keyed by bucket length; shared between slots whose hyper
    # is equal (the closures capture only hyper + engine knobs — the
    # counts are traced arguments, so XLA handles shape changes itself)
    sweep_fns: Dict[int, Any]
    rtlda_fns: Dict[int, Any]


class _Bucket:
    """One fixed-shape slot batch: all device state for bucket width L.

    ``slot_model`` pins the model version the bucket's current occupants
    decode under: it is (re)tagged to the engine's current slot whenever
    a request is placed into an *empty* bucket, and never changes while
    any slot is active — the invariant that lets ``reload`` swap the
    engine's model without touching in-flight chains."""

    def __init__(self, length: int, slots: int, num_topics: int):
        self.length = length
        self.words = jnp.zeros((slots, length), jnp.int32)
        self.mask = jnp.zeros((slots, length), bool)
        self.z = jnp.zeros((slots, length), jnp.int32)
        self.n_kd = jnp.zeros((slots, num_topics), jnp.int32)
        self.active: List[Optional[InferRequest]] = [None] * slots
        self.sweep_keys: List[Optional[jax.Array]] = [None] * slots
        self.slot_model: Optional[_ModelSlot] = None

    def free_slot(self) -> Optional[int]:
        for s, r in enumerate(self.active):
            if r is None:
                return s
        return None

    @property
    def num_active(self) -> int:
        return sum(r is not None for r in self.active)


class CheckpointWatcher:
    """Poll a model-checkpoint directory and push every new committed
    step through ``reload_fn`` — the consuming half of the live
    train→serve pipeline, shared by :class:`LDAEngine` and
    ``serving.router.LDARouter``.

    Failure policy (the old inline watcher swallowed *every* OSError/
    ValueError/KeyError forever, so a corrupt checkpoint looked exactly
    like an empty directory): a load failure is **benign** only while
    nothing is committed yet (``FileNotFoundError`` with no committed
    step dirs — the trainer simply hasn't written one). Anything else —
    a committed step that fails to load (truncated leaf, bad manifest),
    or repeated errors with committed steps present — is a real failure:
    it is retried up to ``max_failures`` consecutive times with a logged
    warning each, then the watcher gives up. The last error is surfaced
    on :attr:`error` and returned by :meth:`stop` (and by the owners'
    ``stop_watching()`` / ``watch_error``); a successful load clears it
    and resets the retry budget.
    """

    def __init__(
        self,
        reload_fn: Callable[["FrozenLDAModel"], Any],
        directory: str,
        period: float = 1.0,
        initial_step: Optional[int] = None,
        max_failures: int = 8,
    ):
        self.reload_fn = reload_fn
        self.directory = directory
        self.period = period
        self.max_failures = max_failures
        self.error: Optional[Exception] = None
        self.failures = 0  # consecutive
        self.last_step = initial_step
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._loop, name="lda-ckpt-watcher", daemon=True
        )

    def start(self) -> "CheckpointWatcher":
        self._thread.start()
        return self

    def is_alive(self) -> bool:
        return self._thread.is_alive()

    def stop(self) -> Optional[Exception]:
        """Stop polling; returns the last load error (None = healthy)."""
        self._stop.set()
        self._thread.join()
        return self.error

    def _loop(self) -> None:
        from repro.train.checkpoint import committed_steps, load_lda_model

        while not self._stop.is_set():
            try:
                n_wk, n_k, hyper, _meta, step = load_lda_model(
                    self.directory
                )
            except (OSError, ValueError, KeyError) as exc:
                if (isinstance(exc, FileNotFoundError)
                        and not committed_steps(self.directory)):
                    # benign: nothing committed yet — keep waiting, and
                    # don't let an empty dir burn the retry budget
                    self.failures = 0
                else:
                    self.failures += 1
                    self.error = exc
                    logger.warning(
                        "checkpoint watch of %r: load failed (%d/%d): %s",
                        self.directory, self.failures, self.max_failures,
                        exc,
                    )
                    if self.failures >= self.max_failures:
                        logger.warning(
                            "checkpoint watch of %r: giving up after %d "
                            "consecutive failures",
                            self.directory, self.failures,
                        )
                        return
                self._stop.wait(self.period)
                continue
            self.failures = 0
            self.error = None
            if self.last_step is None or step > self.last_step:
                self.reload_fn(FrozenLDAModel(
                    n_wk=jnp.asarray(n_wk, jnp.int32),
                    n_k=jnp.asarray(n_k, jnp.int32),
                    hyper=hyper,
                ))
                self.last_step = step
            self._stop.wait(self.period)


class LDAEngine:
    """Continuously-admitting batched frozen-model inference.

    Two call styles front the same bucketed packer:

    * **Blocking batch** — :meth:`infer_batch` submits many documents,
      drains the engine, and returns the (N, K) thetas in order.
    * **Async tickets** — :meth:`submit_async` returns a ticket
      immediately; :meth:`poll` reports ``queued``/``admitted``/``done``;
      :meth:`result` blocks (with optional timeout), returns theta, and
      reaps the ticket. Drive ticks either inline (``result`` steps the
      engine itself when no ticker runs) or via the background ticker
      (:meth:`start`/:meth:`stop`).

    All public methods are thread-safe (one engine-wide lock).
    """

    def __init__(self, model: FrozenLDAModel, cfg: LDAServeConfig,
                 seed: int = 0):
        if not cfg.buckets:
            raise ValueError("need at least one bucket length")
        if cfg.mode not in ("throughput", "latency"):
            raise ValueError(f"unknown serve mode {cfg.mode!r}")
        self.cfg = cfg
        self.backend = algorithms.get(cfg.algorithm)
        self._knobs = cfg.knobs()
        self._mesh = None
        if cfg.mesh_shape is not None:
            if cfg.mode == "latency":
                raise ValueError(
                    "latency mode (RT-LDA) does not shard: drop "
                    "mesh_shape or serve mode='throughput'"
                )
            if len(cfg.mesh_shape) != 2 or cfg.mesh_shape[0] != 1:
                raise ValueError(
                    f"serving mesh_shape must be (1, m) — word rows shard "
                    f"over the model axis, replicas come from the router "
                    f"— got {cfg.mesh_shape!r}"
                )
            from repro.utils import compat

            self._mesh = compat.make_mesh(
                tuple(cfg.mesh_shape), ("data", "model")
            )
        self._current = self._build_slot(model, version=0)
        self._buckets = {
            length: _Bucket(length, cfg.max_batch, model.num_topics)
            for length in sorted(cfg.buckets)
        }
        self._base_key = jax.random.key(seed)
        self._dummy_key = jax.random.key(0)
        self.queue: List[InferRequest] = []
        self._instant: List[InferRequest] = []  # empty docs: done at submit
        self._uid = 0
        self.docs_done = 0
        self.sweeps_run = 0  # jitted bucket sweeps/decodes executed
        self.reloads = 0
        self.spills = 0  # SLA bucket spills (max_slot_wait admissions)
        # runtime SLA knobs: seeded from cfg, retuned in place by the
        # autopilot — cfg itself stays frozen (it is the *requested*
        # setup; these are the *current* values, see the properties below)
        self._tick_period = cfg.tick_period or 0.001
        self._max_slot_wait = cfg.max_slot_wait
        self._pending_buckets: Optional[Tuple[int, ...]] = None
        # observability + autopilot (DESIGN.md §8): built ONLY when
        # enabled — off means no telemetry objects exist and every tick
        # runs the exact pre-observability code path
        self._telemetry = None
        self._autopilot = None
        if cfg.metrics_out or cfg.autopilot:
            from repro.observe import JsonlSink, MetricsRegistry, ServeTelemetry

            sink = JsonlSink(cfg.metrics_out) if cfg.metrics_out else None
            arrivals = cfg.autopilot_window or 64
            self._telemetry = ServeTelemetry(
                MetricsRegistry(sink),
                window_ticks=max(8, 4 * arrivals),
                window_arrivals=arrivals,
            )
        if cfg.autopilot:
            from repro.autotune import ServeAutopilot

            self._autopilot = ServeAutopilot()
        # async front
        self._tickets: Dict[int, InferRequest] = {}
        self._cv = threading.Condition(threading.RLock())
        self._ticker: Optional[threading.Thread] = None
        self._stop_evt = threading.Event()
        # checkpoint watcher (watch_checkpoint_dir)
        self._watcher: Optional[CheckpointWatcher] = None

    # -- the current model slot --------------------------------------------
    @property
    def model(self) -> FrozenLDAModel:
        """The model new admissions decode under (the *current* slot —
        in-flight buckets may still be finishing an older version)."""
        return self._current.model

    @property
    def model_version(self) -> int:
        """Version tag of the current model slot (0 at construction,
        bumped by every :meth:`reload`)."""
        return self._current.version

    @property
    def _alpha_k(self) -> np.ndarray:
        return self._current.alpha_k

    def _build_slot(self, model: FrozenLDAModel, version: int,
                    share_from: Optional[_ModelSlot] = None) -> _ModelSlot:
        if self._mesh is not None and not isinstance(
            model, ShardedFrozenLDAModel
        ):
            model = ShardedFrozenLDAModel.shard(model, self._mesh)
        # latency mode never runs backend sweeps — skip table builds
        # (zen_cdf's prepare_infer materializes a (W, K) CDF)
        if self.cfg.mode == "latency":
            aux = None
        elif isinstance(model, ShardedFrozenLDAModel):
            aux = sharded_prepare_infer(self.backend, model, self._knobs)
        else:
            aux = self.backend.prepare_infer(
                model.n_wk, model.n_k, model.hyper, self._knobs
            )
        # the jitted per-bucket programs close over hyper only (counts
        # and tables are traced arguments) — same hyper, same programs.
        # Sharded programs additionally close over the static row layout
        # (words_per_shard / W / shard count), so the caches only carry
        # across reloads that keep it.
        share = (
            share_from is not None
            and share_from.model.hyper == model.hyper
            and layout_key(share_from.model) == layout_key(model)
        )
        return _ModelSlot(
            model=model,
            aux=aux,
            alpha_k=np.asarray(model.hyper.alpha_k(model.n_k), np.float32),
            version=version,
            sweep_fns=share_from.sweep_fns if share else {},
            rtlda_fns=share_from.rtlda_fns if share else {},
        )

    def reload(self, model: FrozenLDAModel,
               version: Optional[int] = None) -> int:
        """Atomically swap in a new model between admission ticks.

        The swap only moves the engine's *current* slot pointer: requests
        admitted from now on decode under ``model``; every in-flight
        request keeps decoding under the slot its bucket pinned at
        admission and completes on that model (its
        ``InferRequest.model_version`` says which). Nothing is dropped,
        nothing re-decodes, and a bucket starts serving the new version
        as soon as it drains.

        Args:
            model: the new frozen model. Vocabulary/topic-count changes
                are allowed (buckets re-shape their count state when they
                re-tag); hyper changes rebuild the jit caches.
            version: explicit version tag (must be greater than the
                current one); default is ``current + 1``.

        Returns:
            The new version tag.
        """
        with self._cv:
            new_version = (self._current.version + 1 if version is None
                           else int(version))
            if new_version <= self._current.version:
                raise ValueError(
                    f"model version must increase: {new_version} <= "
                    f"{self._current.version}"
                )
            self._current = self._build_slot(
                model, new_version, share_from=self._current
            )
            self.reloads += 1
            return new_version

    def watch_checkpoint_dir(
        self,
        directory: str,
        period: float = 1.0,
        initial_step: Optional[int] = None,
        max_failures: int = 8,
    ) -> None:
        """Poll a model-checkpoint directory and reload every new step.

        The consuming half of the live pipeline (``launch/train.py
        --stream`` writes steps, this follows them): a
        :class:`CheckpointWatcher` daemon checks ``directory`` every
        ``period`` seconds for a committed ``save_lda_model`` checkpoint
        with a step newer than the last one seen and
        hot-:meth:`reload`\\ s it. An empty directory is quietly
        retried; a committed checkpoint that fails to load (truncated
        leaf, torn manifest) is retried ``max_failures`` times with
        logged warnings and then surfaced on :attr:`watch_error` (see
        :class:`CheckpointWatcher` for the policy). Idempotent while a
        watcher runs; stop with :meth:`stop_watching`.

        Args:
            directory: the ``checkpoint_dir`` a trainer writes model
                checkpoints into.
            period: poll cadence in seconds.
            initial_step: treat this step as already served (pass the
                step the engine's construction model came from to avoid
                one redundant reload); default reloads the first
                checkpoint the watcher sees.
            max_failures: consecutive real load failures before the
                watcher gives up.
        """
        with self._cv:
            if self._watcher is not None and self._watcher.is_alive():
                return
            self._watcher = CheckpointWatcher(
                self.reload, directory, period=period,
                initial_step=initial_step, max_failures=max_failures,
            ).start()

    @property
    def watch_error(self) -> Optional[Exception]:
        """Last checkpoint-watcher load error (None = healthy / no
        watcher). Non-None with a dead watcher means it gave up — the
        engine keeps serving its current model, but the pipeline needs
        an operator."""
        watcher = self._watcher
        return None if watcher is None else watcher.error

    def stop_watching(self) -> Optional[Exception]:
        """Stop the checkpoint watcher (no-op if none is running). The
        currently-loaded model keeps serving. Returns the watcher's last
        load error, None when it was healthy (or never ran)."""
        watcher = self._watcher
        if watcher is None:
            return None
        err = watcher.stop()
        self._watcher = None
        return err

    # -- request intake ----------------------------------------------------
    def submit(
        self,
        words,
        key: Optional[jax.Array] = None,
        num_sweeps: Optional[int] = None,
        burn_in: Optional[int] = None,
        thin: Optional[int] = None,
    ) -> int:
        """Queue one document for inference; returns its uid.

        Args:
            words: 1-D array-like of int token ids (any shape is
                flattened). Unknown ids (outside ``[0, W)``) are dropped;
                documents longer than the widest bucket are truncated to
                it; a document that ends up empty completes immediately
                with the normalized prior theta.
            key: whole-chain PRNG key for this request (throughput mode;
                default derives one from the engine seed + uid). Ignored
                in latency mode — RT-LDA decoding is deterministic.
            num_sweeps: CGS sweeps for this request's chain (default
                ``cfg.num_sweeps``; ``<= 0`` completes from the initial
                assignment). Ignored in latency mode, which always runs
                ``cfg.rtlda_sweeps`` fused argmax passes.
            burn_in / thin: per-request estimator knobs (see
                :class:`LDAServeConfig`). Ignored in latency mode.

        Returns:
            The request uid. The finished request (theta, diagnostics,
            timestamps) comes back from :meth:`step` /
            :meth:`run_until_done` — *to whoever called them*, so plain
            ``submit`` is for caller-driven engines only: with the
            background ticker running (:meth:`start`), the ticker's own
            steps collect (and discard) finished non-ticketed requests.
            Use :meth:`submit_async` + :meth:`result` whenever a ticker
            may be driving.
        """
        with self._cv:
            return self._submit(words, key, num_sweeps, burn_in, thin).uid

    def submit_async(
        self,
        words,
        key: Optional[jax.Array] = None,
        num_sweeps: Optional[int] = None,
        burn_in: Optional[int] = None,
        thin: Optional[int] = None,
    ) -> int:
        """Queue one document and return a pollable ticket immediately.

        Same arguments and admission behavior as :meth:`submit`; the
        request additionally registers in the ticket table, so its
        lifecycle is observable with :meth:`poll` and its theta
        retrievable (exactly once) with :meth:`result`. The caller never
        blocks: the request coalesces into the next admission tick's
        batch — whoever drives ticks (the background ticker started with
        :meth:`start`, another thread calling :meth:`step`, or this
        caller's own later :meth:`result`).

        Returns:
            The ticket (an int uid) to pass to :meth:`poll` /
            :meth:`result`.
        """
        with self._cv:
            req = self._submit(words, key, num_sweeps, burn_in, thin)
            self._tickets[req.uid] = req
            return req.uid

    def _submit(self, words, key, num_sweeps, burn_in, thin) -> InferRequest:
        self._uid += 1
        raw = np.asarray(words, np.int32).ravel()
        known = raw[(raw >= 0) & (raw < self.model.num_words)]
        max_len = max(self._buckets)
        latency = self.cfg.mode == "latency"
        req = InferRequest(
            uid=self._uid,
            words=known[:max_len],
            # latency mode is deterministic — never pay the fold_in
            key=None if latency else (
                key if key is not None
                else jax.random.fold_in(self._base_key, self._uid)
            ),
            num_sweeps=self.cfg.rtlda_sweeps if latency
            else (self.cfg.num_sweeps if num_sweeps is None else num_sweeps),
            burn_in=-1 if latency
            else (self.cfg.burn_in if burn_in is None else burn_in),
            thin=1 if latency
            else max(1, self.cfg.thin if thin is None else thin),
            orig_len=int(raw.shape[0]),
            truncated=known.shape[0] > max_len,
            dropped_unknown=int(raw.shape[0] - known.shape[0]),
            t_submit=time.monotonic(),
        )
        if req.words.shape[0] == 0:
            # nothing observed: theta is the normalized prior
            req.model_version = self._current.version
            req.theta = self._alpha_k / self._alpha_k.sum()
            self._complete(req)
            self._instant.append(req)
        elif not latency and req.num_sweeps <= 0:
            # zero sweeps: theta straight from the z0 assignment, matching
            # the oracle's empty scan (never occupies a slot)
            req.model_version = self._current.version
            z0 = np.asarray(jax.random.randint(
                req.key, (req.words.shape[0],), 0, self.model.num_topics,
                dtype=jnp.int32,
            ))
            n_kd0 = np.bincount(
                z0, minlength=self.model.num_topics
            ).astype(np.int32)
            req.theta = self._theta(req, n_kd0, self._alpha_k)
            self._complete(req)
            self._instant.append(req)
        else:
            self.queue.append(req)
        if self._telemetry is not None:
            self._telemetry.record_submit(req.t_submit,
                                          int(req.words.shape[0]))
        return req

    def _complete(self, req: InferRequest) -> None:
        req.done = True
        req.t_done = time.monotonic()
        self.docs_done += 1

    # -- the async ticket lifecycle ----------------------------------------
    def poll(self, ticket: int) -> str:
        """Report a ticket's lifecycle state without blocking.

        Returns ``"queued"`` (waiting for a bucket slot), ``"admitted"``
        (packed into a slot batch / decoding), or ``"done"`` (theta
        ready — collect it with :meth:`result`). Raises ``KeyError`` for
        a ticket that was never issued by :meth:`submit_async` or was
        already reaped by :meth:`result`.
        """
        with self._cv:
            req = self._tickets.get(ticket)
            if req is None:
                raise KeyError(f"unknown or reaped ticket {ticket}")
            if req.done:
                return "done"
            return "admitted" if req.admitted else "queued"

    def result(self, ticket: int, timeout: Optional[float] = None
               ) -> np.ndarray:
        """Block until a ticket's theta is ready; return it and reap.

        If a background ticker is running (:meth:`start`), this waits on
        it; otherwise the caller drives admission ticks itself, so
        progress never depends on another thread. ``timeout`` is in
        seconds (``None`` = wait forever; ``0`` = must already be done).

        Returns:
            theta — the (K,) float32 doc-topic distribution.

        Raises:
            KeyError: unknown or already-reaped ticket.
            TimeoutError: theta not ready within ``timeout`` seconds.

        The ticket is consumed: a second ``result`` (or ``poll``) for it
        raises ``KeyError``. Keep the uid-indexed thetas yourself if you
        need them twice. A ``TimeoutError`` does NOT consume the ticket —
        retry ``result`` later, or :meth:`cancel` it if you are
        abandoning the request (otherwise its entry stays claimable, and
        accumulating abandoned tickets is a leak in a long-running
        server).
        """
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            req = self._tickets.get(ticket)
            if req is None:
                raise KeyError(f"unknown or reaped ticket {ticket}")
            while not req.done:
                if deadline is not None and time.monotonic() >= deadline:
                    raise TimeoutError(
                        f"ticket {ticket} not done within {timeout}s"
                    )
                if self._ticker is not None and self._ticker.is_alive():
                    # bounded wait so a ticker stopped mid-flight hands
                    # driving back to this caller instead of stranding it
                    remaining = (None if deadline is None
                                 else deadline - time.monotonic())
                    self._cv.wait(0.05 if remaining is None
                                  else min(remaining, 0.05))
                else:
                    self.step()
            del self._tickets[ticket]
            return req.theta

    def cancel(self, ticket: int) -> bool:
        """Abandon a ticket: drop it from the ticket table and from
        wherever its request lives — the admission queue (it will never
        decode) or, if it was already admitted, its bucket slot (the
        slot is evacuated immediately).

        Evacuating admitted requests matters beyond freeing a slot one
        tick earlier: a bucket pins the model version its occupants were
        admitted under, so a cancelled-but-still-decoding request used
        to be a *zombie* — under an engine driven by cancel-then-reload
        traffic it could keep its bucket on the old model arbitrarily
        long, blocking admission there (``_admittable`` refuses
        cross-version co-residency) while nobody was waiting for its
        theta. Cancel and the stepping loop hold the same engine lock,
        so the slot arrays are never mutated mid-sweep; a sweep already
        dispatched just computes one masked-out garbage row.

        Call this for every ticket you stop waiting on (e.g. after a
        :meth:`result` timeout you don't intend to retry), or abandoned
        entries accumulate for the engine's lifetime.

        Returns:
            True if the ticket existed (now reaped), False if it was
            unknown or already reaped — cancel never raises, so timeout
            cleanup paths can call it unconditionally.
        """
        with self._cv:
            req = self._tickets.pop(ticket, None)
            if req is None:
                return False
            if req.done:
                return True
            if req.admitted:
                for bucket in self._buckets.values():
                    for slot, r in enumerate(bucket.active):
                        if r is req:
                            bucket.active[slot] = None
                            bucket.sweep_keys[slot] = None
                            bucket.mask = bucket.mask.at[slot].set(False)
                            return True
            else:
                self.queue = [r for r in self.queue if r.uid != ticket]
            return True

    def request(self, ticket: int) -> InferRequest:
        """The live :class:`InferRequest` behind an un-reaped ticket
        (diagnostics: timestamps, truncation, sweep counts). Raises
        ``KeyError`` after :meth:`result` reaped it."""
        with self._cv:
            req = self._tickets.get(ticket)
            if req is None:
                raise KeyError(f"unknown or reaped ticket {ticket}")
            return req

    # -- background ticker -------------------------------------------------
    def start(self, tick_period: Optional[float] = None) -> None:
        """Start the background admission ticker.

        Every ``tick_period`` seconds (default ``cfg.tick_period``, or
        1 ms when that is 0) the ticker runs one :meth:`step` if any work
        is pending, so async submitters coalesce into batches without any
        caller driving the engine. Idempotent while running. While a
        ticker drives, retrieve results through tickets
        (:meth:`submit_async` + :meth:`result`): finished requests from
        plain :meth:`submit` are returned only to whichever caller's
        ``step`` finished them — here, the ticker, which discards them.
        """
        with self._cv:
            if self._ticker is not None and self._ticker.is_alive():
                return
            if tick_period is not None:
                self._tick_period = tick_period
            self._stop_evt = threading.Event()

            def loop():
                # the period is re-read every iteration: the autopilot
                # retunes ``self._tick_period`` in place and the ticker
                # follows from the next wait on — no restart needed
                while not self._stop_evt.is_set():
                    with self._cv:
                        if self._pending():
                            self.step()
                    self._stop_evt.wait(self._tick_period)

            self._ticker = threading.Thread(
                target=loop, name="lda-engine-ticker", daemon=True
            )
            self._ticker.start()

    def stop(self) -> None:
        """Stop the background ticker (no-op if it is not running).
        In-flight requests stay queued/admitted and finish under whoever
        drives ticks next."""
        ticker = self._ticker
        if ticker is None:
            return
        self._stop_evt.set()
        ticker.join()
        self._ticker = None

    def _pending(self) -> bool:
        return bool(
            self.queue or self._instant
            or any(b.num_active for b in self._buckets.values())
        )

    @property
    def load(self) -> int:
        """Queued + in-flight request count — the admission-pressure
        signal ``serving.router.LDARouter`` balances replicas on."""
        with self._cv:
            return len(self.queue) + sum(
                b.num_active for b in self._buckets.values()
            )

    def warm(self) -> None:
        """Compile every bucket's decode program before traffic arrives:
        one minimal document per bucket width through the normal path,
        so first-request latency never pays a jit trace."""
        self.infer_batch(
            [np.zeros(bl, np.int32) for bl in self.bucket_widths]
        )

    # -- runtime SLA knobs (autopilot-visible; DESIGN.md §8.4) --------------
    @property
    def tick_period(self) -> float:
        """The CURRENT ticker cadence (cfg seed, autopilot-retuned)."""
        return self._tick_period

    @property
    def max_slot_wait(self) -> int:
        """The CURRENT bucket-spill SLA knob (cfg seed, autopilot-retuned)."""
        return self._max_slot_wait

    @property
    def bucket_widths(self) -> Tuple[int, ...]:
        """The CURRENT bucket lengths, ascending."""
        return tuple(sorted(self._buckets))

    def _apply_pending_buckets(self) -> None:
        """Swap in an autopilot-proposed bucket grid, but only once every
        bucket has drained — the same discipline as a hot model reload:
        in-flight slot state is never reshaped under a running decode.
        Queued requests survive the swap (their words re-bucket at the
        next admission; over-long ones truncate to the new widest)."""
        if self._pending_buckets is None:
            return
        if any(b.num_active for b in self._buckets.values()):
            return
        widths = self._pending_buckets
        self._pending_buckets = None
        k = self._current.model.num_topics
        self._buckets = {
            length: _Bucket(length, self.cfg.max_batch, k)
            for length in sorted(widths)
        }
        max_len = max(self._buckets)
        for req in self.queue:
            if req.words.shape[0] > max_len:
                req.words = req.words[:max_len]
                req.truncated = True

    def _observe_tick(self, finished: List[InferRequest]) -> None:
        """Measure this tick; when it closes a telemetry window, let the
        autopilot derive new SLA knobs from the window's summary and
        apply them (period/spill immediately — the next tick reads them;
        buckets deferred to a full drain). Called under the engine lock
        from :meth:`step`."""
        summary = self._telemetry.record_tick(
            queue_depth=len(self.queue),
            occupancy=sum(b.num_active for b in self._buckets.values()),
            finished=finished,
            spills_total=self.spills,
            tick_period=self._tick_period,
            max_slot_wait=self._max_slot_wait,
            bucket_widths=self.bucket_widths,
            model_version=self._current.version,
        )
        if summary is None or self._autopilot is None:
            return
        decision = self._autopilot.decide(
            summary,
            tick_period=self._tick_period,
            max_slot_wait=self._max_slot_wait,
            buckets=self.bucket_widths,
        )
        if decision is None:
            return
        applied = False
        if decision.tick_period is not None:
            self._tick_period = float(decision.tick_period)
            applied = True
        if decision.max_slot_wait is not None:
            self._max_slot_wait = int(decision.max_slot_wait)
            applied = True
        if (decision.buckets is not None
                and tuple(sorted(decision.buckets)) != self.bucket_widths):
            self._pending_buckets = tuple(sorted(decision.buckets))
            applied = True
        rec = decision.to_record()
        rec["applied"] = applied
        self._telemetry.emit_decision(rec)

    # -- admission ---------------------------------------------------------
    def _bucket_for(self, length: int) -> _Bucket:
        for bl in sorted(self._buckets):
            if length <= bl:
                return self._buckets[bl]
        return self._buckets[max(self._buckets)]

    def _admittable(self, bucket: _Bucket) -> Optional[int]:
        """A free slot in ``bucket`` a request may take *now*, or None.

        A drained bucket is always admittable (it re-tags to the current
        model slot at placement); an occupied bucket only admits
        co-residents of the same model version — a request must never
        join a batch that decodes under a model it wasn't admitted for.
        After a reload, occupied buckets therefore finish their old-
        version occupants first and flip to the new model when empty.
        """
        if bucket.num_active and bucket.slot_model is not self._current:
            return None
        return bucket.free_slot()

    def _admit(self) -> None:
        still_queued = []
        for req in self.queue:
            bucket = self._bucket_for(req.words.shape[0])
            slot = self._admittable(bucket)
            if slot is None and self._max_slot_wait > 0 \
                    and req.ticks_waited >= self._max_slot_wait:
                # SLA spill: the preferred bucket has been saturated for
                # max_slot_wait ticks — take any wider free slot instead
                for bl in sorted(self._buckets):
                    wider = self._buckets[bl]
                    if bl <= bucket.length or bl < req.words.shape[0]:
                        continue
                    s = self._admittable(wider)
                    if s is not None:
                        bucket, slot = wider, s
                        self.spills += 1
                        break
            if slot is None:
                req.ticks_waited += 1
                still_queued.append(req)
                continue
            self._place(req, bucket, slot)
        self.queue = still_queued

    def _place(self, req: InferRequest, bucket: _Bucket, slot: int) -> None:
        if bucket.num_active == 0:
            # empty bucket: (re)pin to the current model version; if K
            # changed across a reload, re-shape the doc-topic state
            bucket.slot_model = self._current
            k_now = self._current.model.num_topics
            if bucket.n_kd.shape[1] != k_now:
                bucket.n_kd = jnp.zeros(
                    (bucket.n_kd.shape[0], k_now), jnp.int32
                )
        l, k = bucket.length, bucket.slot_model.model.num_topics
        n = req.words.shape[0]
        words = np.zeros(l, np.int32)
        placed_model = bucket.slot_model.model
        if isinstance(placed_model, ShardedFrozenLDAModel):
            # shard-space row ids, mapped at *placement* (not submit):
            # req.words keep original ids, so a request admitted after a
            # reload relabels through the new model's permutation
            words[:n] = placed_model.relabel(req.words)
        else:
            words[:n] = req.words
        mask = np.zeros(l, bool)
        mask[:n] = True
        bucket.words = bucket.words.at[slot].set(jnp.asarray(words))
        bucket.mask = bucket.mask.at[slot].set(jnp.asarray(mask))
        bucket.active[slot] = req
        req.admitted = True
        req.model_version = bucket.slot_model.version
        if self.cfg.mode == "latency":
            # RT-LDA needs no chain state: z/n_kd are produced whole by
            # the fused decode, nothing to initialize per slot
            bucket.sweep_keys[slot] = None
            return
        # same schedule as cgs_infer: z0 from the request key itself, sweep
        # j from split(key)[j]; randint/uniform draws are prefix-stable in
        # the padded length, so the bucket width never changes the chain
        z0 = jax.random.randint(req.key, (l,), 0, k, dtype=jnp.int32)
        z0_np = np.asarray(z0)
        n_kd = np.bincount(z0_np[:n], minlength=k).astype(np.int32)
        bucket.z = bucket.z.at[slot].set(z0)
        bucket.n_kd = bucket.n_kd.at[slot].set(jnp.asarray(n_kd))
        bucket.sweep_keys[slot] = (
            jax.random.split(req.key, req.num_sweeps)
            if req.num_sweeps > 0 else None
        )

    # -- the jitted per-bucket programs -------------------------------------
    def _sweep_fn(self, slot_model: _ModelSlot, length: int):
        """Throughput mode: one chain CGS sweep over a bucket's slots.
        Cached on the model slot (shared across reloads with equal
        hyper — the counts are traced arguments). Sharded slots get the
        ``shard_map`` program instead — same signature, so the stepping
        loop is layout-blind."""
        if length not in slot_model.sweep_fns:
            if isinstance(slot_model.model, ShardedFrozenLDAModel):
                slot_model.sweep_fns[length] = make_sharded_sweep_fn(
                    self.backend, self._knobs, slot_model.model,
                    slot_model.aux,
                )
                return slot_model.sweep_fns[length]
            backend, knobs = self.backend, self._knobs
            hyper = slot_model.model.hyper

            def fn(keys, words, mask, z, n_kd, n_wk, n_k, aux):
                z_new = backend.infer_sweep(
                    keys, words, mask, z, n_kd, n_wk, n_k, hyper, knobs, aux
                )
                z_new = jnp.where(mask, z_new, z)
                onehot = (
                    jax.nn.one_hot(z_new, hyper.num_topics, dtype=jnp.int32)
                    * mask[..., None]
                )
                return z_new, jnp.sum(onehot, axis=1)

            slot_model.sweep_fns[length] = jax.jit(fn)
        return slot_model.sweep_fns[length]

    def _rtlda_fn(self, slot_model: _ModelSlot, length: int):
        """Latency mode: the whole RT-LDA decode for one bucket, fused
        into a single dispatch (init + ``rtlda_sweeps`` argmax passes)."""
        if length not in slot_model.rtlda_fns:
            hyper = slot_model.model.hyper
            sweeps = self.cfg.rtlda_sweeps

            def fn(words, mask, n_wk, n_k):
                return jax.vmap(
                    lambda w, m: rtlda_assign(n_wk, n_k, w, m, hyper, sweeps)
                )(words, mask)

            slot_model.rtlda_fns[length] = jax.jit(fn)
        return slot_model.rtlda_fns[length]

    # -- stepping ----------------------------------------------------------
    def step(self) -> List[InferRequest]:
        """Run one admission tick; return the requests it finished.

        Throughput mode: admit into free slots, run one chain sweep per
        non-empty bucket, finish ripe chains. Latency mode: admit, run
        one fused RT-LDA decode per non-empty bucket — every admitted
        request finishes in the same tick.
        """
        with self._cv:
            self._apply_pending_buckets()
            finished = (self._latency_step() if self.cfg.mode == "latency"
                        else self._throughput_step())
            if self._telemetry is not None:
                self._observe_tick(finished)
            if finished and self._tickets:
                self._cv.notify_all()
            return finished

    def _latency_step(self) -> List[InferRequest]:
        self._admit()
        finished, self._instant = self._instant, []
        for bucket in self._buckets.values():
            if bucket.num_active == 0:
                continue
            sm = bucket.slot_model  # pinned: in-flight = admitted model
            z, n_kd = self._rtlda_fn(sm, bucket.length)(
                bucket.words, bucket.mask, sm.model.n_wk, sm.model.n_k
            )
            self.sweeps_run += 1
            z_host, n_kd_host = np.asarray(z), np.asarray(n_kd)
            for slot, req in enumerate(bucket.active):
                if req is None:
                    continue
                req.sweeps_done = req.num_sweeps
                req.z = z_host[slot, : req.words.shape[0]].copy()
                self._finish(req, bucket, slot, n_kd_host[slot],
                             clear_mask=False)
                finished.append(req)
            bucket.mask = jnp.zeros_like(bucket.mask)  # one bulk clear
        return finished

    def _throughput_step(self) -> List[InferRequest]:
        self._admit()
        finished, self._instant = self._instant, []
        for bucket in self._buckets.values():
            if bucket.num_active == 0:
                continue
            keys = jnp.stack([
                bucket.sweep_keys[s][bucket.active[s].sweeps_done]
                if bucket.active[s] is not None
                and bucket.sweep_keys[s] is not None
                and bucket.active[s].sweeps_done
                < bucket.active[s].num_sweeps
                else self._dummy_key
                for s in range(len(bucket.active))
            ])
            sm = bucket.slot_model  # pinned: in-flight = admitted model
            bucket.z, bucket.n_kd = self._sweep_fn(sm, bucket.length)(
                keys, bucket.words, bucket.mask, bucket.z, bucket.n_kd,
                sm.model.n_wk, sm.model.n_k, sm.aux,
            )
            self.sweeps_run += 1
            n_kd_host = None
            for slot, req in enumerate(bucket.active):
                if req is None:
                    continue
                req.sweeps_done += 1
                want_sample = (
                    req.burn_in >= 0
                    and req.sweeps_done > req.burn_in
                    and (req.sweeps_done - req.burn_in) % req.thin == 0
                )
                ripe = req.sweeps_done >= req.num_sweeps
                if want_sample or ripe:
                    if n_kd_host is None:
                        n_kd_host = np.asarray(bucket.n_kd)
                    if want_sample:
                        if req.theta_sum is None:
                            req.theta_sum = np.zeros(
                                sm.model.num_topics, np.float32
                            )
                        req.theta_sum += self._theta(req, n_kd_host[slot],
                                                     sm.alpha_k)
                        req.theta_samples += 1
                if ripe:
                    self._finish(req, bucket, slot,
                                 None if n_kd_host is None
                                 else n_kd_host[slot])
                    finished.append(req)
        return finished

    def _theta(self, req: InferRequest, n_kd_row: np.ndarray,
               alpha_k: np.ndarray) -> np.ndarray:
        l = req.words.shape[0]
        return (n_kd_row.astype(np.float32) + alpha_k) / (
            l + alpha_k.sum()
        )

    def _finish(self, req: InferRequest, bucket: _Bucket, slot: int,
                n_kd_row: Optional[np.ndarray],
                clear_mask: bool = True) -> None:
        if req.theta_samples:
            req.theta = req.theta_sum / req.theta_samples
        else:
            if n_kd_row is None:  # num_sweeps == 0: counts from z0
                n_kd_row = np.asarray(bucket.n_kd[slot])
            # prior smoothing from the model the request decoded under
            req.theta = self._theta(req, n_kd_row, bucket.slot_model.alpha_k)
        bucket.active[slot] = None
        bucket.sweep_keys[slot] = None
        if clear_mask:
            bucket.mask = bucket.mask.at[slot].set(False)
        self._complete(req)

    def run_until_done(self, max_steps: int = 100_000) -> List[InferRequest]:
        """Drive ticks until the queue and every bucket drain; return all
        requests finished along the way (instant completions included)."""
        with self._cv:
            done: List[InferRequest] = list(self._instant)
            self._instant = []
            for _ in range(max_steps):
                done.extend(self.step())
                if not self.queue and all(
                    b.num_active == 0 for b in self._buckets.values()
                ):
                    break
            return done

    def infer_batch(self, docs: Sequence, **submit_kw) -> np.ndarray:
        """Submit many documents, drain the engine, return their thetas.

        Args:
            docs: sequence of 1-D int token-id arrays (one per document).
            **submit_kw: forwarded to :meth:`submit` for every document
                (``key``/``num_sweeps``/``burn_in``/``thin``).

        Returns:
            ``(N, K)`` float32 thetas in submission order. Shape
            convention: N = ``len(docs)``, K = ``model.num_topics``; row
            n sums to 1 and is the inferred topic mixture of ``docs[n]``.

        This is the blocking convenience front; it shares admission,
        bucketing, and decoding with the async path, so the returned
        thetas are identical to what :meth:`submit_async` +
        :meth:`result` would produce for the same inputs.
        """
        with self._cv:
            uids = [self.submit(d, **submit_kw) for d in docs]
            by_uid = {r.uid: r for r in self.run_until_done()}
            missing = [u for u in uids if u not in by_uid]
            if missing:
                raise RuntimeError(f"engine did not finish requests {missing}")
            return np.stack([by_uid[u].theta for u in uids])


# -- held-out evaluation ---------------------------------------------------
def doc_completion_perplexity(
    engine: LDAEngine, docs: Sequence[np.ndarray]
) -> float:
    """Doc-completion held-out perplexity (Wallach et al.'s estimator).

    Each document is split alternately into an observed half (theta is
    inferred on it through the engine) and a held-out half, scored as
    ``p(w | theta, phi)``. Lower is better; this is the serving-quality
    number ``launch/serve_lda.py --eval`` reports.
    """
    observed, heldout = [], []
    for d in docs:
        d = np.asarray(d, np.int32)
        observed.append(d[0::2])
        heldout.append(d[1::2])
    thetas = engine.infer_batch(observed)  # (N, K)
    phi = np.asarray(engine.model.phi(), np.float32)  # (W, K)
    total_ll, total_tokens = 0.0, 0
    for theta, held in zip(thetas, heldout):
        held = held[(held >= 0) & (held < engine.model.num_words)]
        if held.shape[0] == 0:
            continue
        p = phi[held] @ theta  # (n,)
        total_ll += float(np.sum(np.log(np.maximum(p, 1e-30))))
        total_tokens += int(held.shape[0])
    if total_tokens == 0:
        return float("nan")
    return float(np.exp(-total_ll / total_tokens))


def docs_from_corpus(corpus) -> List[np.ndarray]:
    """Split an edge-list ``Corpus`` into per-document token arrays."""
    words = np.asarray(corpus.word)
    docs = np.asarray(corpus.doc)
    order = np.argsort(docs, kind="stable")
    words, docs = words[order], docs[order]
    bounds = np.searchsorted(docs, np.arange(corpus.num_docs + 1))
    return [words[bounds[d]:bounds[d + 1]] for d in range(corpus.num_docs)]
