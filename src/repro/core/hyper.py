"""Topic-duplicate merging (paper §4.3 "Merge duplicated topics").

The asymmetric prior already biases similar topics toward merging; on top of
that, topics whose L1 distance between word distributions falls below a
threshold are explicitly clustered and merged (union of counts, remapped
assignments).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def topic_l1_distances(n_wk: jax.Array) -> jax.Array:
    """Pairwise L1 distance between topic word distributions. (K, K)."""
    col = n_wk.astype(jnp.float32)
    col = col / jnp.maximum(jnp.sum(col, axis=0, keepdims=True), 1e-30)
    # (K, K) pairwise |phi_i - phi_j|_1; K is moderate so this is fine.
    return jnp.sum(jnp.abs(col[:, :, None] - col[:, None, :]), axis=0)


def duplicate_topic_map(n_wk: np.ndarray, threshold: float) -> np.ndarray:
    """Map each topic to its cluster representative (lowest id wins).

    Host-side union-find over the below-threshold pairs; returns (K,) int32.
    A lower threshold removes more duplicates (paper's knob).
    """
    dist = np.asarray(topic_l1_distances(jnp.asarray(n_wk)))
    k = dist.shape[0]
    parent = np.arange(k)

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    ii, jj = np.where((dist < threshold) & (np.arange(k)[:, None] < np.arange(k)))
    for a, b in zip(ii, jj):
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[max(ra, rb)] = min(ra, rb)
    return np.array([find(x) for x in range(k)], dtype=np.int32)


def merge_topics(
    topic: jax.Array,
    n_wk: jax.Array,
    n_kd: jax.Array,
    n_k: jax.Array,
    topic_map: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Apply a duplicate map: remap assignments, merge count columns."""
    k = n_k.shape[0]
    new_topic = topic_map[topic]
    onehot = jax.nn.one_hot(topic_map, k, dtype=n_wk.dtype)  # (K_old, K_new)
    return (
        new_topic.astype(jnp.int32),
        n_wk @ onehot,
        n_kd @ onehot,
        n_k @ onehot,
    )
