"""State-space blocks: Mamba1 (falcon-mamba-7b) and Mamba2/SSD (zamba2).

Mamba1 uses the exact sequential selective scan (lax.scan over L, O(1)
compile depth, O(B·d_inner·N) carry). Mamba2 uses the chunked SSD matmul
form — intra-chunk quadratic (MXU-friendly) + inter-chunk state recurrence —
which is the TPU-native formulation (DESIGN.md §2: rethink for the MXU).
Both expose O(1)-state decode steps, which is what makes the ``long_500k``
shape runnable for the SSM/hybrid archs while pure-attention archs skip it.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


class SSMCache(NamedTuple):
    conv: jax.Array  # (B, conv_dim-1, d_inner) rolling conv inputs
    state: jax.Array  # mamba1: (B, d_inner, N); mamba2: (B, H, N, P)


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over L. x (B,L,C), w (K,C), b (C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    return out + b


# ---------------------------------------------------------------------------
# Mamba1
# ---------------------------------------------------------------------------

def d_inner_of(cfg: ArchConfig) -> int:
    return cfg.ssm.expand * cfg.d_model


def dt_rank_of(cfg: ArchConfig) -> int:
    return cfg.ssm.dt_rank or math.ceil(cfg.d_model / 16)


def init_mamba1(key, cfg: ArchConfig, dtype) -> dict:
    c = cfg.ssm
    d = cfg.d_model
    di = d_inner_of(cfg)
    r = dt_rank_of(cfg)
    n = c.state_dim
    ks = jax.random.split(key, 8)
    s = d ** -0.5
    return {
        "in_proj": (jax.random.normal(ks[0], (d, 2 * di)) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (c.conv_dim, di)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": (jax.random.normal(ks[2], (di, r + 2 * n)) * di ** -0.5).astype(dtype),
        "dt_proj": (jax.random.normal(ks[3], (r, di)) * r ** -0.5).astype(dtype),
        "dt_bias": jnp.log(
            jnp.exp(
                jnp.exp(
                    jax.random.uniform(ks[4], (di,), minval=math.log(1e-3),
                                       maxval=math.log(1e-1))
                )
            )
            - 1.0
        ).astype(jnp.float32),  # softplus^-1 of dt init
        "a_log": jnp.log(
            jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))
        ),
        "d": jnp.ones((di,), jnp.float32),
        "out_proj": (jax.random.normal(ks[5], (di, d)) * di ** -0.5).astype(dtype),
    }


def _mamba1_core(x, z, params, cfg: ArchConfig):
    """Selective scan. x,z (B,L,di)."""
    c = cfg.ssm
    n = c.state_dim
    r = dt_rank_of(cfg)
    xdbc = jnp.einsum("bld,dk->blk", x, params["x_proj"]).astype(jnp.float32)
    dt_r, bmat, cmat = jnp.split(xdbc, [r, r + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("blr,rd->bld", dt_r, params["dt_proj"].astype(jnp.float32))
        + params["dt_bias"]
    )  # (B,L,di)
    a = -jnp.exp(params["a_log"])  # (di, N)
    da = jnp.exp(dt[..., None] * a)  # (B,L,di,N) discretized A
    dbx = dt[..., None] * bmat[:, :, None, :] * x.astype(jnp.float32)[..., None]

    def step(h, inputs):
        da_t, dbx_t, c_t = inputs  # (B,di,N), (B,di,N), (B,N)
        h = da_t * h + dbx_t
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    b, l, di = x.shape
    h0 = jnp.zeros((b, di, n), jnp.float32)
    _, ys = jax.lax.scan(
        step, h0,
        (da.transpose(1, 0, 2, 3), dbx.transpose(1, 0, 2, 3),
         cmat.transpose(1, 0, 2)),
    )
    y = ys.transpose(1, 0, 2)  # (B,L,di)
    y = y + params["d"] * x.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return y.astype(x.dtype)


def mamba1_block(x: jax.Array, params: dict, cfg: ArchConfig) -> jax.Array:
    xz = jnp.einsum("bld,dk->blk", x, params["in_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = jax.nn.silu(_causal_conv(xi, params["conv_w"], params["conv_b"]))
    y = _mamba1_core(xi, z, params, cfg)
    return jnp.einsum("bld,dk->blk", y, params["out_proj"])


def mamba1_decode(
    x: jax.Array, params: dict, cfg: ArchConfig, cache: SSMCache
) -> Tuple[jax.Array, SSMCache]:
    """One-token step. x (B,1,D)."""
    c = cfg.ssm
    n = c.state_dim
    r = dt_rank_of(cfg)
    xz = jnp.einsum("bld,dk->blk", x, params["in_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)  # (B,1,di)
    conv_in = jnp.concatenate([cache.conv, xi], axis=1)  # (B,K,di)
    w = params["conv_w"]
    xi = jnp.einsum("bkd,kd->bd", conv_in, w)[:, None, :] + params["conv_b"]
    xi = jax.nn.silu(xi)
    xdbc = jnp.einsum("bld,dk->blk", xi, params["x_proj"]).astype(jnp.float32)
    dt_r, bmat, cmat = jnp.split(xdbc, [r, r + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("blr,rd->bld", dt_r, params["dt_proj"].astype(jnp.float32))
        + params["dt_bias"]
    )[:, 0]  # (B,di)
    a = -jnp.exp(params["a_log"])
    da = jnp.exp(dt[..., None] * a)  # (B,di,N)
    h = da * cache.state + dt[..., None] * bmat[:, 0, None, :] * xi.astype(
        jnp.float32
    )[:, 0, :, None]
    y = jnp.einsum("bdn,bn->bd", h, cmat[:, 0])[:, None, :]
    y = y + params["d"] * xi.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bld,dk->blk", y.astype(x.dtype), params["out_proj"])
    return out, SSMCache(conv=conv_in[:, 1:], state=h)


# ---------------------------------------------------------------------------
# Mamba2 (SSD chunked form)
# ---------------------------------------------------------------------------

def init_mamba2(key, cfg: ArchConfig, dtype) -> dict:
    c = cfg.ssm
    d = cfg.d_model
    di = d_inner_of(cfg)
    p = c.head_dim
    h = di // p
    n = c.state_dim
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        # projects to [z, x, B, C, dt]
        "in_proj": (
            jax.random.normal(ks[0], (d, 2 * di + 2 * n + h)) * s
        ).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (c.conv_dim, di + 2 * n)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di + 2 * n,), dtype),
        "a_log_h": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias_h": jnp.zeros((h,), jnp.float32),
        "d_h": jnp.ones((h,), jnp.float32),
        "norm_scale": jnp.zeros((di,), jnp.float32),
        "out_proj": (jax.random.normal(ks[2], (di, d)) * di ** -0.5).astype(dtype),
    }


def _segsum(a: jax.Array) -> jax.Array:
    """exp-safe segment-sum: out[..., i, j] = sum a[..., j+1..i] (i>=j)."""
    l = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, diff, -jnp.inf)


def mamba2_block(x: jax.Array, params: dict, cfg: ArchConfig) -> jax.Array:
    """Chunked SSD. x (B,L,D); L padded internally to a chunk multiple
    (causality makes trailing zero-pad inert for real positions)."""
    c = cfg.ssm
    di = d_inner_of(cfg)
    p = c.head_dim
    h = di // p
    n = c.state_dim
    cl = c.chunk
    b, l_in, _ = x.shape
    pad = (-l_in) % cl
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    l = l_in + pad
    nc = l // cl

    proj = jnp.einsum("bld,dk->blk", x, params["in_proj"])
    z, xbc, dt_raw = jnp.split(proj, [di, 2 * di + 2 * n], axis=-1)
    xbc = jax.nn.silu(_causal_conv(xbc, params["conv_w"], params["conv_b"]))
    xs, bmat, cmat = jnp.split(xbc, [di, di + n], axis=-1)
    xs = xs.reshape(b, l, h, p)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias_h"])  # (B,L,H)
    a = -jnp.exp(params["a_log_h"])  # (H,)
    da = dt * a  # (B,L,H) log-decay per step

    # chunked views
    dac = da.reshape(b, nc, cl, h).transpose(0, 1, 3, 2)  # (B,nc,H,cl)
    xc = xs.reshape(b, nc, cl, h, p)
    bc = bmat.reshape(b, nc, cl, n).astype(jnp.float32)
    cc = cmat.reshape(b, nc, cl, n).astype(jnp.float32)
    dtc = dt.reshape(b, nc, cl, h)

    # 1) intra-chunk (quadratic, MXU): Y_diag = (L ∘ C Bᵀ) · (dt x)
    lmat = jnp.exp(_segsum(dac))  # (B,nc,H,cl,cl)
    cb = jnp.einsum("bzin,bzjn->bzij", cc, bc)  # (B,nc,cl,cl)
    w = cb[:, :, None] * lmat  # (B,nc,H,cl,cl)
    y_diag = jnp.einsum("bzhij,bzjh,bzjhp->bzihp", w, dtc, xc.astype(jnp.float32))

    # 2) chunk end-states: S_z = Σ_j exp(Σ_{j+1..end} a) dt_j B_j x_jᵀ
    a_cum = jnp.cumsum(dac, axis=-1)  # (B,nc,H,cl)
    a_total = a_cum[..., -1:]  # (B,nc,H,1)
    decay_to_end = jnp.exp(a_total - a_cum)  # (B,nc,H,cl)
    s_chunk = jnp.einsum(
        "bzhj,bzjh,bzjn,bzjhp->bzhnp", decay_to_end, dtc, bc,
        xc.astype(jnp.float32),
    )  # (B,nc,H,N,P)

    # 3) inter-chunk recurrence (scan over nc)
    def step(s, inp):
        s_c, a_tot = inp  # (B,H,N,P), (B,H)
        s_new = jnp.exp(a_tot)[..., None, None] * s + s_c
        return s_new, s  # emit state *before* this chunk

    s0 = jnp.zeros((b, h, n, p), jnp.float32)
    _, s_prev = jax.lax.scan(
        step, s0,
        (s_chunk.transpose(1, 0, 2, 3, 4), a_total[..., 0].transpose(1, 0, 2)),
    )
    s_prev = s_prev.transpose(1, 0, 2, 3, 4)  # (B,nc,H,N,P) state entering chunk

    # 4) inter-chunk contribution: Y_off = exp(a_cum) C · S_prev
    y_off = jnp.einsum(
        "bzhi,bzin,bzhnp->bzihp", jnp.exp(a_cum), cc, s_prev
    )

    y = (y_diag + y_off).reshape(b, l, h, p)
    y = y + params["d_h"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, l, di) * jax.nn.silu(z.astype(jnp.float32))
    if pad:
        y = y[:, :l_in]
    # group norm (simplified to rmsnorm over di)
    from repro.models.layers import rmsnorm

    y = rmsnorm(y.astype(x.dtype), params["norm_scale"], cfg.norm_eps)
    return jnp.einsum("bld,dk->blk", y, params["out_proj"])


def mamba2_decode(
    x: jax.Array, params: dict, cfg: ArchConfig, cache: SSMCache
) -> Tuple[jax.Array, SSMCache]:
    c = cfg.ssm
    di = d_inner_of(cfg)
    p = c.head_dim
    h = di // p
    n = c.state_dim
    b = x.shape[0]
    proj = jnp.einsum("bld,dk->blk", x, params["in_proj"])
    z, xbc, dt_raw = jnp.split(proj, [di, 2 * di + 2 * n], axis=-1)
    conv_in = jnp.concatenate([cache.conv, xbc], axis=1)
    xbc = jnp.einsum("bkd,kd->bd", conv_in, params["conv_w"])[:, None, :] + params["conv_b"]
    xbc = jax.nn.silu(xbc)
    xs, bmat, cmat = jnp.split(xbc, [di, di + n], axis=-1)
    xs = xs.reshape(b, h, p)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias_h"])  # (B,H)
    a = -jnp.exp(params["a_log_h"])
    decay = jnp.exp(dt * a)  # (B,H)
    s = decay[..., None, None] * cache.state + jnp.einsum(
        "bh,bn,bhp->bhnp", dt, bmat[:, 0].astype(jnp.float32),
        xs.astype(jnp.float32),
    )
    y = jnp.einsum("bn,bhnp->bhp", cmat[:, 0].astype(jnp.float32), s)
    y = y + params["d_h"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, 1, di) * jax.nn.silu(z.astype(jnp.float32))
    from repro.models.layers import rmsnorm

    y = rmsnorm(y.astype(x.dtype), params["norm_scale"], cfg.norm_eps)
    out = jnp.einsum("bld,dk->blk", y, params["out_proj"])
    return out, SSMCache(conv=conv_in[:, 1:], state=s)
