"""Batched topic-inference serving engine over a frozen trained model.

This is the deployment half of the paper's system (§4.3 "Model
inference"): training produces ``N_w|k``/``N_k``; downstream traffic is
unseen documents whose topic mixture theta must be inferred at high
throughput. The engine:

* freezes the trained counts into a :class:`FrozenLDAModel` (plus any
  backend-specific sampling tables via ``SamplerBackend.prepare_infer`` —
  e.g. ``zen_cdf`` builds its per-word CDFs once, for the engine's whole
  lifetime);
* packs incoming documents into **length-bucketed padded batches** — one
  slot array per bucket width, so every jitted sweep sees a fixed shape
  and XLA compiles each bucket exactly once;
* runs continuously-admitting multi-document CGS sweeps through the
  ``repro.algorithms`` registry's ``infer_sweep`` capability: finished
  slots are refilled from the queue every step (the continuous-batching
  idea of ``serving/engine.py``, applied to Gibbs sweeps instead of
  decode steps).

Statistical contract: each request's chain consumes randomness only from
its own key, with the same schedule as the single-doc oracle
``repro.core.inference.cgs_infer`` (z0 from ``randint(key)``, sweep j
from ``split(key)[j]``). For the default (dense) backend with cdf
sampling this makes a served document's theta *bit-identical* to
``cgs_infer(key, ...)`` regardless of bucket padding or batch
composition — the property ``tests/test_lda_engine.py`` pins down.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import algorithms
from repro.algorithms import SamplerKnobs
from repro.core.types import LDAHyperParams


@dataclasses.dataclass(frozen=True)
class FrozenLDAModel:
    """A trained LDA model frozen for serving: counts + hyper-parameters."""

    n_wk: jax.Array  # (W, K) int32 word-topic counts
    n_k: jax.Array  # (K,) int32 topic totals
    hyper: LDAHyperParams

    @property
    def num_words(self) -> int:
        return int(self.n_wk.shape[0])

    @property
    def num_topics(self) -> int:
        return int(self.n_wk.shape[1])

    def phi(self) -> jax.Array:
        """Smoothed topic-word distributions, (W, K) column-normalized."""
        w_beta = self.num_words * self.hyper.beta
        return (self.n_wk.astype(jnp.float32) + self.hyper.beta) / (
            self.n_k.astype(jnp.float32) + w_beta
        )[None, :]

    @classmethod
    def from_state(cls, state, hyper: LDAHyperParams) -> "FrozenLDAModel":
        """Freeze a trainer ``CGSState`` (single-box or gathered)."""
        return cls(
            n_wk=jnp.asarray(state.n_wk, jnp.int32),
            n_k=jnp.asarray(state.n_k, jnp.int32),
            hyper=hyper,
        )

    @classmethod
    def from_checkpoint(cls, directory: str) -> "FrozenLDAModel":
        """Load the newest committed model checkpoint (see
        ``repro.train.checkpoint.save_lda_model``)."""
        from repro.train.checkpoint import load_lda_model

        n_wk, n_k, hyper, _meta, _step = load_lda_model(directory)
        return cls(
            n_wk=jnp.asarray(n_wk, jnp.int32),
            n_k=jnp.asarray(n_k, jnp.int32),
            hyper=hyper,
        )


@dataclasses.dataclass(frozen=True)
class LDAServeConfig:
    """Engine knobs.

    ``burn_in < 0`` (default) reproduces the oracle estimator: theta from
    the final sweep's doc-topic counts. ``burn_in >= 0`` switches to the
    posterior-mean estimator: counts are sampled every ``thin`` sweeps
    after the first ``burn_in`` and theta is their average — better
    quality per sweep, no longer bit-comparable to ``cgs_infer``.
    """

    buckets: Tuple[int, ...] = (32, 64, 128, 256)
    max_batch: int = 32  # slots per bucket
    num_sweeps: int = 10
    burn_in: int = -1  # < 0 => final-sweep theta (oracle-compatible)
    thin: int = 1
    algorithm: str = "zen"  # any algorithms.registered() name
    sampling_method: str = "cdf"  # cdf | gumbel (dense default path)
    max_kd: int = 0  # zen_cdf doc-row width (0 = backend default)

    def knobs(self) -> SamplerKnobs:
        return SamplerKnobs(
            sampling_method=self.sampling_method, max_kd=self.max_kd
        )


@dataclasses.dataclass
class InferRequest:
    uid: int
    words: np.ndarray  # filtered (and possibly truncated) token ids
    key: jax.Array  # the request's whole-chain PRNG key
    num_sweeps: int
    burn_in: int
    thin: int
    orig_len: int = 0
    truncated: bool = False
    dropped_unknown: int = 0
    theta: Optional[np.ndarray] = None
    done: bool = False
    # in-flight bookkeeping
    sweeps_done: int = 0
    theta_sum: Optional[np.ndarray] = None
    theta_samples: int = 0


class _Bucket:
    """One fixed-shape slot batch: all device state for bucket width L."""

    def __init__(self, length: int, slots: int, num_topics: int):
        self.length = length
        self.words = jnp.zeros((slots, length), jnp.int32)
        self.mask = jnp.zeros((slots, length), bool)
        self.z = jnp.zeros((slots, length), jnp.int32)
        self.n_kd = jnp.zeros((slots, num_topics), jnp.int32)
        self.active: List[Optional[InferRequest]] = [None] * slots
        self.sweep_keys: List[Optional[jax.Array]] = [None] * slots

    def free_slot(self) -> Optional[int]:
        for s, r in enumerate(self.active):
            if r is None:
                return s
        return None

    @property
    def num_active(self) -> int:
        return sum(r is not None for r in self.active)


class LDAEngine:
    """Continuously-admitting batched frozen-model inference."""

    def __init__(self, model: FrozenLDAModel, cfg: LDAServeConfig,
                 seed: int = 0):
        if not cfg.buckets:
            raise ValueError("need at least one bucket length")
        self.model = model
        self.cfg = cfg
        self.backend = algorithms.get(cfg.algorithm)
        self._knobs = cfg.knobs()
        self._aux = self.backend.prepare_infer(
            model.n_wk, model.n_k, model.hyper, self._knobs
        )
        self._alpha_k = np.asarray(model.hyper.alpha_k(model.n_k), np.float32)
        self._buckets = {
            length: _Bucket(length, cfg.max_batch, model.num_topics)
            for length in sorted(cfg.buckets)
        }
        self._sweep_fns: Dict[int, Any] = {}
        self._base_key = jax.random.key(seed)
        self._dummy_key = jax.random.key(0)
        self.queue: List[InferRequest] = []
        self._instant: List[InferRequest] = []  # empty docs: done at submit
        self._uid = 0
        self.docs_done = 0
        self.sweeps_run = 0  # jitted bucket sweeps executed

    # -- request intake ----------------------------------------------------
    def submit(
        self,
        words,
        key: Optional[jax.Array] = None,
        num_sweeps: Optional[int] = None,
        burn_in: Optional[int] = None,
        thin: Optional[int] = None,
    ) -> int:
        """Queue one document; returns its uid.

        Unknown word ids (outside the model vocabulary) are dropped;
        over-long documents are truncated to the widest bucket; a document
        that ends up empty completes immediately with the prior theta.
        """
        self._uid += 1
        raw = np.asarray(words, np.int32).ravel()
        known = raw[(raw >= 0) & (raw < self.model.num_words)]
        max_len = max(self._buckets)
        req = InferRequest(
            uid=self._uid,
            words=known[:max_len],
            key=key if key is not None
            else jax.random.fold_in(self._base_key, self._uid),
            num_sweeps=self.cfg.num_sweeps if num_sweeps is None
            else num_sweeps,
            burn_in=self.cfg.burn_in if burn_in is None else burn_in,
            thin=max(1, self.cfg.thin if thin is None else thin),
            orig_len=int(raw.shape[0]),
            truncated=known.shape[0] > max_len,
            dropped_unknown=int(raw.shape[0] - known.shape[0]),
        )
        if req.words.shape[0] == 0:
            # nothing observed: theta is the normalized prior
            req.theta = self._alpha_k / self._alpha_k.sum()
            req.done = True
            self.docs_done += 1
            self._instant.append(req)
        elif req.num_sweeps <= 0:
            # zero sweeps: theta straight from the z0 assignment, matching
            # the oracle's empty scan (never occupies a slot)
            z0 = np.asarray(jax.random.randint(
                req.key, (req.words.shape[0],), 0, self.model.num_topics,
                dtype=jnp.int32,
            ))
            n_kd0 = np.bincount(
                z0, minlength=self.model.num_topics
            ).astype(np.int32)
            req.theta = self._theta(req, n_kd0)
            req.done = True
            self.docs_done += 1
            self._instant.append(req)
        else:
            self.queue.append(req)
        return req.uid

    # -- admission ---------------------------------------------------------
    def _bucket_for(self, length: int) -> _Bucket:
        for bl in sorted(self._buckets):
            if length <= bl:
                return self._buckets[bl]
        return self._buckets[max(self._buckets)]

    def _admit(self) -> None:
        still_queued = []
        for req in self.queue:
            bucket = self._bucket_for(req.words.shape[0])
            slot = bucket.free_slot()
            if slot is None:
                still_queued.append(req)
                continue
            self._place(req, bucket, slot)
        self.queue = still_queued

    def _place(self, req: InferRequest, bucket: _Bucket, slot: int) -> None:
        l, k = bucket.length, self.model.num_topics
        n = req.words.shape[0]
        words = np.zeros(l, np.int32)
        words[:n] = req.words
        mask = np.zeros(l, bool)
        mask[:n] = True
        # same schedule as cgs_infer: z0 from the request key itself, sweep
        # j from split(key)[j]; randint/uniform draws are prefix-stable in
        # the padded length, so the bucket width never changes the chain
        z0 = jax.random.randint(req.key, (l,), 0, k, dtype=jnp.int32)
        z0_np = np.asarray(z0)
        n_kd = np.bincount(z0_np[:n], minlength=k).astype(np.int32)
        bucket.words = bucket.words.at[slot].set(jnp.asarray(words))
        bucket.mask = bucket.mask.at[slot].set(jnp.asarray(mask))
        bucket.z = bucket.z.at[slot].set(z0)
        bucket.n_kd = bucket.n_kd.at[slot].set(jnp.asarray(n_kd))
        bucket.active[slot] = req
        bucket.sweep_keys[slot] = (
            jax.random.split(req.key, req.num_sweeps)
            if req.num_sweeps > 0 else None
        )

    # -- the jitted per-bucket sweep ----------------------------------------
    def _sweep_fn(self, length: int):
        if length not in self._sweep_fns:
            backend, hyper, knobs = self.backend, self.model.hyper, self._knobs

            def fn(keys, words, mask, z, n_kd, n_wk, n_k, aux):
                z_new = backend.infer_sweep(
                    keys, words, mask, z, n_kd, n_wk, n_k, hyper, knobs, aux
                )
                z_new = jnp.where(mask, z_new, z)
                onehot = (
                    jax.nn.one_hot(z_new, hyper.num_topics, dtype=jnp.int32)
                    * mask[..., None]
                )
                return z_new, jnp.sum(onehot, axis=1)

            self._sweep_fns[length] = jax.jit(fn)
        return self._sweep_fns[length]

    # -- stepping ----------------------------------------------------------
    def step(self) -> List[InferRequest]:
        """Admit, run one sweep per non-empty bucket, finish ripe requests."""
        self._admit()
        finished, self._instant = self._instant, []
        for bucket in self._buckets.values():
            if bucket.num_active == 0:
                continue
            keys = jnp.stack([
                bucket.sweep_keys[s][bucket.active[s].sweeps_done]
                if bucket.active[s] is not None
                and bucket.sweep_keys[s] is not None
                and bucket.active[s].sweeps_done
                < bucket.active[s].num_sweeps
                else self._dummy_key
                for s in range(len(bucket.active))
            ])
            bucket.z, bucket.n_kd = self._sweep_fn(bucket.length)(
                keys, bucket.words, bucket.mask, bucket.z, bucket.n_kd,
                self.model.n_wk, self.model.n_k, self._aux,
            )
            self.sweeps_run += 1
            n_kd_host = None
            for slot, req in enumerate(bucket.active):
                if req is None:
                    continue
                req.sweeps_done += 1
                want_sample = (
                    req.burn_in >= 0
                    and req.sweeps_done > req.burn_in
                    and (req.sweeps_done - req.burn_in) % req.thin == 0
                )
                ripe = req.sweeps_done >= req.num_sweeps
                if want_sample or ripe:
                    if n_kd_host is None:
                        n_kd_host = np.asarray(bucket.n_kd)
                    if want_sample:
                        if req.theta_sum is None:
                            req.theta_sum = np.zeros(
                                self.model.num_topics, np.float32
                            )
                        req.theta_sum += self._theta(req, n_kd_host[slot])
                        req.theta_samples += 1
                if ripe:
                    self._finish(req, bucket, slot,
                                 None if n_kd_host is None
                                 else n_kd_host[slot])
                    finished.append(req)
        return finished

    def _theta(self, req: InferRequest, n_kd_row: np.ndarray) -> np.ndarray:
        l = req.words.shape[0]
        return (n_kd_row.astype(np.float32) + self._alpha_k) / (
            l + self._alpha_k.sum()
        )

    def _finish(self, req: InferRequest, bucket: _Bucket, slot: int,
                n_kd_row: Optional[np.ndarray]) -> None:
        if req.theta_samples:
            req.theta = req.theta_sum / req.theta_samples
        else:
            if n_kd_row is None:  # num_sweeps == 0: counts from z0
                n_kd_row = np.asarray(bucket.n_kd[slot])
            req.theta = self._theta(req, n_kd_row)
        req.done = True
        bucket.active[slot] = None
        bucket.sweep_keys[slot] = None
        bucket.mask = bucket.mask.at[slot].set(False)
        self.docs_done += 1

    def run_until_done(self, max_steps: int = 100_000) -> List[InferRequest]:
        done: List[InferRequest] = list(self._instant)
        self._instant = []
        for _ in range(max_steps):
            done.extend(self.step())
            if not self.queue and all(
                b.num_active == 0 for b in self._buckets.values()
            ):
                break
        return done

    def infer_batch(self, docs: Sequence, **submit_kw) -> np.ndarray:
        """Submit many documents, drain the engine, return (N, K) thetas in
        submission order."""
        uids = [self.submit(d, **submit_kw) for d in docs]
        by_uid = {r.uid: r for r in self.run_until_done()}
        missing = [u for u in uids if u not in by_uid]
        if missing:
            raise RuntimeError(f"engine did not finish requests {missing}")
        return np.stack([by_uid[u].theta for u in uids])


# -- held-out evaluation ---------------------------------------------------
def doc_completion_perplexity(
    engine: LDAEngine, docs: Sequence[np.ndarray]
) -> float:
    """Doc-completion held-out perplexity (Wallach et al.'s estimator).

    Each document is split alternately into an observed half (theta is
    inferred on it through the engine) and a held-out half, scored as
    ``p(w | theta, phi)``. Lower is better; this is the serving-quality
    number ``launch/serve_lda.py --eval`` reports.
    """
    observed, heldout = [], []
    for d in docs:
        d = np.asarray(d, np.int32)
        observed.append(d[0::2])
        heldout.append(d[1::2])
    thetas = engine.infer_batch(observed)  # (N, K)
    phi = np.asarray(engine.model.phi(), np.float32)  # (W, K)
    total_ll, total_tokens = 0.0, 0
    for theta, held in zip(thetas, heldout):
        held = held[(held >= 0) & (held < engine.model.num_words)]
        if held.shape[0] == 0:
            continue
        p = phi[held] @ theta  # (n,)
        total_ll += float(np.sum(np.log(np.maximum(p, 1e-30))))
        total_tokens += int(held.shape[0])
    if total_tokens == 0:
        return float("nan")
    return float(np.exp(-total_ll / total_tokens))


def docs_from_corpus(corpus) -> List[np.ndarray]:
    """Split an edge-list ``Corpus`` into per-document token arrays."""
    words = np.asarray(corpus.word)
    docs = np.asarray(corpus.doc)
    order = np.argsort(docs, kind="stable")
    words, docs = words[order], docs[order]
    bounds = np.searchsorted(docs, np.arange(corpus.num_docs + 1))
    return [words[bounds[d]:bounds[d + 1]] for d in range(corpus.num_docs)]
