"""Quickstart: train ZenLDA on a synthetic corpus and print topics.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import LDAHyperParams, LDATrainer, TrainConfig
from repro.data import synthetic_lda_corpus


def main():
    corpus, true_phi = synthetic_lda_corpus(
        seed=0, num_docs=200, num_words=300, num_topics=10, avg_doc_len=50
    )
    hyper = LDAHyperParams(num_topics=10, alpha=0.1, beta=0.01)
    trainer = LDATrainer(corpus, hyper, TrainConfig(algorithm="zen"))

    state = trainer.init_state(jax.random.key(0))
    print(f"corpus: {corpus.num_tokens} tokens, llh0 = {trainer.llh(state):.1f}")
    for it in range(1, 31):
        state = trainer.step(state)
        if it % 10 == 0:
            print(f"iter {it:3d}  llh {trainer.llh(state):12.1f}  "
                  f"perplexity {trainer.perplexity(state):8.2f}  "
                  f"change_rate {trainer.change_rate(state):.3f}")

    # top words per learned topic
    n_wk = np.asarray(state.n_wk)
    print("\ntop words per topic:")
    for k in range(hyper.num_topics):
        top = np.argsort(-n_wk[:, k])[:8]
        print(f"  topic {k:2d}: {top.tolist()}")


if __name__ == "__main__":
    main()
