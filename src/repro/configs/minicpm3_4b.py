"""minicpm3-4b [dense]: 62L d_model=2560 40H d_ff=6400 vocab=73448, MLA
(multi-head latent attention). [hf:openbmb/MiniCPM3-4B; hf]

MLA latent KV (kv_lora_rank + rope dims per token) is the arch's memory
feature; decode caches store latents only. Pure full attention ->
long_500k skipped (DESIGN.md §4).
"""
from repro.configs.base import ArchConfig, MLAConfig

CONFIG = ArchConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    head_dim=96,  # qk_nope(64) + qk_rope(32)
    d_ff=6400,
    vocab_size=73448,
    mla=MLAConfig(
        kv_lora_rank=256,
        q_lora_rank=768,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
    ),
    tie_embeddings=True,
    skip_shapes=("long_500k",),
)
