"""Paper §4.1 partitioning study: balance + replication across strategies
(RandomVertexCut / EdgePartition1D / 2D / DBH / DBH+), and the padding
overhead of the SPMD grid layouts the TPU runtime actually uses."""
from __future__ import annotations

import numpy as np

from benchmarks.common import row
from repro.core.graph import PARTITIONERS, grid_partition, partition_metrics
from repro.data import synthetic_corpus


def main():
    c = synthetic_corpus(7, num_docs=2000, num_words=1500, avg_doc_len=20,
                         zipf_a=1.4)
    w, d = np.asarray(c.word), np.asarray(c.doc)
    for name, fn in PARTITIONERS.items():
        m = partition_metrics(w, d, fn(w, d, 16), 16)
        row(f"sec41_{name}", 0.0,
            f"balance={m['edge_balance']:.3f};repl={m['total_replication']:.3f}")
    for bal in ("lpt", "hash"):
        g = grid_partition(c, 4, 4, balance=bal)
        row(f"sec41_grid_{bal}", 0.0,
            f"padding_overhead={g.padding_overhead:.4f}")
